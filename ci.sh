#!/usr/bin/env bash
# CI gate for the sten crate. Run from the repo root.
#
# Tier-1 (build + tests) is the hard gate that catches missing-manifest-class
# regressions (the seed shipped without a Cargo.toml and could not build at
# all). Then two timed --release gates (serving stress, forward_latency
# --smoke) catch lock and thread-pool regressions as loud wall-clock
# failures, and fmt/clippy run strict — the legacy STEN_CI_LENIENT escape
# hatch is gone now that the lint debt is burned down.
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "==> xtask lint (unsafe/SAFETY, guard-across-scope, spawn, shim + SIMD invariants)"
# Fail-fast static gate: every `unsafe` carries a SAFETY comment, no lock
# guard is held across a threadpool scope call, threads are only spawned
# under util/, shim-ported files never name std::sync directly, std::arch
# intrinsics live only under kernels/simd/, and every #[target_feature] fn
# sits behind a runtime feature-detection guard.
cargo run -q -p xtask -- lint

echo "==> tier-1: cargo build --release"
cargo build --release

echo "==> tier-1: cargo test -q"
cargo test -q

echo "==> tier-1 under forced scalar backend"
# The scalar backend is the bit-identical reference every SIMD kernel is
# judged against, so it must stay green on its own — a SIMD-only fix that
# silently breaks the scalar path fails here.
STEN_BACKEND=scalar cargo test -q --lib

echo "==> backend parity harness (golden vectors, scalar vs SIMD)"
# Generates golden vectors from the forced-scalar backend, then checks every
# runtime artifact on both backends against them within per-seam tolerances
# (bit-identical where the seam demands it). A drifting SIMD kernel fails
# here before it can skew any benchmark.
cargo test -q --test backend_parity

echo "==> xtask self-tests"
cargo test -q -p xtask

echo "==> loom interleaving suite (model-checked sync primitives, 600s ceiling)"
# Exhaustively explores bounded thread interleavings of the threadpool,
# channel, and completion latch through the util::sync shim. The ceiling
# turns a state-space blowup into a loud failure rather than a hung CI.
timeout 600 cargo test --features loom --test loom

echo "==> timed serving stress test (release, 600s ceiling)"
# Exactly-once completion under submitter contention, run optimized and
# timed: a reintroduced global lock on the serving hot path (completion
# store, runtime timing, prepared-artifact map) shows up here as a loud
# wall-clock regression even while the assertions still pass; the timeout
# turns an outright deadlock into a loud failure too.
time timeout 600 cargo test --release --test serving_stress -- --nocapture

echo "==> building bench targets"
cargo build --release --benches

echo "==> forward_latency --smoke (pool + tensor-parallel gate, 300s ceiling)"
# Runs the tiny-config latency breakdown and asserts zero thread spawns per
# request in steady state — for the global pool AND for the sharded
# (tensor-parallel) model, whose W-thread shard pool and ring-collective
# group are built once at shard() time. Also asserts the sharded forward is
# bit-identical to the unsharded engine at every swept width. The
# wall-clock ceiling turns a deadlocked parked pool worker or a stuck
# collective barrier into a loud failure.
timeout 300 cargo bench --bench forward_latency -- --smoke

echo "==> fig10_gemm --smoke (kernel correctness gate, 300s ceiling)"
# Small-shape Fig.10 sweep with every kernel (blocked and baseline n:m:g,
# CSR, blocked and naive BCSR) asserted allclose against the densified
# dense-GEMM reference before timing — a cache-blocking bug that silently
# skews results fails here as an assertion, not as a bad benchmark number.
timeout 300 cargo bench --bench fig10_gemm -- --smoke

echo "==> serving_arrivals --smoke (open-loop scheduler + overload gate, 300s ceiling)"
# Paced open-loop (non-blocking submit) arrivals on a 1-model and a 2-model
# mix: a trivial-load point per mix asserts zero steady-state thread spawns
# and a sane SLO-miss fraction, then one defended overload point (offered
# >> capacity, admission + shedding on) asserts goodput holds a floor
# instead of collapsing and that shed/reject/degrade counts surface in
# BENCH_serving_arrivals.json — so a continuous-batching regression
# (starvation, stalled workers, queues that never drain, silent drops)
# fails loudly here instead of only under real traffic.
timeout 300 cargo bench --bench serving_arrivals -- --smoke

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy -- -D warnings"
cargo clippy --workspace --all-targets -- -D warnings

echo "CI OK"
