#!/usr/bin/env bash
# CI gate for the sten crate. Run from the repo root.
#
# Tier-1 (build + tests) is the hard gate that catches missing-manifest-class
# regressions (the seed shipped without a Cargo.toml and could not build at
# all). fmt/clippy run after it; export STEN_CI_LENIENT=1 to downgrade the
# style gates to warnings while burning down legacy lint debt.
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "==> tier-1: cargo build --release"
cargo build --release

echo "==> tier-1: cargo test -q"
cargo test -q

echo "==> timed serving stress test (release)"
# Exactly-once completion under submitter contention, run optimized and
# timed: a reintroduced global lock on the serving hot path (completion
# store, runtime timing, prepared-artifact map) shows up here as a loud
# wall-clock regression even while the assertions still pass.
time cargo test --release --test serving_stress -- --nocapture

echo "==> building bench targets"
cargo build --release --benches

style() {
    if [[ "${STEN_CI_LENIENT:-0}" == "1" ]]; then
        "$@" || echo "WARN (lenient): '$*' failed"
    else
        "$@"
    fi
}

echo "==> cargo fmt --check"
style cargo fmt --check

echo "==> cargo clippy -- -D warnings"
style cargo clippy --all-targets -- -D warnings

echo "CI OK"
