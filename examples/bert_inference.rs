//! Sparse end-to-end encoder inference with the batching coordinator (Fig. 11).
//!
//! Loads the AOT encoder artifacts, serves batched requests with the FFN
//! executed (a) as a dense PJRT artifact, (b) as a native dense GEMM, and
//! (c) through the native n:m:g sparse GEMM, and reports median latency,
//! throughput and the STen-vs-runtime latency breakdown.
//!
//! Run: `cargo run --release --example bert_inference -- --tag base --requests 32`

use std::time::Duration;

use anyhow::Result;
use sten::coordinator::{BatchServer, Engine, FfnMode};
use sten::runtime::ArtifactRuntime;
use sten::util::cli::Args;
use sten::util::rng::Pcg64;

fn run_mode(tag: &str, mode: FfnMode, requests: usize) -> Result<(f64, f64, Vec<(&'static str, f64)>)> {
    let rt = ArtifactRuntime::open_default()?;
    let mut engine = Engine::new(rt, tag, mode, 42)?;
    // Warm up (compiles artifacts).
    let mut rng = Pcg64::seeded(5);
    let tokens = engine.random_tokens(&mut rng);
    engine.forward(&tokens)?;
    engine.reset_timing();

    let mut server = BatchServer::new(engine, Duration::from_millis(2));
    let seq = server.engine().dims.seq;
    let vocab = server.engine().dims.vocab as u32;
    for _ in 0..requests {
        let toks: Vec<i32> = (0..seq).map(|_| rng.below(vocab) as i32).collect();
        server.submit(&toks);
    }
    server.run_until_drained()?;
    let lat = server.median_latency().unwrap_or(0.0);
    let thr = server.throughput().unwrap_or(0.0);
    let breakdown = server.engine().timing().sorted();
    Ok((lat, thr, breakdown))
}

fn main() -> Result<()> {
    let args = Args::parse();
    let tag = args.get_or("tag", "tiny");
    let requests: usize = args.num("requests", 32);

    println!("mode\tmedian_latency_ms\tthroughput_req_s\tbreakdown");
    let modes: Vec<(&str, FfnMode)> = vec![
        ("dense-artifact (PyTorch-baseline analog)", FfnMode::DenseArtifact),
        ("native-dense", FfnMode::NativeDense),
        ("nmg-2:4:4 (STen)", FfnMode::NativeNmg { n: 2, m: 4, g: 4 }),
        ("nmg-1:4:4 (STen, 75%)", FfnMode::NativeNmg { n: 1, m: 4, g: 4 }),
    ];
    let mut dense_lat = None;
    for (label, mode) in modes {
        let (lat, thr, breakdown) = run_mode(&tag, mode, requests)?;
        dense_lat.get_or_insert(lat);
        let speedup = dense_lat.unwrap() / lat;
        let bd: Vec<String> = breakdown
            .iter()
            .map(|(k, v)| format!("{k}={:.1}ms", v * 1e3))
            .collect();
        println!(
            "{label}\t{:.2}\t{:.1}\t[{}]  ({speedup:.2}x vs dense artifact)",
            lat * 1e3,
            thr,
            bd.join(" ")
        );
    }
    println!("\nbert_inference OK");
    Ok(())
}
