//! Distributed data-parallel masked training (§4.6 + the §6.1 weak-scaling
//! experiment, simulated with in-process workers and a real ring allreduce).
//!
//! Each worker holds a replica of the masked MLP and computes gradients on
//! its own shard; gradients are synchronized per step with the configured
//! strategy (dense / sparse-resparsify / sparse-fixed-pattern). Reports the
//! per-step time split and verifies replicas stay bit-identical.
//!
//! Run: `cargo run --release --example distributed_training -- --workers 4 --steps 30`

use std::collections::BTreeMap;

use anyhow::Result;
use sten::autograd::Tape;
use sten::dist::collective::RingAllreduce;
use sten::dist::ddp::{sync_gradients, GradSyncMode, GradSyncStats};
use sten::formats::{AnyTensor, MaskedTensor};
use sten::model::MlpSpec;
use sten::tensor::DenseTensor;
use sten::train::data::ClusterDataset;
use sten::train::masked::{compute_mask, MaskFormat};
use sten::util::cli::Args;
use sten::util::rng::Pcg64;

fn main() -> Result<()> {
    let args = Args::parse();
    let workers: usize = args.num("workers", 4);
    let steps: usize = args.num("steps", 30);
    let mode = match args.get_or("mode", "resparsify").as_str() {
        "dense" => GradSyncMode::Dense,
        "fixed" => GradSyncMode::SparseFixedPattern,
        _ => GradSyncMode::SparseResparsify,
    };
    println!("DDP: {workers} workers, {steps} steps, mode {mode:?}");

    let spec = MlpSpec { input_dim: 32, hidden: vec![64], classes: 4 };
    let mut rng = Pcg64::seeded(11);
    // All replicas start from identical parameters (standard DDP).
    let mut params = spec.init(&mut rng);
    // 50% n:m masks on the prunable weights (same everywhere).
    let masks: BTreeMap<String, DenseTensor> = spec
        .prunable_weights()
        .into_iter()
        .map(|nm| {
            let mask = compute_mask(&params[&nm], 0.5, MaskFormat::Nm { m: 4 });
            (nm, mask)
        })
        .collect();
    for (nm, mask) in &masks {
        let w = params[nm].zip(mask, |v, mk| v * mk);
        params.insert(nm.clone(), w);
    }

    let ds = ClusterDataset::new(32, 4, 0.4, 3);
    let ring = RingAllreduce::new(workers);
    let names = spec.weight_names();
    let lr = 0.1f32;

    let mut total = GradSyncStats::default();
    let mut compute_s = 0.0f64;
    let mut last_loss = 0.0f32;
    for step in 0..steps {
        // Per-worker gradient computation (each worker draws its own shard).
        let t = std::time::Instant::now();
        let grads_per_worker: Vec<BTreeMap<String, DenseTensor>> = (0..workers)
            .map(|w| {
                let mut shard_rng = Pcg64::new(1000 + step as u64, w as u64);
                let (x, y) = ds.batch(16, &mut shard_rng);
                let tape = Tape::new();
                let (logits, vars) = spec.forward_tape(&tape, &params, x);
                let loss = tape.softmax_cross_entropy(logits, &y);
                if w == 0 {
                    last_loss = tape.value(loss).data()[0];
                }
                tape.backward(loss).unwrap();
                vars.iter().map(|(nm, v)| (nm.clone(), tape.grad(*v).unwrap())).collect()
            })
            .collect();
        compute_s += t.elapsed().as_secs_f64();

        // Synchronize each parameter's gradient across workers.
        for nm in &names {
            let is_masked = masks.contains_key(nm);
            let per_worker: Vec<AnyTensor> = grads_per_worker
                .iter()
                .map(|g| {
                    let grad = g[nm].clone();
                    if is_masked {
                        AnyTensor::Masked(MaskedTensor::new(grad, masks[nm].clone()))
                    } else {
                        AnyTensor::Dense(grad)
                    }
                })
                .collect();
            let (synced, stats) = sync_gradients(&ring, &per_worker, mode)?;
            total.to_dense_s += stats.to_dense_s;
            total.allreduce_s += stats.allreduce_s;
            total.resparsify_s += stats.resparsify_s;
            // All replicas apply the identical averaged gradient -> replicas
            // stay in sync; verify on the first weight.
            let g0 = synced[0].to_dense();
            for s in &synced[1..] {
                assert!(s.to_dense().allclose(&g0, 1e-6, 1e-6), "replicas diverged");
            }
            let mut w = params[nm].clone();
            w.axpy(-lr, &g0);
            if let Some(mask) = masks.get(nm) {
                w = w.zip(mask, |v, mk| v * mk);
            }
            params.insert(nm.clone(), w);
        }
        if step % 10 == 0 {
            println!("step {step:3}: loss {last_loss:.4}");
        }
    }

    // Sanity: masks held.
    for (nm, mask) in &masks {
        let leaked = params[nm]
            .data()
            .iter()
            .zip(mask.data())
            .filter(|&(v, m)| *m == 0.0 && *v != 0.0)
            .count();
        assert_eq!(leaked, 0, "{nm} leaked {leaked} masked weights");
    }

    println!("\nper-step time split over {steps} steps x {} tensors:", names.len());
    println!("  gradient compute: {:.1} ms/step", compute_s / steps as f64 * 1e3);
    println!("  to_dense:         {:.2} ms/step", total.to_dense_s / steps as f64 * 1e3);
    println!("  allreduce:        {:.2} ms/step", total.allreduce_s / steps as f64 * 1e3);
    println!("  resparsify:       {:.2} ms/step", total.resparsify_s / steps as f64 * 1e3);
    let overhead = total.to_dense_s + total.resparsify_s;
    println!(
        "  sparse-handling overhead: {:.1}% of sync time",
        100.0 * overhead / (overhead + total.allreduce_s).max(1e-12)
    );
    println!("\ndistributed_training OK (replicas consistent, masks held)");
    Ok(())
}
