//! Quickstart: the STen programming model in five minutes.
//!
//! Walks the three core concepts — sparsity layouts, operators, sparsifiers —
//! then sparsifies a small model with the `SparsityBuilder` and runs sparse
//! inference through the dispatcher.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;
use sten::dispatch::{Dispatcher, OutputFormat};
use sten::formats::{AnyTensor, CsrTensor, Layout, NmgTensor};
use sten::model::{MlpSpec, SparsityBuilder};
use sten::ops::OpKind;
use sten::sparsify::{GroupedNm, RandomFraction, ScalarFraction, Sparsifier};
use sten::tensor::DenseTensor;
use sten::util::rng::Pcg64;

fn main() -> Result<()> {
    let mut rng = Pcg64::seeded(42);
    let d = Dispatcher::with_builtins();

    // ----- 1. Sparsity layouts -------------------------------------------
    println!("== sparsity layouts ==");
    let w = DenseTensor::randn(&[64, 96], &mut rng);
    let csr = CsrTensor::from_dense(&ScalarFraction { fraction: 0.9 }.prune(&w));
    let nmg = NmgTensor::from_dense(&w, 2, 4, 4);
    println!("dense:  {} bytes", w.numel() * 4);
    println!("csr@90%: {} bytes ({} nnz)", csr.bytes(), csr.nnz());
    println!("n:m:g 2:4:4: {} bytes ({} nnz)", nmg.bytes(), nmg.nnz());

    // ----- 2. Operators: dispatch picks the right kernel ------------------
    println!("\n== operators ==");
    let x = AnyTensor::Dense(DenseTensor::randn(&[96, 32], &mut rng));
    let y = d.call(OpKind::MatMul, &[AnyTensor::Nmg(nmg), x.clone()])?;
    println!("Nmg x Dense matmul -> {:?} (specialized kernel)", y.shape());
    let y = d.call(OpKind::Softmax, &[AnyTensor::Csr(csr.clone()).clone()])?;
    println!("Softmax on CSR -> {:?} (dense fallback)", y.shape());
    let (hits, conversions, fallbacks) = d.stats.counts();
    println!("dispatch: {hits} hits, {conversions} conversions, {fallbacks} fallbacks");

    // ----- 3. Sparsifiers + sparse operators ------------------------------
    println!("\n== sparsifiers ==");
    let a = AnyTensor::Dense(DenseTensor::randn(&[8, 8], &mut rng));
    let b = AnyTensor::Dense(DenseTensor::randn(&[8, 8], &mut rng));
    // The paper's §3.3 example: add -> random-fraction(0.5) -> CSR.
    let fmt = OutputFormat::external(Box::new(RandomFraction::new(0.5, 7)), Layout::Csr);
    let c = d.call_sparse(OpKind::Add, &[a, b], &fmt)?;
    println!("sparse_add output: layout {:?}, nnz {} / 64", c.layout(), c.nnz());

    // ----- 4. Sparsifying an existing model -------------------------------
    println!("\n== SparsityBuilder ==");
    let spec = MlpSpec { input_dim: 64, hidden: vec![128], classes: 10 };
    let params = spec.init(&mut rng);
    let model = spec.build_graph(&params);
    println!("dense model: {} bytes", model.param_bytes());

    let mut sb = SparsityBuilder::new();
    sb.set_weight("fc0.w", Box::new(GroupedNm { n: 2, m: 4, g: 4 }), Layout::Nmg);
    sb.set_weight("fc1.w", Box::new(ScalarFraction { fraction: 0.9 }), Layout::Csr);
    let sparse = sb.get_sparse_model(model)?;
    println!("sparse model: {} bytes", sparse.param_bytes());

    let x = AnyTensor::Dense(DenseTensor::randn(&[4, 64], &mut rng));
    let logits = sparse.forward(&d, &[x])?;
    println!("sparse forward -> {:?}", logits.shape());
    println!("\nquickstart OK");
    Ok(())
}
