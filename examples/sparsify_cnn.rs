//! Productivity study (§6.2, Table 2 / Fig. 12): one-shot, iterative, and
//! layer-wise magnitude pruning of a classifier to 50% sparsity.
//!
//! The paper fine-tunes Wide ResNet-16-8 on CIFAR10; our substitute (see
//! DESIGN.md §Substitutions) is an MLP on a synthetic CIFAR-shaped cluster
//! dataset. What is measured is the same: each schedule is a few lines of
//! code over the same training loop, and each recovers (approximately) the
//! dense accuracy at 50% sparsity.
//!
//! Run: `cargo run --release --example sparsify_cnn -- --steps 400`
//! Writes `sparsify_loss.csv` (schedule, step, loss, sparsity).

use std::io::Write as _;

use anyhow::Result;
use sten::model::MlpSpec;
use sten::train::data::ClusterDataset;
use sten::train::masked::{MaskFormat, MaskedTrainer};
use sten::train::schedule::PruneSchedule;
use sten::util::cli::Args;
use sten::util::rng::Pcg64;

struct Outcome {
    name: &'static str,
    accuracy: f64,
    sparsity: f64,
    /// Lines of code of the schedule definition (Table 2's metric).
    loc: usize,
}

fn train(
    name: &'static str,
    schedule: Option<PruneSchedule>,
    loc: usize,
    steps: usize,
    csv: &mut std::fs::File,
) -> Result<Outcome> {
    let spec = MlpSpec { input_dim: 64, hidden: vec![128, 128], classes: 10 };
    let mut rng = Pcg64::seeded(2024);
    let params = spec.init(&mut rng);
    let mut trainer = MaskedTrainer::new(spec, params, 0.1, MaskFormat::Unstructured);
    let ds = ClusterDataset::new(64, 10, 0.45, 7);
    let mut data_rng = Pcg64::seeded(31);

    for step in 0..steps {
        if let Some(s) = &schedule {
            if let Some(event) = s.event_at(step) {
                trainer.apply_event(&event);
            }
        }
        let (x, y) = ds.batch(64, &mut data_rng);
        let loss = trainer.step(&x, &y)?;
        if step % 5 == 0 {
            writeln!(csv, "{name},{step},{loss},{:.3}", trainer.sparsity())?;
        }
    }
    let (xe, ye) = ds.batch(2048, &mut data_rng);
    let accuracy = ClusterDataset::accuracy(&trainer.logits(&xe), &ye);
    Ok(Outcome { name, accuracy, sparsity: trainer.sparsity(), loc })
}

fn main() -> Result<()> {
    let args = Args::parse();
    let steps: usize = args.num("steps", 400);
    let mut csv = std::fs::File::create(args.get_or("out", "sparsify_loss.csv"))?;
    writeln!(csv, "schedule,step,loss,sparsity")?;

    // Table 2: each schedule is a handful of lines on the shared loop.
    let runs = vec![
        train("dense", None, 0, steps, &mut csv)?,
        train(
            "one-shot",
            // One-shot magnitude: prune to 50% once, mid-training. (1 line)
            Some(PruneSchedule::OneShot { at_step: steps / 2, sparsity: 0.5 }),
            1,
            steps,
            &mut csv,
        )?,
        train(
            "iterative",
            // Iterative magnitude: 10% -> 50% in 10%-steps. (2 lines)
            Some(PruneSchedule::Iterative {
                start: 0.1, step: 0.1, every: steps / 8, target: 0.5,
            }),
            2,
            steps,
            &mut csv,
        )?,
        train(
            "layer-wise",
            // Layer-wise magnitude: one layer at a time. (2 lines)
            Some(PruneSchedule::LayerWise { every: steps / 6, sparsity: 0.5, layers: 3 }),
            2,
            steps,
            &mut csv,
        )?,
    ];

    println!("\nschedule\taccuracy\tsparsity\tLoC-added");
    let dense_acc = runs[0].accuracy;
    for r in &runs {
        println!(
            "{}\t{:.2}%\t{:.2}\t{}",
            r.name,
            r.accuracy * 100.0,
            r.sparsity,
            r.loc
        );
    }
    // Fig. 12 / Table 2 claim: sparse schedules approximately recover dense accuracy.
    for r in &runs[1..] {
        let gap = dense_acc - r.accuracy;
        println!(
            "{}: accuracy gap to dense {:.2} pts ({})",
            r.name,
            gap * 100.0,
            if gap < 0.05 { "recovered" } else { "NOT recovered" }
        );
    }
    println!("\nsparsify_cnn OK");
    Ok(())
}
