//! End-to-end driver: masked sparse training of a transformer LM (Fig. 8).
//!
//! All three layers compose here:
//!   L1 Pallas/L2 JAX — the AOT `train_step_*` artifact (fwd + xent + bwd +
//!     masked SGD) produced by `make artifacts`;
//!   L3 Rust — this coordinator: data generation, the pruning schedule, and
//!     n:m:g mask (re)computation between steps, feeding masks back into the
//!     artifact exactly like STen's masked sparse fine-tuning.
//!
//! Reproduces the *shape* of the paper's Fig. 8: per-layer n:m:g pruning
//! events spike the loss; fine-tuning recovers it; the final model is sparse.
//!
//! Run: `cargo run --release --example train_transformer -- --tag tiny --steps 300`
//! Writes `train_loss.csv` (step, loss, sparsity, event).

use std::io::Write as _;

use anyhow::{anyhow, Result};
use sten::formats::NmgTensor;
use sten::runtime::{ArtifactRuntime, Value};
use sten::tensor::DenseTensor;
use sten::train::data::TokenCorpus;
use sten::util::cli::Args;
use sten::util::rng::Pcg64;

fn main() -> Result<()> {
    let args = Args::parse();
    let tag = args.get_or("tag", "tiny");
    let steps: usize = args.num("steps", 300);
    let lr: f32 = args.num("lr", 0.05);
    let every: usize = args.num("prune-every", 60);
    let (n, m, g) = (args.num("n", 2usize), args.num("m", 4usize), args.num("g", 4usize));
    let out_csv = args.get_or("out", "train_loss.csv");

    let rt = ArtifactRuntime::open_default()?;
    let name = format!("train_step_{tag}");
    let spec = rt.spec(&name)?.clone();
    let meta = &spec.meta;
    let vocab = meta.get("vocab").ok_or_else(|| anyhow!("meta.vocab"))?.usize()?;
    let seq = meta.get("seq").unwrap().usize()?;
    let batch = meta.get("batch").unwrap().usize()?;
    let n_layers = meta.get("n_layers").unwrap().usize()?;
    println!(
        "training {name}: vocab={vocab} seq={seq} batch={batch} layers={n_layers}, \
         {steps} steps, layer-wise {n}:{m}:{g} pruning every {every} steps"
    );

    // Initialize inputs per the manifest.
    let mut rng = Pcg64::seeded(1234);
    let mut inputs: Vec<Value> = Vec::with_capacity(spec.inputs.len());
    let mut mask_slots: Vec<(usize, String)> = Vec::new(); // (input idx, param name)
    let mut param_count = 0usize;
    for (i, io) in spec.inputs.iter().enumerate() {
        let v = match io.name.as_str() {
            "tokens" | "targets" => Value::I32(io.shape.clone(), vec![0; io.numel()]),
            "lr" => Value::from(DenseTensor::from_vec(&[], vec![lr])),
            nm if nm.starts_with("mask.") => {
                mask_slots.push((i, nm.strip_prefix("mask.").unwrap().to_string()));
                Value::from(DenseTensor::ones(&io.shape))
            }
            nm if nm.ends_with("_g") => {
                param_count += 1;
                Value::from(DenseTensor::ones(&io.shape))
            }
            _ if io.shape.len() == 2 => {
                param_count += 1;
                let mut w = DenseTensor::randn(&io.shape, &mut rng);
                w.scale((2.0 / io.shape[0] as f32).sqrt() * 0.5);
                Value::from(w)
            }
            _ => {
                param_count += 1;
                Value::from(DenseTensor::zeros(&io.shape))
            }
        };
        inputs.push(v);
    }
    let param_index = |nm: &str| spec.input_index(nm).unwrap();

    // Deterministic Markov corpus — the model has real structure to learn.
    let corpus = TokenCorpus::new(vocab, 4, 99);
    let mut data_rng = Pcg64::seeded(777);
    let tok_i = param_index("tokens");
    let tgt_i = param_index("targets");

    let mut csv = std::fs::File::create(&out_csv)?;
    writeln!(csv, "step,loss,sparsity,event")?;

    let mut pruned_layers = 0usize;
    let mut losses: Vec<f32> = Vec::new();
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        // Layer-wise pruning schedule: prune layer k's FFN weights at step
        // k * every (Rust recomputes the n:m:g masks from current weights).
        let mut event = String::new();
        if step % every == 0 && pruned_layers < n_layers {
            let l = pruned_layers;
            for wname in [format!("layer{l}.w1"), format!("layer{l}.w2")] {
                let wi = param_index(&wname);
                let w = inputs[wi].as_f32()?.clone();
                // Sparse dim must divide m: W1 (d, f) prune along rows of W^T.
                let wt = w.transpose2();
                let mask_t = NmgTensor::from_dense(&wt, n, m, g)
                    .to_dense()
                    .map(|v| if v != 0.0 { 1.0 } else { 0.0 });
                let mask = mask_t.transpose2();
                let mi = mask_slots.iter().find(|(_, p)| *p == wname).unwrap().0;
                inputs[mi] = Value::from(mask.clone());
                // Apply immediately so the weight conforms from this step on.
                inputs[wi] = Value::from(w.zip(&mask, |x, mk| x * mk));
            }
            pruned_layers += 1;
            event = format!("prune layer{l} to {n}:{m}:{g}");
        }

        let (tokens, targets) = corpus.batch(batch, seq, &mut data_rng);
        inputs[tok_i] = Value::I32(vec![batch, seq], tokens);
        inputs[tgt_i] = Value::I32(vec![batch, seq], targets);

        let out = rt.call(&name, &inputs)?;
        let loss = out[0].as_f32()?.data()[0];
        losses.push(loss);
        // Feed updated params back (outputs 1.. are params in input order).
        for (j, v) in out.into_iter().skip(1).enumerate() {
            inputs[j] = v;
        }

        // Mask sparsity across FFN weights.
        let sparsity = {
            let (mut z, mut t) = (0usize, 0usize);
            for (mi, _) in &mask_slots {
                let mk = inputs[*mi].as_f32()?;
                z += mk.count_zeros();
                t += mk.numel();
            }
            z as f64 / t.max(1) as f64
        };
        writeln!(csv, "{step},{loss},{sparsity:.4},{event}")?;
        if step % 20 == 0 || !event.is_empty() {
            println!(
                "step {step:4}: loss {loss:.4}  ffn-sparsity {sparsity:.2}  {event}"
            );
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let _ = param_count;

    // Summary: the Fig. 8 claims.
    let head = losses[..losses.len().min(10)].iter().sum::<f32>() / 10f32.min(losses.len() as f32);
    let tail = losses[losses.len().saturating_sub(10)..].iter().sum::<f32>()
        / 10f32.min(losses.len() as f32);
    println!("\n{steps} steps in {elapsed:.1}s ({:.3}s/step)", elapsed / steps as f64);
    println!("loss: first-10 avg {head:.4} -> last-10 avg {tail:.4} (floor ~{:.4})", corpus.loss_floor());
    println!("pruned {pruned_layers}/{n_layers} layers to {n}:{m}:{g}; wrote {out_csv}");
    if tail < head {
        println!("train_transformer OK (loss decreased under pruning)");
    } else {
        println!("WARNING: loss did not decrease");
    }
    Ok(())
}
