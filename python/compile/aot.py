"""AOT lowering: JAX/Pallas (L2/L1) -> HLO text artifacts for the Rust runtime.

HLO **text** is the interchange format, never ``.serialize()``: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Run via ``make artifacts``; it is a no-op when artifacts are newer than the
compile sources. Emits ``artifacts/manifest.json`` describing every artifact
(input/output names, shapes, dtypes, format metadata) — the Rust runtime is
manifest-driven and never hard-codes shapes.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import nmg
from .kernels.nmg_gemm import nmg_gemm
from .kernels.masked_gemm import masked_gemm
from .kernels.ref import ref_layernorm


def to_hlo_text(lowered) -> str:
    """Convert a jax lowering to XLA HLO text.

    ``print_large_constants=True`` is essential: the default elides large
    constants as ``constant({...})``, which the consuming HLO text parser
    (xla_extension 0.5.1) silently reads back as zeros.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


class Emitter:
    """Collects artifacts + manifest entries."""

    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.manifest = {"artifacts": []}
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name, fn, inputs, meta=None, golden=False):
        """Lower `fn(*inputs)` (inputs = [(name, ShapeDtypeStruct)]) to HLO
        text; optionally also write a golden test vector for the Rust side."""
        specs = [s for _, s in inputs]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *specs)
        if not isinstance(out_avals, (tuple, list)):
            out_avals = (out_avals,)
        entry = {
            "name": name,
            "file": fname,
            "inputs": [
                {"name": n, "dtype": str(s.dtype), "shape": list(s.shape)}
                for n, s in inputs
            ],
            "outputs": [
                {"dtype": str(a.dtype), "shape": list(a.shape)} for a in out_avals
            ],
            "meta": meta or {},
        }
        self.manifest["artifacts"].append(entry)
        print(f"  wrote {fname} ({len(text) / 1024:.0f} KiB, "
              f"{len(inputs)} inputs, {len(out_avals)} outputs)")
        if golden:
            self.emit_golden(name, fn, inputs)
        return entry

    def emit_golden(self, name, fn, inputs, seed=0):
        """Run `fn` on deterministic random inputs and write a golden test
        vector: all inputs then all outputs, concatenated little-endian
        (f32 / i32 per the manifest dtypes). The Rust integration tests load
        these to verify the PJRT path bit-for-bit against jax — true
        cross-language verification, independent of HLO-translation bugs.
        """
        rng = np.random.default_rng(seed)
        concrete = []
        for _, s in inputs:
            if np.issubdtype(s.dtype, np.integer):
                hi = 8  # small non-negative ints: valid for tokens and idx
                concrete.append(rng.integers(0, hi, s.shape).astype(np.int32))
            else:
                concrete.append(rng.standard_normal(s.shape).astype(np.float32))
        outs = fn(*concrete)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        path = os.path.join(self.out_dir, f"{name}.golden.bin")
        with open(path, "wb") as f:
            for a in concrete:
                f.write(np.ascontiguousarray(a).tobytes())
            for a in outs:
                f.write(np.ascontiguousarray(np.asarray(a)).tobytes())
        for entry in self.manifest["artifacts"]:
            if entry["name"] == name:
                entry["golden"] = f"{name}.golden.bin"
        print(f"  wrote {name}.golden.bin")

    def finish(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"  wrote manifest.json ({len(self.manifest['artifacts'])} artifacts)")


def nmg_meta(mm, nn, g, M_, K):
    C = nmg.num_patterns(mm, nn)
    CH = -(-K // (C * g))
    return {"m": mm, "n": nn, "g": g, "C": C, "CH": CH, "S": M_ // mm,
            "M": M_, "K": K}


def emit_gemms(em: Emitter, quick: bool):
    """Standalone GEMM artifacts: dense, masked, and Pallas n:m:g."""
    shapes = [(8, 48, 16)] if quick else [(8, 48, 16), (64, 192, 128)]
    for (Mm, K, N) in shapes:
        em.emit(
            f"gemm_dense_{Mm}x{K}x{N}",
            lambda a, b: (jnp.matmul(a, b),),
            [("a", spec([Mm, K])), ("b", spec([K, N]))],
            golden=True,
        )
        em.emit(
            f"gemm_masked_{Mm}x{K}x{N}",
            lambda a, mask, b: (masked_gemm(a, mask, b, mt=min(8, Mm), nt=min(16, N)),),
            [("a", spec([Mm, K])), ("mask", spec([Mm, K])), ("b", spec([K, N]))],
            golden=True,
        )
    # Pallas n:m:g GEMM: A (M, K) in n:m:g times B (K, N).
    mm, nn, g = 4, 2, 4
    nmg_shapes = [(8, 48, 16)] if quick else [(8, 48, 16), (16, 96, 64)]
    for (Mm, K, N) in nmg_shapes:
        meta = nmg_meta(mm, nn, g, Mm, K)
        S, CH, C = meta["S"], meta["CH"], meta["C"]
        em.emit(
            f"gemm_nmg_{Mm}x{K}x{N}",
            lambda val, idx, b, N=N: (nmg_gemm(val, idx, b, m=mm, n=nn, g=g, nt=min(16, N)),),
            [
                ("val", spec([S, CH, C, g, nn])),
                ("idx", spec([S, CH, C, g], jnp.int32)),
                ("b", spec([K, N])),
            ],
            meta={**meta, "N": N},
            golden=True,
        )


def encoder_input_specs(cfg: M.EncoderConfig):
    shapes = cfg.param_shapes()
    return [(n, spec(shapes[n])) for n in cfg.param_names()]


def emit_encoder(em: Emitter, cfg: M.EncoderConfig, tag: str):
    """Whole-encoder forward + per-block artifacts + train step for `cfg`."""
    d, f, B, S = cfg.d_model, cfg.d_ff, cfg.batch, cfg.seq
    cfg_meta = {
        "vocab": cfg.vocab, "seq": S, "batch": B, "d_model": d,
        "n_heads": cfg.n_heads, "d_ff": f, "n_layers": cfg.n_layers,
        "param_names": cfg.param_names(),
        "masked_params": cfg.masked_param_names(),
    }

    # Whole forward.
    params_in = encoder_input_specs(cfg)
    em.emit(
        f"encoder_fwd_{tag}",
        lambda *args: (M.encoder_fwd(cfg, list(args[:-1]), args[-1]),),
        params_in + [("tokens", spec([B, S], jnp.int32))],
        meta=cfg_meta,
        golden=(tag == "tiny"),
    )

    # Per-block artifacts (one attention block, one dense FFN block) — the
    # coordinator composes these per layer.
    em.emit(
        f"attn_block_{tag}",
        lambda x, ln_g, ln_b, wq, bq, wk, bk, wv, bv, wo, bo: (
            M.attn_block(x, ln_g, ln_b, wq, bq, wk, bk, wv, bv, wo, bo,
                         n_heads=cfg.n_heads),
        ),
        [
            ("x", spec([B, S, d])),
            ("ln_g", spec([d])), ("ln_b", spec([d])),
            ("wq", spec([d, d])), ("bq", spec([d])),
            ("wk", spec([d, d])), ("bk", spec([d])),
            ("wv", spec([d, d])), ("bv", spec([d])),
            ("wo", spec([d, d])), ("bo", spec([d])),
        ],
        meta=cfg_meta,
        golden=(tag == "tiny"),
    )
    em.emit(
        f"ffn_block_{tag}",
        lambda x, ln_g, ln_b, w1, b1, w2, b2: (
            M.ffn_block(x, ln_g, ln_b, w1, b1, w2, b2),
        ),
        [
            ("x", spec([B, S, d])),
            ("ln_g", spec([d])), ("ln_b", spec([d])),
            ("w1", spec([d, f])), ("b1", spec([f])),
            ("w2", spec([f, d])), ("b2", spec([d])),
        ],
        meta=cfg_meta,
        golden=(tag == "tiny"),
    )
    # Embedding front-end and LM head, so the coordinator can run the whole
    # model block-by-block.
    em.emit(
        f"embed_{tag}",
        lambda emb, pos, tokens: (emb[tokens] + pos[None, :, :],),
        [
            ("emb", spec([cfg.vocab, d])), ("pos", spec([S, d])),
            ("tokens", spec([B, S], jnp.int32)),
        ],
        meta=cfg_meta,
        golden=(tag == "tiny"),
    )
    em.emit(
        f"lm_head_{tag}",
        lambda x, lnf_g, lnf_b, out_w, out_b: (
            jnp.matmul(ref_layernorm(x, lnf_g, lnf_b), out_w) + out_b,
        ),
        [
            ("x", spec([B, S, d])),
            ("lnf_g", spec([d])), ("lnf_b", spec([d])),
            ("out_w", spec([d, cfg.vocab])), ("out_b", spec([cfg.vocab])),
        ],
        meta=cfg_meta,
        golden=(tag == "tiny"),
    )

    # n:m:g FFN block (Pallas kernel inside), W1^T (f, d) in 2:4:4.
    mm, nn, g = 4, 2, 4
    meta = nmg_meta(mm, nn, g, f, d)
    em.emit(
        f"ffn_block_nmg_{tag}",
        lambda x, ln_g, ln_b, val, idx, b1, w2, b2: (
            M.ffn_block_nmg(x, ln_g, ln_b, val, idx, b1, w2, b2, m=mm, n=nn, g=g),
        ),
        [
            ("x", spec([B, S, d])),
            ("ln_g", spec([d])), ("ln_b", spec([d])),
            ("val", spec([meta["S"], meta["CH"], meta["C"], g, nn])),
            ("idx", spec([meta["S"], meta["CH"], meta["C"], g], jnp.int32)),
            ("b1", spec([f])),
            ("w2", spec([f, d])), ("b2", spec([d])),
        ],
        meta={**cfg_meta, "nmg": meta},
        golden=(tag == "tiny"),
    )

    # Train step: params + masks + tokens/targets + lr -> (loss, *params').
    masks_in = [
        (f"mask.{n}", spec(cfg.param_shapes()[n])) for n in cfg.masked_param_names()
    ]
    em.emit(
        f"train_step_{tag}",
        lambda *args: M.train_step(
            cfg,
            list(args[: len(params_in)]),
            list(args[len(params_in) : len(params_in) + len(masks_in)]),
            args[-3], args[-2], args[-1],
        ),
        params_in
        + masks_in
        + [
            ("tokens", spec([B, S], jnp.int32)),
            ("targets", spec([B, S], jnp.int32)),
            ("lr", spec([], jnp.float32)),
        ],
        meta=cfg_meta,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="emit only the small test-sized artifacts")
    args = ap.parse_args()
    em = Emitter(args.out)

    print("[aot] GEMM artifacts")
    emit_gemms(em, quick=args.quick)

    print("[aot] encoder artifacts (tiny: pytest/cargo-test scale)")
    tiny = M.EncoderConfig(vocab=256, seq=16, batch=2, d_model=32, n_heads=2,
                           d_ff=64, n_layers=2)
    emit_encoder(em, tiny, "tiny")

    if not args.quick:
        print("[aot] encoder artifacts (base: example/bench scale)")
        base = M.EncoderConfig(vocab=2048, seq=128, batch=8, d_model=256,
                               n_heads=4, d_ff=1024, n_layers=4)
        emit_encoder(em, base, "base")

    em.finish()


if __name__ == "__main__":
    main()
