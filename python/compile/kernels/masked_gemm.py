"""Layer-1 Pallas kernel: masked (emulated-sparse) GEMM.

The training path of STen uses dense tensors + masks to emulate sparsity
(§2, §6.1: "masked sparse training"). This kernel is the L1 building block
for the AOT train step: ``C = (A * mask) @ B`` with the mask applied in VMEM
so the masked operand is never materialized in HBM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _masked_kernel(a_ref, mask_ref, b_ref, o_ref):
    a = a_ref[...]
    mask = mask_ref[...]
    b = b_ref[...]
    o_ref[...] = jnp.dot(a * mask, b, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("mt", "nt"))
def masked_gemm(a, mask, b, *, mt=128, nt=128):
    """``C = (A * mask) @ B`` tiled over (M, N).

    Args:
      a, mask: float32 (M, K); mask entries are 0.0 / 1.0.
      b: float32 (K, N).
      mt, nt: output tile sizes.
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2 and mask.shape == a.shape
    mt = min(mt, M)
    nt = min(nt, N)
    assert M % mt == 0 and N % nt == 0, f"({M},{N}) not divisible by ({mt},{nt})"
    return pl.pallas_call(
        _masked_kernel,
        grid=(M // mt, N // nt),
        in_specs=[
            pl.BlockSpec((mt, K), lambda i, j: (i, 0)),
            pl.BlockSpec((mt, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, nt), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((mt, nt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=True,
    )(a, mask, b)
