"""Grouped n:m (n:m:g) sparsity format — reference implementation (§5 of STen).

Format definition used across this repo (Python and Rust agree bit-for-bit):

* A sparse matrix ``A`` of shape ``(M, K)`` with ``M % m == 0`` is split into
  ``S = M / m`` *slabs* of ``m`` consecutive rows.
* Within a slab, each column holds ``m`` values of which ``n`` are kept; the
  kept row-positions form a *pattern*, one of ``C = comb(m, n)`` choices.
* Columns are processed in *chunks* of ``chunk_cols = C * g`` consecutive
  columns. Inside a chunk the columns are permuted so that the patterns appear
  in a fixed (Gray-code-like) order, each repeated for a *group* of ``g``
  columns; the original column of each slot is stored in ``idx``.
* Trailing chunks may be partial: pad slots carry ``val = 0`` (and ``idx = 0``)
  so kernels need no bounds logic.

Stored arrays:

* ``val``: float32 ``(S, CH, C, g, n)`` — the kept values per column slot.
* ``idx``: int32 ``(S, CH, C, g)`` — original (absolute) column in ``[0, K)``.

The pattern order within a chunk is chosen so adjacent patterns differ in as
few row positions as possible (the paper's "save and initialize only one
vector register" property); see :func:`patterns`.
"""

import math
from functools import lru_cache

import numpy as np


@lru_cache(maxsize=None)
def patterns(m: int, n: int) -> tuple:
    """All C(m, n) patterns (sorted tuples of kept-row indices) in an order
    where adjacent patterns differ minimally (greedy revolving-door order)."""
    from itertools import combinations

    combos = [tuple(c) for c in combinations(range(m), n)]
    order = [combos.pop(0)]
    while combos:
        cur = set(order[-1])
        best = min(range(len(combos)), key=lambda i: (len(cur ^ set(combos[i])), combos[i]))
        order.append(combos.pop(best))
    return tuple(order)


def num_patterns(m: int, n: int) -> int:
    """C(m, n)."""
    return math.comb(m, n)


def chunk_cols(m: int, n: int, g: int) -> int:
    """Columns per chunk: C(m, n) * g."""
    return num_patterns(m, n) * g


def pattern_matrix(m: int, n: int) -> np.ndarray:
    """(C, n) int32 matrix of kept-row indices, in chunk order."""
    return np.asarray(patterns(m, n), dtype=np.int32)


def dense_to_nmg(a: np.ndarray, n: int, m: int, g: int):
    """Convert a dense (M, K) matrix to n:m:g arrays ``(val, idx)``.

    Greedy magnitude assignment (§5.2, CPU algorithm): per slab and chunk,
    score every (column, pattern) pair by the L1 mass the pattern preserves,
    sort descending and assign columns to patterns first-come-first-served
    until each pattern's group of g column slots is full.
    """
    a = np.asarray(a, dtype=np.float32)
    M, K = a.shape
    assert M % m == 0, f"rows {M} not divisible by m={m}"
    S = M // m
    pats = pattern_matrix(m, n)  # (C, n)
    C = pats.shape[0]
    cc = C * g
    CH = -(-K // cc)  # ceil
    val = np.zeros((S, CH, C, g, n), dtype=np.float32)
    idx = np.zeros((S, CH, C, g), dtype=np.int32)

    for s in range(S):
        slab = a[s * m : (s + 1) * m, :]  # (m, K)
        for ch in range(CH):
            lo, hi = ch * cc, min((ch + 1) * cc, K)
            cols = np.arange(lo, hi)
            ncols = len(cols)
            # scores[j, p] = L1 mass preserved if column cols[j] uses pattern p
            block = np.abs(slab[:, lo:hi])  # (m, ncols)
            scores = block[pats, :].sum(axis=1).T  # (ncols, C)
            order = np.argsort(-scores, axis=None, kind="stable")
            col_assigned = np.full(ncols, -1, dtype=np.int64)
            pat_fill = np.zeros(C, dtype=np.int64)
            assigned = 0
            for flat in order:
                j, p = divmod(int(flat), C)
                if col_assigned[j] >= 0 or pat_fill[p] >= g:
                    continue
                col_assigned[j] = p
                slot = pat_fill[p]
                pat_fill[p] += 1
                k = int(cols[j])
                idx[s, ch, p, slot] = k
                val[s, ch, p, slot, :] = slab[pats[p], k]
                assigned += 1
                if assigned == ncols:
                    break
            # Partial chunk: unfilled slots stay (val=0, idx=0).
    return val, idx


def nmg_to_dense(val: np.ndarray, idx: np.ndarray, m: int, n: int, K: int) -> np.ndarray:
    """Convert n:m:g arrays back to a dense (M, K) matrix.

    Accumulating writes make pad slots (val=0, idx=0) harmless: every real
    column appears in exactly one slot, so ``+=`` never double-counts, and
    pad slots only ever add zeros.
    """
    S, CH, C, g, n_ = val.shape
    assert n_ == n
    pats = pattern_matrix(m, n)  # (C, n)
    out = np.zeros((S * m, K), dtype=np.float32)
    cols = idx.reshape(S, -1)  # (S, CH*C*g)
    vals = val.reshape(S, CH * C * g, n)
    rows = np.broadcast_to(pats[None, :, None, :], (CH, C, g, n)).reshape(-1, n)
    for s in range(S):
        r = rows + s * m  # (slots, n)
        np.add.at(out, (r.ravel(), np.repeat(cols[s], n)), vals[s].ravel())
    return out


def sparsity_of(n: int, m: int) -> float:
    """Nominal sparsity of an n:m format."""
    return 1.0 - n / m


def energy(dense: np.ndarray, pruned: np.ndarray) -> float:
    """The paper's energy metric: ||pruned||_1 / ||dense||_1 (Fig. 7)."""
    denom = np.abs(dense).sum()
    return float(np.abs(pruned).sum() / denom) if denom > 0 else 1.0
