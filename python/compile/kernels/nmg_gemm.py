"""Layer-1 Pallas kernel: n:m:g sparse-dense GEMM (§5.1 of STen).

TPU adaptation of the paper's AVX2/AVX-512 kernel (see DESIGN.md
§Hardware-Adaptation):

* the chunk's fixed permutation order becomes a *static* pattern matrix, so
  gather indices are data, not control flow;
* the per-pattern "broadcast into vector registers" FMA loop becomes a small
  dense (m × chunk_slots) × (chunk_slots × NT) contraction that feeds the MXU;
* indirect loads of B rows become a VMEM gather over the stored `idx`.

``interpret=True`` is mandatory on this image (CPU PJRT cannot execute Mosaic
custom-calls); real-TPU efficiency is estimated analytically in EXPERIMENTS.md.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import nmg


def _nmg_kernel(val_ref, idx_ref, onehot_ref, b_ref, o_ref):
    """One grid step: one slab (m output rows) × one N tile."""
    val = val_ref[...]        # (1, CH, C, g, n)
    idx = idx_ref[...]        # (1, CH, C, g)
    onehot = onehot_ref[...]  # (m, C, n) pattern scatter matrix (static data)
    b = b_ref[...]            # (K, NT)
    _, CH, C, g, _ = val.shape
    slots = CH * C * g
    # Gather the B rows each column slot multiplies (pad slots gather row 0
    # but carry val == 0, so they contribute nothing).
    m = onehot.shape[0]
    gathered = b[idx.reshape(slots)]  # (slots, NT)
    # Scatter the kept values into a chunk-dense (m, slots) tile: column slot
    # (ch, c, gi) has its n values at rows pats[c]. Deliberately expressed as
    # an m-leading broadcast-multiply-reduce (no einsum, no transpose): einsum
    # lowers to a dot with non-leading batch dims, and the mul+sum+transpose
    # form to a fusion sandwich, both of which the AOT target
    # (xla_extension 0.5.1) miscompiles; this form lowers to version-stable
    # primitives (verified by the golden-vector integration tests).
    contrib = onehot[:, None, :, None, :] * val  # (m,1,C,1,n)*(1,CH,C,g,n)
    a_cd = contrib.sum(axis=4).reshape(m, slots)
    # MXU contraction: (m, slots) @ (slots, NT).
    o_ref[...] = jnp.dot(a_cd, gathered, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("m", "n", "g", "nt"))
def nmg_gemm(val, idx, b, *, m, n, g, nt=128):
    """Sparse-dense GEMM: ``C = A_nmg @ B``.

    Args:
      val: float32 (S, CH, C, g, n) kept values.
      idx: int32 (S, CH, C, g) original column per slot.
      b: float32 (K, N) dense right-hand side.
      m, n, g: the n:m:g format parameters.
      nt: N tile width (the lane dimension of the output block).

    Returns:
      float32 (S*m, N).
    """
    S, CH, C, gg, nn = val.shape
    assert (gg, nn) == (g, n), f"format mismatch: {(gg, nn)} vs {(g, n)}"
    K, N = b.shape
    nt = min(nt, N)
    assert N % nt == 0, f"N={N} not divisible by tile {nt}"
    pats = nmg.pattern_matrix(m, n)
    # m-first scatter matrix, built in numpy so the lowered constant carries
    # the DEFAULT physical layout: a transposed jnp constant enters the
    # pallas while-loop carry with layout {0,2,1}, which xla_extension 0.5.1
    # silently misreads (the root cause of the golden-test corruption).
    oh = np.zeros((m, pats.shape[0], n), dtype=np.float32)
    for c, pat in enumerate(pats):
        for j, r in enumerate(pat):
            oh[r, c, j] = 1.0
    onehot = jnp.asarray(oh)  # (m, C, n)
    return pl.pallas_call(
        _nmg_kernel,
        grid=(S, N // nt),
        in_specs=[
            pl.BlockSpec((1, CH, C, g, n), lambda s, j: (s, 0, 0, 0, 0)),
            pl.BlockSpec((1, CH, C, g), lambda s, j: (s, 0, 0, 0)),
            pl.BlockSpec((m, C, n), lambda s, j: (0, 0, 0)),
            pl.BlockSpec((K, nt), lambda s, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((m, nt), lambda s, j: (s, j)),
        out_shape=jax.ShapeDtypeStruct((S * m, N), jnp.float32),
        interpret=True,
    )(val, idx, onehot, b)


def vmem_estimate_bytes(m, n, g, CH, K, nt):
    """Analytic VMEM footprint of one grid step (bytes), for DESIGN §Perf.

    val + idx blocks + the full-K B tile + the output tile + the chunk-dense
    scratch. TPU VMEM is ~16 MiB/core; this guides the choice of `nt`.
    """
    C = nmg.num_patterns(m, n)
    slots = CH * C * g
    val_b = slots * n * 4
    idx_b = slots * 4
    b_b = K * nt * 4
    out_b = m * nt * 4
    scratch = m * slots * 4 + slots * nt * 4
    return val_b + idx_b + b_b + out_b + scratch


def mxu_utilization_estimate(m, n, g, K, nt):
    """Fraction of MXU work that is useful (non-pad, non-scatter overhead).

    The contraction is (m × slots) @ (slots × NT); the MXU processes 128×128
    tiles, so utilization ≈ (m / pad128(m)) × (nt / pad128(nt)) discounted by
    the densification overhead slots/K ≈ 1 (slots counts every column once).
    """
    pad = lambda x: 128 * -(-x // 128)
    return (m / pad(m)) * (min(nt, 128) / 128)
