"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference here; pytest (and hypothesis)
assert ``assert_allclose(kernel(...), ref(...))`` over shape/dtype sweeps.
"""

import jax.numpy as jnp
import numpy as np

from . import nmg


def ref_dense_gemm(a, b):
    """C = A @ B."""
    return jnp.matmul(a, b)


def ref_masked_gemm(a, mask, b):
    """C = (A * mask) @ B — masked (emulated-sparse) GEMM used in training."""
    return jnp.matmul(a * mask, b)


def ref_nmg_gemm(val, idx, b, *, m, n):
    """C = densify(val, idx) @ B via the numpy reference densifier."""
    K = b.shape[0]
    a = nmg.nmg_to_dense(np.asarray(val), np.asarray(idx), m, n, K)
    return jnp.matmul(jnp.asarray(a), b)


def ref_gelu(x):
    """tanh-approximated GeLU (matches the Rust kernels and model)."""
    c = np.float32(np.sqrt(2.0 / np.pi))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def ref_layernorm(x, gamma, beta, eps=1e-5):
    """LayerNorm over the last axis."""
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def ref_softmax(x, axis=-1):
    """Numerically-stable softmax."""
    z = x - x.max(axis=axis, keepdims=True)
    e = jnp.exp(z)
    return e / e.sum(axis=axis, keepdims=True)
