"""Layer-2 JAX model: BERT-style transformer encoder + masked train step.

This is the compute graph the Rust coordinator executes through PJRT. It is
lowered once by :mod:`compile.aot` to HLO text; Python never runs at serving
or training time.

Three granularities are exported:

* **Blocks** (`attn_block`, `ffn_block`, `ffn_block_nmg`) — one residual
  sub-block each; the coordinator composes them per-layer so it can dispatch
  the FFN either to the dense PJRT artifact or to the native Rust n:m:g GEMM
  (the STen dispatch story, end to end).
* **Whole encoder** (`encoder_fwd`) — single-artifact forward for latency
  baselines.
* **Train step** (`train_step`) — fwd + cross-entropy + bwd + masked SGD
  update; masks for the FFN weights are inputs so the Rust side can run
  fixed-mask or recompute-mask (Fig. 9) schedules.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import nmg
from .kernels.nmg_gemm import nmg_gemm
from .kernels.ref import ref_gelu, ref_layernorm, ref_softmax


@dataclass(frozen=True)
class EncoderConfig:
    """Transformer encoder hyperparameters (shapes fixed at AOT time)."""

    vocab: int = 2048
    seq: int = 64
    batch: int = 8
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 512
    n_layers: int = 2

    @property
    def head_dim(self):
        return self.d_model // self.n_heads

    def layer_param_names(self, i):
        p = f"layer{i}."
        return [
            p + s
            for s in (
                "ln1_g", "ln1_b", "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo",
                "ln2_g", "ln2_b", "w1", "b1", "w2", "b2",
            )
        ]

    def param_names(self):
        """Canonical parameter order — the artifact input order."""
        names = ["emb", "pos"]
        for i in range(self.n_layers):
            names += self.layer_param_names(i)
        names += ["lnf_g", "lnf_b", "out_w", "out_b"]
        return names

    def param_shapes(self):
        d, f, v, s = self.d_model, self.d_ff, self.vocab, self.seq
        shapes = {"emb": (v, d), "pos": (s, d)}
        for i in range(self.n_layers):
            p = f"layer{i}."
            shapes.update({
                p + "ln1_g": (d,), p + "ln1_b": (d,),
                p + "wq": (d, d), p + "bq": (d,),
                p + "wk": (d, d), p + "bk": (d,),
                p + "wv": (d, d), p + "bv": (d,),
                p + "wo": (d, d), p + "bo": (d,),
                p + "ln2_g": (d,), p + "ln2_b": (d,),
                p + "w1": (d, f), p + "b1": (f,),
                p + "w2": (f, d), p + "b2": (d,),
            })
        shapes.update({"lnf_g": (d,), "lnf_b": (d,), "out_w": (d, v), "out_b": (v,)})
        return shapes

    def masked_param_names(self):
        """Parameters that carry sparsity masks in the train step (FFN weights)."""
        names = []
        for i in range(self.n_layers):
            names += [f"layer{i}.w1", f"layer{i}.w2"]
        return names

    def num_params(self):
        return sum(int(np.prod(s)) for s in self.param_shapes().values())


def init_params(cfg: EncoderConfig, seed: int = 0):
    """Kaiming/normal init; returns {name: np.float32 array} in canonical order."""
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in cfg.param_shapes().items():
        if name.endswith(("_b", "_g")) or name.startswith(("b", "ln")) or ".b" in name or "ln" in name:
            base = np.ones(shape) if name.endswith("_g") else np.zeros(shape)
            params[name] = base.astype(np.float32)
        elif len(shape) == 2:
            std = (2.0 / shape[0]) ** 0.5
            params[name] = (rng.standard_normal(shape) * std).astype(np.float32)
        else:
            params[name] = np.zeros(shape, dtype=np.float32)
    # Embeddings: small normal.
    params["emb"] = (rng.standard_normal(cfg.param_shapes()["emb"]) * 0.02).astype(np.float32)
    params["pos"] = (rng.standard_normal(cfg.param_shapes()["pos"]) * 0.02).astype(np.float32)
    return params


def attn_block(x, ln_g, ln_b, wq, bq, wk, bk, wv, bv, wo, bo, *, n_heads):
    """Pre-LN multi-head self-attention with residual. x: (B, S, D)."""
    B, S, D = x.shape
    hd = D // n_heads
    y = ref_layernorm(x, ln_g, ln_b)
    q = (y @ wq + bq).reshape(B, S, n_heads, hd).transpose(0, 2, 1, 3)
    k = (y @ wk + bk).reshape(B, S, n_heads, hd).transpose(0, 2, 1, 3)
    v = (y @ wv + bv).reshape(B, S, n_heads, hd).transpose(0, 2, 1, 3)
    att = ref_softmax(q @ k.transpose(0, 1, 3, 2) / np.float32(hd**0.5))
    o = (att @ v).transpose(0, 2, 1, 3).reshape(B, S, D)
    return x + o @ wo + bo


def ffn_block(x, ln_g, ln_b, w1, b1, w2, b2):
    """Pre-LN GeLU FFN with residual. x: (B, S, D)."""
    y = ref_layernorm(x, ln_g, ln_b)
    return x + ref_gelu(y @ w1 + b1) @ w2 + b2


def ffn_block_masked(x, ln_g, ln_b, w1, m1, b1, w2, m2, b2):
    """FFN with masked (emulated-sparse) weights, the training-path form."""
    y = ref_layernorm(x, ln_g, ln_b)
    return x + ref_gelu(y @ (w1 * m1) + b1) @ (w2 * m2) + b2


def ffn_block_nmg(x, ln_g, ln_b, val1, idx1, b1, w2, b2, *, m, n, g):
    """FFN whose first linear runs through the Pallas n:m:g GEMM kernel.

    ``val1/idx1`` encode W1^T (shape (F, D)) in n:m:g; the kernel computes
    ``W1^T @ y^T`` and we transpose back.
    """
    B, S, D = x.shape
    y = ref_layernorm(x, ln_g, ln_b)
    yt = y.reshape(B * S, D).T  # (D, B*S)
    h = nmg_gemm(val1, idx1, yt, m=m, n=n, g=g).T  # (B*S, F)
    h = ref_gelu(h + b1)
    out = h @ w2 + b2
    return x + out.reshape(B, S, D)


def encoder_fwd(cfg: EncoderConfig, params: list, tokens):
    """Full forward: tokens (B, S) int32 -> logits (B, S, V).

    `params` is a flat list in `cfg.param_names()` order.
    """
    names = cfg.param_names()
    p = dict(zip(names, params))
    x = p["emb"][tokens] + p["pos"][None, :, :]
    for i in range(cfg.n_layers):
        l = f"layer{i}."
        x = attn_block(
            x, p[l + "ln1_g"], p[l + "ln1_b"],
            p[l + "wq"], p[l + "bq"], p[l + "wk"], p[l + "bk"],
            p[l + "wv"], p[l + "bv"], p[l + "wo"], p[l + "bo"],
            n_heads=cfg.n_heads,
        )
        x = ffn_block(
            x, p[l + "ln2_g"], p[l + "ln2_b"],
            p[l + "w1"], p[l + "b1"], p[l + "w2"], p[l + "b2"],
        )
    x = ref_layernorm(x, p["lnf_g"], p["lnf_b"])
    return x @ p["out_w"] + p["out_b"]


def encoder_fwd_masked(cfg: EncoderConfig, params: list, masks: list, tokens):
    """Forward with masks applied to the FFN weights (training-path network)."""
    names = cfg.param_names()
    p = dict(zip(names, params))
    mk = dict(zip(cfg.masked_param_names(), masks))
    x = p["emb"][tokens] + p["pos"][None, :, :]
    for i in range(cfg.n_layers):
        l = f"layer{i}."
        x = attn_block(
            x, p[l + "ln1_g"], p[l + "ln1_b"],
            p[l + "wq"], p[l + "bq"], p[l + "wk"], p[l + "bk"],
            p[l + "wv"], p[l + "bv"], p[l + "wo"], p[l + "bo"],
            n_heads=cfg.n_heads,
        )
        x = ffn_block_masked(
            x, p[l + "ln2_g"], p[l + "ln2_b"],
            p[l + "w1"], mk[l + "w1"], p[l + "b1"],
            p[l + "w2"], mk[l + "w2"], p[l + "b2"],
        )
    x = ref_layernorm(x, p["lnf_g"], p["lnf_b"])
    return x @ p["out_w"] + p["out_b"]


def cross_entropy(logits, targets):
    """Mean token-level cross entropy. logits (B,S,V), targets (B,S) int32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()


def train_step(cfg: EncoderConfig, params: list, masks: list, tokens, targets, lr):
    """One masked-SGD step: returns (loss, *updated_params).

    Masked weights are updated as ``(p - lr * grad) * mask`` — the paper's
    Fig. 2 semantics where the in-place update is re-sparsified with the
    SameFormatSparsifier (here: the fixed mask). Unmasked weights take plain
    SGD steps.
    """
    names = cfg.param_names()
    masked = set(cfg.masked_param_names())

    def loss_fn(ps):
        logits = encoder_fwd_masked(cfg, ps, masks, tokens)
        return cross_entropy(logits, targets)

    loss, grads = jax.value_and_grad(loss_fn)(list(params))
    mk = dict(zip(cfg.masked_param_names(), masks))
    new_params = []
    for name, p, gr in zip(names, params, grads):
        q = p - lr * gr
        if name in masked:
            q = q * mk[name]
        new_params.append(q)
    return (loss, *new_params)
