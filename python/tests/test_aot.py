"""AOT emitter: HLO text generation + manifest coherence."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M


def test_to_hlo_text_roundtrips_simple_fn(tmp_path):
    import jax

    def fn(a, b):
        return (jnp.matmul(a, b) + 1.0,)

    spec = aot.spec([4, 4])
    lowered = jax.jit(fn).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4,4]" in text


def test_emitter_writes_artifact_and_manifest(tmp_path):
    em = aot.Emitter(str(tmp_path))
    entry = em.emit(
        "toy_add",
        lambda a, b: (a + b,),
        [("a", aot.spec([2, 3])), ("b", aot.spec([2, 3]))],
        meta={"k": 1},
    )
    em.finish()
    assert (tmp_path / "toy_add.hlo.txt").exists()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["artifacts"][0]["name"] == "toy_add"
    assert entry["inputs"][0] == {"name": "a", "dtype": "float32", "shape": [2, 3]}
    assert entry["outputs"][0]["shape"] == [2, 3]
    assert entry["meta"] == {"k": 1}


def test_emitter_multiple_outputs(tmp_path):
    em = aot.Emitter(str(tmp_path))
    entry = em.emit(
        "toy_two",
        lambda a: (a + 1.0, (a * 2.0).sum()),
        [("a", aot.spec([3]))],
    )
    assert len(entry["outputs"]) == 2
    assert entry["outputs"][1]["shape"] == []


def test_nmg_meta_consistency():
    meta = aot.nmg_meta(4, 2, 4, 16, 48)
    assert meta["C"] == 6
    assert meta["S"] == 4
    assert meta["CH"] == 2  # ceil(48 / 24)


def test_int_inputs_lower(tmp_path):
    em = aot.Emitter(str(tmp_path))
    entry = em.emit(
        "toy_gather",
        lambda emb, tok: (emb[tok],),
        [("emb", aot.spec([16, 4])), ("tok", aot.spec([2, 3], jnp.int32))],
    )
    assert entry["inputs"][1]["dtype"] == "int32"
    text = (tmp_path / "toy_gather.hlo.txt").read_text()
    assert "s32[2,3]" in text
