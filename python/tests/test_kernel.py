"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes and format parameters; assert_allclose against
`compile.kernels.ref`.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import nmg
from compile.kernels.masked_gemm import masked_gemm
from compile.kernels.nmg_gemm import nmg_gemm, vmem_estimate_bytes, mxu_utilization_estimate
from compile.kernels import ref


def make_nmg(rng, slabs, K, m, n, g):
    a = rng.standard_normal((slabs * m, K)).astype(np.float32)
    val, idx = nmg.dense_to_nmg(a, n, m, g)
    return a, val, idx


@pytest.mark.parametrize("m,n,g", [(4, 2, 4), (4, 1, 2), (8, 2, 2)])
def test_nmg_gemm_matches_ref(m, n, g):
    rng = np.random.default_rng(0)
    slabs, K, N = 3, nmg.chunk_cols(m, n, g) * 2, 32
    _, val, idx = make_nmg(rng, slabs, K, m, n, g)
    b = rng.standard_normal((K, N)).astype(np.float32)
    out = nmg_gemm(val, idx, b, m=m, n=n, g=g, nt=16)
    want = ref.ref_nmg_gemm(val, idx, b, m=m, n=n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    st.sampled_from([(4, 2, 1), (4, 2, 4), (4, 1, 2), (10, 1, 2)]),
    st.integers(1, 3),   # slabs
    st.integers(1, 3),   # chunks worth of K (may end partial)
    st.sampled_from([8, 16]),  # N
    st.integers(0, 2**31 - 1),
)
def test_nmg_gemm_hypothesis(fmt, slabs, kchunks, N, seed):
    m, n, g = fmt
    rng = np.random.default_rng(seed)
    cc = nmg.chunk_cols(m, n, g)
    K = cc * kchunks - (cc // 2)  # force a partial trailing chunk
    _, val, idx = make_nmg(rng, slabs, K, m, n, g)
    b = rng.standard_normal((K, N)).astype(np.float32)
    out = nmg_gemm(val, idx, b, m=m, n=n, g=g, nt=N)
    want = ref.ref_nmg_gemm(val, idx, b, m=m, n=n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_nmg_gemm_end_to_end_vs_dense():
    """sparsify -> kernel == densify -> matmul, on a magnitude-friendly matrix."""
    m, n, g = 4, 2, 4
    rng = np.random.default_rng(7)
    slabs, K, N = 4, nmg.chunk_cols(m, n, g) * 3, 64
    a, val, idx = make_nmg(rng, slabs, K, m, n, g)
    b = rng.standard_normal((K, N)).astype(np.float32)
    a_pruned = nmg.nmg_to_dense(val, idx, m, n, K)
    out = nmg_gemm(val, idx, b, m=m, n=n, g=g, nt=32)
    np.testing.assert_allclose(np.asarray(out), a_pruned @ b, rtol=1e-4, atol=1e-4)
    # And the pruning kept at least half of the L1 mass (n/m = 50% sparsity).
    assert nmg.energy(a, a_pruned) > 0.5


@settings(max_examples=10, deadline=None)
@given(
    st.sampled_from([(8, 16, 8), (16, 32, 16), (8, 48, 32)]),
    st.floats(0.0, 1.0),
    st.integers(0, 2**31 - 1),
)
def test_masked_gemm_hypothesis(shape, density, seed):
    M, K, N = shape
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((M, K)).astype(np.float32)
    mask = (rng.random((M, K)) < density).astype(np.float32)
    b = rng.standard_normal((K, N)).astype(np.float32)
    out = masked_gemm(a, mask, b, mt=8, nt=8)
    want = ref.ref_masked_gemm(a, mask, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_masked_gemm_zero_mask_gives_zero():
    a = np.ones((8, 16), np.float32)
    b = np.ones((16, 8), np.float32)
    out = masked_gemm(a, np.zeros_like(a), b, mt=8, nt=8)
    assert np.abs(np.asarray(out)).max() == 0.0


def test_vmem_estimate_within_tpu_budget():
    """The BlockSpec chosen for the paper-scale GEMM fits in 16 MiB VMEM."""
    m, n, g = 4, 2, 4
    K = 3072
    C = nmg.num_patterns(m, n)
    CH = -(-K // (C * g))
    bytes_ = vmem_estimate_bytes(m, n, g, CH, K, nt=128)
    assert bytes_ < 16 * 2**20, f"VMEM estimate {bytes_/2**20:.1f} MiB"
    assert 0.0 < mxu_utilization_estimate(m, n, g, K, 128) <= 1.0
