"""L2 model: shapes, block composition, masked training semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import nmg


CFG = M.EncoderConfig(vocab=64, seq=8, batch=2, d_model=16, n_heads=2,
                      d_ff=32, n_layers=2)


def params_list(cfg, seed=0):
    p = M.init_params(cfg, seed)
    return [jnp.asarray(p[n]) for n in cfg.param_names()]


def ones_masks(cfg):
    shapes = cfg.param_shapes()
    return [jnp.ones(shapes[n], jnp.float32) for n in cfg.masked_param_names()]


def test_param_accounting():
    names = CFG.param_names()
    shapes = CFG.param_shapes()
    assert len(names) == len(set(names)) == 2 + 16 * CFG.n_layers + 4
    assert set(names) == set(shapes)
    assert CFG.num_params() > 0


def test_forward_shapes_and_finiteness():
    params = params_list(CFG)
    tokens = jnp.zeros((CFG.batch, CFG.seq), jnp.int32)
    logits = M.encoder_fwd(CFG, params, tokens)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_masked_forward_with_ones_masks_matches_dense():
    params = params_list(CFG)
    tokens = jnp.arange(CFG.batch * CFG.seq, dtype=jnp.int32).reshape(
        CFG.batch, CFG.seq) % CFG.vocab
    dense = M.encoder_fwd(CFG, params, tokens)
    masked = M.encoder_fwd_masked(CFG, params, ones_masks(CFG), tokens)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(masked), rtol=1e-5, atol=1e-5)


def test_block_composition_equals_full_forward():
    """embed -> (attn, ffn)* -> lm_head equals encoder_fwd — this is what the
    Rust coordinator does when it composes per-block artifacts."""
    params = params_list(CFG)
    p = dict(zip(CFG.param_names(), params))
    tokens = (jnp.arange(CFG.batch * CFG.seq, dtype=jnp.int32)
              .reshape(CFG.batch, CFG.seq) * 7) % CFG.vocab
    x = p["emb"][tokens] + p["pos"][None, :, :]
    for i in range(CFG.n_layers):
        l = f"layer{i}."
        x = M.attn_block(x, p[l + "ln1_g"], p[l + "ln1_b"],
                         p[l + "wq"], p[l + "bq"], p[l + "wk"], p[l + "bk"],
                         p[l + "wv"], p[l + "bv"], p[l + "wo"], p[l + "bo"],
                         n_heads=CFG.n_heads)
        x = M.ffn_block(x, p[l + "ln2_g"], p[l + "ln2_b"],
                        p[l + "w1"], p[l + "b1"], p[l + "w2"], p[l + "b2"])
    from compile.kernels.ref import ref_layernorm
    logits = ref_layernorm(x, p["lnf_g"], p["lnf_b"]) @ p["out_w"] + p["out_b"]
    full = M.encoder_fwd(CFG, params, tokens)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full), rtol=1e-5, atol=1e-5)


def test_ffn_block_nmg_matches_pruned_dense():
    """The Pallas-n:m:g FFN block equals the dense FFN block run with the
    pruned (densified) weight."""
    m, n, g = 4, 2, 4
    cfg = CFG
    rng = np.random.default_rng(3)
    d, f = cfg.d_model, cfg.d_ff
    x = jnp.asarray(rng.standard_normal((cfg.batch, cfg.seq, d)), jnp.float32)
    ln_g = jnp.ones((d,)); ln_b = jnp.zeros((d,))
    w1 = rng.standard_normal((d, f)).astype(np.float32) * 0.1
    b1 = rng.standard_normal((f,)).astype(np.float32) * 0.1
    w2 = rng.standard_normal((f, d)).astype(np.float32) * 0.1
    b2 = rng.standard_normal((d,)).astype(np.float32) * 0.1
    val, idx = nmg.dense_to_nmg(w1.T, n, m, g)  # W1^T is (f, d)
    w1_pruned = nmg.nmg_to_dense(val, idx, m, n, d).T  # back to (d, f)
    got = M.ffn_block_nmg(x, ln_g, ln_b, jnp.asarray(val), jnp.asarray(idx),
                          b1, w2, b2, m=m, n=n, g=g)
    want = M.ffn_block(x, ln_g, ln_b, jnp.asarray(w1_pruned), b1, w2, b2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_train_step_reduces_loss_and_respects_masks():
    cfg = CFG
    params = params_list(cfg, seed=1)
    shapes = cfg.param_shapes()
    rng = np.random.default_rng(0)
    masks = []
    for nme in cfg.masked_param_names():
        mask = (rng.random(shapes[nme]) < 0.5).astype(np.float32)
        masks.append(jnp.asarray(mask))
    # Pre-apply masks so weights start conforming.
    names = cfg.param_names()
    mk = dict(zip(cfg.masked_param_names(), masks))
    params = [p * mk[n] if n in mk else p for n, p in zip(names, params)]
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)), jnp.int32)
    targets = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)), jnp.int32)
    lr = jnp.float32(0.1)

    loss0, *p1 = M.train_step(cfg, params, masks, tokens, targets, lr)
    for _ in range(5):
        loss, *p1 = M.train_step(cfg, list(p1), masks, tokens, targets, lr)
    assert float(loss) < float(loss0), f"{float(loss)} !< {float(loss0)}"
    # Masked weights stay masked after updates.
    p1d = dict(zip(names, p1))
    for nme in cfg.masked_param_names():
        masked_out = np.asarray(p1d[nme]) * (1.0 - np.asarray(mk[nme]))
        assert np.abs(masked_out).max() == 0.0


def test_cross_entropy_uniform_logits():
    logits = jnp.zeros((2, 3, 10))
    targets = jnp.zeros((2, 3), jnp.int32)
    ce = M.cross_entropy(logits, targets)
    assert float(ce) == pytest.approx(np.log(10.0), rel=1e-5)
