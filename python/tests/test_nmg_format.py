"""n:m:g format invariants and conversion correctness (numpy reference)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import nmg


@pytest.mark.parametrize("m,n", [(4, 2), (4, 1), (8, 2), (10, 1), (6, 3)])
def test_patterns_cover_all_combinations(m, n):
    pats = nmg.patterns(m, n)
    assert len(pats) == nmg.num_patterns(m, n)
    assert len(set(pats)) == len(pats)
    for p in pats:
        assert len(p) == n
        assert all(0 <= r < m for r in p)


@pytest.mark.parametrize("m,n", [(4, 2), (4, 1), (8, 2), (10, 1)])
def test_patterns_adjacent_differ_minimally(m, n):
    """The chunk order is chosen so adjacent patterns differ in one swap
    (the paper's single-register save/init property)."""
    pats = nmg.patterns(m, n)
    for a, b in zip(pats, pats[1:]):
        diff = len(set(a) ^ set(b))
        assert diff == 2, f"{a} -> {b} differ in {diff} positions"


def test_roundtrip_exact_when_structure_matches():
    """A matrix that already satisfies the structure is preserved exactly."""
    m, n, g = 4, 2, 2
    C = nmg.num_patterns(m, n)
    K = C * g * 2
    rng = np.random.default_rng(0)
    # Build a conforming matrix: per chunk, exactly g columns per pattern
    # (shuffled within the chunk — the format permits in-chunk permutation).
    a = np.zeros((m, K), dtype=np.float32)
    pats = nmg.patterns(m, n)
    cc = C * g
    for ch in range(2):
        cols = list(range(ch * cc, (ch + 1) * cc))
        rng.shuffle(cols)
        i = 0
        for p in pats:
            for _ in range(g):
                a[list(p), cols[i]] = rng.standard_normal(n).astype(np.float32) + 2.0
                i += 1
    val, idx = nmg.dense_to_nmg(a, n, m, g)
    back = nmg.nmg_to_dense(val, idx, m, n, K)
    np.testing.assert_allclose(back, a)
    assert nmg.energy(a, back) == pytest.approx(1.0, abs=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    st.sampled_from([(4, 2, 1), (4, 2, 4), (4, 1, 2), (8, 2, 2), (10, 1, 4)]),
    st.integers(1, 3),  # slabs
    st.integers(1, 40),  # K columns (may be partial chunks)
    st.integers(0, 2**31 - 1),
)
def test_conversion_invariants(fmt, slabs, K, seed):
    m, n, g = fmt
    M = slabs * m
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((M, K)).astype(np.float32)
    val, idx = nmg.dense_to_nmg(a, n, m, g)
    C = nmg.num_patterns(m, n)
    CH = -(-K // (C * g))
    assert val.shape == (slabs, CH, C, g, n)
    assert idx.shape == (slabs, CH, C, g)
    # idx in range, and each real column appears at most once per slab.
    assert idx.min() >= 0 and idx.max() < max(K, 1)
    for s in range(slabs):
        cols = idx[s].reshape(-1)
        vals = val[s].reshape(-1, n)
        real = np.abs(vals).sum(axis=1) > 0
        real_cols = cols[real]
        assert len(np.unique(real_cols)) == len(real_cols)
        # idx stays within its chunk's column range.
        for ch in range(CH):
            lo, hi = ch * C * g, min((ch + 1) * C * g, K)
            chunk_idx = idx[s, ch].reshape(-1)
            chunk_real = np.abs(val[s, ch].reshape(-1, n)).sum(axis=1) > 0
            assert ((chunk_idx[chunk_real] >= lo) & (chunk_idx[chunk_real] < hi)).all()


@settings(max_examples=20, deadline=None)
@given(
    st.sampled_from([(4, 2, 2), (4, 1, 2), (8, 2, 1)]),
    st.integers(1, 2),
    st.integers(4, 30),
    st.integers(0, 2**31 - 1),
)
def test_roundtrip_is_nm_projection(fmt, slabs, K, seed):
    """densify(sparsify(A)) keeps exactly n values per (column, m-block) and
    never invents values."""
    m, n, g = fmt
    M = slabs * m
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((M, K)).astype(np.float32)
    val, idx = nmg.dense_to_nmg(a, n, m, g)
    back = nmg.nmg_to_dense(val, idx, m, n, K)
    assert back.shape == a.shape
    # Every kept value matches the original.
    kept = back != 0
    np.testing.assert_allclose(back[kept], a[kept])
    # Per column of each slab: at most n nonzeros.
    for s in range(slabs):
        nnz_per_col = (back[s * m : (s + 1) * m] != 0).sum(axis=0)
        assert (nnz_per_col <= n).all()


def test_energy_close_to_nm_upper_bound():
    """Fig. 7 sanity: n:m:g with larger g preserves more energy, bounded by
    the unstructured top-k projection."""
    m, n = 4, 2
    rng = np.random.default_rng(1)
    a = rng.standard_normal((64, 96)).astype(np.float32)
    energies = []
    for g in (1, 4, 8):
        val, idx = nmg.dense_to_nmg(a, n, m, g)
        back = nmg.nmg_to_dense(val, idx, m, n, a.shape[1])
        energies.append(nmg.energy(a, back))
    # Unstructured top-50% energy upper bound.
    flat = np.sort(np.abs(a).ravel())[::-1]
    unstructured = flat[: flat.size // 2].sum() / flat.sum()
    for e in energies:
        assert 0.5 < e <= unstructured + 1e-6
    # Larger groups are weakly better (more freedom inside a chunk).
    assert energies[0] <= energies[-1] + 0.02


def test_sparsity_of():
    assert nmg.sparsity_of(2, 4) == 0.5
    assert nmg.sparsity_of(1, 10) == 0.9
