//! Ablation (§5.1/§6.1 design choices): group size g and conversion algorithm.
//!
//! Sweeps g for fixed 2:4 sparsity and reports (a) GEMM runtime — larger
//! groups amortize the per-pattern accumulator save/init, (b) energy —
//! larger groups approach plain n:m, and (c) conversion cost — larger
//! chunks make greedy assignment more expensive. Also ablates greedy vs
//! swap-refinement conversion (§5.2 CPU vs GPU algorithm).
//!
//! Run: `cargo bench --bench ablation_group_size [-- --full]`

use sten::energy;
use sten::formats::NmgTensor;
use sten::kernels::{gemm_flops, nmg_gemm};
use sten::tensor::DenseTensor;
use sten::util::benchkit::{parse_mode, Bench, BenchMode};
use sten::util::rng::Pcg64;

fn main() {
    let mode = parse_mode();
    let (m_dim, k_dim, n_dim, bench) = match mode {
        BenchMode::Full => (768, 3072, 2048, Bench::new(2, 8)),
        BenchMode::Quick => (256, 768, 512, Bench::new(1, 5)),
    };
    println!("# Ablation: group size g at 2:4, GEMM {m_dim}x{k_dim}x{n_dim} (mode {mode:?})");
    let flops = gemm_flops(m_dim, k_dim, n_dim);
    let mut rng = Pcg64::seeded(8);
    let a = DenseTensor::randn(&[m_dim, k_dim], &mut rng);
    let b = DenseTensor::randn(&[k_dim, n_dim], &mut rng);

    println!("\ng\tgemm_ms\tgflops\tenergy\tconvert_ms\tbytes");
    for g in [1usize, 2, 4, 8, 16] {
        let conv = Bench::new(1, 3).run(|| NmgTensor::from_dense(&a, 2, 4, g));
        let t = NmgTensor::from_dense(&a, 2, 4, g);
        let e = energy::energy(&a, &t.to_dense());
        let run = bench.run(|| nmg_gemm::spmm(&t, &b));
        println!(
            "{g}\t{:.2}\t{:.1}\t{:.4}\t{:.1}\t{}",
            run.median * 1e3,
            flops / run.median / 1e9,
            e,
            conv.median * 1e3,
            t.bytes()
        );
    }

    println!("\n# conversion algorithm ablation (2:4:4)");
    let greedy = Bench::new(1, 3).run(|| NmgTensor::from_dense(&a, 2, 4, 4));
    let tg = NmgTensor::from_dense(&a, 2, 4, 4);
    println!(
        "greedy\t{:.1} ms\tenergy {:.4}",
        greedy.median * 1e3,
        energy::energy(&a, &tg.to_dense())
    );
    // Swap refinement is O(chunk^2) per sweep; bench on a slice in quick mode.
    let rows = if mode == BenchMode::Full { m_dim } else { 64.min(m_dim) };
    let asub = DenseTensor::from_vec(
        &[rows, k_dim],
        a.data()[..rows * k_dim].to_vec(),
    );
    let swap = Bench::new(0, 2).run(|| NmgTensor::from_dense_swap(&asub, 2, 4, 4));
    let ts = NmgTensor::from_dense_swap(&asub, 2, 4, 4);
    println!(
        "swap-refine ({rows} rows)\t{:.1} ms\tenergy {:.4}",
        swap.median * 1e3,
        energy::energy(&asub, &ts.to_dense())
    );
}
