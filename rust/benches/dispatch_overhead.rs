//! §4.4 dispatch overhead: direct hit vs lossless conversion vs dense
//! fallback, plus the operator-patching route.
//!
//! Measures the per-call cost of each dispatch outcome on a small matmul so
//! the dispatch machinery (signature hash, conversion search, fallback
//! densification) dominates — the framework-overhead component of Fig. 11.
//! Also compares frozen (lock-free snapshot) vs unfrozen (Mutex-guarded)
//! registry lookup under pool-worker contention, and reports conversion-path
//! clones avoided by the Cow operand pass-through.
//!
//! Run: `cargo bench --bench dispatch_overhead [-- --full]`

use sten::dispatch::{Dispatcher, PatchTable};
use sten::formats::{AnyTensor, CooTensor, CsrTensor, Layout, MaskedTensor};
use sten::ops::OpKind;
use sten::tensor::DenseTensor;
use sten::util::benchkit::{parse_mode, Bench, BenchMode};
use sten::util::rng::Pcg64;
use sten::util::threadpool;

fn main() {
    let mode = parse_mode();
    let (dim, bench) = match mode {
        BenchMode::Full => (256, Bench::new(5, 40)),
        BenchMode::Quick => (64, Bench::new(3, 20)),
    };
    println!("# Dispatch overhead on {dim}x{dim} matmul operands (mode {mode:?})");
    let mut rng = Pcg64::seeded(9);
    let w = DenseTensor::randn(&[dim, dim], &mut rng).map(|x| if x > 0.5 { x } else { 0.0 });
    let x = AnyTensor::Dense(DenseTensor::randn(&[dim, dim], &mut rng));

    let d = Dispatcher::with_builtins();
    println!("\nroute\tper_call_us\toutcome");

    // 1. Exact hit: (Dense, Dense).
    let a = AnyTensor::Dense(w.clone());
    let t = bench.run(|| d.call(OpKind::MatMul, &[a.clone(), x.clone()]).unwrap());
    println!("hit (Dense,Dense)\t{:.1}\thit", t.median * 1e6);

    // 2. Exact hit: (Csr, Dense) sparse kernel.
    let a = AnyTensor::Csr(CsrTensor::from_dense(&w));
    let t = bench.run(|| d.call(OpKind::MatMul, &[a.clone(), x.clone()]).unwrap());
    println!("hit (Csr,Dense)\t{:.1}\thit", t.median * 1e6);

    // 3. Conversion: (Coo, Dense) -> (Csr, Dense). The dense rhs is already
    // in the candidate layout, so it rides through borrowed (Cow), not
    // cloned — counted by `avoided_clones`.
    let a = AnyTensor::Coo(CooTensor::from_dense(&w));
    d.stats.reset();
    let t = bench.run(|| d.call(OpKind::MatMul, &[a.clone(), x.clone()]).unwrap());
    let (_, conv, _) = d.stats.counts();
    assert!(conv > 0, "expected conversion route");
    let avoided = d.stats.avoided_clones();
    assert!(avoided >= conv, "each conversion call must borrow its dense rhs");
    println!("convert (Coo->Csr)\t{:.1}\tconversion ({avoided} clones avoided)", t.median * 1e6);

    // 4. Dense fallback: softmax on a masked tensor.
    let a = AnyTensor::Masked(MaskedTensor::from_dense(&w));
    d.stats.reset();
    let t = bench.run(|| d.call(OpKind::Softmax, &[a.clone()]).unwrap());
    let (_, _, fb) = d.stats.counts();
    assert!(fb > 0, "expected fallback route");
    println!("fallback (Softmax on Masked)\t{:.1}\tdense fallback", t.median * 1e6);

    // 5. Patched external function with sparse input.
    let table = PatchTable::new();
    fn ext_matmul(ins: &[AnyTensor]) -> anyhow::Result<AnyTensor> {
        Ok(AnyTensor::Dense(sten::kernels::dense_gemm::matmul(
            ins[0].as_dense().unwrap(),
            ins[1].as_dense().unwrap(),
        )))
    }
    table.patch("ext.matmul", ext_matmul, OpKind::MatMul);
    let a = AnyTensor::Csr(CsrTensor::from_dense(&w));
    let t = bench.run(|| table.call(&d, "ext.matmul", &[a.clone(), x.clone()]).unwrap());
    println!("patched (Csr via ext.matmul)\t{:.1}\tpatch->hit", t.median * 1e6);

    // 6. Pure dispatch decision cost: tiny operands so the kernel is ~free.
    let tiny_a = AnyTensor::Dense(DenseTensor::ones(&[2, 2]));
    let tiny_b = AnyTensor::Dense(DenseTensor::ones(&[2, 2]));
    let t = bench.run(|| d.call(OpKind::MatMul, &[tiny_a.clone(), tiny_b.clone()]).unwrap());
    println!("decision-only (2x2)\t{:.2}\thit", t.median * 1e6);

    // 6b. Same decision through call_ref: no owned argument vector at all.
    let t = bench.run(|| d.call_ref(OpKind::MatMul, &[&tiny_a, &tiny_b]).unwrap());
    println!("decision-only call_ref (2x2)\t{:.2}\thit (zero-clone)", t.median * 1e6);

    // 7. Frozen vs unfrozen registry under contention: pool workers hammer
    // call_ref concurrently. Unfrozen, every call serializes on the registry
    // Mutex (one acquisition per decision — and before this PR, up to
    // 1 + 2 x conversion-targets); frozen, lookup is lock-free.
    let df = Dispatcher::with_builtins();
    df.freeze();
    let calls_per_worker = 256usize;
    let lanes = 16usize;
    let contended = |disp: &Dispatcher| {
        bench
            .run(|| {
                threadpool::parallel_for(lanes, 1, |s, e| {
                    for _ in s..e {
                        for _ in 0..calls_per_worker {
                            disp.call_ref(OpKind::MatMul, &[&tiny_a, &tiny_b]).unwrap();
                        }
                    }
                });
            })
            .median
            / (lanes * calls_per_worker) as f64
    };
    let t_unfrozen = contended(&d);
    let t_frozen = contended(&df);
    println!(
        "contended lookup\tunfrozen {:.3} us/call, frozen {:.3} us/call ({:.2}x)",
        t_unfrozen * 1e6,
        t_frozen * 1e6,
        t_unfrozen / t_frozen.max(1e-12)
    );
    // Generous bound (timing noise on loaded CI boxes), but a frozen
    // registry must never be meaningfully slower than a locked one.
    assert!(
        t_frozen <= t_unfrozen * 1.5,
        "frozen (lock-free) lookup slower than locked lookup: {:.3}us vs {:.3}us",
        t_frozen * 1e6,
        t_unfrozen * 1e6
    );

    let (dispatch_s, kernel_s) = d.stats.times();
    println!(
        "\ncumulative: dispatch {:.1} ms vs kernel {:.1} ms ({:.1}% dispatch share)",
        dispatch_s * 1e3,
        kernel_s * 1e3,
        100.0 * dispatch_s / (dispatch_s + kernel_s)
    );

    // Registered-layout sanity: at least one signature per builtin op.
    assert!(d.len() >= 14);
    let _ = Layout::Dense;
}
