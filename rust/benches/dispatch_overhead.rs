//! §4.4 dispatch overhead: direct hit vs lossless conversion vs dense
//! fallback, plus the operator-patching route.
//!
//! Measures the per-call cost of each dispatch outcome on a small matmul so
//! the dispatch machinery (signature hash, conversion search, fallback
//! densification) dominates — the framework-overhead component of Fig. 11.
//!
//! Run: `cargo bench --bench dispatch_overhead [-- --full]`

use sten::dispatch::{Dispatcher, PatchTable};
use sten::formats::{AnyTensor, CooTensor, CsrTensor, Layout, MaskedTensor};
use sten::ops::OpKind;
use sten::tensor::DenseTensor;
use sten::util::benchkit::{parse_mode, Bench, BenchMode};
use sten::util::rng::Pcg64;

fn main() {
    let mode = parse_mode();
    let (dim, bench) = match mode {
        BenchMode::Full => (256, Bench::new(5, 40)),
        BenchMode::Quick => (64, Bench::new(3, 20)),
    };
    println!("# Dispatch overhead on {dim}x{dim} matmul operands (mode {mode:?})");
    let mut rng = Pcg64::seeded(9);
    let w = DenseTensor::randn(&[dim, dim], &mut rng).map(|x| if x > 0.5 { x } else { 0.0 });
    let x = AnyTensor::Dense(DenseTensor::randn(&[dim, dim], &mut rng));

    let d = Dispatcher::with_builtins();
    println!("\nroute\tper_call_us\toutcome");

    // 1. Exact hit: (Dense, Dense).
    let a = AnyTensor::Dense(w.clone());
    let t = bench.run(|| d.call(OpKind::MatMul, &[a.clone(), x.clone()]).unwrap());
    println!("hit (Dense,Dense)\t{:.1}\thit", t.median * 1e6);

    // 2. Exact hit: (Csr, Dense) sparse kernel.
    let a = AnyTensor::Csr(CsrTensor::from_dense(&w));
    let t = bench.run(|| d.call(OpKind::MatMul, &[a.clone(), x.clone()]).unwrap());
    println!("hit (Csr,Dense)\t{:.1}\thit", t.median * 1e6);

    // 3. Conversion: (Coo, Dense) -> (Csr, Dense).
    let a = AnyTensor::Coo(CooTensor::from_dense(&w));
    d.stats.reset();
    let t = bench.run(|| d.call(OpKind::MatMul, &[a.clone(), x.clone()]).unwrap());
    let (_, conv, _) = d.stats.counts();
    assert!(conv > 0, "expected conversion route");
    println!("convert (Coo->Csr)\t{:.1}\tconversion", t.median * 1e6);

    // 4. Dense fallback: softmax on a masked tensor.
    let a = AnyTensor::Masked(MaskedTensor::from_dense(&w));
    d.stats.reset();
    let t = bench.run(|| d.call(OpKind::Softmax, &[a.clone()]).unwrap());
    let (_, _, fb) = d.stats.counts();
    assert!(fb > 0, "expected fallback route");
    println!("fallback (Softmax on Masked)\t{:.1}\tdense fallback", t.median * 1e6);

    // 5. Patched external function with sparse input.
    let table = PatchTable::new();
    fn ext_matmul(ins: &[AnyTensor]) -> anyhow::Result<AnyTensor> {
        Ok(AnyTensor::Dense(sten::kernels::dense_gemm::matmul(
            ins[0].as_dense().unwrap(),
            ins[1].as_dense().unwrap(),
        )))
    }
    table.patch("ext.matmul", ext_matmul, OpKind::MatMul);
    let a = AnyTensor::Csr(CsrTensor::from_dense(&w));
    let t = bench.run(|| table.call(&d, "ext.matmul", &[a.clone(), x.clone()]).unwrap());
    println!("patched (Csr via ext.matmul)\t{:.1}\tpatch->hit", t.median * 1e6);

    // 6. Pure dispatch decision cost: tiny operands so the kernel is ~free.
    let tiny_a = AnyTensor::Dense(DenseTensor::ones(&[2, 2]));
    let tiny_b = AnyTensor::Dense(DenseTensor::ones(&[2, 2]));
    let t = bench.run(|| d.call(OpKind::MatMul, &[tiny_a.clone(), tiny_b.clone()]).unwrap());
    println!("decision-only (2x2)\t{:.2}\thit", t.median * 1e6);

    let (dispatch_s, kernel_s) = d.stats.times();
    println!(
        "\ncumulative: dispatch {:.1} ms vs kernel {:.1} ms ({:.1}% dispatch share)",
        dispatch_s * 1e3,
        kernel_s * 1e3,
        100.0 * dispatch_s / (dispatch_s + kernel_s)
    );

    // Registered-layout sanity: at least one signature per builtin op.
    assert!(d.len() >= 14);
    let _ = Layout::Dense;
}
