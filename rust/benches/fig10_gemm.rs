//! Fig. 10: sparse-dense GEMM — n:m:g vs the unstructured comparator.
//!
//! The paper benchmarks its n:m:g kernel against DeepSparse (unstructured)
//! on a 768x3072x4096 BERT FFN GEMM over 50-95% sparsity; DeepSparse is
//! closed-source, so the comparator here is the tuned CSR kernel (DESIGN.md
//! §Substitutions). Also reports the dense GEMM and the BCSR (TVM-block
//! style) kernel for context.
//!
//! Paper claims to reproduce in shape: n:m:g beats unstructured at every
//! sparsity level (up to ~4x), and beats dense from moderate sparsity on.
//!
//! Run: `cargo bench --bench fig10_gemm [-- --full]`

use sten::formats::{BcsrTensor, CsrTensor, NmgTensor};
use sten::kernels::{bcsr_gemm, csr_gemm, dense_gemm, gemm_flops, nmg_gemm};
use sten::sparsify::{BlockFraction, ScalarFraction, Sparsifier};
use sten::tensor::DenseTensor;
use sten::util::benchkit::{parse_mode, Bench, BenchMode};
use sten::util::rng::Pcg64;

fn main() {
    let mode = parse_mode();
    // (M, K, N): A (M,K) sparse weight, B (K,N) dense activations.
    let (m_dim, k_dim, n_dim, bench) = match mode {
        BenchMode::Full => (760, 3072, 4096, Bench::new(2, 8)),
        BenchMode::Quick => (240, 1024, 512, Bench::new(1, 5)),
    };
    println!("# Fig 10: sparse-dense GEMM {m_dim}x{k_dim}x{n_dim} (M chosen divisible by m in {{4,8,10}}) (mode {mode:?})");
    let flops = gemm_flops(m_dim, k_dim, n_dim);

    let mut rng = Pcg64::seeded(3);
    let a = DenseTensor::randn(&[m_dim, k_dim], &mut rng);
    let b = DenseTensor::randn(&[k_dim, n_dim], &mut rng);

    // Dense baseline.
    let dense_t = bench.run(|| dense_gemm::matmul(&a, &b)).median;
    println!("\nsparsity\tkernel\tmedian_ms\tdense_gflops_equiv\tspeedup_vs_dense");
    println!("0.00\tdense\t{:.2}\t{:.1}\t1.00", dense_t * 1e3, flops / dense_t / 1e9);

    // Sweep formats: (n, m, g) covering 50-90%.
    for (n, m, g) in [(2usize, 4usize, 4usize), (1, 4, 4), (2, 8, 4), (1, 8, 4), (1, 10, 4)] {
        let s = 1.0 - n as f32 / m as f32;

        // n:m:g kernel on a conforming (pruned) weight.
        let nmg = NmgTensor::from_dense(&a, n, m, g);
        let t_nmg = bench.run(|| nmg_gemm::spmm(&nmg, &b)).median;
        println!(
            "{s:.2}\tnmg-{n}:{m}:{g}\t{:.2}\t{:.1}\t{:.2}",
            t_nmg * 1e3,
            flops / t_nmg / 1e9,
            dense_t / t_nmg
        );

        // Unstructured comparator (DeepSparse stand-in) at matched sparsity.
        let pruned = ScalarFraction { fraction: s }.prune(&a);
        let csr = CsrTensor::from_dense(&pruned);
        let t_csr = bench.run(|| csr_gemm::spmm(&csr, &b)).median;
        println!(
            "{s:.2}\tcsr-unstructured\t{:.2}\t{:.1}\t{:.2}",
            t_csr * 1e3,
            flops / t_csr / 1e9,
            dense_t / t_csr
        );

        // Block comparator (TVM-block stand-in) at matched sparsity.
        let bpruned = BlockFraction { fraction: s, bh: 4, bw: 4 }.prune(&a);
        let bcsr = BcsrTensor::from_dense(&bpruned, 4, 4);
        let t_bcsr = bench.run(|| bcsr_gemm::spmm(&bcsr, &b)).median;
        println!(
            "{s:.2}\tbcsr-4x4\t{:.2}\t{:.1}\t{:.2}",
            t_bcsr * 1e3,
            flops / t_bcsr / 1e9,
            dense_t / t_bcsr
        );

        // Shape claim: n:m:g faster than unstructured at every level.
        if t_nmg >= t_csr {
            println!("WARNING: nmg not faster than csr at sparsity {s:.2}");
        }
    }

    // Conversion cost (paper §5.2: conversion speed matters for training).
    println!("\n# dense -> n:m:g conversion (2:4:4)");
    let conv = Bench::new(1, 5).run(|| NmgTensor::from_dense(&a, 2, 4, 4)).median;
    let swap = Bench::new(1, 3).run(|| NmgTensor::from_dense_swap(&a, 2, 4, 4)).median;
    println!("greedy\t{:.2} ms", conv * 1e3);
    println!("swap-refine\t{:.2} ms", swap * 1e3);
}
