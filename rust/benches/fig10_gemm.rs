//! Fig. 10: sparse-dense GEMM — n:m:g vs the unstructured comparator.
//!
//! The paper benchmarks its n:m:g kernel against DeepSparse (unstructured)
//! on a 768x3072x4096 BERT FFN GEMM over 50-95% sparsity; DeepSparse is
//! closed-source, so the comparator here is the tuned CSR kernel (DESIGN.md
//! §Substitutions). Also reports the dense GEMM and the BCSR (TVM-block
//! style) kernel for context, plus blocked-vs-baseline rows for the
//! cache-blocked n:m:g and BCSR kernels (`spmm` vs `spmm_unblocked` /
//! `spmm_naive`) and the format the cost-model autotuner would choose at
//! each swept point.
//!
//! Paper claims to reproduce in shape: n:m:g beats unstructured at every
//! sparsity level (up to ~4x), and beats dense from moderate sparsity on.
//!
//! Run: `cargo bench --bench fig10_gemm [-- --full | -- --smoke]`
//! (`--smoke` is the CI gate: small shapes, every kernel asserted allclose
//! against the densified dense-GEMM reference before timing.)
//!
//! Emits `BENCH_fig10_gemm.json` (machine-readable points, including the
//! autotuner's chosen format per sparsity level).

use sten::formats::{BcsrTensor, CsrTensor, Layout, NmgTensor};
use sten::kernels::backend::{self, Backend};
use sten::kernels::{bcsr_gemm, csr_gemm, dense_gemm, gemm_flops, nmg_gemm, simd};
use sten::sparsify::{BlockFraction, ScalarFraction, Sparsifier};
use sten::tensor::DenseTensor;
use sten::tune::{model_cost, WeightStats};
use sten::util::benchkit::{Bench, JsonReport};
use sten::util::rng::Pcg64;

/// Cheapest layout under the autotuner's cost model for this pruned weight
/// (scored for the backend the sweep is actually running on).
fn chosen_format(
    weight: &DenseTensor,
    ncols: usize,
    nmg: Option<(usize, usize, usize)>,
) -> String {
    let stats = WeightStats::measure(weight);
    let mut best: Option<(Layout, f64)> = None;
    for layout in [Layout::Dense, Layout::Nmg, Layout::Bcsr, Layout::Ell, Layout::Csr] {
        if let Some(cost) = model_cost(layout, &stats, ncols, nmg, backend::active()) {
            let better = match best {
                None => true,
                Some((_, c)) => cost < c,
            };
            if better {
                best = Some((layout, cost));
            }
        }
    }
    best.map(|(l, _)| l.to_string()).unwrap_or_else(|| "none".to_string())
}

fn assert_close(got: &DenseTensor, want: &DenseTensor, label: &str) {
    assert!(
        got.allclose(want, 1e-3, 1e-3),
        "{label}: kernel diverges from dense reference by {}",
        got.max_abs_diff(want)
    );
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let full = args.iter().any(|a| a == "--full");
    // (M, K, N): A (M,K) sparse weight, B (K,N) dense activations.
    let (m_dim, k_dim, n_dim, bench) = if full {
        (760, 3072, 4096, Bench::new(2, 8))
    } else if smoke {
        (120, 512, 256, Bench::new(1, 3))
    } else {
        (240, 1024, 512, Bench::new(1, 5))
    };
    println!(
        "# Fig 10: sparse-dense GEMM {m_dim}x{k_dim}x{n_dim} \
         (M chosen divisible by m in {{4,8,10}}) (smoke={smoke}, full={full})"
    );
    let flops = gemm_flops(m_dim, k_dim, n_dim);
    let mut json = JsonReport::new("fig10_gemm");
    // Every row records the backend the timed kernels dispatched to plus
    // the detected CPU features, so BENCH_ deltas across machines/backends
    // are attributable.
    let be = backend::active().to_string();
    let cpu = simd::cpu_features();
    println!("# backend: {be} (cpu features: {cpu})");

    let mut rng = Pcg64::seeded(3);
    let a = DenseTensor::randn(&[m_dim, k_dim], &mut rng);
    let b = DenseTensor::randn(&[k_dim, n_dim], &mut rng);

    // Dense baseline.
    let dense_t = bench.run(|| dense_gemm::matmul(&a, &b)).median;
    println!("\nsparsity\tkernel\tmedian_ms\tdense_gflops_equiv\tspeedup_vs_dense\tchosen_format");
    println!(
        "0.00\tdense\t{:.2}\t{:.1}\t1.00\t{}",
        dense_t * 1e3,
        flops / dense_t / 1e9,
        chosen_format(&a, n_dim, None)
    );
    json.row(&[
        ("sparsity", 0.0.into()),
        ("kernel", "dense".into()),
        ("median_s", dense_t.into()),
        ("chosen_format", chosen_format(&a, n_dim, None).as_str().into()),
        ("backend", be.as_str().into()),
        ("cpu_features", cpu.as_str().into()),
    ]);

    // Scalar-vs-SIMD backend sweep on the two kernels the backend work
    // targets hardest: dense GEMM and the n:m:g slab kernel. Results are
    // allclose-asserted against each other BEFORE anything is timed, so a
    // silently-diverging SIMD path can never post a speedup number.
    {
        let nmg = NmgTensor::from_dense(&a, 2, 4, 4);
        let (scalar_dense, scalar_nmg) = {
            let _g = backend::force(Backend::Scalar);
            (dense_gemm::matmul(&a, &b), nmg_gemm::spmm(&nmg, &b))
        };
        if simd::have_avx2_fma() {
            {
                let _g = backend::force(Backend::Simd);
                let simd_dense = dense_gemm::matmul(&a, &b);
                let simd_nmg = nmg_gemm::spmm(&nmg, &b);
                assert_close(&simd_dense, &scalar_dense, "backend sweep: dense simd-vs-scalar");
                assert_close(&simd_nmg, &scalar_nmg, "backend sweep: nmg simd-vs-scalar");
            }
            println!("\n# backend sweep: scalar vs simd (allclose-checked before timing)");
            let dense_run = || dense_gemm::matmul(&a, &b);
            let nmg_run = || nmg_gemm::spmm(&nmg, &b);
            let kernels: [(&str, f64, &dyn Fn() -> DenseTensor); 2] =
                [("dense", 0.0, &dense_run), ("nmg-2:4:4", 0.5, &nmg_run)];
            for (kernel, sparsity, run) in kernels {
                let t_scalar = {
                    let _g = backend::force(Backend::Scalar);
                    bench.run(run).median
                };
                let t_simd = {
                    let _g = backend::force(Backend::Simd);
                    bench.run(run).median
                };
                let speedup = t_scalar / t_simd;
                println!(
                    "{kernel}\tscalar {:.2} ms\tsimd {:.2} ms\tspeedup {speedup:.2}x",
                    t_scalar * 1e3,
                    t_simd * 1e3
                );
                if speedup <= 1.0 {
                    println!("WARNING: simd not faster than scalar on {kernel}");
                }
                json.row(&[
                    ("sparsity", sparsity.into()),
                    ("kernel", format!("{kernel}-backend-sweep").as_str().into()),
                    ("scalar_median_s", t_scalar.into()),
                    ("simd_median_s", t_simd.into()),
                    ("simd_speedup", speedup.into()),
                    ("backend", "both".into()),
                    ("cpu_features", cpu.as_str().into()),
                ]);
            }
        } else {
            println!("# backend sweep skipped: AVX2+FMA not detected on this host");
        }
    }

    // Sweep formats: (n, m, g) covering 50-90%.
    for (n, m, g) in [(2usize, 4usize, 4usize), (1, 4, 4), (2, 8, 4), (1, 8, 4), (1, 10, 4)] {
        let s = 1.0 - n as f32 / m as f32;

        // n:m:g kernel on a conforming (pruned) weight.
        let nmg = NmgTensor::from_dense(&a, n, m, g);
        let pruned_nmg = nmg.to_dense();
        let want_nmg = dense_gemm::matmul(&pruned_nmg, &b);
        if smoke {
            assert_close(&nmg_gemm::spmm(&nmg, &b), &want_nmg, "nmg blocked");
            assert_close(&nmg_gemm::spmm_unblocked(&nmg, &b), &want_nmg, "nmg unblocked");
        }
        let chosen = chosen_format(&pruned_nmg, n_dim, Some((n, m, g)));
        let t_nmg = bench.run(|| nmg_gemm::spmm(&nmg, &b)).median;
        let t_nmg_un = bench.run(|| nmg_gemm::spmm_unblocked(&nmg, &b)).median;
        println!(
            "{s:.2}\tnmg-{n}:{m}:{g}\t{:.2}\t{:.1}\t{:.2}\t{chosen}",
            t_nmg * 1e3,
            flops / t_nmg / 1e9,
            dense_t / t_nmg
        );
        println!(
            "{s:.2}\tnmg-{n}:{m}:{g}-unblocked\t{:.2}\t{:.1}\t{:.2}\t-",
            t_nmg_un * 1e3,
            flops / t_nmg_un / 1e9,
            dense_t / t_nmg_un
        );
        json.row(&[
            ("sparsity", (s as f64).into()),
            ("kernel", format!("nmg-{n}:{m}:{g}").as_str().into()),
            ("median_s", t_nmg.into()),
            ("unblocked_median_s", t_nmg_un.into()),
            ("blocked_speedup", (t_nmg_un / t_nmg).into()),
            ("chosen_format", chosen.as_str().into()),
            ("backend", be.as_str().into()),
            ("cpu_features", cpu.as_str().into()),
        ]);
        if t_nmg > t_nmg_un {
            println!("WARNING: blocked nmg slower than unblocked at sparsity {s:.2}");
        }

        // Unstructured comparator (DeepSparse stand-in) at matched sparsity.
        let pruned = ScalarFraction { fraction: s }.prune(&a);
        let csr = CsrTensor::from_dense(&pruned);
        if smoke {
            assert_close(&csr_gemm::spmm(&csr, &b), &dense_gemm::matmul(&pruned, &b), "csr");
        }
        let t_csr = bench.run(|| csr_gemm::spmm(&csr, &b)).median;
        println!(
            "{s:.2}\tcsr-unstructured\t{:.2}\t{:.1}\t{:.2}\t{}",
            t_csr * 1e3,
            flops / t_csr / 1e9,
            dense_t / t_csr,
            chosen_format(&pruned, n_dim, None)
        );
        json.row(&[
            ("sparsity", (s as f64).into()),
            ("kernel", "csr-unstructured".into()),
            ("median_s", t_csr.into()),
            ("chosen_format", chosen_format(&pruned, n_dim, None).as_str().into()),
            ("backend", be.as_str().into()),
            ("cpu_features", cpu.as_str().into()),
        ]);

        // Block comparator (TVM-block stand-in) at matched sparsity.
        let bpruned = BlockFraction { fraction: s, bh: 4, bw: 4 }.prune(&a);
        let bcsr = BcsrTensor::from_dense(&bpruned, 4, 4);
        if smoke {
            let want = dense_gemm::matmul(&bpruned, &b);
            assert_close(&bcsr_gemm::spmm(&bcsr, &b), &want, "bcsr blocked");
            assert_close(&bcsr_gemm::spmm_naive(&bcsr, &b), &want, "bcsr naive");
        }
        let t_bcsr = bench.run(|| bcsr_gemm::spmm(&bcsr, &b)).median;
        let t_bcsr_naive = bench.run(|| bcsr_gemm::spmm_naive(&bcsr, &b)).median;
        println!(
            "{s:.2}\tbcsr-4x4\t{:.2}\t{:.1}\t{:.2}\t{}",
            t_bcsr * 1e3,
            flops / t_bcsr / 1e9,
            dense_t / t_bcsr,
            chosen_format(&bpruned, n_dim, None)
        );
        println!(
            "{s:.2}\tbcsr-4x4-naive\t{:.2}\t{:.1}\t{:.2}\t-",
            t_bcsr_naive * 1e3,
            flops / t_bcsr_naive / 1e9,
            dense_t / t_bcsr_naive
        );
        json.row(&[
            ("sparsity", (s as f64).into()),
            ("kernel", "bcsr-4x4".into()),
            ("median_s", t_bcsr.into()),
            ("naive_median_s", t_bcsr_naive.into()),
            ("blocked_speedup", (t_bcsr_naive / t_bcsr).into()),
            ("chosen_format", chosen_format(&bpruned, n_dim, None).as_str().into()),
            ("backend", be.as_str().into()),
            ("cpu_features", cpu.as_str().into()),
        ]);
        if t_bcsr > t_bcsr_naive {
            println!("WARNING: blocked bcsr slower than naive at sparsity {s:.2}");
        }

        // Shape claim: n:m:g faster than unstructured at every level.
        if t_nmg >= t_csr {
            println!("WARNING: nmg not faster than csr at sparsity {s:.2}");
        }
    }

    // Conversion cost (paper §5.2: conversion speed matters for training).
    println!("\n# dense -> n:m:g conversion (2:4:4)");
    let conv = Bench::new(1, 5).run(|| NmgTensor::from_dense(&a, 2, 4, 4)).median;
    let swap = Bench::new(1, 3).run(|| NmgTensor::from_dense_swap(&a, 2, 4, 4)).median;
    println!("greedy\t{:.2} ms", conv * 1e3);
    println!("swap-refine\t{:.2} ms", swap * 1e3);

    if smoke {
        println!("smoke OK: every kernel matched the dense reference");
    }
    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write bench json: {e}"),
    }
}
