//! Fig. 11: end-to-end encoder inference latency with breakdown.
//!
//! Runs the coordinator over the AOT artifacts in each FFN execution mode
//! and reports median latency plus the runtime/native/framework split (the
//! paper's "STen time vs PyTorch runtime" breakdown). Paper claims to
//! reproduce in shape: sparse n:m:g inference beats the dense baseline, and
//! a visible share of residual latency is framework/runtime overhead rather
//! than kernels.
//!
//! Run: `cargo bench --bench fig11_e2e_inference [-- --full]`
//! (full mode uses the `base` artifacts: d_model 256, 4 layers, seq 128.)

use sten::coordinator::{Engine, FfnMode};
use sten::runtime::ArtifactRuntime;
use sten::util::benchkit::{parse_mode, Bench, BenchMode};
use sten::util::rng::Pcg64;

fn main() {
    let mode = parse_mode();
    let (tag, bench) = match mode {
        BenchMode::Full => ("base", Bench::new(2, 10)),
        BenchMode::Quick => ("tiny", Bench::new(2, 8)),
    };
    println!("# Fig 11: end-to-end encoder inference, artifacts `{tag}` (mode {mode:?})");
    println!("\nffn_mode\tmedian_ms\tspeedup_vs_dense_artifact\truntime_ms\tnative_ms\tframework_ms");

    let modes: Vec<(&str, FfnMode)> = vec![
        ("dense-artifact", FfnMode::DenseArtifact),
        ("native-dense", FfnMode::NativeDense),
        ("nmg-2:4:4", FfnMode::NativeNmg { n: 2, m: 4, g: 4 }),
        ("nmg-1:4:4", FfnMode::NativeNmg { n: 1, m: 4, g: 4 }),
        ("nmg-2:8:4", FfnMode::NativeNmg { n: 2, m: 8, g: 4 }),
    ];
    let mut dense = None;
    for (name, ffn) in modes {
        let rt = ArtifactRuntime::open_default().expect("make artifacts first");
        let mut engine = Engine::new(rt, tag, ffn, 42).unwrap();
        let mut rng = Pcg64::seeded(7);
        let tokens = engine.random_tokens(&mut rng);
        engine.forward(&tokens).unwrap(); // warm (compile)
        engine.reset_timing();
        let sample = bench.run(|| engine.forward(&tokens).unwrap());
        let t = engine.timing();
        // Timing accumulates over warmup + measured iterations.
        let total_calls = (bench.warmup + sample.iters) as f64;
        println!(
            "{name}\t{:.2}\t{:.2}\t{:.2}\t{:.2}\t{:.2}",
            sample.median * 1e3,
            dense.get_or_insert(sample.median).to_owned() / sample.median,
            t.secs("runtime") / total_calls * 1e3,
            t.secs("native") / total_calls * 1e3,
            t.secs("framework") / total_calls * 1e3,
        );
    }

    // Monolithic single-artifact forward for contrast (inference-engine analog
    // with zero per-block framework overhead).
    let rt = ArtifactRuntime::open_default().unwrap();
    let mut engine = Engine::new(rt, tag, FfnMode::DenseArtifact, 42).unwrap();
    let mut rng = Pcg64::seeded(7);
    let tokens = engine.random_tokens(&mut rng);
    engine.forward_monolithic(&tokens).unwrap();
    let sample = bench.run(|| engine.forward_monolithic(&tokens).unwrap());
    println!("monolithic-artifact\t{:.2}\t{:.2}\t-\t-\t-",
        sample.median * 1e3, dense.unwrap() / sample.median);
}
