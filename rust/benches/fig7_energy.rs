//! Fig. 7: energy (||pruned||_1 / ||dense||_1) vs sparsity structure.
//!
//! Compares unstructured magnitude pruning, n:m, n:m:g with g in {1,4,16},
//! and 4x4 block pruning on a BERT-shaped weight tensor. Paper claims:
//! unstructured >= n:m >= n:m:g (approaching n:m as g grows) > blocked.
//!
//! Run: `cargo bench --bench fig7_energy [-- --full]`

use sten::energy;
use sten::tensor::DenseTensor;
use sten::util::benchkit::{parse_mode, BenchMode};
use sten::util::rng::Pcg64;

fn main() {
    let mode = parse_mode();
    let (rows, cols) = match mode {
        BenchMode::Full => (760, 3072), // ~BERT_BASE FFN weight; rows % {4,8,10} == 0
        BenchMode::Quick => (120, 480),
    };
    let mut rng = Pcg64::seeded(1);
    let w = DenseTensor::randn(&[rows, cols], &mut rng);
    println!("# Fig 7: energy vs structure, weight {rows}x{cols} (mode {mode:?})");
    println!("sparsity\tformat\tenergy");

    // (n, m) pairs spanning the paper's 50-90% sparsity range.
    for (n, m) in [(2usize, 4usize), (1, 4), (2, 8), (1, 8), (1, 10)] {
        let s = 1.0 - n as f32 / m as f32;
        println!("{s:.2}\tunstructured\t{:.4}", energy::energy_unstructured(&w, s));
        println!("{s:.2}\t{n}:{m}\t{:.4}", energy::energy_nm(&w, n, m));
        for g in [1usize, 4, 16] {
            println!("{s:.2}\t{n}:{m}:{g}\t{:.4}", energy::energy_nmg(&w, n, m, g));
        }
        println!("{s:.2}\tblocked-4x4\t{:.4}", energy::energy_blocked(&w, s, 4, 4));
    }

    // Storage context (paper §2: sparse formats must also save bytes).
    println!("\n# storage at 2:4(:4), bytes");
    for (name, bytes) in energy::storage_report(&w, 2, 4, 4) {
        println!("{name}\t{bytes}");
    }

    // Shape assertions (the figure's qualitative claims).
    let unstructured = energy::energy_unstructured(&w, 0.5);
    let nm = energy::energy_nm(&w, 2, 4);
    let nmg16 = energy::energy_nmg(&w, 2, 4, 16);
    let nmg1 = energy::energy_nmg(&w, 2, 4, 1);
    let blocked = energy::energy_blocked(&w, 0.5, 4, 4);
    assert!(unstructured >= nm && nm >= nmg16 - 1e-6 && nmg16 >= nmg1 - 0.02 && nmg1 > blocked);
    println!("\nfig7 shape check OK: unstructured >= n:m >= n:m:g(16) >= n:m:g(1) > blocked");
}
