//! Fig. 9: masked-training overheads by format and sparsification mode.
//!
//! Measures per-step training time of the masked MLP trainer relative to
//! dense training, for unstructured / n:m / n:m:g mask formats, in two
//! regimes: *fixed* sparsification (mask reused every step — the common
//! case) and *new* sparsification (mask recomputed every step — e.g. when
//! sparsity increases). Paper claims: fixed is cheap for all formats; new
//! is more expensive for formats with complex constraints (n:m:g > n:m >
//! unstructured).
//!
//! Run: `cargo bench --bench fig9_training_overhead [-- --full]`

use sten::model::MlpSpec;
use sten::train::data::ClusterDataset;
use sten::train::masked::{compute_mask, MaskFormat, MaskedTrainer};
use sten::train::schedule::PruneEvent;
use sten::util::benchkit::{parse_mode, Bench, BenchMode};
use sten::util::rng::Pcg64;

fn main() {
    let mode = parse_mode();
    let (spec, batch, bench) = match mode {
        BenchMode::Full => (
            MlpSpec { input_dim: 256, hidden: vec![1024, 1024], classes: 10 },
            256,
            Bench::new(2, 10),
        ),
        BenchMode::Quick => (
            MlpSpec { input_dim: 64, hidden: vec![256, 256], classes: 10 },
            64,
            Bench::new(1, 6),
        ),
    };
    println!(
        "# Fig 9: masked training overheads, MLP {:?} batch {batch} (mode {mode:?})",
        spec.layer_dims()
    );

    let mut rng = Pcg64::seeded(5);
    let ds = ClusterDataset::new(spec.input_dim, spec.classes, 0.4, 9);
    let mut data_rng = Pcg64::seeded(17);
    let (x, y) = ds.batch(batch, &mut data_rng);

    // Dense baseline: trainer with all-ones masks, never re-sparsified.
    let params = spec.init(&mut rng);
    let mut dense_tr = MaskedTrainer::new(spec.clone(), params.clone(), 0.05, MaskFormat::Unstructured);
    let t_dense = bench.run(|| dense_tr.step(&x, &y).unwrap()).median;
    println!("\nformat\tmode\tstep_ms\toverhead_vs_dense");
    println!("dense\t-\t{:.2}\t1.00", t_dense * 1e3);

    let formats: Vec<(&str, MaskFormat)> = vec![
        ("unstructured", MaskFormat::Unstructured),
        ("2:4", MaskFormat::Nm { m: 4 }),
        ("2:4:4", MaskFormat::Nmg { m: 4, g: 4 }),
    ];
    for (name, fmt) in formats {
        // Fixed sparsification: prune once, then train with the fixed mask.
        let mut tr = MaskedTrainer::new(spec.clone(), params.clone(), 0.05, fmt);
        tr.apply_event(&PruneEvent { layers: Vec::new(), sparsity: 0.5 });
        let t_fixed = bench.run(|| tr.step(&x, &y).unwrap()).median;
        println!("{name}\tfixed\t{:.2}\t{:.2}", t_fixed * 1e3, t_fixed / t_dense);

        // New sparsification: recompute masks every step.
        let mut tr = MaskedTrainer::new(spec.clone(), params.clone(), 0.05, fmt);
        tr.apply_event(&PruneEvent { layers: Vec::new(), sparsity: 0.5 });
        let t_new = bench
            .run(|| {
                tr.apply_event(&PruneEvent { layers: Vec::new(), sparsity: 0.5 });
                tr.step(&x, &y).unwrap()
            })
            .median;
        println!("{name}\tnew\t{:.2}\t{:.2}", t_new * 1e3, t_new / t_dense);
    }

    // Mask recomputation cost alone (the Fig. 9 "new sparsification" bar).
    println!("\n# mask recomputation alone, largest layer");
    let (din, dout) = *spec.layer_dims().iter().max_by_key(|(a, b)| a * b).unwrap();
    let w = sten::tensor::DenseTensor::randn(&[din, dout], &mut rng);
    for (name, fmt) in [
        ("unstructured", MaskFormat::Unstructured),
        ("2:4", MaskFormat::Nm { m: 4 }),
        ("2:4:4", MaskFormat::Nmg { m: 4, g: 4 }),
    ] {
        let t = bench.run(|| compute_mask(&w, 0.5, fmt)).median;
        println!("{name}\t{:.3} ms", t * 1e3);
    }
}
