//! Single-request forward latency breakdown: attention vs FFN vs LM head,
//! swept over per-scope worker budgets 1..=cores, dense vs n:m:g weights —
//! plus the tensor-parallel strong-scaling sweep: one batch executed
//! cooperatively by `W` shard threads ([`Engine::shard`]) vs `W`
//! independent replicas each serving its own batch.
//!
//! Proves the persistent-pool claims — block latency scales with the
//! worker budget and steady state performs **zero thread spawns per
//! request** — and the tensor-parallel claims: the sharded forward is
//! bit-identical to the unsharded engine (asserted on every run) and the
//! per-request critical-path CPU time shrinks as shards are added (the
//! strong-scaling curve in the JSON; wall clock follows on multi-core).
//! `--smoke` additionally asserts the sharded steady state is spawn-free,
//! under ci.sh's wall-clock ceiling so a deadlocked barrier fails loudly.
//!
//! Run: `cargo bench --bench forward_latency [-- --full | -- --smoke]`
//! (quick/full serve the `base` artifacts; smoke serves `tiny`.)
//!
//! Emits `BENCH_forward_latency.json` (machine-readable points) so the perf
//! trajectory is tracked across PRs.

use std::sync::Arc;
use std::time::Instant;

use sten::coordinator::{Engine, FfnMode};
use sten::formats::NmgTensor;
use sten::kernels::{backend, simd};
use sten::runtime::{ArtifactRuntime, ArtifactSpec, DType, Value};
use sten::tensor::DenseTensor;
use sten::tune::{Autotuner, TunePolicy};
use sten::util::benchkit::{summarize, table_header, Bench, JsonReport};
use sten::util::rng::Pcg64;
use sten::util::threadpool;

/// Deterministic inputs for one artifact spec. The nmg FFN block needs a
/// coherent `val`/`idx` encoding, built from a random dense weight.
fn build_inputs(spec: &ArtifactSpec, rng: &mut Pcg64) -> Vec<Value> {
    let nmg: Option<NmgTensor> = spec.meta.get("nmg").map(|meta| {
        let f = meta.get("M").expect("nmg.M").usize().expect("nmg.M usize");
        let k = meta.get("K").expect("nmg.K").usize().expect("nmg.K usize");
        let dense = DenseTensor::randn(&[f, k], rng);
        NmgTensor::from_dense(&dense, 2, 4, 4)
    });
    spec.inputs
        .iter()
        .map(|io| match io.name.as_str() {
            "val" => {
                let sparse = nmg.as_ref().expect("val input without nmg meta");
                Value::from(DenseTensor::from_vec(&io.shape, sparse.val_flat().to_vec()))
            }
            "idx" => {
                let sparse = nmg.as_ref().expect("idx input without nmg meta");
                Value::I32(io.shape.clone(), sparse.idx_flat().iter().map(|&i| i as i32).collect())
            }
            name if name.ends_with("_g") => Value::from(DenseTensor::ones(&io.shape)),
            _ if io.dtype == DType::I32 => Value::I32(
                io.shape.clone(),
                (0..io.numel()).map(|_| rng.below(1 << 15) as i32).collect(),
            ),
            _ if io.shape.len() >= 2 => {
                let mut w = DenseTensor::randn(&io.shape, rng);
                w.scale(0.1);
                Value::from(w)
            }
            _ => Value::from(DenseTensor::zeros(&io.shape)),
        })
        .collect()
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let full = args.iter().any(|a| a == "--full");
    let tag = if smoke { "tiny" } else { "base" };
    let bench = if full { Bench::new(2, 8) } else { Bench::new(1, 3) };
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let rt = Arc::new(ArtifactRuntime::open_default().expect("artifact runtime"));

    // Worker budgets swept: 1, powers of two, and the full machine.
    let mut threads: Vec<usize> = vec![1];
    let mut t = 2;
    while t < cores {
        threads.push(t);
        t *= 2;
    }
    if cores > 1 {
        threads.push(cores);
    }
    threads.dedup();

    let mut rng = Pcg64::seeded(4242);
    let blocks: Vec<(&str, String)> = vec![
        ("embed", format!("embed_{tag}")),
        ("attention", format!("attn_block_{tag}")),
        ("ffn-dense", format!("ffn_block_{tag}")),
        ("ffn-nmg", format!("ffn_block_nmg_{tag}")),
        ("lm-head", format!("lm_head_{tag}")),
    ];
    let prepared: Vec<(&str, String, Vec<Value>)> = blocks
        .into_iter()
        .map(|(label, artifact)| {
            let spec = rt.spec(&artifact).expect("artifact spec").clone();
            let inputs = build_inputs(&spec, &mut rng);
            (label, artifact, inputs)
        })
        .collect();

    println!(
        "# forward latency breakdown: artifacts `{tag}`, {cores} cores \
         (smoke={smoke}, full={full})"
    );
    let mut json = JsonReport::new("forward_latency");
    // Stamp every row with the backend the kernels dispatched to plus the
    // detected CPU features, so latency deltas across hosts are attributable.
    let be = backend::active().to_string();
    let cpu = simd::cpu_features();
    println!("# backend: {be} (cpu features: {cpu})");
    let mut attn_by_threads: Vec<(usize, f64)> = Vec::new();

    table_header("block latency", &["block", "threads", "median_ms", "p95_ms", "speedup_vs_1"]);
    for (label, artifact, inputs) in &prepared {
        let mut base_median = 0.0f64;
        for &nthreads in &threads {
            threadpool::set_worker_cap(Some(nthreads));
            let sample = bench.run(|| rt.call(artifact, inputs).expect("artifact call"));
            if nthreads == 1 {
                base_median = sample.median;
            }
            if *label == "attention" {
                attn_by_threads.push((nthreads, sample.median));
            }
            println!(
                "{label}\t{nthreads}\t{:.3}\t{:.3}\t{:.2}",
                sample.median * 1e3,
                sample.p95 * 1e3,
                base_median / sample.median.max(1e-12),
            );
            json.row(&[
                ("tag", tag.into()),
                ("block", (*label).into()),
                ("threads", nthreads.into()),
                ("median_s", sample.median.into()),
                ("p95_s", sample.p95.into()),
                ("backend", be.as_str().into()),
                ("cpu_features", cpu.as_str().into()),
            ]);
        }
    }
    threadpool::set_worker_cap(None);

    // End-to-end single request (all blocks composed), dense vs n:m:g FFN.
    // The chosen-format column is what the cost-model autotuner would store
    // the layer-0 FFN weight as for this mode's sparsity.
    table_header(
        "end-to-end forward",
        &["ffn", "threads", "median_ms", "p95_ms", "chosen_format"],
    );
    for (mode_label, mode) in
        [("dense", FfnMode::NativeDense), ("nmg", FfnMode::NativeNmg { n: 2, m: 4, g: 4 })]
    {
        let mut engine = Engine::with_runtime(rt.clone(), tag, mode, 42).expect("engine");
        let nmg_cfg = match mode {
            FfnMode::NativeNmg { n, m, g } => Some((n, m, g)),
            _ => None,
        };
        let mut tuner = Autotuner::new(TunePolicy::CostModel);
        let w1t = engine.param("layer0.w1").transpose2();
        let ncols = engine.dims.batch * engine.dims.seq;
        let chosen = tuner
            .choose(sten::dispatch::global(), &w1t, ncols, nmg_cfg)
            .map(|d| d.layout.to_string())
            .unwrap_or_else(|e| format!("error: {e}"));
        let tokens = engine.random_tokens(&mut rng);
        for &nthreads in &threads {
            threadpool::set_worker_cap(Some(nthreads));
            let sample = bench.run(|| engine.forward(&tokens).expect("forward"));
            println!(
                "{mode_label}\t{nthreads}\t{:.3}\t{:.3}\t{chosen}",
                sample.median * 1e3,
                sample.p95 * 1e3
            );
            json.row(&[
                ("tag", tag.into()),
                ("block", "e2e".into()),
                ("ffn", mode_label.into()),
                ("threads", nthreads.into()),
                ("median_s", sample.median.into()),
                ("p95_s", sample.p95.into()),
                ("chosen_format", chosen.as_str().into()),
                ("backend", be.as_str().into()),
                ("cpu_features", cpu.as_str().into()),
            ]);
        }
    }
    threadpool::set_worker_cap(None);

    // ── Tensor parallelism: sharded vs replicated strong scaling ──
    //
    // At width W the *sharded* row executes ONE batch cooperatively on W
    // dedicated shard threads (a latency play: per-request critical-path
    // CPU ~ 1/W); the *replicated* row executes W batches concurrently on
    // W independent weight-sharing replicas (a throughput play: latency
    // flat, batches/s ~ W). Kernel users are registered per width so the
    // shared pool budget matches what serving would grant.
    table_header(
        "tensor-parallel forward (sharded vs replicated)",
        &["mode", "width", "median_ms", "p95_ms", "batches_per_s", "cpu_crit_ms"],
    );
    let mut eng = Engine::with_runtime(rt.clone(), tag, FfnMode::NativeDense, 42).expect("engine");
    let tokens = eng.random_tokens(&mut rng);
    let want = eng.forward(&tokens).expect("unsharded forward");
    let widths: Vec<usize> = if smoke { vec![1, 2] } else { vec![1, 2, 4] };
    let mut tp_curve: Vec<(usize, f64, f64)> = Vec::new();
    for &w in &widths {
        let _users = threadpool::register_kernel_users(w);

        // Sharded: warm up, then time with per-rank CPU accounting.
        let mut sharded = eng.shard(w).expect("shard");
        for _ in 0..bench.warmup {
            sharded.forward(&tokens);
        }
        let got = sharded.forward(&tokens);
        assert_eq!(got.data(), want.data(), "w={w}: sharded forward must be bit-identical");
        sharded.reset_timing();
        let mut times = Vec::with_capacity(bench.iters);
        for _ in 0..bench.iters {
            let t = Instant::now();
            std::hint::black_box(sharded.forward(&tokens));
            times.push(t.elapsed().as_secs_f64());
        }
        let sample = summarize(&times);
        let timing = sharded.shard_timing();
        let per_req = |key: &str| {
            timing.iter().map(|t| t.secs(key)).fold(0.0, f64::max) / sample.iters as f64
        };
        let (cpu_crit, coll_crit) = (per_req("cpu"), per_req("collective"));
        tp_curve.push((w, sample.median, cpu_crit));
        println!(
            "sharded\t{w}\t{:.3}\t{:.3}\t{:.2}\t{:.3}",
            sample.median * 1e3,
            sample.p95 * 1e3,
            1.0 / sample.median.max(1e-12),
            cpu_crit * 1e3,
        );
        json.row(&[
            ("tag", tag.into()),
            ("block", "tp".into()),
            ("mode", "sharded".into()),
            ("width", w.into()),
            ("median_s", sample.median.into()),
            ("p95_s", sample.p95.into()),
            ("batches_per_s", (1.0 / sample.median.max(1e-12)).into()),
            ("cpu_crit_s", cpu_crit.into()),
            ("collective_crit_s", coll_crit.into()),
            ("backend", be.as_str().into()),
            ("cpu_features", cpu.as_str().into()),
        ]);

        // Replicated baseline: W replicas, each forwarding its own batch.
        let mut reps: Vec<Engine> = (0..w).map(|_| eng.replicate()).collect();
        let toks = &tokens;
        let sample = bench.run(|| {
            std::thread::scope(|s| {
                for rep in reps.iter_mut() {
                    s.spawn(move || {
                        rep.forward(toks).expect("replicated forward");
                    });
                }
            })
        });
        println!(
            "replicated\t{w}\t{:.3}\t{:.3}\t{:.2}\t-",
            sample.median * 1e3,
            sample.p95 * 1e3,
            w as f64 / sample.median.max(1e-12),
        );
        json.row(&[
            ("tag", tag.into()),
            ("block", "tp".into()),
            ("mode", "replicated".into()),
            ("width", w.into()),
            ("median_s", sample.median.into()),
            ("p95_s", sample.p95.into()),
            ("batches_per_s", (w as f64 / sample.median.max(1e-12)).into()),
            ("backend", be.as_str().into()),
            ("cpu_features", cpu.as_str().into()),
        ]);
    }
    if let Some(&(_, wall1, cpu1)) = tp_curve.iter().find(|(w, _, _)| *w == 1) {
        for &(w, wall, cpu) in &tp_curve {
            if w != 1 {
                println!(
                    "tp-scaling-{w}v1: wall {:.2}x, cpu-critical-path {:.2}x",
                    wall1 / wall.max(1e-12),
                    cpu1 / cpu.max(1e-12),
                );
            }
        }
    }

    // Sharded steady state must also be spawn-free: the shard pool and
    // collective group are built once at `shard()` time, so repeated
    // forwards may not create a single thread.
    let mut sharded = eng.shard(2).expect("shard");
    sharded.forward(&tokens);
    let spawns_before = threadpool::total_spawns();
    let requests = if smoke { 5 } else { 3 };
    for _ in 0..requests {
        sharded.forward(&tokens);
    }
    let spawned = threadpool::total_spawns() - spawns_before;
    println!("sharded steady-state thread spawns across {requests} requests: {spawned} (expect 0)");
    json.row(&[
        ("block", "tp_steady_state".into()),
        ("spawns", spawned.into()),
        ("backend", be.as_str().into()),
    ]);
    if smoke {
        assert_eq!(spawned, 0, "sharded steady state must not spawn threads");
        println!("smoke OK: sharded forward is bit-identical and spawn-free in steady state");
    }
    drop(sharded);

    // Attention scaling summary (the ROADMAP's last serial compute path).
    if let Some(&(_, base)) = attn_by_threads.iter().find(|(t, _)| *t == 1) {
        for &(nthreads, median) in &attn_by_threads {
            if nthreads != 1 {
                println!(
                    "attention-scaling-{nthreads}v1: {:.2}",
                    base / median.max(1e-12)
                );
            }
        }
    }

    // Steady state must be spawn-free: the persistent pool was warmed up by
    // the sweep above, so further requests may not create a single thread.
    let requests = if smoke { 5 } else { 3 };
    let spawns_before = threadpool::total_spawns();
    for _ in 0..requests {
        for (_, artifact, inputs) in &prepared {
            rt.call(artifact, inputs).expect("artifact call");
        }
    }
    let spawned = threadpool::total_spawns() - spawns_before;
    println!("\nsteady-state thread spawns across {requests} requests: {spawned} (expect 0)");
    json.row(&[
        ("block", "steady_state".into()),
        ("spawns", spawned.into()),
        ("backend", be.as_str().into()),
    ]);
    if smoke {
        assert_eq!(spawned, 0, "steady-state requests must not spawn threads");
        println!("smoke OK: persistent pool is spawn-free in steady state");
    }

    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write bench json: {e}"),
    }
}
