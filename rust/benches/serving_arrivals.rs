//! Open-loop arrivals: offered load vs SLO-miss and goodput, for 1-model,
//! 2-model and bursty (Markov-modulated) registry mixes, with and without
//! overload defense (admission control + sparse-degrade + load shedding).
//! The 2-model mixes offer a heavy-tailed prompt-length mix
//! (Pareto-sampled lengths clamped to `[1, seq]` — most prompts short, a
//! fat tail full-length; `len_mean`/`len_p99` land in the JSON report).
//!
//! An open-loop generator submits on a precomputed arrival schedule —
//! inter-arrival gaps, per-request model picks and prompt lengths drawn
//! from a seeded [`Pcg64`], so the *workload* is fully deterministic (no wall clock
//! anywhere in its construction; real time is only used to pace the
//! schedule and to measure latency). Arrivals never wait for completions
//! — submission is **non-blocking** (`try_submit_to`), and a failed
//! submission (queue full, admission-rejected) is *counted as an SLO
//! miss* rather than stalling the generator. Blocking here would silently
//! turn the bench closed-loop at saturation (coordinated omission): the
//! generator's own backpressure stall would pace arrivals down to
//! capacity and hide the overload it exists to measure.
//!
//! Per mix, the bench calibrates achievable throughput with a closed-loop
//! blast, then sweeps offered load as fractions of that capacity with
//! overload defense ON (plus one undefended contrast point at the top
//! fraction) and reports achieved rps, goodput (in-SLO completions/s),
//! p50/p95/p99, SLO-miss (overall and per model) and per-model
//! shed/reject/degrade counts. Past saturation, defended goodput must
//! plateau near capacity instead of collapsing.
//!
//! Run: `cargo bench --bench serving_arrivals [-- --full | -- --smoke]`
//! (quick/smoke serve the `tiny` artifacts; full serves `base`.)
//! `--smoke` (the ci.sh gate) runs per mix one trivial-load point
//! (asserting zero steady-state thread spawns and a sane SLO-miss) and
//! one defended overload point at ~6x capacity (asserting zero spawns, a
//! goodput floor, and that shed/reject/degrade outcomes actually fired).
//!
//! Emits `BENCH_serving_arrivals.json` via `benchkit::JsonReport`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use sten::coordinator::metrics::{goodput, per_model, percentile, slo_miss_fraction};
use sten::coordinator::{
    ConcurrentServer, Engine, FfnMode, ModelRegistry, RequestResult, SchedPolicy, ServeConfig,
    SubmitError,
};
use sten::runtime::ArtifactRuntime;
use sten::util::benchkit::JsonReport;
use sten::util::rng::Pcg64;
use sten::util::threadpool;

const NMG: FfnMode = FfnMode::NativeNmg { n: 2, m: 4, g: 4 };

/// Arrival process shape (same mean rate either way).
#[derive(Clone, Copy)]
enum Arrivals {
    /// Memoryless: exponential inter-arrival gaps.
    Poisson,
    /// Bursty: two-state Markov-modulated Poisson process.
    Mmpp,
}

/// Request token-length distribution. The server pads/truncates every
/// prompt to the model's fixed `seq` (`canonical_tokens`), so the mix
/// shapes the *offered* prompt lengths that the padding path absorbs —
/// the realistic serving workload is heavy-tailed, not full-length.
#[derive(Clone, Copy)]
enum LengthMix {
    /// Every request arrives with a full `seq`-length prompt.
    Full,
    /// Heavy-tailed: Pareto (scale 1 token, shape `alpha`), clamped to
    /// `[1, seq]`. Most prompts are a few tokens; a fat tail is
    /// full-length (`P(len >= seq) = seq^-alpha` before clamping).
    Pareto { alpha: f64 },
}

impl LengthMix {
    fn sample(self, rng: &mut Pcg64, seq: usize) -> usize {
        match self {
            LengthMix::Full => seq,
            LengthMix::Pareto { alpha } => (rng.pareto(alpha) as usize).clamp(1, seq),
        }
    }

    fn label(self) -> String {
        match self {
            LengthMix::Full => "full".to_string(),
            LengthMix::Pareto { alpha } => format!("pareto-{alpha}"),
        }
    }
}

/// A registry mix: (name, ffn mode, replicas, weight) per model, plus an
/// optional admission-control degrade link (from, to).
struct Mix {
    label: &'static str,
    models: Vec<(&'static str, FfnMode, usize, u64)>,
    policy: SchedPolicy,
    arrivals: Arrivals,
    lengths: LengthMix,
    degrade: Option<(&'static str, &'static str)>,
}

fn start_server(
    rt: &Arc<ArtifactRuntime>,
    tag: &str,
    mix: &Mix,
    cfg: ServeConfig,
) -> ConcurrentServer {
    let mut registry = ModelRegistry::new();
    for (i, (name, mode, replicas, weight)) in mix.models.iter().enumerate() {
        let engine = Engine::with_runtime(rt.clone(), tag, *mode, 42 + i as u64).expect("engine");
        registry.register(name, engine, *replicas, *weight).expect("register model");
    }
    if let Some((from, to)) = mix.degrade {
        registry.set_degrade(from, to).expect("degrade link");
    }
    ConcurrentServer::start_registry(registry, cfg).expect("start server")
}

/// Seeded exponential inter-arrival gaps (seconds) for `rate_rps`.
fn poisson_gaps(rng: &mut Pcg64, rate_rps: f64, n: usize) -> Vec<f64> {
    (0..n)
        .map(|_| {
            let u = (1.0 - rng.next_f32() as f64).max(1e-9); // in (0, 1]
            -u.ln() / rate_rps
        })
        .collect()
}

/// Seeded two-state Markov-modulated Poisson gaps with overall mean rate
/// `rate_rps`: a "hi" burst state (mean gap 0.25/rate) and a "lo" quiet
/// state (mean gap 1.75/rate), switching with probability 1/8 per
/// arrival. Symmetric switching gives the states equal occupancy, so the
/// long-run mean gap is 1/rate — same offered load as Poisson, arriving
/// in bursts that stress the queue and the shed path far harder.
fn mmpp_gaps(rng: &mut Pcg64, rate_rps: f64, n: usize) -> Vec<f64> {
    let mean_gap = 1.0 / rate_rps;
    let mut hi = true;
    (0..n)
        .map(|_| {
            if rng.next_f32() < 0.125 {
                hi = !hi;
            }
            let mean = if hi { 0.25 * mean_gap } else { 1.75 * mean_gap };
            let u = (1.0 - rng.next_f32() as f64).max(1e-9);
            -u.ln() * mean
        })
        .collect()
}

/// Closed-loop blast to estimate the mix's achievable req/s.
fn calibrate(rt: &Arc<ArtifactRuntime>, tag: &str, mix: &Mix, requests: usize) -> f64 {
    let cfg = ServeConfig {
        queue_cap: 64,
        max_wait: Duration::from_millis(1),
        policy: mix.policy,
        ..ServeConfig::default()
    };
    let server = start_server(rt, tag, mix, cfg);
    let seq = server.dims().seq;
    let vocab = server.dims().vocab as u32;
    let mut rng = Pcg64::seeded(5);
    // Warm artifact preparation before timing.
    for (name, ..) in &mix.models {
        let toks: Vec<i32> = (0..seq).map(|_| rng.below(vocab) as i32).collect();
        server.submit_to(name, &toks).unwrap();
    }
    server.drain();
    let t = Instant::now();
    for i in 0..requests {
        let (name, ..) = mix.models[i % mix.models.len()];
        let toks: Vec<i32> = (0..seq).map(|_| rng.below(vocab) as i32).collect();
        server.submit_to(name, &toks).unwrap();
    }
    server.drain();
    let rps = requests as f64 / t.elapsed().as_secs_f64().max(1e-9);
    server.finish().expect("calibration finish");
    rps
}

struct Point {
    offered_rps: f64,
    achieved_rps: f64,
    goodput_rps: f64,
    p50_s: f64,
    p95_s: f64,
    p99_s: f64,
    slo_miss: f64,
    failed_submits: usize,
    shed: u64,
    rejected: u64,
    degraded: u64,
    /// (name, slo_miss, shed, rejected, degraded) per model.
    per_model: Vec<(String, f64, u64, u64, u64)>,
    spawned: usize,
    len_mean: f64,
    len_p99: f64,
}

/// One open-loop load point: pace `n` arrivals at `offered_rps`, measure
/// latency/SLO/goodput over the paced window only (warmup excluded).
/// `defended` turns on admission control (with the mix's degrade link)
/// and expired-entry shedding.
#[allow(clippy::too_many_arguments)]
fn run_point(
    rt: &Arc<ArtifactRuntime>,
    tag: &str,
    mix: &Mix,
    offered_rps: f64,
    n: usize,
    slo: Duration,
    seed: u64,
    defended: bool,
) -> Point {
    let cfg = ServeConfig {
        // Bounded, but deep enough that the undefended points in these
        // sweep sizes never hit QueueFull: their failure accounting stays
        // zero and overload shows up purely as latency/SLO collapse.
        queue_cap: 1024,
        max_wait: Duration::from_millis(2),
        policy: mix.policy,
        slo,
        admission: defended,
        shed: defended,
        ..ServeConfig::default()
    };
    let server = start_server(rt, tag, mix, cfg);
    let seq = server.dims().seq;
    let vocab = server.dims().vocab as u32;
    let names: Vec<&str> = mix.models.iter().map(|m| m.0).collect();

    // The deterministic workload: gaps, model picks and token streams.
    let mut rng = Pcg64::seeded(seed);
    let gaps = match mix.arrivals {
        Arrivals::Poisson => poisson_gaps(&mut rng, offered_rps, n),
        Arrivals::Mmpp => mmpp_gaps(&mut rng, offered_rps, n),
    };
    let picks: Vec<usize> = (0..n).map(|_| rng.below(names.len() as u32) as usize).collect();
    let lens: Vec<usize> = (0..n).map(|_| mix.lengths.sample(&mut rng, seq)).collect();
    let tokens: Vec<Vec<i32>> =
        lens.iter().map(|&l| (0..l).map(|_| rng.below(vocab) as i32).collect()).collect();
    let len_mean = lens.iter().sum::<usize>() as f64 / n as f64;
    let len_p99 = {
        let mut sorted: Vec<f64> = lens.iter().map(|&l| l as f64).collect();
        sorted.sort_by(|a, b| a.total_cmp(b));
        percentile(&sorted, 99.0)
    };

    // Warmup wave (every model once, plus pool/artifact spin-up; primes
    // the admission EWMA), drained and excluded from the measured window.
    let mut warm_ids = Vec::new();
    for (m, name) in names.iter().enumerate() {
        warm_ids.push(server.submit_to(name, &tokens[m % n]).unwrap());
    }
    server.drain();
    // A warmup entry shed instead of served (possible only when slo is
    // tighter than a cold first batch) must not be charged to the window.
    let warm_shed = (warm_ids.len() - server.completed().len()) as u64;
    let spawns_before = threadpool::total_spawns();

    let start = Instant::now();
    let mut due = 0.0f64;
    let mut failed_submits = 0usize;
    for i in 0..n {
        due += gaps[i];
        let target = start + Duration::from_secs_f64(due);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        // Non-blocking: a submission the server cannot take *now* is a
        // failure the JSON accounts as an SLO miss, not a generator stall.
        match server.try_submit_to(names[picks[i]], &tokens[i]) {
            Ok(_) => {}
            Err(SubmitError::QueueFull) | Err(SubmitError::Rejected { .. }) => failed_submits += 1,
            Err(e) => panic!("submit failed: {e}"),
        }
    }
    server.drain();
    // Achieved throughput includes the post-submission drain: under
    // overload the backlog is served after the last arrival, and counting
    // only the submission window would just echo the offered rate.
    let served_wall = start.elapsed().as_secs_f64().max(1e-9);
    let spawned = threadpool::total_spawns() - spawns_before;
    let report = server.finish().expect("serve finish");

    // Measured window = everything after the warmup ids.
    let measured: Vec<RequestResult> =
        report.results.iter().filter(|r| !warm_ids.contains(&r.id)).cloned().collect();
    let window_shed = report.shed - warm_shed;
    // Every paced arrival is accounted exactly once: completed, failed at
    // submit (queue full / rejected), or shed from the queue.
    assert_eq!(
        measured.len() + failed_submits + window_shed as usize,
        n,
        "lost completions in the measured window"
    );
    let mut lat: Vec<f64> = measured.iter().map(|r| r.total_s).collect();
    lat.sort_by(|a, b| a.total_cmp(b));
    let slo_s = slo.as_secs_f64();
    let pct = |q: f64| if lat.is_empty() { 0.0 } else { percentile(&lat, q) };
    // SLO accounting: a failed submission and a shed entry are misses —
    // the client got nothing inside the deadline.
    let measured_misses =
        slo_miss_fraction(&measured, slo_s).unwrap_or(0.0) * measured.len() as f64;
    let slo_miss = (measured_misses + failed_submits as f64 + window_shed as f64) / n as f64;
    let per_model_rows = per_model(&measured, names.len(), slo_s)
        .into_iter()
        .zip(&report.per_model)
        .zip(&names)
        .map(|((mm, rep), name)| {
            ((*name).to_string(), mm.slo_miss.unwrap_or(0.0), rep.shed, rep.rejected, rep.degraded)
        })
        .collect();
    Point {
        offered_rps,
        achieved_rps: measured.len() as f64 / served_wall,
        goodput_rps: goodput(&measured, slo_s, served_wall),
        p50_s: pct(50.0),
        p95_s: pct(95.0),
        p99_s: pct(99.0),
        slo_miss,
        failed_submits,
        shed: window_shed,
        rejected: report.rejected,
        degraded: report.degraded,
        per_model: per_model_rows,
        spawned,
        len_mean,
        len_p99,
    }
}

fn emit_point(
    json: &mut JsonReport,
    mix: &Mix,
    frac: f64,
    defended: bool,
    slo: Duration,
    p: &Point,
) {
    println!(
        "{frac:.2}x{}\t{:.0}\t{:.0}\t{:.0}\t{:.3}\t{:.3}\t{:.3}\t{:.3}\t{}/{}/{}/{}\t{}",
        if defended { "" } else { " (undefended)" },
        p.offered_rps,
        p.achieved_rps,
        p.goodput_rps,
        p.p50_s * 1e3,
        p.p95_s * 1e3,
        p.p99_s * 1e3,
        p.slo_miss,
        p.shed,
        p.rejected,
        p.degraded,
        p.failed_submits,
        p.spawned
    );
    for (name, miss, shed, rejected, degraded) in &p.per_model {
        println!(
            "  model {name}: slo_miss {miss:.3}, \
             shed/rejected/degraded {shed}/{rejected}/{degraded}"
        );
    }
    json.row(&[
        ("mix", mix.label.into()),
        ("load_fraction", frac.into()),
        ("defended", usize::from(defended).into()),
        ("offered_rps", p.offered_rps.into()),
        ("achieved_rps", p.achieved_rps.into()),
        ("goodput_rps", p.goodput_rps.into()),
        ("p50_s", p.p50_s.into()),
        ("p95_s", p.p95_s.into()),
        ("p99_s", p.p99_s.into()),
        ("slo_miss", p.slo_miss.into()),
        ("slo_s", slo.as_secs_f64().into()),
        ("failed_submits", p.failed_submits.into()),
        ("shed", (p.shed as usize).into()),
        ("rejected", (p.rejected as usize).into()),
        ("degraded", (p.degraded as usize).into()),
        ("spawns", p.spawned.into()),
        ("length_mix", mix.lengths.label().as_str().into()),
        ("len_mean", p.len_mean.into()),
        ("len_p99", p.len_p99.into()),
    ]);
    for (name, miss, shed, rejected, degraded) in &p.per_model {
        json.row(&[
            ("mix", mix.label.into()),
            ("load_fraction", frac.into()),
            ("defended", usize::from(defended).into()),
            ("model", name.as_str().into()),
            ("slo_miss", (*miss).into()),
            ("shed", (*shed as usize).into()),
            ("rejected", (*rejected as usize).into()),
            ("degraded", (*degraded as usize).into()),
        ]);
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let full = args.iter().any(|a| a == "--full");
    let tag = if full { "base" } else { "tiny" };
    let rt = Arc::new(ArtifactRuntime::open_default().expect("artifact runtime"));
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);

    let mixes = vec![
        Mix {
            label: "1-model-nmg",
            models: vec![("nmg", NMG, 2, 1)],
            policy: SchedPolicy::Fifo,
            arrivals: Arrivals::Poisson,
            lengths: LengthMix::Full,
            degrade: None,
        },
        Mix {
            label: "2-model-dense+nmg",
            models: vec![("dense", FfnMode::NativeDense, 1, 1), ("nmg", NMG, 1, 3)],
            policy: SchedPolicy::Wdrr,
            arrivals: Arrivals::Poisson,
            lengths: LengthMix::Pareto { alpha: 1.2 },
            degrade: Some(("dense", "nmg")),
        },
        Mix {
            label: "2-model-bursty-mmpp",
            models: vec![("dense", FfnMode::NativeDense, 1, 1), ("nmg", NMG, 1, 3)],
            policy: SchedPolicy::Wdrr,
            arrivals: Arrivals::Mmpp,
            lengths: LengthMix::Pareto { alpha: 1.2 },
            degrade: Some(("dense", "nmg")),
        },
    ];
    let load_fractions: Vec<f64> = if smoke {
        vec![0.2]
    } else if full {
        vec![0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 2.0, 3.0]
    } else {
        vec![0.25, 0.5, 1.0, 1.5, 2.5]
    };
    let n_requests = if smoke {
        64
    } else if full {
        512
    } else {
        256
    };
    let calib_requests = if smoke { 64 } else { 128 };
    let overload_frac = 6.0;

    println!(
        "# Open-loop arrivals: artifacts `{tag}`, {n_requests} requests/point, \
         {cores} cores (smoke={smoke}, full={full})"
    );
    let mut json = JsonReport::new("serving_arrivals");
    for mix in &mixes {
        let capacity = calibrate(&rt, tag, mix, calib_requests);
        // SLO: an order of magnitude above the per-request service time at
        // capacity, floored for scheduler granularity — tight enough that
        // overload shows, loose enough that trivial load sails under it.
        let slo = Duration::from_secs_f64((10.0 / capacity).max(0.005));
        println!(
            "\n## mix {} ({:?}, lengths {}); calibrated capacity {:.0} req/s, slo {:.1} ms",
            mix.label,
            mix.policy,
            mix.lengths.label(),
            capacity,
            slo.as_secs_f64() * 1e3
        );
        println!(
            "load\toffered_rps\tachieved_rps\tgoodput_rps\tp50_ms\tp95_ms\tp99_ms\tslo_miss\
             \tshed/rej/degr/failed\tspawns"
        );
        for (pi, &frac) in load_fractions.iter().enumerate() {
            let offered = (capacity * frac).max(1.0);
            // The sweep runs defended: past saturation, goodput must
            // plateau as admission/degrade/shed absorb the excess.
            let defended = !smoke;
            let p =
                run_point(&rt, tag, mix, offered, n_requests, slo, 900 + pi as u64, defended);
            emit_point(&mut json, mix, frac, defended, slo, &p);
            if smoke {
                assert_eq!(
                    p.spawned, 0,
                    "steady-state serving must not spawn threads (mix {})",
                    mix.label
                );
                assert!(
                    p.slo_miss <= 0.5,
                    "slo-miss {:.3} at trivial load ({:.0} of {:.0} req/s capacity, mix {})",
                    p.slo_miss,
                    p.offered_rps,
                    capacity,
                    mix.label
                );
            }
        }
        // One overload point at ~6x capacity: defended, so goodput holds a
        // floor instead of collapsing. In the sweep modes, pair it with an
        // undefended contrast point at the same load.
        let offered = (capacity * overload_frac).max(1.0);
        let p = run_point(&rt, tag, mix, offered, n_requests, slo, 990, true);
        emit_point(&mut json, mix, overload_frac, true, slo, &p);
        if smoke {
            assert_eq!(
                p.spawned, 0,
                "overload must not spawn threads (mix {})",
                mix.label
            );
            assert!(
                p.goodput_rps >= 0.05 * capacity,
                "defended goodput {:.0} collapsed below 5% of capacity {:.0} (mix {})",
                p.goodput_rps,
                capacity,
                mix.label
            );
            assert!(
                p.shed + p.rejected + p.degraded > 0,
                "overload at {overload_frac}x fired no shed/reject/degrade (mix {})",
                mix.label
            );
        } else {
            let u = run_point(&rt, tag, mix, offered, n_requests, slo, 990, false);
            emit_point(&mut json, mix, overload_frac, false, slo, &u);
        }
    }

    match json.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("failed to write bench json: {e}"),
    }
    if smoke {
        println!(
            "smoke OK: spawn-free open-loop serving, sane SLO-miss at trivial load, \
             goodput floor held at {overload_frac}x overload"
        );
    }
    println!(
        "\n(expect defended goodput to plateau near capacity past 1.0x offered load while \
         undefended p99 collapses; the 2-model mixes degrade dense -> nmg under pressure \
         and the mmpp mix arrives in bursts)"
    );
}
