//! Open-loop Poisson arrivals: offered load vs SLO-miss fraction, for a
//! 1-model and a 2-model registry mix.
//!
//! An open-loop generator submits on a precomputed arrival schedule —
//! exponential inter-arrival gaps and per-request model picks drawn from a
//! seeded [`Pcg64`], so the *workload* is fully deterministic (no wall
//! clock anywhere in its construction; real time is only used to pace the
//! schedule and to measure latency). Arrivals do not wait for completions,
//! which is what makes overload visible: past the server's capacity the
//! queue grows and the SLO-miss fraction climbs toward 1 — the Fig. 11
//! serving story measured the way serving systems are actually loaded.
//!
//! Per mix, the bench calibrates achievable throughput with a closed-loop
//! blast, then sweeps offered load as fractions of that capacity and
//! reports achieved rps, p50/p95/p99 and SLO-miss (overall and per model).
//!
//! Run: `cargo bench --bench serving_arrivals [-- --full | -- --smoke]`
//! (quick/smoke serve the `tiny` artifacts; full serves `base`.)
//! `--smoke` runs one trivial-load point per mix and asserts zero
//! steady-state thread spawns and a sane SLO-miss fraction (ci.sh gate).
//!
//! Emits `BENCH_serving_arrivals.json` via `benchkit::JsonReport`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use sten::coordinator::metrics::{per_model, percentile, slo_miss_fraction};
use sten::coordinator::{
    ConcurrentServer, Engine, FfnMode, ModelRegistry, RequestResult, SchedPolicy, ServeConfig,
};
use sten::runtime::ArtifactRuntime;
use sten::util::benchkit::JsonReport;
use sten::util::rng::Pcg64;
use sten::util::threadpool;

const NMG: FfnMode = FfnMode::NativeNmg { n: 2, m: 4, g: 4 };

/// A registry mix: (name, ffn mode, replicas, weight) per model.
struct Mix {
    label: &'static str,
    models: Vec<(&'static str, FfnMode, usize, u64)>,
    policy: SchedPolicy,
}

fn start_server(
    rt: &Arc<ArtifactRuntime>,
    tag: &str,
    mix: &Mix,
    cfg: ServeConfig,
) -> ConcurrentServer {
    let mut registry = ModelRegistry::new();
    for (i, (name, mode, replicas, weight)) in mix.models.iter().enumerate() {
        let engine = Engine::with_runtime(rt.clone(), tag, *mode, 42 + i as u64).expect("engine");
        registry.register(name, engine, *replicas, *weight).expect("register model");
    }
    ConcurrentServer::start_registry(registry, cfg).expect("start server")
}

/// Seeded exponential inter-arrival gaps (seconds) for `rate_rps`.
fn poisson_gaps(rng: &mut Pcg64, rate_rps: f64, n: usize) -> Vec<f64> {
    (0..n)
        .map(|_| {
            let u = (1.0 - rng.next_f32() as f64).max(1e-9); // in (0, 1]
            -u.ln() / rate_rps
        })
        .collect()
}

/// Closed-loop blast to estimate the mix's achievable req/s.
fn calibrate(rt: &Arc<ArtifactRuntime>, tag: &str, mix: &Mix, requests: usize) -> f64 {
    let cfg = ServeConfig {
        queue_cap: 64,
        max_wait: Duration::from_millis(1),
        policy: mix.policy,
        ..ServeConfig::default()
    };
    let server = start_server(rt, tag, mix, cfg);
    let seq = server.dims().seq;
    let vocab = server.dims().vocab as u32;
    let mut rng = Pcg64::seeded(5);
    // Warm artifact preparation before timing.
    for (name, ..) in &mix.models {
        let toks: Vec<i32> = (0..seq).map(|_| rng.below(vocab) as i32).collect();
        server.submit_to(name, &toks).unwrap();
    }
    server.drain();
    let t = Instant::now();
    for i in 0..requests {
        let (name, ..) = mix.models[i % mix.models.len()];
        let toks: Vec<i32> = (0..seq).map(|_| rng.below(vocab) as i32).collect();
        server.submit_to(name, &toks).unwrap();
    }
    server.drain();
    let rps = requests as f64 / t.elapsed().as_secs_f64().max(1e-9);
    server.finish().expect("calibration finish");
    rps
}

struct Point {
    offered_rps: f64,
    achieved_rps: f64,
    p50_s: f64,
    p95_s: f64,
    p99_s: f64,
    slo_miss: f64,
    per_model_miss: Vec<(String, f64)>,
    spawned: usize,
}

/// One open-loop load point: pace `n` arrivals at `offered_rps`, measure
/// latency/SLO over the paced window only (warmup excluded).
fn run_point(
    rt: &Arc<ArtifactRuntime>,
    tag: &str,
    mix: &Mix,
    offered_rps: f64,
    n: usize,
    slo: Duration,
    seed: u64,
) -> Point {
    let cfg = ServeConfig {
        // Open loop: the generator must never block on backpressure within
        // the sweep sizes used here.
        queue_cap: 16384,
        max_wait: Duration::from_millis(2),
        policy: mix.policy,
        slo,
        ..ServeConfig::default()
    };
    let server = start_server(rt, tag, mix, cfg);
    let seq = server.dims().seq;
    let vocab = server.dims().vocab as u32;
    let names: Vec<&str> = mix.models.iter().map(|m| m.0).collect();

    // The deterministic workload: gaps, model picks and token streams.
    let mut rng = Pcg64::seeded(seed);
    let gaps = poisson_gaps(&mut rng, offered_rps, n);
    let picks: Vec<usize> = (0..n).map(|_| rng.below(names.len() as u32) as usize).collect();
    let tokens: Vec<Vec<i32>> =
        (0..n).map(|_| (0..seq).map(|_| rng.below(vocab) as i32).collect()).collect();

    // Warmup wave (every model once, plus pool/artifact spin-up), drained
    // and excluded from the measured window.
    let mut warm_ids = Vec::new();
    for (m, name) in names.iter().enumerate() {
        warm_ids.push(server.submit_to(name, &tokens[m % n]).unwrap());
    }
    server.drain();
    let spawns_before = threadpool::total_spawns();

    let start = Instant::now();
    let mut due = 0.0f64;
    for i in 0..n {
        due += gaps[i];
        let target = start + Duration::from_secs_f64(due);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        server.submit_to(names[picks[i]], &tokens[i]).unwrap();
    }
    server.drain();
    // Achieved throughput includes the post-submission drain: under
    // overload the backlog is served after the last arrival, and counting
    // only the submission window would just echo the offered rate.
    let served_wall = start.elapsed().as_secs_f64().max(1e-9);
    let spawned = threadpool::total_spawns() - spawns_before;
    let report = server.finish().expect("serve finish");

    // Measured window = everything after the warmup ids.
    let measured: Vec<RequestResult> =
        report.results.iter().filter(|r| !warm_ids.contains(&r.id)).cloned().collect();
    assert_eq!(measured.len(), n, "lost completions in the measured window");
    let mut lat: Vec<f64> = measured.iter().map(|r| r.total_s).collect();
    lat.sort_by(|a, b| a.total_cmp(b));
    let slo_s = slo.as_secs_f64();
    let per_model_miss = per_model(&measured, names.len(), slo_s)
        .into_iter()
        .zip(&names)
        .map(|(mm, name)| ((*name).to_string(), mm.slo_miss.unwrap_or(0.0)))
        .collect();
    Point {
        offered_rps,
        achieved_rps: n as f64 / served_wall,
        p50_s: percentile(&lat, 50.0),
        p95_s: percentile(&lat, 95.0),
        p99_s: percentile(&lat, 99.0),
        slo_miss: slo_miss_fraction(&measured, slo_s).unwrap_or(0.0),
        per_model_miss,
        spawned,
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let full = args.iter().any(|a| a == "--full");
    let tag = if full { "base" } else { "tiny" };
    let rt = Arc::new(ArtifactRuntime::open_default().expect("artifact runtime"));
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);

    let mixes = vec![
        Mix { label: "1-model-nmg", models: vec![("nmg", NMG, 2, 1)], policy: SchedPolicy::Fifo },
        Mix {
            label: "2-model-dense+nmg",
            models: vec![("dense", FfnMode::NativeDense, 1, 1), ("nmg", NMG, 1, 3)],
            policy: SchedPolicy::Wdrr,
        },
    ];
    let load_fractions: Vec<f64> = if smoke {
        vec![0.2]
    } else if full {
        vec![0.25, 0.5, 0.75, 1.0, 1.25, 1.5]
    } else {
        vec![0.25, 0.5, 1.0, 1.5]
    };
    let n_requests = if smoke {
        64
    } else if full {
        512
    } else {
        256
    };
    let calib_requests = if smoke { 64 } else { 128 };

    println!(
        "# Open-loop Poisson arrivals: artifacts `{tag}`, {n_requests} requests/point, \
         {cores} cores (smoke={smoke}, full={full})"
    );
    let mut json = JsonReport::new("serving_arrivals");
    for mix in &mixes {
        let capacity = calibrate(&rt, tag, mix, calib_requests);
        // SLO: an order of magnitude above the per-request service time at
        // capacity, floored for scheduler granularity — tight enough that
        // overload shows, loose enough that trivial load sails under it.
        let slo = Duration::from_secs_f64((10.0 / capacity).max(0.005));
        println!(
            "\n## mix {} ({:?}); calibrated capacity {:.0} req/s, slo {:.1} ms",
            mix.label,
            mix.policy,
            capacity,
            slo.as_secs_f64() * 1e3
        );
        println!("load\toffered_rps\tachieved_rps\tp50_ms\tp95_ms\tp99_ms\tslo_miss\tspawns");
        for (pi, &frac) in load_fractions.iter().enumerate() {
            let offered = (capacity * frac).max(1.0);
            let p = run_point(&rt, tag, mix, offered, n_requests, slo, 900 + pi as u64);
            println!(
                "{frac:.2}x\t{:.0}\t{:.0}\t{:.3}\t{:.3}\t{:.3}\t{:.3}\t{}",
                p.offered_rps,
                p.achieved_rps,
                p.p50_s * 1e3,
                p.p95_s * 1e3,
                p.p99_s * 1e3,
                p.slo_miss,
                p.spawned
            );
            for (name, miss) in &p.per_model_miss {
                println!("  model {name}: slo_miss {miss:.3}");
            }
            json.row(&[
                ("mix", mix.label.into()),
                ("load_fraction", frac.into()),
                ("offered_rps", p.offered_rps.into()),
                ("achieved_rps", p.achieved_rps.into()),
                ("p50_s", p.p50_s.into()),
                ("p95_s", p.p95_s.into()),
                ("p99_s", p.p99_s.into()),
                ("slo_miss", p.slo_miss.into()),
                ("slo_s", slo.as_secs_f64().into()),
                ("spawns", p.spawned.into()),
            ]);
            for (name, miss) in &p.per_model_miss {
                json.row(&[
                    ("mix", mix.label.into()),
                    ("load_fraction", frac.into()),
                    ("model", name.as_str().into()),
                    ("slo_miss", (*miss).into()),
                ]);
            }
            if smoke {
                assert_eq!(
                    p.spawned, 0,
                    "steady-state serving must not spawn threads (mix {})",
                    mix.label
                );
                assert!(
                    p.slo_miss <= 0.5,
                    "slo-miss {:.3} at trivial load ({:.0} of {:.0} req/s capacity, mix {})",
                    p.slo_miss,
                    p.offered_rps,
                    capacity,
                    mix.label
                );
            }
        }
    }

    match json.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("failed to write bench json: {e}"),
    }
    if smoke {
        println!("smoke OK: spawn-free open-loop serving, sane SLO-miss at trivial load");
    }
    println!(
        "\n(expect slo_miss ~0 below capacity and climbing past 1.0x offered load; \
         the 2-model mix shares workers under weighted deficit round-robin)"
    );
}
