//! Serving throughput: single-threaded drain loop vs the concurrent
//! deadline-batching server.
//!
//! Submits a fixed request stream to (a) the synchronous `BatchServer`
//! baseline and (b) `ConcurrentServer` swept over replicas x max_wait, and
//! reports wall-clock requests/sec, latency percentiles, batch counts and
//! the queue high-water mark. On a multi-core host >= 2 replicas should
//! beat the drain loop: batches execute in parallel on engine replicas
//! that share one Arc-held (pruned) weight set.
//!
//! Run: `cargo bench --bench serving_throughput [-- --full]`
//! (full mode serves the `base` artifacts; quick mode serves `tiny`.)

use std::time::{Duration, Instant};

use sten::coordinator::{BatchServer, ConcurrentServer, Engine, FfnMode, ServeConfig};
use sten::runtime::ArtifactRuntime;
use sten::util::benchkit::{parse_mode, BenchMode, JsonReport};
use sten::util::rng::Pcg64;
use sten::util::threadpool;

const FFN: FfnMode = FfnMode::NativeNmg { n: 2, m: 4, g: 4 };

fn engine(tag: &str) -> Engine {
    let rt = ArtifactRuntime::open_default().expect("artifact runtime");
    Engine::new(rt, tag, FFN, 42).unwrap()
}

fn requests(seq: usize, vocab: usize, count: usize) -> Vec<Vec<i32>> {
    let mut rng = Pcg64::seeded(77);
    (0..count)
        .map(|_| (0..seq).map(|_| rng.below(vocab as u32) as i32).collect())
        .collect()
}

/// Baseline: enqueue everything, drain on the caller thread.
fn run_baseline(tag: &str, reqs: &[Vec<i32>]) -> (f64, f64) {
    let mut server = BatchServer::new(engine(tag), Duration::from_millis(1));
    let t = Instant::now();
    for r in reqs {
        server.submit(r);
    }
    server.run_until_drained().unwrap();
    let wall = t.elapsed().as_secs_f64();
    let p50 = server.latency_summary().map(|s| s.p50).unwrap_or(0.0);
    (reqs.len() as f64 / wall, p50)
}

struct ConcRow {
    rps: f64,
    p50: f64,
    p95: f64,
    p99: f64,
    batches: u64,
    high_water: usize,
}

fn run_concurrent(tag: &str, reqs: &[Vec<i32>], replicas: usize, max_wait: Duration) -> ConcRow {
    let cfg = ServeConfig { replicas, queue_cap: 64, max_wait, ..ServeConfig::default() };
    let server = ConcurrentServer::start(engine(tag), cfg).unwrap();
    let t = Instant::now();
    for r in reqs {
        server.submit(r).unwrap();
    }
    let report = server.finish().unwrap();
    let wall = t.elapsed().as_secs_f64();
    let lat = report.latency.expect("latency summary");
    ConcRow {
        rps: reqs.len() as f64 / wall,
        p50: lat.p50,
        p95: lat.p95,
        p99: lat.p99,
        batches: report.batches,
        high_water: report.queue_high_water,
    }
}

fn main() {
    let mode = parse_mode();
    let (tag, count) = match mode {
        BenchMode::Full => ("base", 96),
        BenchMode::Quick => ("tiny", 512),
    };
    let probe = engine(tag);
    let (seq, vocab, batch) = (probe.dims.seq, probe.dims.vocab, probe.dims.batch);
    drop(probe);
    let reqs = requests(seq, vocab, count);
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    println!(
        "# Serving throughput: artifacts `{tag}`, {count} requests, batch {batch}, \
         {cores} cores (mode {mode:?})"
    );

    let mut json = JsonReport::new("serving_throughput");
    let (base_rps, base_p50) = run_baseline(tag, &reqs);
    println!("\nserver\treplicas\tmax_wait_ms\treq_per_s\tspeedup\tp50_ms\tp95_ms\tp99_ms\tbatches\tqueue_hw");
    println!(
        "drain-loop\t1\t1\t{base_rps:.0}\t1.00\t{:.3}\t-\t-\t-\t-",
        base_p50 * 1e3
    );
    json.row(&[
        ("server", "drain-loop".into()),
        ("replicas", 1usize.into()),
        ("req_per_s", base_rps.into()),
        ("p50_s", base_p50.into()),
    ]);

    // Best observed throughput per replica count (across max_wait settings),
    // for the replica-scaling summary below.
    let mut best_rps: Vec<(usize, f64)> = Vec::new();
    for replicas in [1usize, 2, 4, 8] {
        if replicas > cores.max(2) * 2 {
            continue;
        }
        let mut best = 0f64;
        for wait_ms in [1u64, 5] {
            let row = run_concurrent(tag, &reqs, replicas, Duration::from_millis(wait_ms));
            best = best.max(row.rps);
            println!(
                "concurrent\t{replicas}\t{wait_ms}\t{:.0}\t{:.2}\t{:.3}\t{:.3}\t{:.3}\t{}\t{}",
                row.rps,
                row.rps / base_rps,
                row.p50 * 1e3,
                row.p95 * 1e3,
                row.p99 * 1e3,
                row.batches,
                row.high_water
            );
            json.row(&[
                ("server", "concurrent".into()),
                ("replicas", replicas.into()),
                ("max_wait_ms", (wait_ms as usize).into()),
                ("req_per_s", row.rps.into()),
                ("p50_s", row.p50.into()),
                ("p95_s", row.p95.into()),
                ("p99_s", row.p99.into()),
            ]);
        }
        best_rps.push((replicas, best));
    }

    // Replica scaling: with Arc-shared weights (no per-forward memcpy),
    // sharded runtime timing, per-worker completion buffers and the
    // cores/replicas kernel-thread cap, adding replicas should raise
    // throughput instead of staying flat on lock contention.
    if let Some(&(_, one)) = best_rps.iter().find(|(r, _)| *r == 1) {
        println!("\nreplica scaling (best req/s vs 1 replica):");
        for &(replicas, rps) in &best_rps {
            println!("  {replicas} replicas: {:.0} req/s ({:.2}x)", rps, rps / one);
        }
        if let Some(&(_, four)) = best_rps.iter().find(|(r, _)| *r == 4) {
            println!("replica-scaling-4x-vs-1x: {:.2}", four / one);
        }
    }
    // Spawn-free steady state: with a warm server (pool workers, replica
    // threads and artifact preparation all up), a second wave of requests
    // must not create a single thread — kernel parallelism comes entirely
    // from the persistent pool.
    let steady_replicas = 2usize.min(cores.max(1));
    let steady_cfg = ServeConfig {
        replicas: steady_replicas,
        queue_cap: 64,
        max_wait: Duration::from_millis(1),
        ..ServeConfig::default()
    };
    let server = ConcurrentServer::start(engine(tag), steady_cfg).unwrap();
    for r in reqs.iter().take(reqs.len() / 4 + 1) {
        server.submit(r).unwrap(); // warmup wave
    }
    server.drain();
    let spawns_before = threadpool::total_spawns();
    let t = Instant::now();
    for r in &reqs {
        server.submit(r).unwrap();
    }
    server.drain();
    let steady_wall = t.elapsed().as_secs_f64();
    let spawned = threadpool::total_spawns() - spawns_before;
    let steady_rps = reqs.len() as f64 / steady_wall.max(1e-12);
    println!(
        "\nsteady-state (warm server, {steady_replicas} replicas): {steady_rps:.0} req/s, \
         {spawned} thread spawns (expect 0)"
    );
    json.row(&[
        ("server", "steady-state".into()),
        ("replicas", steady_replicas.into()),
        ("req_per_s", steady_rps.into()),
        ("spawns", spawned.into()),
    ]);
    let report = server.finish().unwrap();
    println!("per-replica runtime timing (cumulative over both waves):");
    for (r, times) in report.replica_timing.iter().enumerate() {
        println!(
            "  replica {r}: execute {:.3}s, transfer {:.3}s",
            times.secs("execute"),
            times.secs("transfer")
        );
    }

    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write bench json: {e}"),
    }
    println!(
        "\n(expect concurrent >= 2 replicas to beat the drain loop in req/s on a \
         multi-core host; higher max_wait trades latency for fuller batches; \
         steady-state spawns must be 0 — the pool is persistent)"
    );
}
