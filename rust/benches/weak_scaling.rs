//! §6.1 weak scaling: distributed masked training overhead vs worker count.
//!
//! Fixed per-worker batch, workers 1..=N (in-process replicas + real ring
//! allreduce). Reports per-step time for dense vs masked-sparse gradient
//! synchronization, and the share of step time spent on sparse handling
//! (dense conversion + re-sparsification). Paper claims: conservative
//! convert-and-resparsify handling adds < 10% weak-scaling overhead.
//!
//! Run: `cargo bench --bench weak_scaling [-- --full]`

use std::collections::BTreeMap;

use sten::autograd::Tape;
use sten::dist::collective::RingAllreduce;
use sten::dist::ddp::{sync_gradients, GradSyncMode, GradSyncStats};
use sten::formats::{AnyTensor, MaskedTensor};
use sten::model::MlpSpec;
use sten::tensor::DenseTensor;
use sten::train::data::ClusterDataset;
use sten::train::masked::{compute_mask, MaskFormat};
use sten::util::benchkit::{parse_mode, Bench, BenchMode};
use sten::util::rng::Pcg64;

fn step_time(spec: &MlpSpec, workers: usize, mode: GradSyncMode, batch: usize, bench: Bench) -> (f64, GradSyncStats) {
    let mut rng = Pcg64::seeded(21);
    let mut params = spec.init(&mut rng);
    let masks: BTreeMap<String, DenseTensor> = spec
        .prunable_weights()
        .into_iter()
        .map(|nm| (nm.clone(), compute_mask(&params[&nm], 0.5, MaskFormat::Nm { m: 4 })))
        .collect();
    for (nm, mask) in &masks {
        let w = params[nm].zip(mask, |v, m| v * m);
        params.insert(nm.clone(), w);
    }
    let ds = ClusterDataset::new(spec.input_dim, spec.classes, 0.4, 5);
    let ring = RingAllreduce::new(workers);
    let names = spec.weight_names();
    let mut stats_acc = GradSyncStats::default();

    let sample = bench.run(|| {
        // Per-worker gradients.
        let grads: Vec<BTreeMap<String, DenseTensor>> = (0..workers)
            .map(|w| {
                let mut r = Pcg64::new(100, w as u64);
                let (x, y) = ds.batch(batch, &mut r);
                let tape = Tape::new();
                let (logits, vars) = spec.forward_tape(&tape, &params, x);
                let loss = tape.softmax_cross_entropy(logits, &y);
                tape.backward(loss).unwrap();
                vars.iter().map(|(nm, v)| (nm.clone(), tape.grad(*v).unwrap())).collect()
            })
            .collect();
        // Synchronize.
        for nm in &names {
            let per: Vec<AnyTensor> = grads
                .iter()
                .map(|g| match (mode, masks.get(nm)) {
                    (GradSyncMode::Dense, _) | (_, None) => AnyTensor::Dense(g[nm].clone()),
                    (_, Some(mask)) => {
                        AnyTensor::Masked(MaskedTensor::new(g[nm].clone(), mask.clone()))
                    }
                })
                .collect();
            let (_, st) = sync_gradients(&ring, &per, mode).unwrap();
            stats_acc.to_dense_s += st.to_dense_s;
            stats_acc.allreduce_s += st.allreduce_s;
            stats_acc.resparsify_s += st.resparsify_s;
        }
    });
    (sample.median, stats_acc)
}

fn main() {
    let mode = parse_mode();
    let (spec, batch, bench, max_workers) = match mode {
        BenchMode::Full => (
            MlpSpec { input_dim: 256, hidden: vec![1024], classes: 10 },
            64,
            Bench::new(1, 6),
            16,
        ),
        BenchMode::Quick => (
            MlpSpec { input_dim: 64, hidden: vec![256], classes: 10 },
            32,
            Bench::new(1, 4),
            8,
        ),
    };
    println!("# Weak scaling: fixed per-worker batch {batch} (mode {mode:?})");
    println!("\nworkers\tdense_ms\tsparse_ms\tsparse_overhead_pct\tdense_efficiency\tsparse_efficiency");
    let mut base: Option<(f64, f64)> = None;
    let mut w = 1;
    while w <= max_workers {
        let (t_dense, _) = step_time(&spec, w, GradSyncMode::Dense, batch, bench);
        let (t_sparse, st) = step_time(&spec, w, GradSyncMode::SparseResparsify, batch, bench);
        let (d0, s0) = *base.get_or_insert((t_dense, t_sparse));
        let overhead = 100.0 * (t_sparse - t_dense).max(0.0) / t_dense;
        println!(
            "{w}\t{:.2}\t{:.2}\t{overhead:.1}\t{:.2}\t{:.2}",
            t_dense * 1e3,
            t_sparse * 1e3,
            d0 / t_dense,
            s0 / t_sparse
        );
        let _ = st;
        w *= 2;
    }

    // Fixed-pattern optimization (§4.6): resparsify vs pattern-reuse.
    println!("\n# sync-mode comparison at max workers");
    for (name, m) in [
        ("dense", GradSyncMode::Dense),
        ("sparse-resparsify", GradSyncMode::SparseResparsify),
        ("sparse-fixed-pattern", GradSyncMode::SparseFixedPattern),
    ] {
        let (t, st) = step_time(&spec, max_workers, m, batch, bench);
        println!(
            "{name}\t{:.2} ms/step (to_dense {:.2} allreduce {:.2} resparsify {:.2})",
            t * 1e3,
            st.to_dense_s * 1e3,
            st.allreduce_s * 1e3,
            st.resparsify_s * 1e3
        );
    }
}
