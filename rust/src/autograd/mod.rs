//! Reverse-mode autograd with sparsified gradients (§4.5, Fig. 2).
//!
//! A minimal tape over [`DenseTensor`] compute, reproducing the STen
//! attachment points: every parameter can carry a *gradient output format*
//! (inline sparsifier → temporary layout → external sparsifier → final
//! layout), applied when its gradient is materialized during backward — the
//! `grad_fmt` argument of `SparseParameterWrapper` in the paper.

use std::cell::RefCell;

use anyhow::{anyhow, Result};

use crate::dispatch::OutputFormat;
use crate::formats::AnyTensor;
use crate::kernels::{dense_gemm, elementwise};
use crate::tensor::DenseTensor;

/// A variable on the tape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Var(usize);

enum Expr {
    Leaf,
    MatMul(Var, Var),
    Add(Var, Var),
    Mul(Var, Var),
    BiasAdd(Var, Var),
    Relu(Var),
    Gelu(Var),
    Scale(Var, f32),
    /// Mean softmax cross-entropy against integer labels; scalar output.
    SoftmaxXent(Var, Vec<usize>),
    /// Mean squared error against a constant target; scalar output.
    Mse(Var, DenseTensor),
}

struct Node {
    value: DenseTensor,
    expr: Expr,
    grad: Option<DenseTensor>,
    /// Sparsified gradient view (populated when a grad format is attached).
    sparse_grad: Option<AnyTensor>,
    grad_fmt: Option<OutputFormat>,
    requires_grad: bool,
}

/// The gradient tape. Single-threaded (interior mutability via `RefCell`),
/// rebuilt per step — the standard define-by-run model.
#[derive(Default)]
pub struct Tape {
    nodes: RefCell<Vec<Node>>,
}

impl Tape {
    /// Empty tape.
    pub fn new() -> Self {
        Self::default()
    }

    fn push(&self, value: DenseTensor, expr: Expr, requires_grad: bool) -> Var {
        let mut nodes = self.nodes.borrow_mut();
        nodes.push(Node {
            value,
            expr,
            grad: None,
            sparse_grad: None,
            grad_fmt: None,
            requires_grad,
        });
        Var(nodes.len() - 1)
    }

    /// Non-differentiable input (activations, data).
    pub fn input(&self, value: DenseTensor) -> Var {
        self.push(value, Expr::Leaf, false)
    }

    /// Trainable parameter.
    pub fn param(&self, value: DenseTensor) -> Var {
        self.push(value, Expr::Leaf, true)
    }

    /// Trainable parameter with a gradient output format (Fig. 2: the weight
    /// gradient is sparsified on materialization).
    pub fn param_with_grad_fmt(&self, value: DenseTensor, fmt: OutputFormat) -> Var {
        let v = self.push(value, Expr::Leaf, true);
        self.nodes.borrow_mut()[v.0].grad_fmt = Some(fmt);
        v
    }

    /// Current value of a variable.
    pub fn value(&self, v: Var) -> DenseTensor {
        self.nodes.borrow()[v.0].value.clone()
    }

    /// Dense gradient of a variable (after `backward`).
    pub fn grad(&self, v: Var) -> Option<DenseTensor> {
        self.nodes.borrow()[v.0].grad.clone()
    }

    /// Sparsified gradient (present when a grad format was attached).
    pub fn sparse_grad(&self, v: Var) -> Option<AnyTensor> {
        self.nodes.borrow()[v.0].sparse_grad.clone()
    }

    /// C = A · B.
    pub fn matmul(&self, a: Var, b: Var) -> Var {
        let value = {
            let nodes = self.nodes.borrow();
            dense_gemm::matmul(&nodes[a.0].value, &nodes[b.0].value)
        };
        self.push(value, Expr::MatMul(a, b), true)
    }

    /// Elementwise add.
    pub fn add(&self, a: Var, b: Var) -> Var {
        let value = {
            let nodes = self.nodes.borrow();
            nodes[a.0].value.zip(&nodes[b.0].value, |x, y| x + y)
        };
        self.push(value, Expr::Add(a, b), true)
    }

    /// Elementwise multiply.
    pub fn mul(&self, a: Var, b: Var) -> Var {
        let value = {
            let nodes = self.nodes.borrow();
            nodes[a.0].value.zip(&nodes[b.0].value, |x, y| x * y)
        };
        self.push(value, Expr::Mul(a, b), true)
    }

    /// Bias add over the rows of a 2-D tensor.
    pub fn bias_add(&self, x: Var, bias: Var) -> Var {
        let value = {
            let nodes = self.nodes.borrow();
            elementwise::bias_add(&nodes[x.0].value, nodes[bias.0].value.data())
        };
        self.push(value, Expr::BiasAdd(x, bias), true)
    }

    /// ReLU.
    pub fn relu(&self, x: Var) -> Var {
        let value = elementwise::relu(&self.nodes.borrow()[x.0].value);
        self.push(value, Expr::Relu(x), true)
    }

    /// GeLU.
    pub fn gelu(&self, x: Var) -> Var {
        let value = elementwise::gelu(&self.nodes.borrow()[x.0].value);
        self.push(value, Expr::Gelu(x), true)
    }

    /// Scalar scale.
    pub fn scale(&self, x: Var, alpha: f32) -> Var {
        let value = self.nodes.borrow()[x.0].value.map(|v| v * alpha);
        self.push(value, Expr::Scale(x, alpha), true)
    }

    /// Mean softmax cross-entropy of 2-D logits against integer labels.
    pub fn softmax_cross_entropy(&self, logits: Var, labels: &[usize]) -> Var {
        let value = {
            let nodes = self.nodes.borrow();
            let l = &nodes[logits.0].value;
            assert_eq!(l.rows(), labels.len(), "label count mismatch");
            let probs = elementwise::softmax_rows(l);
            let mut loss = 0f32;
            for (i, &y) in labels.iter().enumerate() {
                loss -= probs.get2(i, y).max(1e-12).ln();
            }
            DenseTensor::from_vec(&[], vec![loss / labels.len() as f32])
        };
        self.push(value, Expr::SoftmaxXent(logits, labels.to_vec()), true)
    }

    /// Mean squared error against a constant target.
    pub fn mse(&self, x: Var, target: &DenseTensor) -> Var {
        let value = {
            let nodes = self.nodes.borrow();
            let diff = nodes[x.0].value.zip(target, |a, b| a - b);
            let n = diff.numel() as f32;
            DenseTensor::from_vec(&[], vec![diff.data().iter().map(|d| d * d).sum::<f32>() / n])
        };
        self.push(value, Expr::Mse(x, target.clone()), true)
    }

    /// Run reverse-mode accumulation from a scalar `root`.
    pub fn backward(&self, root: Var) -> Result<()> {
        let mut nodes = self.nodes.borrow_mut();
        if nodes[root.0].value.numel() != 1 {
            return Err(anyhow!("backward root must be scalar"));
        }
        for n in nodes.iter_mut() {
            n.grad = None;
            n.sparse_grad = None;
        }
        nodes[root.0].grad = Some(DenseTensor::from_vec(&[], vec![1.0]));

        for i in (0..=root.0).rev() {
            let Some(gout) = nodes[i].grad.clone() else { continue };
            // Split borrows by taking the expr description first.
            match &nodes[i].expr {
                Expr::Leaf => {}
                Expr::MatMul(a, b) => {
                    let (a, b) = (*a, *b);
                    let av = nodes[a.0].value.clone();
                    let bv = nodes[b.0].value.clone();
                    let da = dense_gemm::matmul(&gout, &bv.transpose2());
                    let db = dense_gemm::matmul(&av.transpose2(), &gout);
                    accumulate(&mut nodes[a.0], da);
                    accumulate(&mut nodes[b.0], db);
                }
                Expr::Add(a, b) => {
                    let (a, b) = (*a, *b);
                    accumulate(&mut nodes[a.0], gout.clone());
                    accumulate(&mut nodes[b.0], gout);
                }
                Expr::Mul(a, b) => {
                    let (a, b) = (*a, *b);
                    let av = nodes[a.0].value.clone();
                    let bv = nodes[b.0].value.clone();
                    accumulate(&mut nodes[a.0], gout.zip(&bv, |g, y| g * y));
                    accumulate(&mut nodes[b.0], gout.zip(&av, |g, x| g * x));
                }
                Expr::BiasAdd(x, bias) => {
                    let (x, bias) = (*x, *bias);
                    let cols = gout.cols();
                    let mut db = vec![0f32; cols];
                    for (j, v) in gout.data().iter().enumerate() {
                        db[j % cols] += v;
                    }
                    accumulate(&mut nodes[x.0], gout);
                    accumulate(&mut nodes[bias.0], DenseTensor::from_vec(&[cols], db));
                }
                Expr::Relu(x) => {
                    let x = *x;
                    let mask = nodes[x.0].value.map(|v| if v > 0.0 { 1.0 } else { 0.0 });
                    accumulate(&mut nodes[x.0], gout.zip(&mask, |g, m| g * m));
                }
                Expr::Gelu(x) => {
                    let x = *x;
                    let dg = elementwise::gelu_grad(&nodes[x.0].value);
                    accumulate(&mut nodes[x.0], gout.zip(&dg, |g, d| g * d));
                }
                Expr::Scale(x, alpha) => {
                    let (x, alpha) = (*x, *alpha);
                    accumulate(&mut nodes[x.0], gout.map(|g| g * alpha));
                }
                Expr::SoftmaxXent(logits, labels) => {
                    let logits = *logits;
                    let labels = labels.clone();
                    let probs = elementwise::softmax_rows(&nodes[logits.0].value);
                    let batch = labels.len() as f32;
                    let mut g = probs;
                    for (i, &y) in labels.iter().enumerate() {
                        let cur = g.get2(i, y);
                        g.set2(i, y, cur - 1.0);
                    }
                    g.scale(gout.data()[0] / batch);
                    accumulate(&mut nodes[logits.0], g);
                }
                Expr::Mse(x, target) => {
                    let x = *x;
                    let target = target.clone();
                    let n = nodes[x.0].value.numel() as f32;
                    let g = nodes[x.0]
                        .value
                        .zip(&target, |a, b| 2.0 * (a - b) / n)
                        .map(|v| v * gout.data()[0]);
                    accumulate(&mut nodes[x.0], g);
                }
            }
        }

        // Apply gradient output formats (Fig. 2: sparsify weight gradients).
        for n in nodes.iter_mut() {
            if let (Some(fmt), Some(g)) = (&n.grad_fmt, &n.grad) {
                let sparse = fmt.apply(&AnyTensor::Dense(g.clone()))?;
                // The dense view also reflects the sparsified gradient.
                n.grad = Some(sparse.to_dense());
                n.sparse_grad = Some(sparse);
            }
        }
        Ok(())
    }

    /// SGD step over the given parameters: `p -= lr * grad(p)`.
    pub fn sgd_step(&self, params: &[Var], lr: f32) {
        let mut nodes = self.nodes.borrow_mut();
        for &p in params {
            let g = nodes[p.0].grad.clone().expect("missing grad; call backward first");
            nodes[p.0].value.axpy(-lr, &g);
        }
    }
}

fn accumulate(node: &mut Node, g: DenseTensor) {
    if !node.requires_grad && matches!(node.expr, Expr::Leaf) {
        return;
    }
    match &mut node.grad {
        Some(acc) => acc.axpy(1.0, &g),
        slot @ None => *slot = Some(g),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Layout;
    use crate::sparsify::ScalarFraction;
    use crate::util::rng::Pcg64;

    /// Finite-difference check of d(loss)/d(param[i]).
    fn fd_check(build: impl Fn(&DenseTensor) -> f32, w: &DenseTensor, grad: &DenseTensor) {
        let eps = 1e-2;
        for i in (0..w.numel()).step_by((w.numel() / 8).max(1)) {
            let mut up = w.clone();
            up.data_mut()[i] += eps;
            let mut dn = w.clone();
            dn.data_mut()[i] -= eps;
            let fd = (build(&up) - build(&dn)) / (2.0 * eps);
            let an = grad.data()[i];
            assert!(
                (fd - an).abs() < 2e-2 * (1.0 + fd.abs()),
                "param {i}: fd {fd} vs autograd {an}"
            );
        }
    }

    #[test]
    fn linear_mse_gradients_match_finite_difference() {
        let mut rng = Pcg64::seeded(300);
        let x0 = DenseTensor::randn(&[4, 5], &mut rng);
        let w0 = DenseTensor::randn(&[5, 3], &mut rng);
        let t0 = DenseTensor::randn(&[4, 3], &mut rng);

        let loss_of = |w: &DenseTensor| {
            let tape = Tape::new();
            let x = tape.input(x0.clone());
            let wv = tape.param(w.clone());
            let y = tape.matmul(x, wv);
            let l = tape.mse(y, &t0);
            tape.value(l).data()[0]
        };

        let tape = Tape::new();
        let x = tape.input(x0.clone());
        let w = tape.param(w0.clone());
        let y = tape.matmul(x, w);
        let l = tape.mse(y, &t0);
        tape.backward(l).unwrap();
        fd_check(loss_of, &w0, &tape.grad(w).unwrap());
    }

    #[test]
    fn mlp_xent_gradients_match_finite_difference() {
        let mut rng = Pcg64::seeded(301);
        let x0 = DenseTensor::randn(&[6, 8], &mut rng);
        let w1_0 = DenseTensor::kaiming(&[8, 10], &mut rng);
        let b1_0 = DenseTensor::zeros(&[10]);
        let w2_0 = DenseTensor::kaiming(&[10, 4], &mut rng);
        let labels = vec![0usize, 1, 2, 3, 1, 2];

        let loss_of = |w1: &DenseTensor| {
            let tape = Tape::new();
            let x = tape.input(x0.clone());
            let w1v = tape.param(w1.clone());
            let b1v = tape.param(b1_0.clone());
            let w2v = tape.param(w2_0.clone());
            let h = tape.gelu(tape.bias_add(tape.matmul(x, w1v), b1v));
            let logits = tape.matmul(h, w2v);
            let l = tape.softmax_cross_entropy(logits, &labels);
            tape.value(l).data()[0]
        };

        let tape = Tape::new();
        let x = tape.input(x0.clone());
        let w1 = tape.param(w1_0.clone());
        let b1 = tape.param(b1_0.clone());
        let w2 = tape.param(w2_0.clone());
        let h = tape.gelu(tape.bias_add(tape.matmul(x, w1), b1));
        let logits = tape.matmul(h, w2);
        let l = tape.softmax_cross_entropy(logits, &labels);
        tape.backward(l).unwrap();
        fd_check(loss_of, &w1_0, &tape.grad(w1).unwrap());
    }

    #[test]
    fn relu_grad_masks_negatives() {
        let tape = Tape::new();
        let x = tape.param(DenseTensor::from_vec(&[1, 4], vec![-1.0, 2.0, -3.0, 4.0]));
        let y = tape.relu(x);
        let l = tape.mse(y, &DenseTensor::zeros(&[1, 4]));
        tape.backward(l).unwrap();
        let g = tape.grad(x).unwrap();
        assert_eq!(g.data()[0], 0.0);
        assert_eq!(g.data()[2], 0.0);
        assert!(g.data()[1] != 0.0 && g.data()[3] != 0.0);
    }

    #[test]
    fn grad_fmt_sparsifies_weight_gradient() {
        let mut rng = Pcg64::seeded(302);
        let x0 = DenseTensor::randn(&[4, 6], &mut rng);
        let tape = Tape::new();
        let x = tape.input(x0);
        let fmt = OutputFormat::external(Box::new(ScalarFraction { fraction: 0.5 }), Layout::Csr);
        let w = tape.param_with_grad_fmt(DenseTensor::randn(&[6, 3], &mut rng), fmt);
        let y = tape.matmul(x, w);
        let l = tape.mse(y, &DenseTensor::zeros(&[4, 3]));
        tape.backward(l).unwrap();
        let sg = tape.sparse_grad(w).unwrap();
        assert_eq!(sg.layout(), Layout::Csr);
        assert_eq!(sg.nnz(), 9); // half of 18 dropped
        // Dense view agrees with the sparsified gradient.
        assert!(tape.grad(w).unwrap().allclose(&sg.to_dense(), 0.0, 0.0));
    }

    #[test]
    fn sgd_step_reduces_loss() {
        let mut rng = Pcg64::seeded(303);
        let x0 = DenseTensor::randn(&[8, 4], &mut rng);
        let t0 = DenseTensor::randn(&[8, 2], &mut rng);
        let mut w0 = DenseTensor::kaiming(&[4, 2], &mut rng);
        let mut losses = Vec::new();
        for _ in 0..20 {
            let tape = Tape::new();
            let x = tape.input(x0.clone());
            let w = tape.param(w0.clone());
            let y = tape.matmul(x, w);
            let l = tape.mse(y, &t0);
            losses.push(tape.value(l).data()[0]);
            tape.backward(l).unwrap();
            tape.sgd_step(&[w], 0.1);
            w0 = tape.value(w);
        }
        assert!(losses.last().unwrap() < &(losses[0] * 0.5), "{losses:?}");
    }

    #[test]
    fn backward_requires_scalar_root() {
        let tape = Tape::new();
        let x = tape.param(DenseTensor::ones(&[2, 2]));
        assert!(tape.backward(x).is_err());
    }

    #[test]
    fn grad_accumulates_over_shared_use() {
        let tape = Tape::new();
        let x = tape.param(DenseTensor::from_vec(&[], vec![3.0]));
        let y = tape.add(x, x); // y = 2x
        let l = tape.mse(y, &DenseTensor::from_vec(&[], vec![0.0]));
        tape.backward(l).unwrap();
        // d/dx (2x)^2 = 8x = 24.
        assert!((tape.grad(x).unwrap().data()[0] - 24.0).abs() < 1e-4);
    }
}
