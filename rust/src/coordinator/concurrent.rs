//! The concurrent serving front-end: bounded submission queue, deadline
//! batcher, N engine replicas.
//!
//! Topology (all threads live on one [`WorkerPool`]):
//!
//! ```text
//! submit() --bounded channel--> [batcher] --batch channel--> [worker 0..N)
//!   (backpressure: send blocks    |  deadline batch formation   each owns an
//!    when queue_cap is reached)   |  (full batch: dispatch now;  Engine replica
//!                                 |   else: dispatch when the    sharing weights
//!                                 |   oldest request has waited  via Arc
//!                                 |   max_wait)
//! ```
//!
//! Guarantees:
//!
//! * **Backpressure** — at most `queue_cap` requests are queued ahead of the
//!   batcher; further `submit` calls block (no unbounded memory).
//! * **Deadline batching** — a batch is dispatched the moment it is full,
//!   or as soon as its oldest request has waited `max_wait`, whichever
//!   comes first. Under light load no request waits in queue longer than
//!   `max_wait` before its batch is formed.
//! * **Shared weights** — replicas are [`Engine::replicate`] clones: one
//!   `Arc`-held parameter set, n:m:g conversion done once, and zero weight
//!   bytes copied per forward (`Value::F32` carries `Arc` handles).
//! * **De-contended completion** — each worker records results in its own
//!   buffer (merged on snapshot/finish); the only cross-worker critical
//!   section per batch is a counter bump under the completion condvar's
//!   mutex. Kernel parallelism is divided among replicas via
//!   [`crate::util::threadpool::register_kernel_users`], so R replicas
//!   never oversubscribe the host by R x cores.
//! * **Metrics** — per-request latency records with real batch ids,
//!   p50/p95/p99 summaries and a queue-depth gauge with high-water mark.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::runtime::ArtifactRuntime;
use crate::util::channel::{self, Received};
use crate::util::threadpool::{self, WorkerPool};
use crate::util::timer::TimeBreakdown;

use super::engine::{EncoderDims, Engine};
use super::metrics::{self, LatencySummary, QueueGauge};
use super::serve::{canonical_tokens, pad_batch_tokens, Request, RequestResult};

/// Configuration for [`ConcurrentServer`].
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Engine replicas (worker threads executing batches).
    pub replicas: usize,
    /// Submission queue bound; `submit` blocks past this depth.
    pub queue_cap: usize,
    /// Max time a request may wait for batch-mates before its (possibly
    /// partial) batch is dispatched.
    pub max_wait: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { replicas: 2, queue_cap: 256, max_wait: Duration::from_millis(2) }
    }
}

/// A formed batch travelling from the batcher to a worker.
struct Batch {
    id: u64,
    formed: Instant,
    requests: Vec<Request>,
}

/// State shared by submitters, the batcher and the workers.
struct Shared {
    /// One completion buffer per worker. Each worker appends only to its
    /// own slot, so the result-recording hot path never contends with other
    /// workers; snapshots and `finish` merge the buffers.
    worker_results: Vec<Mutex<Vec<RequestResult>>>,
    /// Batch/batcher failures (rare path; a plain shared lock is fine).
    errors: Mutex<Vec<String>>,
    /// Requests accounted for (completed or failed). The mutex exists for
    /// the condvar; the critical section is a bare counter bump.
    finished: Mutex<u64>,
    done_cv: Condvar,
    gauge: QueueGauge,
    batches: AtomicU64,
}

impl Shared {
    /// Mark `n` requests accounted for and wake any drainer.
    fn account(&self, n: u64) {
        let mut fin = self.finished.lock().unwrap();
        *fin += n;
        drop(fin);
        self.done_cv.notify_all();
    }

    /// Record a failure covering `n` requests.
    fn fail(&self, n: u64, msg: String) {
        self.errors.lock().unwrap().push(msg);
        self.account(n);
    }

    /// Merge all per-worker buffers into one id-ordered result vector.
    fn merged_results(&self) -> Vec<RequestResult> {
        let mut out = Vec::new();
        for buf in &self.worker_results {
            out.extend(buf.lock().unwrap().iter().cloned());
        }
        out.sort_by_key(|r| r.id);
        out
    }
}

/// Final report returned by [`ConcurrentServer::finish`].
#[derive(Debug)]
pub struct ServeReport {
    /// One record per completed request.
    pub results: Vec<RequestResult>,
    /// p50/p95/p99 end-to-end latency summary.
    pub latency: Option<LatencySummary>,
    /// Batches dispatched.
    pub batches: u64,
    /// Server lifetime, start -> finish.
    pub wall_s: f64,
    /// Requests per second of wall-clock server lifetime.
    pub wall_rps: f64,
    /// Requests per second of (batch-deduplicated) compute time.
    pub compute_rps: Option<f64>,
    /// Deepest the submission queue has been.
    pub queue_high_water: usize,
    /// Per-replica runtime timing views (`execute`/`transfer`/`compile`
    /// buckets charged by each replica's worker thread), indexed by replica
    /// id.
    pub replica_timing: Vec<TimeBreakdown>,
}

/// The concurrent, deadline-aware batch server.
pub struct ConcurrentServer {
    dims: EncoderDims,
    submit_tx: Option<channel::Sender<Request>>,
    pool: Option<WorkerPool>,
    shared: Arc<Shared>,
    /// The replicas' shared artifact runtime (for per-replica timing views).
    rt: Arc<ArtifactRuntime>,
    replicas: usize,
    next_id: AtomicU64,
    submitted: AtomicU64,
    started: Instant,
    /// Divides the global kernel pool among this server's replicas for the
    /// server's lifetime (released on drop).
    _kernel_users: threadpool::KernelUsersGuard,
}

impl ConcurrentServer {
    /// Start serving: replicates `engine` per `cfg.replicas` (sharing its
    /// weights) and spawns the batcher plus one worker thread per replica.
    pub fn start(engine: Engine, cfg: ServeConfig) -> Result<Self> {
        if cfg.replicas == 0 {
            bail!("ServeConfig.replicas must be at least 1");
        }
        let dims = engine.dims.clone();
        let rt = Arc::clone(engine.runtime());
        let mut engines = Vec::with_capacity(cfg.replicas);
        for _ in 1..cfg.replicas {
            engines.push(engine.replicate());
        }
        engines.push(engine);

        let shared = Arc::new(Shared {
            worker_results: (0..cfg.replicas).map(|_| Mutex::new(Vec::new())).collect(),
            errors: Mutex::new(Vec::new()),
            finished: Mutex::new(0),
            done_cv: Condvar::new(),
            gauge: QueueGauge::new(),
            batches: AtomicU64::new(0),
        });

        let (submit_tx, submit_rx) = channel::bounded::<Request>(cfg.queue_cap.max(1));
        let (batch_tx, batch_rx) = channel::bounded::<Batch>(cfg.replicas * 2);
        let pool = WorkerPool::named("sten-serve", cfg.replicas + 1);

        // The batcher: deadline-driven batch formation.
        {
            let shared = shared.clone();
            let batch_size = dims.batch;
            let max_wait = cfg.max_wait;
            pool.execute(move || {
                let mut pending: VecDeque<Request> = VecDeque::new();
                let mut open = true;
                let mut next_batch = 0u64;
                while open || !pending.is_empty() {
                    if pending.is_empty() {
                        match submit_rx.recv() {
                            Some(r) => pending.push_back(r),
                            None => {
                                open = false;
                                continue;
                            }
                        }
                    }
                    while open && pending.len() < batch_size {
                        let deadline = pending.front().unwrap().arrived + max_wait;
                        match submit_rx.recv_deadline(deadline) {
                            Received::Item(r) => pending.push_back(r),
                            Received::TimedOut => break,
                            Received::Closed => open = false,
                        }
                    }
                    let take = pending.len().min(batch_size);
                    let requests: Vec<Request> = pending.drain(..take).collect();
                    shared.gauge.exit(take);
                    shared.batches.fetch_add(1, Ordering::SeqCst);
                    let batch = Batch { id: next_batch, formed: Instant::now(), requests };
                    next_batch += 1;
                    if let Err(channel::SendError(batch)) = batch_tx.send(batch) {
                        // All workers are gone (e.g. panicked): fail this
                        // batch, everything still pending, and everything
                        // that arrives until the queue closes, so drain()
                        // and finish() never hang on requests nobody will
                        // execute.
                        shared.fail(
                            batch.requests.len() as u64,
                            format!("batch {}: no workers left", batch.id),
                        );
                        let stranded = pending.len();
                        shared.gauge.exit(stranded);
                        pending.clear();
                        if stranded > 0 {
                            shared.fail(
                                stranded as u64,
                                format!("{stranded} pending requests: no workers left"),
                            );
                        }
                        while let Some(r) = submit_rx.recv() {
                            shared.gauge.exit(1);
                            shared.fail(1, format!("request {}: no workers left", r.id));
                        }
                        break;
                    }
                }
            });
        }

        // The workers: one engine replica each, each with a private
        // completion buffer so recording results never contends.
        for (worker_idx, mut engine) in engines.into_iter().enumerate() {
            let rx = batch_rx.clone();
            let shared = shared.clone();
            let dims = dims.clone();
            pool.execute(move || {
                // Tag this worker thread so the shared runtime charges its
                // artifact time to this replica's timing view.
                crate::runtime::set_replica_id(Some(worker_idx as u64));
                while let Some(batch) = rx.recv() {
                    let tokens = pad_batch_tokens(&dims, &batch.requests);
                    let t = Instant::now();
                    // A panicking forward must not kill the worker: the
                    // batch's requests would never be accounted and drain()
                    // would hang. Weights are immutable, so continuing with
                    // this engine after an unwind is safe.
                    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || engine.forward(&tokens),
                    ))
                    .unwrap_or_else(|_| Err(anyhow!("engine forward panicked")));
                    let compute_s = t.elapsed().as_secs_f64();
                    let done = Instant::now();
                    match outcome {
                        Ok(_) => {
                            let mut buf = shared.worker_results[worker_idx].lock().unwrap();
                            for r in &batch.requests {
                                buf.push(RequestResult {
                                    id: r.id,
                                    batch_id: batch.id,
                                    queue_s: batch
                                        .formed
                                        .saturating_duration_since(r.arrived)
                                        .as_secs_f64(),
                                    compute_s,
                                    total_s: done
                                        .saturating_duration_since(r.arrived)
                                        .as_secs_f64(),
                                    batch_size: batch.requests.len(),
                                });
                            }
                        }
                        Err(e) => {
                            shared.errors.lock().unwrap().push(format!("batch {}: {e:#}", batch.id))
                        }
                    }
                    shared.account(batch.requests.len() as u64);
                }
                crate::runtime::set_replica_id(None);
            });
        }
        drop(batch_rx);

        Ok(ConcurrentServer {
            dims,
            submit_tx: Some(submit_tx),
            pool: Some(pool),
            shared,
            rt,
            replicas: cfg.replicas,
            next_id: AtomicU64::new(0),
            submitted: AtomicU64::new(0),
            started: Instant::now(),
            _kernel_users: threadpool::register_kernel_users(cfg.replicas),
        })
    }

    /// Encoder dimensions of the served model.
    pub fn dims(&self) -> &EncoderDims {
        &self.dims
    }

    /// Enqueue a request (tokens clamped/padded); blocks while the
    /// submission queue is at capacity. Returns the request id.
    pub fn submit(&self, tokens: &[i32]) -> Result<u64> {
        let t = canonical_tokens(&self.dims, tokens);
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        self.shared.gauge.enter();
        let tx = self.submit_tx.as_ref().ok_or_else(|| anyhow!("server is shut down"))?;
        if tx.send(Request { id, tokens: t, arrived: Instant::now() }).is_err() {
            self.shared.gauge.exit(1);
            bail!("server is shut down");
        }
        self.submitted.fetch_add(1, Ordering::SeqCst);
        Ok(id)
    }

    /// Requests currently waiting for batch formation.
    pub fn queue_depth(&self) -> usize {
        self.shared.gauge.depth()
    }

    /// Deepest the submission queue has been.
    pub fn queue_high_water(&self) -> usize {
        self.shared.gauge.high_water()
    }

    /// Completion records so far (snapshot, merged across worker buffers,
    /// ordered by request id).
    pub fn completed(&self) -> Vec<RequestResult> {
        self.shared.merged_results()
    }

    /// Block until every request submitted so far has completed or failed.
    pub fn drain(&self) {
        let target = self.submitted.load(Ordering::SeqCst);
        let mut fin = self.shared.finished.lock().unwrap();
        while *fin < target {
            fin = self.shared.done_cv.wait(fin).unwrap();
        }
    }

    /// Stop accepting requests, flush everything in flight, join all
    /// threads and return the final report. Fails if any batch errored.
    pub fn finish(mut self) -> Result<ServeReport> {
        self.submit_tx.take(); // closes the submission queue
        if let Some(pool) = self.pool.take() {
            pool.join();
        }
        let wall_s = self.started.elapsed().as_secs_f64();
        {
            let errors = self.shared.errors.lock().unwrap();
            if !errors.is_empty() {
                bail!("{} batch(es) failed; first: {}", errors.len(), errors[0]);
            }
        }
        let results = self.shared.merged_results();
        let latency = metrics::summarize(&results);
        let compute_rps = metrics::compute_throughput(&results);
        let replica_timing =
            (0..self.replicas as u64).map(|r| self.rt.timing_for_replica(r)).collect();
        Ok(ServeReport {
            wall_rps: results.len() as f64 / wall_s.max(1e-12),
            latency,
            batches: self.shared.batches.load(Ordering::SeqCst),
            wall_s,
            compute_rps,
            queue_high_water: self.shared.gauge.high_water(),
            replica_timing,
            results,
        })
    }
}

impl Drop for ConcurrentServer {
    fn drop(&mut self) {
        // Close the queue and join threads even when `finish` was skipped.
        self.submit_tx.take();
        self.pool.take(); // WorkerPool::drop joins
    }
}
