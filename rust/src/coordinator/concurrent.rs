//! The concurrent serving front-end: model registry, pluggable scheduler,
//! shared worker pool, continuous batching with admission control and
//! load shedding.
//!
//! Topology (all threads live on one [`WorkerPool`]):
//!
//! ```text
//! submit_to(model, ..) ---> [admission control] --bounded channel--> [ingester]
//!   (EWMA service-time     |  predicted wait > SLO:   |  feeds the shared
//!    estimate per model;   |  degrade to the n:m:g    |  scheduler queues,
//!    try_submit_to never   |  variant, else Rejected) |  bounded by forming_cap
//!    blocks: QueueFull)                               v
//!                                        +------ Mutex<Scheduler> ------+
//!                                        |  per-model forming queues,   |
//!                                        |  FIFO or weighted deficit RR |
//!                                        +---^----------------------^---+
//!                                            |                      |
//!                                      [worker 0]    ...      [worker W-1]
//!                                 each worker PULLS its next batch the moment
//!                                 it frees up (continuous batching); sheds
//!                                 expired entries first, then executes on its
//!                                 own Engine replica of EVERY model
//! ```
//!
//! Guarantees:
//!
//! * **Continuous batching** — there is no formed-batch channel: a batch is
//!   formed at the instant a worker frees up, from everything queued at
//!   that moment. A slow batch occupies exactly one worker; the queues keep
//!   draining through the other workers, so head-of-line blocking is
//!   bounded by one batch per worker rather than a pipeline of pre-formed
//!   batches.
//! * **Backpressure** — at most `queue_cap` requests are queued ahead of
//!   the ingester (global across models); further `submit` calls block
//!   (`try_submit` returns [`SubmitError::QueueFull`] instead). The
//!   scheduler's forming queues are bounded by `max(queue_cap, max model
//!   batch)`: the ingester parks until a dispatch or shed frees space, so
//!   total in-flight admissions stay bounded end to end.
//! * **Admission control** (opt-in, `ServeConfig::admission`) — a
//!   per-model EWMA of observed per-request service time predicts each
//!   submission's queue-plus-service delay. Past the SLO the server
//!   degrades the request to the model's registered sparse variant
//!   ([`ModelRegistry::set_degrade`]) when that variant's own prediction
//!   fits, and otherwise rejects with [`SubmitError::Rejected`] — shifting
//!   work the queue cannot absorb to the cheap n:m:g weights instead of
//!   letting every queued request go late.
//! * **Load shedding** (opt-in, `ServeConfig::shed`) — before forming a
//!   batch, a worker drops queue entries that have already outlived the
//!   SLO: executing them would spend compute on guaranteed misses. Sheds,
//!   rejections and degrades are first-class outcomes in [`ServeReport`]
//!   (per model and total), and `goodput_rps` counts only in-SLO
//!   completions — the number that must plateau, not collapse, under
//!   overload.
//! * **Deadline batching** — per model: a full batch (that model's
//!   artifact batch size) dispatches immediately; otherwise a batch
//!   dispatches the moment its oldest request has waited `max_wait`.
//!   Deadline-expired batches bypass the weighted-scheduling deficit, so
//!   `max_wait` is a latency promise no weight assignment can starve.
//! * **Weighted sharing** — under saturation the WDRR policy serves models
//!   proportionally to their registry weights; the FIFO policy serves the
//!   globally-oldest request first.
//! * **Shared weights** — each worker holds an [`Engine::replicate`] clone
//!   of every registered model: one `Arc`-held parameter set per model,
//!   n:m:g conversion done once per model, zero weight bytes copied per
//!   forward. Kernel parallelism is divided among the workers via
//!   [`crate::util::threadpool::register_kernel_users`] (one registration
//!   for the whole server, W workers), so the worker pool never
//!   oversubscribes the host regardless of how many models it serves.
//! * **De-contended completion** — each worker records results in its own
//!   buffer; snapshots merge by cloning, `finish` drains the buffers
//!   without cloning. The scheduler mutex is held only for queue surgery
//!   (shed/form/enqueue), never across a forward.
//! * **Metrics** — per-request records carry model and batch ids;
//!   [`ServeReport`] summarizes p50/p95/p99 latency, SLO-miss fractions,
//!   goodput, shed/reject/degrade counts and queue high-water marks
//!   globally and per model.

use std::fmt;
use std::time::{Duration, Instant};

use crate::util::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use crate::util::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, bail, Result};

use crate::runtime::ArtifactRuntime;
use crate::util::channel::{self, TrySendError};
use crate::util::threadpool::{self, WorkerPool};
use crate::util::timer::TimeBreakdown;

use super::engine::{EncoderDims, Engine};
use super::metrics::{self, LatencySummary, ModelMetrics, QueueGauge};
use super::registry::ModelRegistry;
use super::scheduler::{self, Decision, SchedModel, SchedPolicy, Scheduler};
use super::serve::{canonical_tokens, pad_batch_tokens, Request, RequestResult};
use super::shard::ShardedModel;

/// EWMA smoothing for the per-model service-time estimate: each new
/// observation contributes 20%, so the estimate tracks drift in a few
/// dozen batches without whipsawing on one outlier.
const SVC_EWMA_ALPHA: f64 = 0.2;

/// Configuration for [`ConcurrentServer`].
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Engine replicas (worker threads) for the single-model
    /// [`ConcurrentServer::start`] path. The registry path ignores this:
    /// there, each model's registered replica count contributes workers.
    pub replicas: usize,
    /// Submission queue bound, global across models; `submit` blocks past
    /// this depth. The scheduler's forming queues are additionally bounded
    /// by `max(queue_cap, largest model batch)`.
    pub queue_cap: usize,
    /// Max time a request may wait for batch-mates before its (possibly
    /// partial) batch is dispatched.
    pub max_wait: Duration,
    /// Batch-formation policy across models.
    pub policy: SchedPolicy,
    /// End-to-end latency objective judged against each request's
    /// `total_s`. Always reported as SLO-miss fractions and goodput; with
    /// `admission`/`shed` enabled it also drives reject/degrade/shed
    /// decisions.
    pub slo: Duration,
    /// Enable admission control: predict queue wait at submit time from
    /// the per-model service-time EWMA, and degrade (or reject) requests
    /// whose prediction blows the SLO. Off by default: an unloaded server
    /// admits everything either way, and tests exercising raw queue
    /// mechanics want no admission interference.
    pub admission: bool,
    /// Enable load shedding: drop queue entries that have already
    /// outlived the SLO before forming batches. Off by default — with
    /// `max_wait` larger than `slo`, shedding would drop lone requests
    /// that deadline batching is deliberately holding.
    pub shed: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            replicas: 2,
            queue_cap: 256,
            max_wait: Duration::from_millis(2),
            policy: SchedPolicy::Fifo,
            slo: Duration::from_millis(25),
            admission: false,
            shed: false,
        }
    }
}

/// Typed rejection from the submit paths. Non-exhaustive: overload
/// handling grows outcomes (`Rejected`, `QueueFull`), and downstream
/// matches must not break when it does.
#[derive(Debug, Clone, PartialEq, Eq)]
#[non_exhaustive]
pub enum SubmitError {
    /// The model name is not in the server's registry.
    UnknownModel(String),
    /// The server no longer accepts requests.
    ShutDown,
    /// Admission control predicted `predicted` of queue-plus-service
    /// delay — past the SLO — and no registered degrade target could
    /// absorb the request either.
    Rejected {
        /// The predicted end-to-end delay that triggered the rejection.
        predicted: Duration,
    },
    /// Non-blocking submit ([`ConcurrentServer::try_submit_to`]) found
    /// the submission queue at capacity.
    QueueFull,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::UnknownModel(name) => write!(f, "unknown model {name:?}"),
            SubmitError::ShutDown => write!(f, "server is shut down"),
            SubmitError::Rejected { predicted } => {
                let ms = predicted.as_secs_f64() * 1e3;
                write!(f, "rejected: predicted wait {ms:.1}ms past SLO")
            }
            SubmitError::QueueFull => write!(f, "submission queue full"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Condvar-guarded completion counter: workers [`account`] finished
/// requests, drainers [`wait`] for a submission-count target.
///
/// Extracted from [`ConcurrentServer`]'s shared state so the loom lane
/// (`tests/loom.rs`) can model-check the accounting protocol directly:
/// the counter bump and the wakeup must be indivisible enough that a
/// drain racing the final completion can never sleep through it.
///
/// [`account`]: CompletionLatch::account
/// [`wait`]: CompletionLatch::wait
pub struct CompletionLatch {
    /// The mutex exists for the condvar; the critical section is a bare
    /// counter bump.
    finished: Mutex<u64>,
    done_cv: Condvar,
}

impl CompletionLatch {
    /// New latch with nothing accounted.
    pub fn new() -> Self {
        CompletionLatch { finished: Mutex::new(0), done_cv: Condvar::new() }
    }

    /// Mark `n` requests accounted for and wake any waiting drainer.
    pub fn account(&self, n: u64) {
        let mut fin = self.finished.lock().unwrap();
        *fin += n;
        drop(fin);
        self.done_cv.notify_all();
    }

    /// Requests accounted for so far.
    pub fn count(&self) -> u64 {
        *self.finished.lock().unwrap()
    }

    /// Block until at least `target` requests have been accounted for.
    pub fn wait(&self, target: u64) {
        let mut fin = self.finished.lock().unwrap();
        while *fin < target {
            fin = self.done_cv.wait(fin).unwrap();
        }
    }
}

impl Default for CompletionLatch {
    fn default() -> Self {
        Self::new()
    }
}

/// A batch a worker formed for itself, about to execute.
struct Batch {
    id: u64,
    model: usize,
    formed: Instant,
    requests: Vec<Request>,
}

/// One registered model as a worker sees it.
enum WorkerModel {
    /// The worker's private [`Engine::replicate`] clone (weights
    /// `Arc`-shared with every other replica).
    Own(Engine),
    /// A handle on the model's shared tensor-parallel instance set:
    /// batches round-robin across instances and each batch executes
    /// cooperatively on that instance's dedicated shard threads.
    Sharded(Arc<ShardedSet>),
}

/// The tensor-parallel instances of one registered model (`replicas`
/// instances, each with its own shard-thread pool and collective group,
/// weight slices `Arc`-shared). Shared by every worker: a sharded batch is
/// executed by whichever instance round-robin assigns, regardless of which
/// worker formed it.
struct ShardedSet {
    instances: Vec<Mutex<ShardedModel>>,
    next: AtomicUsize,
}

impl ShardedSet {
    /// Execute one padded batch on the next instance in round-robin order.
    /// Holding the instance lock across the forward is the intended
    /// serialization: an instance runs one cooperative batch at a time.
    fn forward(&self, requests: &[Request]) -> crate::tensor::DenseTensor {
        let i = self.next.fetch_add(1, Ordering::SeqCst) % self.instances.len();
        let mut inst = self.instances[i].lock().unwrap();
        let tokens = pad_batch_tokens(inst.dims(), requests);
        inst.forward(&tokens)
    }
}

/// The scheduler plus the ingest state it is driven under. One mutex:
/// every queue decision (enqueue, shed, form) is a pure function of this
/// state and a timestamp.
struct SchedState {
    sched: Box<dyn Scheduler>,
    /// False once the submission queue has closed and drained: pollers
    /// then dispatch partial batches immediately instead of waiting for
    /// batch-mates that can no longer arrive.
    open: bool,
}

/// State shared by submitters, the ingester and the workers.
struct Shared {
    /// The forming queues; workers pull batches out of it directly.
    sched: Mutex<SchedState>,
    /// Signals queued work (or closure) to parked workers.
    work_cv: Condvar,
    /// Signals freed forming-queue space (dispatch or shed) to the
    /// ingester.
    space_cv: Condvar,
    /// Forming-queue bound the ingester enforces.
    forming_cap: usize,
    /// One completion buffer per worker. Each worker appends only to its
    /// own slot, so the result-recording hot path never contends with other
    /// workers; snapshots merge the buffers by cloning, `finish` drains
    /// them.
    worker_results: Vec<Mutex<Vec<RequestResult>>>,
    /// Batch/worker failures (rare path; a plain shared lock is fine).
    errors: Mutex<Vec<String>>,
    /// Requests accounted for (completed, failed, or shed).
    latch: CompletionLatch,
    gauge: QueueGauge,
    /// Per-model queue gauges, indexed by registry order. Admission
    /// control reads these as the live backlog estimate.
    model_gauges: Vec<QueueGauge>,
    /// Per-model EWMA of observed per-request service time, stored as
    /// `f64::to_bits` (0 = no observation yet, which predicts zero wait:
    /// everything is admitted until the first completion calibrates it).
    svc_ewma: Vec<AtomicU64>,
    /// Per-model count of queue entries dropped past their SLO.
    shed: Vec<AtomicU64>,
    /// Per-model count of submissions rejected by admission control
    /// (indexed by the model the client asked for).
    rejected: Vec<AtomicU64>,
    /// Per-model count of submissions degraded to the sparse variant
    /// (indexed by the model the client asked for, not the target).
    degraded: Vec<AtomicU64>,
    batches: AtomicU64,
}

impl Shared {
    /// Mark `n` requests accounted for and wake any drainer.
    fn account(&self, n: u64) {
        self.latch.account(n);
    }

    /// A request left the queues (dispatched, shed, or failed).
    fn exit_queues(&self, model: usize, n: usize) {
        self.gauge.exit(n);
        self.model_gauges[model].exit(n);
    }

    /// Current service-time estimate for `model`, seconds per request
    /// (0.0 until the first batch of that model completes).
    fn svc_estimate(&self, model: usize) -> f64 {
        f64::from_bits(self.svc_ewma[model].load(Ordering::SeqCst))
    }

    /// Fold one observed per-request service time into `model`'s EWMA.
    fn observe_svc(&self, model: usize, obs: f64) {
        let cell = &self.svc_ewma[model];
        let mut cur = cell.load(Ordering::SeqCst);
        loop {
            let new = if cur == 0 {
                obs
            } else {
                (1.0 - SVC_EWMA_ALPHA) * f64::from_bits(cur) + SVC_EWMA_ALPHA * obs
            };
            match cell.compare_exchange(cur, new.to_bits(), Ordering::SeqCst, Ordering::SeqCst) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Merge all per-worker buffers into one id-ordered result vector,
    /// leaving the buffers intact (mid-run snapshots).
    fn merged_results(&self) -> Vec<RequestResult> {
        let mut out = Vec::new();
        for buf in &self.worker_results {
            out.extend(buf.lock().unwrap().iter().cloned());
        }
        out.sort_by_key(|r| r.id);
        out
    }

    /// Drain all per-worker buffers into one id-ordered result vector
    /// without cloning any record (the `finish` path: workers are done).
    fn drain_results(&self) -> Vec<RequestResult> {
        let mut out = Vec::new();
        for buf in &self.worker_results {
            out.append(&mut buf.lock().unwrap());
        }
        out.sort_by_key(|r| r.id);
        out
    }
}

/// Per-model slice of the final report.
#[derive(Debug)]
pub struct ModelReport {
    /// Registered model name.
    pub name: String,
    /// Latency / SLO / batch rollup for this model's requests.
    pub metrics: ModelMetrics,
    /// Deepest this model's share of the submission queue has been.
    pub queue_high_water: usize,
    /// Queue entries for this model dropped past their SLO.
    pub shed: u64,
    /// Submissions naming this model rejected by admission control.
    pub rejected: u64,
    /// Submissions naming this model degraded to its sparse variant
    /// (their completions are accounted under the target model).
    pub degraded: u64,
}

/// Final report returned by [`ConcurrentServer::finish`].
#[derive(Debug)]
pub struct ServeReport {
    /// One record per completed request.
    pub results: Vec<RequestResult>,
    /// p50/p95/p99 end-to-end latency summary over all models.
    pub latency: Option<LatencySummary>,
    /// Fraction of all requests that exceeded `ServeConfig::slo`.
    pub slo_miss: Option<f64>,
    /// Per-model reports, in registry order.
    pub per_model: Vec<ModelReport>,
    /// Batches dispatched.
    pub batches: u64,
    /// Server lifetime, start -> finish.
    pub wall_s: f64,
    /// Requests per second of wall-clock server lifetime.
    pub wall_rps: f64,
    /// In-SLO completions per second of wall-clock server lifetime: the
    /// overload figure of merit (see [`metrics::goodput`]).
    pub goodput_rps: f64,
    /// Requests per second of (batch-deduplicated) compute time.
    pub compute_rps: Option<f64>,
    /// Queue entries dropped past their SLO, all models.
    pub shed: u64,
    /// Submissions rejected by admission control, all models.
    pub rejected: u64,
    /// Submissions degraded to a sparse variant, all models.
    pub degraded: u64,
    /// Deepest the submission queue has been (all models).
    pub queue_high_water: usize,
    /// Per-worker runtime timing views (`execute`/`transfer`/`compile`
    /// buckets charged by each worker thread), indexed by worker id.
    pub replica_timing: Vec<TimeBreakdown>,
    /// Per-rank timing for every tensor-parallel model (empty when no
    /// registered model declared `shards > 1`).
    pub shard_timing: Vec<ShardTiming>,
}

/// Per-rank timing rollup for one tensor-parallel model: rank `r`'s
/// breakdown merged across all of the model's instances.
#[derive(Debug)]
pub struct ShardTiming {
    /// Registered model name.
    pub model: String,
    /// Shard count (ranks per instance).
    pub shards: usize,
    /// Merged per-rank breakdowns: `compute` (local kernels),
    /// `collective` (ring steps incl. barrier waits), `cpu` (thread CPU
    /// time, Linux only).
    pub per_rank: Vec<TimeBreakdown>,
}

/// The concurrent, deadline-aware, multi-model batch server.
pub struct ConcurrentServer {
    names: Vec<String>,
    dims: Vec<EncoderDims>,
    /// Admission-control degrade target per model (registry order).
    degrade_idx: Vec<Option<usize>>,
    slo: Duration,
    admission: bool,
    submit_tx: Option<channel::Sender<Request>>,
    pool: Option<WorkerPool>,
    shared: Arc<Shared>,
    /// Tensor-parallel instance sets, indexed by model (None = unsharded).
    /// Kept for the post-join shard-timing rollup in [`Self::finish`].
    sharded: Vec<Option<Arc<ShardedSet>>>,
    /// The workers' shared artifact runtime (for per-worker timing views).
    rt: Arc<ArtifactRuntime>,
    workers: usize,
    next_id: AtomicU64,
    submitted: AtomicU64,
    started: Instant,
    /// Divides the global kernel pool among this server's workers for the
    /// server's lifetime (released on drop; a new server re-registers its
    /// own worker count, so kernel budgets follow replica assignment).
    _kernel_users: threadpool::KernelUsersGuard,
}

impl ConcurrentServer {
    /// Start a single-model server: replicates `engine` per `cfg.replicas`
    /// (sharing its weights) under the model name `"default"`. This is the
    /// pre-registry entry point; with the (default) FIFO policy its batch
    /// formation is identical to the old single-queue batcher.
    pub fn start(engine: Engine, cfg: ServeConfig) -> Result<Self> {
        if cfg.replicas == 0 {
            bail!("ServeConfig.replicas must be at least 1");
        }
        let mut registry = ModelRegistry::new();
        registry.register("default", engine, cfg.replicas, 1)?;
        Self::start_registry(registry, cfg)
    }

    /// Start serving every model in `registry` behind one front-end: one
    /// shared scheduler (per `cfg.policy`), one ingester thread feeding it,
    /// and a pool of `registry.total_replicas()` workers that pull batches
    /// from it continuously, each holding a replica of every model so it
    /// can execute whichever model's batch it forms next.
    pub fn start_registry(registry: ModelRegistry, cfg: ServeConfig) -> Result<Self> {
        if registry.is_empty() {
            bail!("model registry has no models");
        }
        let entries = registry.into_entries();
        let names: Vec<String> = entries.iter().map(|m| m.name.clone()).collect();
        let dims: Vec<EncoderDims> = entries.iter().map(|m| m.engine.dims.clone()).collect();
        let mut degrade_idx = Vec::with_capacity(entries.len());
        for m in &entries {
            degrade_idx.push(match &m.degrade_to {
                None => None,
                Some(t) => match names.iter().position(|n| n == t) {
                    Some(i) => Some(i),
                    None => bail!("model {:?}: degrade target {t:?} is not registered", m.name),
                },
            });
        }
        let rt = Arc::clone(entries[0].engine.runtime());
        // Per-worker timing views (and the compile-once guarantee) are read
        // from one runtime; engines built over separate runtimes would
        // silently charge their artifact time elsewhere. Require sharing
        // (build registry engines with `Engine::with_runtime`).
        if let Some(stray) = entries.iter().find(|m| !Arc::ptr_eq(m.engine.runtime(), &rt)) {
            bail!(
                "model {:?} uses a different ArtifactRuntime than {:?}; registry engines \
                 must share one runtime (build them with Engine::with_runtime)",
                stray.name,
                entries[0].name
            );
        }
        let workers: usize = entries.iter().map(|m| m.replicas).sum();
        let sched_models: Vec<SchedModel> = entries
            .iter()
            .map(|m| SchedModel { batch: m.engine.dims.batch, weight: m.weight })
            .collect();
        let sched = scheduler::make(cfg.policy, sched_models, cfg.max_wait);
        // The forming queues must hold at least one full batch of the
        // largest model or full batches could never form under a tiny
        // queue_cap; beyond that, queue_cap bounds total in-flight work.
        let max_batch = entries.iter().map(|m| m.engine.dims.batch).max().unwrap_or(1);
        let forming_cap = cfg.queue_cap.max(1).max(max_batch);

        // Tensor-parallel models: one shared set of `replicas` sharded
        // instances per model (weight slices computed once, Arc-shared
        // across instances via ShardedModel::replicate).
        let mut sharded: Vec<Option<Arc<ShardedSet>>> = Vec::with_capacity(entries.len());
        for m in &entries {
            sharded.push(if m.shards > 1 {
                let proto = m.engine.shard(m.shards)?;
                let mut instances: Vec<Mutex<ShardedModel>> =
                    (1..m.replicas).map(|_| Mutex::new(proto.replicate())).collect();
                instances.insert(0, Mutex::new(proto));
                Some(Arc::new(ShardedSet { instances, next: AtomicUsize::new(0) }))
            } else {
                None
            });
        }

        // One model set per worker: a private replica of every unsharded
        // model (Arc-shared weights), a shared handle on every sharded one.
        let worker_models: Vec<Vec<WorkerModel>> = (0..workers)
            .map(|_| {
                entries
                    .iter()
                    .zip(&sharded)
                    .map(|(m, set)| match set {
                        Some(s) => WorkerModel::Sharded(Arc::clone(s)),
                        None => WorkerModel::Own(m.engine.replicate()),
                    })
                    .collect()
            })
            .collect();

        // Kernel budgets follow compute threads: a sharded model's replica
        // runs its batch on `shards` dedicated threads, not on the worker.
        let kernel_users: usize = entries.iter().map(|m| m.replicas * m.shards).sum();

        let shared = Arc::new(Shared {
            sched: Mutex::new(SchedState { sched, open: true }),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            forming_cap,
            worker_results: (0..workers).map(|_| Mutex::new(Vec::new())).collect(),
            errors: Mutex::new(Vec::new()),
            latch: CompletionLatch::new(),
            gauge: QueueGauge::new(),
            model_gauges: (0..names.len()).map(|_| QueueGauge::new()).collect(),
            svc_ewma: (0..names.len()).map(|_| AtomicU64::new(0)).collect(),
            shed: (0..names.len()).map(|_| AtomicU64::new(0)).collect(),
            rejected: (0..names.len()).map(|_| AtomicU64::new(0)).collect(),
            degraded: (0..names.len()).map(|_| AtomicU64::new(0)).collect(),
            batches: AtomicU64::new(0),
        });

        let (submit_tx, submit_rx) = channel::bounded::<Request>(cfg.queue_cap.max(1));
        let pool = WorkerPool::named("sten-serve", workers + 1);

        // The ingester: moves arrivals from the submission channel into the
        // scheduler's forming queues, parking when the queues are at
        // forming_cap (a dispatch or shed frees space and signals space_cv
        // — liveness holds because any nonempty queue dispatches within
        // max_wait). On channel closure it flips `open` so pollers drain.
        {
            let shared = shared.clone();
            pool.execute(move || {
                while let Some(r) = submit_rx.recv() {
                    let mut st = shared.sched.lock().unwrap();
                    while st.sched.pending() >= shared.forming_cap {
                        st = shared.space_cv.wait(st).unwrap();
                    }
                    st.sched.enqueue(r);
                    drop(st);
                    shared.work_cv.notify_one();
                }
                shared.sched.lock().unwrap().open = false;
                shared.work_cv.notify_all();
            });
        }

        // The workers: continuous batching. Each worker, the moment it is
        // free, sheds expired entries, asks the scheduler for a batch
        // formed from everything queued *now*, and executes it on its own
        // engine replicas — so a slow batch stalls one worker, never the
        // queues.
        let slo = cfg.slo;
        let shed_enabled = cfg.shed;
        for (worker_idx, mut models) in worker_models.into_iter().enumerate() {
            let shared = shared.clone();
            pool.execute(move || {
                // Tag this worker thread so the shared runtime charges its
                // artifact time to this worker's timing view.
                crate::runtime::set_replica_id(Some(worker_idx as u64));
                let mut st = shared.sched.lock().unwrap();
                loop {
                    // Load shedding: entries older than the SLO are already
                    // guaranteed misses — drop them before they cost a
                    // batch slot. (checked_sub: very early in process life
                    // Instant cannot go back by `slo`; nothing can have
                    // expired then either.)
                    if shed_enabled {
                        if let Some(cutoff) = Instant::now().checked_sub(slo) {
                            let dropped = st.sched.shed_expired(cutoff);
                            if !dropped.is_empty() {
                                for r in &dropped {
                                    shared.exit_queues(r.model, 1);
                                    shared.shed[r.model].fetch_add(1, Ordering::SeqCst);
                                }
                                shared.space_cv.notify_all();
                                shared.account(dropped.len() as u64);
                            }
                        }
                    }
                    match st.sched.poll(Instant::now(), st.open) {
                        Decision::Dispatch(formed) => {
                            shared.exit_queues(formed.model, formed.requests.len());
                            shared.batches.fetch_add(1, Ordering::SeqCst);
                            shared.space_cv.notify_all();
                            drop(st);
                            let batch = Batch {
                                id: formed.id,
                                model: formed.model,
                                formed: Instant::now(),
                                requests: formed.requests,
                            };
                            Self::execute_batch(&shared, &mut models, worker_idx, batch);
                            st = shared.sched.lock().unwrap();
                        }
                        Decision::WaitUntil(deadline) => {
                            let now = Instant::now();
                            if deadline <= now {
                                // The deadline lapsed between the poll's
                                // timestamp and now; re-poll dispatches it.
                                continue;
                            }
                            let (guard, _) =
                                shared.work_cv.wait_timeout(st, deadline - now).unwrap();
                            st = guard;
                        }
                        Decision::WaitForArrival => {
                            st = shared.work_cv.wait(st).unwrap();
                        }
                        Decision::Idle => break,
                    }
                }
                drop(st);
                // Wake sibling workers so they re-poll, see Idle and exit
                // too instead of parking forever on work_cv.
                shared.work_cv.notify_all();
                crate::runtime::set_replica_id(None);
            });
        }

        Ok(ConcurrentServer {
            names,
            dims,
            degrade_idx,
            slo: cfg.slo,
            admission: cfg.admission,
            submit_tx: Some(submit_tx),
            pool: Some(pool),
            shared,
            sharded,
            rt,
            workers,
            next_id: AtomicU64::new(0),
            submitted: AtomicU64::new(0),
            started: Instant::now(),
            _kernel_users: threadpool::register_kernel_users(kernel_users),
        })
    }

    /// Execute one formed batch on this worker's model set (its own engine
    /// replica, or the shared sharded instances) and record/account its
    /// results.
    fn execute_batch(
        shared: &Shared,
        models: &mut [WorkerModel],
        worker_idx: usize,
        batch: Batch,
    ) {
        let model = batch.model;
        let t = Instant::now();
        // A panicking forward (or pad) must not kill the worker: the
        // batch's requests would never be accounted and drain() would
        // hang. Weights are immutable, so continuing with this engine
        // after an unwind is safe.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            match &mut models[model] {
                WorkerModel::Own(engine) => {
                    let tokens = pad_batch_tokens(&engine.dims, &batch.requests);
                    engine.forward(&tokens)
                }
                WorkerModel::Sharded(set) => Ok(set.forward(&batch.requests)),
            }
        }))
        .unwrap_or_else(|_| Err(anyhow!("engine forward panicked")));
        let compute_s = t.elapsed().as_secs_f64();
        let done = Instant::now();
        match outcome {
            Ok(_) => {
                // Calibrate admission control: observed service time per
                // request of this batch.
                shared.observe_svc(model, compute_s / batch.requests.len().max(1) as f64);
                let mut buf = shared.worker_results[worker_idx].lock().unwrap();
                for r in &batch.requests {
                    buf.push(RequestResult {
                        id: r.id,
                        model,
                        batch_id: batch.id,
                        queue_s: batch.formed.saturating_duration_since(r.arrived).as_secs_f64(),
                        compute_s,
                        total_s: done.saturating_duration_since(r.arrived).as_secs_f64(),
                        batch_size: batch.requests.len(),
                    });
                }
            }
            Err(e) => {
                shared.errors.lock().unwrap().push(format!("batch {}: {e:#}", batch.id));
            }
        }
        shared.account(batch.requests.len() as u64);
    }

    /// Registered model names, in registry order.
    pub fn models(&self) -> &[String] {
        &self.names
    }

    /// Encoder dimensions of the first registered model (the only one on
    /// single-model servers).
    pub fn dims(&self) -> &EncoderDims {
        &self.dims[0]
    }

    /// Encoder dimensions of model `model` (registry order).
    pub fn dims_of(&self, model: usize) -> &EncoderDims {
        &self.dims[model]
    }

    /// Current admission-control service-time estimate for model `model`,
    /// seconds per request (0.0 until its first batch completes).
    pub fn service_estimate(&self, model: usize) -> f64 {
        self.shared.svc_estimate(model)
    }

    /// Predicted queue-plus-service delay for a request submitted to
    /// `model` right now: the backlog of every model weighted by its
    /// service estimate, divided across the workers, plus one service
    /// time of `model` itself. This is what admission control compares
    /// against the SLO.
    pub fn predicted_wait(&self, model: usize) -> Duration {
        Duration::from_secs_f64(self.predicted_wait_s(model))
    }

    fn predicted_wait_s(&self, model: usize) -> f64 {
        let backlog: f64 = (0..self.names.len())
            .map(|m| self.shared.model_gauges[m].depth() as f64 * self.shared.svc_estimate(m))
            .sum();
        backlog / self.workers as f64 + self.shared.svc_estimate(model)
    }

    /// Enqueue a request for the first registered model; blocks while the
    /// submission queue is at capacity. Returns the request id.
    pub fn submit(&self, tokens: &[i32]) -> Result<u64, SubmitError> {
        self.submit_inner(0, tokens, true)
    }

    /// Enqueue a request for the named model (tokens clamped/padded to that
    /// model's dims); blocks while the submission queue is at capacity.
    /// Returns [`SubmitError::UnknownModel`] for unregistered names, and —
    /// with admission control on — [`SubmitError::Rejected`] when the
    /// predicted wait blows the SLO and no degrade target can absorb it.
    pub fn submit_to(&self, model: &str, tokens: &[i32]) -> Result<u64, SubmitError> {
        self.submit_inner(self.model_idx(model)?, tokens, true)
    }

    /// Non-blocking [`Self::submit`]: a full submission queue returns
    /// [`SubmitError::QueueFull`] immediately instead of parking the
    /// caller. Open-loop load generators use this so saturation surfaces
    /// as accountable failures rather than silently stalling the arrival
    /// process (coordinated omission).
    pub fn try_submit(&self, tokens: &[i32]) -> Result<u64, SubmitError> {
        self.submit_inner(0, tokens, false)
    }

    /// Non-blocking [`Self::submit_to`]; see [`Self::try_submit`].
    pub fn try_submit_to(&self, model: &str, tokens: &[i32]) -> Result<u64, SubmitError> {
        self.submit_inner(self.model_idx(model)?, tokens, false)
    }

    fn model_idx(&self, model: &str) -> Result<usize, SubmitError> {
        self.names
            .iter()
            .position(|n| n == model)
            .ok_or_else(|| SubmitError::UnknownModel(model.to_string()))
    }

    fn submit_inner(
        &self,
        model: usize,
        tokens: &[i32],
        blocking: bool,
    ) -> Result<u64, SubmitError> {
        let mut target = model;
        let mut degraded = false;
        if self.admission {
            let slo_s = self.slo.as_secs_f64();
            let predicted = self.predicted_wait_s(model);
            if predicted > slo_s {
                // Try one degrade hop: the registered sparse variant, if
                // its own prediction fits the SLO.
                match self.degrade_idx[model] {
                    Some(d) if self.predicted_wait_s(d) <= slo_s => {
                        target = d;
                        degraded = true;
                    }
                    _ => {
                        self.shared.rejected[model].fetch_add(1, Ordering::SeqCst);
                        return Err(SubmitError::Rejected {
                            predicted: Duration::from_secs_f64(predicted),
                        });
                    }
                }
            }
        }
        let t = canonical_tokens(&self.dims[target], tokens);
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        self.shared.gauge.enter();
        self.shared.model_gauges[target].enter();
        let Some(tx) = self.submit_tx.as_ref() else {
            self.shared.exit_queues(target, 1);
            return Err(SubmitError::ShutDown);
        };
        let req = Request { id, tokens: t, model: target, arrived: Instant::now() };
        let sent: Result<(), SubmitError> = if blocking {
            tx.send(req).map_err(|_| SubmitError::ShutDown)
        } else {
            tx.try_send(req).map_err(|e| match e {
                TrySendError::Full(_) => SubmitError::QueueFull,
                TrySendError::Closed(_) => SubmitError::ShutDown,
            })
        };
        if let Err(e) = sent {
            self.shared.exit_queues(target, 1);
            return Err(e);
        }
        if degraded {
            self.shared.degraded[model].fetch_add(1, Ordering::SeqCst);
        }
        self.submitted.fetch_add(1, Ordering::SeqCst);
        Ok(id)
    }

    /// Requests currently waiting for batch formation (all models).
    pub fn queue_depth(&self) -> usize {
        self.shared.gauge.depth()
    }

    /// Requests currently waiting for batch formation for one model.
    pub fn queue_depth_of(&self, model: usize) -> usize {
        self.shared.model_gauges[model].depth()
    }

    /// Deepest the submission queue has been.
    pub fn queue_high_water(&self) -> usize {
        self.shared.gauge.high_water()
    }

    /// Completion records so far (snapshot, merged across worker buffers,
    /// ordered by request id).
    pub fn completed(&self) -> Vec<RequestResult> {
        self.shared.merged_results()
    }

    /// Block until every request submitted so far has completed, failed,
    /// or been shed.
    pub fn drain(&self) {
        let target = self.submitted.load(Ordering::SeqCst);
        self.shared.latch.wait(target);
    }

    /// Stop accepting requests, flush everything in flight, join all
    /// threads and return the final report. Fails if any batch errored.
    pub fn finish(mut self) -> Result<ServeReport> {
        self.submit_tx.take(); // closes the submission queue
        if let Some(pool) = self.pool.take() {
            pool.join();
        }
        let wall_s = self.started.elapsed().as_secs_f64();
        {
            let errors = self.shared.errors.lock().unwrap();
            if !errors.is_empty() {
                bail!("{} batch(es) failed; first: {}", errors.len(), errors[0]);
            }
        }
        // Workers are joined: drain their buffers instead of cloning every
        // record (clones are reserved for mid-run snapshots).
        let results = self.shared.drain_results();
        let latency = metrics::summarize(&results);
        let compute_rps = metrics::compute_throughput(&results);
        let slo_s = self.slo.as_secs_f64();
        let slo_miss = metrics::slo_miss_fraction(&results, slo_s);
        let counts = |v: &[AtomicU64]| -> Vec<u64> {
            v.iter().map(|c| c.load(Ordering::SeqCst)).collect()
        };
        let (shed, rejected, degraded) = (
            counts(&self.shared.shed),
            counts(&self.shared.rejected),
            counts(&self.shared.degraded),
        );
        let per_model = metrics::per_model(&results, self.names.len(), slo_s)
            .into_iter()
            .enumerate()
            .map(|(m, rollup)| ModelReport {
                name: self.names[m].clone(),
                metrics: rollup,
                queue_high_water: self.shared.model_gauges[m].high_water(),
                shed: shed[m],
                rejected: rejected[m],
                degraded: degraded[m],
            })
            .collect();
        let replica_timing =
            (0..self.workers as u64).map(|r| self.rt.timing_for_replica(r)).collect();
        // Per-rank shard timing, merged across each model's instances
        // (workers are joined, so the instance locks are uncontended).
        let mut shard_timing = Vec::new();
        for (m, set) in self.sharded.iter().enumerate() {
            let Some(set) = set else { continue };
            let mut per_rank: Vec<TimeBreakdown> = Vec::new();
            for inst in &set.instances {
                let inst = inst.lock().unwrap();
                for (r, t) in inst.shard_timing().iter().enumerate() {
                    if per_rank.len() <= r {
                        per_rank.push(TimeBreakdown::new());
                    }
                    per_rank[r].merge(t);
                }
            }
            shard_timing.push(ShardTiming {
                model: self.names[m].clone(),
                shards: per_rank.len(),
                per_rank,
            });
        }
        Ok(ServeReport {
            wall_rps: results.len() as f64 / wall_s.max(1e-12),
            goodput_rps: metrics::goodput(&results, slo_s, wall_s),
            latency,
            slo_miss,
            per_model,
            batches: self.shared.batches.load(Ordering::SeqCst),
            wall_s,
            compute_rps,
            shed: shed.iter().sum(),
            rejected: rejected.iter().sum(),
            degraded: degraded.iter().sum(),
            queue_high_water: self.shared.gauge.high_water(),
            replica_timing,
            shard_timing,
            results,
        })
    }
}

impl Drop for ConcurrentServer {
    fn drop(&mut self) {
        // Close the queue and join threads even when `finish` was skipped.
        self.submit_tx.take();
        self.pool.take(); // WorkerPool::drop joins
    }
}
