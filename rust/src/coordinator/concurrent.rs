//! The concurrent serving front-end: model registry, pluggable scheduler,
//! shared worker pool.
//!
//! Topology (all threads live on one [`WorkerPool`]):
//!
//! ```text
//! submit_to(model, ..) --bounded channel--> [batcher] --batch channel--> [worker 0..W)
//!   (backpressure: send blocks    |  drives a Scheduler:        each worker owns one
//!    when queue_cap is reached;   |  per-model forming queues,  Engine replica of
//!    per-model queue gauges)      |  FIFO-across-models or      EVERY model (weights
//!                                 |  weighted deficit RR,       Arc-shared per model),
//!                                 |  max_wait deadline batching |  executes whichever
//!                                 |                             |  model's batch arrives
//! ```
//!
//! Guarantees:
//!
//! * **Backpressure** — at most `queue_cap` requests are queued ahead of the
//!   batcher (global across models); further `submit` calls block. The
//!   scheduler's per-model forming queues stay small because the batcher
//!   dispatches every dispatchable batch before ingesting the next arrival.
//! * **Deadline batching** — per model: a full batch (that model's artifact
//!   batch size) dispatches immediately; otherwise a batch dispatches the
//!   moment its oldest request has waited `max_wait`. Deadline-expired
//!   batches bypass the weighted-scheduling deficit, so `max_wait` is a
//!   latency promise no weight assignment can starve.
//! * **Weighted sharing** — under saturation the WDRR policy serves models
//!   proportionally to their registry weights; the FIFO policy serves the
//!   globally-oldest request first and, with a single registered model,
//!   reproduces the pre-registry server's batch formation exactly.
//! * **Shared weights** — each worker holds an [`Engine::replicate`] clone
//!   of every registered model: one `Arc`-held parameter set per model,
//!   n:m:g conversion done once per model, zero weight bytes copied per
//!   forward. Kernel parallelism is divided among the workers via
//!   [`crate::util::threadpool::register_kernel_users`] (one registration
//!   for the whole server, W workers), so the worker pool never
//!   oversubscribes the host regardless of how many models it serves.
//! * **De-contended completion** — each worker records results in its own
//!   buffer; snapshots merge by cloning, `finish` drains the buffers
//!   without cloning. The only cross-worker critical section per batch is
//!   a counter bump under the completion condvar's mutex.
//! * **Metrics** — per-request records carry model and batch ids;
//!   [`ServeReport`] summarizes p50/p95/p99 latency, SLO-miss fractions
//!   and queue high-water marks globally and per model.

use std::fmt;
use std::time::{Duration, Instant};

use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, bail, Result};

use crate::runtime::ArtifactRuntime;
use crate::util::channel::{self, Received};
use crate::util::threadpool::{self, WorkerPool};
use crate::util::timer::TimeBreakdown;

use super::engine::{EncoderDims, Engine};
use super::metrics::{self, LatencySummary, ModelMetrics, QueueGauge};
use super::registry::ModelRegistry;
use super::scheduler::{self, Decision, SchedModel, SchedPolicy, Scheduler};
use super::serve::{canonical_tokens, pad_batch_tokens, Request, RequestResult};

/// Configuration for [`ConcurrentServer`].
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Engine replicas (worker threads) for the single-model
    /// [`ConcurrentServer::start`] path. The registry path ignores this:
    /// there, each model's registered replica count contributes workers.
    pub replicas: usize,
    /// Submission queue bound, global across models; `submit` blocks past
    /// this depth. Per-model forming queues inside the scheduler are not
    /// separately bounded — they hold less than one batch per model.
    pub queue_cap: usize,
    /// Max time a request may wait for batch-mates before its (possibly
    /// partial) batch is dispatched.
    pub max_wait: Duration,
    /// Batch-formation policy across models.
    pub policy: SchedPolicy,
    /// End-to-end latency objective judged against each request's
    /// `total_s`; reported as SLO-miss fractions, never enforced.
    pub slo: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            replicas: 2,
            queue_cap: 256,
            max_wait: Duration::from_millis(2),
            policy: SchedPolicy::Fifo,
            slo: Duration::from_millis(25),
        }
    }
}

/// Typed rejection from [`ConcurrentServer::submit_to`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// The model name is not in the server's registry.
    UnknownModel(String),
    /// The server no longer accepts requests.
    ShutDown,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::UnknownModel(name) => write!(f, "unknown model {name:?}"),
            SubmitError::ShutDown => write!(f, "server is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Condvar-guarded completion counter: workers [`account`] finished
/// requests, drainers [`wait`] for a submission-count target.
///
/// Extracted from [`ConcurrentServer`]'s shared state so the loom lane
/// (`tests/loom.rs`) can model-check the accounting protocol directly:
/// the counter bump and the wakeup must be indivisible enough that a
/// drain racing the final completion can never sleep through it.
///
/// [`account`]: CompletionLatch::account
/// [`wait`]: CompletionLatch::wait
pub struct CompletionLatch {
    /// The mutex exists for the condvar; the critical section is a bare
    /// counter bump.
    finished: Mutex<u64>,
    done_cv: Condvar,
}

impl CompletionLatch {
    /// New latch with nothing accounted.
    pub fn new() -> Self {
        CompletionLatch { finished: Mutex::new(0), done_cv: Condvar::new() }
    }

    /// Mark `n` requests accounted for and wake any waiting drainer.
    pub fn account(&self, n: u64) {
        let mut fin = self.finished.lock().unwrap();
        *fin += n;
        drop(fin);
        self.done_cv.notify_all();
    }

    /// Requests accounted for so far.
    pub fn count(&self) -> u64 {
        *self.finished.lock().unwrap()
    }

    /// Block until at least `target` requests have been accounted for.
    pub fn wait(&self, target: u64) {
        let mut fin = self.finished.lock().unwrap();
        while *fin < target {
            fin = self.done_cv.wait(fin).unwrap();
        }
    }
}

impl Default for CompletionLatch {
    fn default() -> Self {
        Self::new()
    }
}

/// A formed batch travelling from the batcher to a worker.
struct Batch {
    id: u64,
    model: usize,
    formed: Instant,
    requests: Vec<Request>,
}

/// State shared by submitters, the batcher and the workers.
struct Shared {
    /// One completion buffer per worker. Each worker appends only to its
    /// own slot, so the result-recording hot path never contends with other
    /// workers; snapshots merge the buffers by cloning, `finish` drains
    /// them.
    worker_results: Vec<Mutex<Vec<RequestResult>>>,
    /// Batch/batcher failures (rare path; a plain shared lock is fine).
    errors: Mutex<Vec<String>>,
    /// Requests accounted for (completed or failed).
    latch: CompletionLatch,
    gauge: QueueGauge,
    /// Per-model queue gauges, indexed by registry order.
    model_gauges: Vec<QueueGauge>,
    batches: AtomicU64,
}

impl Shared {
    /// Mark `n` requests accounted for and wake any drainer.
    fn account(&self, n: u64) {
        self.latch.account(n);
    }

    /// Record a failure covering `n` requests.
    fn fail(&self, n: u64, msg: String) {
        self.errors.lock().unwrap().push(msg);
        self.account(n);
    }

    /// A request left the queues (dispatched or failed).
    fn exit_queues(&self, model: usize, n: usize) {
        self.gauge.exit(n);
        self.model_gauges[model].exit(n);
    }

    /// Merge all per-worker buffers into one id-ordered result vector,
    /// leaving the buffers intact (mid-run snapshots).
    fn merged_results(&self) -> Vec<RequestResult> {
        let mut out = Vec::new();
        for buf in &self.worker_results {
            out.extend(buf.lock().unwrap().iter().cloned());
        }
        out.sort_by_key(|r| r.id);
        out
    }

    /// Drain all per-worker buffers into one id-ordered result vector
    /// without cloning any record (the `finish` path: workers are done).
    fn drain_results(&self) -> Vec<RequestResult> {
        let mut out = Vec::new();
        for buf in &self.worker_results {
            out.append(&mut buf.lock().unwrap());
        }
        out.sort_by_key(|r| r.id);
        out
    }
}

/// Per-model slice of the final report.
#[derive(Debug)]
pub struct ModelReport {
    /// Registered model name.
    pub name: String,
    /// Latency / SLO / batch rollup for this model's requests.
    pub metrics: ModelMetrics,
    /// Deepest this model's share of the submission queue has been.
    pub queue_high_water: usize,
}

/// Final report returned by [`ConcurrentServer::finish`].
#[derive(Debug)]
pub struct ServeReport {
    /// One record per completed request.
    pub results: Vec<RequestResult>,
    /// p50/p95/p99 end-to-end latency summary over all models.
    pub latency: Option<LatencySummary>,
    /// Fraction of all requests that exceeded `ServeConfig::slo`.
    pub slo_miss: Option<f64>,
    /// Per-model reports, in registry order.
    pub per_model: Vec<ModelReport>,
    /// Batches dispatched.
    pub batches: u64,
    /// Server lifetime, start -> finish.
    pub wall_s: f64,
    /// Requests per second of wall-clock server lifetime.
    pub wall_rps: f64,
    /// Requests per second of (batch-deduplicated) compute time.
    pub compute_rps: Option<f64>,
    /// Deepest the submission queue has been (all models).
    pub queue_high_water: usize,
    /// Per-worker runtime timing views (`execute`/`transfer`/`compile`
    /// buckets charged by each worker thread), indexed by worker id.
    pub replica_timing: Vec<TimeBreakdown>,
}

/// The concurrent, deadline-aware, multi-model batch server.
pub struct ConcurrentServer {
    names: Vec<String>,
    dims: Vec<EncoderDims>,
    slo: Duration,
    submit_tx: Option<channel::Sender<Request>>,
    pool: Option<WorkerPool>,
    shared: Arc<Shared>,
    /// The workers' shared artifact runtime (for per-worker timing views).
    rt: Arc<ArtifactRuntime>,
    workers: usize,
    next_id: AtomicU64,
    submitted: AtomicU64,
    started: Instant,
    /// Divides the global kernel pool among this server's workers for the
    /// server's lifetime (released on drop; a new server re-registers its
    /// own worker count, so kernel budgets follow replica assignment).
    _kernel_users: threadpool::KernelUsersGuard,
}

impl ConcurrentServer {
    /// Start a single-model server: replicates `engine` per `cfg.replicas`
    /// (sharing its weights) under the model name `"default"`. This is the
    /// pre-registry entry point; with the (default) FIFO policy its batch
    /// formation is identical to the old single-queue batcher.
    pub fn start(engine: Engine, cfg: ServeConfig) -> Result<Self> {
        if cfg.replicas == 0 {
            bail!("ServeConfig.replicas must be at least 1");
        }
        let mut registry = ModelRegistry::new();
        registry.register("default", engine, cfg.replicas, 1)?;
        Self::start_registry(registry, cfg)
    }

    /// Start serving every model in `registry` behind one front-end: one
    /// scheduler (per `cfg.policy`), one batcher thread, and a shared pool
    /// of `registry.total_replicas()` workers, each holding a replica of
    /// every model so it can execute whichever model's batch the scheduler
    /// forms next.
    pub fn start_registry(registry: ModelRegistry, cfg: ServeConfig) -> Result<Self> {
        if registry.is_empty() {
            bail!("model registry has no models");
        }
        let entries = registry.into_entries();
        let names: Vec<String> = entries.iter().map(|m| m.name.clone()).collect();
        let dims: Vec<EncoderDims> = entries.iter().map(|m| m.engine.dims.clone()).collect();
        let rt = Arc::clone(entries[0].engine.runtime());
        // Per-worker timing views (and the compile-once guarantee) are read
        // from one runtime; engines built over separate runtimes would
        // silently charge their artifact time elsewhere. Require sharing
        // (build registry engines with `Engine::with_runtime`).
        if let Some(stray) = entries.iter().find(|m| !Arc::ptr_eq(m.engine.runtime(), &rt)) {
            bail!(
                "model {:?} uses a different ArtifactRuntime than {:?}; registry engines \
                 must share one runtime (build them with Engine::with_runtime)",
                stray.name,
                entries[0].name
            );
        }
        let workers: usize = entries.iter().map(|m| m.replicas).sum();
        let sched_models: Vec<SchedModel> = entries
            .iter()
            .map(|m| SchedModel { batch: m.engine.dims.batch, weight: m.weight })
            .collect();
        let mut sched = scheduler::make(cfg.policy, sched_models, cfg.max_wait);

        // One replica set per worker: every model, Arc-shared weights.
        let worker_engines: Vec<Vec<Engine>> = (0..workers)
            .map(|_| entries.iter().map(|m| m.engine.replicate()).collect())
            .collect();

        let shared = Arc::new(Shared {
            worker_results: (0..workers).map(|_| Mutex::new(Vec::new())).collect(),
            errors: Mutex::new(Vec::new()),
            latch: CompletionLatch::new(),
            gauge: QueueGauge::new(),
            model_gauges: (0..names.len()).map(|_| QueueGauge::new()).collect(),
            batches: AtomicU64::new(0),
        });

        let (submit_tx, submit_rx) = channel::bounded::<Request>(cfg.queue_cap.max(1));
        let (batch_tx, batch_rx) = channel::bounded::<Batch>(workers * 2);
        let pool = WorkerPool::named("sten-serve", workers + 1);

        // The batcher: drives the scheduler over the arrival stream.
        {
            let shared = shared.clone();
            pool.execute(move || {
                let mut open = true;
                loop {
                    match sched.poll(Instant::now(), open) {
                        Decision::Dispatch(formed) => {
                            shared.exit_queues(formed.model, formed.requests.len());
                            shared.batches.fetch_add(1, Ordering::SeqCst);
                            let batch = Batch {
                                id: formed.id,
                                model: formed.model,
                                formed: Instant::now(),
                                requests: formed.requests,
                            };
                            if let Err(channel::SendError(batch)) = batch_tx.send(batch) {
                                // All workers are gone (e.g. panicked): fail
                                // this batch, everything still queued, and
                                // everything that arrives until the queue
                                // closes, so drain() and finish() never hang
                                // on requests nobody will execute.
                                shared.fail(
                                    batch.requests.len() as u64,
                                    format!("batch {}: no workers left", batch.id),
                                );
                                let stranded = sched.take_all();
                                if !stranded.is_empty() {
                                    for r in &stranded {
                                        shared.exit_queues(r.model, 1);
                                    }
                                    shared.fail(
                                        stranded.len() as u64,
                                        format!(
                                            "{} pending requests: no workers left",
                                            stranded.len()
                                        ),
                                    );
                                }
                                while let Some(r) = submit_rx.recv() {
                                    shared.exit_queues(r.model, 1);
                                    shared.fail(1, format!("request {}: no workers left", r.id));
                                }
                                break;
                            }
                        }
                        Decision::WaitUntil(deadline) => match submit_rx.recv_deadline(deadline) {
                            Received::Item(r) => sched.enqueue(r),
                            Received::TimedOut => {}
                            Received::Closed => open = false,
                        },
                        Decision::WaitForArrival => match submit_rx.recv() {
                            Some(r) => sched.enqueue(r),
                            None => open = false,
                        },
                        Decision::Idle => break,
                    }
                }
            });
        }

        // The workers: each holds one engine replica per model and executes
        // whatever the scheduler dispatched, recording results in a private
        // buffer so completion never contends.
        for (worker_idx, mut engines) in worker_engines.into_iter().enumerate() {
            let rx = batch_rx.clone();
            let shared = shared.clone();
            pool.execute(move || {
                // Tag this worker thread so the shared runtime charges its
                // artifact time to this worker's timing view.
                crate::runtime::set_replica_id(Some(worker_idx as u64));
                while let Some(batch) = rx.recv() {
                    let model = batch.model;
                    let tokens = pad_batch_tokens(&engines[model].dims, &batch.requests);
                    let t = Instant::now();
                    // A panicking forward must not kill the worker: the
                    // batch's requests would never be accounted and drain()
                    // would hang. Weights are immutable, so continuing with
                    // this engine after an unwind is safe.
                    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || engines[model].forward(&tokens),
                    ))
                    .unwrap_or_else(|_| Err(anyhow!("engine forward panicked")));
                    let compute_s = t.elapsed().as_secs_f64();
                    let done = Instant::now();
                    match outcome {
                        Ok(_) => {
                            let mut buf = shared.worker_results[worker_idx].lock().unwrap();
                            for r in &batch.requests {
                                buf.push(RequestResult {
                                    id: r.id,
                                    model,
                                    batch_id: batch.id,
                                    queue_s: batch
                                        .formed
                                        .saturating_duration_since(r.arrived)
                                        .as_secs_f64(),
                                    compute_s,
                                    total_s: done
                                        .saturating_duration_since(r.arrived)
                                        .as_secs_f64(),
                                    batch_size: batch.requests.len(),
                                });
                            }
                        }
                        Err(e) => {
                            shared.errors.lock().unwrap().push(format!("batch {}: {e:#}", batch.id))
                        }
                    }
                    shared.account(batch.requests.len() as u64);
                }
                crate::runtime::set_replica_id(None);
            });
        }
        drop(batch_rx);

        Ok(ConcurrentServer {
            names,
            dims,
            slo: cfg.slo,
            submit_tx: Some(submit_tx),
            pool: Some(pool),
            shared,
            rt,
            workers,
            next_id: AtomicU64::new(0),
            submitted: AtomicU64::new(0),
            started: Instant::now(),
            _kernel_users: threadpool::register_kernel_users(workers),
        })
    }

    /// Registered model names, in registry order.
    pub fn models(&self) -> &[String] {
        &self.names
    }

    /// Encoder dimensions of the first registered model (the only one on
    /// single-model servers).
    pub fn dims(&self) -> &EncoderDims {
        &self.dims[0]
    }

    /// Encoder dimensions of model `model` (registry order).
    pub fn dims_of(&self, model: usize) -> &EncoderDims {
        &self.dims[model]
    }

    /// Enqueue a request for the first registered model; blocks while the
    /// submission queue is at capacity. Returns the request id.
    pub fn submit(&self, tokens: &[i32]) -> Result<u64, SubmitError> {
        self.submit_idx(0, tokens)
    }

    /// Enqueue a request for the named model (tokens clamped/padded to that
    /// model's dims); blocks while the submission queue is at capacity.
    /// Returns [`SubmitError::UnknownModel`] for unregistered names.
    pub fn submit_to(&self, model: &str, tokens: &[i32]) -> Result<u64, SubmitError> {
        let idx = self
            .names
            .iter()
            .position(|n| n == model)
            .ok_or_else(|| SubmitError::UnknownModel(model.to_string()))?;
        self.submit_idx(idx, tokens)
    }

    fn submit_idx(&self, model: usize, tokens: &[i32]) -> Result<u64, SubmitError> {
        let t = canonical_tokens(&self.dims[model], tokens);
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        self.shared.gauge.enter();
        self.shared.model_gauges[model].enter();
        let Some(tx) = self.submit_tx.as_ref() else {
            self.shared.exit_queues(model, 1);
            return Err(SubmitError::ShutDown);
        };
        if tx.send(Request { id, tokens: t, model, arrived: Instant::now() }).is_err() {
            self.shared.exit_queues(model, 1);
            return Err(SubmitError::ShutDown);
        }
        self.submitted.fetch_add(1, Ordering::SeqCst);
        Ok(id)
    }

    /// Requests currently waiting for batch formation (all models).
    pub fn queue_depth(&self) -> usize {
        self.shared.gauge.depth()
    }

    /// Requests currently waiting for batch formation for one model.
    pub fn queue_depth_of(&self, model: usize) -> usize {
        self.shared.model_gauges[model].depth()
    }

    /// Deepest the submission queue has been.
    pub fn queue_high_water(&self) -> usize {
        self.shared.gauge.high_water()
    }

    /// Completion records so far (snapshot, merged across worker buffers,
    /// ordered by request id).
    pub fn completed(&self) -> Vec<RequestResult> {
        self.shared.merged_results()
    }

    /// Block until every request submitted so far has completed or failed.
    pub fn drain(&self) {
        let target = self.submitted.load(Ordering::SeqCst);
        self.shared.latch.wait(target);
    }

    /// Stop accepting requests, flush everything in flight, join all
    /// threads and return the final report. Fails if any batch errored.
    pub fn finish(mut self) -> Result<ServeReport> {
        self.submit_tx.take(); // closes the submission queue
        if let Some(pool) = self.pool.take() {
            pool.join();
        }
        let wall_s = self.started.elapsed().as_secs_f64();
        {
            let errors = self.shared.errors.lock().unwrap();
            if !errors.is_empty() {
                bail!("{} batch(es) failed; first: {}", errors.len(), errors[0]);
            }
        }
        // Workers are joined: drain their buffers instead of cloning every
        // record (clones are reserved for mid-run snapshots).
        let results = self.shared.drain_results();
        let latency = metrics::summarize(&results);
        let compute_rps = metrics::compute_throughput(&results);
        let slo_s = self.slo.as_secs_f64();
        let slo_miss = metrics::slo_miss_fraction(&results, slo_s);
        let per_model = metrics::per_model(&results, self.names.len(), slo_s)
            .into_iter()
            .enumerate()
            .map(|(m, rollup)| ModelReport {
                name: self.names[m].clone(),
                metrics: rollup,
                queue_high_water: self.shared.model_gauges[m].high_water(),
            })
            .collect();
        let replica_timing =
            (0..self.workers as u64).map(|r| self.rt.timing_for_replica(r)).collect();
        Ok(ServeReport {
            wall_rps: results.len() as f64 / wall_s.max(1e-12),
            latency,
            slo_miss,
            per_model,
            batches: self.shared.batches.load(Ordering::SeqCst),
            wall_s,
            compute_rps,
            queue_high_water: self.shared.gauge.high_water(),
            replica_timing,
            results,
        })
    }
}

impl Drop for ConcurrentServer {
    fn drop(&mut self) {
        // Close the queue and join threads even when `finish` was skipped.
        self.submit_tx.take();
        self.pool.take(); // WorkerPool::drop joins
    }
}
