//! The sparse-BERT inference engine.
//!
//! Weights live in Rust (so sparsifiers can transform them); attention /
//! embedding / LM-head blocks run through the PJRT runtime; the FFN — the
//! paper's sparse hot spot — runs either as a dense artifact or natively
//! via the n:m:g GEMM, selected by [`FfnMode`]. Latency is split into
//! `runtime` (PJRT execute), `native` (Rust kernels) and `framework`
//! (everything else: batching, transposes, dispatch) — the Fig. 11
//! STen-vs-runtime breakdown.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::formats::NmgTensor;
use crate::kernels::{dense_gemm, elementwise, nmg_gemm};
use crate::runtime::{ArtifactRuntime, Value};
use crate::tensor::DenseTensor;
use crate::util::rng::Pcg64;
use crate::util::timer::TimeBreakdown;

/// Encoder dimensions, read from the artifact manifest meta.
#[derive(Debug, Clone)]
pub struct EncoderDims {
    /// Vocabulary size.
    pub vocab: usize,
    /// Sequence length.
    pub seq: usize,
    /// Batch size (fixed at AOT time).
    pub batch: usize,
    /// Model width.
    pub d_model: usize,
    /// FFN width.
    pub d_ff: usize,
    /// Encoder layers.
    pub n_layers: usize,
}

/// How the FFN blocks execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FfnMode {
    /// PJRT dense artifact (the "dense PyTorch" baseline of Fig. 11).
    DenseArtifact,
    /// Native Rust dense GEMM (framework-overhead-free dense baseline).
    NativeDense,
    /// Native n:m:g sparse GEMM for the first FFN linear (the STen path).
    NativeNmg {
        /// Kept values per block.
        n: usize,
        /// Block size.
        m: usize,
        /// Group size.
        g: usize,
    },
}

/// The engine: runtime + weights + execution mode.
pub struct Engine {
    rt: ArtifactRuntime,
    tag: String,
    /// Encoder dimensions.
    pub dims: EncoderDims,
    params: BTreeMap<String, DenseTensor>,
    /// Pre-converted W1^T n:m:g weights per layer (NativeNmg mode).
    nmg_w1t: Vec<NmgTensor>,
    /// Execution mode for FFN blocks.
    pub ffn_mode: FfnMode,
    times: TimeBreakdown,
}

impl Engine {
    /// Build an engine over artifact set `tag` ("tiny"/"base") with random
    /// (deterministic) weights.
    pub fn new(rt: ArtifactRuntime, tag: &str, ffn_mode: FfnMode, seed: u64) -> Result<Self> {
        let spec = rt.spec(&format!("encoder_fwd_{tag}"))?.clone();
        let meta = &spec.meta;
        let dims = EncoderDims {
            vocab: meta.get("vocab").ok_or_else(|| anyhow!("meta.vocab"))?.usize()?,
            seq: meta.get("seq").ok_or_else(|| anyhow!("meta.seq"))?.usize()?,
            batch: meta.get("batch").ok_or_else(|| anyhow!("meta.batch"))?.usize()?,
            d_model: meta.get("d_model").ok_or_else(|| anyhow!("meta.d_model"))?.usize()?,
            d_ff: meta.get("d_ff").ok_or_else(|| anyhow!("meta.d_ff"))?.usize()?,
            n_layers: meta.get("n_layers").ok_or_else(|| anyhow!("meta.n_layers"))?.usize()?,
        };
        let mut rng = Pcg64::seeded(seed);
        let mut params = BTreeMap::new();
        for io in &spec.inputs {
            if io.name == "tokens" {
                continue;
            }
            let t = if io.name.ends_with("_g") {
                DenseTensor::ones(&io.shape)
            } else if io.shape.len() == 2 {
                let mut w = DenseTensor::randn(&io.shape, &mut rng);
                w.scale((2.0 / io.shape[0] as f32).sqrt() * 0.5);
                w
            } else {
                DenseTensor::zeros(&io.shape)
            };
            params.insert(io.name.clone(), t);
        }
        let mut engine = Engine {
            rt,
            tag: tag.to_string(),
            dims,
            params,
            nmg_w1t: Vec::new(),
            ffn_mode,
            times: TimeBreakdown::new(),
        };
        engine.set_ffn_mode(ffn_mode);
        Ok(engine)
    }

    /// Change the FFN execution mode (re-sparsifying weights as needed).
    ///
    /// In `NativeNmg` mode every layer's W1 is pruned into n:m:g — the
    /// engine thereafter *serves the pruned network*, exactly like loading
    /// a sparse checkpoint in STen.
    pub fn set_ffn_mode(&mut self, mode: FfnMode) {
        self.ffn_mode = mode;
        self.nmg_w1t.clear();
        if let FfnMode::NativeNmg { n, m, g } = mode {
            for l in 0..self.dims.n_layers {
                let w1 = &self.params[&format!("layer{l}.w1")];
                let w1t = w1.transpose2(); // (F, D)
                let nmg = NmgTensor::from_dense(&w1t, n, m, g);
                // Keep the served dense weights consistent with the pruned
                // sparse ones (weights are pruned, not approximated).
                self.params
                    .insert(format!("layer{l}.w1"), nmg.to_dense().transpose2());
                self.nmg_w1t.push(nmg);
            }
        }
    }

    /// Borrow a parameter.
    pub fn param(&self, name: &str) -> &DenseTensor {
        &self.params[name]
    }

    /// Accumulated timing (runtime / native / framework).
    pub fn timing(&self) -> &TimeBreakdown {
        &self.times
    }

    /// Reset timing.
    pub fn reset_timing(&mut self) {
        self.times = TimeBreakdown::new();
        self.rt.reset_timing();
    }

    fn p(&self, name: &str) -> Value {
        Value::F32(self.params[name].clone())
    }

    /// Full forward via the single whole-encoder artifact (baseline).
    pub fn forward_monolithic(&mut self, tokens: &[i32]) -> Result<DenseTensor> {
        let name = format!("encoder_fwd_{}", self.tag);
        let spec = self.rt.spec(&name)?.clone();
        let mut inputs = Vec::with_capacity(spec.inputs.len());
        for io in &spec.inputs {
            if io.name == "tokens" {
                inputs.push(Value::I32(io.shape.clone(), tokens.to_vec()));
            } else {
                inputs.push(self.p(&io.name));
            }
        }
        let t = Instant::now();
        let out = self.rt.call1(&name, &inputs)?;
        self.times.add("runtime", t.elapsed());
        Ok(out)
    }

    /// Block-composed forward: embed -> (attn, ffn)*L -> lm_head, with the
    /// FFN executed per `ffn_mode`.
    pub fn forward(&mut self, tokens: &[i32]) -> Result<DenseTensor> {
        let t_all = Instant::now();
        let tag = self.tag.clone();
        let dims = self.dims.clone();

        let t = Instant::now();
        let tok_shape = vec![dims.batch, dims.seq];
        let mut x = self.rt.call1(
            &format!("embed_{tag}"),
            &[self.p("emb"), self.p("pos"), Value::I32(tok_shape, tokens.to_vec())],
        )?;
        let mut runtime_s = t.elapsed();

        let mut native_s = std::time::Duration::ZERO;
        for l in 0..dims.n_layers {
            let pre = |s: &str| format!("layer{l}.{s}");
            let t = Instant::now();
            x = self.rt.call1(
                &format!("attn_block_{tag}"),
                &[
                    Value::F32(x),
                    self.p(&pre("ln1_g")), self.p(&pre("ln1_b")),
                    self.p(&pre("wq")), self.p(&pre("bq")),
                    self.p(&pre("wk")), self.p(&pre("bk")),
                    self.p(&pre("wv")), self.p(&pre("bv")),
                    self.p(&pre("wo")), self.p(&pre("bo")),
                ],
            )?;
            runtime_s += t.elapsed();

            match self.ffn_mode {
                FfnMode::DenseArtifact => {
                    let t = Instant::now();
                    x = self.rt.call1(
                        &format!("ffn_block_{tag}"),
                        &[
                            Value::F32(x),
                            self.p(&pre("ln2_g")), self.p(&pre("ln2_b")),
                            self.p(&pre("w1")), self.p(&pre("b1")),
                            self.p(&pre("w2")), self.p(&pre("b2")),
                        ],
                    )?;
                    runtime_s += t.elapsed();
                }
                FfnMode::NativeDense | FfnMode::NativeNmg { .. } => {
                    let t = Instant::now();
                    x = self.native_ffn(l, &x)?;
                    native_s += t.elapsed();
                }
            }
        }

        let t = Instant::now();
        let logits = self.rt.call1(
            &format!("lm_head_{tag}"),
            &[
                Value::F32(x),
                self.p("lnf_g"), self.p("lnf_b"),
                self.p("out_w"), self.p("out_b"),
            ],
        )?;
        runtime_s += t.elapsed();

        self.times.add("runtime", runtime_s);
        self.times.add("native", native_s);
        self.times
            .add("framework", t_all.elapsed().saturating_sub(runtime_s).saturating_sub(native_s));
        Ok(logits)
    }

    /// Native FFN block: LN -> (W1 sparse or dense) -> GeLU -> W2 -> residual.
    fn native_ffn(&self, l: usize, x: &DenseTensor) -> Result<DenseTensor> {
        let dims = &self.dims;
        let (b, s, d) = (dims.batch, dims.seq, dims.d_model);
        let rows = b * s;
        let x2 = x.reshape(&[rows, d]);
        let pre = |n: &str| format!("layer{l}.{n}");
        let ln_g = &self.params[&pre("ln2_g")];
        let ln_b = &self.params[&pre("ln2_b")];
        let y = elementwise::layernorm_rows(&x2, ln_g.data(), ln_b.data());

        let h = match self.ffn_mode {
            FfnMode::NativeNmg { .. } => {
                // (F, D) nmg @ (D, rows) -> (F, rows) -> transpose.
                let yt = y.transpose2();
                nmg_gemm::spmm(&self.nmg_w1t[l], &yt).transpose2()
            }
            _ => dense_gemm::matmul(&y, &self.params[&pre("w1")]),
        };
        let h = elementwise::bias_add(&h, self.params[&pre("b1")].data());
        let h = elementwise::gelu(&h);
        let out = dense_gemm::matmul(&h, &self.params[&pre("w2")]);
        let out = elementwise::bias_add(&out, self.params[&pre("b2")].data());
        Ok(x2.zip(&out, |a, c| a + c).reshape(&[b, s, d]))
    }

    /// Random valid tokens for smoke tests and benches.
    pub fn random_tokens(&self, rng: &mut Pcg64) -> Vec<i32> {
        (0..self.dims.batch * self.dims.seq)
            .map(|_| rng.below(self.dims.vocab as u32) as i32)
            .collect()
    }
}
