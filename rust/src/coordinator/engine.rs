//! The sparse-BERT inference engine.
//!
//! Weights live in Rust (so sparsifiers can transform them); attention /
//! embedding / LM-head blocks run through the artifact runtime; the FFN —
//! the paper's sparse hot spot — runs either as a dense artifact or natively
//! via the n:m:g GEMM, selected by [`FfnMode`]. Latency is split into
//! `runtime` (artifact execute), `native` (Rust kernels) and `framework`
//! (everything else: batching, transposes, dispatch) — the Fig. 11
//! STen-vs-runtime breakdown.
//!
//! # Replication
//!
//! Weights are held behind an `Arc` ([`Engine::replicate`]): the serving
//! layer runs N engine replicas on worker threads that all share one
//! parameter set and one pre-converted n:m:g weight set, so FFN weights are
//! sparsified exactly once per server no matter how many replicas serve
//! traffic. Replicas keep private timing state; the runtime (also `Arc`-
//! shared) aggregates its own buckets across replicas.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::formats::{AnyTensor, Layout, NmgTensor};
use crate::kernels::{dense_gemm, elementwise, nmg_gemm};
use crate::ops::OpKind;
use crate::runtime::{ArtifactRuntime, Value};
use crate::tensor::DenseTensor;
use crate::tune::{Autotuner, Decision};
use crate::util::rng::Pcg64;
use crate::util::timer::TimeBreakdown;

use super::shard::{SeamMode, ShardedModel};

/// Encoder dimensions, read from the artifact manifest meta.
#[derive(Debug, Clone)]
pub struct EncoderDims {
    /// Vocabulary size.
    pub vocab: usize,
    /// Sequence length.
    pub seq: usize,
    /// Batch size (fixed at AOT time).
    pub batch: usize,
    /// Model width.
    pub d_model: usize,
    /// FFN width.
    pub d_ff: usize,
    /// Encoder layers.
    pub n_layers: usize,
}

/// How the FFN blocks execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FfnMode {
    /// Dense artifact through the runtime (the "dense PyTorch" baseline of
    /// Fig. 11).
    DenseArtifact,
    /// Native Rust dense GEMM (framework-overhead-free dense baseline).
    NativeDense,
    /// Native n:m:g sparse GEMM for the first FFN linear (the STen path).
    NativeNmg {
        /// Kept values per block.
        n: usize,
        /// Block size.
        m: usize,
        /// Group size.
        g: usize,
    },
}

/// The immutable weight set shared across engine replicas.
///
/// Each parameter is itself `Arc`-held so [`Engine::p`] can hand tensors to
/// the artifact runtime as [`Value::F32`] handles without copying: every
/// artifact call on every replica shares the one weight allocation. Cloning
/// `EngineWeights` (the `Arc::make_mut` copy-on-write path of
/// [`Engine::set_ffn_mode`]) clones only the `Arc` handles; the parameters
/// that are then mutated get fresh allocations via `Arc::new`.
#[derive(Clone)]
struct EngineWeights {
    params: BTreeMap<String, Arc<DenseTensor>>,
    /// Pre-converted W1^T n:m:g weights per layer (NativeNmg mode).
    nmg_w1t: Vec<NmgTensor>,
    /// Autotuned W1^T per layer ([`Engine::autotune_ffn`]): each weight
    /// stored in the layout the tuner picked, dispatched as an exact
    /// phase-1 signature hit. Takes precedence over `nmg_w1t` when present.
    tuned_w1t: Vec<AnyTensor>,
}

/// The engine: runtime + shared weights + execution mode.
pub struct Engine {
    rt: Arc<ArtifactRuntime>,
    tag: String,
    /// Encoder dimensions.
    pub dims: EncoderDims,
    weights: Arc<EngineWeights>,
    /// Execution mode for FFN blocks. Mutating this field switches the
    /// kernel path without touching the (shared, possibly pruned) weights —
    /// useful to run the dense kernels over an already-pruned network. If
    /// n:m:g weights were never converted (the engine was not in `NativeNmg`
    /// mode), the native path falls back to the dense GEMM; use
    /// [`Engine::set_ffn_mode`] to actually (re-)sparsify.
    pub ffn_mode: FfnMode,
    times: TimeBreakdown,
}

impl Engine {
    /// Build an engine over artifact set `tag` ("tiny"/"base") with random
    /// (deterministic) weights.
    pub fn new(rt: ArtifactRuntime, tag: &str, ffn_mode: FfnMode, seed: u64) -> Result<Self> {
        Self::with_runtime(Arc::new(rt), tag, ffn_mode, seed)
    }

    /// Build an engine over a shared runtime (serving-layer entry point).
    pub fn with_runtime(
        rt: Arc<ArtifactRuntime>,
        tag: &str,
        ffn_mode: FfnMode,
        seed: u64,
    ) -> Result<Self> {
        let spec = rt.spec(&format!("encoder_fwd_{tag}"))?.clone();
        let meta = &spec.meta;
        let dims = EncoderDims {
            vocab: meta.get("vocab").ok_or_else(|| anyhow!("meta.vocab"))?.usize()?,
            seq: meta.get("seq").ok_or_else(|| anyhow!("meta.seq"))?.usize()?,
            batch: meta.get("batch").ok_or_else(|| anyhow!("meta.batch"))?.usize()?,
            d_model: meta.get("d_model").ok_or_else(|| anyhow!("meta.d_model"))?.usize()?,
            d_ff: meta.get("d_ff").ok_or_else(|| anyhow!("meta.d_ff"))?.usize()?,
            n_layers: meta.get("n_layers").ok_or_else(|| anyhow!("meta.n_layers"))?.usize()?,
        };
        let mut rng = Pcg64::seeded(seed);
        let mut params = BTreeMap::new();
        for io in &spec.inputs {
            if io.name == "tokens" {
                continue;
            }
            let t = if io.name.ends_with("_g") {
                DenseTensor::ones(&io.shape)
            } else if io.shape.len() == 2 {
                let mut w = DenseTensor::randn(&io.shape, &mut rng);
                w.scale((2.0 / io.shape[0] as f32).sqrt() * 0.5);
                w
            } else {
                DenseTensor::zeros(&io.shape)
            };
            params.insert(io.name.clone(), Arc::new(t));
        }
        let mut engine = Engine {
            rt,
            tag: tag.to_string(),
            dims,
            weights: Arc::new(EngineWeights {
                params,
                nmg_w1t: Vec::new(),
                tuned_w1t: Vec::new(),
            }),
            ffn_mode,
            times: TimeBreakdown::new(),
        };
        engine.set_ffn_mode(ffn_mode);
        Ok(engine)
    }

    /// A replica sharing this engine's runtime and (pruned) weights, with
    /// fresh timing state. Conversion to n:m:g is *not* repeated: replicas
    /// reference the same `Arc`-held weight set. Configure the FFN mode
    /// before replicating; replicas made earlier keep the old weights.
    pub fn replicate(&self) -> Engine {
        Engine {
            rt: self.rt.clone(),
            tag: self.tag.clone(),
            dims: self.dims.clone(),
            weights: self.weights.clone(),
            ffn_mode: self.ffn_mode,
            times: TimeBreakdown::new(),
        }
    }

    /// The shared artifact runtime.
    pub fn runtime(&self) -> &Arc<ArtifactRuntime> {
        &self.rt
    }

    /// Attention head count, read from the artifact spec meta (it is not
    /// part of [`EncoderDims`] because only attention-sharding needs it).
    pub fn n_heads(&self) -> Result<usize> {
        let spec = self.rt.spec(&format!("encoder_fwd_{}", self.tag))?;
        spec.meta.get("n_heads").ok_or_else(|| anyhow!("meta.n_heads"))?.usize()
    }

    /// Split this engine into a `world`-way tensor-parallel
    /// [`ShardedModel`]: attention sharded per head, FFN column-parallel
    /// for W1 (sparse formats sliced on slab/block boundaries) and
    /// row-parallel at the W2 seam, shards meeting at ring collectives.
    /// Dense sharded forwards are bit-identical to [`Engine::forward`];
    /// sparse modes are allclose. The engine itself is unchanged — weight
    /// slices are copies, replicated tensors `Arc`-shared.
    pub fn shard(&self, world: usize) -> Result<ShardedModel> {
        ShardedModel::from_engine(self, world, SeamMode::default())
    }

    /// [`Engine::shard`] with an explicit FFN W2 [`SeamMode`].
    pub fn shard_with_seam(&self, world: usize, seam: SeamMode) -> Result<ShardedModel> {
        ShardedModel::from_engine(self, world, seam)
    }

    /// Weight views for the sharder: parameters, pre-converted n:m:g W1^T
    /// and autotuned W1^T (same precedence as [`Engine::forward`]).
    pub(crate) fn weights_view(
        &self,
    ) -> (&BTreeMap<String, Arc<DenseTensor>>, &[NmgTensor], &[AnyTensor]) {
        (&self.weights.params, &self.weights.nmg_w1t, &self.weights.tuned_w1t)
    }

    /// True when two engines share one weight set (replicas of each other).
    pub fn shares_weights_with(&self, other: &Engine) -> bool {
        Arc::ptr_eq(&self.weights, &other.weights)
    }

    /// Change the FFN execution mode (re-sparsifying weights as needed).
    ///
    /// In `NativeNmg` mode every layer's W1 is pruned into n:m:g — the
    /// engine thereafter *serves the pruned network*, exactly like loading
    /// a sparse checkpoint in STen. When the weight set is shared with
    /// replicas, this engine gets a private copy (copy-on-write); replicas
    /// are unaffected.
    pub fn set_ffn_mode(&mut self, mode: FfnMode) {
        self.ffn_mode = mode;
        let n_layers = self.dims.n_layers;
        let w = Arc::make_mut(&mut self.weights);
        w.nmg_w1t.clear();
        // Tuned layouts were chosen for the previous mode's weights; drop
        // them (re-run autotune_ffn after a mode switch).
        w.tuned_w1t.clear();
        if let FfnMode::NativeNmg { n, m, g } = mode {
            for l in 0..n_layers {
                let key = format!("layer{l}.w1");
                let w1t = w.params[&key].transpose2(); // (F, D)
                let nmg = NmgTensor::from_dense(&w1t, n, m, g);
                // Keep the served dense weights consistent with the pruned
                // sparse ones (weights are pruned, not approximated).
                w.params.insert(key, Arc::new(nmg.to_dense().transpose2()));
                w.nmg_w1t.push(nmg);
            }
        }
    }

    /// Autotune the FFN W1 weights: for every layer, score each registered
    /// `(format, kernel)` matmul candidate under the tuner's policy, store
    /// W1^T in the winning layout, and route subsequent native FFN calls
    /// through the dispatcher (exact phase-1 hit, zero per-call tuning
    /// overhead). Decisions come from / go into the tuner's cache, so a
    /// second build of the same engine replays them without re-scoring.
    ///
    /// Call after [`Engine::set_ffn_mode`]: in `NativeNmg` mode the weights
    /// are already pruned, the n:m:g config becomes a tuning candidate, and
    /// re-materializing into n:m:g is lossless (same-format). When the
    /// weight set is shared with replicas this engine gets a private copy.
    pub fn autotune_ffn(&mut self, tuner: &mut Autotuner) -> Result<Vec<Decision>> {
        let n_layers = self.dims.n_layers;
        let ncols = self.dims.batch * self.dims.seq;
        let nmg = match self.ffn_mode {
            FfnMode::NativeNmg { n, m, g } => Some((n, m, g)),
            _ => None,
        };
        let d = crate::dispatch::global();
        let w = Arc::make_mut(&mut self.weights);
        w.tuned_w1t.clear();
        let mut decisions = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let key = format!("layer{l}.w1");
            let w1t = w.params[&key].transpose2(); // (F, D)
            let dec = tuner.choose(d, &w1t, ncols, nmg)?;
            let tuned = crate::tune::materialize(&w1t, dec.layout, nmg)?;
            if dec.layout == Layout::Nmg {
                // n:m:g re-prunes; keep the served dense weights consistent
                // (a no-op when set_ffn_mode already pruned them).
                w.params.insert(key, Arc::new(tuned.to_dense().transpose2()));
            }
            w.tuned_w1t.push(tuned);
            decisions.push(dec);
        }
        Ok(decisions)
    }

    /// Borrow a parameter.
    pub fn param(&self, name: &str) -> &DenseTensor {
        &self.weights.params[name]
    }

    /// Accumulated timing (runtime / native / framework).
    pub fn timing(&self) -> &TimeBreakdown {
        &self.times
    }

    /// Reset timing (including the shared runtime's buckets).
    pub fn reset_timing(&mut self) {
        self.times = TimeBreakdown::new();
        self.rt.reset_timing();
    }

    /// A parameter as a runtime [`Value`]: an `Arc` bump, never a tensor
    /// copy — the hot-path guarantee that makes replica weight sharing real
    /// on every artifact call.
    fn p(&self, name: &str) -> Value {
        Value::F32(Arc::clone(&self.weights.params[name]))
    }

    /// Full forward via the single whole-encoder artifact (baseline).
    pub fn forward_monolithic(&mut self, tokens: &[i32]) -> Result<DenseTensor> {
        let name = format!("encoder_fwd_{}", self.tag);
        let spec = self.rt.spec(&name)?.clone();
        let mut inputs = Vec::with_capacity(spec.inputs.len());
        for io in &spec.inputs {
            if io.name == "tokens" {
                inputs.push(Value::I32(io.shape.clone(), tokens.to_vec()));
            } else {
                inputs.push(self.p(&io.name));
            }
        }
        let t = Instant::now();
        let out = self.rt.call1(&name, &inputs)?;
        self.times.add("runtime", t.elapsed());
        Ok(out)
    }

    /// Block-composed forward: embed -> (attn, ffn)*L -> lm_head, with the
    /// FFN executed per `ffn_mode`.
    pub fn forward(&mut self, tokens: &[i32]) -> Result<DenseTensor> {
        let t_all = Instant::now();
        let tag = self.tag.clone();
        let dims = self.dims.clone();

        let t = Instant::now();
        let tok_shape = vec![dims.batch, dims.seq];
        let mut x = self.rt.call1(
            &format!("embed_{tag}"),
            &[self.p("emb"), self.p("pos"), Value::I32(tok_shape, tokens.to_vec())],
        )?;
        let mut runtime_s = t.elapsed();

        let mut native_s = std::time::Duration::ZERO;
        for l in 0..dims.n_layers {
            let pre = |s: &str| format!("layer{l}.{s}");
            let t = Instant::now();
            x = self.rt.call1(
                &format!("attn_block_{tag}"),
                &[
                    Value::from(x),
                    self.p(&pre("ln1_g")), self.p(&pre("ln1_b")),
                    self.p(&pre("wq")), self.p(&pre("bq")),
                    self.p(&pre("wk")), self.p(&pre("bk")),
                    self.p(&pre("wv")), self.p(&pre("bv")),
                    self.p(&pre("wo")), self.p(&pre("bo")),
                ],
            )?;
            runtime_s += t.elapsed();

            match self.ffn_mode {
                FfnMode::DenseArtifact => {
                    let t = Instant::now();
                    x = self.rt.call1(
                        &format!("ffn_block_{tag}"),
                        &[
                            Value::from(x),
                            self.p(&pre("ln2_g")), self.p(&pre("ln2_b")),
                            self.p(&pre("w1")), self.p(&pre("b1")),
                            self.p(&pre("w2")), self.p(&pre("b2")),
                        ],
                    )?;
                    runtime_s += t.elapsed();
                }
                FfnMode::NativeDense | FfnMode::NativeNmg { .. } => {
                    let t = Instant::now();
                    x = self.native_ffn(l, &x)?;
                    native_s += t.elapsed();
                }
            }
        }

        let t = Instant::now();
        let logits = self.rt.call1(
            &format!("lm_head_{tag}"),
            &[
                Value::from(x),
                self.p("lnf_g"), self.p("lnf_b"),
                self.p("out_w"), self.p("out_b"),
            ],
        )?;
        runtime_s += t.elapsed();

        self.times.add("runtime", runtime_s);
        self.times.add("native", native_s);
        self.times
            .add("framework", t_all.elapsed().saturating_sub(runtime_s).saturating_sub(native_s));
        Ok(logits)
    }

    /// Native FFN block: LN -> (W1 sparse or dense) -> GeLU -> W2 -> residual.
    fn native_ffn(&self, l: usize, x: &DenseTensor) -> Result<DenseTensor> {
        let dims = &self.dims;
        let (b, s, d) = (dims.batch, dims.seq, dims.d_model);
        let rows = b * s;
        let x2 = x.reshape(&[rows, d]);
        let pre = |n: &str| format!("layer{l}.{n}");
        let params = &self.weights.params;
        let ln_g = &params[&pre("ln2_g")];
        let ln_b = &params[&pre("ln2_b")];
        let y = elementwise::layernorm_rows(&x2, ln_g.data(), ln_b.data());

        // Precedence: autotuned layout (dispatcher route) > pre-converted
        // n:m:g > dense GEMM (the mode was switched by field mutation
        // rather than set_ffn_mode, so no converted weights exist).
        let nmg_w1t = match self.ffn_mode {
            FfnMode::NativeNmg { .. } => self.weights.nmg_w1t.get(l),
            _ => None,
        };
        let h = if let Some(w1t) = self.weights.tuned_w1t.get(l) {
            // (F, D) tuned @ (D, rows) -> (F, rows) -> transpose. The tuned
            // signature is registered, so this is an exact phase-1 hit.
            let yt = AnyTensor::Dense(y.transpose2());
            let out = crate::dispatch::global().call_ref(OpKind::MatMul, &[w1t, &yt])?;
            match out {
                AnyTensor::Dense(t) => t,
                other => other.to_dense(),
            }
            .transpose2()
        } else if let Some(w1t) = nmg_w1t {
            // (F, D) nmg @ (D, rows) -> (F, rows) -> transpose.
            let yt = y.transpose2();
            nmg_gemm::spmm(w1t, &yt).transpose2()
        } else {
            dense_gemm::matmul(&y, &params[&pre("w1")])
        };
        let h = elementwise::bias_add(&h, params[&pre("b1")].data());
        let h = elementwise::gelu(&h);
        let out = dense_gemm::matmul(&h, &params[&pre("w2")]);
        let out = elementwise::bias_add(&out, params[&pre("b2")].data());
        Ok(x2.zip(&out, |a, c| a + c).reshape(&[b, s, d]))
    }

    /// Random valid tokens for smoke tests and benches.
    pub fn random_tokens(&self, rng: &mut Pcg64) -> Vec<i32> {
        (0..self.dims.batch * self.dims.seq)
            .map(|_| rng.below(self.dims.vocab as u32) as i32)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_engine(mode: FfnMode) -> Engine {
        let rt = ArtifactRuntime::open(std::path::PathBuf::from("target/nonexistent-artifacts"))
            .unwrap();
        Engine::new(rt, "tiny", mode, 7).unwrap()
    }

    #[test]
    fn autotuned_ffn_matches_untuned_forward_and_replays_from_cache() {
        use crate::tune::{Autotuner, TunePolicy};
        let mut rng = Pcg64::seeded(5);
        let mut e = tiny_engine(FfnMode::NativeNmg { n: 2, m: 4, g: 2 });
        let tokens = e.random_tokens(&mut rng);
        let want = e.forward(&tokens).unwrap();

        let mut tuner = Autotuner::new(TunePolicy::CostModel);
        let decisions = e.autotune_ffn(&mut tuner).unwrap();
        assert_eq!(decisions.len(), e.dims.n_layers);
        assert!(tuner.misses >= 1, "fresh cache: at least the first layer is a miss");
        let got = e.forward(&tokens).unwrap();
        assert!(got.allclose(&want, 1e-4, 1e-4), "tuned forward must match untuned");

        // A second engine build with the same shapes and sparsity replays
        // every decision from the cache without re-scoring.
        let hits_before = tuner.hits;
        let mut e2 = tiny_engine(FfnMode::NativeNmg { n: 2, m: 4, g: 2 });
        let replay = e2.autotune_ffn(&mut tuner).unwrap();
        assert_eq!(replay, decisions);
        assert_eq!(tuner.hits - hits_before, e.dims.n_layers as u64);

        // Switching modes drops the tuned weights (stale layouts must not
        // survive a re-sparsification).
        e.set_ffn_mode(FfnMode::NativeDense);
        assert!(e.weights.tuned_w1t.is_empty());
    }

    #[test]
    fn artifact_call_values_share_weight_storage() {
        // Engine::p hands the runtime an Arc handle, not a copy: two calls
        // for one parameter alias the identical allocation.
        let e = tiny_engine(FfnMode::NativeDense);
        let v1 = e.p("emb");
        let v2 = e.p("emb");
        let p1 = v1.as_f32().unwrap().data().as_ptr();
        let p2 = v2.as_f32().unwrap().data().as_ptr();
        assert_eq!(p1, p2, "Engine::p must not copy weight tensors");
        assert_eq!(p1, e.param("emb").data().as_ptr());
    }

    #[test]
    fn replicas_share_weights_by_pointer_identity_through_forwards() {
        let mut a = tiny_engine(FfnMode::NativeNmg { n: 2, m: 4, g: 4 });
        let mut b = a.replicate();
        assert!(a.shares_weights_with(&b));
        let before = a.param("layer0.w1").data().as_ptr();
        assert_eq!(before, b.param("layer0.w1").data().as_ptr());

        let mut rng = Pcg64::seeded(3);
        let tokens = a.random_tokens(&mut rng);
        a.forward(&tokens).unwrap();
        b.forward(&tokens).unwrap();

        // Zero per-forward weight copies on the artifact-call path: after
        // serving traffic the same allocation still backs both replicas'
        // parameters, and fresh Values still alias it.
        assert!(a.shares_weights_with(&b));
        assert_eq!(a.param("layer0.w1").data().as_ptr(), before);
        assert_eq!(b.param("layer0.w1").data().as_ptr(), before);
        let va = a.p("emb");
        let vb = b.p("emb");
        assert!(std::ptr::eq(va.as_f32().unwrap(), vb.as_f32().unwrap()));
    }
}
