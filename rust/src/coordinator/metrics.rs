//! Serving metrics: latency percentiles, SLO-miss fractions, queue-depth
//! gauges and batch-deduplicated throughput, shared by the synchronous
//! drain-loop server and the concurrent multi-model server. Every
//! [`RequestResult`] carries its model index, so any aggregate here also
//! has a per-model form (one [`ModelMetrics`] per registered model).

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};

use super::serve::RequestResult;

/// Latency distribution over completed requests, in seconds.
#[derive(Debug, Clone, Copy)]
pub struct LatencySummary {
    /// Number of requests summarized.
    pub count: usize,
    /// Median end-to-end latency.
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile.
    pub p99: f64,
    /// Mean.
    pub mean: f64,
    /// Worst observed.
    pub max: f64,
}

/// Nearest-rank percentile of an ascending-sorted slice; `q` in [0, 100].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty slice");
    let rank = (q / 100.0 * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Summarize end-to-end latencies (`total_s`) of completed requests.
pub fn summarize(results: &[RequestResult]) -> Option<LatencySummary> {
    if results.is_empty() {
        return None;
    }
    let mut v: Vec<f64> = results.iter().map(|r| r.total_s).collect();
    v.sort_by(|a, b| a.total_cmp(b));
    Some(LatencySummary {
        count: v.len(),
        p50: percentile(&v, 50.0),
        p95: percentile(&v, 95.0),
        p99: percentile(&v, 99.0),
        mean: v.iter().sum::<f64>() / v.len() as f64,
        max: *v.last().unwrap(),
    })
}

/// Fraction of completed requests whose end-to-end latency (`total_s`)
/// exceeded `slo_s` seconds; `None` when nothing completed.
pub fn slo_miss_fraction(results: &[RequestResult], slo_s: f64) -> Option<f64> {
    if results.is_empty() {
        return None;
    }
    let misses = results.iter().filter(|r| r.total_s > slo_s).count();
    Some(misses as f64 / results.len() as f64)
}

/// Per-model rollup of the request-level aggregates.
#[derive(Debug, Clone)]
pub struct ModelMetrics {
    /// Completed requests for this model.
    pub requests: usize,
    /// p50/p95/p99 end-to-end latency over this model's requests.
    pub latency: Option<LatencySummary>,
    /// Fraction of this model's requests that missed the SLO.
    pub slo_miss: Option<f64>,
    /// Distinct batches this model's requests rode in.
    pub batches: u64,
}

/// Roll `results` up per model (`0..n_models`, registration order),
/// judging SLO misses against `slo_s` seconds.
pub fn per_model(results: &[RequestResult], n_models: usize, slo_s: f64) -> Vec<ModelMetrics> {
    (0..n_models)
        .map(|m| {
            let rs: Vec<RequestResult> =
                results.iter().filter(|r| r.model == m).cloned().collect();
            let batches = rs.iter().map(|r| r.batch_id).collect::<HashSet<u64>>().len() as u64;
            ModelMetrics {
                requests: rs.len(),
                latency: summarize(&rs),
                slo_miss: slo_miss_fraction(&rs, slo_s),
                batches,
            }
        })
        .collect()
}

/// Goodput: completions that landed *within* the SLO, per second of wall
/// time. Under overload this is the number that must plateau rather than
/// collapse — total throughput can stay high while every completion is
/// late, and SLO-miss fractions hide how much useful work still finishes.
pub fn goodput(results: &[RequestResult], slo_s: f64, wall_s: f64) -> f64 {
    let in_slo = results.iter().filter(|r| r.total_s <= slo_s).count();
    in_slo as f64 / wall_s.max(1e-12)
}

/// Requests per second of compute: each batch's `compute_s` is counted once
/// (keyed by `batch_id` — batches with bit-identical compute times used to
/// be merged, undercounting total compute).
pub fn compute_throughput(results: &[RequestResult]) -> Option<f64> {
    if results.is_empty() {
        return None;
    }
    let mut per_batch: HashMap<u64, f64> = HashMap::new();
    for r in results {
        per_batch.insert(r.batch_id, r.compute_s);
    }
    let total: f64 = per_batch.values().sum();
    if total <= 0.0 {
        return None;
    }
    Some(results.len() as f64 / total)
}

/// A queue-depth gauge with a high-water mark.
#[derive(Debug, Default)]
pub struct QueueGauge {
    depth: AtomicUsize,
    high_water: AtomicUsize,
}

impl QueueGauge {
    /// New gauge at depth 0.
    pub fn new() -> Self {
        Self::default()
    }

    /// A request entered the queue.
    pub fn enter(&self) {
        let d = self.depth.fetch_add(1, Ordering::SeqCst) + 1;
        self.high_water.fetch_max(d, Ordering::SeqCst);
    }

    /// `n` requests left the queue (were placed into a batch, shed, or
    /// rolled back after a failed submit). Saturating: a double-counted
    /// exit must not wrap the gauge to `usize::MAX` and freeze every
    /// depth-based decision (admission control reads this gauge). Debug
    /// builds assert instead, so the double count is found, not papered
    /// over.
    pub fn exit(&self, n: usize) {
        let prev = loop {
            let cur = self.depth.load(Ordering::SeqCst);
            let next = cur.saturating_sub(n);
            match self.depth.compare_exchange(cur, next, Ordering::SeqCst, Ordering::SeqCst) {
                Ok(prev) => break prev,
                Err(_) => continue,
            }
        };
        debug_assert!(prev >= n, "queue gauge under-flow: exit({n}) at depth {prev}");
    }

    /// Current depth.
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::SeqCst)
    }

    /// Deepest the queue has been.
    pub fn high_water(&self) -> usize {
        self.high_water.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(total_s: f64, batch_id: u64, compute_s: f64) -> RequestResult {
        RequestResult {
            id: 0,
            model: 0,
            batch_id,
            queue_s: 0.0,
            compute_s,
            total_s,
            batch_size: 1,
        }
    }

    #[test]
    fn percentiles_are_monotone_and_exact_on_small_sets() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 50.0), 50.0);
        assert_eq!(percentile(&v, 95.0), 95.0);
        assert_eq!(percentile(&v, 99.0), 99.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
        let one = [42.0];
        for q in [50.0, 95.0, 99.0] {
            assert_eq!(percentile(&one, q), 42.0);
        }
    }

    #[test]
    fn summary_orders_p50_p95_p99() {
        let results: Vec<RequestResult> =
            (0..57).map(|i| result(i as f64 * 0.01, i, 0.001)).collect();
        let s = summarize(&results).unwrap();
        assert_eq!(s.count, 57);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert!(summarize(&[]).is_none());
    }

    #[test]
    fn throughput_counts_identical_compute_times_per_batch() {
        // Two distinct batches with bit-identical compute_s: the old
        // to_bits() dedup merged them; batch_id keying must not.
        let results = vec![
            result(0.1, 1, 0.5),
            result(0.1, 1, 0.5),
            result(0.1, 2, 0.5),
        ];
        let t = compute_throughput(&results).unwrap();
        assert!((t - 3.0).abs() < 1e-9, "3 requests / 1.0s compute, got {t}");
    }

    #[test]
    fn slo_miss_counts_strict_exceedances() {
        let results =
            vec![result(0.010, 0, 0.001), result(0.020, 0, 0.001), result(0.050, 1, 0.001)];
        assert_eq!(slo_miss_fraction(&results, 0.020), Some(1.0 / 3.0));
        assert_eq!(slo_miss_fraction(&results, 1.0), Some(0.0));
        assert_eq!(slo_miss_fraction(&[], 0.02), None);
    }

    #[test]
    fn per_model_rolls_up_by_model_index() {
        let mut results = Vec::new();
        // Model 0: two requests in one batch, both within SLO.
        for _ in 0..2 {
            let mut r = result(0.010, 0, 0.001);
            r.model = 0;
            results.push(r);
        }
        // Model 1: three requests over two batches, one SLO miss.
        for (batch_id, total_s) in [(1u64, 0.010), (1, 0.015), (2, 0.090)] {
            let mut r = result(total_s, batch_id, 0.001);
            r.model = 1;
            results.push(r);
        }
        let per = per_model(&results, 3, 0.050);
        assert_eq!(per.len(), 3);
        assert_eq!((per[0].requests, per[0].batches), (2, 1));
        assert_eq!(per[0].slo_miss, Some(0.0));
        assert_eq!((per[1].requests, per[1].batches), (3, 2));
        assert!((per[1].slo_miss.unwrap() - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(per[1].latency.unwrap().count, 3);
        // Model 2 never saw traffic.
        assert_eq!(per[2].requests, 0);
        assert!(per[2].latency.is_none() && per[2].slo_miss.is_none());
    }

    #[test]
    fn gauge_tracks_high_water() {
        let g = QueueGauge::new();
        g.enter();
        g.enter();
        g.enter();
        g.exit(2);
        assert_eq!(g.depth(), 1);
        assert_eq!(g.high_water(), 3);
    }

    /// Regression: `exit` used to be an unguarded `fetch_sub`, so a
    /// double-counted exit (a request both shed and batch-exited) wrapped
    /// the depth gauge to `usize::MAX`. Release builds must saturate at 0.
    #[test]
    #[cfg(not(debug_assertions))]
    fn gauge_over_exit_saturates_instead_of_wrapping() {
        let g = QueueGauge::new();
        g.enter();
        g.exit(3);
        assert_eq!(g.depth(), 0, "over-exit must saturate, not wrap");
        g.enter();
        assert_eq!(g.depth(), 1, "gauge must stay usable after an over-exit");
    }

    /// Debug builds surface the same double count as an assertion so the
    /// bug is found rather than silently clamped.
    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "queue gauge under-flow")]
    fn gauge_over_exit_asserts_in_debug() {
        let g = QueueGauge::new();
        g.enter();
        g.exit(3);
    }

    #[test]
    fn goodput_counts_only_in_slo_completions() {
        let results =
            vec![result(0.010, 0, 0.001), result(0.020, 0, 0.001), result(0.050, 1, 0.001)];
        // SLO 20ms: two in-SLO completions over 4s of wall time.
        assert!((goodput(&results, 0.020, 4.0) - 0.5).abs() < 1e-12);
        assert_eq!(goodput(&[], 0.020, 4.0), 0.0);
    }
}
