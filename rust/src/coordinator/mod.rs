//! The Layer-3 inference coordinator: engines, model registry, schedulers,
//! concurrent serving.
//!
//! Composes the AOT-lowered encoder blocks (attention, embedding, LM head —
//! executed through the artifact runtime) with the FFN executed either as
//! another artifact (dense baseline) or through the native n:m:g sparse
//! kernels (the STen fast path). This is the end-to-end system of Fig. 11:
//! a general framework runtime whose sparse operators are dispatched to
//! specialized kernels, with the remaining graph falling back to the dense
//! executor — now serving *several* such models (dense vs n:m:g variants,
//! different sparsity budgets) behind one front-end.
//!
//! # Serving topology
//!
//! ```text
//!                 ┌────────────────────── ConcurrentServer ──────────────────────┐
//! submit_to(      │ [admission control]   [ingester]          [worker 0..W)      │
//!  "dense",toks)──┼─> EWMA predicts wait ──> bounded  ┌─ Scheduler ─┐  each      │
//!  (blocks at     │   > SLO? degrade to      submit   │ per-model   │  worker    │
//!   queue_cap;    │   "nmg" | Rejected       channel ─> queues;     │  PULLS a   │
//!   try_submit:   │                                   │ FIFO | WDRR <── batch    │
//!   QueueFull)    │                                   └──────┬──────┘  when free │
//!                 │   sheds: entries already past the SLO    └─> shed   (its own │
//!                 │   are dropped before batch formation          path   replica │
//!                 │   (accounted per model, never executed)        of every model│
//!                 └──────────────────────────────────────────────────────────────┘
//! ```
//!
//! Batches are *continuously* formed: a worker pulls its next batch from
//! the shared scheduler at the moment it frees up, so a slow batch
//! occupies one worker while the queues keep draining through the rest —
//! there is no pre-formed batch pipeline to stall behind.
//!
//! Three serving modes share one request/result vocabulary
//! ([`serve::Request`], [`RequestResult`] — both carry a model index):
//!
//! * [`BatchServer`] — the single-threaded drain-loop baseline: callers
//!   enqueue, then `run_until_drained` forms and executes batches inline.
//! * [`ConcurrentServer::start`] — the single-model concurrent server:
//!   bounded submission queue, ingester thread, N weight-sharing replicas
//!   pulling batches continuously. With the default FIFO policy and a
//!   free worker its batch formation matches the pre-registry behavior
//!   (asserted by scripted-trace equivalence tests in [`scheduler`],
//!   including one driving a simulated finite worker pool).
//! * [`ConcurrentServer::start_registry`] — the multi-model front-end: a
//!   [`registry::ModelRegistry`] of named engines (each with its own
//!   `FfnMode`/sparsity config and replica count) served through a
//!   pluggable [`scheduler::Scheduler`] — FIFO across models, or weighted
//!   deficit round-robin with per-model weights and no starvation.
//!
//! **Replica sharing.** Worker replicas come from [`Engine::replicate`]:
//! each model's weight tensors (and its pre-converted n:m:g FFN weights)
//! live behind one `Arc`, so sparsification happens once per model
//! regardless of worker count, and weights stay immutable while serving.
//! Kernel parallelism is divided across the whole worker pool via
//! `threadpool::register_kernel_users(workers)` — one registration per
//! server, re-made when a server (re)starts with a different worker count.
//!
//! **Deadline semantics.** Batch formation honors `max_wait` *per model*:
//! a full batch (the model's artifact batch size) dispatches immediately;
//! otherwise a batch dispatches the moment its oldest request has waited
//! `max_wait`. Deadline-expired batches bypass WDRR deficits, so weights
//! shape bandwidth under saturation but can never starve a model past its
//! deadline. Under overload the bounded queue pushes the wait back onto
//! blocking submitters; `try_submit` surfaces it as `QueueFull` instead.
//!
//! **Overload defense.** With `ServeConfig::admission` on, each submit is
//! checked against a predicted queue-plus-service delay (per-model EWMA
//! of observed `compute_s` per request × live queue depths ÷ workers):
//! past the SLO, the request is degraded to the model's registered sparse
//! n:m:g variant ([`ModelRegistry::set_degrade`]) if that variant's own
//! prediction fits, else rejected with `SubmitError::Rejected`. With
//! `ServeConfig::shed` on, queue entries that have already outlived the
//! SLO are dropped before batch formation — compute is never spent on a
//! guaranteed miss.
//!
//! **Metrics / goodput accounting.** Every completion carries its model
//! index and real `batch_id`; [`metrics`] derives global and per-model
//! p50/p95/p99 latency summaries, SLO-miss fractions, batch-deduplicated
//! compute throughput and queue-depth gauges with high-water marks,
//! surfaced in [`ServeReport::per_model`]. The overload figure of merit
//! is `goodput_rps` = completions with `total_s <= slo` per wall second:
//! sheds and rejections reduce goodput's numerator but are reported as
//! their own per-model counts (`shed`/`rejected`/`degraded`), never as
//! completions. A degraded request's completion is accounted under the
//! *target* model; the `degraded` count stays with the model the client
//! asked for.
//!
//! **Tensor parallelism.** A registry entry can declare `shards: W` in
//! addition to `replicas`: the model is then served by [`ShardedModel`]
//! instances ([`Engine::shard`]) whose batches are executed cooperatively
//! by `W` dedicated shard threads — attention split per head, FFN
//! column-parallel for W1 (sparse formats sliced on their natural
//! slab/block boundaries) and row-parallel at the W2 seam — meeting at
//! [`crate::dist::ShardGroup`] ring collectives. Dense sharded execution
//! is bit-identical to the unsharded engine (see [`shard`]).
//!
//! * [`engine`] — the per-model engine with latency breakdown.
//! * [`registry`] — named models behind one front-end.
//! * [`scheduler`] — batch-formation policies (FIFO, WDRR).
//! * [`serve`] — request vocabulary + the synchronous dynamic batcher.
//! * [`concurrent`] — the multi-model deadline-batching front-end.
//! * [`metrics`] — latency percentiles, SLO misses, throughput, gauges.
//! * [`shard`] — tensor-parallel sharded execution over ring collectives.

pub mod concurrent;
pub mod engine;
pub mod metrics;
pub mod registry;
pub mod scheduler;
pub mod serve;
pub mod shard;

pub use concurrent::{
    CompletionLatch, ConcurrentServer, ModelReport, ServeConfig, ServeReport, ShardTiming,
    SubmitError,
};
pub use engine::{Engine, EncoderDims, FfnMode};
pub use metrics::{LatencySummary, ModelMetrics};
pub use registry::ModelRegistry;
pub use scheduler::{SchedPolicy, Scheduler};
pub use serve::{BatchServer, RequestResult};
pub use shard::{shard_bounds, SeamMode, ShardedModel};
