//! The Layer-3 inference coordinator.
//!
//! Composes the AOT-lowered encoder blocks (attention, embedding, LM head —
//! executed through PJRT) with the FFN executed either as another artifact
//! (dense baseline) or through the native n:m:g sparse kernels (the STen
//! fast path). This is the end-to-end system of Fig. 11: a general framework
//! runtime whose sparse operators are dispatched to specialized kernels,
//! with the remaining graph falling back to the dense executor.
//!
//! * [`engine`] — the per-model engine with latency breakdown.
//! * [`serve`] — request queue + dynamic batcher over the engine.

pub mod engine;
pub mod serve;

pub use engine::{Engine, EncoderDims, FfnMode};
pub use serve::{BatchServer, RequestResult};
