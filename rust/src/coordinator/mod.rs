//! The Layer-3 inference coordinator: engines, batching, concurrent serving.
//!
//! Composes the AOT-lowered encoder blocks (attention, embedding, LM head —
//! executed through the artifact runtime) with the FFN executed either as
//! another artifact (dense baseline) or through the native n:m:g sparse
//! kernels (the STen fast path). This is the end-to-end system of Fig. 11:
//! a general framework runtime whose sparse operators are dispatched to
//! specialized kernels, with the remaining graph falling back to the dense
//! executor.
//!
//! # Concurrency model
//!
//! Two serving modes share one request/result vocabulary ([`serve::Request`],
//! [`RequestResult`]):
//!
//! * [`BatchServer`] — the single-threaded drain-loop baseline: callers
//!   enqueue, then `run_until_drained` forms and executes batches inline.
//! * [`ConcurrentServer`] — the production shape: a bounded submission
//!   queue (blocking `submit` past `queue_cap` — backpressure, never
//!   unbounded memory), a dedicated batcher thread, and N worker threads
//!   each owning an [`Engine`] replica.
//!
//! **Replica sharing.** Replicas come from [`Engine::replicate`]: weight
//! tensors (and the pre-converted n:m:g FFN weights) live behind one `Arc`,
//! so sparsification happens once per server regardless of replica count,
//! and replicas stay immutable while serving. Per-replica timing state is
//! private; the `Arc`-shared runtime aggregates artifact-level buckets.
//!
//! **Deadline semantics.** Batch formation honors `max_wait`: a full batch
//! (the artifact batch size) dispatches immediately; otherwise the batch is
//! dispatched the moment its *oldest* request has waited `max_wait`, padded
//! by repeating the last sequence. Under light load no request waits in
//! queue longer than `max_wait` before its batch is formed; under overload
//! the bounded queue pushes the wait back onto submitters.
//!
//! **Metrics.** Every completion carries its real `batch_id`; [`metrics`]
//! derives p50/p95/p99 latency summaries, batch-deduplicated compute
//! throughput and queue-depth gauges with high-water marks.
//!
//! * [`engine`] — the per-model engine with latency breakdown.
//! * [`serve`] — request vocabulary + the synchronous dynamic batcher.
//! * [`concurrent`] — the multi-replica deadline-batching front-end.
//! * [`metrics`] — latency percentiles, throughput, queue gauges.

pub mod concurrent;
pub mod engine;
pub mod metrics;
pub mod serve;

pub use concurrent::{ConcurrentServer, ServeConfig, ServeReport};
pub use engine::{Engine, EncoderDims, FfnMode};
pub use metrics::LatencySummary;
pub use serve::{BatchServer, RequestResult};
