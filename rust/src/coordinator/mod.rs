//! The Layer-3 inference coordinator: engines, model registry, schedulers,
//! concurrent serving.
//!
//! Composes the AOT-lowered encoder blocks (attention, embedding, LM head —
//! executed through the artifact runtime) with the FFN executed either as
//! another artifact (dense baseline) or through the native n:m:g sparse
//! kernels (the STen fast path). This is the end-to-end system of Fig. 11:
//! a general framework runtime whose sparse operators are dispatched to
//! specialized kernels, with the remaining graph falling back to the dense
//! executor — now serving *several* such models (dense vs n:m:g variants,
//! different sparsity budgets) behind one front-end.
//!
//! # Serving topology
//!
//! ```text
//!                 ┌────────────────────── ConcurrentServer ──────────────────────┐
//! submit_to(      │  [batcher thread]                       [worker 0..W)        │
//!  "nmg", toks) ──┼─> bounded submit     ┌─ Scheduler ─┐     each worker holds   │
//!  (blocks at     │   channel ─────────> │ per-model   │ ──> one Engine replica  │
//!   queue_cap,    │                      │ queues;     │     of EVERY model      │
//!   global)       │                      │ FIFO | WDRR │     (Arc-shared weights │
//!                 │                      └─────────────┘     per model) and runs │
//!                 │                        max_wait deadline  whichever model's  │
//!                 │                        batching per model batch it receives  │
//!                 └──────────────────────────────────────────────────────────────┘
//! ```
//!
//! Three serving modes share one request/result vocabulary
//! ([`serve::Request`], [`RequestResult`] — both carry a model index):
//!
//! * [`BatchServer`] — the single-threaded drain-loop baseline: callers
//!   enqueue, then `run_until_drained` forms and executes batches inline.
//! * [`ConcurrentServer::start`] — the single-model concurrent server:
//!   bounded submission queue, batcher thread, N weight-sharing replicas.
//!   With the default FIFO policy its batch formation is bit-for-bit the
//!   pre-registry behavior (asserted by a scripted-trace equivalence test
//!   in [`scheduler`]).
//! * [`ConcurrentServer::start_registry`] — the multi-model front-end: a
//!   [`registry::ModelRegistry`] of named engines (each with its own
//!   `FfnMode`/sparsity config and replica count) served through a
//!   pluggable [`scheduler::Scheduler`] — FIFO across models, or weighted
//!   deficit round-robin with per-model weights and no starvation.
//!
//! **Replica sharing.** Worker replicas come from [`Engine::replicate`]:
//! each model's weight tensors (and its pre-converted n:m:g FFN weights)
//! live behind one `Arc`, so sparsification happens once per model
//! regardless of worker count, and weights stay immutable while serving.
//! Kernel parallelism is divided across the whole worker pool via
//! `threadpool::register_kernel_users(workers)` — one registration per
//! server, re-made when a server (re)starts with a different worker count.
//!
//! **Deadline semantics.** Batch formation honors `max_wait` *per model*:
//! a full batch (the model's artifact batch size) dispatches immediately;
//! otherwise a batch dispatches the moment its oldest request has waited
//! `max_wait`. Deadline-expired batches bypass WDRR deficits, so weights
//! shape bandwidth under saturation but can never starve a model past its
//! deadline. Under overload the bounded queue pushes the wait back onto
//! submitters.
//!
//! **Metrics.** Every completion carries its model index and real
//! `batch_id`; [`metrics`] derives global and per-model p50/p95/p99
//! latency summaries, SLO-miss fractions, batch-deduplicated compute
//! throughput and queue-depth gauges with high-water marks, surfaced in
//! [`ServeReport::per_model`].
//!
//! * [`engine`] — the per-model engine with latency breakdown.
//! * [`registry`] — named models behind one front-end.
//! * [`scheduler`] — batch-formation policies (FIFO, WDRR).
//! * [`serve`] — request vocabulary + the synchronous dynamic batcher.
//! * [`concurrent`] — the multi-model deadline-batching front-end.
//! * [`metrics`] — latency percentiles, SLO misses, throughput, gauges.

pub mod concurrent;
pub mod engine;
pub mod metrics;
pub mod registry;
pub mod scheduler;
pub mod serve;

pub use concurrent::{
    CompletionLatch, ConcurrentServer, ModelReport, ServeConfig, ServeReport, SubmitError,
};
pub use engine::{Engine, EncoderDims, FfnMode};
pub use metrics::{LatencySummary, ModelMetrics};
pub use registry::ModelRegistry;
pub use scheduler::{SchedPolicy, Scheduler};
pub use serve::{BatchServer, RequestResult};
