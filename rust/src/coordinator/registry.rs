//! The model registry: named [`Engine`]s served behind one front-end.
//!
//! A registry entry is a *prototype* engine plus its serving parameters
//! (replica count, scheduling weight). Each model keeps its own
//! [`super::engine::FfnMode`] / sparsity configuration and its own weight
//! set; when [`super::concurrent::ConcurrentServer::start_registry`] takes
//! the registry, every worker thread receives an [`Engine::replicate`]
//! clone of *every* model, so a model's replica set shares one `Arc`-held
//! parameter allocation (n:m:g conversion done once per model, zero weight
//! bytes copied per forward) and any worker can execute whichever model's
//! batch the scheduler hands it.
//!
//! Model *indices* (registration order) are the scheduler's and the
//! metrics' vocabulary; model *names* are the submit-side vocabulary
//! (`submit_to("nmg", ..)` and the `serve --models` CLI).

use anyhow::{bail, Result};

use super::engine::{EncoderDims, Engine};

/// One registered model: a prototype engine plus serving parameters.
pub struct ModelEntry {
    /// Unique model name (the `submit_to` key).
    pub name: String,
    /// Prototype engine; replicated per worker at server start.
    pub engine: Engine,
    /// Capacity contribution: how many worker threads this model adds to
    /// the shared worker pool.
    pub replicas: usize,
    /// Scheduling weight (used by weighted policies; 1 = neutral).
    pub weight: u64,
    /// Admission-control fallback: when this model's predicted queue wait
    /// blows the SLO, degrade the request to the named model (typically the
    /// sparse n:m:g variant of the same weights) instead of rejecting.
    pub degrade_to: Option<String>,
    /// Tensor-parallel shard count. 1 (the default) serves each batch on
    /// one engine replica; `W > 1` serves the model as `replicas`
    /// [`super::shard::ShardedModel`] instances whose batches execute
    /// cooperatively on `W` dedicated shard threads each, with attention
    /// split per head and the FFN split column-/row-parallel.
    pub shards: usize,
}

/// An ordered collection of named models; indices are registration order.
#[derive(Default)]
pub struct ModelRegistry {
    models: Vec<ModelEntry>,
}

impl ModelRegistry {
    /// New empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a model; returns its index (registration order). Fails on
    /// an empty or duplicate name, zero replicas, or zero weight.
    pub fn register(
        &mut self,
        name: &str,
        engine: Engine,
        replicas: usize,
        weight: u64,
    ) -> Result<usize> {
        self.register_sharded(name, engine, replicas, weight, 1)
    }

    /// Register a tensor-parallel model: each of its `replicas` serving
    /// slots is a sharded instance executing batches cooperatively on
    /// `shards` dedicated threads ([`crate::coordinator::Engine::shard`]).
    /// `shards = 1` is identical to [`ModelRegistry::register`].
    pub fn register_sharded(
        &mut self,
        name: &str,
        engine: Engine,
        replicas: usize,
        weight: u64,
        shards: usize,
    ) -> Result<usize> {
        if name.is_empty() {
            bail!("model name must be non-empty");
        }
        if self.index_of(name).is_some() {
            bail!("model {name:?} is already registered");
        }
        if replicas == 0 {
            bail!("model {name:?}: replicas must be at least 1");
        }
        if weight == 0 {
            bail!("model {name:?}: weight must be at least 1");
        }
        if shards == 0 {
            bail!("model {name:?}: shards must be at least 1");
        }
        self.models.push(ModelEntry {
            name: name.to_string(),
            engine,
            replicas,
            weight,
            degrade_to: None,
            shards,
        });
        Ok(self.models.len() - 1)
    }

    /// Declare that overloaded submissions for `from` may be degraded to
    /// `to` (the registered sparse variant of the same model). Both names
    /// must already be registered and distinct; degrading to a model with
    /// its own degrade target is allowed but the chain is not followed —
    /// admission control tries exactly one hop.
    pub fn set_degrade(&mut self, from: &str, to: &str) -> Result<()> {
        if from == to {
            bail!("model {from:?} cannot degrade to itself");
        }
        if self.index_of(to).is_none() {
            bail!("degrade target {to:?} is not registered");
        }
        let Some(idx) = self.index_of(from) else {
            bail!("model {from:?} is not registered");
        };
        self.models[idx].degrade_to = Some(to.to_string());
        Ok(())
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.models.len()
    }

    /// True when nothing is registered.
    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// Index of the model named `name`, if registered.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.models.iter().position(|m| m.name == name)
    }

    /// The registered entries, in registration order.
    pub fn entries(&self) -> &[ModelEntry] {
        &self.models
    }

    /// Encoder dimensions of model `idx`.
    pub fn dims(&self, idx: usize) -> &EncoderDims {
        &self.models[idx].engine.dims
    }

    /// Total worker threads the registered models contribute.
    pub fn total_replicas(&self) -> usize {
        self.models.iter().map(|m| m.replicas).sum()
    }

    /// Total compute threads the registered models put behind the worker
    /// pool: each replica of a sharded model runs its batches on `shards`
    /// dedicated threads, so its kernel footprint is `replicas * shards`.
    pub fn total_kernel_users(&self) -> usize {
        self.models.iter().map(|m| m.replicas * m.shards).sum()
    }

    /// Consume the registry (server start).
    pub(super) fn into_entries(self) -> Vec<ModelEntry> {
        self.models
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::FfnMode;
    use crate::runtime::ArtifactRuntime;

    fn tiny_engine() -> Engine {
        let rt = ArtifactRuntime::open(std::path::PathBuf::from("target/nonexistent-artifacts"))
            .unwrap();
        Engine::new(rt, "tiny", FfnMode::NativeDense, 7).unwrap()
    }

    #[test]
    fn registers_in_order_and_indexes_by_name() {
        let mut reg = ModelRegistry::new();
        assert!(reg.is_empty());
        assert_eq!(reg.register("dense", tiny_engine(), 2, 1).unwrap(), 0);
        assert_eq!(reg.register("nmg", tiny_engine(), 1, 3).unwrap(), 1);
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.index_of("nmg"), Some(1));
        assert_eq!(reg.index_of("missing"), None);
        assert_eq!(reg.total_replicas(), 3);
        assert_eq!(reg.entries()[1].weight, 3);
        assert_eq!(reg.dims(0).batch, reg.dims(1).batch);
    }

    #[test]
    fn sharded_entries_declare_their_kernel_footprint() {
        let mut reg = ModelRegistry::new();
        reg.register("dense", tiny_engine(), 2, 1).unwrap();
        reg.register_sharded("tp", tiny_engine(), 2, 1, 2).unwrap();
        assert_eq!(reg.entries()[0].shards, 1);
        assert_eq!(reg.entries()[1].shards, 2);
        // Worker slots count replicas; compute threads count shards too.
        assert_eq!(reg.total_replicas(), 4);
        assert_eq!(reg.total_kernel_users(), 2 + 2 * 2);
        assert!(reg.register_sharded("z", tiny_engine(), 1, 1, 0).is_err(), "zero shards");
    }

    #[test]
    fn rejects_duplicates_and_degenerate_parameters() {
        let mut reg = ModelRegistry::new();
        reg.register("m", tiny_engine(), 1, 1).unwrap();
        assert!(reg.register("m", tiny_engine(), 1, 1).is_err(), "duplicate name");
        assert!(reg.register("", tiny_engine(), 1, 1).is_err(), "empty name");
        assert!(reg.register("r0", tiny_engine(), 0, 1).is_err(), "zero replicas");
        assert!(reg.register("w0", tiny_engine(), 1, 0).is_err(), "zero weight");
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn degrade_links_require_registered_distinct_models() {
        let mut reg = ModelRegistry::new();
        reg.register("dense", tiny_engine(), 1, 1).unwrap();
        reg.register("nmg", tiny_engine(), 1, 1).unwrap();
        assert!(reg.set_degrade("dense", "dense").is_err(), "self-degrade");
        assert!(reg.set_degrade("dense", "missing").is_err(), "unknown target");
        assert!(reg.set_degrade("missing", "nmg").is_err(), "unknown source");
        reg.set_degrade("dense", "nmg").unwrap();
        assert_eq!(reg.entries()[0].degrade_to.as_deref(), Some("nmg"));
        assert!(reg.entries()[1].degrade_to.is_none());
    }
}
