//! Batch-formation policies over per-model queues.
//!
//! The batcher thread of [`super::concurrent::ConcurrentServer`] used to own
//! batch formation inline; it is now split into a [`Scheduler`] its callers
//! *drive*: the ingest thread feeds arrivals in with [`Scheduler::enqueue`]
//! and each worker, the moment it frees up, asks [`Scheduler::poll`] what to
//! do next — dispatch a formed batch, wait for more arrivals (optionally
//! with a deadline), or stop. That worker-pull loop is *continuous
//! batching*: the next batch is formed at dispatch time from everything
//! queued at that instant, so a slow batch occupies only its worker and
//! never stalls queue progress behind pre-formed batches. Every decision is
//! a pure function of the queues, the passed-in `now` and the `open` flag,
//! so policies are unit-testable in *virtual time* against scripted arrival
//! traces (no wall clock, no threads) — both in the legacy
//! always-a-free-worker regime and under a simulated worker pool
//! (`drive_workers` below).
//!
//! Two policies:
//!
//! * [`SchedPolicy::Fifo`] — FIFO across models: the model owning the
//!   globally-oldest pending request dispatches first (full batches
//!   anywhere dispatch immediately). With a single registered model this
//!   reproduces the pre-registry server's batch formation bit for bit —
//!   asserted by `fifo_single_model_matches_pre_refactor_batcher` below
//!   against a literal replay of the old batcher loop.
//! * [`SchedPolicy::Wdrr`] — weighted deficit round-robin: under
//!   saturation, models dispatch full batches proportionally to their
//!   weights; deadline-expired partial batches bypass the deficit so the
//!   `max_wait` latency contract holds for every model and a weight-1
//!   model can never be starved by a heavier competitor.
//!
//! Queue-cap semantics: the scheduler's per-model queues are *forming*
//! queues, not the backpressure bound. The server's bounded submission
//! channel (`ServeConfig::queue_cap`, global across models) is what blocks
//! submitters; the ingester additionally caps total forming-queue depth at
//! `max(queue_cap, largest model batch)`, parking until a dispatch or a
//! shed frees space, so end-to-end in-flight work stays bounded even
//! though workers pull batches asynchronously.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::serve::Request;

/// Scheduling policy selector (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    /// FIFO across models; single-model behavior identical to the
    /// pre-registry server.
    Fifo,
    /// Weighted deficit round-robin across models.
    Wdrr,
}

/// Per-model scheduling configuration.
#[derive(Debug, Clone, Copy)]
pub struct SchedModel {
    /// Batch size of the model's artifact (the dispatch unit).
    pub batch: usize,
    /// Scheduling weight (WDRR only; FIFO ignores it).
    pub weight: u64,
}

/// A batch formed by the scheduler, ready for a worker.
#[derive(Debug)]
pub struct FormedBatch {
    /// Index of the model in registration order.
    pub model: usize,
    /// Sequential batch id (unique per scheduler).
    pub id: u64,
    /// The requests riding in this batch (1..=batch of `model`).
    pub requests: Vec<Request>,
}

/// What the batcher should do next.
#[derive(Debug)]
pub enum Decision {
    /// Hand this batch to a worker, then poll again.
    Dispatch(FormedBatch),
    /// Wait for an arrival until the deadline, then poll again.
    WaitUntil(Instant),
    /// Nothing is pending: block for the next arrival.
    WaitForArrival,
    /// Nothing is pending and the arrival stream is closed: stop.
    Idle,
}

/// A batch-formation policy over per-model queues. All methods take time as
/// an explicit argument so policies can be driven in virtual time by tests.
pub trait Scheduler: Send {
    /// Accept an arrived request (`req.model` indexes registration order).
    fn enqueue(&mut self, req: Request);
    /// Decide the next action given the current time and whether more
    /// arrivals may still come (`open`).
    fn poll(&mut self, now: Instant, open: bool) -> Decision;
    /// Requests currently queued across all models.
    fn pending(&self) -> usize;
    /// Requests currently queued for one model.
    fn pending_for(&self, model: usize) -> usize;
    /// Drop and return every queued request that arrived at or before
    /// `expire_before` (load shedding: entries already past their service
    /// objective are removed *before* batch formation, so a worker that
    /// frees up under backlog spends its capacity on requests that can
    /// still complete in time). Relative queue order of the survivors is
    /// unchanged; WDRR deficits are untouched.
    fn shed_expired(&mut self, expire_before: Instant) -> Vec<Request>;
    /// Remove and return everything queued (shutdown/failure path).
    fn take_all(&mut self) -> Vec<Request>;
}

/// Build a scheduler for `policy` over `models` (registration order).
pub fn make(policy: SchedPolicy, models: Vec<SchedModel>, max_wait: Duration) -> Box<dyn Scheduler> {
    match policy {
        SchedPolicy::Fifo => Box::new(FifoScheduler { q: Queues::new(models, max_wait) }),
        SchedPolicy::Wdrr => {
            let n = models.len();
            Box::new(WdrrScheduler {
                q: Queues::new(models, max_wait),
                current: 0,
                entered: false,
                deficit: vec![0; n],
            })
        }
    }
}

/// The per-model queues and batch bookkeeping shared by all policies.
struct Queues {
    queues: Vec<VecDeque<Request>>,
    models: Vec<SchedModel>,
    max_wait: Duration,
    next_batch: u64,
    pending: usize,
}

impl Queues {
    fn new(models: Vec<SchedModel>, max_wait: Duration) -> Self {
        assert!(!models.is_empty(), "scheduler needs at least one model");
        assert!(models.iter().all(|m| m.batch >= 1), "model batch sizes must be at least 1");
        let queues = models.iter().map(|_| VecDeque::new()).collect();
        Queues { queues, models, max_wait, next_batch: 0, pending: 0 }
    }

    fn enqueue(&mut self, req: Request) {
        assert!(req.model < self.queues.len(), "request for unregistered model {}", req.model);
        self.queues[req.model].push_back(req);
        self.pending += 1;
    }

    /// Model whose front (oldest queued) request arrived earliest.
    fn oldest_model(&self) -> Option<usize> {
        self.queues
            .iter()
            .enumerate()
            .filter_map(|(m, q)| q.front().map(|r| (m, r.arrived)))
            .min_by_key(|&(_, t)| t)
            .map(|(m, _)| m)
    }

    /// Model with a full batch queued, earliest front first.
    fn full_model(&self) -> Option<usize> {
        self.queues
            .iter()
            .enumerate()
            .filter(|(m, q)| q.len() >= self.models[*m].batch)
            .filter_map(|(m, q)| q.front().map(|r| (m, r.arrived)))
            .min_by_key(|&(_, t)| t)
            .map(|(m, _)| m)
    }

    /// Model whose front request has aged past `max_wait`, earliest first.
    fn expired_model(&self, now: Instant) -> Option<usize> {
        self.queues
            .iter()
            .enumerate()
            .filter_map(|(m, q)| q.front().map(|r| (m, r.arrived)))
            .filter(|&(_, t)| now >= t + self.max_wait)
            .min_by_key(|&(_, t)| t)
            .map(|(m, _)| m)
    }

    /// Earliest `max_wait` deadline over all queue fronts.
    fn earliest_deadline(&self) -> Option<Instant> {
        self.queues
            .iter()
            .filter_map(|q| q.front().map(|r| r.arrived + self.max_wait))
            .min()
    }

    fn full(&self, model: usize) -> bool {
        self.queues[model].len() >= self.models[model].batch
    }

    /// Pop up to one batch of `model`'s requests into a [`FormedBatch`].
    fn form(&mut self, model: usize) -> FormedBatch {
        let take = self.queues[model].len().min(self.models[model].batch);
        debug_assert!(take >= 1, "forming an empty batch");
        let requests: Vec<Request> = self.queues[model].drain(..take).collect();
        self.pending -= take;
        let id = self.next_batch;
        self.next_batch += 1;
        FormedBatch { model, id, requests }
    }

    fn take_all(&mut self) -> Vec<Request> {
        let mut out = Vec::with_capacity(self.pending);
        for q in &mut self.queues {
            out.extend(q.drain(..));
        }
        self.pending = 0;
        out
    }

    /// Remove every queued request with `arrived <= expire_before`,
    /// preserving the relative order of both the shed and the surviving
    /// requests. Queues are FIFO per model, so expired entries are a
    /// prefix of each queue only under FIFO arrival — a retained scan
    /// keeps this correct for any arrival pattern.
    fn shed_expired(&mut self, expire_before: Instant) -> Vec<Request> {
        let mut out = Vec::new();
        for q in &mut self.queues {
            let mut keep = VecDeque::with_capacity(q.len());
            for r in q.drain(..) {
                if r.arrived <= expire_before {
                    out.push(r);
                } else {
                    keep.push_back(r);
                }
            }
            *q = keep;
        }
        self.pending -= out.len();
        out
    }
}

/// FIFO across models: serve the globally-oldest request's model next; a
/// full batch anywhere dispatches immediately.
struct FifoScheduler {
    q: Queues,
}

impl Scheduler for FifoScheduler {
    fn enqueue(&mut self, req: Request) {
        self.q.enqueue(req);
    }

    fn poll(&mut self, now: Instant, open: bool) -> Decision {
        let Some(oldest) = self.q.oldest_model() else {
            return if open { Decision::WaitForArrival } else { Decision::Idle };
        };
        if !open {
            // Drain: no more arrivals can come, so waiting is pointless.
            return Decision::Dispatch(self.q.form(oldest));
        }
        // Expired deadlines outrank full batches: a saturated competitor
        // model must not defer another model's `max_wait` promise. (With a
        // single model the order is indistinguishable: an expired full
        // queue forms the same full batch either way.)
        if let Some(expired) = self.q.expired_model(now) {
            return Decision::Dispatch(self.q.form(expired));
        }
        if let Some(full) = self.q.full_model() {
            return Decision::Dispatch(self.q.form(full));
        }
        // Nothing full and nothing expired, so the oldest front's deadline
        // is strictly in the future.
        Decision::WaitUntil(self.q.queues[oldest].front().unwrap().arrived + self.q.max_wait)
    }

    fn pending(&self) -> usize {
        self.q.pending
    }

    fn pending_for(&self, model: usize) -> usize {
        self.q.queues[model].len()
    }

    fn shed_expired(&mut self, expire_before: Instant) -> Vec<Request> {
        self.q.shed_expired(expire_before)
    }

    fn take_all(&mut self) -> Vec<Request> {
        self.q.take_all()
    }
}

/// Weighted deficit round-robin: full batches are scheduled by a classic
/// DRR rotation (quantum = `weight x batch` requests, credited once per
/// visit, deficit capped at quantum + batch so an idle model cannot hoard
/// service), while deadline-expired partial batches bypass the deficit —
/// the `max_wait` promise is latency, not bandwidth, and honoring it is
/// also what makes starvation impossible regardless of weights.
struct WdrrScheduler {
    q: Queues,
    /// Model the rotation currently points at.
    current: usize,
    /// Whether `current` was already credited its quantum for this visit.
    entered: bool,
    /// Per-model deficit counters, in requests.
    deficit: Vec<u64>,
}

impl WdrrScheduler {
    fn quantum(&self, model: usize) -> u64 {
        self.q.models[model].weight * self.q.models[model].batch as u64
    }
}

impl Scheduler for WdrrScheduler {
    fn enqueue(&mut self, req: Request) {
        self.q.enqueue(req);
    }

    fn poll(&mut self, now: Instant, open: bool) -> Decision {
        if self.q.pending == 0 {
            return if open { Decision::WaitForArrival } else { Decision::Idle };
        }
        if !open {
            // Drain in arrival order; weights only matter under contention.
            let oldest = self.q.oldest_model().unwrap();
            return Decision::Dispatch(self.q.form(oldest));
        }
        // Deadline pass: an expired oldest request dispatches now (possibly
        // partial), regardless of its model's deficit.
        if let Some(expired) = self.q.expired_model(now) {
            return Decision::Dispatch(self.q.form(expired));
        }
        // DRR pass over full batches only, so quantum is credited only
        // during productive rotations.
        if self.q.full_model().is_some() {
            let n = self.q.models.len();
            let mut hops = 0;
            while hops <= n {
                let m = self.current;
                let batch = self.q.models[m].batch as u64;
                if !self.entered {
                    self.entered = true;
                    if self.q.queues[m].is_empty() {
                        self.deficit[m] = 0;
                    } else {
                        let quantum = self.quantum(m);
                        self.deficit[m] = (self.deficit[m] + quantum).min(quantum + batch);
                    }
                }
                if self.q.full(m) && self.deficit[m] >= batch {
                    self.deficit[m] -= batch;
                    return Decision::Dispatch(self.q.form(m));
                }
                self.current = (m + 1) % n;
                self.entered = false;
                hops += 1;
            }
            // Unreachable (a credited visit to a full model always has
            // deficit >= batch), but never livelock if the invariant breaks.
            if let Some(full) = self.q.full_model() {
                return Decision::Dispatch(self.q.form(full));
            }
        }
        // Nothing full and nothing expired: wait for the earliest deadline.
        Decision::WaitUntil(self.q.earliest_deadline().unwrap())
    }

    fn pending(&self) -> usize {
        self.q.pending
    }

    fn pending_for(&self, model: usize) -> usize {
        self.q.queues[model].len()
    }

    fn shed_expired(&mut self, expire_before: Instant) -> Vec<Request> {
        self.q.shed_expired(expire_before)
    }

    fn take_all(&mut self) -> Vec<Request> {
        self.q.take_all()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, model: usize, at: Instant) -> Request {
        Request { id, tokens: Vec::new(), model, arrived: at }
    }

    fn models(specs: &[(usize, u64)]) -> Vec<SchedModel> {
        specs.iter().map(|&(batch, weight)| SchedModel { batch, weight }).collect()
    }

    /// Literal virtual-time replay of the pre-registry `ConcurrentServer`
    /// batcher loop (bounded-channel recv/recv_deadline over one pending
    /// queue), returning `(batch_id, batch_size)` per dispatched batch.
    fn reference_old_batcher(
        offsets_ms: &[u64],
        batch: usize,
        max_wait_ms: u64,
    ) -> Vec<(u64, usize)> {
        let mut out = Vec::new();
        let mut pending: VecDeque<u64> = VecDeque::new();
        let mut open = true;
        let mut next_id = 0u64;
        let mut i = 0usize;
        while open || !pending.is_empty() {
            if pending.is_empty() {
                if i < offsets_ms.len() {
                    pending.push_back(offsets_ms[i]); // blocking recv
                    i += 1;
                } else {
                    open = false; // channel closed
                    continue;
                }
            }
            while open && pending.len() < batch {
                let deadline = pending.front().unwrap() + max_wait_ms;
                if i < offsets_ms.len() && offsets_ms[i] <= deadline {
                    pending.push_back(offsets_ms[i]); // recv_deadline: Item
                    i += 1;
                } else if i < offsets_ms.len() {
                    break; // recv_deadline: TimedOut
                } else {
                    open = false; // recv_deadline: Closed
                }
            }
            let take = pending.len().min(batch);
            pending.drain(..take);
            out.push((next_id, take));
            next_id += 1;
        }
        out
    }

    /// Drive a scheduler through a scripted single-model arrival trace in
    /// virtual time, exactly as the batcher thread would: arrivals feed in
    /// when the scheduler waits, the stream closes once the trace is
    /// exhausted and a wait can no longer be satisfied.
    fn drive(sched: &mut dyn Scheduler, offsets_ms: &[u64]) -> Vec<(u64, usize)> {
        let base = Instant::now();
        let at = |ms: u64| base + Duration::from_millis(ms);
        let mut out = Vec::new();
        let mut now = base;
        let mut open = true;
        let mut i = 0usize;
        loop {
            match sched.poll(now, open) {
                Decision::Dispatch(b) => out.push((b.id, b.requests.len())),
                Decision::WaitUntil(deadline) => {
                    if i < offsets_ms.len() && at(offsets_ms[i]) <= deadline {
                        now = now.max(at(offsets_ms[i]));
                        sched.enqueue(req(i as u64, 0, at(offsets_ms[i])));
                        i += 1;
                    } else if i < offsets_ms.len() {
                        now = deadline; // timed out waiting
                    } else {
                        open = false; // submitters done, channel closed
                    }
                }
                Decision::WaitForArrival => {
                    if i < offsets_ms.len() {
                        now = now.max(at(offsets_ms[i]));
                        sched.enqueue(req(i as u64, 0, at(offsets_ms[i])));
                        i += 1;
                    } else {
                        open = false;
                    }
                }
                Decision::Idle => break,
            }
        }
        out
    }

    #[test]
    fn fifo_single_model_matches_pre_refactor_batcher() {
        // Bursts, stragglers, deadline gaps and a trailing backlog: every
        // case the old batcher loop distinguished.
        let traces: [&[u64]; 4] = [
            &[0, 1, 2, 3, 4, 20, 21, 40, 41, 42, 43, 44, 45, 100],
            &[0, 50, 100, 150],
            &[0, 0, 0, 0, 0, 0, 0, 0, 0],
            &[7],
        ];
        for (batch, max_wait_ms) in [(4usize, 10u64), (3, 5), (2, 25)] {
            for trace in traces {
                let expected = reference_old_batcher(trace, batch, max_wait_ms);
                let mut sched = make(
                    SchedPolicy::Fifo,
                    models(&[(batch, 1)]),
                    Duration::from_millis(max_wait_ms),
                );
                let got = drive(sched.as_mut(), trace);
                assert_eq!(
                    got, expected,
                    "batch formation diverged (batch={batch}, max_wait={max_wait_ms}ms, \
                     trace={trace:?})"
                );
            }
        }
    }

    #[test]
    fn fifo_serves_models_in_global_arrival_order() {
        let base = Instant::now();
        let mut sched =
            make(SchedPolicy::Fifo, models(&[(2, 1), (2, 1)]), Duration::from_millis(5));
        // Model 1's pair arrives first, then model 0's pair.
        sched.enqueue(req(0, 1, base));
        sched.enqueue(req(1, 1, base + Duration::from_millis(1)));
        sched.enqueue(req(2, 0, base + Duration::from_millis(2)));
        sched.enqueue(req(3, 0, base + Duration::from_millis(3)));
        let now = base + Duration::from_millis(4);
        let first = match sched.poll(now, true) {
            Decision::Dispatch(b) => b,
            other => panic!("expected dispatch, got {other:?}"),
        };
        assert_eq!((first.model, first.requests.len()), (1, 2));
        let second = match sched.poll(now, true) {
            Decision::Dispatch(b) => b,
            other => panic!("expected dispatch, got {other:?}"),
        };
        assert_eq!((second.model, second.requests.len()), (0, 2));
        assert!(matches!(sched.poll(now, true), Decision::WaitForArrival));
    }

    #[test]
    fn fifo_expired_request_preempts_full_batches() {
        // A saturated competitor must not defer another model's max_wait
        // promise: the lone expired model-0 request goes first.
        let base = Instant::now();
        let max_wait = Duration::from_millis(10);
        let batch = 4;
        let mut sched = make(SchedPolicy::Fifo, models(&[(batch, 1), (batch, 1)]), max_wait);
        sched.enqueue(req(0, 0, base));
        let later = base + Duration::from_millis(11);
        for id in 1..=(batch as u64 * 8) {
            sched.enqueue(req(id, 1, later));
        }
        let b = match sched.poll(later, true) {
            Decision::Dispatch(b) => b,
            other => panic!("expected dispatch, got {other:?}"),
        };
        assert_eq!((b.model, b.requests.len()), (0, 1), "expired request must go first");
    }

    /// Saturate every model's queue at `base`, then count which model each
    /// full-batch dispatch goes to.
    fn dispatch_counts(
        sched: &mut dyn Scheduler,
        per_model: usize,
        batch: usize,
        n_models: usize,
        dispatches: usize,
    ) -> Vec<usize> {
        let base = Instant::now();
        let mut id = 0u64;
        for m in 0..n_models {
            for _ in 0..per_model * batch {
                sched.enqueue(req(id, m, base));
                id += 1;
            }
        }
        let mut counts = vec![0usize; n_models];
        for _ in 0..dispatches {
            match sched.poll(base, true) {
                Decision::Dispatch(b) => {
                    assert_eq!(b.requests.len(), batch, "saturated dispatches must be full");
                    counts[b.model] += 1;
                }
                other => panic!("expected dispatch under saturation, got {other:?}"),
            }
        }
        counts
    }

    #[test]
    fn wdrr_serves_proportionally_to_weights_under_saturation() {
        // Weights 1:3, both queues saturated: 32 dispatches = 8 rotations,
        // each rotation serving exactly (1, 3) batches.
        let batch = 4;
        let mut sched =
            make(SchedPolicy::Wdrr, models(&[(batch, 1), (batch, 3)]), Duration::from_secs(3600));
        let counts = dispatch_counts(sched.as_mut(), 40, batch, 2, 32);
        assert_eq!(counts, vec![8, 24], "weighted shares diverged from 1:3");
    }

    #[test]
    fn wdrr_never_starves_a_weight_one_model() {
        // Weight 1 vs weight 64: the light model still lands one full batch
        // per rotation, i.e. at least 2 of the first 2 * (1 + 64) dispatches.
        let batch = 2;
        let mut sched =
            make(SchedPolicy::Wdrr, models(&[(batch, 1), (batch, 64)]), Duration::from_secs(3600));
        let counts = dispatch_counts(sched.as_mut(), 200, batch, 2, 130);
        assert!(counts[0] >= 2, "weight-1 model starved: {counts:?}");
        assert_eq!(counts.iter().sum::<usize>(), 130);
    }

    #[test]
    fn wdrr_expired_deadline_bypasses_the_deficit() {
        let base = Instant::now();
        let max_wait = Duration::from_millis(10);
        let batch = 4;
        let mut sched = make(SchedPolicy::Wdrr, models(&[(batch, 1), (batch, 100)]), max_wait);
        // A lone (partial) model-0 request past its deadline, while model 1
        // has a mountain of fresh full batches.
        sched.enqueue(req(0, 0, base));
        let later = base + Duration::from_millis(11);
        for id in 1..=(batch as u64 * 8) {
            sched.enqueue(req(id, 1, later));
        }
        let b = match sched.poll(later, true) {
            Decision::Dispatch(b) => b,
            other => panic!("expected dispatch, got {other:?}"),
        };
        assert_eq!((b.model, b.requests.len()), (0, 1), "expired request must go first");
    }

    #[test]
    fn drain_dispatches_everything_in_arrival_order() {
        for policy in [SchedPolicy::Fifo, SchedPolicy::Wdrr] {
            let base = Instant::now();
            let mut sched = make(policy, models(&[(4, 1), (4, 2)]), Duration::from_secs(3600));
            sched.enqueue(req(0, 0, base));
            sched.enqueue(req(1, 1, base + Duration::from_millis(1)));
            sched.enqueue(req(2, 0, base + Duration::from_millis(2)));
            let mut sizes = Vec::new();
            loop {
                match sched.poll(base + Duration::from_millis(3), false) {
                    Decision::Dispatch(b) => sizes.push((b.model, b.requests.len())),
                    Decision::Idle => break,
                    other => panic!("drain must dispatch or idle, got {other:?}"),
                }
            }
            assert_eq!(sizes, vec![(0, 2), (1, 1)], "policy {policy:?}");
            assert_eq!(sched.pending(), 0);
        }
    }

    /// Drive a scheduler through a scripted single-model arrival trace in
    /// virtual time under a *simulated finite worker pool* — the continuous
    /// batching regime: a batch can only form when a worker is free, and
    /// arrivals keep landing while workers are busy. Each dispatch occupies
    /// one worker for `service_ms`.
    fn drive_workers(
        sched: &mut dyn Scheduler,
        offsets_ms: &[u64],
        workers: usize,
        service_ms: u64,
    ) -> Vec<(u64, usize)> {
        let base = Instant::now();
        let at = |ms: u64| base + Duration::from_millis(ms);
        let mut free_at: Vec<Instant> = vec![base; workers];
        let mut out = Vec::new();
        let mut now = base;
        let mut open = true;
        let mut i = 0usize;
        loop {
            // The ingester runs concurrently with busy workers: everything
            // due by `now` is already in the forming queues.
            while i < offsets_ms.len() && at(offsets_ms[i]) <= now {
                sched.enqueue(req(i as u64, 0, at(offsets_ms[i])));
                i += 1;
            }
            // No free worker: nothing can pull a batch until one frees up.
            let earliest_free = *free_at.iter().min().unwrap();
            if earliest_free > now {
                now = earliest_free;
                continue; // re-ingest whatever arrived meanwhile
            }
            match sched.poll(now, open) {
                Decision::Dispatch(b) => {
                    out.push((b.id, b.requests.len()));
                    let w = free_at.iter().position(|&f| f <= now).unwrap();
                    free_at[w] = now + Duration::from_millis(service_ms);
                }
                Decision::WaitUntil(deadline) => {
                    if i < offsets_ms.len() && at(offsets_ms[i]) <= deadline {
                        now = now.max(at(offsets_ms[i]));
                    } else if i < offsets_ms.len() {
                        now = deadline; // timed out waiting for batch-mates
                    } else {
                        open = false; // submitters done, channel closed
                    }
                }
                Decision::WaitForArrival => {
                    if i < offsets_ms.len() {
                        now = now.max(at(offsets_ms[i]));
                    } else {
                        open = false;
                    }
                }
                Decision::Idle => break,
            }
        }
        out
    }

    #[test]
    fn continuous_refill_matches_form_then_drain_at_sub_saturation() {
        // The tentpole equivalence gate: under continuous batching with a
        // finite worker pool, as long as the pool is never the bottleneck
        // (sub-saturation: service time <= every inter-dispatch gap), batch
        // formation must be byte-identical to the old form-then-drain
        // batcher. Same traces and (batch, max_wait) matrix as
        // `fifo_single_model_matches_pre_refactor_batcher`.
        let traces: [&[u64]; 4] = [
            &[0, 1, 2, 3, 4, 20, 21, 40, 41, 42, 43, 44, 45, 100],
            &[0, 50, 100, 150],
            &[0, 0, 0, 0, 0, 0, 0, 0, 0],
            &[7],
        ];
        for (batch, max_wait_ms) in [(4usize, 10u64), (3, 5), (2, 25)] {
            for trace in traces {
                let expected = reference_old_batcher(trace, batch, max_wait_ms);
                let mut sched = make(
                    SchedPolicy::Fifo,
                    models(&[(batch, 1)]),
                    Duration::from_millis(max_wait_ms),
                );
                let got = drive_workers(sched.as_mut(), trace, 2, 1);
                assert_eq!(
                    got, expected,
                    "continuous batching diverged (batch={batch}, \
                     max_wait={max_wait_ms}ms, trace={trace:?})"
                );
            }
        }
    }

    #[test]
    fn wdrr_deadline_bypass_under_continuous_refill() {
        // Continuous batching never leaves the heavy model's queue empty:
        // after every pull, four fresh model-1 requests land before the
        // next poll. The lone weight-1 model-0 request must still dispatch
        // the moment its max_wait deadline expires — the bypass has to win
        // against a queue that is *always* full, not just a static backlog.
        let base = Instant::now();
        let batch = 4;
        let max_wait = Duration::from_millis(10);
        let mut sched = make(SchedPolicy::Wdrr, models(&[(batch, 1), (batch, 100)]), max_wait);
        sched.enqueue(req(0, 0, base));
        let mut id = 1u64;
        let mut served = None;
        for step in 0..20u64 {
            let now = base + Duration::from_millis(step);
            for _ in 0..batch {
                sched.enqueue(req(id, 1, now));
                id += 1;
            }
            match sched.poll(now, true) {
                Decision::Dispatch(b) if b.model == 0 => {
                    served = Some((step, b.requests.len()));
                    break;
                }
                Decision::Dispatch(b) => {
                    assert_eq!((b.model, b.requests.len()), (1, batch));
                }
                other => panic!("expected dispatch under refill, got {other:?}"),
            }
        }
        // Expired at exactly base + max_wait; not a poll earlier.
        assert_eq!(served, Some((10, 1)), "deadline bypass failed under continuous refill");
    }

    #[test]
    fn drain_orders_across_models_with_full_batch_chunks() {
        // Drain phase (open == false) under a mixed backlog: the scheduler
        // must empty the queues oldest-front-first, in full-batch chunks,
        // regardless of policy — WDRR deficits don't apply once the stream
        // is closed.
        for policy in [SchedPolicy::Fifo, SchedPolicy::Wdrr] {
            let base = Instant::now();
            let at = |ms: u64| base + Duration::from_millis(ms);
            let mut sched = make(policy, models(&[(2, 1), (3, 5)]), Duration::from_secs(3600));
            // model 0: ids 0(t0), 3(t3), 4(t4); model 1: 1(t1), 2(t2), 5(t5), 6(t6)
            for (id, model, t) in
                [(0, 0, 0), (1, 1, 1), (2, 1, 2), (3, 0, 3), (4, 0, 4), (5, 1, 5), (6, 1, 6)]
            {
                sched.enqueue(req(id, model, at(t)));
            }
            let mut got = Vec::new();
            loop {
                match sched.poll(at(7), false) {
                    Decision::Dispatch(b) => {
                        got.push((b.model, b.requests.iter().map(|r| r.id).collect::<Vec<_>>()));
                    }
                    Decision::Idle => break,
                    other => panic!("drain must dispatch or idle, got {other:?}"),
                }
            }
            let want = vec![
                (0, vec![0, 3]),    // oldest front t0, chunked at batch 2
                (1, vec![1, 2, 5]), // next-oldest front t1, chunked at batch 3
                (0, vec![4]),       // fronts t4 vs t6
                (1, vec![6]),
            ];
            assert_eq!(got, want, "policy {policy:?}");
            assert_eq!(sched.pending(), 0);
        }
    }

    #[test]
    fn shed_expired_drops_only_aged_entries_preserving_order() {
        for policy in [SchedPolicy::Fifo, SchedPolicy::Wdrr] {
            let base = Instant::now();
            let at = |ms: u64| base + Duration::from_millis(ms);
            let mut sched = make(policy, models(&[(4, 1), (4, 1)]), Duration::from_secs(3600));
            // model 0: 0(t0), 1(t5), 2(t10); model 1: 3(t1), 4(t12)
            for (id, model, t) in [(0, 0, 0), (1, 0, 5), (2, 0, 10), (3, 1, 1), (4, 1, 12)] {
                sched.enqueue(req(id, model, at(t)));
            }
            // Cutoff is inclusive: arrived <= expire_before is shed.
            let shed = sched.shed_expired(at(5));
            let shed_ids: Vec<u64> = shed.iter().map(|r| r.id).collect();
            assert_eq!(shed_ids, vec![0, 1, 3], "policy {policy:?}");
            assert_eq!(sched.pending(), 2);
            assert_eq!(sched.pending_for(0), 1);
            assert_eq!(sched.pending_for(1), 1);
            // Survivors keep their order and stay dispatchable: drain
            // serves the t10 model-0 front before the t12 model-1 front.
            let mut got = Vec::new();
            loop {
                match sched.poll(at(13), false) {
                    Decision::Dispatch(b) => {
                        got.push((b.model, b.requests.iter().map(|r| r.id).collect::<Vec<_>>()));
                    }
                    Decision::Idle => break,
                    other => panic!("drain must dispatch or idle, got {other:?}"),
                }
            }
            assert_eq!(got, vec![(0, vec![2]), (1, vec![4])], "policy {policy:?}");
            // Nothing left, and shedding an empty scheduler is a no-op.
            assert!(sched.shed_expired(at(100)).is_empty());
            assert_eq!(sched.pending(), 0);
        }
    }

    #[test]
    fn take_all_empties_every_queue() {
        let base = Instant::now();
        let mut sched =
            make(SchedPolicy::Fifo, models(&[(4, 1), (4, 1)]), Duration::from_millis(1));
        for id in 0..5u64 {
            sched.enqueue(req(id, (id % 2) as usize, base));
        }
        assert_eq!(sched.pending(), 5);
        assert_eq!(sched.pending_for(0), 3);
        let all = sched.take_all();
        assert_eq!(all.len(), 5);
        assert_eq!(sched.pending(), 0);
        assert!(matches!(sched.poll(base, false), Decision::Idle));
    }
}
