//! Request queue + dynamic batcher over the engine.
//!
//! Requests (one sequence each) arrive on a queue; the batcher groups up to
//! the artifact batch size within a timeout, pads the batch, runs one engine
//! forward and reports per-request latency — the serving shape of the
//! Fig. 11 end-to-end evaluation.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::tensor::DenseTensor;

use super::engine::Engine;

/// One served request: a token sequence (padded/truncated to the model's
/// sequence length).
#[derive(Debug, Clone)]
pub struct Request {
    /// Request id.
    pub id: u64,
    /// Tokens.
    pub tokens: Vec<i32>,
    /// Enqueue timestamp.
    pub arrived: Instant,
}

/// Completion record for one request.
#[derive(Debug, Clone)]
pub struct RequestResult {
    /// Request id.
    pub id: u64,
    /// Queueing delay (arrival -> batch start).
    pub queue_s: f64,
    /// Model execution time of the batch this request rode in.
    pub compute_s: f64,
    /// End-to-end latency.
    pub total_s: f64,
    /// How many requests shared the batch.
    pub batch_size: usize,
}

/// Synchronous dynamic batcher: callers enqueue, `run_until_drained` forms
/// batches and executes them in arrival order.
pub struct BatchServer {
    engine: Engine,
    queue: VecDeque<Request>,
    /// Max time a request may wait for batch-mates.
    pub max_wait: Duration,
    next_id: u64,
    /// Completed request records.
    pub completed: Vec<RequestResult>,
}

impl BatchServer {
    /// Server over an engine.
    pub fn new(engine: Engine, max_wait: Duration) -> Self {
        BatchServer { engine, queue: VecDeque::new(), max_wait, next_id: 0, completed: Vec::new() }
    }

    /// The wrapped engine.
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Enqueue a request; tokens are clamped to vocab and padded/truncated
    /// to the model sequence length. Returns the request id.
    pub fn submit(&mut self, tokens: &[i32]) -> u64 {
        let dims = &self.engine.dims;
        let mut t: Vec<i32> = tokens
            .iter()
            .map(|&x| x.rem_euclid(dims.vocab as i32))
            .take(dims.seq)
            .collect();
        t.resize(dims.seq, 0);
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Request { id, tokens: t, arrived: Instant::now() });
        id
    }

    /// Form and execute batches until the queue is empty.
    pub fn run_until_drained(&mut self) -> Result<()> {
        while !self.queue.is_empty() {
            self.run_one_batch()?;
        }
        Ok(())
    }

    /// Execute a single batch (up to the artifact batch size; padded with
    /// copies of the last request if underfull).
    pub fn run_one_batch(&mut self) -> Result<Option<DenseTensor>> {
        let dims = self.engine.dims.clone();
        if self.queue.is_empty() {
            return Ok(None);
        }
        let take = self.queue.len().min(dims.batch);
        let batch: Vec<Request> = (0..take).map(|_| self.queue.pop_front().unwrap()).collect();
        let start = Instant::now();

        // Pad to the fixed artifact batch by repeating the last sequence.
        let mut tokens = Vec::with_capacity(dims.batch * dims.seq);
        for r in &batch {
            tokens.extend_from_slice(&r.tokens);
        }
        let last = batch.last().unwrap().tokens.clone();
        for _ in take..dims.batch {
            tokens.extend_from_slice(&last);
        }

        let logits = self.engine.forward(&tokens)?;
        let compute_s = start.elapsed().as_secs_f64();
        let done = Instant::now();
        for r in &batch {
            self.completed.push(RequestResult {
                id: r.id,
                queue_s: (start - r.arrived).as_secs_f64(),
                compute_s,
                total_s: (done - r.arrived).as_secs_f64(),
                batch_size: take,
            });
        }
        Ok(Some(logits))
    }

    /// Median end-to-end latency over completed requests.
    pub fn median_latency(&self) -> Option<f64> {
        if self.completed.is_empty() {
            return None;
        }
        let mut v: Vec<f64> = self.completed.iter().map(|r| r.total_s).collect();
        v.sort_by(|a, b| a.total_cmp(b));
        Some(v[v.len() / 2])
    }

    /// Requests per second over completed requests (compute time only).
    pub fn throughput(&self) -> Option<f64> {
        if self.completed.is_empty() {
            return None;
        }
        // Each batch's compute time is shared by its riders.
        let mut total_compute = 0.0;
        let mut seen = std::collections::HashSet::new();
        for r in &self.completed {
            // compute_s is identical for batch-mates; count each batch once
            // (keyed by bit pattern).
            if seen.insert(r.compute_s.to_bits()) {
                total_compute += r.compute_s;
            }
        }
        Some(self.completed.len() as f64 / total_compute)
    }
}
