//! Request queue + synchronous dynamic batcher over one engine.
//!
//! Requests (one sequence each) arrive on a queue; the batcher groups up to
//! the artifact batch size within the `max_wait` timeout, pads the batch,
//! runs one engine forward and reports per-request latency — the serving
//! shape of the Fig. 11 end-to-end evaluation.
//!
//! Deadline semantics (honored since the `max_wait` regression fix): a
//! *full* batch dispatches immediately; an underfull batch dispatches as
//! soon as the oldest queued request has waited `max_wait`, padded with
//! copies of the last sequence. [`BatchServer`] is the single-threaded
//! drain-loop baseline; the concurrent, multi-replica front-end lives in
//! [`super::concurrent`].

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::tensor::DenseTensor;

use super::engine::{EncoderDims, Engine};
use super::metrics;

/// One served request: a token sequence (padded/truncated to the model's
/// sequence length).
#[derive(Debug, Clone)]
pub struct Request {
    /// Request id.
    pub id: u64,
    /// Tokens.
    pub tokens: Vec<i32>,
    /// Index of the target model in the server's registry (registration
    /// order); always 0 on single-model servers like [`BatchServer`].
    pub model: usize,
    /// Enqueue timestamp.
    pub arrived: Instant,
}

/// Completion record for one request. Only *completions* produce one of
/// these: requests rejected by admission control or shed past their SLO
/// are reported as counts in the concurrent server's `ServeReport`, never
/// as results. A degraded request completes (and is recorded) under the
/// model that actually served it.
#[derive(Debug, Clone)]
pub struct RequestResult {
    /// Request id.
    pub id: u64,
    /// Index of the model that served the request (registry order).
    pub model: usize,
    /// Id of the batch this request rode in (unique per server).
    pub batch_id: u64,
    /// Queueing delay (arrival -> batch formation).
    pub queue_s: f64,
    /// Model execution time of the batch this request rode in.
    pub compute_s: f64,
    /// End-to-end latency.
    pub total_s: f64,
    /// How many real requests shared the batch (excluding padding).
    pub batch_size: usize,
}

/// Clamp tokens to the vocabulary and pad/truncate to the model sequence
/// length.
pub(super) fn canonical_tokens(dims: &EncoderDims, tokens: &[i32]) -> Vec<i32> {
    let mut t: Vec<i32> = tokens
        .iter()
        .map(|&x| x.rem_euclid(dims.vocab as i32))
        .take(dims.seq)
        .collect();
    t.resize(dims.seq, 0);
    t
}

/// Concatenate the batch's sequences and pad to the fixed artifact batch by
/// repeating the last sequence.
pub(super) fn pad_batch_tokens(dims: &EncoderDims, batch: &[Request]) -> Vec<i32> {
    assert!(!batch.is_empty() && batch.len() <= dims.batch);
    let mut tokens = Vec::with_capacity(dims.batch * dims.seq);
    for r in batch {
        tokens.extend_from_slice(&r.tokens);
    }
    let last = &batch.last().unwrap().tokens;
    for _ in batch.len()..dims.batch {
        tokens.extend_from_slice(last);
    }
    tokens
}

/// Synchronous dynamic batcher: callers enqueue, `run_until_drained` forms
/// batches and executes them in arrival order. This is the single-threaded
/// baseline the concurrent server is benchmarked against.
pub struct BatchServer {
    engine: Engine,
    queue: VecDeque<Request>,
    /// Max time a request may wait for batch-mates.
    pub max_wait: Duration,
    next_id: u64,
    next_batch_id: u64,
    /// Completed request records.
    pub completed: Vec<RequestResult>,
}

impl BatchServer {
    /// Server over an engine.
    pub fn new(engine: Engine, max_wait: Duration) -> Self {
        BatchServer {
            engine,
            queue: VecDeque::new(),
            max_wait,
            next_id: 0,
            next_batch_id: 0,
            completed: Vec::new(),
        }
    }

    /// The wrapped engine.
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Enqueue a request; tokens are clamped to vocab and padded/truncated
    /// to the model sequence length. Returns the request id.
    pub fn submit(&mut self, tokens: &[i32]) -> u64 {
        let t = canonical_tokens(&self.engine.dims, tokens);
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Request { id, tokens: t, model: 0, arrived: Instant::now() });
        id
    }

    /// Form and execute batches until the queue is empty.
    pub fn run_until_drained(&mut self) -> Result<()> {
        while !self.queue.is_empty() {
            self.run_one_batch()?;
        }
        Ok(())
    }

    /// Execute a single batch honoring the `max_wait` contract: a full
    /// batch (artifact batch size) dispatches immediately; an underfull
    /// batch waits until the oldest request has aged `max_wait` (no
    /// batch-mates can arrive while this single-threaded server runs, but
    /// the deadline is the documented dispatch point and the latency
    /// numbers must reflect it), then dispatches padded.
    pub fn run_one_batch(&mut self) -> Result<Option<DenseTensor>> {
        let dims = self.engine.dims.clone();
        if self.queue.is_empty() {
            return Ok(None);
        }
        if self.queue.len() < dims.batch {
            let deadline = self.queue.front().unwrap().arrived + self.max_wait;
            let now = Instant::now();
            if now < deadline {
                std::thread::sleep(deadline - now);
            }
        }
        let take = self.queue.len().min(dims.batch);
        let batch: Vec<Request> = self.queue.drain(..take).collect();
        let batch_id = self.next_batch_id;
        self.next_batch_id += 1;
        let formed = Instant::now();

        let tokens = pad_batch_tokens(&dims, &batch);
        let logits = self.engine.forward(&tokens)?;
        let compute_s = formed.elapsed().as_secs_f64();
        let done = Instant::now();
        for r in &batch {
            self.completed.push(RequestResult {
                id: r.id,
                model: r.model,
                batch_id,
                queue_s: (formed - r.arrived).as_secs_f64(),
                compute_s,
                total_s: (done - r.arrived).as_secs_f64(),
                batch_size: take,
            });
        }
        Ok(Some(logits))
    }

    /// Median end-to-end latency over completed requests.
    pub fn median_latency(&self) -> Option<f64> {
        metrics::summarize(&self.completed).map(|s| s.p50)
    }

    /// Latency percentiles over completed requests.
    pub fn latency_summary(&self) -> Option<metrics::LatencySummary> {
        metrics::summarize(&self.completed)
    }

    /// Requests per second over completed requests (compute time only),
    /// counting each batch's compute once (keyed by `batch_id`).
    pub fn throughput(&self) -> Option<f64> {
        metrics::compute_throughput(&self.completed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> EncoderDims {
        EncoderDims { vocab: 100, seq: 4, batch: 3, d_model: 8, d_ff: 16, n_layers: 1 }
    }

    fn req(id: u64, tokens: Vec<i32>) -> Request {
        Request { id, tokens, model: 0, arrived: Instant::now() }
    }

    #[test]
    fn canonical_tokens_clamps_pads_and_truncates() {
        let d = dims();
        assert_eq!(canonical_tokens(&d, &[-5, 999, 1]), vec![95, 99, 1, 0]);
        assert_eq!(canonical_tokens(&d, &[1, 2, 3, 4, 5, 6]), vec![1, 2, 3, 4]);
        assert_eq!(canonical_tokens(&d, &[]), vec![0, 0, 0, 0]);
    }

    #[test]
    fn padding_repeats_the_last_sequence() {
        let d = dims();
        let batch = vec![req(0, vec![1, 2, 3, 4]), req(1, vec![5, 6, 7, 8])];
        let tokens = pad_batch_tokens(&d, &batch);
        assert_eq!(tokens.len(), d.batch * d.seq);
        assert_eq!(&tokens[..4], &[1, 2, 3, 4]);
        assert_eq!(&tokens[4..8], &[5, 6, 7, 8]);
        // The pad slot repeats the last real sequence.
        assert_eq!(&tokens[8..12], &[5, 6, 7, 8]);
    }

    #[test]
    fn full_batch_needs_no_padding() {
        let d = dims();
        let batch: Vec<Request> =
            (0..3).map(|i| req(i, vec![i as i32; 4])).collect();
        let tokens = pad_batch_tokens(&d, &batch);
        assert_eq!(&tokens[8..12], &[2, 2, 2, 2]);
    }
}
