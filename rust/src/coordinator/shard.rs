//! Tensor-parallel sharded model execution.
//!
//! A [`ShardedModel`] splits one engine's weights across `W` shard engines
//! that execute every batch *cooperatively*: each shard owns a disjoint
//! slice of the attention heads, the FFN hidden dimension, the output
//! projection rows and the vocabulary, and the shards meet at
//! [`ShardGroup`](crate::dist::ShardGroup) ring collectives at each seam.
//! Shard threads are **dedicated** [`WorkerPool`] workers — never
//! threadpool-scope chunks — because a collective blocks until all `W`
//! ranks arrive, and a blocked chunk inside a pool scope could deadlock the
//! pool (see `util::threadpool`). `W` cooperative jobs on a `W`-thread
//! `WorkerPool` always land on `W` distinct workers: a worker cannot take a
//! second job until its first completes, and no job completes until all
//! have run.
//!
//! # Exact sharded-vs-unsharded equivalence
//!
//! All sharded GEMMs run in *transposed* space: activations are carried as
//! `X^T` so each shard computes contiguous **row** ranges of the transposed
//! result — `Q^T = Wq^T·Y^T`, `H^T = W1^T·Y^T`, etc. — and the seams are
//! ring allgathers over those contiguous row segments. This makes dense
//! sharded execution **bit-identical** to the unsharded engine at *any*
//! split boundary, because of two properties of `dense_gemm`:
//!
//! 1. Row (M-dimension) slicing never changes a result element's
//!    accumulation order (k-blocks and column tiles are absolute), so a
//!    shard's `matmul(W^T rows [lo, hi), Y^T)` equals those rows of the
//!    full product bitwise.
//! 2. `A·B` and `(B^T·A^T)^T` are bit-identical when both outputs consist
//!    of full 16-wide column tiles (IEEE multiplication commutes exactly
//!    and the k-grouping matches). The transposed products have
//!    `N = batch·seq` columns and the unsharded ones `N ∈ {d_model, d_ff,
//!    vocab}` — all multiples of 16 for the shipped configs (asserted at
//!    shard time; non-multiple shapes still shard correctly, just with
//!    allclose- rather than bit-level equivalence).
//!
//! Sparse formats shard along their natural boundaries — n:m:g by slab
//! ([`NmgTensor::slice_slabs`]), BCSR by block row
//! ([`BcsrTensor::slice_block_rows`]) — so autotuned formats survive
//! sharding; their kernels produce exactly the sliced output rows.
//!
//! The FFN's second linear supports two seams ([`SeamMode`]): the default
//! `Allgather` keeps `W2^T` row-parallel after gathering the full hidden
//! activation (bit-identical, one allgather each side); `Allreduce` is the
//! classic Megatron-style row-parallel `W2` whose partial outputs are
//! summed with a ring allreduce (deterministic ring-order reduction, but a
//! *different* order than the unsharded GEMM — allclose, not bit-equal).
//!
//! Synchronization goes through the `util::sync` shim (this file is
//! lint-ported) and the collective barrier has a loom model in
//! `tests/loom.rs`.

use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::dist::ShardGroup;
use crate::formats::{AnyTensor, BcsrTensor, NmgTensor};
use crate::kernels::{bcsr_gemm, dense_gemm, elementwise, nmg_gemm};
use crate::tensor::DenseTensor;
use crate::util::sync::{Arc, Mutex};
use crate::util::threadpool::WorkerPool;
use crate::util::timer::TimeBreakdown;

use super::concurrent::CompletionLatch;
use super::engine::{EncoderDims, Engine, FfnMode};

/// How the FFN's second linear combines shard partials.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SeamMode {
    /// Gather the full hidden activation, then compute disjoint output
    /// rows (`W2^T` row-parallel). Bit-identical to unsharded dense.
    #[default]
    Allgather,
    /// Classic row-parallel `W2`: each shard computes a full-size partial
    /// output from its hidden slice; partials are ring-allreduce-summed.
    /// Deterministic (fixed ring order) but allclose to unsharded, not
    /// bit-equal.
    Allreduce,
}

/// Balanced `[0 ..= w]` split bounds of `total` in multiples of `align`
/// (the remainder spread over the low shards; the final bound is clamped
/// to `total`, so with `align > 1` the last shard absorbs the ragged
/// tail). Empty shards (`bounds[i] == bounds[i+1]`) are legal and arise
/// when `total / align < w`.
pub fn shard_bounds(total: usize, w: usize, align: usize) -> Vec<usize> {
    assert!(w >= 1, "need at least one shard");
    assert!(align >= 1, "alignment must be positive");
    let units = total.div_ceil(align);
    let (q, r) = (units / w, units % w);
    (0..=w).map(|i| ((i * q + i.min(r)) * align).min(total)).collect()
}

/// This shard's slice of one layer's first FFN linear, stored transposed
/// (`W1^T` rows `[ff_lo, ff_hi)`) in the format the engine serves.
enum W1Slice {
    /// No rows on this shard.
    Empty,
    /// Dense `(ff_hi - ff_lo, d_model)`.
    Dense(DenseTensor),
    /// n:m:g slab range.
    Nmg(NmgTensor),
    /// BCSR block-row range.
    Bcsr(BcsrTensor),
}

/// Per-layer attention weights, pre-sliced for one shard.
struct AttnShard {
    ln_g: Arc<DenseTensor>,
    ln_b: Arc<DenseTensor>,
    /// Rows `[hc_lo, hc_hi)` of `Wq^T` / `Wk^T` / `Wv^T` — this shard's
    /// head columns, transposed: shape `(hc, d_model)`.
    wqt: DenseTensor,
    wkt: DenseTensor,
    wvt: DenseTensor,
    bq: Vec<f32>,
    bk: Vec<f32>,
    bv: Vec<f32>,
    /// Rows `[dm_lo, dm_hi)` of `Wo^T`: shape `(dm, d_model)`.
    wot: DenseTensor,
    bo: Vec<f32>,
}

/// This shard's slice of one layer's second FFN linear.
enum W2Seam {
    /// Rows `[dm_lo, dm_hi)` of `W2^T` (shape `(dm, d_ff)`) plus the
    /// matching `b2` slice.
    Allgather { w2t: DenseTensor, b2: Vec<f32> },
    /// Rows `[ff_lo, ff_hi)` of `W2` (shape `(ff, d_model)`) plus the
    /// *full* `b2` (added after the reduction).
    Allreduce { w2: DenseTensor, b2: Vec<f32> },
}

/// Per-layer FFN weights, pre-sliced for one shard.
struct FfnShard {
    ln_g: Arc<DenseTensor>,
    ln_b: Arc<DenseTensor>,
    w1t: W1Slice,
    b1: Vec<f32>,
    /// Full `[0 ..= w]` hidden-dimension bounds for this layer (aligned to
    /// the format's slab/block size — they can differ per layer when
    /// autotuning picked different formats).
    ff_bounds: Vec<usize>,
    w2: W2Seam,
}

/// Everything immutable a shard needs: pre-sliced weights and the split
/// bounds. `Arc`-shared between replicas of the same sharded model, and
/// the replicated parameters (layernorms, embeddings) are `Arc` clones of
/// the source engine's allocations — zero copies of unsliced weights.
struct ShardWeights {
    emb: Arc<DenseTensor>,
    pos: Arc<DenseTensor>,
    layers: Vec<(AttnShard, FfnShard)>,
    lnf_g: Arc<DenseTensor>,
    lnf_b: Arc<DenseTensor>,
    /// Rows `[v_lo, v_hi)` of `out_w^T`: shape `(v, d_model)`.
    out_wt: DenseTensor,
    out_b: Vec<f32>,
    /// Head-column bounds (head index bounds × head dim).
    hc_bounds: Vec<usize>,
    /// d_model row bounds (attention projection / FFN output rows).
    dm_bounds: Vec<usize>,
    /// Vocabulary row bounds (LM head).
    v_bounds: Vec<usize>,
}

/// One rank of a sharded model: its weight slices plus private timing.
pub struct ShardEngine {
    rank: usize,
    world: usize,
    dims: EncoderDims,
    n_heads: usize,
    seam: SeamMode,
    weights: Arc<ShardWeights>,
    times: TimeBreakdown,
}

/// Copy rows `[r0, r1)` of a row-major 2-D tensor.
fn row_slice(t: &DenseTensor, r0: usize, r1: usize) -> DenseTensor {
    let c = t.cols();
    DenseTensor::from_vec(&[r1 - r0, c], t.data()[r0 * c..r1 * c].to_vec())
}

/// Copy the rectangular block rows `[r0, r0+nr)` × cols `[c0, c0+nc)`.
fn block(t: &DenseTensor, r0: usize, nr: usize, c0: usize, nc: usize) -> DenseTensor {
    let cols = t.cols();
    let mut out = vec![0f32; nr * nc];
    for r in 0..nr {
        let src = (r0 + r) * cols + c0;
        out[r * nc..(r + 1) * nc].copy_from_slice(&t.data()[src..src + nc]);
    }
    DenseTensor::from_vec(&[nr, nc], out)
}

/// `out[r, c] = t[r, c] + bias[r]` — the transposed-layout form of
/// `elementwise::bias_add` (bias varies per *row*). Same scalar additions
/// as the row-major form, so results stay bit-identical to it.
fn bias_add_rows(t: &DenseTensor, bias: &[f32]) -> DenseTensor {
    let (r, c) = (t.rows(), t.cols());
    assert_eq!(r, bias.len(), "row-bias length mismatch");
    let mut out = t.data().to_vec();
    for (i, &b) in bias.iter().enumerate() {
        for v in &mut out[i * c..(i + 1) * c] {
            *v += b;
        }
    }
    DenseTensor::from_vec(&[r, c], out)
}

/// Element-count bounds for an allgather over row ranges of a transposed
/// `(R, cols)` buffer: row bounds × cols.
fn elem_bounds(bounds: &[usize], cols: usize) -> Vec<usize> {
    bounds.iter().map(|&b| b * cols).collect()
}

/// This thread's cumulative CPU time (user + system) from
/// `/proc/thread-self/stat`, or `None` off Linux. Used for the per-shard
/// `cpu` timing bucket: on machines with fewer cores than shards,
/// wall-clock hides the per-shard speedup that CPU time still shows.
fn thread_cpu_time() -> Option<Duration> {
    let stat = std::fs::read_to_string("/proc/thread-self/stat").ok()?;
    // Fields 14/15 (1-based: utime, stime) count from after the comm field,
    // which is parenthesized and may contain spaces.
    let rest = &stat[stat.rfind(')')? + 2..];
    let mut it = rest.split_ascii_whitespace();
    let utime: u64 = it.nth(11)?.parse().ok()?;
    let stime: u64 = it.next()?.parse().ok()?;
    // Jiffies at the kernel's USER_HZ, which is 100 on every Linux ABI.
    Some(Duration::from_millis((utime + stime) * 10))
}

impl ShardEngine {
    /// This shard's rank in `[0, world)`.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Accumulated per-shard timing: `compute` (local kernels),
    /// `collective` (time inside allgather/allreduce, including barrier
    /// waits) and `cpu` (thread CPU time, Linux only).
    pub fn timing(&self) -> &TimeBreakdown {
        &self.times
    }

    /// Reset the accumulated timing.
    pub fn reset_timing(&mut self) {
        self.times = TimeBreakdown::new();
    }

    /// Replicated embedding: same math as the runtime's `embed_` artifact
    /// (token row + position row), so every shard starts from the same
    /// activations as the unsharded engine, bitwise.
    fn embed(&self, tokens: &[i32]) -> DenseTensor {
        let (d, s, v) = (self.dims.d_model, self.dims.seq, self.dims.vocab);
        let w = &self.weights;
        let (embd, posd) = (w.emb.data(), w.pos.data());
        let rows = tokens.len();
        let mut out = vec![0f32; rows * d];
        for r in 0..rows {
            let tok = tokens[r].rem_euclid(v as i32) as usize;
            let e = &embd[tok * d..(tok + 1) * d];
            let p = &posd[(r % s) * d..(r % s + 1) * d];
            for (j, o) in out[r * d..(r + 1) * d].iter_mut().enumerate() {
                *o = e[j] + p[j];
            }
        }
        DenseTensor::from_vec(&[rows, d], out)
    }

    /// Pre-LN multi-head attention with residual, head-sharded: this rank
    /// computes `Q^T/K^T/V^T` for its head columns, runs its heads'
    /// score/softmax/value pipelines, allgathers the transposed attention
    /// output, computes its `Wo^T` row range of the projection, and
    /// allgathers again before the (replicated) residual add.
    fn attn_block(
        &self,
        l: usize,
        x: &DenseTensor,
        group: &ShardGroup,
        coll: &mut Duration,
    ) -> DenseTensor {
        let (b, s, d) = (self.dims.batch, self.dims.seq, self.dims.d_model);
        let rows = b * s;
        let hd = d / self.n_heads;
        let w = &self.weights.layers[l].0;
        let (hc_lo, hc_hi) =
            (self.weights.hc_bounds[self.rank], self.weights.hc_bounds[self.rank + 1]);

        let y = elementwise::layernorm_rows(x, w.ln_g.data(), w.ln_b.data());
        let yt = y.transpose2();

        let mut ot = vec![0f32; d * rows];
        if hc_hi > hc_lo {
            let qt = bias_add_rows(&dense_gemm::matmul(&w.wqt, &yt), &w.bq);
            let kt = bias_add_rows(&dense_gemm::matmul(&w.wkt, &yt), &w.bk);
            let vt = bias_add_rows(&dense_gemm::matmul(&w.wvt, &yt), &w.bv);
            let scale = 1.0 / (hd as f32).sqrt();
            for h in 0..(hc_hi - hc_lo) / hd {
                for bi in 0..b {
                    let qb = block(&qt, h * hd, hd, bi * s, s).transpose2();
                    let kbt = block(&kt, h * hd, hd, bi * s, s);
                    let vb = block(&vt, h * hd, hd, bi * s, s).transpose2();
                    let mut scores = dense_gemm::matmul_serial(&qb, &kbt);
                    scores.scale(scale);
                    let att = elementwise::softmax_rows(&scores);
                    let ob = dense_gemm::matmul_serial(&att, &vb); // (s, hd)
                    let obd = ob.data();
                    for c in 0..hd {
                        let dst = (hc_lo + h * hd + c) * rows + bi * s;
                        for r in 0..s {
                            ot[dst + r] = obd[r * hd + c];
                        }
                    }
                }
            }
        }
        let t = Instant::now();
        group.allgather(self.rank, &mut ot, &elem_bounds(&self.weights.hc_bounds, rows));
        *coll += t.elapsed();
        let ot = DenseTensor::from_vec(&[d, rows], ot);

        let (dm_lo, dm_hi) =
            (self.weights.dm_bounds[self.rank], self.weights.dm_bounds[self.rank + 1]);
        let mut pt = vec![0f32; d * rows];
        if dm_hi > dm_lo {
            let p = bias_add_rows(&dense_gemm::matmul(&w.wot, &ot), &w.bo);
            pt[dm_lo * rows..dm_hi * rows].copy_from_slice(p.data());
        }
        let t = Instant::now();
        group.allgather(self.rank, &mut pt, &elem_bounds(&self.weights.dm_bounds, rows));
        *coll += t.elapsed();
        let proj = DenseTensor::from_vec(&[d, rows], pt).transpose2();
        x.zip(&proj, |a, c| a + c)
    }

    /// Pre-LN FFN with residual: column-parallel `W1` (this rank's hidden
    /// rows, sparse formats sliced on their natural boundaries), then the
    /// configured [`SeamMode`] for `W2`.
    fn ffn_block(
        &self,
        l: usize,
        x: &DenseTensor,
        group: &ShardGroup,
        coll: &mut Duration,
    ) -> DenseTensor {
        let (b, s, d) = (self.dims.batch, self.dims.seq, self.dims.d_model);
        let (rows, f) = (b * s, self.dims.d_ff);
        let w = &self.weights.layers[l].1;
        let (ff_lo, ff_hi) = (w.ff_bounds[self.rank], w.ff_bounds[self.rank + 1]);

        let y = elementwise::layernorm_rows(x, w.ln_g.data(), w.ln_b.data());
        let yt = y.transpose2();

        // This rank's hidden rows, transposed: (ff_hi - ff_lo, rows).
        let ht_s = match &w.w1t {
            W1Slice::Empty => None,
            W1Slice::Dense(w1t) => Some(dense_gemm::matmul(w1t, &yt)),
            W1Slice::Nmg(w1t) => Some(nmg_gemm::spmm(w1t, &yt)),
            W1Slice::Bcsr(w1t) => Some(bcsr_gemm::spmm(w1t, &yt)),
        };
        let ht_s = ht_s.map(|h| elementwise::gelu(&bias_add_rows(&h, &w.b1)));

        match &w.w2 {
            W2Seam::Allgather { w2t, b2 } => {
                let mut ht = vec![0f32; f * rows];
                if let Some(h) = &ht_s {
                    ht[ff_lo * rows..ff_hi * rows].copy_from_slice(h.data());
                }
                let t = Instant::now();
                group.allgather(self.rank, &mut ht, &elem_bounds(&w.ff_bounds, rows));
                *coll += t.elapsed();
                let ht = DenseTensor::from_vec(&[f, rows], ht);

                let (dm_lo, dm_hi) =
                    (self.weights.dm_bounds[self.rank], self.weights.dm_bounds[self.rank + 1]);
                let mut ot = vec![0f32; d * rows];
                if dm_hi > dm_lo {
                    let o = bias_add_rows(&dense_gemm::matmul(w2t, &ht), b2);
                    ot[dm_lo * rows..dm_hi * rows].copy_from_slice(o.data());
                }
                let t = Instant::now();
                group.allgather(self.rank, &mut ot, &elem_bounds(&self.weights.dm_bounds, rows));
                *coll += t.elapsed();
                let o = DenseTensor::from_vec(&[d, rows], ot).transpose2();
                x.zip(&o, |a, c| a + c)
            }
            W2Seam::Allreduce { w2, b2 } => {
                // Partial output from this rank's hidden slice; ring-summed.
                let mut partial = match &ht_s {
                    Some(h) => dense_gemm::matmul(&h.transpose2(), w2),
                    None => DenseTensor::zeros(&[rows, d]),
                };
                let t = Instant::now();
                group.allreduce_sum(self.rank, partial.data_mut());
                *coll += t.elapsed();
                let o = elementwise::bias_add(&partial, b2);
                x.zip(&o, |a, c| a + c)
            }
        }
    }

    /// One full forward on this rank. Collective: all `world` ranks must
    /// call concurrently with the same tokens. Returns the full logits
    /// `(batch, seq, vocab)` (identical on every rank).
    fn forward_local(&mut self, tokens: &[i32], group: &ShardGroup) -> DenseTensor {
        let t_all = Instant::now();
        let cpu0 = thread_cpu_time();
        let mut coll = Duration::ZERO;
        let (b, s, v) = (self.dims.batch, self.dims.seq, self.dims.vocab);
        let rows = b * s;

        let mut x = self.embed(tokens);
        for l in 0..self.dims.n_layers {
            x = self.attn_block(l, &x, group, &mut coll);
            x = self.ffn_block(l, &x, group, &mut coll);
        }

        let w = Arc::clone(&self.weights);
        let y = elementwise::layernorm_rows(&x, w.lnf_g.data(), w.lnf_b.data());
        let yt = y.transpose2();
        let (v_lo, v_hi) = (w.v_bounds[self.rank], w.v_bounds[self.rank + 1]);
        let mut lt = vec![0f32; v * rows];
        if v_hi > v_lo {
            let part = bias_add_rows(&dense_gemm::matmul(&w.out_wt, &yt), &w.out_b);
            lt[v_lo * rows..v_hi * rows].copy_from_slice(part.data());
        }
        let t = Instant::now();
        group.allgather(self.rank, &mut lt, &elem_bounds(&w.v_bounds, rows));
        coll += t.elapsed();
        let logits = DenseTensor::from_vec(&[v, rows], lt).transpose2().reshape(&[b, s, v]);

        self.times.add("collective", coll);
        self.times.add("compute", t_all.elapsed().saturating_sub(coll));
        if let (Some(c0), Some(c1)) = (cpu0, thread_cpu_time()) {
            self.times.add("cpu", c1.saturating_sub(c0));
        }
        logits
    }
}

/// A model executed cooperatively by `W` shard engines on a dedicated
/// worker pool. Construct via [`Engine::shard`]; replicate via
/// [`ShardedModel::replicate`] (weight slices are `Arc`-shared, never
/// re-sliced). `forward` takes `&mut self`: one batch at a time per
/// instance — run several replicas for concurrent sharded batches.
pub struct ShardedModel {
    shards: Arc<Vec<Mutex<ShardEngine>>>,
    group: Arc<ShardGroup>,
    pool: WorkerPool,
    world: usize,
    dims: EncoderDims,
}

impl ShardedModel {
    /// Split `engine`'s weights into `world` shard engines.
    pub(crate) fn from_engine(engine: &Engine, world: usize, seam: SeamMode) -> Result<Self> {
        assert!(world >= 1, "need at least one shard");
        let dims = engine.dims.clone();
        let n_heads = engine.n_heads()?;
        if dims.d_model % n_heads != 0 {
            return Err(anyhow!("d_model {} % n_heads {n_heads} != 0", dims.d_model));
        }
        let hd = dims.d_model / n_heads;
        let (params, nmg_w1t, tuned_w1t) = engine.weights_view();

        let head_bounds = shard_bounds(n_heads, world, 1);
        let hc_bounds: Vec<usize> = head_bounds.iter().map(|&h| h * hd).collect();
        let dm_bounds = shard_bounds(dims.d_model, world, 1);
        let v_bounds = shard_bounds(dims.vocab, world, 1);

        let p = |name: &str| -> Result<&Arc<DenseTensor>> {
            params.get(name).ok_or_else(|| anyhow!("missing parameter {name}"))
        };

        let mut shards = Vec::with_capacity(world);
        for rank in 0..world {
            let (hc_lo, hc_hi) = (hc_bounds[rank], hc_bounds[rank + 1]);
            let (dm_lo, dm_hi) = (dm_bounds[rank], dm_bounds[rank + 1]);
            let (v_lo, v_hi) = (v_bounds[rank], v_bounds[rank + 1]);
            let mut layers = Vec::with_capacity(dims.n_layers);
            for l in 0..dims.n_layers {
                let key = |n: &str| format!("layer{l}.{n}");
                let slice_qkv = |w_name: &str, b_name: &str| -> Result<(DenseTensor, Vec<f32>)> {
                    let wt = p(&key(w_name))?.transpose2();
                    Ok((
                        row_slice(&wt, hc_lo, hc_hi),
                        p(&key(b_name))?.data()[hc_lo..hc_hi].to_vec(),
                    ))
                };
                let (wqt, bq) = slice_qkv("wq", "bq")?;
                let (wkt, bk) = slice_qkv("wk", "bk")?;
                let (wvt, bv) = slice_qkv("wv", "bv")?;
                let wot_full = p(&key("wo"))?.transpose2();
                let attn = AttnShard {
                    ln_g: Arc::clone(p(&key("ln1_g"))?),
                    ln_b: Arc::clone(p(&key("ln1_b"))?),
                    wqt,
                    wkt,
                    wvt,
                    bq,
                    bk,
                    bv,
                    wot: row_slice(&wot_full, dm_lo, dm_hi),
                    bo: p(&key("bo"))?.data()[dm_lo..dm_hi].to_vec(),
                };

                // W1^T slices in the engine's served format. Autotuned
                // layouts take precedence, mirroring Engine::native_ffn.
                let (w1t, ff_bounds) = match tuned_w1t.get(l) {
                    Some(AnyTensor::Nmg(t)) => slice_w1_nmg(t, world, rank, dims.d_ff),
                    Some(AnyTensor::Bcsr(t)) => slice_w1_bcsr(t, world, rank, dims.d_ff),
                    Some(AnyTensor::Dense(t)) => slice_w1_dense(t, world, rank, dims.d_ff),
                    Some(other) => {
                        // CSR/ELL and friends have no natural row-slab
                        // boundary; shard their densified form (allclose).
                        slice_w1_dense(&other.to_dense(), world, rank, dims.d_ff)
                    }
                    None => match (engine.ffn_mode, nmg_w1t.get(l)) {
                        (FfnMode::NativeNmg { .. }, Some(t)) => {
                            slice_w1_nmg(t, world, rank, dims.d_ff)
                        }
                        _ => {
                            let w1t_full = p(&key("w1"))?.transpose2();
                            slice_w1_dense(&w1t_full, world, rank, dims.d_ff)
                        }
                    },
                };
                let (ff_lo, ff_hi) = (ff_bounds[rank], ff_bounds[rank + 1]);
                let w2 = match seam {
                    SeamMode::Allgather => {
                        let w2t_full = p(&key("w2"))?.transpose2();
                        W2Seam::Allgather {
                            w2t: row_slice(&w2t_full, dm_lo, dm_hi),
                            b2: p(&key("b2"))?.data()[dm_lo..dm_hi].to_vec(),
                        }
                    }
                    SeamMode::Allreduce => W2Seam::Allreduce {
                        w2: row_slice(p(&key("w2"))?, ff_lo, ff_hi),
                        b2: p(&key("b2"))?.data().to_vec(),
                    },
                };
                let ffn = FfnShard {
                    ln_g: Arc::clone(p(&key("ln2_g"))?),
                    ln_b: Arc::clone(p(&key("ln2_b"))?),
                    w1t,
                    b1: p(&key("b1"))?.data()[ff_lo..ff_hi].to_vec(),
                    ff_bounds,
                    w2,
                };
                layers.push((attn, ffn));
            }
            let out_wt_full = p("out_w")?.transpose2();
            let weights = ShardWeights {
                emb: Arc::clone(p("emb")?),
                pos: Arc::clone(p("pos")?),
                layers,
                lnf_g: Arc::clone(p("lnf_g")?),
                lnf_b: Arc::clone(p("lnf_b")?),
                out_wt: row_slice(&out_wt_full, v_lo, v_hi),
                out_b: p("out_b")?.data()[v_lo..v_hi].to_vec(),
                hc_bounds: hc_bounds.clone(),
                dm_bounds: dm_bounds.clone(),
                v_bounds: v_bounds.clone(),
            };
            shards.push(Mutex::new(ShardEngine {
                rank,
                world,
                dims: dims.clone(),
                n_heads,
                seam,
                weights: Arc::new(weights),
                times: TimeBreakdown::new(),
            }));
        }
        Ok(ShardedModel {
            shards: Arc::new(shards),
            group: Arc::new(ShardGroup::new(world)),
            pool: WorkerPool::named("sten-shard", world),
            world,
            dims,
        })
    }

    /// Shard count.
    pub fn world(&self) -> usize {
        self.world
    }

    /// Encoder dimensions (same as the source engine's).
    pub fn dims(&self) -> &EncoderDims {
        &self.dims
    }

    /// A replica executing the same sharded weights on its own pool and
    /// collective group: weight slices are `Arc`-shared, never re-sliced.
    pub fn replicate(&self) -> ShardedModel {
        let shards: Vec<Mutex<ShardEngine>> = self
            .shards
            .iter()
            .map(|s| {
                let src = s.lock().unwrap();
                Mutex::new(ShardEngine {
                    rank: src.rank,
                    world: src.world,
                    dims: src.dims.clone(),
                    n_heads: src.n_heads,
                    seam: src.seam,
                    weights: Arc::clone(&src.weights),
                    times: TimeBreakdown::new(),
                })
            })
            .collect();
        ShardedModel {
            shards: Arc::new(shards),
            group: Arc::new(ShardGroup::new(self.world)),
            pool: WorkerPool::named("sten-shard", self.world),
            world: self.world,
            dims: self.dims.clone(),
        }
    }

    /// Execute one batch cooperatively across all shards and return the
    /// logits `(batch, seq, vocab)`. Spawn-free in steady state: the `W`
    /// jobs run on the model's persistent dedicated workers.
    pub fn forward(&mut self, tokens: &[i32]) -> DenseTensor {
        let tokens: Arc<Vec<i32>> = Arc::new(tokens.to_vec());
        let latch = Arc::new(CompletionLatch::new());
        let out: Arc<Mutex<Option<DenseTensor>>> = Arc::new(Mutex::new(None));
        for rank in 0..self.world {
            let shards = Arc::clone(&self.shards);
            let group = Arc::clone(&self.group);
            let tokens = Arc::clone(&tokens);
            let latch = Arc::clone(&latch);
            let out = Arc::clone(&out);
            self.pool.execute(move || {
                let logits = shards[rank].lock().unwrap().forward_local(&tokens, &group);
                if rank == 0 {
                    *out.lock().unwrap() = Some(logits);
                }
                latch.account(1);
            });
        }
        latch.wait(self.world);
        let logits = out.lock().unwrap().take();
        logits.expect("shard 0 produced no logits")
    }

    /// Per-shard timing snapshots (rank order).
    pub fn shard_timing(&self) -> Vec<TimeBreakdown> {
        self.shards.iter().map(|s| s.lock().unwrap().timing().clone()).collect()
    }

    /// Reset every shard's timing.
    pub fn reset_timing(&mut self) {
        for s in self.shards.iter() {
            s.lock().unwrap().reset_timing();
        }
    }
}

/// Dense `W1^T` slice: any row boundary is exact (M-dimension slicing).
fn slice_w1_dense(w1t: &DenseTensor, world: usize, rank: usize, f: usize) -> (W1Slice, Vec<usize>) {
    let bounds = shard_bounds(f, world, 1);
    let (lo, hi) = (bounds[rank], bounds[rank + 1]);
    let slice = if hi > lo {
        W1Slice::Dense(row_slice(w1t, lo, hi))
    } else {
        W1Slice::Empty
    };
    (slice, bounds)
}

/// n:m:g `W1^T` slice on slab boundaries.
fn slice_w1_nmg(w1t: &NmgTensor, world: usize, rank: usize, f: usize) -> (W1Slice, Vec<usize>) {
    let m = w1t.m;
    let bounds = shard_bounds(f, world, m);
    let (lo, hi) = (bounds[rank], bounds[rank + 1]);
    let slice = if hi > lo {
        W1Slice::Nmg(w1t.slice_slabs(lo / m, hi.div_ceil(m)))
    } else {
        W1Slice::Empty
    };
    (slice, bounds)
}

/// BCSR `W1^T` slice on block-row boundaries.
fn slice_w1_bcsr(w1t: &BcsrTensor, world: usize, rank: usize, f: usize) -> (W1Slice, Vec<usize>) {
    let bh = w1t.bh;
    let bounds = shard_bounds(f, world, bh);
    let (lo, hi) = (bounds[rank], bounds[rank + 1]);
    let slice = if hi > lo {
        W1Slice::Bcsr(w1t.slice_block_rows(lo / bh, hi.div_ceil(bh)))
    } else {
        W1Slice::Empty
    };
    (slice, bounds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_bounds_balanced_and_aligned() {
        assert_eq!(shard_bounds(10, 1, 1), vec![0, 10]);
        assert_eq!(shard_bounds(10, 3, 1), vec![0, 4, 7, 10]);
        assert_eq!(shard_bounds(2, 4, 1), vec![0, 1, 2, 2, 2]);
        // Aligned: 64 rows in units of 4 across 3 shards -> 16 slabs as 6/5/5.
        assert_eq!(shard_bounds(64, 3, 4), vec![0, 24, 44, 64]);
        // Ragged tail: 18 rows, m = 4 -> 5 slabs as 3/2, last bound clamped.
        assert_eq!(shard_bounds(18, 2, 4), vec![0, 12, 18]);
        // Fewer units than shards leaves trailing shards empty.
        assert_eq!(shard_bounds(4, 3, 4), vec![0, 4, 4, 4]);
    }

    #[test]
    fn elem_bounds_scale_rows() {
        assert_eq!(elem_bounds(&[0, 2, 5], 3), vec![0, 6, 15]);
    }

    #[test]
    fn bias_add_rows_matches_manual() {
        let t = DenseTensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let out = bias_add_rows(&t, &[10.0, 20.0]);
        assert_eq!(out.data(), &[11.0, 12.0, 13.0, 24.0, 25.0, 26.0]);
    }
}
