//! Built-in operator implementations registered with the dispatcher.
//!
//! Mirrors STen's defaults: dense implementations for every op, plus
//! layout-specialized kernels for the operators that matter for sparse
//! inference (matmul over CSR / BCSR / n:m / n:m:g / masked operands) and
//! sparse-add structure union over CSR.

use anyhow::{bail, Result};

use crate::formats::{AnyTensor, Layout};
use crate::kernels::{bcsr_gemm, csr_gemm, dense_gemm, nmg_gemm};
use crate::ops::{dense_reference, OpKind};

use super::Dispatcher;

/// Register every built-in implementation on `d`.
pub fn register_all(d: &Dispatcher) {
    use Layout::*;

    // Dense implementations for every op.
    d.register(OpKind::MatMul, &[Dense, Dense], |ins| {
        dense_ref(OpKind::MatMul, ins)
    });
    d.register(OpKind::Add, &[Dense, Dense], |ins| dense_ref(OpKind::Add, ins));
    d.register(OpKind::Mul, &[Dense, Dense], |ins| dense_ref(OpKind::Mul, ins));
    d.register(OpKind::Relu, &[Dense], |ins| dense_ref(OpKind::Relu, ins));
    d.register(OpKind::Gelu, &[Dense], |ins| dense_ref(OpKind::Gelu, ins));
    d.register(OpKind::Softmax, &[Dense], |ins| dense_ref(OpKind::Softmax, ins));
    d.register(OpKind::LayerNorm, &[Dense, Dense, Dense], |ins| {
        dense_ref(OpKind::LayerNorm, ins)
    });
    d.register(OpKind::BiasAdd, &[Dense, Dense], |ins| dense_ref(OpKind::BiasAdd, ins));
    d.register(OpKind::Transpose, &[Dense], |ins| dense_ref(OpKind::Transpose, ins));

    // Sparse-dense matmuls: the inference hot path (Fig. 10 contenders).
    d.register(OpKind::MatMul, &[Nmg, Dense], |ins| {
        let AnyTensor::Nmg(a) = ins[0] else { bail!("expected Nmg lhs") };
        let Some(b) = ins[1].as_dense() else { bail!("expected dense rhs") };
        Ok(AnyTensor::Dense(nmg_gemm::spmm(a, b)))
    });
    d.register(OpKind::MatMul, &[Csr, Dense], |ins| {
        let AnyTensor::Csr(a) = ins[0] else { bail!("expected Csr lhs") };
        let Some(b) = ins[1].as_dense() else { bail!("expected dense rhs") };
        Ok(AnyTensor::Dense(csr_gemm::spmm(a, b)))
    });
    d.register(OpKind::MatMul, &[Bcsr, Dense], |ins| {
        let AnyTensor::Bcsr(a) = ins[0] else { bail!("expected Bcsr lhs") };
        let Some(b) = ins[1].as_dense() else { bail!("expected dense rhs") };
        Ok(AnyTensor::Dense(bcsr_gemm::spmm(a, b)))
    });
    d.register(OpKind::MatMul, &[Masked, Dense], |ins| {
        let AnyTensor::Masked(a) = ins[0] else { bail!("expected Masked lhs") };
        let Some(b) = ins[1].as_dense() else { bail!("expected dense rhs") };
        // Values are stored pre-masked: a plain GEMM is exact.
        Ok(AnyTensor::Dense(dense_gemm::matmul(a.values(), b)))
    });
    d.register(OpKind::MatMul, &[Ell, Dense], |ins| {
        let AnyTensor::Ell(a) = ins[0] else { bail!("expected Ell lhs") };
        let Some(b) = ins[1].as_dense() else { bail!("expected dense rhs") };
        Ok(AnyTensor::Dense(crate::kernels::ell_gemm::spmm(a, b)))
    });
    d.register(OpKind::MatMul, &[Dense, Csc], |ins| {
        let Some(a) = ins[0].as_dense() else { bail!("expected dense lhs") };
        let AnyTensor::Csc(b) = ins[1] else { bail!("expected Csc rhs") };
        Ok(AnyTensor::Dense(crate::kernels::csc_gemm::spmm_dense_csc(a, b)))
    });
    d.register(OpKind::MatMul, &[Nm, Dense], |ins| {
        let AnyTensor::Nm(a) = ins[0] else { bail!("expected Nm lhs") };
        let Some(b) = ins[1].as_dense() else { bail!("expected dense rhs") };
        // n:m goes through CSR (its structure is unstructured-within-block).
        let csr = crate::formats::CsrTensor::from_dense(&a.to_dense());
        Ok(AnyTensor::Dense(csr_gemm::spmm(&csr, b)))
    });

    // Sparse add with keep-all: union of nonzeros (the §3.3 example).
    d.register(OpKind::Add, &[Csr, Csr], |ins| {
        let (AnyTensor::Csr(a), AnyTensor::Csr(b)) = (ins[0], ins[1]) else {
            bail!("expected Csr operands")
        };
        if a.shape() != b.shape() {
            bail!("sparse add shape mismatch");
        }
        let rows = a.shape()[0];
        let cols = a.shape()[1];
        let mut indptr = vec![0usize];
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for r in 0..rows {
            let mut ia = a.indptr[r];
            let mut ib = b.indptr[r];
            while ia < a.indptr[r + 1] || ib < b.indptr[r + 1] {
                let ca = if ia < a.indptr[r + 1] { a.indices[ia] } else { u32::MAX };
                let cb = if ib < b.indptr[r + 1] { b.indices[ib] } else { u32::MAX };
                match ca.cmp(&cb) {
                    std::cmp::Ordering::Less => {
                        indices.push(ca);
                        values.push(a.values[ia]);
                        ia += 1;
                    }
                    std::cmp::Ordering::Greater => {
                        indices.push(cb);
                        values.push(b.values[ib]);
                        ib += 1;
                    }
                    std::cmp::Ordering::Equal => {
                        indices.push(ca);
                        values.push(a.values[ia] + b.values[ib]);
                        ia += 1;
                        ib += 1;
                    }
                }
            }
            indptr.push(values.len());
        }
        Ok(AnyTensor::Csr(crate::formats::CsrTensor::new(
            [rows, cols],
            indptr,
            indices,
            values,
        )))
    });

    // Elementwise ops preserve masked structure cheaply.
    d.register(OpKind::Relu, &[Masked], |ins| {
        let AnyTensor::Masked(a) = ins[0] else { bail!("expected Masked input") };
        Ok(AnyTensor::Masked(a.with_values(
            &crate::kernels::elementwise::relu(a.values()),
        )))
    });
}

fn dense_ref(op: OpKind, ins: &[&AnyTensor]) -> Result<AnyTensor> {
    let dense: Vec<_> = ins
        .iter()
        .map(|t| t.as_dense().cloned().unwrap_or_else(|| t.to_dense()))
        .collect();
    Ok(AnyTensor::Dense(dense_reference(op, &dense)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::CsrTensor;
    use crate::tensor::DenseTensor;

    #[test]
    fn csr_add_is_nonzero_union() {
        let d = Dispatcher::with_builtins();
        let a = DenseTensor::from_vec(&[2, 3], vec![1.0, 0.0, 2.0, 0.0, 0.0, 0.0]);
        let b = DenseTensor::from_vec(&[2, 3], vec![0.0, 3.0, 2.0, 0.0, 5.0, 0.0]);
        let out = d
            .call(
                OpKind::Add,
                &[
                    AnyTensor::Csr(CsrTensor::from_dense(&a)),
                    AnyTensor::Csr(CsrTensor::from_dense(&b)),
                ],
            )
            .unwrap();
        assert_eq!(out.layout(), Layout::Csr);
        assert_eq!(out.nnz(), 4); // union of nonzeros
        assert!(out.to_dense().allclose(&a.zip(&b, |x, y| x + y), 0.0, 0.0));
    }

    #[test]
    fn masked_relu_stays_masked() {
        let d = Dispatcher::with_builtins();
        let x = DenseTensor::from_vec(&[4], vec![-1.0, 0.0, 2.0, -3.0]);
        let masked = crate::formats::MaskedTensor::from_dense(&x);
        let out = d.call(OpKind::Relu, &[AnyTensor::Masked(masked)]).unwrap();
        assert_eq!(out.layout(), Layout::Masked);
        assert_eq!(out.to_dense().data(), &[0.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn specialized_matmuls_agree_with_dense() {
        use crate::formats::{BcsrTensor, MaskedTensor, NmgTensor};
        use crate::util::rng::Pcg64;
        let d = Dispatcher::with_builtins();
        let mut rng = Pcg64::seeded(100);
        let mut w = DenseTensor::randn(&[8, 16], &mut rng);
        for (i, x) in w.data_mut().iter_mut().enumerate() {
            if i % 3 == 0 {
                *x = 0.0;
            }
        }
        let b = DenseTensor::randn(&[16, 9], &mut rng);
        let bany = AnyTensor::Dense(b.clone());

        let csr_out = d
            .call(OpKind::MatMul, &[AnyTensor::Csr(CsrTensor::from_dense(&w)), bany.clone()])
            .unwrap();
        let bcsr_out = d
            .call(OpKind::MatMul, &[AnyTensor::Bcsr(BcsrTensor::from_dense(&w, 4, 4)), bany.clone()])
            .unwrap();
        let masked_out = d
            .call(OpKind::MatMul, &[AnyTensor::Masked(MaskedTensor::from_dense(&w)), bany.clone()])
            .unwrap();
        let want = dense_gemm::matmul_naive(&w, &b);
        for (name, out) in [("csr", csr_out), ("bcsr", bcsr_out), ("masked", masked_out)] {
            assert!(out.to_dense().allclose(&want, 1e-4, 1e-4), "{name}");
        }
        // n:m:g is lossy (pruned); compare against its own densified weight.
        let nmg = NmgTensor::from_dense(&w, 2, 4, 2);
        let pruned = nmg.to_dense();
        let nmg_out = d.call(OpKind::MatMul, &[AnyTensor::Nmg(nmg), bany]).unwrap();
        assert!(nmg_out
            .to_dense()
            .allclose(&dense_gemm::matmul_naive(&pruned, &b), 1e-4, 1e-4));
        // All five were exact registry hits.
        assert_eq!(d.stats.counts().0, 4);
    }
}
