//! In-place operation handling (§4.4).
//!
//! STen handles in-place ops (`add_`, views) pessimistically when no native
//! in-place sparse implementation exists: compute out-of-place via the
//! dispatcher, then **re-sparsify the original tensor's format** (the
//! "inplace fallback" of Fig. 4). This module provides that route plus a
//! registry for native in-place implementations.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::Result;

use crate::formats::{AnyTensor, Layout};
use crate::ops::OpKind;
use crate::sparsify::SameFormat;

use super::{Dispatcher, Signature};

/// Native in-place implementation: mutates the first operand.
pub type InplaceImplFn = fn(&mut AnyTensor, &[AnyTensor]) -> Result<()>;

/// Registry of native in-place implementations + the pessimistic fallback.
#[derive(Default)]
pub struct InplaceDispatcher {
    native: Mutex<HashMap<Signature, InplaceImplFn>>,
    /// Count of pessimistic (compute + resparsify) fallbacks taken.
    pub fallbacks: std::sync::atomic::AtomicU64,
}

impl InplaceDispatcher {
    /// Empty in-place dispatcher.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a native in-place implementation for `(op, layouts)` where
    /// layouts include the mutated operand first.
    pub fn register(&self, op: OpKind, inputs: &[Layout], f: InplaceImplFn) {
        self.native
            .lock()
            .unwrap()
            .insert(Signature { op, inputs: inputs.to_vec() }, f);
    }

    /// Apply `op` in place on `target` with extra `args`.
    ///
    /// Route: native in-place implementation if registered; otherwise the
    /// pessimistic fallback — run the out-of-place op through `dispatcher`,
    /// then resparsify the result back into `target`'s original format with
    /// the `SameFormatSparsifier`.
    pub fn call_inplace(
        &self,
        dispatcher: &Dispatcher,
        op: OpKind,
        target: &mut AnyTensor,
        args: &[AnyTensor],
    ) -> Result<()> {
        let mut layouts = vec![target.layout()];
        layouts.extend(args.iter().map(|a| a.layout()));
        let sig = Signature { op, inputs: layouts };
        if let Some(f) = self.native.lock().unwrap().get(&sig).copied() {
            return f(target, args);
        }
        // Pessimistic fallback (§4.4): out-of-place + resparsify.
        self.fallbacks.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut inputs = Vec::with_capacity(args.len() + 1);
        inputs.push(target.clone());
        inputs.extend_from_slice(args);
        let out = dispatcher.call(op, &inputs)?;
        *target = SameFormat.resparsify(target, &out.to_dense())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{CsrTensor, MaskedTensor};
    use crate::tensor::DenseTensor;
    use crate::util::rng::Pcg64;

    #[test]
    fn pessimistic_fallback_preserves_layout() {
        let d = Dispatcher::with_builtins();
        let inp = InplaceDispatcher::new();
        let mut rng = Pcg64::seeded(1);
        let w = DenseTensor::randn(&[4, 4], &mut rng).map(|x| if x > 0.0 { x } else { 0.0 });
        let mut t = AnyTensor::Csr(CsrTensor::from_dense(&w));
        let other = AnyTensor::Dense(DenseTensor::ones(&[4, 4]));
        inp.call_inplace(&d, OpKind::Add, &mut t, &[other]).unwrap();
        // Layout preserved, values updated (+1 everywhere, recompressed).
        assert_eq!(t.layout(), Layout::Csr);
        let want = w.map(|x| x + 1.0);
        assert!(t.to_dense().allclose(&want, 1e-6, 1e-6));
        assert_eq!(inp.fallbacks.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn masked_inplace_keeps_pattern() {
        // Masked tensors re-apply their mask on in-place updates (the Fig. 2
        // weight-update semantics).
        let d = Dispatcher::with_builtins();
        let inp = InplaceDispatcher::new();
        let v = DenseTensor::from_vec(&[2, 2], vec![1.0, 0.0, 2.0, 0.0]);
        let mut t = AnyTensor::Masked(MaskedTensor::from_dense(&v));
        let other = AnyTensor::Dense(DenseTensor::ones(&[2, 2]));
        inp.call_inplace(&d, OpKind::Add, &mut t, &[other]).unwrap();
        assert_eq!(t.to_dense().data(), &[2.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn native_inplace_takes_precedence() {
        fn scale_dense(t: &mut AnyTensor, args: &[AnyTensor]) -> Result<()> {
            let AnyTensor::Dense(d) = t else { anyhow::bail!("dense only") };
            let other = args[0].to_dense();
            *d = d.zip(&other, |a, b| a + b);
            Ok(())
        }
        let d = Dispatcher::with_builtins();
        let inp = InplaceDispatcher::new();
        inp.register(OpKind::Add, &[Layout::Dense, Layout::Dense], scale_dense);
        let mut t = AnyTensor::Dense(DenseTensor::ones(&[2]));
        inp.call_inplace(&d, OpKind::Add, &mut t, &[AnyTensor::Dense(DenseTensor::ones(&[2]))])
            .unwrap();
        assert_eq!(t.to_dense().data(), &[2.0, 2.0]);
        assert_eq!(inp.fallbacks.load(std::sync::atomic::Ordering::Relaxed), 0);
    }

    #[test]
    fn unary_inplace_relu() {
        let d = Dispatcher::with_builtins();
        let inp = InplaceDispatcher::new();
        let v = DenseTensor::from_vec(&[2, 2], vec![-1.0, 2.0, -3.0, 4.0]);
        let mut t = AnyTensor::Csr(CsrTensor::from_dense(&v));
        inp.call_inplace(&d, OpKind::Relu, &mut t, &[]).unwrap();
        assert_eq!(t.layout(), Layout::Csr);
        assert_eq!(t.to_dense().data(), &[0.0, 2.0, 0.0, 4.0]);
    }
}
