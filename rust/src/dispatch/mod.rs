//! The STen dispatch engine (§4.4, Figs. 3–4).
//!
//! Routing for an op call over tensors with arbitrary sparsity layouts:
//!
//! 1. **Registry lookup** — hash the canonical signature
//!    `(op, input layouts)` and call the registered implementation.
//! 2. **Lossless conversion** — if no implementation matches, try converting
//!    inputs (only via conversions guaranteed lossless, see
//!    [`crate::formats::convert`]) to reach a registered signature.
//! 3. **Dense fallback** — convert everything to dense (with masks) and run
//!    the dense reference implementation, with a warning counter.
//!
//! Every phase is timed and counted ([`DispatchStats`]) — these counters
//! feed the Fig. 11 "STen overhead" breakdown and the dispatch-overhead
//! bench.

pub mod builtin;
mod inplace;
mod patch;
pub use inplace::{InplaceDispatcher, InplaceImplFn};
pub use patch::{PatchTable, Patched};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::formats::{convert, AnyTensor, Layout};
use crate::ops::{dense_reference_any, OpKind};
use crate::sparsify::{sparsifier_registry, Sparsifier};

/// An operator implementation for one layout signature.
pub type OpImplFn = fn(&[AnyTensor]) -> Result<AnyTensor>;

/// Canonical dispatch signature.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Signature {
    /// The operator.
    pub op: OpKind,
    /// Input layouts, in argument order.
    pub inputs: Vec<Layout>,
}

impl Signature {
    /// Signature of a concrete call.
    pub fn of(op: OpKind, inputs: &[AnyTensor]) -> Self {
        Signature { op, inputs: inputs.iter().map(|t| t.layout()).collect() }
    }
}

/// Dispatch outcome counters (reset-able).
#[derive(Debug, Default)]
pub struct DispatchStats {
    /// Exact registry hits.
    pub hits: AtomicU64,
    /// Calls resolved after lossless conversion.
    pub conversions: AtomicU64,
    /// Calls resolved by the dense fallback.
    pub fallbacks: AtomicU64,
    /// Nanoseconds spent inside dispatch decision-making (not kernels).
    pub dispatch_ns: AtomicU64,
    /// Nanoseconds spent inside kernels / fallbacks.
    pub kernel_ns: AtomicU64,
}

impl DispatchStats {
    fn snapshot(&self) -> (u64, u64, u64, f64, f64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.conversions.load(Ordering::Relaxed),
            self.fallbacks.load(Ordering::Relaxed),
            self.dispatch_ns.load(Ordering::Relaxed) as f64 / 1e9,
            self.kernel_ns.load(Ordering::Relaxed) as f64 / 1e9,
        )
    }

    /// Reset all counters.
    pub fn reset(&self) {
        self.hits.store(0, Ordering::Relaxed);
        self.conversions.store(0, Ordering::Relaxed);
        self.fallbacks.store(0, Ordering::Relaxed);
        self.dispatch_ns.store(0, Ordering::Relaxed);
        self.kernel_ns.store(0, Ordering::Relaxed);
    }

    /// (hits, conversions, fallbacks).
    pub fn counts(&self) -> (u64, u64, u64) {
        let (h, c, f, _, _) = self.snapshot();
        (h, c, f)
    }

    /// (dispatch seconds, kernel seconds) — the Fig. 11 split.
    pub fn times(&self) -> (f64, f64) {
        let (_, _, _, d, k) = self.snapshot();
        (d, k)
    }
}

/// The dispatcher: registry + conversion search + dense fallback.
pub struct Dispatcher {
    registry: Mutex<HashMap<Signature, OpImplFn>>,
    /// Preferred conversion targets, in order (§4.4: "generally it only
    /// attempts conversion to formats such as CSR").
    conversion_targets: Vec<Layout>,
    /// Outcome statistics.
    pub stats: DispatchStats,
}

impl Default for Dispatcher {
    fn default() -> Self {
        Self::new()
    }
}

impl Dispatcher {
    /// Empty dispatcher (no implementations registered).
    pub fn new() -> Self {
        Dispatcher {
            registry: Mutex::new(HashMap::new()),
            conversion_targets: vec![Layout::Csr],
            stats: DispatchStats::default(),
        }
    }

    /// Dispatcher with all built-in implementations registered.
    pub fn with_builtins() -> Self {
        let d = Self::new();
        builtin::register_all(&d);
        d
    }

    /// Register an implementation for a signature (last registration wins).
    pub fn register(&self, op: OpKind, inputs: &[Layout], f: OpImplFn) {
        self.registry
            .lock()
            .unwrap()
            .insert(Signature { op, inputs: inputs.to_vec() }, f);
    }

    /// Number of registered implementations.
    pub fn len(&self) -> usize {
        self.registry.lock().unwrap().len()
    }

    /// True when no implementations are registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn lookup(&self, sig: &Signature) -> Option<OpImplFn> {
        self.registry.lock().unwrap().get(sig).copied()
    }

    /// Route an op call (§4.4 flow). Returns the output tensor.
    pub fn call(&self, op: OpKind, inputs: &[AnyTensor]) -> Result<AnyTensor> {
        if inputs.len() != op.arity() {
            bail!("{op}: expected {} inputs, got {}", op.arity(), inputs.len());
        }
        let t0 = Instant::now();
        // Phase 1: exact hit.
        let sig = Signature::of(op, inputs);
        if let Some(f) = self.lookup(&sig) {
            self.stats.hits.fetch_add(1, Ordering::Relaxed);
            self.charge_dispatch(t0);
            return self.run_kernel(f, inputs);
        }

        // Phase 2: lossless conversion search (§4.4: conversion only to
        // formats guaranteed lossless, e.g. CSR — never through sparsifiers).
        // Candidates per preferred target: (a) convert only the sparse
        // inputs (dense stays dense) — covers sparse×dense kernels; (b)
        // convert every input — covers sparse-sparse kernels.
        for &target in &self.conversion_targets {
            let candidates = [
                sig.inputs
                    .iter()
                    .map(|&l| if l == Layout::Dense { Layout::Dense } else { target })
                    .collect::<Vec<_>>(),
                sig.inputs.iter().map(|_| target).collect::<Vec<_>>(),
            ];
            for cand in candidates {
                if cand == sig.inputs {
                    continue;
                }
                let cand_sig = Signature { op, inputs: cand.clone() };
                if let Some(f) = self.lookup(&cand_sig) {
                    let converted: Option<Vec<AnyTensor>> = inputs
                        .iter()
                        .zip(&cand)
                        .map(|(t, &l)| convert::lossless(t, l))
                        .collect();
                    if let Some(conv) = converted {
                        self.stats.conversions.fetch_add(1, Ordering::Relaxed);
                        self.charge_dispatch(t0);
                        return self.run_kernel(f, &conv);
                    }
                }
            }
        }

        // Phase 3: dense fallback (always possible — every layout densifies).
        self.stats.fallbacks.fetch_add(1, Ordering::Relaxed);
        self.charge_dispatch(t0);
        let t1 = Instant::now();
        let out = dense_reference_any(op, inputs);
        self.stats
            .kernel_ns
            .fetch_add(t1.elapsed().as_nanos() as u64, Ordering::Relaxed);
        out
    }

    /// Sparse-operator call (§3.3): run `op`, then the output format chain
    /// `inline sparsifier -> tmp layout -> external sparsifier -> out layout`.
    pub fn call_sparse(
        &self,
        op: OpKind,
        inputs: &[AnyTensor],
        out_fmt: &OutputFormat,
    ) -> Result<AnyTensor> {
        let raw = self.call(op, inputs)?;
        out_fmt.apply(&raw)
    }

    fn run_kernel(&self, f: OpImplFn, inputs: &[AnyTensor]) -> Result<AnyTensor> {
        let t = Instant::now();
        let out = f(inputs);
        self.stats
            .kernel_ns
            .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        out
    }

    fn charge_dispatch(&self, t0: Instant) {
        self.stats
            .dispatch_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
}

/// Output format of a sparse operator (§3.3): inline sparsifier + temporary
/// layout, then external sparsifier + final layout.
pub struct OutputFormat {
    /// Applied "inside" the op (streaming/blocking candidates).
    pub inline: Box<dyn Sparsifier>,
    /// Layout the inline sparsifier materializes.
    pub tmp: Layout,
    /// Applied to the materialized temporary.
    pub external: Box<dyn Sparsifier>,
    /// Final output layout.
    pub out: Layout,
}

impl OutputFormat {
    /// Keep-all into dense: the default output format of a dense operator.
    pub fn dense() -> Self {
        OutputFormat {
            inline: Box::new(crate::sparsify::KeepAll),
            tmp: Layout::Dense,
            external: Box::new(crate::sparsify::KeepAll),
            out: Layout::Dense,
        }
    }

    /// Single-sparsifier shorthand: keep-all inline, `s` external into `out`.
    pub fn external(s: Box<dyn Sparsifier>, out: Layout) -> Self {
        OutputFormat {
            inline: Box::new(crate::sparsify::KeepAll),
            tmp: Layout::Dense,
            external: s,
            out,
        }
    }

    /// Apply the two-stage sparsification chain to an op output.
    pub fn apply(&self, raw: &AnyTensor) -> Result<AnyTensor> {
        let reg = sparsifier_registry();
        let tmp = reg.apply(self.inline.as_ref(), raw, self.tmp)?;
        reg.apply(self.external.as_ref(), &tmp, self.out)
    }
}

/// The process-wide dispatcher with builtins registered.
pub fn global() -> &'static Dispatcher {
    static D: OnceLock<Dispatcher> = OnceLock::new();
    D.get_or_init(Dispatcher::with_builtins)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{CsrTensor, NmgTensor};
    use crate::sparsify::{RandomFraction, ScalarThreshold};
    use crate::tensor::DenseTensor;
    use crate::util::rng::Pcg64;

    fn dense(shape: &[usize], seed: u64) -> DenseTensor {
        let mut rng = Pcg64::seeded(seed);
        DenseTensor::randn(shape, &mut rng)
    }

    #[test]
    fn exact_hit_path() {
        let d = Dispatcher::with_builtins();
        let a = AnyTensor::Dense(dense(&[4, 6], 1));
        let b = AnyTensor::Dense(dense(&[6, 3], 2));
        let out = d.call(OpKind::MatMul, &[a, b]).unwrap();
        assert_eq!(out.shape(), &[4, 3]);
        let (h, c, f) = d.stats.counts();
        assert_eq!((h, c, f), (1, 0, 0));
    }

    #[test]
    fn sparse_hit_path_nmg() {
        let d = Dispatcher::with_builtins();
        let w = dense(&[8, 24], 3);
        let a = AnyTensor::Nmg(NmgTensor::from_dense(&w, 2, 4, 2));
        let b = AnyTensor::Dense(dense(&[24, 5], 4));
        let out = d.call(OpKind::MatMul, &[a.clone(), b.clone()]).unwrap();
        let want = crate::kernels::dense_gemm::matmul_naive(&a.to_dense(), b.as_dense().unwrap());
        assert!(out.to_dense().allclose(&want, 1e-4, 1e-4));
        assert_eq!(d.stats.counts().0, 1);
    }

    #[test]
    fn conversion_path_coo_matmul() {
        // COO x Dense matmul has no direct impl; it converts COO -> CSR.
        let d = Dispatcher::with_builtins();
        let mut w = dense(&[6, 6], 5);
        for (i, x) in w.data_mut().iter_mut().enumerate() {
            if i % 2 == 0 {
                *x = 0.0;
            }
        }
        let a = AnyTensor::Coo(crate::formats::CooTensor::from_dense(&w));
        let b = AnyTensor::Dense(dense(&[6, 4], 6));
        let out = d.call(OpKind::MatMul, &[a, b.clone()]).unwrap();
        let want = crate::kernels::dense_gemm::matmul_naive(&w, b.as_dense().unwrap());
        assert!(out.to_dense().allclose(&want, 1e-4, 1e-4));
        let (h, c, f) = d.stats.counts();
        assert_eq!((h, c, f), (0, 1, 0));
    }

    #[test]
    fn fallback_path_softmax_on_csr() {
        let d = Dispatcher::with_builtins();
        let w = dense(&[4, 4], 7).map(|x| x.max(0.0));
        let a = AnyTensor::Csr(CsrTensor::from_dense(&w));
        let out = d.call(OpKind::Softmax, &[a]).unwrap();
        assert_eq!(out.layout(), Layout::Dense);
        let (_, _, f) = d.stats.counts();
        assert_eq!(f, 1);
    }

    #[test]
    fn all_ops_dispatch_on_all_layout_combos() {
        // The §4.4 guarantee: every PyTorch operator works with sparse
        // inputs, possibly through the dense fallback.
        let d = Dispatcher::with_builtins();
        let base = dense(&[8, 8], 8).map(|x| if x > 0.0 { x } else { 0.0 });
        let variants: Vec<AnyTensor> = vec![
            AnyTensor::Dense(base.clone()),
            AnyTensor::Csr(CsrTensor::from_dense(&base)),
            AnyTensor::Coo(crate::formats::CooTensor::from_dense(&base)),
            AnyTensor::Masked(crate::formats::MaskedTensor::from_dense(&base)),
            AnyTensor::Nmg(NmgTensor::from_dense(&base, 2, 4, 1)),
        ];
        for a in &variants {
            for b in &variants {
                for op in [OpKind::MatMul, OpKind::Add, OpKind::Mul] {
                    let out = d.call(op, &[a.clone(), b.clone()]).unwrap();
                    assert_eq!(out.shape(), &[8, 8], "{op} {:?}x{:?}", a.layout(), b.layout());
                }
            }
            for op in [OpKind::Relu, OpKind::Gelu, OpKind::Softmax, OpKind::Transpose] {
                d.call(op, &[a.clone()]).unwrap();
            }
        }
    }

    #[test]
    fn sparse_operator_output_format_chain() {
        let d = Dispatcher::with_builtins();
        let a = AnyTensor::Dense(dense(&[6, 6], 9));
        let b = AnyTensor::Dense(dense(&[6, 6], 10));
        // add -> random-fraction(0.5) -> CSR: the paper's §3.3 example.
        let fmt = OutputFormat::external(Box::new(RandomFraction::new(0.5, 11)), Layout::Csr);
        let out = d.call_sparse(OpKind::Add, &[a.clone(), b.clone()], &fmt).unwrap();
        assert_eq!(out.layout(), Layout::Csr);
        let frac = out.nnz() as f64 / 36.0;
        assert!(frac < 0.85, "some values must be dropped, kept {frac}");
    }

    #[test]
    fn inline_plus_external_chain() {
        let d = Dispatcher::with_builtins();
        let a = AnyTensor::Dense(dense(&[4, 4], 12));
        let b = AnyTensor::Dense(dense(&[4, 4], 13));
        let fmt = OutputFormat {
            inline: Box::new(ScalarThreshold { threshold: 0.5 }),
            tmp: Layout::Masked,
            external: Box::new(crate::sparsify::KeepAll),
            out: Layout::Csc,
        };
        let out = d.call_sparse(OpKind::Add, &[a.clone(), b.clone()], &fmt).unwrap();
        assert_eq!(out.layout(), Layout::Csc);
        // Every surviving value exceeds the threshold.
        for &v in out.to_dense().data() {
            assert!(v == 0.0 || v.abs() >= 0.5);
        }
    }

    #[test]
    fn arity_mismatch_rejected() {
        let d = Dispatcher::with_builtins();
        let a = AnyTensor::Dense(dense(&[2, 2], 14));
        assert!(d.call(OpKind::MatMul, &[a]).is_err());
    }

    #[test]
    fn stats_times_split() {
        let d = Dispatcher::with_builtins();
        let a = AnyTensor::Dense(dense(&[32, 32], 15));
        let b = AnyTensor::Dense(dense(&[32, 32], 16));
        for _ in 0..4 {
            d.call(OpKind::MatMul, &[a.clone(), b.clone()]).unwrap();
        }
        let (dispatch, kernel) = d.stats.times();
        assert!(dispatch > 0.0 && kernel > 0.0);
        d.stats.reset();
        assert_eq!(d.stats.counts(), (0, 0, 0));
    }
}
