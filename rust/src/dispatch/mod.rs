//! The STen dispatch engine (§4.4, Figs. 3–4).
//!
//! Routing for an op call over tensors with arbitrary sparsity layouts:
//!
//! 1. **Registry lookup** — hash the canonical signature
//!    `(op, input layouts)` and call the registered implementation.
//! 2. **Lossless conversion** — if no implementation matches, try converting
//!    inputs (only via conversions guaranteed lossless, see
//!    [`crate::formats::convert`]) to reach a registered signature.
//! 3. **Dense fallback** — convert everything to dense (with masks) and run
//!    the dense reference implementation, with a warning counter.
//!
//! Every phase is timed and counted ([`DispatchStats`]) — these counters
//! feed the Fig. 11 "STen overhead" breakdown and the dispatch-overhead
//! bench.

pub mod builtin;
mod inplace;
mod patch;
pub use inplace::{InplaceDispatcher, InplaceImplFn};
pub use patch::{PatchTable, Patched};

use std::borrow::Cow;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::formats::{convert, AnyTensor, Layout};
use crate::ops::{dense_reference, OpKind};
use crate::sparsify::{sparsifier_registry, Sparsifier};

/// An operator implementation for one layout signature. Implementations
/// take borrowed operands so the hot path (and the conversion path's
/// unchanged operands) never clone tensors just to build an argument slice.
pub type OpImplFn = fn(&[&AnyTensor]) -> Result<AnyTensor>;

/// Canonical dispatch signature.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Signature {
    /// The operator.
    pub op: OpKind,
    /// Input layouts, in argument order.
    pub inputs: Vec<Layout>,
}

impl Signature {
    /// Signature of a concrete call.
    pub fn of(op: OpKind, inputs: &[AnyTensor]) -> Self {
        Signature { op, inputs: inputs.iter().map(|t| t.layout()).collect() }
    }
}

/// Dispatch outcome counters (reset-able).
#[derive(Debug, Default)]
pub struct DispatchStats {
    /// Exact registry hits.
    pub hits: AtomicU64,
    /// Calls resolved after lossless conversion.
    pub conversions: AtomicU64,
    /// Calls resolved by the dense fallback.
    pub fallbacks: AtomicU64,
    /// Nanoseconds spent inside dispatch decision-making (not kernels).
    pub dispatch_ns: AtomicU64,
    /// Nanoseconds spent inside kernels / fallbacks.
    pub kernel_ns: AtomicU64,
    /// Conversion-path operands passed through borrowed because they were
    /// already in the target layout (each one is a deep clone avoided).
    pub avoided_clones: AtomicU64,
}

impl DispatchStats {
    fn snapshot(&self) -> (u64, u64, u64, f64, f64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.conversions.load(Ordering::Relaxed),
            self.fallbacks.load(Ordering::Relaxed),
            self.dispatch_ns.load(Ordering::Relaxed) as f64 / 1e9,
            self.kernel_ns.load(Ordering::Relaxed) as f64 / 1e9,
        )
    }

    /// Reset all counters.
    pub fn reset(&self) {
        self.hits.store(0, Ordering::Relaxed);
        self.conversions.store(0, Ordering::Relaxed);
        self.fallbacks.store(0, Ordering::Relaxed);
        self.dispatch_ns.store(0, Ordering::Relaxed);
        self.kernel_ns.store(0, Ordering::Relaxed);
        self.avoided_clones.store(0, Ordering::Relaxed);
    }

    /// (hits, conversions, fallbacks).
    pub fn counts(&self) -> (u64, u64, u64) {
        let (h, c, f, _, _) = self.snapshot();
        (h, c, f)
    }

    /// (dispatch seconds, kernel seconds) — the Fig. 11 split.
    pub fn times(&self) -> (f64, f64) {
        let (_, _, _, d, k) = self.snapshot();
        (d, k)
    }

    /// Deep clones avoided on the conversion path (operands already in the
    /// candidate layout, passed through borrowed).
    pub fn avoided_clones(&self) -> u64 {
        self.avoided_clones.load(Ordering::Relaxed)
    }
}

/// The dispatcher: registry + conversion search + dense fallback.
///
/// The registry has two phases. During registration (builtins, autotuner
/// extras) it lives behind a `Mutex`; [`Dispatcher::freeze`] then snapshots
/// it into a read-only map that every subsequent lookup reads lock-free —
/// the serving hot path (continuous-batching workers dispatching
/// concurrently) never contends on the registry again. Unfrozen dispatchers
/// still work (tests build ad-hoc ones), paying one lock acquisition per
/// call for the whole phase-1 + phase-2 decision.
pub struct Dispatcher {
    registry: Mutex<HashMap<Signature, OpImplFn>>,
    /// Read-only snapshot of `registry`, set once by [`Self::freeze`].
    frozen: OnceLock<HashMap<Signature, OpImplFn>>,
    /// Preferred conversion targets, in order (§4.4: "generally it only
    /// attempts conversion to formats such as CSR").
    conversion_targets: Vec<Layout>,
    /// Outcome statistics.
    pub stats: DispatchStats,
}

/// Routing decision for one call, computed under a single registry access.
enum Decision {
    /// Phase 1: exact signature hit.
    Exact(OpImplFn),
    /// Phase 2 candidates in preference order: (impl, candidate layouts).
    /// Conversion is attempted outside the registry access; the first
    /// candidate whose operands all convert losslessly wins.
    Convert(Vec<(OpImplFn, Vec<Layout>)>),
}

impl Default for Dispatcher {
    fn default() -> Self {
        Self::new()
    }
}

impl Dispatcher {
    /// Empty dispatcher (no implementations registered).
    pub fn new() -> Self {
        Dispatcher {
            registry: Mutex::new(HashMap::new()),
            frozen: OnceLock::new(),
            conversion_targets: vec![Layout::Csr],
            stats: DispatchStats::default(),
        }
    }

    /// Dispatcher with all built-in implementations registered (unfrozen, so
    /// tests and the autotuner can still register; [`global`] freezes).
    pub fn with_builtins() -> Self {
        let d = Self::new();
        builtin::register_all(&d);
        d
    }

    /// Register an implementation for a signature (last registration wins).
    ///
    /// Panics after [`Self::freeze`]: the frozen map is the one lock-free
    /// structure the serving hot path reads, so late registration would be
    /// silently invisible — fail loudly instead.
    pub fn register(&self, op: OpKind, inputs: &[Layout], f: OpImplFn) {
        assert!(
            self.frozen.get().is_none(),
            "dispatcher registry is frozen; register all implementations before freeze()"
        );
        self.registry
            .lock()
            .unwrap()
            .insert(Signature { op, inputs: inputs.to_vec() }, f);
    }

    /// Snapshot the registry into the read-only, lock-free map used by every
    /// subsequent lookup. Idempotent; call after all registrations.
    pub fn freeze(&self) {
        let snapshot = self.registry.lock().unwrap().clone();
        let _ = self.frozen.set(snapshot);
    }

    /// True once [`Self::freeze`] has run.
    pub fn is_frozen(&self) -> bool {
        self.frozen.get().is_some()
    }

    /// Run `f` against the active registry map: the frozen snapshot
    /// (lock-free) when present, else the build-side map under its lock.
    fn with_map<R>(&self, f: impl FnOnce(&HashMap<Signature, OpImplFn>) -> R) -> R {
        match self.frozen.get() {
            Some(m) => f(m),
            None => f(&self.registry.lock().unwrap()),
        }
    }

    /// Number of registered implementations.
    pub fn len(&self) -> usize {
        self.with_map(|m| m.len())
    }

    /// True when no implementations are registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn lookup(&self, sig: &Signature) -> Option<OpImplFn> {
        self.with_map(|m| m.get(sig).copied())
    }

    /// Input-layout signatures registered for `op`, in unspecified order.
    /// The autotuner enumerates its (format, kernel) candidates from this.
    pub fn registered_inputs(&self, op: OpKind) -> Vec<Vec<Layout>> {
        self.with_map(|m| {
            m.keys().filter(|s| s.op == op).map(|s| s.inputs.clone()).collect()
        })
    }

    /// Compute the routing decision for `sig` under ONE registry access
    /// (frozen: lock-free; unfrozen: a single lock acquisition, where the
    /// old per-lookup scheme took up to `1 + 2 x targets`).
    fn decide(&self, sig: &Signature) -> Decision {
        self.with_map(|m| {
            if let Some(&f) = m.get(sig) {
                return Decision::Exact(f);
            }
            // Phase-2 candidates per preferred target: (a) convert only the
            // sparse inputs (dense stays dense) — covers sparse×dense
            // kernels; (b) convert every input — covers sparse-sparse.
            let mut cands = Vec::new();
            for &target in &self.conversion_targets {
                let options = [
                    sig.inputs
                        .iter()
                        .map(|&l| if l == Layout::Dense { Layout::Dense } else { target })
                        .collect::<Vec<_>>(),
                    sig.inputs.iter().map(|_| target).collect::<Vec<_>>(),
                ];
                for cand in options {
                    if cand == sig.inputs || cands.iter().any(|(_, c)| *c == cand) {
                        continue;
                    }
                    let cand_sig = Signature { op: sig.op, inputs: cand.clone() };
                    if let Some(&f) = m.get(&cand_sig) {
                        cands.push((f, cand));
                    }
                }
            }
            Decision::Convert(cands)
        })
    }

    /// Route an op call (§4.4 flow) over owned operands. Delegates to
    /// [`Self::call_ref`]; prefer that on hot paths to avoid building owned
    /// argument vectors.
    pub fn call(&self, op: OpKind, inputs: &[AnyTensor]) -> Result<AnyTensor> {
        let refs: Vec<&AnyTensor> = inputs.iter().collect();
        self.call_ref(op, &refs)
    }

    /// Route an op call over borrowed operands — the zero-clone hot path:
    /// a phase-1 exact hit performs no allocation beyond the kernel's own.
    pub fn call_ref(&self, op: OpKind, inputs: &[&AnyTensor]) -> Result<AnyTensor> {
        if inputs.len() != op.arity() {
            bail!("{op}: expected {} inputs, got {}", op.arity(), inputs.len());
        }
        let t0 = Instant::now();
        let sig = Signature { op, inputs: inputs.iter().map(|t| t.layout()).collect() };
        let decision = self.decide(&sig);

        // Phase 1: exact hit.
        if let Decision::Exact(f) = decision {
            self.stats.hits.fetch_add(1, Ordering::Relaxed);
            self.charge_dispatch(t0);
            return self.run_kernel(f, inputs);
        }

        // Phase 2: lossless conversion search (§4.4: conversion only to
        // formats guaranteed lossless, e.g. CSR — never through
        // sparsifiers). Operands already in the candidate layout pass
        // through borrowed (counted as avoided clones).
        let Decision::Convert(cands) = decision else { unreachable!() };
        for (f, cand) in cands {
            let converted: Option<Vec<Cow<'_, AnyTensor>>> = inputs
                .iter()
                .zip(&cand)
                .map(|(t, &l)| convert::lossless_cow(t, l))
                .collect();
            if let Some(conv) = converted {
                let borrowed = conv.iter().filter(|c| matches!(c, Cow::Borrowed(_))).count();
                self.stats.avoided_clones.fetch_add(borrowed as u64, Ordering::Relaxed);
                self.stats.conversions.fetch_add(1, Ordering::Relaxed);
                self.charge_dispatch(t0);
                let refs: Vec<&AnyTensor> = conv.iter().map(|c| c.as_ref()).collect();
                return self.run_kernel(f, &refs);
            }
        }

        // Phase 3: dense fallback (always possible — every layout densifies).
        self.stats.fallbacks.fetch_add(1, Ordering::Relaxed);
        self.charge_dispatch(t0);
        let t1 = Instant::now();
        let dense: Vec<crate::tensor::DenseTensor> =
            inputs.iter().map(|t| t.to_dense()).collect();
        let out = dense_reference(op, &dense).map(AnyTensor::Dense);
        self.stats
            .kernel_ns
            .fetch_add(t1.elapsed().as_nanos() as u64, Ordering::Relaxed);
        out
    }

    /// Sparse-operator call (§3.3): run `op`, then the output format chain
    /// `inline sparsifier -> tmp layout -> external sparsifier -> out layout`.
    pub fn call_sparse(
        &self,
        op: OpKind,
        inputs: &[AnyTensor],
        out_fmt: &OutputFormat,
    ) -> Result<AnyTensor> {
        let raw = self.call(op, inputs)?;
        out_fmt.apply(&raw)
    }

    fn run_kernel(&self, f: OpImplFn, inputs: &[&AnyTensor]) -> Result<AnyTensor> {
        let t = Instant::now();
        let out = f(inputs);
        self.stats
            .kernel_ns
            .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        out
    }

    fn charge_dispatch(&self, t0: Instant) {
        self.stats
            .dispatch_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
}

/// Output format of a sparse operator (§3.3): inline sparsifier + temporary
/// layout, then external sparsifier + final layout.
pub struct OutputFormat {
    /// Applied "inside" the op (streaming/blocking candidates).
    pub inline: Box<dyn Sparsifier>,
    /// Layout the inline sparsifier materializes.
    pub tmp: Layout,
    /// Applied to the materialized temporary.
    pub external: Box<dyn Sparsifier>,
    /// Final output layout.
    pub out: Layout,
}

impl OutputFormat {
    /// Keep-all into dense: the default output format of a dense operator.
    pub fn dense() -> Self {
        OutputFormat {
            inline: Box::new(crate::sparsify::KeepAll),
            tmp: Layout::Dense,
            external: Box::new(crate::sparsify::KeepAll),
            out: Layout::Dense,
        }
    }

    /// Single-sparsifier shorthand: keep-all inline, `s` external into `out`.
    pub fn external(s: Box<dyn Sparsifier>, out: Layout) -> Self {
        OutputFormat {
            inline: Box::new(crate::sparsify::KeepAll),
            tmp: Layout::Dense,
            external: s,
            out,
        }
    }

    /// Apply the two-stage sparsification chain to an op output.
    pub fn apply(&self, raw: &AnyTensor) -> Result<AnyTensor> {
        let reg = sparsifier_registry();
        let tmp = reg.apply(self.inline.as_ref(), raw, self.tmp)?;
        reg.apply(self.external.as_ref(), &tmp, self.out)
    }
}

/// The process-wide dispatcher with builtins registered, frozen for
/// lock-free lookup (register on a local [`Dispatcher`] instead if you need
/// ad-hoc implementations).
pub fn global() -> &'static Dispatcher {
    static D: OnceLock<Dispatcher> = OnceLock::new();
    D.get_or_init(|| {
        let d = Dispatcher::with_builtins();
        d.freeze();
        d
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{CsrTensor, NmgTensor};
    use crate::sparsify::{RandomFraction, ScalarThreshold};
    use crate::tensor::DenseTensor;
    use crate::util::rng::Pcg64;

    fn dense(shape: &[usize], seed: u64) -> DenseTensor {
        let mut rng = Pcg64::seeded(seed);
        DenseTensor::randn(shape, &mut rng)
    }

    #[test]
    fn exact_hit_path() {
        let d = Dispatcher::with_builtins();
        let a = AnyTensor::Dense(dense(&[4, 6], 1));
        let b = AnyTensor::Dense(dense(&[6, 3], 2));
        let out = d.call(OpKind::MatMul, &[a, b]).unwrap();
        assert_eq!(out.shape(), &[4, 3]);
        let (h, c, f) = d.stats.counts();
        assert_eq!((h, c, f), (1, 0, 0));
    }

    #[test]
    fn sparse_hit_path_nmg() {
        let d = Dispatcher::with_builtins();
        let w = dense(&[8, 24], 3);
        let a = AnyTensor::Nmg(NmgTensor::from_dense(&w, 2, 4, 2));
        let b = AnyTensor::Dense(dense(&[24, 5], 4));
        let out = d.call(OpKind::MatMul, &[a.clone(), b.clone()]).unwrap();
        let want = crate::kernels::dense_gemm::matmul_naive(&a.to_dense(), b.as_dense().unwrap());
        assert!(out.to_dense().allclose(&want, 1e-4, 1e-4));
        assert_eq!(d.stats.counts().0, 1);
    }

    #[test]
    fn conversion_path_coo_matmul() {
        // COO x Dense matmul has no direct impl; it converts COO -> CSR.
        let d = Dispatcher::with_builtins();
        let mut w = dense(&[6, 6], 5);
        for (i, x) in w.data_mut().iter_mut().enumerate() {
            if i % 2 == 0 {
                *x = 0.0;
            }
        }
        let a = AnyTensor::Coo(crate::formats::CooTensor::from_dense(&w));
        let b = AnyTensor::Dense(dense(&[6, 4], 6));
        let out = d.call(OpKind::MatMul, &[a, b.clone()]).unwrap();
        let want = crate::kernels::dense_gemm::matmul_naive(&w, b.as_dense().unwrap());
        assert!(out.to_dense().allclose(&want, 1e-4, 1e-4));
        let (h, c, f) = d.stats.counts();
        assert_eq!((h, c, f), (0, 1, 0));
    }

    #[test]
    fn fallback_path_softmax_on_csr() {
        let d = Dispatcher::with_builtins();
        let w = dense(&[4, 4], 7).map(|x| x.max(0.0));
        let a = AnyTensor::Csr(CsrTensor::from_dense(&w));
        let out = d.call(OpKind::Softmax, &[a]).unwrap();
        assert_eq!(out.layout(), Layout::Dense);
        let (_, _, f) = d.stats.counts();
        assert_eq!(f, 1);
    }

    #[test]
    fn all_ops_dispatch_on_all_layout_combos() {
        // The §4.4 guarantee: every PyTorch operator works with sparse
        // inputs, possibly through the dense fallback.
        let d = Dispatcher::with_builtins();
        let base = dense(&[8, 8], 8).map(|x| if x > 0.0 { x } else { 0.0 });
        let variants: Vec<AnyTensor> = vec![
            AnyTensor::Dense(base.clone()),
            AnyTensor::Csr(CsrTensor::from_dense(&base)),
            AnyTensor::Coo(crate::formats::CooTensor::from_dense(&base)),
            AnyTensor::Masked(crate::formats::MaskedTensor::from_dense(&base)),
            AnyTensor::Nmg(NmgTensor::from_dense(&base, 2, 4, 1)),
        ];
        for a in &variants {
            for b in &variants {
                for op in [OpKind::MatMul, OpKind::Add, OpKind::Mul] {
                    let out = d.call(op, &[a.clone(), b.clone()]).unwrap();
                    assert_eq!(out.shape(), &[8, 8], "{op} {:?}x{:?}", a.layout(), b.layout());
                }
            }
            for op in [OpKind::Relu, OpKind::Gelu, OpKind::Softmax, OpKind::Transpose] {
                d.call(op, &[a.clone()]).unwrap();
            }
        }
    }

    #[test]
    fn sparse_operator_output_format_chain() {
        let d = Dispatcher::with_builtins();
        let a = AnyTensor::Dense(dense(&[6, 6], 9));
        let b = AnyTensor::Dense(dense(&[6, 6], 10));
        // add -> random-fraction(0.5) -> CSR: the paper's §3.3 example.
        let fmt = OutputFormat::external(Box::new(RandomFraction::new(0.5, 11)), Layout::Csr);
        let out = d.call_sparse(OpKind::Add, &[a.clone(), b.clone()], &fmt).unwrap();
        assert_eq!(out.layout(), Layout::Csr);
        let frac = out.nnz() as f64 / 36.0;
        assert!(frac < 0.85, "some values must be dropped, kept {frac}");
    }

    #[test]
    fn inline_plus_external_chain() {
        let d = Dispatcher::with_builtins();
        let a = AnyTensor::Dense(dense(&[4, 4], 12));
        let b = AnyTensor::Dense(dense(&[4, 4], 13));
        let fmt = OutputFormat {
            inline: Box::new(ScalarThreshold { threshold: 0.5 }),
            tmp: Layout::Masked,
            external: Box::new(crate::sparsify::KeepAll),
            out: Layout::Csc,
        };
        let out = d.call_sparse(OpKind::Add, &[a.clone(), b.clone()], &fmt).unwrap();
        assert_eq!(out.layout(), Layout::Csc);
        // Every surviving value exceeds the threshold.
        for &v in out.to_dense().data() {
            assert!(v == 0.0 || v.abs() >= 0.5);
        }
    }

    #[test]
    fn frozen_registry_dispatches_and_rejects_late_registration() {
        let d = Dispatcher::with_builtins();
        let before = d.len();
        d.freeze();
        assert!(d.is_frozen());
        assert_eq!(d.len(), before);
        d.freeze(); // idempotent
        let a = AnyTensor::Dense(dense(&[4, 6], 30));
        let b = AnyTensor::Dense(dense(&[6, 3], 31));
        let out = d.call(OpKind::MatMul, &[a, b]).unwrap();
        assert_eq!(out.shape(), &[4, 3]);
        assert_eq!(d.stats.counts(), (1, 0, 0));
        let late = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            d.register(OpKind::Relu, &[Layout::Csr], |_| bail!("unused"));
        }));
        assert!(late.is_err(), "late registration must panic loudly");
    }

    #[test]
    fn call_ref_is_the_zero_clone_hot_path() {
        let d = Dispatcher::with_builtins();
        let a = AnyTensor::Dense(dense(&[4, 6], 32));
        let b = AnyTensor::Dense(dense(&[6, 3], 33));
        let out = d.call_ref(OpKind::MatMul, &[&a, &b]).unwrap();
        assert_eq!(out.shape(), &[4, 3]);
        assert_eq!(d.stats.counts(), (1, 0, 0));
    }

    #[test]
    fn conversion_path_counts_avoided_clones() {
        // COO x Dense converts COO -> CSR; the dense rhs is already in the
        // candidate layout and must pass through borrowed, not cloned.
        let d = Dispatcher::with_builtins();
        let mut w = dense(&[6, 6], 34);
        for (i, x) in w.data_mut().iter_mut().enumerate() {
            if i % 2 == 0 {
                *x = 0.0;
            }
        }
        let a = AnyTensor::Coo(crate::formats::CooTensor::from_dense(&w));
        let b = AnyTensor::Dense(dense(&[6, 4], 35));
        d.call(OpKind::MatMul, &[a, b]).unwrap();
        assert_eq!(d.stats.counts(), (0, 1, 0));
        assert_eq!(d.stats.avoided_clones(), 1);
        d.stats.reset();
        assert_eq!(d.stats.avoided_clones(), 0);
    }

    #[test]
    fn registered_inputs_enumerates_matmul_candidates() {
        let d = Dispatcher::with_builtins();
        let sigs = d.registered_inputs(OpKind::MatMul);
        for want in [
            vec![Layout::Dense, Layout::Dense],
            vec![Layout::Csr, Layout::Dense],
            vec![Layout::Bcsr, Layout::Dense],
            vec![Layout::Nmg, Layout::Dense],
            vec![Layout::Ell, Layout::Dense],
        ] {
            assert!(sigs.contains(&want), "missing {want:?}");
        }
    }

    #[test]
    fn arity_mismatch_rejected() {
        let d = Dispatcher::with_builtins();
        let a = AnyTensor::Dense(dense(&[2, 2], 14));
        assert!(d.call(OpKind::MatMul, &[a]).is_err());
    }

    #[test]
    fn stats_times_split() {
        let d = Dispatcher::with_builtins();
        let a = AnyTensor::Dense(dense(&[32, 32], 15));
        let b = AnyTensor::Dense(dense(&[32, 32], 16));
        for _ in 0..4 {
            d.call(OpKind::MatMul, &[a.clone(), b.clone()]).unwrap();
        }
        let (dispatch, kernel) = d.stats.times();
        assert!(dispatch > 0.0 && kernel > 0.0);
        d.stats.reset();
        assert_eq!(d.stats.counts(), (0, 0, 0));
    }
}
