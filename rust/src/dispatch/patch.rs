//! Operator patching (§4.4): redirect arbitrary functions through the
//! dispatcher when any argument is sparse.
//!
//! STen patches Python callables from external libraries (e.g. Apex) so
//! calls with sparse tensors reach the sparse dispatcher. The Rust analog:
//! a [`PatchTable`] maps function names to [`Patched`] entries holding the
//! original dense function and the dispatcher route; `call` picks the route
//! based on operand layouts.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, Result};

use crate::formats::{AnyTensor, Layout};
use crate::ops::OpKind;

use super::Dispatcher;

/// Original (dense-only) function type: the "native extension" being patched.
pub type DenseFn = fn(&[AnyTensor]) -> Result<AnyTensor>;

/// A patched function: dense original + sparse dispatcher route.
pub struct Patched {
    /// The pre-existing dense implementation.
    pub original: DenseFn,
    /// The op the dispatcher should route sparse calls to.
    pub op: OpKind,
    /// How often the sparse route was taken.
    pub sparse_calls: AtomicU64,
    /// How often the original was called directly.
    pub dense_calls: AtomicU64,
}

/// Table of patched functions, keyed by name.
#[derive(Default)]
pub struct PatchTable {
    entries: Mutex<HashMap<String, Patched>>,
}

impl PatchTable {
    /// Empty table.
    pub fn new() -> Self {
        Self::default()
    }

    /// Patch `name`: subsequent `call(name, ...)` goes through `dispatcher`
    /// whenever any argument is sparse.
    pub fn patch(&self, name: &str, original: DenseFn, op: OpKind) {
        self.entries.lock().unwrap().insert(
            name.to_string(),
            Patched {
                original,
                op,
                sparse_calls: AtomicU64::new(0),
                dense_calls: AtomicU64::new(0),
            },
        );
    }

    /// Remove a patch.
    pub fn unpatch(&self, name: &str) -> bool {
        self.entries.lock().unwrap().remove(name).is_some()
    }

    /// Call a patched function: dense arguments use the original, any sparse
    /// argument reroutes through the dispatcher.
    pub fn call(
        &self,
        dispatcher: &Dispatcher,
        name: &str,
        inputs: &[AnyTensor],
    ) -> Result<AnyTensor> {
        let entries = self.entries.lock().unwrap();
        let p = entries
            .get(name)
            .ok_or_else(|| anyhow!("function {name:?} is not patched"))?;
        let any_sparse = inputs.iter().any(|t| t.layout() != Layout::Dense);
        if any_sparse {
            p.sparse_calls.fetch_add(1, Ordering::Relaxed);
            let op = p.op;
            drop(entries);
            dispatcher.call(op, inputs)
        } else {
            p.dense_calls.fetch_add(1, Ordering::Relaxed);
            (p.original)(inputs)
        }
    }

    /// (sparse, dense) call counts for a patched function.
    pub fn counts(&self, name: &str) -> Option<(u64, u64)> {
        self.entries.lock().unwrap().get(name).map(|p| {
            (
                p.sparse_calls.load(Ordering::Relaxed),
                p.dense_calls.load(Ordering::Relaxed),
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::CsrTensor;
    use crate::tensor::DenseTensor;
    use crate::util::rng::Pcg64;

    /// Simulated "external library" matmul that only understands dense.
    fn apex_matmul(inputs: &[AnyTensor]) -> Result<AnyTensor> {
        let a = inputs[0].as_dense().ok_or_else(|| anyhow!("apex: dense only"))?;
        let b = inputs[1].as_dense().ok_or_else(|| anyhow!("apex: dense only"))?;
        Ok(AnyTensor::Dense(crate::kernels::dense_gemm::matmul(a, b)))
    }

    #[test]
    fn dense_calls_use_original() {
        let table = PatchTable::new();
        let d = Dispatcher::with_builtins();
        table.patch("apex.matmul", apex_matmul, OpKind::MatMul);
        let mut rng = Pcg64::seeded(1);
        let a = AnyTensor::Dense(DenseTensor::randn(&[3, 3], &mut rng));
        let b = AnyTensor::Dense(DenseTensor::randn(&[3, 3], &mut rng));
        table.call(&d, "apex.matmul", &[a, b]).unwrap();
        assert_eq!(table.counts("apex.matmul"), Some((0, 1)));
        // The dispatcher saw nothing.
        assert_eq!(d.stats.counts(), (0, 0, 0));
    }

    #[test]
    fn sparse_calls_reroute_through_dispatcher() {
        let table = PatchTable::new();
        let d = Dispatcher::with_builtins();
        table.patch("apex.matmul", apex_matmul, OpKind::MatMul);
        let mut rng = Pcg64::seeded(2);
        let w = DenseTensor::randn(&[4, 4], &mut rng).map(|x| if x > 0.0 { x } else { 0.0 });
        let a = AnyTensor::Csr(CsrTensor::from_dense(&w));
        let b = AnyTensor::Dense(DenseTensor::randn(&[4, 4], &mut rng));
        let out = table.call(&d, "apex.matmul", &[a, b.clone()]).unwrap();
        assert_eq!(table.counts("apex.matmul"), Some((1, 0)));
        assert_eq!(d.stats.counts().0, 1); // dispatcher hit (Csr, Dense)
        let want = crate::kernels::dense_gemm::matmul_naive(&w, b.as_dense().unwrap());
        assert!(out.to_dense().allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn unpatched_function_errors() {
        let table = PatchTable::new();
        let d = Dispatcher::with_builtins();
        assert!(table.call(&d, "unknown.fn", &[]).is_err());
    }

    #[test]
    fn unpatch_restores_nothing_silently() {
        let table = PatchTable::new();
        table.patch("f", apex_matmul, OpKind::MatMul);
        assert!(table.unpatch("f"));
        assert!(!table.unpatch("f"));
    }
}
