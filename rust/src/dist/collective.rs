//! Ring allreduce over in-process worker buffers.
//!
//! The classic two-phase algorithm: w-1 reduce-scatter steps (each worker
//! accumulates its neighbor's rotating segment) followed by w-1 allgather
//! steps (the fully-reduced segments rotate back around), over in-process
//! buffers. Within a step, every segment is "in flight" between exactly one
//! sender/receiver pair, and the pair's read and write regions of any one
//! buffer are *different* segments — so the w transfers of a step run
//! concurrently on the persistent thread pool (real overlap, matching the
//! wire-parallel behavior of a physical ring), with a barrier between
//! steps. The per-segment accumulation order is unchanged, so results are
//! bit-identical to the sequential emulation.

use crate::util::threadpool;

/// A ring of `workers` in-process replicas.
#[derive(Debug, Clone, Copy)]
pub struct RingAllreduce {
    workers: usize,
}

impl RingAllreduce {
    /// Ring over `workers` replicas (at least 1).
    pub fn new(workers: usize) -> Self {
        assert!(workers >= 1, "ring needs at least one worker");
        RingAllreduce { workers }
    }

    /// Number of workers in the ring.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Segment bounds `[lo, hi)` of segment `s` for buffers of length `n`.
    fn segment(&self, n: usize, s: usize) -> (usize, usize) {
        let w = self.workers;
        let q = n / w;
        let r = n % w;
        let lo = s * q + s.min(r);
        let len = q + usize::from(s < r);
        (lo, lo + len)
    }

    /// In-place mean-allreduce: every buffer ends up holding the
    /// element-wise mean across workers. All buffers must share one length
    /// and their count must match the ring size. Each ring step runs its w
    /// transfers concurrently on the pool (barrier between steps).
    pub fn allreduce_mean(&self, bufs: &mut [Vec<f32>]) {
        let w = self.workers;
        assert_eq!(bufs.len(), w, "buffer count {} != ring size {w}", bufs.len());
        if w == 1 {
            return;
        }
        let n = bufs[0].len();
        assert!(bufs.iter().all(|b| b.len() == n), "ragged allreduce buffers");
        let ptrs: Vec<threadpool::SyncPtr<f32>> =
            bufs.iter_mut().map(|b| threadpool::SyncPtr::new(b.as_mut_ptr())).collect();

        // Reduce-scatter: after step t, the accumulating copy of segment s
        // sits at worker (s + t + 1) % w; after w-1 steps worker i holds
        // the full sum of segment (i + 1) % w.
        for t in 0..w - 1 {
            threadpool::parallel_for(w, 1, |i0, i1| {
                for i in i0..i1 {
                    let s = (i + w - t) % w;
                    let (lo, hi) = self.segment(n, s);
                    let dst = (i + 1) % w;
                    // SAFETY: within this step, segment s is in flight only
                    // between (i, dst), and dst's concurrently-read segment
                    // is (s + 1) % w != s (w >= 2): the regions below are
                    // disjoint from every other transfer's.
                    unsafe {
                        let src = std::slice::from_raw_parts(ptrs[i].get().add(lo), hi - lo);
                        let out =
                            std::slice::from_raw_parts_mut(ptrs[dst].get().add(lo), hi - lo);
                        for (o, v) in out.iter_mut().zip(src) {
                            *o += *v;
                        }
                    }
                }
            });
        }
        // Scale the fully-reduced segments to means before sharing them
        // (each segment has exactly one owner: transfers are disjoint).
        threadpool::parallel_for(w, 1, |s0, s1| {
            for s in s0..s1 {
                let owner = (s + w - 1) % w;
                let (lo, hi) = self.segment(n, s);
                // SAFETY: segment s of its owner is touched only here.
                unsafe {
                    let seg = std::slice::from_raw_parts_mut(ptrs[owner].get().add(lo), hi - lo);
                    for v in seg {
                        *v /= w as f32;
                    }
                }
            }
        });
        // Allgather: worker i starts owning segment (i + 1) % w; the
        // reduced segments rotate around the ring, overwriting stale copies.
        for t in 0..w - 1 {
            threadpool::parallel_for(w, 1, |i0, i1| {
                for i in i0..i1 {
                    let s = (i + 1 + w - t) % w;
                    let (lo, hi) = self.segment(n, s);
                    let dst = (i + 1) % w;
                    // SAFETY: as above — dst's read segment differs from its
                    // written segment, and segment s travels on one edge.
                    unsafe {
                        let src = std::slice::from_raw_parts(ptrs[i].get().add(lo), hi - lo);
                        let out =
                            std::slice::from_raw_parts_mut(ptrs[dst].get().add(lo), hi - lo);
                        out.copy_from_slice(src);
                    }
                }
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn naive_mean(bufs: &[Vec<f32>]) -> Vec<f32> {
        let n = bufs[0].len();
        let mut out = vec![0f32; n];
        for b in bufs {
            for (o, v) in out.iter_mut().zip(b) {
                *o += v;
            }
        }
        for o in &mut out {
            *o /= bufs.len() as f32;
        }
        out
    }

    fn check(workers: usize, n: usize) {
        let mut rng = Pcg64::seeded((workers * 1000 + n) as u64);
        let mut bufs: Vec<Vec<f32>> =
            (0..workers).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let want = naive_mean(&bufs);
        RingAllreduce::new(workers).allreduce_mean(&mut bufs);
        for (w, b) in bufs.iter().enumerate() {
            for (j, (&got, &expect)) in b.iter().zip(&want).enumerate() {
                assert!(
                    (got - expect).abs() < 1e-4 * (1.0 + expect.abs()),
                    "worker {w} elem {j}: {got} vs {expect}"
                );
            }
        }
    }

    #[test]
    fn matches_naive_mean_across_shapes() {
        for workers in [1, 2, 3, 4, 8] {
            for n in [1, 5, 16, 97, 1024] {
                check(workers, n);
            }
        }
    }

    #[test]
    fn short_buffers_with_empty_segments() {
        // n < workers leaves some segments empty; must still be exact.
        check(8, 3);
        check(5, 1);
    }

    #[test]
    fn single_worker_is_identity() {
        let mut bufs = vec![vec![1.0, -2.0, 3.0]];
        RingAllreduce::new(1).allreduce_mean(&mut bufs);
        assert_eq!(bufs[0], vec![1.0, -2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "buffer count")]
    fn wrong_buffer_count_panics() {
        let mut bufs = vec![vec![0.0; 4]; 3];
        RingAllreduce::new(2).allreduce_mean(&mut bufs);
    }
}
