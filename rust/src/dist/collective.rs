//! Ring collectives over in-process worker buffers.
//!
//! Two families share the same ring schedules:
//!
//! * [`RingAllreduce`] — caller-orchestrated: one thread owns all `w`
//!   buffers and each ring step fans its `w` edge transfers out on the
//!   persistent pool (`parallel_for`), with the pool's scope join as the
//!   inter-step barrier. Used by `dist::ddp` gradient averaging.
//! * [`ShardGroup`] — thread-cooperative: `w` dedicated shard threads each
//!   own one buffer and drive their own edge of the ring, rendezvousing at
//!   a [`ShardBarrier`] between steps. Used by tensor-parallel sharded
//!   execution, where the participants are long-lived worker threads that
//!   cannot be fanned out from a single orchestrator without handing their
//!   buffers over.
//!
//! The classic two-phase allreduce: w-1 reduce-scatter steps (each worker
//! accumulates its neighbor's rotating segment) followed by w-1 allgather
//! steps (the fully-reduced segments rotate back around). Within a step,
//! every segment is "in flight" between exactly one sender/receiver pair,
//! and the pair's read and write regions of any one buffer are *different*
//! segments — so the w transfers of a step run concurrently (real overlap,
//! matching the wire-parallel behavior of a physical ring), with a barrier
//! between steps. The per-segment accumulation order is fixed by the ring
//! schedule alone, so results are bit-identical run to run and independent
//! of thread timing.
//!
//! `ShardGroup` synchronization goes through the `util::sync` shim and has
//! a loom model (`tests/loom.rs`) covering the barrier.

use crate::util::sync::{Condvar, Mutex};
use crate::util::threadpool;

/// Balanced segment bounds `[lo, hi)` of segment `s` when a length-`n`
/// buffer is cut into `w` near-equal segments (remainder spread over the
/// low segments). Shared by both collective families so their reduction
/// orders line up.
fn segment_bounds(n: usize, w: usize, s: usize) -> (usize, usize) {
    let q = n / w;
    let r = n % w;
    let lo = s * q + s.min(r);
    let len = q + usize::from(s < r);
    (lo, lo + len)
}

/// A ring of `workers` in-process replicas.
#[derive(Debug, Clone, Copy)]
pub struct RingAllreduce {
    workers: usize,
}

impl RingAllreduce {
    /// Ring over `workers` replicas (at least 1).
    pub fn new(workers: usize) -> Self {
        assert!(workers >= 1, "ring needs at least one worker");
        RingAllreduce { workers }
    }

    /// Number of workers in the ring.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Segment bounds `[lo, hi)` of segment `s` for buffers of length `n`.
    fn segment(&self, n: usize, s: usize) -> (usize, usize) {
        segment_bounds(n, self.workers, s)
    }

    /// In-place mean-allreduce: every buffer ends up holding the
    /// element-wise mean across workers. All buffers must share one length
    /// and their count must match the ring size. Each ring step runs its w
    /// transfers concurrently on the pool (barrier between steps).
    pub fn allreduce_mean(&self, bufs: &mut [Vec<f32>]) {
        let w = self.workers;
        assert_eq!(bufs.len(), w, "buffer count {} != ring size {w}", bufs.len());
        if w == 1 {
            return;
        }
        let n = bufs[0].len();
        assert!(bufs.iter().all(|b| b.len() == n), "ragged allreduce buffers");
        let ptrs: Vec<threadpool::SyncPtr<f32>> =
            bufs.iter_mut().map(|b| threadpool::SyncPtr::new(b.as_mut_ptr())).collect();

        // Reduce-scatter: after step t, the accumulating copy of segment s
        // sits at worker (s + t + 1) % w; after w-1 steps worker i holds
        // the full sum of segment (i + 1) % w.
        for t in 0..w - 1 {
            threadpool::parallel_for(w, 1, |i0, i1| {
                for i in i0..i1 {
                    let s = (i + w - t) % w;
                    let (lo, hi) = self.segment(n, s);
                    let dst = (i + 1) % w;
                    // SAFETY: within this step, segment s is in flight only
                    // between (i, dst), and dst's concurrently-read segment
                    // is (s + 1) % w != s (w >= 2): the regions below are
                    // disjoint from every other transfer's.
                    unsafe {
                        let src = std::slice::from_raw_parts(ptrs[i].get().add(lo), hi - lo);
                        let out =
                            std::slice::from_raw_parts_mut(ptrs[dst].get().add(lo), hi - lo);
                        for (o, v) in out.iter_mut().zip(src) {
                            *o += *v;
                        }
                    }
                }
            });
        }
        // Scale the fully-reduced segments to means before sharing them
        // (each segment has exactly one owner: transfers are disjoint).
        threadpool::parallel_for(w, 1, |s0, s1| {
            for s in s0..s1 {
                let owner = (s + w - 1) % w;
                let (lo, hi) = self.segment(n, s);
                // SAFETY: segment s of its owner is touched only here.
                unsafe {
                    let seg = std::slice::from_raw_parts_mut(ptrs[owner].get().add(lo), hi - lo);
                    for v in seg {
                        *v /= w as f32;
                    }
                }
            }
        });
        // Allgather: worker i starts owning segment (i + 1) % w; the
        // reduced segments rotate around the ring, overwriting stale copies.
        for t in 0..w - 1 {
            threadpool::parallel_for(w, 1, |i0, i1| {
                for i in i0..i1 {
                    let s = (i + 1 + w - t) % w;
                    let (lo, hi) = self.segment(n, s);
                    let dst = (i + 1) % w;
                    // SAFETY: as above — dst's read segment differs from its
                    // written segment, and segment s travels on one edge.
                    unsafe {
                        let src = std::slice::from_raw_parts(ptrs[i].get().add(lo), hi - lo);
                        let out =
                            std::slice::from_raw_parts_mut(ptrs[dst].get().add(lo), hi - lo);
                        out.copy_from_slice(src);
                    }
                }
            });
        }
    }
}

/// Sense-reversing barrier for a fixed party of `w` shard threads.
///
/// Built on the `util::sync` shim (`Mutex` + `Condvar`) so the loom suite
/// can model it; modeled in `tests/loom.rs`. The generation counter is the
/// "sense": the last arrival of a round flips it and wakes the rest, and a
/// waiter only sleeps while the generation it arrived under is still
/// current — a wakeup from a *later* round can never strand a thread from
/// an earlier one.
#[derive(Debug)]
pub struct ShardBarrier {
    state: Mutex<BarrierState>,
    cv: Condvar,
    parties: usize,
}

#[derive(Debug)]
struct BarrierState {
    count: usize,
    generation: usize,
}

impl ShardBarrier {
    /// Barrier for `parties` threads (at least 1).
    pub fn new(parties: usize) -> Self {
        assert!(parties >= 1, "barrier needs at least one party");
        ShardBarrier {
            state: Mutex::new(BarrierState { count: 0, generation: 0 }),
            cv: Condvar::new(),
            parties,
        }
    }

    /// Number of threads that rendezvous per round.
    pub fn parties(&self) -> usize {
        self.parties
    }

    /// Block until all `parties` threads have called `wait` this round.
    ///
    /// Establishes happens-before between everything each thread did before
    /// its call and everything every thread does after returning (the
    /// shared `Mutex` carries the ordering), which is what lets the ring
    /// transfers publish raw buffer contents across the barrier.
    pub fn wait(&self) {
        let mut st = self.state.lock().unwrap();
        st.count += 1;
        if st.count == self.parties {
            st.count = 0;
            st.generation = st.generation.wrapping_add(1);
            self.cv.notify_all();
        } else {
            let arrived = st.generation;
            while st.generation == arrived {
                st = self.cv.wait(st).unwrap();
            }
        }
    }
}

/// One shard's published buffer: a raw pointer plus length, parked in a
/// `Mutex` slot for the ring neighbors to pick up.
#[derive(Debug)]
struct SharedSlot {
    ptr: *mut f32,
    len: usize,
}

// SAFETY: `SharedSlot` is only a mailbox for a pointer + length; it never
// dereferences the pointer itself. All dereferences happen in
// `ShardGroup::{allgather, allreduce_sum}` under the disjoint-segment
// schedule proven there, with the barrier providing happens-before, so
// moving the slot's *value* across threads (what `Send` permits) is sound.
unsafe impl Send for SharedSlot {}

/// Thread-cooperative ring collectives for `w` dedicated shard threads.
///
/// Unlike [`RingAllreduce`] (one orchestrator fanning transfers onto the
/// pool), every participant here is a long-lived thread that owns its
/// buffer and drives its own ring edge, meeting the others at a
/// [`ShardBarrier`] between steps. Calls are *collective*: all `w` threads
/// must call the same operation with agreeing arguments, and the call
/// returns only once every rank's buffer holds the final result.
///
/// Reduction order is fixed by the ring schedule (segment `s` accumulates
/// rank `s`, then `s+1`, … around the ring), so sums are bit-identical run
/// to run. Never call these from inside a threadpool scope: a blocked
/// barrier inside a scope chunk can deadlock the pool (see
/// `util::threadpool` docs) — shard threads must be dedicated
/// `WorkerPool` workers.
#[derive(Debug)]
pub struct ShardGroup {
    workers: usize,
    slots: Vec<Mutex<SharedSlot>>,
    barrier: ShardBarrier,
}

impl ShardGroup {
    /// Group of `workers` cooperating shard threads (at least 1).
    pub fn new(workers: usize) -> Self {
        assert!(workers >= 1, "shard group needs at least one worker");
        ShardGroup {
            workers,
            slots: (0..workers)
                .map(|_| Mutex::new(SharedSlot { ptr: std::ptr::null_mut(), len: 0 }))
                .collect(),
            barrier: ShardBarrier::new(workers),
        }
    }

    /// Number of shard threads in the group.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Rendezvous all shard threads (a bare barrier round).
    pub fn barrier(&self) {
        if self.workers > 1 {
            self.barrier.wait();
        }
    }

    /// Publish this rank's buffer and return the right neighbor's pointer.
    ///
    /// The returned pointer is valid for the duration of the current
    /// collective call: the neighbor's buffer is a live `&mut [f32]` held
    /// across its own matching call, and the final barrier of the schedule
    /// quiesces all access before anyone returns.
    fn publish(&self, rank: usize, buf: &mut [f32]) -> *mut f32 {
        {
            let mut slot = self.slots[rank].lock().unwrap();
            slot.ptr = buf.as_mut_ptr();
            slot.len = buf.len();
        }
        self.barrier.wait();
        let right = (rank + 1) % self.workers;
        let slot = self.slots[right].lock().unwrap();
        assert_eq!(slot.len, buf.len(), "ragged collective buffers");
        slot.ptr
    }

    /// Ring allgather with explicit segment `bounds` (length `w + 1`,
    /// `bounds[0] == 0`, `bounds[w] == buf.len()`, non-decreasing; empty
    /// segments are fine). On entry rank `r` owns segment
    /// `[bounds[r], bounds[r+1])` of its buffer; on return every rank's
    /// buffer holds all segments, byte-for-byte identical across ranks.
    ///
    /// Collective: all `w` threads must call with the same `bounds` and
    /// equal buffer lengths.
    pub fn allgather(&self, rank: usize, buf: &mut [f32], bounds: &[usize]) {
        let w = self.workers;
        assert!(rank < w, "rank {rank} out of range for {w} workers");
        assert_eq!(bounds.len(), w + 1, "bounds must have w + 1 entries");
        assert_eq!(bounds[0], 0, "bounds must start at 0");
        assert_eq!(bounds[w], buf.len(), "bounds must end at buffer length");
        assert!(bounds.windows(2).all(|p| p[0] <= p[1]), "bounds must be non-decreasing");
        if w == 1 {
            return;
        }
        let right_ptr = self.publish(rank, buf);
        // Step t: rank i forwards the segment it most recently received,
        // s = (i - t) mod w, to its right neighbor. After w-1 steps every
        // segment has visited every rank.
        for t in 0..w - 1 {
            let s = (rank + w - t) % w;
            let (lo, hi) = (bounds[s], bounds[s + 1]);
            // SAFETY: within this step rank i writes its right neighbor's
            // segment s = (i - t) mod w and reads its own segment s; the
            // only concurrent writer of rank i's buffer is its left
            // neighbor, writing segment (i - 1 - t) mod w != s (w >= 2), so
            // every read and write region in flight is disjoint. The
            // neighbor pointer was published under the slot mutex and the
            // barrier after each step orders the writes of step t before
            // the reads of step t + 1; the final step's barrier quiesces
            // all access before any rank returns.
            unsafe {
                let src = std::slice::from_raw_parts(buf.as_ptr().add(lo), hi - lo);
                let dst = std::slice::from_raw_parts_mut(right_ptr.add(lo), hi - lo);
                dst.copy_from_slice(src);
            }
            self.barrier.wait();
        }
    }

    /// Ring allreduce-sum over balanced segments: on return every rank's
    /// buffer holds the element-wise sum of all ranks' buffers, with a
    /// reduction order fixed by the ring schedule (bit-identical run to
    /// run). Collective: all `w` threads must call with equal lengths.
    pub fn allreduce_sum(&self, rank: usize, buf: &mut [f32]) {
        let w = self.workers;
        assert!(rank < w, "rank {rank} out of range for {w} workers");
        if w == 1 {
            return;
        }
        let n = buf.len();
        let right_ptr = self.publish(rank, buf);
        // Reduce-scatter: step t, rank i accumulates its segment
        // s = (i - t) mod w into the right neighbor's copy; after w-1
        // steps rank i holds the full sum of segment (i + 1) mod w, built
        // in ring order s, s+1, ... regardless of thread timing.
        for t in 0..w - 1 {
            let s = (rank + w - t) % w;
            let (lo, hi) = segment_bounds(n, w, s);
            // SAFETY: same disjointness argument as `allgather` — rank i
            // reads its own segment s and writes the neighbor's segment s,
            // while the left neighbor writes rank i's segment
            // (s - 1) mod w != s; barriers order step t's writes before
            // step t + 1's reads.
            unsafe {
                let src = std::slice::from_raw_parts(buf.as_ptr().add(lo), hi - lo);
                let dst = std::slice::from_raw_parts_mut(right_ptr.add(lo), hi - lo);
                for (d, v) in dst.iter_mut().zip(src) {
                    *d += *v;
                }
            }
            self.barrier.wait();
        }
        // Allgather rotation: rank i starts owning the fully-reduced
        // segment (i + 1) mod w; w-1 copy steps rotate the reduced
        // segments around the ring, overwriting stale partials.
        for t in 0..w - 1 {
            let s = (rank + 1 + w - t) % w;
            let (lo, hi) = segment_bounds(n, w, s);
            // SAFETY: as above; copies only, regions disjoint per step,
            // barriers between steps, final barrier quiesces the buffers.
            unsafe {
                let src = std::slice::from_raw_parts(buf.as_ptr().add(lo), hi - lo);
                let dst = std::slice::from_raw_parts_mut(right_ptr.add(lo), hi - lo);
                dst.copy_from_slice(src);
            }
            self.barrier.wait();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn naive_mean(bufs: &[Vec<f32>]) -> Vec<f32> {
        let n = bufs[0].len();
        let mut out = vec![0f32; n];
        for b in bufs {
            for (o, v) in out.iter_mut().zip(b) {
                *o += v;
            }
        }
        for o in &mut out {
            *o /= bufs.len() as f32;
        }
        out
    }

    fn check(workers: usize, n: usize) {
        let mut rng = Pcg64::seeded((workers * 1000 + n) as u64);
        let mut bufs: Vec<Vec<f32>> =
            (0..workers).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let want = naive_mean(&bufs);
        RingAllreduce::new(workers).allreduce_mean(&mut bufs);
        for (w, b) in bufs.iter().enumerate() {
            for (j, (&got, &expect)) in b.iter().zip(&want).enumerate() {
                assert!(
                    (got - expect).abs() < 1e-4 * (1.0 + expect.abs()),
                    "worker {w} elem {j}: {got} vs {expect}"
                );
            }
        }
    }

    #[test]
    fn matches_naive_mean_across_shapes() {
        for workers in [1, 2, 3, 4, 8] {
            for n in [1, 5, 16, 97, 1024] {
                check(workers, n);
            }
        }
    }

    #[test]
    fn short_buffers_with_empty_segments() {
        // n < workers leaves some segments empty; must still be exact.
        check(8, 3);
        check(5, 1);
    }

    #[test]
    fn single_worker_is_identity() {
        let mut bufs = vec![vec![1.0, -2.0, 3.0]];
        RingAllreduce::new(1).allreduce_mean(&mut bufs);
        assert_eq!(bufs[0], vec![1.0, -2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "buffer count")]
    fn wrong_buffer_count_panics() {
        let mut bufs = vec![vec![0.0; 4]; 3];
        RingAllreduce::new(2).allreduce_mean(&mut bufs);
    }

    /// Run a `w`-thread collective: thread `r` gets buffer `r` and calls
    /// `op(group, rank, buf)`; returns the final buffers.
    fn run_group<F>(bufs: Vec<Vec<f32>>, op: F) -> Vec<Vec<f32>>
    where
        F: Fn(&ShardGroup, usize, &mut [f32]) + Send + Sync + 'static,
    {
        let w = bufs.len();
        let group = std::sync::Arc::new(ShardGroup::new(w));
        let op = std::sync::Arc::new(op);
        let handles: Vec<_> = bufs
            .into_iter()
            .enumerate()
            .map(|(rank, mut buf)| {
                let group = std::sync::Arc::clone(&group);
                let op = std::sync::Arc::clone(&op);
                std::thread::spawn(move || {
                    op(&group, rank, &mut buf);
                    buf
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    fn gather_case(w: usize, bounds: Vec<usize>) {
        let n = *bounds.last().unwrap();
        let mut rng = Pcg64::seeded((w * 7919 + n) as u64);
        let full: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        // Rank r starts with only its own segment valid.
        let bufs: Vec<Vec<f32>> = (0..w)
            .map(|r| {
                let mut b = vec![f32::NAN; n];
                b[bounds[r]..bounds[r + 1]].copy_from_slice(&full[bounds[r]..bounds[r + 1]]);
                b
            })
            .collect();
        let bc = bounds.clone();
        let out = run_group(bufs, move |g, rank, buf| g.allgather(rank, buf, &bc));
        for (r, b) in out.iter().enumerate() {
            assert_eq!(b, &full, "rank {r} allgather mismatch (w={w}, bounds={bounds:?})");
        }
    }

    #[test]
    fn allgather_matches_across_shapes() {
        gather_case(1, vec![0, 9]);
        gather_case(2, vec![0, 4, 9]);
        gather_case(3, vec![0, 5, 5, 12]); // empty middle segment
        gather_case(4, vec![0, 1, 2, 3, 4]);
        gather_case(4, vec![0, 16, 32, 48, 64]);
    }

    fn sum_case(w: usize, n: usize) {
        let mut rng = Pcg64::seeded((w * 104729 + n) as u64);
        let bufs: Vec<Vec<f32>> =
            (0..w).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        // Reference: accumulate in ring order per segment — seg s sums
        // ranks s, s+1, ... around the ring, then everything allclose
        // (and every rank bit-identical to every other).
        let mut want = vec![0f32; n];
        for s in 0..w {
            let (lo, hi) = segment_bounds(n, w, s);
            for j in lo..hi {
                let mut acc = bufs[s][j];
                for step in 1..w {
                    acc += bufs[(s + step) % w][j];
                }
                want[j] = acc;
            }
        }
        let out = run_group(bufs, |g, rank, buf| g.allreduce_sum(rank, buf));
        for (r, b) in out.iter().enumerate() {
            for (j, (&got, &expect)) in b.iter().zip(&want).enumerate() {
                assert!(
                    got.to_bits() == expect.to_bits()
                        || (got - expect).abs() < 1e-5 * (1.0 + expect.abs()),
                    "rank {r} elem {j}: {got} vs {expect} (w={w}, n={n})"
                );
            }
            assert_eq!(b, &out[0], "rank {r} not bit-identical to rank 0");
        }
    }

    #[test]
    fn allreduce_sum_matches_ring_order_reference() {
        for w in [1, 2, 3, 4] {
            for n in [1, 3, 16, 257] {
                sum_case(w, n);
            }
        }
    }

    #[test]
    fn allreduce_sum_is_deterministic_across_runs() {
        let w = 4;
        let n = 129;
        let make = || -> Vec<Vec<f32>> {
            let mut rng = Pcg64::seeded(42);
            (0..w).map(|_| (0..n).map(|_| rng.normal()).collect()).collect()
        };
        let a = run_group(make(), |g, rank, buf| g.allreduce_sum(rank, buf));
        let b = run_group(make(), |g, rank, buf| g.allreduce_sum(rank, buf));
        assert_eq!(a, b, "allreduce_sum must be bit-identical run to run");
    }

    #[test]
    fn barrier_keeps_rounds_in_lockstep() {
        let w = 3;
        let rounds = 50;
        let group = std::sync::Arc::new(ShardGroup::new(w));
        let count = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let handles: Vec<_> = (0..w)
            .map(|_| {
                let group = std::sync::Arc::clone(&group);
                let count = std::sync::Arc::clone(&count);
                std::thread::spawn(move || {
                    for round in 0..rounds {
                        count.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                        group.barrier();
                        // Every thread of round r sees all w increments of
                        // round r and none of round r + 1 yet... until it
                        // increments again itself.
                        let seen = count.load(std::sync::atomic::Ordering::SeqCst);
                        assert!(seen >= (round + 1) * w && seen < (round + 2) * w);
                        group.barrier();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
