//! Distributed data-parallel gradient synchronization with sparse handling.
//!
//! Reproduces STen's §4.6 design space for synchronizing *sparse* gradients
//! across data-parallel workers:
//!
//! * [`GradSyncMode::Dense`] — the baseline: gradients travel dense.
//! * [`GradSyncMode::SparseResparsify`] — the conservative semantics:
//!   densify each worker's masked gradient, allreduce, re-apply each
//!   worker's mask to the mean (sum-then-resparsify, the paper's default).
//! * [`GradSyncMode::SparseFixedPattern`] — the optimization when every
//!   worker shares one mask (standard DDP): the nonzero *values* are
//!   reduced directly, skipping densification and re-sparsification.
//!
//! The per-phase time split ([`GradSyncStats`]) is what the §6.1
//! weak-scaling experiment reports: sparse handling must stay a small
//! fraction of allreduce time.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::formats::{AnyTensor, MaskedTensor};
use crate::tensor::DenseTensor;

use super::collective::RingAllreduce;

/// How gradients are synchronized across workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GradSyncMode {
    /// Densify everything; plain dense allreduce.
    Dense,
    /// Densify, allreduce, re-apply each worker's mask to the mean.
    SparseResparsify,
    /// Allreduce the masked values directly (requires one shared pattern).
    SparseFixedPattern,
}

/// Seconds spent in each phase of one synchronization.
#[derive(Debug, Default, Clone, Copy)]
pub struct GradSyncStats {
    /// Sparse -> dense conversion.
    pub to_dense_s: f64,
    /// The allreduce itself.
    pub allreduce_s: f64,
    /// Re-sparsification of the reduced gradient.
    pub resparsify_s: f64,
}

/// Synchronize one parameter's per-worker gradients; returns the synced
/// gradient for every worker (all numerically identical) plus the phase
/// time split. `per_worker.len()` must match the ring size and all
/// gradients must share one shape.
pub fn sync_gradients(
    ring: &RingAllreduce,
    per_worker: &[AnyTensor],
    mode: GradSyncMode,
) -> Result<(Vec<AnyTensor>, GradSyncStats)> {
    if per_worker.is_empty() {
        bail!("sync_gradients needs at least one worker gradient");
    }
    if per_worker.len() != ring.workers() {
        bail!(
            "got {} gradients for a ring of {} workers",
            per_worker.len(),
            ring.workers()
        );
    }
    let shape = per_worker[0].shape().to_vec();
    for g in per_worker {
        if g.shape() != shape.as_slice() {
            bail!("ragged gradient shapes: {:?} vs {:?}", g.shape(), shape);
        }
    }
    let mut stats = GradSyncStats::default();
    let all_masked = per_worker.iter().all(|g| matches!(g, AnyTensor::Masked(_)));

    if mode == GradSyncMode::SparseFixedPattern && all_masked {
        // Fixed shared pattern: reduce the pre-masked value arrays
        // directly — no densify, no resparsify. (With one shared mask the
        // mean of masked values *is* the masked mean.)
        let t = Instant::now();
        let mut bufs: Vec<Vec<f32>> = per_worker
            .iter()
            .map(|g| match g {
                AnyTensor::Masked(m) => m.values().data().to_vec(),
                _ => unreachable!("all_masked checked above"),
            })
            .collect();
        ring.allreduce_mean(&mut bufs);
        stats.allreduce_s = t.elapsed().as_secs_f64();
        let synced = per_worker
            .iter()
            .zip(bufs)
            .map(|(g, buf)| match g {
                AnyTensor::Masked(m) => AnyTensor::Masked(
                    m.with_values(&DenseTensor::from_vec(&shape, buf)),
                ),
                _ => unreachable!(),
            })
            .collect();
        return Ok((synced, stats));
    }

    // Conservative path: densify, allreduce, optionally resparsify.
    let t = Instant::now();
    let mut bufs: Vec<Vec<f32>> =
        per_worker.iter().map(|g| g.to_dense().into_vec()).collect();
    stats.to_dense_s = t.elapsed().as_secs_f64();

    let t = Instant::now();
    ring.allreduce_mean(&mut bufs);
    stats.allreduce_s = t.elapsed().as_secs_f64();

    let t = Instant::now();
    let resparsify = mode != GradSyncMode::Dense && all_masked;
    let synced: Vec<AnyTensor> = per_worker
        .iter()
        .zip(bufs)
        .map(|(g, buf)| {
            let mean = DenseTensor::from_vec(&shape, buf);
            match (resparsify, g) {
                (true, AnyTensor::Masked(m)) => AnyTensor::Masked(m.with_values(&mean)),
                _ => AnyTensor::Dense(mean),
            }
        })
        .collect();
    if resparsify {
        stats.resparsify_s = t.elapsed().as_secs_f64();
    }
    Ok((synced, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn grads(workers: usize, n: usize, seed: u64) -> Vec<DenseTensor> {
        let mut rng = Pcg64::seeded(seed);
        (0..workers).map(|_| DenseTensor::randn(&[n], &mut rng)).collect()
    }

    fn mean_of(gs: &[DenseTensor]) -> DenseTensor {
        let mut acc = DenseTensor::zeros(gs[0].shape());
        for g in gs {
            acc.axpy(1.0, g);
        }
        acc.scale(1.0 / gs.len() as f32);
        acc
    }

    #[test]
    fn dense_sync_averages_and_matches_all_replicas() {
        let ring = RingAllreduce::new(4);
        let gs = grads(4, 33, 1);
        let per: Vec<AnyTensor> = gs.iter().map(|g| AnyTensor::Dense(g.clone())).collect();
        let (synced, stats) = sync_gradients(&ring, &per, GradSyncMode::Dense).unwrap();
        let want = mean_of(&gs);
        assert_eq!(synced.len(), 4);
        for s in &synced {
            assert!(s.to_dense().allclose(&want, 1e-5, 1e-5));
        }
        assert!(stats.allreduce_s >= 0.0 && stats.resparsify_s == 0.0);
    }

    #[test]
    fn resparsify_keeps_each_workers_mask() {
        let ring = RingAllreduce::new(3);
        let gs = grads(3, 24, 2);
        let mut rng = Pcg64::seeded(3);
        let mask = DenseTensor::from_vec(
            &[24],
            (0..24).map(|_| if rng.next_f32() < 0.5 { 1.0 } else { 0.0 }).collect(),
        );
        let per: Vec<AnyTensor> = gs
            .iter()
            .map(|g| AnyTensor::Masked(MaskedTensor::new(g.clone(), mask.clone())))
            .collect();
        let (synced, _) = sync_gradients(&ring, &per, GradSyncMode::SparseResparsify).unwrap();
        // The mean of *masked* gradients, re-masked.
        let masked: Vec<DenseTensor> = gs.iter().map(|g| g.zip(&mask, |v, m| v * m)).collect();
        let want = mean_of(&masked).zip(&mask, |v, m| v * m);
        for s in &synced {
            assert!(matches!(s, AnyTensor::Masked(_)));
            assert!(s.to_dense().allclose(&want, 1e-5, 1e-5));
        }
    }

    #[test]
    fn fixed_pattern_matches_resparsify_under_shared_mask() {
        let ring = RingAllreduce::new(4);
        let gs = grads(4, 40, 4);
        let mask = DenseTensor::from_vec(
            &[40],
            (0..40).map(|i| if i % 4 < 2 { 1.0 } else { 0.0 }).collect(),
        );
        let per: Vec<AnyTensor> = gs
            .iter()
            .map(|g| AnyTensor::Masked(MaskedTensor::new(g.clone(), mask.clone())))
            .collect();
        let (a, sa) = sync_gradients(&ring, &per, GradSyncMode::SparseResparsify).unwrap();
        let (b, sb) = sync_gradients(&ring, &per, GradSyncMode::SparseFixedPattern).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!(x.to_dense().allclose(&y.to_dense(), 1e-5, 1e-5));
        }
        // The fixed-pattern path skips densification entirely.
        assert!(sa.to_dense_s > 0.0);
        assert_eq!(sb.to_dense_s, 0.0);
        assert_eq!(sb.resparsify_s, 0.0);
    }

    #[test]
    fn mixed_inputs_fall_back_to_dense() {
        let ring = RingAllreduce::new(2);
        let gs = grads(2, 8, 5);
        let mask = DenseTensor::ones(&[8]);
        let per = vec![
            AnyTensor::Masked(MaskedTensor::new(gs[0].clone(), mask)),
            AnyTensor::Dense(gs[1].clone()),
        ];
        let (synced, _) = sync_gradients(&ring, &per, GradSyncMode::SparseResparsify).unwrap();
        assert!(synced.iter().all(|s| matches!(s, AnyTensor::Dense(_))));
    }

    #[test]
    fn shape_and_count_validation() {
        let ring = RingAllreduce::new(2);
        let gs = grads(2, 8, 6);
        let one = vec![AnyTensor::Dense(gs[0].clone())];
        assert!(sync_gradients(&ring, &one, GradSyncMode::Dense).is_err());
        let ragged = vec![
            AnyTensor::Dense(gs[0].clone()),
            AnyTensor::Dense(DenseTensor::zeros(&[9])),
        ];
        assert!(sync_gradients(&ring, &ragged, GradSyncMode::Dense).is_err());
        assert!(sync_gradients(&ring, &[], GradSyncMode::Dense).is_err());
    }
}
