//! Data-parallel gradient synchronization with sparse handling (§4.6).
//!
//! In-process simulation of distributed masked training: [`collective`]
//! implements faithful ring collectives — a caller-orchestrated allreduce
//! (reduce-scatter + allgather over per-worker buffers) plus the
//! thread-cooperative [`collective::ShardGroup`] family (allgather /
//! allreduce-sum with a sense-reversing barrier) used by tensor-parallel
//! sharded execution — and [`ddp`] layers STen's sparse gradient handling
//! on top — the conservative convert-and-resparsify path and the
//! fixed-pattern optimization that skips densification when every worker
//! shares one mask (the §6.1 weak-scaling experiment).

pub mod collective;
pub mod ddp;

pub use collective::{RingAllreduce, ShardBarrier, ShardGroup};
pub use ddp::{sync_gradients, GradSyncMode, GradSyncStats};
