//! The paper's *energy* metric (§6.1, Fig. 7): `||pruned||_1 / ||dense||_1`.
//!
//! Energy in [0, 1] captures how much of a tensor's magnitude a pruning
//! preserves; Fig. 7 compares it across sparsity structures (unstructured,
//! n:m, n:m:g with varying g, blocked).

use crate::formats::{BcsrTensor, NmTensor, NmgTensor};
use crate::sparsify::{BlockFraction, ScalarFraction, Sparsifier};
use crate::tensor::DenseTensor;

/// Energy of a pruned tensor relative to the original.
pub fn energy(dense: &DenseTensor, pruned: &DenseTensor) -> f64 {
    assert_eq!(dense.shape(), pruned.shape(), "energy shape mismatch");
    let denom = dense.l1_norm() as f64;
    if denom == 0.0 {
        return 1.0;
    }
    pruned.l1_norm() as f64 / denom
}

/// Energy of unstructured magnitude pruning at `sparsity`.
pub fn energy_unstructured(dense: &DenseTensor, sparsity: f32) -> f64 {
    energy(dense, &ScalarFraction { fraction: sparsity }.prune(dense))
}

/// Energy of plain n:m pruning.
pub fn energy_nm(dense: &DenseTensor, n: usize, m: usize) -> f64 {
    energy(dense, &NmTensor::from_dense(dense, n, m).to_dense())
}

/// Energy of n:m:g pruning.
pub fn energy_nmg(dense: &DenseTensor, n: usize, m: usize, g: usize) -> f64 {
    energy(dense, &NmgTensor::from_dense(dense, n, m, g).to_dense())
}

/// Energy of block-magnitude pruning at `sparsity` with `bh x bw` blocks.
pub fn energy_blocked(dense: &DenseTensor, sparsity: f32, bh: usize, bw: usize) -> f64 {
    energy(dense, &BlockFraction { fraction: sparsity, bh, bw }.prune(dense))
}

/// Storage bytes of each layout at the same sparsity (context for Fig. 7).
pub fn storage_report(dense: &DenseTensor, n: usize, m: usize, g: usize) -> Vec<(&'static str, usize)> {
    let pruned = ScalarFraction { fraction: 1.0 - n as f32 / m as f32 }.prune(dense);
    vec![
        ("dense", dense.numel() * 4),
        ("csr", crate::formats::CsrTensor::from_dense(&pruned).bytes()),
        ("nm", NmTensor::from_dense(dense, n, m).bytes()),
        ("nmg", NmgTensor::from_dense(dense, n, m, g).bytes()),
        ("bcsr", BcsrTensor::from_dense(&BlockFraction { fraction: 1.0 - n as f32 / m as f32, bh: 4, bw: 4 }.prune(dense), 4, 4).bytes()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn weight() -> DenseTensor {
        let mut rng = Pcg64::seeded(200);
        DenseTensor::randn(&[64, 96], &mut rng)
    }

    #[test]
    fn energy_bounds() {
        let w = weight();
        for s in [0.5, 0.75, 0.9] {
            let e = energy_unstructured(&w, s);
            assert!((0.0..=1.0).contains(&e), "{e}");
        }
        assert_eq!(energy(&w, &w), 1.0);
        assert_eq!(energy(&w, &DenseTensor::zeros(w.shape())), 0.0);
    }

    #[test]
    fn fig7_structure_ordering() {
        // Fig. 7's qualitative result: unstructured >= n:m >= n:m:g(g) >= blocked,
        // with n:m:g approaching n:m as g grows.
        let w = weight();
        let unstructured = energy_unstructured(&w, 0.5);
        let nm = energy_nm(&w, 2, 4);
        let nmg16 = energy_nmg(&w, 2, 4, 16);
        let nmg1 = energy_nmg(&w, 2, 4, 1);
        let blocked = energy_blocked(&w, 0.5, 4, 4);
        assert!(unstructured >= nm - 1e-9, "unstructured {unstructured} vs nm {nm}");
        assert!(nm >= nmg16 - 1e-6, "nm {nm} vs nmg16 {nmg16}");
        assert!(nmg16 >= nmg1 - 0.02, "nmg16 {nmg16} vs nmg1 {nmg1}");
        assert!(nmg1 > blocked, "nmg1 {nmg1} vs blocked {blocked}");
        // n:m:g with g=16 should be within a few percent of n:m (paper claim).
        assert!(nm - nmg16 < 0.05, "gap {}", nm - nmg16);
    }

    #[test]
    fn zero_tensor_energy_is_one() {
        let z = DenseTensor::zeros(&[4, 4]);
        assert_eq!(energy(&z, &z), 1.0);
    }

    #[test]
    fn storage_report_nmg_beats_csr_at_50pct() {
        let w = weight();
        let report = storage_report(&w, 2, 4, 4);
        let get = |name: &str| report.iter().find(|(n, _)| *n == name).unwrap().1;
        assert!(get("nmg") < get("dense"));
        assert!(get("nmg") < get("csr"), "nmg {} csr {}", get("nmg"), get("csr"));
    }
}
