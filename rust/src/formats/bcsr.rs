//! Block CSR format: CSR over dense `bh x bw` blocks.
//!
//! This is the "more structure than n:m:g" comparator of Fig. 7 (block
//! magnitude pruning) and the substrate of the TVM-block-style GEMM
//! ([`crate::kernels::bcsr_gemm`]).

use crate::tensor::DenseTensor;

/// BCSR matrix: nonzero blocks of shape `bh x bw`, CSR-indexed by block row.
#[derive(Debug, Clone, PartialEq)]
pub struct BcsrTensor {
    shape: [usize; 2],
    /// Block height.
    pub bh: usize,
    /// Block width.
    pub bw: usize,
    /// Block-row pointers (len = rows/bh + 1).
    pub indptr: Vec<usize>,
    /// Block-column index per stored block.
    pub indices: Vec<u32>,
    /// Dense block payloads, each `bh * bw`, row-major per block.
    pub blocks: Vec<f32>,
}

impl BcsrTensor {
    /// Compress a dense matrix, storing every block containing a nonzero.
    /// Requires `rows % bh == 0 && cols % bw == 0`.
    pub fn from_dense(d: &DenseTensor, bh: usize, bw: usize) -> Self {
        assert_eq!(d.rank(), 2, "BCSR requires 2-D");
        let (rows, cols) = (d.rows(), d.cols());
        assert!(rows % bh == 0 && cols % bw == 0, "shape {rows}x{cols} not divisible by block {bh}x{bw}");
        let (brows, bcols) = (rows / bh, cols / bw);
        let mut indptr = vec![0usize];
        let mut indices = Vec::new();
        let mut blocks = Vec::new();
        for br in 0..brows {
            for bc in 0..bcols {
                let mut any = false;
                'scan: for i in 0..bh {
                    for j in 0..bw {
                        if d.get2(br * bh + i, bc * bw + j) != 0.0 {
                            any = true;
                            break 'scan;
                        }
                    }
                }
                if any {
                    indices.push(bc as u32);
                    for i in 0..bh {
                        for j in 0..bw {
                            blocks.push(d.get2(br * bh + i, bc * bw + j));
                        }
                    }
                }
            }
            indptr.push(indices.len());
        }
        BcsrTensor { shape: [rows, cols], bh, bw, indptr, indices, blocks }
    }

    /// The row-slice covering block rows `[br0, br1)` — the format's
    /// natural sharding boundary (tensor-parallel row splits must land on
    /// block-row edges so stored blocks stay whole). Rows become
    /// `[br0 * bh, br1 * bh)`; `indptr` is rebased and the covered
    /// `indices`/`blocks` are copied verbatim, so a kernel over the slice
    /// produces exactly the corresponding output rows of the full tensor.
    pub fn slice_block_rows(&self, br0: usize, br1: usize) -> BcsrTensor {
        let brows = self.indptr.len() - 1;
        assert!(br0 <= br1 && br1 <= brows, "block-row range {br0}..{br1} out of 0..{brows}");
        let (blk_lo, blk_hi) = (self.indptr[br0], self.indptr[br1]);
        let bsz = self.bh * self.bw;
        BcsrTensor {
            shape: [(br1 - br0) * self.bh, self.shape[1]],
            bh: self.bh,
            bw: self.bw,
            indptr: self.indptr[br0..=br1].iter().map(|&p| p - blk_lo).collect(),
            indices: self.indices[blk_lo..blk_hi].to_vec(),
            blocks: self.blocks[blk_lo * bsz..blk_hi * bsz].to_vec(),
        }
    }

    /// Materialize as dense.
    pub fn to_dense(&self) -> DenseTensor {
        let mut out = DenseTensor::zeros(&self.shape);
        let bsz = self.bh * self.bw;
        for br in 0..self.indptr.len() - 1 {
            for (bi, &bc) in self.indices[self.indptr[br]..self.indptr[br + 1]]
                .iter()
                .enumerate()
            {
                let blk = self.indptr[br] + bi;
                for i in 0..self.bh {
                    for j in 0..self.bw {
                        out.set2(
                            br * self.bh + i,
                            bc as usize * self.bw + j,
                            self.blocks[blk * bsz + i * self.bw + j],
                        );
                    }
                }
            }
        }
        out
    }

    /// Shape as a slice.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Number of stored blocks.
    pub fn nblocks(&self) -> usize {
        self.indices.len()
    }

    /// Stored values (block slots; includes explicit zeros inside blocks).
    pub fn nnz(&self) -> usize {
        self.blocks.iter().filter(|&&v| v != 0.0).count()
    }

    /// Storage bytes.
    pub fn bytes(&self) -> usize {
        self.blocks.len() * 4 + self.indices.len() * 4 + self.indptr.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip_exact() {
        let mut rng = Pcg64::seeded(7);
        let mut d = DenseTensor::randn(&[8, 12], &mut rng);
        for (i, x) in d.data_mut().iter_mut().enumerate() {
            if (i / 16) % 2 == 0 {
                *x = 0.0;
            }
        }
        let b = BcsrTensor::from_dense(&d, 4, 4);
        assert_eq!(b.to_dense(), d);
    }

    #[test]
    fn block_count_reflects_structure() {
        // 8x8 matrix with nonzeros only in the top-left 4x4 block.
        let mut d = DenseTensor::zeros(&[8, 8]);
        d.set2(1, 2, 5.0);
        d.set2(3, 3, -1.0);
        let b = BcsrTensor::from_dense(&d, 4, 4);
        assert_eq!(b.nblocks(), 1);
        assert_eq!(b.nnz(), 2);
        assert_eq!(b.to_dense(), d);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn indivisible_shape_rejected() {
        BcsrTensor::from_dense(&DenseTensor::zeros(&[6, 6]), 4, 4);
    }

    #[test]
    fn block_row_slices_cover_the_dense_rows() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::seeded(31);
        let mut d = DenseTensor::randn(&[16, 8], &mut rng);
        // Punch out some blocks so indptr is non-trivial.
        for r in 4..8 {
            for c in 0..8 {
                d.set2(r, c, 0.0);
            }
        }
        let b = BcsrTensor::from_dense(&d, 4, 4);
        let full = b.to_dense();
        for (br0, br1) in [(0, 4), (0, 0), (1, 3), (2, 4), (4, 4)] {
            let s = b.slice_block_rows(br0, br1);
            let sd = s.to_dense();
            assert_eq!(sd.rows(), (br1 - br0) * 4);
            for r in 0..sd.rows() {
                for c in 0..sd.cols() {
                    assert_eq!(sd.get2(r, c), full.get2(br0 * 4 + r, c), "({br0},{br1}) at ({r},{c})");
                }
            }
        }
    }
}
