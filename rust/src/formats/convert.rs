//! Lossless layout conversion (§4.4).
//!
//! The dispatcher may convert operands to find a registered implementation,
//! but "conversion is only attempted when STen can guarantee that it is
//! lossless, to prevent any information loss". Exact-compression formats
//! (CSR/CSC/COO/ELL/BCSR/Masked/Dense) convert freely among themselves;
//! structured formats (n:m, n:m:g) convert *out* losslessly but never *in*
//! (going in requires a sparsifier, which may drop values).

use std::borrow::Cow;

use super::{AnyTensor, BcsrTensor, CooTensor, CscTensor, CsrTensor, EllTensor, Layout, MaskedTensor};

/// True when `from -> to` is guaranteed lossless.
pub fn is_lossless(from: Layout, to: Layout) -> bool {
    use Layout::*;
    if from == to {
        return true;
    }
    let exact_target = matches!(to, Dense | Csr | Csc | Coo | Ell | Masked);
    match from {
        // Exact-compression sources convert to any exact-compression target.
        Dense | Csr | Csc | Coo | Ell | Bcsr | Masked => exact_target,
        // Structured and custom formats escape losslessly to exact formats
        // (their stored values are preserved verbatim).
        Nm | Nmg | Custom => exact_target,
    }
}

/// Convert losslessly, or return `None` when the conversion could lose
/// information (the caller then falls back to dense-with-mask or errors).
pub fn lossless(t: &AnyTensor, target: Layout) -> Option<AnyTensor> {
    lossless_cow(t, target).map(Cow::into_owned)
}

/// Borrow-preserving variant of [`lossless`]: an operand already in the
/// target layout comes back as `Cow::Borrowed` — no clone — so the
/// dispatcher's conversion path only pays for operands that actually change
/// layout (it counts the borrows as `avoided_clones` in `DispatchStats`).
pub fn lossless_cow(t: &AnyTensor, target: Layout) -> Option<Cow<'_, AnyTensor>> {
    if t.layout() == target {
        return Some(Cow::Borrowed(t));
    }
    if !is_lossless(t.layout(), target) {
        return None;
    }
    let dense = t.to_dense();
    Some(Cow::Owned(match target {
        Layout::Dense => AnyTensor::Dense(dense),
        Layout::Csr => AnyTensor::Csr(CsrTensor::from_dense(&dense)),
        Layout::Csc => AnyTensor::Csc(CscTensor::from_dense(&dense)),
        Layout::Coo => AnyTensor::Coo(CooTensor::from_dense(&dense)),
        Layout::Ell => AnyTensor::Ell(EllTensor::from_dense(&dense)),
        Layout::Masked => AnyTensor::Masked(MaskedTensor::from_dense(&dense)),
        // Bcsr target needs block-size parameters; not offered as an
        // automatic conversion target. Nm/Nmg/Custom require sparsifiers.
        _ => return None,
    }))
}

/// Exact BCSR conversion with explicit block shape (all nonzero blocks kept).
pub fn to_bcsr(t: &AnyTensor, bh: usize, bw: usize) -> AnyTensor {
    AnyTensor::Bcsr(BcsrTensor::from_dense(&t.to_dense(), bh, bw))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DenseTensor;
    use crate::util::rng::Pcg64;

    fn sample() -> AnyTensor {
        let mut rng = Pcg64::seeded(21);
        let d = DenseTensor::randn(&[8, 8], &mut rng)
            .map(|x| if x > 0.3 { x } else { 0.0 });
        AnyTensor::Csr(CsrTensor::from_dense(&d))
    }

    #[test]
    fn lossless_roundtrips_preserve_values() {
        let t = sample();
        let want = t.to_dense();
        for target in [Layout::Dense, Layout::Csc, Layout::Coo, Layout::Ell, Layout::Masked] {
            let converted = lossless(&t, target).unwrap();
            assert_eq!(converted.layout(), target);
            assert!(converted.to_dense().allclose(&want, 0.0, 0.0), "{target}");
        }
    }

    #[test]
    fn structured_targets_refused() {
        let t = sample();
        assert!(lossless(&t, Layout::Nm).is_none());
        assert!(lossless(&t, Layout::Nmg).is_none());
        assert!(lossless(&t, Layout::Bcsr).is_none());
        assert!(lossless(&t, Layout::Custom).is_none());
    }

    #[test]
    fn identity_conversion_is_always_allowed() {
        let t = sample();
        let same = lossless(&t, Layout::Csr).unwrap();
        assert_eq!(same.layout(), Layout::Csr);
    }

    #[test]
    fn identity_conversion_borrows_instead_of_cloning() {
        let t = sample();
        match lossless_cow(&t, Layout::Csr) {
            Some(Cow::Borrowed(b)) => assert!(std::ptr::eq(b, &t)),
            other => panic!("expected borrowed identity conversion, got {other:?}"),
        }
        // A layout change still produces an owned tensor.
        assert!(matches!(lossless_cow(&t, Layout::Dense), Some(Cow::Owned(_))));
    }

    #[test]
    fn structured_sources_escape_losslessly() {
        use crate::formats::NmgTensor;
        let mut rng = Pcg64::seeded(22);
        let d = DenseTensor::randn(&[8, 24], &mut rng);
        let t = AnyTensor::Nmg(NmgTensor::from_dense(&d, 2, 4, 2));
        let pruned = t.to_dense();
        let csr = lossless(&t, Layout::Csr).unwrap();
        assert!(csr.to_dense().allclose(&pruned, 0.0, 0.0));
    }

    #[test]
    fn explicit_bcsr_conversion() {
        let t = sample();
        let b = to_bcsr(&t, 4, 4);
        assert_eq!(b.layout(), Layout::Bcsr);
        assert!(b.to_dense().allclose(&t.to_dense(), 0.0, 0.0));
    }
}
