//! Coordinate (COO) format: nonzeros with absolute offsets.

use crate::tensor::DenseTensor;

/// COO tensor: parallel arrays of (row, col, value), sorted row-major.
#[derive(Debug, Clone, PartialEq)]
pub struct CooTensor {
    shape: [usize; 2],
    /// Row coordinate per nonzero.
    pub rows: Vec<u32>,
    /// Column coordinate per nonzero.
    pub cols: Vec<u32>,
    /// Nonzero values.
    pub values: Vec<f32>,
}

impl CooTensor {
    /// Compress a dense matrix (exact, row-major sorted).
    pub fn from_dense(d: &DenseTensor) -> Self {
        assert_eq!(d.rank(), 2, "COO requires 2-D");
        let (nr, nc) = (d.rows(), d.cols());
        let mut rows = Vec::new();
        let mut cols = Vec::new();
        let mut values = Vec::new();
        for r in 0..nr {
            for c in 0..nc {
                let v = d.get2(r, c);
                if v != 0.0 {
                    rows.push(r as u32);
                    cols.push(c as u32);
                    values.push(v);
                }
            }
        }
        CooTensor { shape: [nr, nc], rows, cols, values }
    }

    /// Materialize as dense (duplicate coordinates accumulate).
    pub fn to_dense(&self) -> DenseTensor {
        let mut out = DenseTensor::zeros(&self.shape);
        for ((&r, &c), &v) in self.rows.iter().zip(&self.cols).zip(&self.values) {
            let cur = out.get2(r as usize, c as usize);
            out.set2(r as usize, c as usize, cur + v);
        }
        out
    }

    /// Shape as a slice.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Storage bytes.
    pub fn bytes(&self) -> usize {
        self.values.len() * 4 + self.rows.len() * 4 + self.cols.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip_exact() {
        let mut rng = Pcg64::seeded(5);
        let mut d = DenseTensor::randn(&[6, 7], &mut rng);
        for (i, x) in d.data_mut().iter_mut().enumerate() {
            if i % 3 != 0 {
                *x = 0.0;
            }
        }
        let coo = CooTensor::from_dense(&d);
        assert_eq!(coo.to_dense(), d);
        assert_eq!(coo.nnz(), d.numel() - d.count_zeros());
    }

    #[test]
    fn duplicates_accumulate() {
        let coo = CooTensor {
            shape: [2, 2],
            rows: vec![0, 0, 1],
            cols: vec![1, 1, 0],
            values: vec![1.5, 2.5, -1.0],
        };
        let d = coo.to_dense();
        assert_eq!(d.get2(0, 1), 4.0);
        assert_eq!(d.get2(1, 0), -1.0);
    }
}
