//! Compressed Sparse Column format.

use crate::tensor::DenseTensor;

/// CSC matrix: `indptr[c]..indptr[c+1]` indexes `indices`/`values` for column `c`.
#[derive(Debug, Clone, PartialEq)]
pub struct CscTensor {
    shape: [usize; 2],
    /// Column pointers, length cols + 1.
    pub indptr: Vec<usize>,
    /// Row index per nonzero.
    pub indices: Vec<u32>,
    /// Nonzero values (column-major order).
    pub values: Vec<f32>,
}

impl CscTensor {
    /// Compress a dense matrix (exact).
    pub fn from_dense(d: &DenseTensor) -> Self {
        assert_eq!(d.rank(), 2, "CSC requires 2-D");
        let (rows, cols) = (d.rows(), d.cols());
        let mut indptr = Vec::with_capacity(cols + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for c in 0..cols {
            for r in 0..rows {
                let v = d.get2(r, c);
                if v != 0.0 {
                    indices.push(r as u32);
                    values.push(v);
                }
            }
            indptr.push(values.len());
        }
        CscTensor { shape: [rows, cols], indptr, indices, values }
    }

    /// Materialize as dense.
    pub fn to_dense(&self) -> DenseTensor {
        let mut out = DenseTensor::zeros(&self.shape);
        for c in 0..self.shape[1] {
            for i in self.indptr[c]..self.indptr[c + 1] {
                out.set2(self.indices[i] as usize, c, self.values[i]);
            }
        }
        out
    }

    /// Shape as a slice.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Storage bytes.
    pub fn bytes(&self) -> usize {
        self.values.len() * 4 + self.indices.len() * 4 + self.indptr.len() * 8
    }

    /// Iterate nonzeros of one column as `(row, value)`.
    pub fn col(&self, c: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        let lo = self.indptr[c];
        let hi = self.indptr[c + 1];
        self.indices[lo..hi]
            .iter()
            .zip(&self.values[lo..hi])
            .map(|(&r, &v)| (r as usize, v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::csr::CsrTensor;
    use crate::util::rng::Pcg64;

    fn sparse_dense(rng: &mut Pcg64, rows: usize, cols: usize, density: f32) -> DenseTensor {
        let data = (0..rows * cols)
            .map(|_| if rng.next_f32() < density { rng.normal() } else { 0.0 })
            .collect();
        DenseTensor::from_vec(&[rows, cols], data)
    }

    #[test]
    fn roundtrip_exact() {
        let mut rng = Pcg64::seeded(3);
        let d = sparse_dense(&mut rng, 9, 5, 0.4);
        let csc = CscTensor::from_dense(&d);
        assert_eq!(csc.to_dense(), d);
    }

    #[test]
    fn csc_agrees_with_csr_transpose_structure() {
        let mut rng = Pcg64::seeded(4);
        let d = sparse_dense(&mut rng, 6, 8, 0.3);
        let csc = CscTensor::from_dense(&d);
        let csr_t = CsrTensor::from_dense(&d.transpose2());
        assert_eq!(csc.values, csr_t.values);
        assert_eq!(csc.indices, csr_t.indices);
        assert_eq!(csc.indptr, csr_t.indptr);
    }

    #[test]
    fn col_iteration() {
        let d = DenseTensor::from_vec(&[3, 2], vec![1.0, 0.0, 0.0, 2.0, 3.0, 0.0]);
        let csc = CscTensor::from_dense(&d);
        assert_eq!(csc.col(0).collect::<Vec<_>>(), vec![(0, 1.0), (2, 3.0)]);
        assert_eq!(csc.col(1).collect::<Vec<_>>(), vec![(1, 2.0)]);
    }
}
