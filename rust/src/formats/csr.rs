//! Compressed Sparse Row format.

use crate::tensor::DenseTensor;

/// CSR matrix: `indptr[r]..indptr[r+1]` indexes `indices`/`values` for row `r`.
///
/// This is also the substrate of the DeepSparse-style unstructured comparator
/// kernel ([`crate::kernels::csr_gemm`]).
#[derive(Debug, Clone, PartialEq)]
pub struct CsrTensor {
    shape: [usize; 2],
    /// Row pointers, length rows + 1.
    pub indptr: Vec<usize>,
    /// Column index per nonzero.
    pub indices: Vec<u32>,
    /// Nonzero values.
    pub values: Vec<f32>,
}

impl CsrTensor {
    /// Build from raw arrays (validates invariants).
    pub fn new(shape: [usize; 2], indptr: Vec<usize>, indices: Vec<u32>, values: Vec<f32>) -> Self {
        assert_eq!(indptr.len(), shape[0] + 1, "indptr length");
        assert_eq!(indices.len(), values.len(), "indices/values length");
        assert_eq!(*indptr.last().unwrap(), values.len(), "indptr total");
        debug_assert!(indptr.windows(2).all(|w| w[0] <= w[1]), "indptr monotone");
        debug_assert!(indices.iter().all(|&c| (c as usize) < shape[1]), "col bounds");
        CsrTensor { shape, indptr, indices, values }
    }

    /// Compress a dense matrix (exact: keeps every nonzero).
    pub fn from_dense(d: &DenseTensor) -> Self {
        assert_eq!(d.rank(), 2, "CSR requires 2-D");
        let (rows, cols) = (d.rows(), d.cols());
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for r in 0..rows {
            for c in 0..cols {
                let v = d.get2(r, c);
                if v != 0.0 {
                    indices.push(c as u32);
                    values.push(v);
                }
            }
            indptr.push(values.len());
        }
        CsrTensor { shape: [rows, cols], indptr, indices, values }
    }

    /// Materialize as dense.
    pub fn to_dense(&self) -> DenseTensor {
        let mut out = DenseTensor::zeros(&self.shape);
        for r in 0..self.shape[0] {
            for i in self.indptr[r]..self.indptr[r + 1] {
                out.set2(r, self.indices[i] as usize, self.values[i]);
            }
        }
        out
    }

    /// Shape as a slice.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Storage bytes: values + column indices + row pointers.
    pub fn bytes(&self) -> usize {
        self.values.len() * 4 + self.indices.len() * 4 + self.indptr.len() * 8
    }

    /// Iterate nonzeros of one row as `(col, value)`.
    pub fn row(&self, r: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        self.indices[lo..hi]
            .iter()
            .zip(&self.values[lo..hi])
            .map(|(&c, &v)| (c as usize, v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Pcg64;

    fn sparse_dense(rng: &mut Pcg64, rows: usize, cols: usize, density: f32) -> DenseTensor {
        let data = (0..rows * cols)
            .map(|_| if rng.next_f32() < density { rng.normal() } else { 0.0 })
            .collect();
        DenseTensor::from_vec(&[rows, cols], data)
    }

    #[test]
    fn roundtrip_exact() {
        let mut rng = Pcg64::seeded(1);
        let d = sparse_dense(&mut rng, 7, 9, 0.3);
        let csr = CsrTensor::from_dense(&d);
        assert_eq!(csr.to_dense(), d);
        assert_eq!(csr.nnz(), d.numel() - d.count_zeros());
    }

    #[test]
    fn empty_matrix() {
        let d = DenseTensor::zeros(&[3, 3]);
        let csr = CsrTensor::from_dense(&d);
        assert_eq!(csr.nnz(), 0);
        assert_eq!(csr.to_dense(), d);
    }

    #[test]
    fn row_iteration() {
        let d = DenseTensor::from_vec(&[2, 3], vec![1.0, 0.0, 2.0, 0.0, 3.0, 0.0]);
        let csr = CsrTensor::from_dense(&d);
        let row0: Vec<_> = csr.row(0).collect();
        assert_eq!(row0, vec![(0, 1.0), (2, 2.0)]);
        let row1: Vec<_> = csr.row(1).collect();
        assert_eq!(row1, vec![(1, 3.0)]);
    }

    #[test]
    fn bytes_smaller_than_dense_when_sparse() {
        let mut rng = Pcg64::seeded(2);
        let d = sparse_dense(&mut rng, 64, 64, 0.05);
        let csr = CsrTensor::from_dense(&d);
        assert!(csr.bytes() < d.numel() * 4);
    }

    #[test]
    fn prop_roundtrip() {
        proptest::check(
            "csr-roundtrip",
            50,
            |rng| {
                let rows = 1 + rng.below(12) as usize;
                let cols = 1 + rng.below(12) as usize;
                let density = rng.next_f32();
                let mut r2 = Pcg64::seeded(rng.next_u64());
                sparse_dense(&mut r2, rows, cols, density)
            },
            |d| CsrTensor::from_dense(d).to_dense() == *d,
        );
    }

    #[test]
    #[should_panic(expected = "indptr length")]
    fn invalid_indptr_rejected() {
        CsrTensor::new([2, 2], vec![0, 1], vec![0], vec![1.0]);
    }
}
