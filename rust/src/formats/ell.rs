//! ELLPACK format: fixed number of stored entries per row.

use crate::tensor::DenseTensor;

/// ELL tensor: `width` entries per row, padded with explicit zeros.
///
/// `indices[r * width + j]` / `values[r * width + j]` is entry `j` of row `r`;
/// padding entries carry value 0 and repeat the last valid column index.
#[derive(Debug, Clone, PartialEq)]
pub struct EllTensor {
    shape: [usize; 2],
    /// Entries stored per row.
    pub width: usize,
    /// Column index per slot (rows * width).
    pub indices: Vec<u32>,
    /// Value per slot (rows * width).
    pub values: Vec<f32>,
}

impl EllTensor {
    /// Compress a dense matrix; width = max row nnz.
    pub fn from_dense(d: &DenseTensor) -> Self {
        assert_eq!(d.rank(), 2, "ELL requires 2-D");
        let (rows, cols) = (d.rows(), d.cols());
        let width = (0..rows)
            .map(|r| (0..cols).filter(|&c| d.get2(r, c) != 0.0).count())
            .max()
            .unwrap_or(0);
        let mut indices = vec![0u32; rows * width];
        let mut values = vec![0f32; rows * width];
        for r in 0..rows {
            let mut j = 0;
            for c in 0..cols {
                let v = d.get2(r, c);
                if v != 0.0 {
                    indices[r * width + j] = c as u32;
                    values[r * width + j] = v;
                    j += 1;
                }
            }
            // Pad with the last valid index (value 0).
            let pad_col = if j > 0 { indices[r * width + j - 1] } else { 0 };
            for k in j..width {
                indices[r * width + k] = pad_col;
            }
        }
        EllTensor { shape: [rows, cols], width, indices, values }
    }

    /// Materialize as dense (accumulating, so zero padding is harmless).
    pub fn to_dense(&self) -> DenseTensor {
        let mut out = DenseTensor::zeros(&self.shape);
        for r in 0..self.shape[0] {
            for j in 0..self.width {
                let c = self.indices[r * self.width + j] as usize;
                let v = self.values[r * self.width + j];
                if v != 0.0 {
                    out.set2(r, c, v);
                }
            }
        }
        out
    }

    /// Shape as a slice.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Stored slots (including padding).
    pub fn nnz(&self) -> usize {
        self.values.iter().filter(|&&v| v != 0.0).count()
    }

    /// Storage bytes (slots are stored even when padding).
    pub fn bytes(&self) -> usize {
        self.values.len() * 4 + self.indices.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip_exact() {
        let mut rng = Pcg64::seeded(6);
        let mut d = DenseTensor::randn(&[5, 8], &mut rng);
        for (i, x) in d.data_mut().iter_mut().enumerate() {
            if i % 4 != 1 {
                *x = 0.0;
            }
        }
        let ell = EllTensor::from_dense(&d);
        assert_eq!(ell.to_dense(), d);
    }

    #[test]
    fn width_is_max_row_nnz() {
        let d = DenseTensor::from_vec(
            &[2, 4],
            vec![1.0, 2.0, 3.0, 0.0, 0.0, 4.0, 0.0, 0.0],
        );
        let ell = EllTensor::from_dense(&d);
        assert_eq!(ell.width, 3);
        assert_eq!(ell.nnz(), 4);
        assert_eq!(ell.to_dense(), d);
    }

    #[test]
    fn all_zero_rows() {
        let d = DenseTensor::zeros(&[3, 4]);
        let ell = EllTensor::from_dense(&d);
        assert_eq!(ell.width, 0);
        assert_eq!(ell.to_dense(), d);
    }
}
