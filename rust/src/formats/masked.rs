//! Dense tensor + 0/1 mask: the training-path "emulated sparsity" layout.
//!
//! Offers no storage savings (the paper is explicit about this) but keeps
//! the sparsity pattern as data, which is what sparse fine-tuning needs when
//! the pattern changes over time (§2, §6.1). `FixedMaskTensor` in the paper.

use crate::tensor::DenseTensor;

/// Dense values with an explicit 0/1 mask; values are kept pre-masked
/// (invariant: `values[i] == 0` wherever `mask[i] == 0`).
#[derive(Debug, Clone, PartialEq)]
pub struct MaskedTensor {
    values: DenseTensor,
    mask: DenseTensor,
}

impl MaskedTensor {
    /// Wrap a dense tensor; the mask marks its current nonzeros.
    pub fn from_dense(d: &DenseTensor) -> Self {
        let mask = d.map(|x| if x != 0.0 { 1.0 } else { 0.0 });
        MaskedTensor { values: d.clone(), mask }
    }

    /// Build from values and an explicit mask (applies the mask).
    pub fn new(values: DenseTensor, mask: DenseTensor) -> Self {
        assert_eq!(values.shape(), mask.shape(), "mask shape mismatch");
        debug_assert!(mask.data().iter().all(|&m| m == 0.0 || m == 1.0), "mask must be 0/1");
        let masked = values.zip(&mask, |v, m| v * m);
        MaskedTensor { values: masked, mask }
    }

    /// The (pre-masked) dense values.
    pub fn values(&self) -> &DenseTensor {
        &self.values
    }

    /// The 0/1 mask.
    pub fn mask(&self) -> &DenseTensor {
        &self.mask
    }

    /// Re-apply this tensor's mask to new dense values (the
    /// `SameFormatSparsifier` fast path: pattern unchanged, data replaced).
    pub fn with_values(&self, values: &DenseTensor) -> MaskedTensor {
        MaskedTensor::new(values.clone(), self.mask.clone())
    }

    /// Materialize as dense (already materialized; returns the masked values).
    pub fn to_dense(&self) -> DenseTensor {
        self.values.clone()
    }

    /// Shape as a slice.
    pub fn shape(&self) -> &[usize] {
        self.values.shape()
    }

    /// Number of mask-enabled positions.
    pub fn nnz(&self) -> usize {
        self.mask.data().iter().filter(|&&m| m != 0.0).count()
    }

    /// Storage bytes: values + mask (no savings — by design).
    pub fn bytes(&self) -> usize {
        self.values.numel() * 4 + self.mask.numel() * 4
    }

    /// Sparsity of the mask.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / self.mask.numel().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn mask_applied_on_construction() {
        let v = DenseTensor::from_vec(&[4], vec![1.0, 2.0, 3.0, 4.0]);
        let m = DenseTensor::from_vec(&[4], vec![1.0, 0.0, 1.0, 0.0]);
        let t = MaskedTensor::new(v, m);
        assert_eq!(t.to_dense().data(), &[1.0, 0.0, 3.0, 0.0]);
        assert_eq!(t.nnz(), 2);
        assert_eq!(t.sparsity(), 0.5);
    }

    #[test]
    fn from_dense_marks_nonzeros() {
        let d = DenseTensor::from_vec(&[3], vec![0.0, 5.0, 0.0]);
        let t = MaskedTensor::from_dense(&d);
        assert_eq!(t.mask().data(), &[0.0, 1.0, 0.0]);
        assert_eq!(t.to_dense(), d);
    }

    #[test]
    fn with_values_keeps_pattern() {
        let mut rng = Pcg64::seeded(15);
        let d = DenseTensor::randn(&[4, 4], &mut rng).map(|x| if x > 0.0 { x } else { 0.0 });
        let t = MaskedTensor::from_dense(&d);
        let fresh = DenseTensor::ones(&[4, 4]);
        let t2 = t.with_values(&fresh);
        assert_eq!(t2.mask(), t.mask());
        assert_eq!(t2.nnz(), t.nnz());
        // New values masked by old pattern.
        for (v, m) in t2.to_dense().data().iter().zip(t.mask().data()) {
            assert_eq!(*v, *m);
        }
    }

    #[test]
    fn no_storage_savings() {
        let d = DenseTensor::zeros(&[8, 8]);
        let t = MaskedTensor::from_dense(&d);
        assert_eq!(t.bytes(), 2 * 8 * 8 * 4);
    }
}
