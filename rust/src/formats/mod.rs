//! Sparsity layouts (§3.1 of STen).
//!
//! A *sparsity layout* annotates how a tensor's values are stored: classic
//! formats (CSR, CSC, COO), blocked formats (ELL, BCSR), DL-specialized
//! formats (n:m, the paper's novel n:m:g), or dense-with-mask emulation.
//!
//! [`AnyTensor`] is the dynamic tensor type the dispatcher routes on; the
//! closed set of built-in layouts is extended by [`AnyTensor::Custom`], which
//! carries any user type implementing [`CustomTensor`] — mirroring how STen
//! lets users register e.g. a SciPy CSC tensor from Python with just a
//! `to_dense` method.

pub mod csr;
pub mod csc;
pub mod coo;
pub mod ell;
pub mod bcsr;
pub mod nm;
pub mod nmg;
pub mod masked;
pub mod convert;

pub use bcsr::BcsrTensor;
pub use coo::CooTensor;
pub use csc::CscTensor;
pub use csr::CsrTensor;
pub use ell::EllTensor;
pub use masked::MaskedTensor;
pub use nm::NmTensor;
pub use nmg::NmgTensor;

use crate::tensor::DenseTensor;

/// The sparsity layout tag used for dispatch (§4.4 signature hashing).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Layout {
    /// Plain dense tensor.
    Dense,
    /// Compressed sparse row.
    Csr,
    /// Compressed sparse column.
    Csc,
    /// Coordinate (absolute-offset) format.
    Coo,
    /// ELLPACK: fixed nonzeros per row.
    Ell,
    /// Block CSR.
    Bcsr,
    /// Plain n:m (per-block fraction) format.
    Nm,
    /// The paper's grouped n:m format (§5).
    Nmg,
    /// Dense tensor + 0/1 mask (training emulation).
    Masked,
    /// User-registered custom format.
    Custom,
}

impl std::fmt::Display for Layout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

/// User-extensible tensor format: the minimal contract STen demands (§3.1) —
/// a dense conversion plus self-description.
pub trait CustomTensor: std::fmt::Debug + Send + Sync {
    /// Human-readable format name (used in dispatch errors).
    fn format_name(&self) -> &'static str;
    /// Tensor shape.
    fn shape(&self) -> &[usize];
    /// Number of explicitly stored values.
    fn nnz(&self) -> usize;
    /// Materialize as dense.
    fn to_dense(&self) -> DenseTensor;
    /// Re-sparsify from a dense tensor, preserving this format's structure
    /// parameters (the `SameFormatSparsifier` hook of §4).
    fn same_format_from_dense(&self, dense: &DenseTensor) -> Box<dyn CustomTensor>;
    /// Clone into a box.
    fn boxed_clone(&self) -> Box<dyn CustomTensor>;
}

/// A tensor in any sparsity layout — the operand type of the dispatcher.
#[derive(Debug)]
pub enum AnyTensor {
    /// Dense.
    Dense(DenseTensor),
    /// CSR.
    Csr(CsrTensor),
    /// CSC.
    Csc(CscTensor),
    /// COO.
    Coo(CooTensor),
    /// ELLPACK.
    Ell(EllTensor),
    /// Block CSR.
    Bcsr(BcsrTensor),
    /// n:m.
    Nm(NmTensor),
    /// n:m:g.
    Nmg(NmgTensor),
    /// Dense + mask.
    Masked(MaskedTensor),
    /// User format.
    Custom(Box<dyn CustomTensor>),
}

impl Clone for AnyTensor {
    fn clone(&self) -> Self {
        match self {
            AnyTensor::Dense(t) => AnyTensor::Dense(t.clone()),
            AnyTensor::Csr(t) => AnyTensor::Csr(t.clone()),
            AnyTensor::Csc(t) => AnyTensor::Csc(t.clone()),
            AnyTensor::Coo(t) => AnyTensor::Coo(t.clone()),
            AnyTensor::Ell(t) => AnyTensor::Ell(t.clone()),
            AnyTensor::Bcsr(t) => AnyTensor::Bcsr(t.clone()),
            AnyTensor::Nm(t) => AnyTensor::Nm(t.clone()),
            AnyTensor::Nmg(t) => AnyTensor::Nmg(t.clone()),
            AnyTensor::Masked(t) => AnyTensor::Masked(t.clone()),
            AnyTensor::Custom(t) => AnyTensor::Custom(t.boxed_clone()),
        }
    }
}

impl AnyTensor {
    /// Dispatch tag.
    pub fn layout(&self) -> Layout {
        match self {
            AnyTensor::Dense(_) => Layout::Dense,
            AnyTensor::Csr(_) => Layout::Csr,
            AnyTensor::Csc(_) => Layout::Csc,
            AnyTensor::Coo(_) => Layout::Coo,
            AnyTensor::Ell(_) => Layout::Ell,
            AnyTensor::Bcsr(_) => Layout::Bcsr,
            AnyTensor::Nm(_) => Layout::Nm,
            AnyTensor::Nmg(_) => Layout::Nmg,
            AnyTensor::Masked(_) => Layout::Masked,
            AnyTensor::Custom(_) => Layout::Custom,
        }
    }

    /// Tensor shape.
    pub fn shape(&self) -> &[usize] {
        match self {
            AnyTensor::Dense(t) => t.shape(),
            AnyTensor::Csr(t) => t.shape(),
            AnyTensor::Csc(t) => t.shape(),
            AnyTensor::Coo(t) => t.shape(),
            AnyTensor::Ell(t) => t.shape(),
            AnyTensor::Bcsr(t) => t.shape(),
            AnyTensor::Nm(t) => t.shape(),
            AnyTensor::Nmg(t) => t.shape(),
            AnyTensor::Masked(t) => t.shape(),
            AnyTensor::Custom(t) => t.shape(),
        }
    }

    /// Number of explicitly stored (potentially nonzero) values.
    pub fn nnz(&self) -> usize {
        match self {
            AnyTensor::Dense(t) => t.numel(),
            AnyTensor::Csr(t) => t.nnz(),
            AnyTensor::Csc(t) => t.nnz(),
            AnyTensor::Coo(t) => t.nnz(),
            AnyTensor::Ell(t) => t.nnz(),
            AnyTensor::Bcsr(t) => t.nnz(),
            AnyTensor::Nm(t) => t.nnz(),
            AnyTensor::Nmg(t) => t.nnz(),
            AnyTensor::Masked(t) => t.nnz(),
            AnyTensor::Custom(t) => t.nnz(),
        }
    }

    /// Materialize as dense (the universal fallback of §4.4).
    pub fn to_dense(&self) -> DenseTensor {
        match self {
            AnyTensor::Dense(t) => t.clone(),
            AnyTensor::Csr(t) => t.to_dense(),
            AnyTensor::Csc(t) => t.to_dense(),
            AnyTensor::Coo(t) => t.to_dense(),
            AnyTensor::Ell(t) => t.to_dense(),
            AnyTensor::Bcsr(t) => t.to_dense(),
            AnyTensor::Nm(t) => t.to_dense(),
            AnyTensor::Nmg(t) => t.to_dense(),
            AnyTensor::Masked(t) => t.to_dense(),
            AnyTensor::Custom(t) => t.to_dense(),
        }
    }

    /// Storage bytes of the representation (values + metadata).
    pub fn bytes(&self) -> usize {
        match self {
            AnyTensor::Dense(t) => t.numel() * 4,
            AnyTensor::Csr(t) => t.bytes(),
            AnyTensor::Csc(t) => t.bytes(),
            AnyTensor::Coo(t) => t.bytes(),
            AnyTensor::Ell(t) => t.bytes(),
            AnyTensor::Bcsr(t) => t.bytes(),
            AnyTensor::Nm(t) => t.bytes(),
            AnyTensor::Nmg(t) => t.bytes(),
            AnyTensor::Masked(t) => t.bytes(),
            AnyTensor::Custom(t) => t.nnz() * 4,
        }
    }

    /// Borrow the dense payload, if this is a dense tensor.
    pub fn as_dense(&self) -> Option<&DenseTensor> {
        match self {
            AnyTensor::Dense(t) => Some(t),
            _ => None,
        }
    }
}

impl From<DenseTensor> for AnyTensor {
    fn from(t: DenseTensor) -> Self {
        AnyTensor::Dense(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn dense_anytensor_basics() {
        let t = AnyTensor::Dense(DenseTensor::zeros(&[3, 4]));
        assert_eq!(t.layout(), Layout::Dense);
        assert_eq!(t.shape(), &[3, 4]);
        assert_eq!(t.nnz(), 12);
        assert_eq!(t.bytes(), 48);
        assert!(t.as_dense().is_some());
    }

    #[test]
    fn all_layouts_roundtrip_to_dense() {
        let mut rng = Pcg64::seeded(42);
        let mut d = DenseTensor::randn(&[8, 12], &mut rng);
        // Zero half the entries so sparse formats have real structure.
        for (i, x) in d.data_mut().iter_mut().enumerate() {
            if i % 2 == 0 {
                *x = 0.0;
            }
        }
        let candidates: Vec<AnyTensor> = vec![
            AnyTensor::Csr(CsrTensor::from_dense(&d)),
            AnyTensor::Csc(CscTensor::from_dense(&d)),
            AnyTensor::Coo(CooTensor::from_dense(&d)),
            AnyTensor::Ell(EllTensor::from_dense(&d)),
            AnyTensor::Bcsr(BcsrTensor::from_dense(&d, 4, 4)),
            AnyTensor::Masked(MaskedTensor::from_dense(&d)),
        ];
        for t in candidates {
            let back = t.to_dense();
            assert!(
                back.allclose(&d, 0.0, 0.0),
                "{:?} lossy roundtrip, max diff {}",
                t.layout(),
                back.max_abs_diff(&d)
            );
        }
    }

    #[test]
    fn layout_display() {
        assert_eq!(Layout::Nmg.to_string(), "Nmg");
    }
}
