//! Plain n:m sparsity (NVIDIA-style): each block of `m` consecutive elements
//! along the sparse (row) dimension keeps `n` values.
//!
//! This is the "less structure than n:m:g" comparator of Fig. 7. Storage is
//! per-column blocks of `n` values plus an `m`-bit (here: byte) row selector.

use crate::tensor::DenseTensor;

/// n:m tensor over a (M, K) matrix, sparse along the row dimension: for each
/// column and each block of `m` consecutive rows, the `n` largest-magnitude
/// values are kept.
#[derive(Debug, Clone, PartialEq)]
pub struct NmTensor {
    shape: [usize; 2],
    /// Values kept per (row-block, column): `(M/m) * K * n`, block-major.
    pub values: Vec<f32>,
    /// Kept row offsets within each block (same indexing as `values`).
    pub offsets: Vec<u8>,
    /// n (kept per block).
    pub n: usize,
    /// m (block size).
    pub m: usize,
}

impl NmTensor {
    /// Magnitude-prune a dense matrix into n:m. Requires `M % m == 0`.
    pub fn from_dense(d: &DenseTensor, n: usize, m: usize) -> Self {
        assert_eq!(d.rank(), 2, "n:m requires 2-D");
        assert!(n <= m && n > 0, "need 0 < n <= m");
        let (rows, cols) = (d.rows(), d.cols());
        assert_eq!(rows % m, 0, "rows {rows} not divisible by m={m}");
        let blocks = rows / m;
        let mut values = Vec::with_capacity(blocks * cols * n);
        let mut offsets = Vec::with_capacity(blocks * cols * n);
        let mut mags: Vec<(f32, usize)> = Vec::with_capacity(m);
        for b in 0..blocks {
            for c in 0..cols {
                mags.clear();
                for i in 0..m {
                    mags.push((d.get2(b * m + i, c).abs(), i));
                }
                // Keep the n largest magnitudes; stable on ties by row order.
                mags.sort_by(|a, bb| bb.0.total_cmp(&a.0).then(a.1.cmp(&bb.1)));
                let mut kept: Vec<usize> = mags[..n].iter().map(|&(_, i)| i).collect();
                kept.sort_unstable();
                for &i in &kept {
                    values.push(d.get2(b * m + i, c));
                    offsets.push(i as u8);
                }
            }
        }
        NmTensor { shape: [rows, cols], values, offsets, n, m }
    }

    /// Materialize as dense.
    pub fn to_dense(&self) -> DenseTensor {
        let mut out = DenseTensor::zeros(&self.shape);
        let cols = self.shape[1];
        let blocks = self.shape[0] / self.m;
        for b in 0..blocks {
            for c in 0..cols {
                let base = (b * cols + c) * self.n;
                for j in 0..self.n {
                    let r = b * self.m + self.offsets[base + j] as usize;
                    out.set2(r, c, self.values[base + j]);
                }
            }
        }
        out
    }

    /// Shape as a slice.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Stored values.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Storage bytes: values + 1-byte offsets.
    pub fn bytes(&self) -> usize {
        self.values.len() * 4 + self.offsets.len()
    }

    /// Nominal sparsity 1 - n/m.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.n as f64 / self.m as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Pcg64;

    #[test]
    fn keeps_largest_per_block() {
        let d = DenseTensor::from_vec(&[4, 1], vec![0.1, -5.0, 3.0, 0.2]);
        let t = NmTensor::from_dense(&d, 2, 4);
        let back = t.to_dense();
        assert_eq!(back.data(), &[0.0, -5.0, 3.0, 0.0]);
    }

    #[test]
    fn block_structure_invariant() {
        proptest::check(
            "nm-structure",
            40,
            |rng| {
                let blocks = 1 + rng.below(4) as usize;
                let cols = 1 + rng.below(10) as usize;
                let seed = rng.next_u64();
                let mut r2 = Pcg64::seeded(seed);
                DenseTensor::randn(&[blocks * 4, cols], &mut r2)
            },
            |d| {
                let t = NmTensor::from_dense(d, 2, 4);
                let back = t.to_dense();
                // Exactly 2 nonzeros per (4-row block, column), values match original.
                for b in 0..d.rows() / 4 {
                    for c in 0..d.cols() {
                        let nnz = (0..4).filter(|&i| back.get2(b * 4 + i, c) != 0.0).count();
                        if nnz > 2 {
                            return false;
                        }
                        for i in 0..4 {
                            let v = back.get2(b * 4 + i, c);
                            if v != 0.0 && v != d.get2(b * 4 + i, c) {
                                return false;
                            }
                        }
                    }
                }
                true
            },
        );
    }

    #[test]
    fn energy_at_least_n_over_m() {
        let mut rng = Pcg64::seeded(8);
        let d = DenseTensor::randn(&[16, 20], &mut rng);
        let t = NmTensor::from_dense(&d, 2, 4);
        let kept = t.to_dense().l1_norm();
        assert!(kept >= d.l1_norm() * 0.5, "magnitude pruning keeps >= n/m of L1 mass");
    }

    #[test]
    fn sparsity_reported() {
        let d = DenseTensor::ones(&[8, 2]);
        assert_eq!(NmTensor::from_dense(&d, 1, 4).sparsity(), 0.75);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn bad_rows_rejected() {
        NmTensor::from_dense(&DenseTensor::zeros(&[6, 2]), 2, 4);
    }
}
