//! Grouped n:m (n:m:g) sparsity — the paper's novel layout (§5).
//!
//! See `python/compile/kernels/nmg.py` for the format definition; the Rust
//! and Python implementations share semantics (same pattern order, same
//! greedy conversion) so artifacts and native kernels interoperate.
//!
//! Layout recap: a (M, K) matrix is split into slabs of `m` rows; when
//! `M % m != 0` the final slab is zero-padded (the logical `shape` keeps the
//! true row count, and pad rows never re-materialize because their stored
//! values are all zero). Within a slab, columns are processed in chunks of
//! `C(m,n) * g` columns; each column keeps `n` of its `m` values, and the
//! chunk stores its columns permuted so the `C(m,n)` nonzero patterns appear
//! in a fixed Gray-code-like order, `g` columns per pattern ("group"). The
//! original column of each slot is stored in `idx`. Partial trailing chunks
//! pad with `val = 0` slots.

use crate::tensor::DenseTensor;
use crate::util::threadpool;

/// Binomial coefficient C(m, n).
pub fn binomial(m: usize, n: usize) -> usize {
    if n > m {
        return 0;
    }
    let n = n.min(m - n);
    let mut num = 1usize;
    let mut den = 1usize;
    for i in 0..n {
        num *= m - i;
        den *= i + 1;
    }
    num / den
}

/// All C(m, n) patterns (sorted row-index tuples) in greedy revolving-door
/// order: adjacent patterns differ in as few positions as possible, the
/// property the paper's kernel exploits to save/init a single register at
/// group boundaries.
pub fn patterns(m: usize, n: usize) -> Vec<Vec<u8>> {
    assert!(n > 0 && n <= m && m <= 16, "unsupported n:m = {n}:{m}");
    // Lexicographic combinations.
    let mut combos: Vec<Vec<u8>> = Vec::new();
    let mut cur: Vec<u8> = (0..n as u8).collect();
    loop {
        combos.push(cur.clone());
        // Next combination.
        let mut i = n;
        loop {
            if i == 0 {
                return order_greedy(combos);
            }
            i -= 1;
            if cur[i] < (m - n + i) as u8 {
                cur[i] += 1;
                for j in i + 1..n {
                    cur[j] = cur[j - 1] + 1;
                }
                break;
            }
        }
    }
}

fn order_greedy(mut combos: Vec<Vec<u8>>) -> Vec<Vec<u8>> {
    let mut order = vec![combos.remove(0)];
    while !combos.is_empty() {
        let cur = order.last().unwrap();
        let cur_set: u32 = cur.iter().fold(0, |acc, &r| acc | 1 << r);
        // Min by (symmetric difference size, lexicographic tuple) — matches
        // the Python tie-breaking exactly.
        let best = combos
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                let da = (cur_set ^ a.iter().fold(0u32, |acc, &r| acc | 1 << r)).count_ones();
                let db = (cur_set ^ b.iter().fold(0u32, |acc, &r| acc | 1 << r)).count_ones();
                da.cmp(&db).then_with(|| a.cmp(b))
            })
            .map(|(i, _)| i)
            .unwrap();
        order.push(combos.remove(best));
    }
    order
}

/// The n:m:g sparse tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct NmgTensor {
    shape: [usize; 2],
    /// n values kept per column.
    pub n: usize,
    /// Block (pattern) size.
    pub m: usize,
    /// Group size: columns per pattern per chunk.
    pub g: usize,
    /// Number of patterns C(m, n).
    pub c: usize,
    /// Chunks per slab.
    pub chunks: usize,
    /// Slabs (ceil(M / m); the final slab is zero-padded when `M % m != 0`).
    pub slabs: usize,
    /// Kept values, shape (slabs, chunks, C, g, n) flattened.
    pub val: Vec<f32>,
    /// Original column per slot, shape (slabs, chunks, C, g) flattened.
    pub idx: Vec<u32>,
    /// Pattern table (C x n row offsets), chunk order.
    pub pats: Vec<Vec<u8>>,
}

impl NmgTensor {
    /// Columns per chunk.
    pub fn chunk_cols(&self) -> usize {
        self.c * self.g
    }

    /// Greedy magnitude conversion (§5.2, CPU algorithm), parallel over slabs.
    ///
    /// Ragged row counts (`rows % m != 0`) are supported: the final slab is
    /// zero-padded, so no trailing rows are dropped.
    pub fn from_dense(d: &DenseTensor, n: usize, m: usize, g: usize) -> Self {
        assert_eq!(d.rank(), 2, "n:m:g requires 2-D");
        let (rows, k) = (d.rows(), d.cols());
        let pats = patterns(m, n);
        let c = pats.len();
        let cc = c * g;
        let slabs = rows.div_ceil(m);
        let chunks = k.div_ceil(cc);
        let slot_count = slabs * chunks * c * g;
        let mut val = vec![0f32; slot_count * n];
        let mut idx = vec![0u32; slot_count];

        // Parallel over slabs: each slab writes a disjoint range.
        let val_ptr = threadpool::SyncPtr::new(val.as_mut_ptr());
        let idx_ptr = threadpool::SyncPtr::new(idx.as_mut_ptr());
        threadpool::parallel_for(slabs, 1, |s0, s1| {
            for s in s0..s1 {
                let vbase = s * chunks * c * g * n;
                let ibase = s * chunks * c * g;
                // SAFETY: slabs write disjoint [vbase, vbase + chunks*c*g*n).
                let val_s = unsafe {
                    std::slice::from_raw_parts_mut(val_ptr.get().add(vbase), chunks * c * g * n)
                };
                let idx_s = unsafe {
                    std::slice::from_raw_parts_mut(idx_ptr.get().add(ibase), chunks * c * g)
                };
                convert_slab(d, rows, s, n, m, g, &pats, val_s, idx_s);
            }
        });

        NmgTensor { shape: [rows, k], n, m, g, c, chunks, slabs, val, idx, pats }
    }

    /// Swap-refinement conversion (§5.2, "GPU" algorithm analog): arbitrary
    /// initial assignment, then pairwise pattern swaps while they improve the
    /// preserved magnitude. Deterministic and typically faster than greedy
    /// for large chunks; slightly lower energy.
    pub fn from_dense_swap(d: &DenseTensor, n: usize, m: usize, g: usize) -> Self {
        let mut t = Self::template(d, n, m, g);
        let pats = t.pats.clone();
        let (c, chunks, g_, nn) = (t.c, t.chunks, t.g, t.n);
        let cc = c * g_;
        let k = d.cols();
        let rows = d.rows();
        // Zero-padded read past the true row count (ragged final slab).
        let at = |r: usize, col: usize| if r < rows { d.get2(r, col) } else { 0.0 };
        for s in 0..t.slabs {
            for ch in 0..chunks {
                let lo = ch * cc;
                let hi = (lo + cc).min(k);
                let ncols = hi - lo;
                // assignment[slot] = column (or None for pad).
                let mut assign: Vec<Option<usize>> =
                    (0..cc).map(|i| if i < ncols { Some(lo + i) } else { None }).collect();
                let score = |slot: usize, col: usize| -> f32 {
                    let p = slot / g_;
                    pats[p].iter().map(|&r| at(s * m + r as usize, col).abs()).sum()
                };
                // Sweep until no improving swap. Bounded by cc^2 per sweep and
                // monotone improvement, so termination is guaranteed.
                let mut improved = true;
                let mut sweeps = 0;
                while improved && sweeps < 64 {
                    improved = false;
                    sweeps += 1;
                    for a in 0..cc {
                        for b in a + 1..cc {
                            let (ca, cb) = (assign[a], assign[b]);
                            let cur = ca.map_or(0.0, |x| score(a, x)) + cb.map_or(0.0, |x| score(b, x));
                            let alt = ca.map_or(0.0, |x| score(b, x)) + cb.map_or(0.0, |x| score(a, x));
                            if alt > cur + 1e-7 {
                                assign.swap(a, b);
                                improved = true;
                            }
                        }
                    }
                }
                for (slot, colopt) in assign.iter().enumerate() {
                    if let Some(col) = *colopt {
                        let p = slot / g_;
                        let slot_idx = ((s * chunks + ch) * c * g_) + slot;
                        t.idx[slot_idx] = col as u32;
                        for (j, &r) in pats[p].iter().enumerate() {
                            t.val[slot_idx * nn + j] = at(s * m + r as usize, col);
                        }
                    }
                }
            }
        }
        t
    }

    /// Rebuild from the flat artifact layout: `val` shaped (S, CH, C, g, n)
    /// and `idx` shaped (S, CH, C, g), as produced by [`Self::val_flat`] /
    /// [`Self::idx_flat`] and consumed by the n:m:g GEMM artifacts.
    pub fn from_flat(
        shape: [usize; 2],
        n: usize,
        m: usize,
        g: usize,
        val: Vec<f32>,
        idx: Vec<u32>,
    ) -> Self {
        let pats = patterns(m, n);
        let c = pats.len();
        let slabs = shape[0].div_ceil(m);
        let chunks = shape[1].div_ceil(c * g);
        assert_eq!(idx.len(), slabs * chunks * c * g, "idx length mismatch");
        assert_eq!(val.len(), idx.len() * n, "val length mismatch");
        NmgTensor { shape, n, m, g, c, chunks, slabs, val, idx, pats }
    }

    /// The row-slice covering slabs `[s0, s1)` — the format's natural
    /// sharding boundary (tensor-parallel row splits must land on slab
    /// edges so the per-slab val/idx layout survives intact). Rows become
    /// `[s0 * m, min(s1 * m, rows))`; the final slab's zero padding (ragged
    /// `rows % m != 0`) carries over unchanged. Values and indices are
    /// copied verbatim, so a kernel over the slice produces exactly the
    /// corresponding output rows of the full tensor.
    pub fn slice_slabs(&self, s0: usize, s1: usize) -> NmgTensor {
        assert!(s0 <= s1 && s1 <= self.slabs, "slab range {s0}..{s1} out of 0..{}", self.slabs);
        let rows = self.shape[0];
        let k = self.shape[1];
        let (row_lo, row_hi) = ((s0 * self.m).min(rows), (s1 * self.m).min(rows));
        let slot = self.chunks * self.c * self.g;
        NmgTensor::from_flat(
            [row_hi - row_lo, k],
            self.n,
            self.m,
            self.g,
            self.val[s0 * slot * self.n..s1 * slot * self.n].to_vec(),
            self.idx[s0 * slot..s1 * slot].to_vec(),
        )
    }

    fn template(d: &DenseTensor, n: usize, m: usize, g: usize) -> Self {
        let (rows, k) = (d.rows(), d.cols());
        let pats = patterns(m, n);
        let c = pats.len();
        let slabs = rows.div_ceil(m);
        let chunks = k.div_ceil(c * g);
        let slot_count = slabs * chunks * c * g;
        NmgTensor {
            shape: [rows, k],
            n,
            m,
            g,
            c,
            chunks,
            slabs,
            val: vec![0f32; slot_count * n],
            idx: vec![0u32; slot_count],
            pats,
        }
    }

    /// Materialize as dense. Accumulating writes make pad slots harmless.
    pub fn to_dense(&self) -> DenseTensor {
        let mut out = DenseTensor::zeros(&self.shape);
        let slots_per_slab = self.chunks * self.c * self.g;
        for s in 0..self.slabs {
            for slot in 0..slots_per_slab {
                let gi = s * slots_per_slab + slot;
                let col = self.idx[gi] as usize;
                let p = (slot / self.g) % self.c;
                for (j, &r) in self.pats[p].iter().enumerate() {
                    let v = self.val[gi * self.n + j];
                    let row = s * self.m + r as usize;
                    // Pad slots (and pad rows of a ragged final slab) store
                    // val = 0, so skipping zeros also skips out-of-range rows.
                    if v != 0.0 && row < self.shape[0] {
                        let cur = out.get2(row, col);
                        out.set2(row, col, cur + v);
                    }
                }
            }
        }
        out
    }

    /// Shape as a slice.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Stored nonzero values (excludes pad-slot zeros).
    pub fn nnz(&self) -> usize {
        self.val.iter().filter(|&&v| v != 0.0).count()
    }

    /// Storage bytes: values + u32 per-slot index.
    pub fn bytes(&self) -> usize {
        self.val.len() * 4 + self.idx.len() * 4
    }

    /// Nominal sparsity 1 - n/m.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.n as f64 / self.m as f64
    }

    /// Flat value array (S, CH, C, g, n) — artifact input layout.
    pub fn val_flat(&self) -> &[f32] {
        &self.val
    }

    /// Flat index array (S, CH, C, g) — artifact input layout.
    pub fn idx_flat(&self) -> &[u32] {
        &self.idx
    }
}

/// Greedy assignment for one slab (writes this slab's val/idx slices).
/// `rows` is the true (possibly ragged) row count; reads past it see zeros.
fn convert_slab(
    d: &DenseTensor,
    rows: usize,
    s: usize,
    n: usize,
    m: usize,
    g: usize,
    pats: &[Vec<u8>],
    val: &mut [f32],
    idx: &mut [u32],
) {
    let c = pats.len();
    let cc = c * g;
    let k = d.cols();
    let chunks = k.div_ceil(cc);
    let at = |r: usize, col: usize| if r < rows { d.get2(r, col) } else { 0.0 };
    let mut scores: Vec<f32> = Vec::new();
    let mut order: Vec<u32> = Vec::new();
    for ch in 0..chunks {
        let lo = ch * cc;
        let hi = (lo + cc).min(k);
        let ncols = hi - lo;
        // scores[j * c + p] = L1 mass kept if column lo+j uses pattern p.
        scores.clear();
        scores.reserve(ncols * c);
        for j in 0..ncols {
            let col = lo + j;
            for pat in pats {
                let mut acc = 0f32;
                for &r in pat {
                    acc += at(s * m + r as usize, col).abs();
                }
                scores.push(acc);
            }
        }
        // Stable sort by descending score (ties: ascending flat index).
        order.clear();
        order.extend(0..(ncols * c) as u32);
        order.sort_by(|&a, &b| {
            scores[b as usize]
                .total_cmp(&scores[a as usize])
                .then(a.cmp(&b))
        });
        let mut col_assigned = vec![false; ncols];
        let mut pat_fill = vec![0usize; c];
        let mut assigned = 0usize;
        for &flat in &order {
            let j = flat as usize / c;
            let p = flat as usize % c;
            if col_assigned[j] || pat_fill[p] >= g {
                continue;
            }
            col_assigned[j] = true;
            let slot = pat_fill[p];
            pat_fill[p] += 1;
            let col = lo + j;
            let slot_idx = ch * cc + p * g + slot;
            idx[slot_idx] = col as u32;
            for (jj, &r) in pats[p].iter().enumerate() {
                val[slot_idx * n + jj] = at(s * m + r as usize, col);
            }
            assigned += 1;
            if assigned == ncols {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Pcg64;

    #[test]
    fn binomial_values() {
        assert_eq!(binomial(4, 2), 6);
        assert_eq!(binomial(4, 1), 4);
        assert_eq!(binomial(10, 1), 10);
        assert_eq!(binomial(8, 2), 28);
        assert_eq!(binomial(3, 5), 0);
    }

    #[test]
    fn patterns_cover_and_adjacent_differ_by_one_swap() {
        for (m, n) in [(4, 2), (4, 1), (8, 2), (10, 1), (6, 3)] {
            let pats = patterns(m, n);
            assert_eq!(pats.len(), binomial(m, n));
            let mut dedup = pats.clone();
            dedup.sort();
            dedup.dedup();
            assert_eq!(dedup.len(), pats.len());
            for w in pats.windows(2) {
                let a: u32 = w[0].iter().fold(0, |acc, &r| acc | 1 << r);
                let b: u32 = w[1].iter().fold(0, |acc, &r| acc | 1 << r);
                assert_eq!((a ^ b).count_ones(), 2, "{:?} -> {:?}", w[0], w[1]);
            }
        }
    }

    #[test]
    fn roundtrip_values_match_original() {
        let mut rng = Pcg64::seeded(9);
        let d = DenseTensor::randn(&[8, 30], &mut rng); // partial trailing chunk
        let t = NmgTensor::from_dense(&d, 2, 4, 2);
        let back = t.to_dense();
        assert_eq!(back.shape(), d.shape());
        for r in 0..8 {
            for c in 0..30 {
                let v = back.get2(r, c);
                assert!(v == 0.0 || v == d.get2(r, c), "invented value at ({r},{c})");
            }
        }
    }

    #[test]
    fn per_column_block_has_at_most_n_nonzeros() {
        proptest::check(
            "nmg-n-per-block",
            25,
            |rng| {
                let slabs = 1 + rng.below(3) as usize;
                let k = 1 + rng.below(40) as usize;
                let seed = rng.next_u64();
                let mut r2 = Pcg64::seeded(seed);
                DenseTensor::randn(&[slabs * 4, k], &mut r2)
            },
            |d| {
                let t = NmgTensor::from_dense(d, 2, 4, 4);
                let back = t.to_dense();
                (0..d.rows() / 4).all(|s| {
                    (0..d.cols()).all(|c| {
                        (0..4).filter(|&i| back.get2(s * 4 + i, c) != 0.0).count() <= 2
                    })
                })
            },
        );
    }

    #[test]
    fn every_column_is_assigned_exactly_once() {
        let mut rng = Pcg64::seeded(10);
        let d = DenseTensor::randn(&[4, 48], &mut rng);
        let t = NmgTensor::from_dense(&d, 2, 4, 4);
        let mut seen = vec![0usize; 48];
        for slot in 0..t.idx.len() {
            let real = (0..t.n).any(|j| t.val[slot * t.n + j] != 0.0);
            if real {
                seen[t.idx[slot] as usize] += 1;
            }
        }
        assert!(seen.iter().all(|&s| s <= 1));
        // With random data, nearly all columns should be kept (non-empty).
        assert!(seen.iter().filter(|&&s| s == 1).count() >= 40);
    }

    #[test]
    fn idx_stays_within_chunk_range() {
        let mut rng = Pcg64::seeded(11);
        let d = DenseTensor::randn(&[8, 50], &mut rng);
        let t = NmgTensor::from_dense(&d, 1, 4, 3); // cc = 12, partial chunk at end
        let cc = t.chunk_cols();
        for s in 0..t.slabs {
            for ch in 0..t.chunks {
                for slot in 0..cc {
                    let gi = (s * t.chunks + ch) * cc + slot;
                    let real = (0..t.n).any(|j| t.val[gi * t.n + j] != 0.0);
                    if real {
                        let col = t.idx[gi] as usize;
                        assert!(col >= ch * cc && col < ((ch + 1) * cc).min(50));
                    }
                }
            }
        }
    }

    #[test]
    fn greedy_energy_beats_or_matches_swap_within_tolerance() {
        let mut rng = Pcg64::seeded(12);
        let d = DenseTensor::randn(&[16, 48], &mut rng);
        let g_greedy = NmgTensor::from_dense(&d, 2, 4, 4).to_dense().l1_norm();
        let g_swap = NmgTensor::from_dense_swap(&d, 2, 4, 4).to_dense().l1_norm();
        let total = d.l1_norm();
        assert!(g_greedy / total > 0.5);
        assert!(g_swap / total > 0.5);
        // Both heuristics should be within 10% of each other.
        assert!((g_greedy - g_swap).abs() / total < 0.1, "greedy {g_greedy} swap {g_swap}");
    }

    #[test]
    fn larger_group_preserves_no_less_energy() {
        let mut rng = Pcg64::seeded(13);
        let d = DenseTensor::randn(&[8, 96], &mut rng);
        let e1 = NmgTensor::from_dense(&d, 2, 4, 1).to_dense().l1_norm();
        let e16 = NmgTensor::from_dense(&d, 2, 4, 16).to_dense().l1_norm();
        assert!(e16 >= e1 * 0.98, "g=16 {e16} vs g=1 {e1}");
    }

    #[test]
    fn ragged_rows_are_not_dropped() {
        // Regression: rows % m != 0 used to assert (and an earlier draft
        // silently truncated). The final slab must be zero-padded so every
        // real row survives the round trip.
        let mut rng = Pcg64::seeded(21);
        for rows in [1usize, 3, 5, 7, 9, 11] {
            let d = DenseTensor::randn(&[rows, 30], &mut rng);
            let t = NmgTensor::from_dense(&d, 2, 4, 2);
            assert_eq!(t.shape(), &[rows, 30]);
            assert_eq!(t.slabs, rows.div_ceil(4));
            let back = t.to_dense();
            assert_eq!(back.shape(), d.shape());
            let kept: usize = (0..rows)
                .map(|r| (0..30).filter(|&c| back.get2(r, c) != 0.0).count())
                .sum();
            assert!(kept > 0, "rows={rows}: every row was dropped");
            // Every kept value is genuine (never invented, incl. pad rows).
            for r in 0..rows {
                for c in 0..30 {
                    let v = back.get2(r, c);
                    assert!(v == 0.0 || v == d.get2(r, c), "invented value at ({r},{c})");
                }
            }
            // The true last row keeps values: with n=2, m=4 and a ragged slab
            // the real rows carry all the magnitude, so the final real row
            // must retain at least one nonzero.
            let last = (0..30).filter(|&c| back.get2(rows - 1, c) != 0.0).count();
            assert!(last > 0, "rows={rows}: trailing ragged row dropped");
        }
    }

    #[test]
    fn ragged_rows_swap_conversion_matches_shapes() {
        let mut rng = Pcg64::seeded(22);
        let d = DenseTensor::randn(&[6, 26], &mut rng);
        let t = NmgTensor::from_dense_swap(&d, 2, 4, 2);
        assert_eq!(t.shape(), &[6, 26]);
        let back = t.to_dense();
        for r in 0..6 {
            for c in 0..26 {
                let v = back.get2(r, c);
                assert!(v == 0.0 || v == d.get2(r, c));
            }
        }
        assert!((0..26).any(|c| back.get2(5, c) != 0.0));
    }

    #[test]
    fn ragged_from_flat_roundtrips() {
        let mut rng = Pcg64::seeded(23);
        let d = DenseTensor::randn(&[7, 30], &mut rng);
        let t = NmgTensor::from_dense(&d, 2, 4, 2);
        let t2 = NmgTensor::from_flat(
            [7, 30],
            2,
            4,
            2,
            t.val_flat().to_vec(),
            t.idx_flat().to_vec(),
        );
        assert_eq!(t.to_dense().data(), t2.to_dense().data());
    }

    #[test]
    fn storage_is_half_plus_metadata_at_2_4() {
        let mut rng = Pcg64::seeded(14);
        let d = DenseTensor::randn(&[64, 96], &mut rng);
        let t = NmgTensor::from_dense(&d, 2, 4, 4);
        // values: numel/2 * 4 bytes; idx: numel/(m) * ... — well under dense.
        assert!(t.bytes() < d.numel() * 4);
    }

    #[test]
    fn slab_slices_cover_the_dense_rows() {
        let mut rng = Pcg64::seeded(33);
        // Ragged row count: 18 rows at m=4 -> 5 slabs, last one padded.
        let d = DenseTensor::randn(&[18, 24], &mut rng);
        let t = NmgTensor::from_dense(&d, 2, 4, 2);
        let full = t.to_dense();
        for (s0, s1) in [(0, 5), (0, 0), (0, 2), (1, 4), (3, 5), (5, 5)] {
            let s = t.slice_slabs(s0, s1);
            let sd = s.to_dense();
            let row_lo = (s0 * 4).min(18);
            let row_hi = (s1 * 4).min(18);
            assert_eq!(sd.rows(), row_hi - row_lo, "({s0},{s1})");
            for r in 0..sd.rows() {
                for c in 0..sd.cols() {
                    assert_eq!(sd.get2(r, c), full.get2(row_lo + r, c), "({s0},{s1}) at ({r},{c})");
                }
            }
        }
    }
}
