//! Process-wide compute-backend selection: [`Backend::Scalar`] vs
//! [`Backend::Simd`].
//!
//! The scalar kernels are the bit-identical reference implementation (the
//! sharded seam and the scheduler-equivalence tests are stated against
//! them); the SIMD kernels under [`super::simd`] are the vectorized twins
//! checked against scalar golden vectors by the parity harness
//! (`tests/backend_parity.rs`).
//!
//! Selection order (first match wins):
//!
//! 1. CLI `--backend scalar|simd|auto` via [`select`];
//! 2. env `STEN_BACKEND=scalar|simd|auto`;
//! 3. auto: SIMD iff the CPU supports AVX2+FMA.
//!
//! `STEN_FORCE_SCALAR=1` masks feature detection entirely (the
//! fallback-coverage knob: it makes an AVX2 host behave like one without),
//! and an explicit `simd` request still degrades to scalar on an unable
//! CPU — the scalar fallback is guaranteed, never a crash.
//!
//! The active backend is a process global (one atomic), **not** a
//! thread-local: kernels run inside `util::threadpool` worker threads, and
//! a thread-local choice would silently fail to propagate into them.

use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, MutexGuard};

use super::simd;

/// A compute-kernel implementation family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Backend {
    /// Portable scalar Rust — the bit-identical reference.
    Scalar,
    /// AVX2+FMA vector kernels (runtime-detected, scalar fallback).
    Simd,
}

impl Backend {
    /// Stable lowercase name (cache keys, bench JSON, CLI echo).
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Simd => "simd",
        }
    }

    /// f32 lanes per vector register the backend's kernels are written for
    /// (1 scalar, 8 for AVX2). Feeds the autotuner's cost model: formats
    /// whose inner loops cannot use the vector width keep their scalar
    /// cost while vectorizable ones get cheaper relative to them.
    pub fn vector_width(self) -> usize {
        match self {
            Backend::Scalar => 1,
            Backend::Simd => 8,
        }
    }
}

impl fmt::Display for Backend {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

const UNSET: u8 = 0;
const SCALAR: u8 = 1;
const SIMD: u8 = 2;

/// The resolved backend; `UNSET` until first use or an explicit [`select`].
static ACTIVE: AtomicU8 = AtomicU8::new(UNSET);

fn encode(b: Backend) -> u8 {
    match b {
        Backend::Scalar => SCALAR,
        Backend::Simd => SIMD,
    }
}

fn decode(v: u8) -> Backend {
    match v {
        SIMD => Backend::Simd,
        _ => Backend::Scalar,
    }
}

/// The backend kernels dispatch on right now. The first call resolves from
/// the environment; later calls are a single atomic load.
pub fn active() -> Backend {
    match ACTIVE.load(Ordering::Acquire) {
        UNSET => {
            let b = resolve_env();
            // Two threads may race the first resolution; both derive the
            // same environment answer, and losing to a concurrent force()
            // or select() is correct too — their store wins.
            let _ =
                ACTIVE.compare_exchange(UNSET, encode(b), Ordering::AcqRel, Ordering::Acquire);
            decode(ACTIVE.load(Ordering::Acquire))
        }
        v => decode(v),
    }
}

/// Pure resolution rule (exposed for tests): what backend does a `request`
/// ("scalar" / "simd" / "auto" / unset) resolve to given the fallback mask
/// and the detected CPU capability?
pub fn resolve_request(request: Option<&str>, force_scalar: bool, simd_supported: bool) -> Backend {
    if request == Some("scalar") {
        return Backend::Scalar;
    }
    // "simd", "auto", unset, and unknown strings all mean "fastest
    // supported": SIMD iff the CPU can run it and detection isn't masked.
    if simd_supported && !force_scalar {
        Backend::Simd
    } else {
        Backend::Scalar
    }
}

fn env_force_scalar() -> bool {
    match std::env::var("STEN_FORCE_SCALAR") {
        Ok(v) => !v.is_empty() && v != "0",
        Err(_) => false,
    }
}

/// Resolve from the environment alone (`STEN_BACKEND`,
/// `STEN_FORCE_SCALAR`, CPU detection) without storing the result.
pub fn resolve_env() -> Backend {
    let req = std::env::var("STEN_BACKEND").ok();
    resolve_request(req.as_deref(), env_force_scalar(), simd::have_avx2_fma())
}

/// Select the backend from a CLI request ("scalar" / "simd" / "auto"),
/// overriding any earlier resolution, and return the resolved choice.
pub fn select(request: &str) -> Backend {
    let b = resolve_request(Some(request), env_force_scalar(), simd::have_avx2_fma());
    ACTIVE.store(encode(b), Ordering::Release);
    b
}

/// Scoped backend override for tests and benches. Serialized through a
/// process-wide lock so two concurrent forcings cannot interleave; the
/// previous state (including "not yet resolved") is restored on drop.
///
/// The lock is not reentrant: never request a second guard (directly or
/// through a callee that forces, like golden-vector generation) while one
/// is alive on the same thread.
pub struct ForceGuard {
    prev: u8,
    _lock: MutexGuard<'static, ()>,
}

static FORCE: Mutex<()> = Mutex::new(());

/// Force `b` for the lifetime of the returned guard.
pub fn force(b: Backend) -> ForceGuard {
    let lock = FORCE.lock().unwrap_or_else(|e| e.into_inner());
    let prev = ACTIVE.swap(encode(b), Ordering::AcqRel);
    ForceGuard { prev, _lock: lock }
}

impl Drop for ForceGuard {
    fn drop(&mut self) {
        ACTIVE.store(self.prev, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: no test here forces or selects a backend — the lib test binary
    // runs its kernel bit-identity tests under the ambient backend, and a
    // concurrent global override would race them. Force-based coverage
    // lives in the integration binaries (tests/backend_parity.rs,
    // tests/kernel_properties.rs) behind the ForceGuard lock.

    #[test]
    fn resolution_truth_table() {
        use Backend::*;
        // (request, force_scalar, simd_supported) -> resolved
        let cases = [
            (None, false, true, Simd),
            (None, false, false, Scalar),
            (None, true, true, Scalar),
            (Some("auto"), false, true, Simd),
            (Some("auto"), true, true, Scalar),
            (Some("scalar"), false, true, Scalar),
            (Some("scalar"), true, false, Scalar),
            (Some("simd"), false, true, Simd),
            (Some("simd"), false, false, Scalar), // degrade, don't crash
            (Some("simd"), true, true, Scalar),   // mask beats request
            (Some("bogus"), false, true, Simd),   // unknown -> auto
        ];
        for (req, force_scalar, supported, want) in cases {
            assert_eq!(
                resolve_request(req, force_scalar, supported),
                want,
                "request {req:?} force {force_scalar} supported {supported}"
            );
        }
    }

    #[test]
    fn names_and_widths_are_stable() {
        assert_eq!(Backend::Scalar.name(), "scalar");
        assert_eq!(Backend::Simd.name(), "simd");
        assert_eq!(Backend::Scalar.vector_width(), 1);
        assert_eq!(Backend::Simd.vector_width(), 8);
        assert_eq!(format!("{}", Backend::Simd), "simd");
    }

    #[test]
    fn active_is_consistent_with_environment() {
        // Whatever the ambient environment, active() must agree with the
        // pure rule applied to it (unless a CLI/forced override is live,
        // which the lib test binary never does).
        let got = active();
        assert!(got == Backend::Scalar || got == Backend::Simd);
        if got == Backend::Simd {
            assert!(simd::have_avx2_fma(), "SIMD active on a CPU that cannot run it");
        }
    }
}
