//! Block-sparse GEMM over BCSR — the TVM-block-sparse stand-in (Fig. 11).
//!
//! Each stored `bh x bw` block multiplies a `bw x NR` stripe of B with a
//! fully dense micro-GEMM, so performance approaches dense-kernel efficiency
//! scaled by the block occupancy — the classic blocked-sparsity trade-off
//! the paper discusses (§1: blocked formats are fast but restrict nonzero
//! placement).

use crate::formats::bcsr::BcsrTensor;
use crate::tensor::DenseTensor;
use crate::util::threadpool;

const NR: usize = 16;

/// Sparse-dense GEMM: `C = A_bcsr · B`.
pub fn spmm(a: &BcsrTensor, b: &DenseTensor) -> DenseTensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "spmm inner dim mismatch");
    let mut out = DenseTensor::zeros(&[m, n]);
    let (bh, bw) = (a.bh, a.bw);
    let bsz = bh * bw;
    let bd = b.data();
    let od_ptr = threadpool::SyncPtr::new(out.data_mut().as_mut_ptr());
    let brows = m / bh;
    threadpool::parallel_for(brows, 1, |r0, r1| {
        for br in r0..r1 {
            // SAFETY: block row br exclusively owns C rows [br*bh, (br+1)*bh).
            let c_rows =
                unsafe { std::slice::from_raw_parts_mut(od_ptr.get().add(br * bh * n), bh * n) };
            for (bi, &bc) in a.indices[a.indptr[br]..a.indptr[br + 1]].iter().enumerate() {
                let blk = &a.blocks[(a.indptr[br] + bi) * bsz..(a.indptr[br] + bi + 1) * bsz];
                let kbase = bc as usize * bw;
                for jj in (0..n).step_by(NR) {
                    let jw = (n - jj).min(NR);
                    for i in 0..bh {
                        let mut acc = [0f32; NR];
                        for p in 0..bw {
                            let av = blk[i * bw + p];
                            if av == 0.0 {
                                continue;
                            }
                            let brow = &bd[(kbase + p) * n + jj..(kbase + p) * n + jj + jw];
                            for (x, &bv) in acc[..jw].iter_mut().zip(brow) {
                                *x += av * bv;
                            }
                        }
                        let crow = &mut c_rows[i * n + jj..i * n + jj + jw];
                        for (co, x) in crow.iter_mut().zip(acc) {
                            *co += x;
                        }
                    }
                }
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_gemm;
    use crate::util::rng::Pcg64;

    #[test]
    fn matches_dense_reference() {
        let mut rng = Pcg64::seeded(60);
        let mut d = DenseTensor::randn(&[16, 24], &mut rng);
        // Zero out some blocks.
        for (i, x) in d.data_mut().iter_mut().enumerate() {
            if (i / 96) % 2 == 0 {
                *x = 0.0;
            }
        }
        let a = BcsrTensor::from_dense(&d, 4, 4);
        let b = DenseTensor::randn(&[24, 21], &mut rng);
        let got = spmm(&a, &b);
        let want = dense_gemm::matmul_naive(&d, &b);
        assert!(got.allclose(&want, 1e-4, 1e-4), "diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn all_zero_blocks() {
        let d = DenseTensor::zeros(&[8, 8]);
        let a = BcsrTensor::from_dense(&d, 4, 4);
        let b = DenseTensor::ones(&[8, 3]);
        assert_eq!(spmm(&a, &b).max_abs(), 0.0);
    }
}
