//! Block-sparse GEMM over BCSR — the TVM-block-sparse stand-in (Fig. 11).
//!
//! Each stored `bh x bw` block multiplies a `bw x NR` stripe of B with a
//! fully dense micro-GEMM, so performance approaches dense-kernel efficiency
//! scaled by the block occupancy — the classic blocked-sparsity trade-off
//! the paper discusses (§1: blocked formats are fast but restrict nonzero
//! placement).
//!
//! The default [`spmm`] is register-blocked: per (block row, N-tile) it keeps
//! the whole `bh x NR` accumulator tile resident across *all* blocks of the
//! row and stores C exactly once (`const BH` specializations for bh in
//! {2, 4, 8}), where the naive loop ([`spmm_naive`], kept as the `fig10_gemm`
//! baseline) re-reads and re-writes C per block. Products are visited in the
//! same (block, block-column) order but accumulated in one running sum
//! instead of per-block partials, so the kernels agree to rounding (allclose
//! against the densified reference is the correctness oracle for both).

use super::backend::{self, Backend};
use super::simd;
use crate::formats::bcsr::BcsrTensor;
use crate::tensor::DenseTensor;
use crate::util::threadpool;

const NR: usize = 16;

/// Sparse-dense GEMM: `C = A_bcsr · B` (register-blocked kernel).
pub fn spmm(a: &BcsrTensor, b: &DenseTensor) -> DenseTensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "spmm inner dim mismatch");
    let mut out = DenseTensor::zeros(&[m, n]);
    let (bh, bw) = (a.bh, a.bw);
    let bd = b.data();
    let od_ptr = threadpool::SyncPtr::new(out.data_mut().as_mut_ptr());
    let brows = m / bh;
    threadpool::parallel_for(brows, 1, |r0, r1| {
        for br in r0..r1 {
            // SAFETY: block row br exclusively owns C rows [br*bh, (br+1)*bh).
            let c_rows =
                unsafe { std::slice::from_raw_parts_mut(od_ptr.get().add(br * bh * n), bh * n) };
            let blocks = &a.blocks[a.indptr[br] * bh * bw..a.indptr[br + 1] * bh * bw];
            let cols = &a.indices[a.indptr[br]..a.indptr[br + 1]];
            for jj in (0..n).step_by(NR) {
                let jw = (n - jj).min(NR);
                match (bh, jw == NR) {
                    (2, true) => brow_tile::<2, true>(blocks, cols, bw, bd, c_rows, n, jj, jw),
                    (2, false) => brow_tile::<2, false>(blocks, cols, bw, bd, c_rows, n, jj, jw),
                    (4, true) => brow_tile::<4, true>(blocks, cols, bw, bd, c_rows, n, jj, jw),
                    (4, false) => brow_tile::<4, false>(blocks, cols, bw, bd, c_rows, n, jj, jw),
                    (8, true) => brow_tile::<8, true>(blocks, cols, bw, bd, c_rows, n, jj, jw),
                    (8, false) => brow_tile::<8, false>(blocks, cols, bw, bd, c_rows, n, jj, jw),
                    _ => brow_tile_generic(blocks, cols, bh, bw, bd, c_rows, n, jj, jw),
                }
            }
        }
    });
    out
}

/// One (block row, N-tile) pass with the `BH x NR` accumulator resident
/// across every block of the row; C is written exactly once at the end.
/// `FULL` selects the fixed-width path (jw == NR, no tail masking).
#[allow(clippy::too_many_arguments)]
#[inline]
fn brow_tile<const BH: usize, const FULL: bool>(
    blocks: &[f32],
    cols: &[u32],
    bw: usize,
    bd: &[f32],
    c_rows: &mut [f32],
    n: usize,
    jj: usize,
    jw: usize,
) {
    if FULL
        && backend::active() == Backend::Simd
        && simd::bcsr::brow_tile(blocks, cols, BH, bw, bd, c_rows, n, jj)
    {
        return;
    }
    let bsz = BH * bw;
    let mut acc = [[0f32; NR]; BH];
    for (bi, &bc) in cols.iter().enumerate() {
        let blk = &blocks[bi * bsz..(bi + 1) * bsz];
        let kbase = bc as usize * bw;
        // Block-column-major micro-GEMM: each B row is loaded once and
        // broadcast-FMAed into all BH accumulator rows.
        for p in 0..bw {
            let brow = &bd[(kbase + p) * n + jj..(kbase + p) * n + jj + jw];
            for (i, acc_row) in acc.iter_mut().enumerate() {
                let av = blk[i * bw + p];
                if FULL {
                    for (x, &bv) in acc_row.iter_mut().zip(&brow[..NR]) {
                        *x += av * bv;
                    }
                } else {
                    for (x, &bv) in acc_row[..jw].iter_mut().zip(brow) {
                        *x += av * bv;
                    }
                }
            }
        }
    }
    for (i, acc_row) in acc.iter().enumerate() {
        c_rows[i * n + jj..i * n + jj + jw].copy_from_slice(&acc_row[..jw]);
    }
}

/// Fallback for bh values without a const specialization.
#[allow(clippy::too_many_arguments)]
fn brow_tile_generic(
    blocks: &[f32],
    cols: &[u32],
    bh: usize,
    bw: usize,
    bd: &[f32],
    c_rows: &mut [f32],
    n: usize,
    jj: usize,
    jw: usize,
) {
    let bsz = bh * bw;
    let mut acc = vec![[0f32; NR]; bh];
    for (bi, &bc) in cols.iter().enumerate() {
        let blk = &blocks[bi * bsz..(bi + 1) * bsz];
        let kbase = bc as usize * bw;
        for p in 0..bw {
            let brow = &bd[(kbase + p) * n + jj..(kbase + p) * n + jj + jw];
            for (i, acc_row) in acc.iter_mut().enumerate() {
                let av = blk[i * bw + p];
                for (x, &bv) in acc_row[..jw].iter_mut().zip(brow) {
                    *x += av * bv;
                }
            }
        }
    }
    for (i, acc_row) in acc.iter().enumerate() {
        c_rows[i * n + jj..i * n + jj + jw].copy_from_slice(&acc_row[..jw]);
    }
}

/// The pre-blocking kernel (C read-modify-written per block), kept as the
/// `fig10_gemm` baseline for the register-blocked version.
pub fn spmm_naive(a: &BcsrTensor, b: &DenseTensor) -> DenseTensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "spmm inner dim mismatch");
    let mut out = DenseTensor::zeros(&[m, n]);
    let (bh, bw) = (a.bh, a.bw);
    let bsz = bh * bw;
    let bd = b.data();
    let od_ptr = threadpool::SyncPtr::new(out.data_mut().as_mut_ptr());
    let brows = m / bh;
    threadpool::parallel_for(brows, 1, |r0, r1| {
        for br in r0..r1 {
            // SAFETY: block row br exclusively owns C rows [br*bh, (br+1)*bh).
            let c_rows =
                unsafe { std::slice::from_raw_parts_mut(od_ptr.get().add(br * bh * n), bh * n) };
            for (bi, &bc) in a.indices[a.indptr[br]..a.indptr[br + 1]].iter().enumerate() {
                let blk = &a.blocks[(a.indptr[br] + bi) * bsz..(a.indptr[br] + bi + 1) * bsz];
                let kbase = bc as usize * bw;
                for jj in (0..n).step_by(NR) {
                    let jw = (n - jj).min(NR);
                    for i in 0..bh {
                        let mut acc = [0f32; NR];
                        for p in 0..bw {
                            let av = blk[i * bw + p];
                            if av == 0.0 {
                                continue;
                            }
                            let brow = &bd[(kbase + p) * n + jj..(kbase + p) * n + jj + jw];
                            for (x, &bv) in acc[..jw].iter_mut().zip(brow) {
                                *x += av * bv;
                            }
                        }
                        let crow = &mut c_rows[i * n + jj..i * n + jj + jw];
                        for (co, x) in crow.iter_mut().zip(acc) {
                            *co += x;
                        }
                    }
                }
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_gemm;
    use crate::util::rng::Pcg64;

    #[test]
    fn matches_dense_reference() {
        let mut rng = Pcg64::seeded(60);
        let mut d = DenseTensor::randn(&[16, 24], &mut rng);
        // Zero out some blocks.
        for (i, x) in d.data_mut().iter_mut().enumerate() {
            if (i / 96) % 2 == 0 {
                *x = 0.0;
            }
        }
        let a = BcsrTensor::from_dense(&d, 4, 4);
        let b = DenseTensor::randn(&[24, 21], &mut rng);
        let got = spmm(&a, &b);
        let want = dense_gemm::matmul_naive(&d, &b);
        assert!(got.allclose(&want, 1e-4, 1e-4), "diff {}", got.max_abs_diff(&want));
        // 1e-4, not 1e-5: under an ambient SIMD backend the blocked kernel
        // contracts with FMA while the naive baseline stays scalar.
        let naive = spmm_naive(&a, &b);
        assert!(got.allclose(&naive, 1e-4, 1e-4), "blocked vs naive {}", got.max_abs_diff(&naive));
    }

    #[test]
    fn all_zero_blocks() {
        let d = DenseTensor::zeros(&[8, 8]);
        let a = BcsrTensor::from_dense(&d, 4, 4);
        let b = DenseTensor::ones(&[8, 3]);
        assert_eq!(spmm(&a, &b).max_abs(), 0.0);
        assert_eq!(spmm_naive(&a, &b).max_abs(), 0.0);
    }

    #[test]
    fn generic_block_heights_and_tail_tiles() {
        let mut rng = Pcg64::seeded(61);
        for (bh, bw, rows, k, n) in
            [(3usize, 2usize, 9usize, 10usize, 7usize), (5, 3, 10, 9, NR + 5), (2, 4, 8, 16, NR)]
        {
            let mut d = DenseTensor::randn(&[rows, k], &mut rng);
            for (i, x) in d.data_mut().iter_mut().enumerate() {
                if i % 3 == 0 {
                    *x = 0.0;
                }
            }
            let a = BcsrTensor::from_dense(&d, bh, bw);
            let b = DenseTensor::randn(&[k, n], &mut rng);
            let got = spmm(&a, &b);
            let want = dense_gemm::matmul_naive(&d, &b);
            assert!(
                got.allclose(&want, 1e-4, 1e-4),
                "bh={bh} bw={bw} diff {}",
                got.max_abs_diff(&want)
            );
            let naive = spmm_naive(&a, &b);
            assert!(got.allclose(&naive, 1e-4, 1e-4), "blocked vs naive bh={bh} bw={bw}");
        }
    }
}
