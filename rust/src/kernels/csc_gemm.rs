//! Dense-sparse GEMM over CSC: `C = A_dense · B_csc`.
//!
//! The activation-times-sparse-weight orientation (`y = x · W` with sparse
//! `W`), complementing [`super::csr_gemm`]'s sparse-times-dense. Column-major
//! sparsity makes each output column a sparse dot accumulation: for output
//! column `j`, only `W`'s stored entries `(k, j)` contribute `A[:, k]`.

use crate::formats::csc::CscTensor;
use crate::tensor::DenseTensor;
use crate::util::threadpool;

/// Rows of A processed per panel (accumulator tile height).
const MR: usize = 8;

/// Dense-sparse GEMM: `C = A · B_csc`, A (M, K), B (K, N) in CSC.
pub fn spmm_dense_csc(a: &DenseTensor, b: &CscTensor) -> DenseTensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "spmm inner dim mismatch: {k} vs {k2}");
    let mut out = DenseTensor::zeros(&[m, n]);
    let ad = a.data();
    let od_ptr = threadpool::SyncPtr::new(out.data_mut().as_mut_ptr());
    let panels = m.div_ceil(MR);
    threadpool::parallel_for(panels, 1, |p0, p1| {
        for panel in p0..p1 {
            let i0 = panel * MR;
            let i1 = (i0 + MR).min(m);
            // SAFETY: each panel owns disjoint C rows [i0, i1).
            let c_panel = unsafe {
                std::slice::from_raw_parts_mut(od_ptr.get().add(i0 * n), (i1 - i0) * n)
            };
            for j in 0..n {
                for (kk, v) in b.col(j) {
                    for i in i0..i1 {
                        c_panel[(i - i0) * n + j] += ad[i * k + kk] * v;
                    }
                }
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_gemm;
    use crate::util::rng::Pcg64;

    #[test]
    fn matches_dense_reference() {
        let mut rng = Pcg64::seeded(70);
        let a = DenseTensor::randn(&[13, 17], &mut rng);
        let mut w = DenseTensor::randn(&[17, 9], &mut rng);
        for (i, x) in w.data_mut().iter_mut().enumerate() {
            if i % 3 != 0 {
                *x = 0.0;
            }
        }
        let b = CscTensor::from_dense(&w);
        let got = spmm_dense_csc(&a, &b);
        let want = dense_gemm::matmul_naive(&a, &w);
        assert!(got.allclose(&want, 1e-4, 1e-4), "diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn empty_sparse_weight() {
        let a = DenseTensor::ones(&[4, 6]);
        let b = CscTensor::from_dense(&DenseTensor::zeros(&[6, 3]));
        assert_eq!(spmm_dense_csc(&a, &b).max_abs(), 0.0);
    }

    #[test]
    fn single_column() {
        let mut rng = Pcg64::seeded(71);
        let a = DenseTensor::randn(&[5, 4], &mut rng);
        let w = DenseTensor::from_vec(&[4, 1], vec![1.0, 0.0, 2.0, 0.0]);
        let got = spmm_dense_csc(&a, &CscTensor::from_dense(&w));
        let want = dense_gemm::matmul_naive(&a, &w);
        assert!(got.allclose(&want, 1e-5, 1e-5));
    }
}
