//! Unstructured sparse-dense GEMM over CSR — the DeepSparse stand-in.
//!
//! DeepSparse is closed-source; per DESIGN.md §Substitutions this kernel is
//! the canonical tuned unstructured comparator: row-parallel, NR-wide
//! register-tiled inner loop over each row's nonzeros.

use crate::formats::csr::CsrTensor;
use crate::tensor::DenseTensor;
use crate::util::threadpool;

const NR: usize = 16;

/// Sparse-dense GEMM: `C = A_csr · B`.
pub fn spmm(a: &CsrTensor, b: &DenseTensor) -> DenseTensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "spmm inner dim mismatch");
    let mut out = DenseTensor::zeros(&[m, n]);
    let bd = b.data();
    let od_ptr = threadpool::SyncPtr::new(out.data_mut().as_mut_ptr());
    threadpool::parallel_for(m, 8, |r0, r1| {
        for r in r0..r1 {
            // SAFETY: row r of C is written only by this iteration.
            let crow = unsafe { std::slice::from_raw_parts_mut(od_ptr.get().add(r * n), n) };
            let lo = a.indptr[r];
            let hi = a.indptr[r + 1];
            for jj in (0..n).step_by(NR) {
                let jw = (n - jj).min(NR);
                let mut acc = [0f32; NR];
                for i in lo..hi {
                    let av = a.values[i];
                    let kk = a.indices[i] as usize;
                    let brow = &bd[kk * n + jj..kk * n + jj + jw];
                    for (x, &bv) in acc[..jw].iter_mut().zip(brow) {
                        *x += av * bv;
                    }
                }
                crow[jj..jj + jw].copy_from_slice(&acc[..jw]);
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_gemm;
    use crate::util::rng::Pcg64;

    fn random_sparse(rng: &mut Pcg64, rows: usize, cols: usize, density: f32) -> DenseTensor {
        let data = (0..rows * cols)
            .map(|_| if rng.next_f32() < density { rng.normal() } else { 0.0 })
            .collect();
        DenseTensor::from_vec(&[rows, cols], data)
    }

    #[test]
    fn matches_dense_reference() {
        let mut rng = Pcg64::seeded(50);
        let d = random_sparse(&mut rng, 31, 45, 0.2);
        let a = CsrTensor::from_dense(&d);
        let b = DenseTensor::randn(&[45, 27], &mut rng);
        let got = spmm(&a, &b);
        let want = dense_gemm::matmul_naive(&d, &b);
        assert!(got.allclose(&want, 1e-4, 1e-4), "diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn empty_rows_give_zero_output() {
        let d = DenseTensor::zeros(&[4, 6]);
        let a = CsrTensor::from_dense(&d);
        let b = DenseTensor::ones(&[6, 5]);
        assert_eq!(spmm(&a, &b).max_abs(), 0.0);
    }

    #[test]
    fn single_element() {
        let mut d = DenseTensor::zeros(&[1, 1]);
        d.set2(0, 0, 3.0);
        let a = CsrTensor::from_dense(&d);
        let b = DenseTensor::from_vec(&[1, 1], vec![4.0]);
        assert_eq!(spmm(&a, &b).data(), &[12.0]);
    }
}
