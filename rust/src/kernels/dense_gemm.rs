//! Dense GEMM: naive reference + blocked/threaded optimized version.
//!
//! The optimized path follows the OpenBLAS-style structure the paper cites
//! for its own kernel (§5.1): pack a K×NR panel of B, run an MR×NR
//! register-blocked microkernel over M, parallelize across M panels.

use super::backend::{self, Backend};
use super::simd;
use crate::tensor::DenseTensor;
use crate::util::threadpool;

/// Microkernel tile height (rows of C per inner call).
const MR: usize = 8;
/// Microkernel tile width (columns of C per inner call).
const NR: usize = 16;
/// K-blocking for L2-cache residency of the packed B panel.
const KC: usize = 256;

/// Naive triple loop — the correctness oracle for everything else.
pub fn matmul_naive(a: &DenseTensor, b: &DenseTensor) -> DenseTensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "gemm inner dim mismatch: {k} vs {k2}");
    let mut out = DenseTensor::zeros(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    let od = out.data_mut();
    for i in 0..m {
        for p in 0..k {
            let av = ad[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &bd[p * n..(p + 1) * n];
            let orow = &mut od[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// Optimized blocked + threaded GEMM.
pub fn matmul(a: &DenseTensor, b: &DenseTensor) -> DenseTensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "gemm inner dim mismatch: {k} vs {k2}");
    let mut out = DenseTensor::zeros(&[m, n]);
    matmul_into(a.data(), b.data(), out.data_mut(), m, k, n);
    out
}

/// GEMM into a preallocated output (C = A·B, overwriting C).
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    c.fill(0.0);
    // Parallelize over M panels of MR rows; each panel owns disjoint C rows.
    let panels = m.div_ceil(MR);
    let c_ptr = threadpool::SyncPtr::new(c.as_mut_ptr());
    threadpool::parallel_for(panels, 1, |p0, p1| {
        for panel in p0..p1 {
            let i0 = panel * MR;
            let i1 = (i0 + MR).min(m);
            // SAFETY: rows [i0, i1) of C are written only by this panel.
            let c_panel =
                unsafe { std::slice::from_raw_parts_mut(c_ptr.get().add(i0 * n), (i1 - i0) * n) };
            run_panel(a, b, c_panel, i0, i1, k, n);
        }
    });
}

/// Blocked GEMM on the calling thread only — identical numerics and
/// blocking to [`matmul`] (per-panel accumulation order is the same), but
/// no pool interaction. This is the kernel for callers that are themselves
/// a unit of pool work (e.g. the per-`(batch, head)` attention tasks in the
/// native runtime), where the outer scope already saturates the machine and
/// a nested scope would only add queueing overhead.
pub fn matmul_serial(a: &DenseTensor, b: &DenseTensor) -> DenseTensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "gemm inner dim mismatch: {k} vs {k2}");
    let mut out = DenseTensor::zeros(&[m, n]);
    let c = out.data_mut();
    for panel in 0..m.div_ceil(MR) {
        let i0 = panel * MR;
        let i1 = (i0 + MR).min(m);
        run_panel(a.data(), b.data(), &mut c[i0 * n..i1 * n], i0, i1, k, n);
    }
    out
}

/// One MR-row panel pass: full K traversal in KC blocks, NR-wide tiles.
#[inline]
fn run_panel(a: &[f32], b: &[f32], c_panel: &mut [f32], i0: usize, i1: usize, k: usize, n: usize) {
    for kk in (0..k).step_by(KC) {
        let kend = (kk + KC).min(k);
        for jj in (0..n).step_by(NR) {
            let jend = (jj + NR).min(n);
            micro_kernel(a, b, c_panel, i0, i1, kk, kend, jj, jend, k, n);
        }
    }
}

/// MRxNR register-blocked microkernel over a K stripe.
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_kernel(
    a: &[f32],
    b: &[f32],
    c_panel: &mut [f32],
    i0: usize,
    i1: usize,
    k0: usize,
    k1: usize,
    j0: usize,
    j1: usize,
    k: usize,
    n: usize,
) {
    if backend::active() == Backend::Simd
        && simd::dense::micro_kernel(a, b, c_panel, i0, i1, k0, k1, j0, j1, k, n)
    {
        return;
    }
    let jw = j1 - j0;
    if jw == NR {
        // Fast path: full-width tile with fixed-size accumulators that LLVM
        // keeps in vector registers.
        for i in i0..i1 {
            let mut acc = [0f32; NR];
            let arow = &a[i * k..];
            for p in k0..k1 {
                let av = arow[p];
                let brow = &b[p * n + j0..p * n + j0 + NR];
                for (x, &bv) in acc.iter_mut().zip(brow) {
                    *x += av * bv;
                }
            }
            let crow = &mut c_panel[(i - i0) * n + j0..(i - i0) * n + j0 + NR];
            for (co, x) in crow.iter_mut().zip(acc) {
                *co += x;
            }
        }
    } else {
        for i in i0..i1 {
            let arow = &a[i * k..];
            for p in k0..k1 {
                let av = arow[p];
                let brow = &b[p * n..];
                let crow = &mut c_panel[(i - i0) * n..];
                for j in j0..j1 {
                    crow[j] += av * brow[j];
                }
            }
        }
    }
}

/// Masked GEMM: C = (A .* mask) · B — the training-emulation operator.
pub fn matmul_masked(a: &DenseTensor, mask: &DenseTensor, b: &DenseTensor) -> DenseTensor {
    assert_eq!(a.shape(), mask.shape(), "mask shape mismatch");
    let masked = a.zip(mask, |x, m| x * m);
    matmul(&masked, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Pcg64;

    #[test]
    fn blocked_matches_naive_square() {
        let mut rng = Pcg64::seeded(30);
        let a = DenseTensor::randn(&[33, 47], &mut rng);
        let b = DenseTensor::randn(&[47, 29], &mut rng);
        let got = matmul(&a, &b);
        let want = matmul_naive(&a, &b);
        assert!(got.allclose(&want, 1e-4, 1e-4), "diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn identity_matmul() {
        let mut eye = DenseTensor::zeros(&[5, 5]);
        for i in 0..5 {
            eye.set2(i, i, 1.0);
        }
        let mut rng = Pcg64::seeded(31);
        let x = DenseTensor::randn(&[5, 7], &mut rng);
        assert!(matmul(&eye, &x).allclose(&x, 1e-6, 1e-6));
    }

    #[test]
    fn prop_blocked_equals_naive() {
        proptest::check(
            "gemm-blocked-vs-naive",
            20,
            |rng| {
                let m = 1 + rng.below(40) as usize;
                let k = 1 + rng.below(64) as usize;
                let n = 1 + rng.below(40) as usize;
                let seed = rng.next_u64();
                (m, k, n, seed)
            },
            |&(m, k, n, seed)| {
                let mut rng = Pcg64::seeded(seed);
                let a = DenseTensor::randn(&[m, k], &mut rng);
                let b = DenseTensor::randn(&[k, n], &mut rng);
                matmul(&a, &b).allclose(&matmul_naive(&a, &b), 1e-3, 1e-3)
            },
        );
    }

    #[test]
    fn serial_matches_threaded_bit_for_bit() {
        let mut rng = Pcg64::seeded(33);
        for (m, k, n) in [(1usize, 1usize, 1usize), (8, 48, 16), (33, 47, 29), (64, 192, 128)] {
            let a = DenseTensor::randn(&[m, k], &mut rng);
            let b = DenseTensor::randn(&[k, n], &mut rng);
            let par = matmul(&a, &b);
            let ser = matmul_serial(&a, &b);
            // Same blocking, same per-panel accumulation order: identical.
            assert_eq!(par.data(), ser.data(), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn masked_gemm_zeroes_contributions() {
        let mut rng = Pcg64::seeded(32);
        let a = DenseTensor::randn(&[8, 8], &mut rng);
        let b = DenseTensor::randn(&[8, 8], &mut rng);
        let zero_mask = DenseTensor::zeros(&[8, 8]);
        let out = matmul_masked(&a, &zero_mask, &b);
        assert_eq!(out.max_abs(), 0.0);
        let ones = DenseTensor::ones(&[8, 8]);
        let full = matmul_masked(&a, &ones, &b);
        assert!(full.allclose(&matmul(&a, &b), 1e-6, 1e-6));
    }

    #[test]
    fn flops_helper() {
        assert_eq!(super::super::gemm_flops(2, 3, 4), 48.0);
    }
}
