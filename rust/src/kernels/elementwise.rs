//! Elementwise / normalization kernels shared by the op implementations.
//!
//! Numerics match the JAX L2 model (`python/compile/kernels/ref.py`) exactly
//! so the native path and the PJRT artifact path are interchangeable.

use super::backend::{self, Backend};
use super::simd;
use crate::tensor::DenseTensor;
use crate::util::threadpool;

/// Element count below which the row-wise kernels stay on the calling
/// thread (see [`threadpool::SERIAL_THRESHOLD`]: the per-(batch, head)
/// attention softmaxes executed from inside pool tasks must not open
/// nested scopes).
const PAR_THRESHOLD: usize = threadpool::SERIAL_THRESHOLD;

/// Rows per parallel chunk for the row-wise kernels.
const ROW_GRAIN: usize = 16;

/// ReLU.
pub fn relu(x: &DenseTensor) -> DenseTensor {
    x.map(|v| v.max(0.0))
}

/// tanh-approximated GeLU (matches `ref_gelu`).
pub fn gelu(x: &DenseTensor) -> DenseTensor {
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    x.map(|v| 0.5 * v * (1.0 + (c * (v + 0.044715 * v * v * v)).tanh()))
}

/// Derivative of the tanh-approximated GeLU.
pub fn gelu_grad(x: &DenseTensor) -> DenseTensor {
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    x.map(|v| {
        let inner = c * (v + 0.044715 * v * v * v);
        let t = inner.tanh();
        let dinner = c * (1.0 + 3.0 * 0.044715 * v * v);
        0.5 * (1.0 + t) + 0.5 * v * (1.0 - t * t) * dinner
    })
}

/// Row-wise numerically-stable softmax over the last dim of a 2-D tensor.
/// Parallel over disjoint row blocks above [`PAR_THRESHOLD`] elements
/// (results are identical to the serial path: rows are independent).
pub fn softmax_rows(x: &DenseTensor) -> DenseTensor {
    fn softmax_block(xd: &[f32], c: usize, od: &mut [f32], i0: usize, i1: usize) {
        // The SIMD twin keeps exp and the sum in scalar order, so this
        // seam stays bit-identical across backends.
        if backend::active() == Backend::Simd && simd::rows::softmax_block(xd, c, od, i0, i1) {
            return;
        }
        for i in i0..i1 {
            let row = &xd[i * c..(i + 1) * c];
            let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut sum = 0.0;
            let orow = &mut od[(i - i0) * c..(i - i0 + 1) * c];
            for (o, &v) in orow.iter_mut().zip(row) {
                *o = (v - mx).exp();
                sum += *o;
            }
            for o in orow.iter_mut() {
                *o /= sum;
            }
        }
    }
    assert_eq!(x.rank(), 2);
    let (r, c) = (x.rows(), x.cols());
    let mut out = DenseTensor::zeros(&[r, c]);
    let xd = x.data();
    if r * c < PAR_THRESHOLD {
        softmax_block(xd, c, out.data_mut(), 0, r);
        return out;
    }
    let o_ptr = threadpool::SyncPtr::new(out.data_mut().as_mut_ptr());
    threadpool::parallel_for(r, ROW_GRAIN, |i0, i1| {
        // SAFETY: rows [i0, i1) are written only by this chunk.
        let od = unsafe { std::slice::from_raw_parts_mut(o_ptr.get().add(i0 * c), (i1 - i0) * c) };
        softmax_block(xd, c, od, i0, i1);
    });
    out
}

/// Row-wise LayerNorm (gamma/beta broadcast over rows) with eps = 1e-5.
/// Parallel over disjoint row blocks above [`PAR_THRESHOLD`] elements
/// (results are identical to the serial path: rows are independent).
pub fn layernorm_rows(x: &DenseTensor, gamma: &[f32], beta: &[f32]) -> DenseTensor {
    fn ln_block(xd: &[f32], gamma: &[f32], beta: &[f32], od: &mut [f32], i0: usize, i1: usize) {
        if backend::active() == Backend::Simd && simd::rows::ln_block(xd, gamma, beta, od, i0, i1)
        {
            return;
        }
        let c = gamma.len();
        for i in i0..i1 {
            let row = &xd[i * c..(i + 1) * c];
            let mean = row.iter().sum::<f32>() / c as f32;
            let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / c as f32;
            let inv = 1.0 / (var + 1e-5).sqrt();
            let orow = &mut od[(i - i0) * c..(i - i0 + 1) * c];
            for j in 0..c {
                orow[j] = (row[j] - mean) * inv * gamma[j] + beta[j];
            }
        }
    }
    assert_eq!(x.rank(), 2);
    let (r, c) = (x.rows(), x.cols());
    assert_eq!(gamma.len(), c);
    assert_eq!(beta.len(), c);
    let mut out = DenseTensor::zeros(&[r, c]);
    let xd = x.data();
    if r * c < PAR_THRESHOLD {
        ln_block(xd, gamma, beta, out.data_mut(), 0, r);
        return out;
    }
    let o_ptr = threadpool::SyncPtr::new(out.data_mut().as_mut_ptr());
    threadpool::parallel_for(r, ROW_GRAIN, |i0, i1| {
        // SAFETY: rows [i0, i1) are written only by this chunk.
        let od = unsafe { std::slice::from_raw_parts_mut(o_ptr.get().add(i0 * c), (i1 - i0) * c) };
        ln_block(xd, gamma, beta, od, i0, i1);
    });
    out
}

/// Bias add: each row of `x` += `bias`.
pub fn bias_add(x: &DenseTensor, bias: &[f32]) -> DenseTensor {
    assert_eq!(x.rank(), 2);
    let c = x.cols();
    assert_eq!(bias.len(), c);
    let mut out = x.clone();
    // Bit-identical across backends: the vector twin performs the exact
    // same per-element addition.
    if backend::active() == Backend::Simd && simd::rows::bias_add(out.data_mut(), bias) {
        return out;
    }
    for (i, v) in out.data_mut().iter_mut().enumerate() {
        *v += bias[i % c];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn relu_clamps() {
        let x = DenseTensor::from_vec(&[3], vec![-1.0, 0.0, 2.0]);
        assert_eq!(relu(&x).data(), &[0.0, 0.0, 2.0]);
    }

    #[test]
    fn gelu_known_values() {
        let x = DenseTensor::from_vec(&[3], vec![0.0, 1.0, -1.0]);
        let y = gelu(&x);
        assert_eq!(y.data()[0], 0.0);
        assert!((y.data()[1] - 0.8412).abs() < 1e-3);
        assert!((y.data()[2] + 0.1588).abs() < 1e-3);
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        let mut rng = Pcg64::seeded(70);
        let x = DenseTensor::randn(&[32], &mut rng);
        let g = gelu_grad(&x);
        let eps = 1e-3;
        let up = gelu(&x.map(|v| v + eps));
        let dn = gelu(&x.map(|v| v - eps));
        for i in 0..32 {
            let fd = (up.data()[i] - dn.data()[i]) / (2.0 * eps);
            assert!((fd - g.data()[i]).abs() < 1e-2, "at {i}: fd {fd} vs {}", g.data()[i]);
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Pcg64::seeded(71);
        let x = DenseTensor::randn(&[4, 7], &mut rng);
        let s = softmax_rows(&x);
        for i in 0..4 {
            let sum: f32 = s.data()[i * 7..(i + 1) * 7].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_handles_large_values() {
        let x = DenseTensor::from_vec(&[1, 3], vec![1000.0, 1000.0, 1000.0]);
        let s = softmax_rows(&x);
        for &v in s.data() {
            assert!((v - 1.0 / 3.0).abs() < 1e-5);
        }
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let mut rng = Pcg64::seeded(72);
        let x = DenseTensor::randn(&[3, 64], &mut rng);
        let gamma = vec![1.0; 64];
        let beta = vec![0.0; 64];
        let y = layernorm_rows(&x, &gamma, &beta);
        for i in 0..3 {
            let row = &y.data()[i * 64..(i + 1) * 64];
            let mean: f32 = row.iter().sum::<f32>() / 64.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 64.0;
            assert!(mean.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn bias_add_broadcasts() {
        let x = DenseTensor::zeros(&[2, 3]);
        let y = bias_add(&x, &[1.0, 2.0, 3.0]);
        assert_eq!(y.data(), &[1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
    }
}
