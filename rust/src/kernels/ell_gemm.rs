//! Sparse-dense GEMM over ELLPACK: `C = A_ell · B`.
//!
//! ELL's fixed width per row gives a regular, unrollable inner loop —
//! historically the GPU-friendly classic format (§2). Padding slots carry
//! value 0 and therefore contribute nothing (at some wasted FLOPs when row
//! occupancy is skewed).

use crate::formats::ell::EllTensor;
use crate::tensor::DenseTensor;
use crate::util::threadpool;

const NR: usize = 16;

/// Sparse-dense GEMM: `C = A_ell · B`.
pub fn spmm(a: &EllTensor, b: &DenseTensor) -> DenseTensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "spmm inner dim mismatch");
    let mut out = DenseTensor::zeros(&[m, n]);
    let bd = b.data();
    let width = a.width;
    let od_ptr = threadpool::SyncPtr::new(out.data_mut().as_mut_ptr());
    threadpool::parallel_for(m, 8, |r0, r1| {
        for r in r0..r1 {
            // SAFETY: row r of C is written only by this iteration.
            let crow = unsafe { std::slice::from_raw_parts_mut(od_ptr.get().add(r * n), n) };
            for jj in (0..n).step_by(NR) {
                let jw = (n - jj).min(NR);
                let mut acc = [0f32; NR];
                // Fixed-width inner loop: no per-row bounds, just `width` slots.
                for slot in 0..width {
                    let av = a.values[r * width + slot];
                    if av == 0.0 {
                        continue; // padding slot
                    }
                    let kk = a.indices[r * width + slot] as usize;
                    let brow = &bd[kk * n + jj..kk * n + jj + jw];
                    for (x, &bv) in acc[..jw].iter_mut().zip(brow) {
                        *x += av * bv;
                    }
                }
                crow[jj..jj + jw].copy_from_slice(&acc[..jw]);
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_gemm;
    use crate::util::rng::Pcg64;

    #[test]
    fn matches_dense_reference() {
        let mut rng = Pcg64::seeded(80);
        let mut d = DenseTensor::randn(&[19, 23], &mut rng);
        for (i, x) in d.data_mut().iter_mut().enumerate() {
            if i % 4 != 0 {
                *x = 0.0;
            }
        }
        let a = EllTensor::from_dense(&d);
        let b = DenseTensor::randn(&[23, 18], &mut rng);
        let got = spmm(&a, &b);
        let want = dense_gemm::matmul_naive(&d, &b);
        assert!(got.allclose(&want, 1e-4, 1e-4), "diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn skewed_rows_with_padding() {
        // Row 0 dense-ish, rows 1..3 nearly empty: heavy ELL padding.
        let mut d = DenseTensor::zeros(&[4, 8]);
        for c in 0..8 {
            d.set2(0, c, (c + 1) as f32);
        }
        d.set2(2, 5, -3.0);
        let a = EllTensor::from_dense(&d);
        assert_eq!(a.width, 8);
        let b = DenseTensor::ones(&[8, 4]);
        let got = spmm(&a, &b);
        let want = dense_gemm::matmul_naive(&d, &b);
        assert!(got.allclose(&want, 1e-5, 1e-5));
    }

    #[test]
    fn empty_matrix() {
        let a = EllTensor::from_dense(&DenseTensor::zeros(&[3, 5]));
        let b = DenseTensor::ones(&[5, 2]);
        assert_eq!(spmm(&a, &b).max_abs(), 0.0);
    }
}
