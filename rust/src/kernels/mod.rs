//! Native CPU compute kernels — the Layer-3 hot path.
//!
//! The paper's n:m:g sparse-dense GEMM (§5.1) plus the baselines its
//! evaluation compares against:
//!
//! * [`dense_gemm`] — blocked, threaded dense GEMM (the "dense PyTorch"
//!   stand-in of Figs. 10–11).
//! * [`nmg_gemm`] — the paper's kernel: chunk-ordered, branch-free inner
//!   loop, register-blocked microkernel, parallel over row panels.
//! * [`csr_gemm`] — unstructured sparse-dense GEMM (DeepSparse stand-in).
//! * [`csc_gemm`] — dense-sparse GEMM (activation x sparse-weight orientation).
//! * [`ell_gemm`] — ELLPACK sparse-dense GEMM (fixed-width classic format).
//! * [`bcsr_gemm`] — block-sparse GEMM (TVM block-sparse stand-in).
//! * [`elementwise`] — activation / normalization kernels shared by ops.
//!
//! Every kernel above is scalar Rust — the bit-identical reference. The
//! [`backend`] module selects between it and the AVX2+FMA vector twins
//! under [`simd`] (env `STEN_BACKEND`, CLI `--backend`, default auto with
//! runtime feature detection and a guaranteed scalar fallback); the
//! cross-backend golden-vector parity harness lives in
//! `crate::parity` + `tests/backend_parity.rs`.

pub mod backend;
pub mod dense_gemm;
pub mod nmg_gemm;
pub mod csr_gemm;
pub mod csc_gemm;
pub mod ell_gemm;
pub mod bcsr_gemm;
pub mod elementwise;
pub mod simd;

/// FLOP count of an (M, K) x (K, N) GEMM.
pub fn gemm_flops(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}
