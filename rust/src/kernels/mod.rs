//! Native CPU compute kernels — the Layer-3 hot path.
//!
//! The paper's n:m:g sparse-dense GEMM (§5.1) plus the baselines its
//! evaluation compares against:
//!
//! * [`dense_gemm`] — blocked, threaded dense GEMM (the "dense PyTorch"
//!   stand-in of Figs. 10–11).
//! * [`nmg_gemm`] — the paper's kernel: chunk-ordered, branch-free inner
//!   loop, register-blocked microkernel, parallel over row panels.
//! * [`csr_gemm`] — unstructured sparse-dense GEMM (DeepSparse stand-in).
//! * [`csc_gemm`] — dense-sparse GEMM (activation x sparse-weight orientation).
//! * [`ell_gemm`] — ELLPACK sparse-dense GEMM (fixed-width classic format).
//! * [`bcsr_gemm`] — block-sparse GEMM (TVM block-sparse stand-in).
//! * [`elementwise`] — activation / normalization kernels shared by ops.

pub mod dense_gemm;
pub mod nmg_gemm;
pub mod csr_gemm;
pub mod csc_gemm;
pub mod ell_gemm;
pub mod bcsr_gemm;
pub mod elementwise;

/// FLOP count of an (M, K) x (K, N) GEMM.
pub fn gemm_flops(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}
