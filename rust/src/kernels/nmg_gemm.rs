//! The paper's n:m:g sparse-dense GEMM (§5.1, Fig. 6), CPU implementation.
//!
//! Design, mirroring the paper:
//!
//! 1. values are loaded per column slot and broadcast (scalar FMA operands
//!    the compiler hoists into vector registers);
//! 2. the chunk's fixed pattern order makes the inner loop **branch-free**:
//!    pattern changes are compile-time-known strides, never data-dependent
//!    branches;
//! 3. the needed rows of B are fetched by **indirect loads** through the
//!    stored per-slot column index;
//! 4. the paper saves/inits one vector register per pattern boundary (Gray
//!    order); on a modern register file we go further and keep the *entire*
//!    m x NR slab accumulator tile resident for the whole K traversal
//!    (`const M` specializations for m in {4, 8, 10}), so pattern boundaries
//!    cost nothing at all;
//! 5. the N-tile loop is outermost (and is the parallel axis), so the K x NR
//!    panel of B stays cache-resident while *all* slabs traverse it — B
//!    traffic matches a dense kernel with panel height m * slabs instead of
//!    being multiplied by the slab count.
//!
//! The default [`spmm`] additionally hoists the pad-slot check out of every
//! chunk that cannot contain pads (only the final, partial chunk can) and
//! unrolls the group loop by two in that pad-free region, so the hot loop is
//! pure broadcast-FMA with two independent B-row streams in flight. The
//! pre-hoisting kernel is kept as [`spmm_unblocked`] so `fig10_gemm` can
//! track the win.
//!
//! See EXPERIMENTS.md §Perf for the measured iteration log of these choices.

use super::backend::{self, Backend};
use super::simd;
use crate::formats::nmg::NmgTensor;
use crate::tensor::DenseTensor;
use crate::util::threadpool;

/// Output-column tile width (vector-register footprint of the inner loop).
const NR: usize = 16;

/// Sparse-dense GEMM: `C = A_nmg · B`, with `A` (M, K) in n:m:g and `B` (K, N).
pub fn spmm(a: &NmgTensor, b: &DenseTensor) -> DenseTensor {
    let (mrows, k) = (a.shape()[0], a.shape()[1]);
    let (k2, ncols) = (b.rows(), b.cols());
    assert_eq!(k, k2, "spmm inner dim mismatch: {k} vs {k2}");
    let mut out = DenseTensor::zeros(&[mrows, ncols]);
    spmm_into(a, b.data(), out.data_mut(), ncols);
    out
}

/// Pre-hoisting kernel (pad check in every chunk, no group unroll). Kept as
/// the `fig10_gemm` baseline for the blocked kernel; identical results.
pub fn spmm_unblocked(a: &NmgTensor, b: &DenseTensor) -> DenseTensor {
    let (mrows, k) = (a.shape()[0], a.shape()[1]);
    let (k2, ncols) = (b.rows(), b.cols());
    assert_eq!(k, k2, "spmm inner dim mismatch: {k} vs {k2}");
    let mut out = DenseTensor::zeros(&[mrows, ncols]);
    spmm_into_impl::<false>(a, b.data(), out.data_mut(), ncols);
    out
}

/// SpMM into a preallocated output buffer of exactly `a.shape()[0] * ncols`
/// elements (the logical row count — pad rows of a ragged final slab are
/// never written).
pub fn spmm_into(a: &NmgTensor, b: &[f32], c: &mut [f32], ncols: usize) {
    spmm_into_impl::<true>(a, b, c, ncols);
}

/// `HOIST` selects the pad-hoisted + group-unrolled fast path; `false`
/// reproduces the earlier kernel exactly (used as the bench baseline).
fn spmm_into_impl<const HOIST: bool>(a: &NmgTensor, b: &[f32], c: &mut [f32], ncols: usize) {
    let mrows = a.shape()[0];
    assert_eq!(
        c.len(),
        mrows * ncols,
        "spmm output length mismatch: got {}, need rows {mrows} x ncols {ncols}",
        c.len()
    );
    // Flattened pattern rows: pattern p occupies pats_flat[p*n .. p*n+n].
    let pats_flat: Vec<usize> =
        a.pats.iter().flat_map(|p| p.iter().map(|&r| r as usize)).collect();
    // Chunks below this bound hold no pad slots: only the final chunk can be
    // partial, and only when K does not fill it.
    let padfree = if HOIST && a.shape()[1] % (a.c * a.g) == 0 {
        a.chunks
    } else if HOIST {
        a.chunks.saturating_sub(1)
    } else {
        0
    };
    let jtiles = ncols.div_ceil(NR);
    // Resolved once per spmm call so every tile of one multiply runs on the
    // same backend even if a test guard flips the global mid-flight.
    let simd_on = backend::active() == Backend::Simd;
    let c_ptr = threadpool::SyncPtr::new(c.as_mut_ptr());
    // Parallelize over N tiles: threads own disjoint column stripes of C,
    // and each stripe's K x NR panel of B stays cache-hot across slabs.
    threadpool::parallel_for(jtiles, 1, |t0, t1| {
        for tile in t0..t1 {
            let jj = tile * NR;
            let jw = (ncols - jj).min(NR);
            for s in 0..a.slabs {
                // SAFETY: tile stripes are disjoint columns; slabs are
                // disjoint rows; each (tile, slab) region is written once,
                // and all writes stay below mrows * ncols == c.len().
                let c_all =
                    unsafe { std::slice::from_raw_parts_mut(c_ptr.get(), mrows * ncols) };
                let t = Tile { s, ncols, mrows, jj, jw, padfree, simd: simd_on };
                match (a.m, jw == NR) {
                    (4, true) => slab_tile::<4, true>(a, b, c_all, &t, &pats_flat),
                    (4, false) => slab_tile::<4, false>(a, b, c_all, &t, &pats_flat),
                    (8, true) => slab_tile::<8, true>(a, b, c_all, &t, &pats_flat),
                    (8, false) => slab_tile::<8, false>(a, b, c_all, &t, &pats_flat),
                    (10, true) => slab_tile::<10, true>(a, b, c_all, &t, &pats_flat),
                    (10, false) => slab_tile::<10, false>(a, b, c_all, &t, &pats_flat),
                    (16, true) => slab_tile::<16, true>(a, b, c_all, &t, &pats_flat),
                    (16, false) => slab_tile::<16, false>(a, b, c_all, &t, &pats_flat),
                    _ => slab_tile_generic(a, b, c_all, &t, &pats_flat),
                }
            }
        }
    });
}

/// Per-(slab, N-tile) geometry shared by the kernels.
struct Tile {
    s: usize,
    ncols: usize,
    /// Logical row count of C (clamps the store for ragged final slabs).
    mrows: usize,
    jj: usize,
    jw: usize,
    /// Chunks `< padfree` are guaranteed pad-free (fast path eligible).
    padfree: usize,
    /// Dispatch the full-width band loops to the AVX2+FMA twins.
    simd: bool,
}

/// One (slab, N-tile) pass with the full m x NR accumulator tile resident.
///
/// `FULL` selects the fixed-width fast path (jw == NR), letting LLVM keep
/// the accumulators in vector registers with no tail masking.
#[inline]
fn slab_tile<const M: usize, const FULL: bool>(
    a: &NmgTensor,
    b: &[f32],
    c: &mut [f32],
    t: &Tile,
    pats_flat: &[usize],
) {
    debug_assert_eq!(a.m, M);
    let (s, ncols, jj, jw) = (t.s, t.ncols, t.jj, t.jw);
    let n = a.n;
    let g = a.g;
    let slots_per_slab = a.chunks * a.c * g;
    let val = &a.val[s * slots_per_slab * n..(s + 1) * slots_per_slab * n];
    let idx = &a.idx[s * slots_per_slab..(s + 1) * slots_per_slab];

    let mut acc = [[0f32; NR]; M];
    let cg = a.c * g;
    // Banded pattern-major traversal: within a band of BAND chunks, iterate
    // patterns with their n accumulator rows resident in vector registers
    // (the paper's one-register save/init per boundary, amortized over the
    // band). Banding keeps the B sub-panel touched per pattern pass
    // L1-resident even at BERT-scale K. Patterns are row-disjoint
    // contributions, so the reordering is exact.
    const BAND: usize = 8;
    for ch0 in (0..a.chunks).step_by(BAND) {
        let ch1 = (ch0 + BAND).min(a.chunks);
    for p in 0..a.c {
        let rows = &pats_flat[p * n..p * n + n];
        match n {
            1 => {
                let mut acc0 = [0f32; NR];
                // Full-width tiles dispatch the whole band to the AVX2+FMA
                // twin; scalar keeps the loop below (and remains the
                // reference when the backend or the CPU says so).
                let handled = FULL
                    && t.simd
                    && simd::nmg::band_n1(
                        val, idx, b, ncols, jj, cg, p, g, ch0, ch1, t.padfree, &mut acc0,
                    );
                let chunks = if handled { 0..0 } else { ch0..ch1 };
                for ch in chunks {
                    let base = ch * cg + p * g;
                    if FULL && ch < t.padfree {
                        // Pad-free chunk: no zero check (a zero value only
                        // adds 0), group loop unrolled by two so two B-row
                        // streams are in flight per iteration.
                        let mut gi = 0;
                        while gi + 2 <= g {
                            let (sa, sb) = (base + gi, base + gi + 1);
                            let (va, vb) = (val[sa], val[sb]);
                            let ka = idx[sa] as usize * ncols + jj;
                            let kb = idx[sb] as usize * ncols + jj;
                            let ba = &b[ka..ka + NR];
                            let bb = &b[kb..kb + NR];
                            for j in 0..NR {
                                acc0[j] += va * ba[j];
                                acc0[j] += vb * bb[j];
                            }
                            gi += 2;
                        }
                        while gi < g {
                            let slot = base + gi;
                            let v0 = val[slot];
                            let kk = idx[slot] as usize * ncols + jj;
                            let brow = &b[kk..kk + NR];
                            for j in 0..NR {
                                acc0[j] += v0 * brow[j];
                            }
                            gi += 1;
                        }
                        continue;
                    }
                    for gi in 0..g {
                        let slot = base + gi;
                        let v0 = val[slot];
                        let kk = idx[slot] as usize;
                        if v0 == 0.0 {
                            continue; // pad slot (partial trailing chunk)
                        }
                        let brow = &b[kk * ncols + jj..kk * ncols + jj + jw];
                        if FULL {
                            for (x, &bv) in acc0.iter_mut().zip(&brow[..NR]) {
                                *x += v0 * bv;
                            }
                        } else {
                            for (x, &bv) in acc0[..jw].iter_mut().zip(brow) {
                                *x += v0 * bv;
                            }
                        }
                    }
                }
                for (x, v) in acc[rows[0]].iter_mut().zip(acc0) {
                    *x += v;
                }
            }
            2 => {
                let (r0, r1) = (rows[0], rows[1]);
                let mut acc0 = [0f32; NR];
                let mut acc1 = [0f32; NR];
                let handled = FULL
                    && t.simd
                    && simd::nmg::band_n2(
                        val, idx, b, ncols, jj, cg, p, g, ch0, ch1, t.padfree, &mut acc0,
                        &mut acc1,
                    );
                let chunks = if handled { 0..0 } else { ch0..ch1 };
                for ch in chunks {
                    let base = ch * cg + p * g;
                    if FULL && ch < t.padfree {
                        // Pad-free chunk: checkless dual-row broadcast FMA.
                        for gi in 0..g {
                            let slot = base + gi;
                            let v0 = val[slot * 2];
                            let v1 = val[slot * 2 + 1];
                            let kk = idx[slot] as usize * ncols + jj;
                            let brow = &b[kk..kk + NR];
                            for j in 0..NR {
                                let bv = brow[j];
                                acc0[j] += v0 * bv;
                                acc1[j] += v1 * bv;
                            }
                        }
                        continue;
                    }
                    for gi in 0..g {
                        let slot = base + gi;
                        let v0 = val[slot * 2];
                        let v1 = val[slot * 2 + 1];
                        let kk = idx[slot] as usize;
                        if v0 == 0.0 && v1 == 0.0 {
                            continue;
                        }
                        let brow = &b[kk * ncols + jj..kk * ncols + jj + jw];
                        if FULL {
                            for j in 0..NR {
                                let bv = brow[j];
                                acc0[j] += v0 * bv;
                                acc1[j] += v1 * bv;
                            }
                        } else {
                            for j in 0..jw {
                                let bv = brow[j];
                                acc0[j] += v0 * bv;
                                acc1[j] += v1 * bv;
                            }
                        }
                    }
                }
                for (x, v) in acc[r0].iter_mut().zip(acc0) {
                    *x += v;
                }
                for (x, v) in acc[r1].iter_mut().zip(acc1) {
                    *x += v;
                }
            }
            _ => {
                for ch in ch0..ch1 {
                    let base = ch * cg + p * g;
                    for gi in 0..g {
                        let slot = base + gi;
                        let kk = idx[slot] as usize;
                        let vslot = &val[slot * n..slot * n + n];
                        let brow = &b[kk * ncols + jj..kk * ncols + jj + jw];
                        for (tt, &row) in rows.iter().enumerate() {
                            let av = vslot[tt];
                            if av == 0.0 {
                                continue;
                            }
                            for j in 0..jw {
                                acc[row][j] += av * brow[j];
                            }
                        }
                    }
                }
            }
        }
    }
    }
    // Single store of the whole slab tile, clamped to the logical row count
    // (a ragged final slab's pad rows have no backing C storage).
    for (r, acc_row) in acc.iter().enumerate() {
        let row = s * M + r;
        if row >= t.mrows {
            break;
        }
        let crow = &mut c[row * ncols + jj..row * ncols + jj + jw];
        crow.copy_from_slice(&acc_row[..jw]);
    }
}

/// Fallback for m values without a const specialization.
fn slab_tile_generic(a: &NmgTensor, b: &[f32], c: &mut [f32], t: &Tile, pats_flat: &[usize]) {
    let (s, ncols, jj, jw) = (t.s, t.ncols, t.jj, t.jw);
    let (m, n, g) = (a.m, a.n, a.g);
    let slots_per_slab = a.chunks * a.c * g;
    let val = &a.val[s * slots_per_slab * n..(s + 1) * slots_per_slab * n];
    let idx = &a.idx[s * slots_per_slab..(s + 1) * slots_per_slab];
    let mut acc = vec![[0f32; NR]; m];
    let mut slot = 0usize;
    for _ch in 0..a.chunks {
        for p in 0..a.c {
            let rows = &pats_flat[p * n..p * n + n];
            for _gi in 0..g {
                let kk = idx[slot] as usize;
                let vslot = &val[slot * n..slot * n + n];
                slot += 1;
                let brow = &b[kk * ncols + jj..kk * ncols + jj + jw];
                for (tt, &row) in rows.iter().enumerate() {
                    let av = vslot[tt];
                    if av == 0.0 {
                        continue;
                    }
                    for j in 0..jw {
                        acc[row][j] += av * brow[j];
                    }
                }
            }
        }
    }
    for (r, acc_row) in acc.iter().enumerate() {
        let row = s * m + r;
        if row >= t.mrows {
            break;
        }
        let crow = &mut c[row * ncols + jj..row * ncols + jj + jw];
        crow.copy_from_slice(&acc_row[..jw]);
    }
}

/// Reference SpMM via densification (correctness oracle).
pub fn spmm_ref(a: &NmgTensor, b: &DenseTensor) -> DenseTensor {
    super::dense_gemm::matmul_naive(&a.to_dense(), b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Pcg64;

    fn check_format(m: usize, n: usize, g: usize, slabs: usize, k: usize, ncols: usize, seed: u64) {
        check_rows(m, n, g, slabs * m, k, ncols, seed);
    }

    fn check_rows(m: usize, n: usize, g: usize, rows: usize, k: usize, ncols: usize, seed: u64) {
        let mut rng = Pcg64::seeded(seed);
        let dense = DenseTensor::randn(&[rows, k], &mut rng);
        let a = NmgTensor::from_dense(&dense, n, m, g);
        let b = DenseTensor::randn(&[k, ncols], &mut rng);
        let got = spmm(&a, &b);
        let want = spmm_ref(&a, &b);
        assert!(
            got.allclose(&want, 1e-4, 1e-4),
            "{n}:{m}:{g} mismatch, diff {}",
            got.max_abs_diff(&want)
        );
        let unblocked = spmm_unblocked(&a, &b);
        assert!(
            got.allclose(&unblocked, 1e-4, 1e-4),
            "{n}:{m}:{g} blocked vs unblocked diff {}",
            got.max_abs_diff(&unblocked)
        );
    }

    #[test]
    fn matches_ref_2_4() {
        check_format(4, 2, 4, 3, 48, 33, 40);
    }

    #[test]
    fn matches_ref_1_4() {
        check_format(4, 1, 2, 2, 30, 17, 41);
    }

    #[test]
    fn matches_ref_2_8() {
        check_format(8, 2, 2, 2, 56, 20, 42);
    }

    #[test]
    fn matches_ref_1_10() {
        check_format(10, 1, 4, 2, 85, 16, 43);
    }

    #[test]
    fn matches_ref_3_6_generic_path() {
        check_format(6, 3, 2, 2, 45, 19, 44);
    }

    #[test]
    fn partial_chunk_and_small_n() {
        check_format(4, 2, 4, 1, 5, 3, 45);
        check_format(4, 2, 1, 1, 1, 1, 46);
    }

    #[test]
    fn wide_n_exercises_multiple_tiles() {
        check_format(4, 2, 4, 2, 48, 100, 47);
        check_format(8, 2, 4, 2, 64, NR * 3 + 5, 48);
    }

    #[test]
    fn ragged_rows_match_ref() {
        // Regression: ragged row counts used to assert in from_dense and
        // would have written past c.len() here. Sweep slab remainders.
        for (rows, seed) in [(5usize, 60u64), (7, 61), (9, 62), (3, 63)] {
            check_rows(4, 2, 2, rows, 37, 21, seed);
            check_rows(4, 1, 4, rows, 40, NR + 3, seed + 100);
        }
        check_rows(6, 3, 2, 7, 45, 19, 70); // generic path, ragged
        check_rows(10, 1, 2, 14, 50, 18, 71);
    }

    #[test]
    #[should_panic(expected = "output length mismatch")]
    fn spmm_into_rejects_wrong_output_length() {
        let mut rng = Pcg64::seeded(64);
        let dense = DenseTensor::randn(&[6, 20], &mut rng);
        let a = NmgTensor::from_dense(&dense, 2, 4, 2);
        let b = DenseTensor::randn(&[20, 8], &mut rng);
        // Padded-slab sizing (8 rows) instead of the logical 6 rows.
        let mut c = vec![0f32; 8 * 8];
        spmm_into(&a, b.data(), &mut c, 8);
    }

    #[test]
    fn prop_matches_ref() {
        proptest::check(
            "nmg-spmm-vs-ref",
            15,
            |rng| {
                let fmts = [(4usize, 2usize, 2usize), (4, 1, 4), (8, 2, 1), (10, 1, 2)];
                let (m, n, g) = fmts[rng.below(4) as usize];
                // Ragged row counts on purpose: any remainder mod m is legal.
                let rows = 1 + rng.below(3 * m as u64) as usize;
                let k = 1 + rng.below(60) as usize;
                let ncols = 1 + rng.below(40) as usize;
                (m, n, g, rows, k, ncols, rng.next_u64())
            },
            |&(m, n, g, rows, k, ncols, seed)| {
                let mut rng = Pcg64::seeded(seed);
                let dense = DenseTensor::randn(&[rows, k], &mut rng);
                let a = NmgTensor::from_dense(&dense, n, m, g);
                let b = DenseTensor::randn(&[k, ncols], &mut rng);
                let got = spmm(&a, &b);
                got.allclose(&spmm_ref(&a, &b), 1e-3, 1e-3)
                    && got.allclose(&spmm_unblocked(&a, &b), 1e-3, 1e-3)
            },
        );
    }
}
