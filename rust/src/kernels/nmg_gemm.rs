//! The paper's n:m:g sparse-dense GEMM (§5.1, Fig. 6), CPU implementation.
//!
//! Design, mirroring the paper:
//!
//! 1. values are loaded per column slot and broadcast (scalar FMA operands
//!    the compiler hoists into vector registers);
//! 2. the chunk's fixed pattern order makes the inner loop **branch-free**:
//!    pattern changes are compile-time-known strides, never data-dependent
//!    branches;
//! 3. the needed rows of B are fetched by **indirect loads** through the
//!    stored per-slot column index;
//! 4. the paper saves/inits one vector register per pattern boundary (Gray
//!    order); on a modern register file we go further and keep the *entire*
//!    m x NR slab accumulator tile resident for the whole K traversal
//!    (`const M` specializations for m in {4, 8, 10}), so pattern boundaries
//!    cost nothing at all;
//! 5. the N-tile loop is outermost (and is the parallel axis), so the K x NR
//!    panel of B stays cache-resident while *all* slabs traverse it — B
//!    traffic matches a dense kernel with panel height m * slabs instead of
//!    being multiplied by the slab count.
//!
//! See EXPERIMENTS.md §Perf for the measured iteration log of these choices.

use crate::formats::nmg::NmgTensor;
use crate::tensor::DenseTensor;
use crate::util::threadpool;

/// Output-column tile width (vector-register footprint of the inner loop).
const NR: usize = 16;

/// Sparse-dense GEMM: `C = A_nmg · B`, with `A` (M, K) in n:m:g and `B` (K, N).
pub fn spmm(a: &NmgTensor, b: &DenseTensor) -> DenseTensor {
    let (mrows, k) = (a.shape()[0], a.shape()[1]);
    let (k2, ncols) = (b.rows(), b.cols());
    assert_eq!(k, k2, "spmm inner dim mismatch: {k} vs {k2}");
    let mut out = DenseTensor::zeros(&[mrows, ncols]);
    spmm_into(a, b.data(), out.data_mut(), ncols);
    out
}

/// SpMM into a preallocated output buffer.
pub fn spmm_into(a: &NmgTensor, b: &[f32], c: &mut [f32], ncols: usize) {
    // Flattened pattern rows: pattern p occupies pats_flat[p*n .. p*n+n].
    let pats_flat: Vec<usize> =
        a.pats.iter().flat_map(|p| p.iter().map(|&r| r as usize)).collect();
    let jtiles = ncols.div_ceil(NR);
    let c_ptr = threadpool::SyncPtr::new(c.as_mut_ptr());
    // Parallelize over N tiles: threads own disjoint column stripes of C,
    // and each stripe's K x NR panel of B stays cache-hot across slabs.
    threadpool::parallel_for(jtiles, 1, |t0, t1| {
        for tile in t0..t1 {
            let jj = tile * NR;
            let jw = (ncols - jj).min(NR);
            for s in 0..a.slabs {
                // SAFETY: tile stripes are disjoint columns; slabs are
                // disjoint rows; each (tile, slab) region is written once.
                let c_all = unsafe {
                    std::slice::from_raw_parts_mut(c_ptr.get(), a.slabs * a.m * ncols)
                };
                match (a.m, jw == NR) {
                    (4, true) => slab_tile::<4, true>(a, s, b, c_all, ncols, jj, jw, &pats_flat),
                    (4, false) => slab_tile::<4, false>(a, s, b, c_all, ncols, jj, jw, &pats_flat),
                    (8, true) => slab_tile::<8, true>(a, s, b, c_all, ncols, jj, jw, &pats_flat),
                    (8, false) => slab_tile::<8, false>(a, s, b, c_all, ncols, jj, jw, &pats_flat),
                    (10, true) => slab_tile::<10, true>(a, s, b, c_all, ncols, jj, jw, &pats_flat),
                    (10, false) => slab_tile::<10, false>(a, s, b, c_all, ncols, jj, jw, &pats_flat),
                    (16, true) => slab_tile::<16, true>(a, s, b, c_all, ncols, jj, jw, &pats_flat),
                    (16, false) => slab_tile::<16, false>(a, s, b, c_all, ncols, jj, jw, &pats_flat),
                    _ => slab_tile_generic(a, s, b, c_all, ncols, jj, jw, &pats_flat),
                }
            }
        }
    });
}

/// One (slab, N-tile) pass with the full m x NR accumulator tile resident.
///
/// `FULL` selects the fixed-width fast path (jw == NR), letting LLVM keep
/// the accumulators in vector registers with no tail masking.
#[allow(clippy::too_many_arguments)]
#[inline]
fn slab_tile<const M: usize, const FULL: bool>(
    a: &NmgTensor,
    s: usize,
    b: &[f32],
    c: &mut [f32],
    ncols: usize,
    jj: usize,
    jw: usize,
    pats_flat: &[usize],
) {
    debug_assert_eq!(a.m, M);
    let n = a.n;
    let g = a.g;
    let slots_per_slab = a.chunks * a.c * g;
    let val = &a.val[s * slots_per_slab * n..(s + 1) * slots_per_slab * n];
    let idx = &a.idx[s * slots_per_slab..(s + 1) * slots_per_slab];

    let mut acc = [[0f32; NR]; M];
    let cg = a.c * g;
    // Banded pattern-major traversal: within a band of BAND chunks, iterate
    // patterns with their n accumulator rows resident in vector registers
    // (the paper's one-register save/init per boundary, amortized over the
    // band). Banding keeps the B sub-panel touched per pattern pass
    // L1-resident even at BERT-scale K. Patterns are row-disjoint
    // contributions, so the reordering is exact.
    const BAND: usize = 8;
    for ch0 in (0..a.chunks).step_by(BAND) {
        let ch1 = (ch0 + BAND).min(a.chunks);
    for p in 0..a.c {
        let rows = &pats_flat[p * n..p * n + n];
        match n {
            1 => {
                let mut acc0 = [0f32; NR];
                for ch in ch0..ch1 {
                    let base = ch * cg + p * g;
                    for gi in 0..g {
                        let slot = base + gi;
                        let v0 = val[slot];
                        let kk = idx[slot] as usize;
                        if v0 == 0.0 {
                            continue; // pad slot (partial trailing chunk)
                        }
                        let brow = &b[kk * ncols + jj..kk * ncols + jj + jw];
                        if FULL {
                            for (x, &bv) in acc0.iter_mut().zip(&brow[..NR]) {
                                *x += v0 * bv;
                            }
                        } else {
                            for (x, &bv) in acc0[..jw].iter_mut().zip(brow) {
                                *x += v0 * bv;
                            }
                        }
                    }
                }
                for (x, v) in acc[rows[0]].iter_mut().zip(acc0) {
                    *x += v;
                }
            }
            2 => {
                let (r0, r1) = (rows[0], rows[1]);
                let mut acc0 = [0f32; NR];
                let mut acc1 = [0f32; NR];
                for ch in ch0..ch1 {
                    let base = ch * cg + p * g;
                    for gi in 0..g {
                        let slot = base + gi;
                        let v0 = val[slot * 2];
                        let v1 = val[slot * 2 + 1];
                        let kk = idx[slot] as usize;
                        if v0 == 0.0 && v1 == 0.0 {
                            continue;
                        }
                        let brow = &b[kk * ncols + jj..kk * ncols + jj + jw];
                        if FULL {
                            for j in 0..NR {
                                let bv = brow[j];
                                acc0[j] += v0 * bv;
                                acc1[j] += v1 * bv;
                            }
                        } else {
                            for j in 0..jw {
                                let bv = brow[j];
                                acc0[j] += v0 * bv;
                                acc1[j] += v1 * bv;
                            }
                        }
                    }
                }
                for (x, v) in acc[r0].iter_mut().zip(acc0) {
                    *x += v;
                }
                for (x, v) in acc[r1].iter_mut().zip(acc1) {
                    *x += v;
                }
            }
            _ => {
                for ch in ch0..ch1 {
                    let base = ch * cg + p * g;
                    for gi in 0..g {
                        let slot = base + gi;
                        let kk = idx[slot] as usize;
                        let vslot = &val[slot * n..slot * n + n];
                        let brow = &b[kk * ncols + jj..kk * ncols + jj + jw];
                        for (t, &row) in rows.iter().enumerate() {
                            let av = vslot[t];
                            if av == 0.0 {
                                continue;
                            }
                            for j in 0..jw {
                                acc[row][j] += av * brow[j];
                            }
                        }
                    }
                }
            }
        }
    }
    }
    // Single store of the whole slab tile.
    for (r, acc_row) in acc.iter().enumerate() {
        let crow = &mut c[(s * M + r) * ncols + jj..(s * M + r) * ncols + jj + jw];
        crow.copy_from_slice(&acc_row[..jw]);
    }
}

/// Fallback for m values without a const specialization.
#[allow(clippy::too_many_arguments)]
fn slab_tile_generic(
    a: &NmgTensor,
    s: usize,
    b: &[f32],
    c: &mut [f32],
    ncols: usize,
    jj: usize,
    jw: usize,
    pats_flat: &[usize],
) {
    let (m, n, g) = (a.m, a.n, a.g);
    let slots_per_slab = a.chunks * a.c * g;
    let val = &a.val[s * slots_per_slab * n..(s + 1) * slots_per_slab * n];
    let idx = &a.idx[s * slots_per_slab..(s + 1) * slots_per_slab];
    let mut acc = vec![[0f32; NR]; m];
    let mut slot = 0usize;
    for _ch in 0..a.chunks {
        for p in 0..a.c {
            let rows = &pats_flat[p * n..p * n + n];
            for _gi in 0..g {
                let kk = idx[slot] as usize;
                let vslot = &val[slot * n..slot * n + n];
                slot += 1;
                let brow = &b[kk * ncols + jj..kk * ncols + jj + jw];
                for (t, &row) in rows.iter().enumerate() {
                    let av = vslot[t];
                    if av == 0.0 {
                        continue;
                    }
                    for j in 0..jw {
                        acc[row][j] += av * brow[j];
                    }
                }
            }
        }
    }
    for (r, acc_row) in acc.iter().enumerate() {
        let crow = &mut c[(s * m + r) * ncols + jj..(s * m + r) * ncols + jj + jw];
        crow.copy_from_slice(&acc_row[..jw]);
    }
}

/// Reference SpMM via densification (correctness oracle).
pub fn spmm_ref(a: &NmgTensor, b: &DenseTensor) -> DenseTensor {
    super::dense_gemm::matmul_naive(&a.to_dense(), b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Pcg64;

    fn check_format(m: usize, n: usize, g: usize, slabs: usize, k: usize, ncols: usize, seed: u64) {
        let mut rng = Pcg64::seeded(seed);
        let dense = DenseTensor::randn(&[slabs * m, k], &mut rng);
        let a = NmgTensor::from_dense(&dense, n, m, g);
        let b = DenseTensor::randn(&[k, ncols], &mut rng);
        let got = spmm(&a, &b);
        let want = spmm_ref(&a, &b);
        assert!(
            got.allclose(&want, 1e-4, 1e-4),
            "{n}:{m}:{g} mismatch, diff {}",
            got.max_abs_diff(&want)
        );
    }

    #[test]
    fn matches_ref_2_4() {
        check_format(4, 2, 4, 3, 48, 33, 40);
    }

    #[test]
    fn matches_ref_1_4() {
        check_format(4, 1, 2, 2, 30, 17, 41);
    }

    #[test]
    fn matches_ref_2_8() {
        check_format(8, 2, 2, 2, 56, 20, 42);
    }

    #[test]
    fn matches_ref_1_10() {
        check_format(10, 1, 4, 2, 85, 16, 43);
    }

    #[test]
    fn matches_ref_3_6_generic_path() {
        check_format(6, 3, 2, 2, 45, 19, 44);
    }

    #[test]
    fn partial_chunk_and_small_n() {
        check_format(4, 2, 4, 1, 5, 3, 45);
        check_format(4, 2, 1, 1, 1, 1, 46);
    }

    #[test]
    fn wide_n_exercises_multiple_tiles() {
        check_format(4, 2, 4, 2, 48, 100, 47);
        check_format(8, 2, 4, 2, 64, NR * 3 + 5, 48);
    }

    #[test]
    fn prop_matches_ref() {
        proptest::check(
            "nmg-spmm-vs-ref",
            15,
            |rng| {
                let fmts = [(4usize, 2usize, 2usize), (4, 1, 4), (8, 2, 1), (10, 1, 2)];
                let (m, n, g) = fmts[rng.below(4) as usize];
                let slabs = 1 + rng.below(3) as usize;
                let k = 1 + rng.below(60) as usize;
                let ncols = 1 + rng.below(40) as usize;
                (m, n, g, slabs, k, ncols, rng.next_u64())
            },
            |&(m, n, g, slabs, k, ncols, seed)| {
                let mut rng = Pcg64::seeded(seed);
                let dense = DenseTensor::randn(&[slabs * m, k], &mut rng);
                let a = NmgTensor::from_dense(&dense, n, m, g);
                let b = DenseTensor::randn(&[k, ncols], &mut rng);
                spmm(&a, &b).allclose(&spmm_ref(&a, &b), 1e-3, 1e-3)
            },
        );
    }
}
