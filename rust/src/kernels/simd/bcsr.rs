//! AVX2+FMA block-row kernel for the register-blocked BCSR GEMM.
//!
//! Vector twin of `bcsr_gemm::brow_tile` on full-width (jw == NR) tiles:
//! the whole `BH x NR` accumulator tile lives in registers across every
//! block of the row, each B row is loaded once and broadcast-FMAed into all
//! BH rows, and C is overwritten exactly once at the end — the same visit
//! order as the scalar kernel, with FMA contraction the allclose parity
//! seam absorbs. B and block accesses go through bounds-checked subslices;
//! the intrinsics never read past what the scalar kernel would.

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// Output-column tile width (must match `bcsr_gemm::NR`).
#[cfg(target_arch = "x86_64")]
const NR: usize = 16;

/// One (block row, full N-tile) pass. Returns `false` (caller runs the
/// scalar loop) when AVX2+FMA is unavailable or `bh` has no vector
/// specialization.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
pub fn brow_tile(
    blocks: &[f32],
    cols: &[u32],
    bh: usize,
    bw: usize,
    bd: &[f32],
    c_rows: &mut [f32],
    n: usize,
    jj: usize,
) -> bool {
    if !super::have_avx2_fma() {
        return false;
    }
    match bh {
        // SAFETY (each arm): AVX2+FMA verified above; the kernel indexes
        // blocks/bd/c_rows through bounds-checked slices only.
        2 => unsafe { kernel::<2>(blocks, cols, bw, bd, c_rows, n, jj) },
        4 => unsafe { kernel::<4>(blocks, cols, bw, bd, c_rows, n, jj) },
        8 => unsafe { kernel::<8>(blocks, cols, bw, bd, c_rows, n, jj) },
        _ => return false,
    }
    true
}

/// Scalar-fallback stub: non-x86_64 hosts never take the vector path.
#[cfg(not(target_arch = "x86_64"))]
#[allow(clippy::too_many_arguments)]
pub fn brow_tile(
    _blocks: &[f32],
    _cols: &[u32],
    _bh: usize,
    _bw: usize,
    _bd: &[f32],
    _c_rows: &mut [f32],
    _n: usize,
    _jj: usize,
) -> bool {
    false
}

/// The resident-accumulator block-row micro-GEMM for one const block
/// height.
///
/// # Safety
///
/// Caller must verify AVX2+FMA before calling; all slice accesses inside
/// are bounds-checked.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[inline]
unsafe fn kernel<const BH: usize>(
    blocks: &[f32],
    cols: &[u32],
    bw: usize,
    bd: &[f32],
    c_rows: &mut [f32],
    n: usize,
    jj: usize,
) {
    // SAFETY: every load/store goes through a pointer derived from a
    // bounds-checked subslice formed just above it; loadu/storeu carry no
    // alignment obligations.
    unsafe {
        let bsz = BH * bw;
        let mut acc = [[_mm256_setzero_ps(); 2]; BH];
        for (bi, &bc) in cols.iter().enumerate() {
            let blk = &blocks[bi * bsz..(bi + 1) * bsz];
            let kbase = bc as usize * bw;
            for p in 0..bw {
                let boff = (kbase + p) * n + jj;
                let brow = &bd[boff..boff + NR];
                let blo = _mm256_loadu_ps(brow.as_ptr());
                let bhi = _mm256_loadu_ps(brow.as_ptr().add(8));
                for (i, acc_row) in acc.iter_mut().enumerate() {
                    let av = _mm256_set1_ps(blk[i * bw + p]);
                    acc_row[0] = _mm256_fmadd_ps(av, blo, acc_row[0]);
                    acc_row[1] = _mm256_fmadd_ps(av, bhi, acc_row[1]);
                }
            }
        }
        for (i, acc_row) in acc.iter().enumerate() {
            let crow = &mut c_rows[i * n + jj..i * n + jj + NR];
            _mm256_storeu_ps(crow.as_mut_ptr(), acc_row[0]);
            _mm256_storeu_ps(crow.as_mut_ptr().add(8), acc_row[1]);
        }
    }
}
