//! AVX2+FMA microkernel for the blocked dense GEMM.
//!
//! Drop-in vector twin of `dense_gemm::micro_kernel`: same (row, K, column)
//! per-element traversal, local per-call accumulators merged into C once at
//! the end — for BOTH full 16-wide tiles and masked tails. Keeping the tail
//! on the FULL-tile accumulation order matters: a sharded column slice of
//! the output sees tail tiles where the unsharded run sees full ones, and
//! identical per-element operation order is what keeps the sharded forward
//! bit-identical to the unsharded engine *within* the SIMD backend.

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// Microkernel tile width (must match `dense_gemm::NR`).
#[cfg(target_arch = "x86_64")]
const NR: usize = 16;

/// Mask rows for `_mm256_maskload_ps`/`_mm256_maskstore_ps`: row `w`
/// enables the first `w` lanes (sign bit set).
#[cfg(target_arch = "x86_64")]
const MASKS: [[i32; 8]; 9] = [
    [0, 0, 0, 0, 0, 0, 0, 0],
    [-1, 0, 0, 0, 0, 0, 0, 0],
    [-1, -1, 0, 0, 0, 0, 0, 0],
    [-1, -1, -1, 0, 0, 0, 0, 0],
    [-1, -1, -1, -1, 0, 0, 0, 0],
    [-1, -1, -1, -1, -1, 0, 0, 0],
    [-1, -1, -1, -1, -1, -1, 0, 0],
    [-1, -1, -1, -1, -1, -1, -1, 0],
    [-1, -1, -1, -1, -1, -1, -1, -1],
];

/// Vectorized microkernel over a K stripe — same contract as the scalar
/// `dense_gemm::micro_kernel` (accumulates `A[i0..i1, k0..k1] ·
/// B[k0..k1, j0..j1]` into the panel rows of `c_panel`, whose row `r` holds
/// logical row `i0 + r` with stride `n`). Returns `false` (caller runs the
/// scalar loop) when AVX2+FMA is unavailable.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
pub fn micro_kernel(
    a: &[f32],
    b: &[f32],
    c_panel: &mut [f32],
    i0: usize,
    i1: usize,
    k0: usize,
    k1: usize,
    j0: usize,
    j1: usize,
    k: usize,
    n: usize,
) -> bool {
    if !super::have_avx2_fma() {
        return false;
    }
    if i1 <= i0 || k1 <= k0 {
        return true; // empty stripe: nothing to accumulate
    }
    let jw = j1 - j0;
    assert!(jw >= 1 && jw <= NR);
    // Bounds the unsafe kernels rely on, established in safe code: every
    // pointer they form stays inside these slices.
    assert!(a.len() >= (i1 - 1) * k + k1);
    assert!(b.len() >= (k1 - 1) * n + j0 + jw);
    assert!(c_panel.len() >= (i1 - i0 - 1) * n + j0 + jw);
    if jw == NR {
        // SAFETY: AVX2+FMA verified above; slice bounds asserted above.
        unsafe { kernel_full(a, b, c_panel, i0, i1, k0, k1, j0, k, n) };
    } else {
        // SAFETY: AVX2+FMA verified above; slice bounds asserted above.
        unsafe { kernel_tail(a, b, c_panel, i0, i1, k0, k1, j0, jw, k, n) };
    }
    true
}

/// Scalar-fallback stub: non-x86_64 hosts never take the vector path.
#[cfg(not(target_arch = "x86_64"))]
#[allow(clippy::too_many_arguments)]
pub fn micro_kernel(
    _a: &[f32],
    _b: &[f32],
    _c_panel: &mut [f32],
    _i0: usize,
    _i1: usize,
    _k0: usize,
    _k1: usize,
    _j0: usize,
    _j1: usize,
    _k: usize,
    _n: usize,
) -> bool {
    false
}

/// Full-width (jw == 16) tile: rows in pairs, two 8-lane accumulators per
/// row, one fused multiply-add per (row, half, p). Per element the order is
/// "accumulate over p ascending, then one merge into C" — the vector
/// analogue of the scalar FULL path.
///
/// # Safety
///
/// Caller must verify AVX2+FMA and assert the slice bounds checked in
/// [`micro_kernel`] before calling.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[allow(clippy::too_many_arguments)]
#[inline]
unsafe fn kernel_full(
    a: &[f32],
    b: &[f32],
    c_panel: &mut [f32],
    i0: usize,
    i1: usize,
    k0: usize,
    k1: usize,
    j0: usize,
    k: usize,
    n: usize,
) {
    // SAFETY: the wrapper asserted every offset formed below is in bounds
    // of its slice; loadu/storeu carry no alignment obligations.
    unsafe {
        let bp = b.as_ptr();
        let cp = c_panel.as_mut_ptr();
        let mut i = i0;
        while i + 2 <= i1 {
            let a0 = &a[i * k..i * k + k1];
            let a1 = &a[(i + 1) * k..(i + 1) * k + k1];
            let mut acc00 = _mm256_setzero_ps();
            let mut acc01 = _mm256_setzero_ps();
            let mut acc10 = _mm256_setzero_ps();
            let mut acc11 = _mm256_setzero_ps();
            for p in k0..k1 {
                let av0 = _mm256_set1_ps(a0[p]);
                let av1 = _mm256_set1_ps(a1[p]);
                let b0 = _mm256_loadu_ps(bp.add(p * n + j0));
                let b1 = _mm256_loadu_ps(bp.add(p * n + j0 + 8));
                acc00 = _mm256_fmadd_ps(av0, b0, acc00);
                acc01 = _mm256_fmadd_ps(av0, b1, acc01);
                acc10 = _mm256_fmadd_ps(av1, b0, acc10);
                acc11 = _mm256_fmadd_ps(av1, b1, acc11);
            }
            let c0 = cp.add((i - i0) * n + j0);
            let c1 = cp.add((i + 1 - i0) * n + j0);
            _mm256_storeu_ps(c0, _mm256_add_ps(_mm256_loadu_ps(c0), acc00));
            _mm256_storeu_ps(c0.add(8), _mm256_add_ps(_mm256_loadu_ps(c0.add(8)), acc01));
            _mm256_storeu_ps(c1, _mm256_add_ps(_mm256_loadu_ps(c1), acc10));
            _mm256_storeu_ps(c1.add(8), _mm256_add_ps(_mm256_loadu_ps(c1.add(8)), acc11));
            i += 2;
        }
        if i < i1 {
            let arow = &a[i * k..i * k + k1];
            let mut acc0 = _mm256_setzero_ps();
            let mut acc1 = _mm256_setzero_ps();
            for p in k0..k1 {
                let av = _mm256_set1_ps(arow[p]);
                acc0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(bp.add(p * n + j0)), acc0);
                acc1 = _mm256_fmadd_ps(av, _mm256_loadu_ps(bp.add(p * n + j0 + 8)), acc1);
            }
            let c0 = cp.add((i - i0) * n + j0);
            _mm256_storeu_ps(c0, _mm256_add_ps(_mm256_loadu_ps(c0), acc0));
            _mm256_storeu_ps(c0.add(8), _mm256_add_ps(_mm256_loadu_ps(c0.add(8)), acc1));
        }
    }
}

/// Tail tile (jw < 16) with masked loads/stores. The per-element operation
/// sequence (fmadd over p ascending into a zeroed local accumulator, one
/// add-merge into C) is identical to [`kernel_full`], so an output column
/// computes to the same bits whether it lands in a full or a tail tile.
///
/// # Safety
///
/// Caller must verify AVX2+FMA and assert the slice bounds checked in
/// [`micro_kernel`] before calling.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[allow(clippy::too_many_arguments)]
#[inline]
unsafe fn kernel_tail(
    a: &[f32],
    b: &[f32],
    c_panel: &mut [f32],
    i0: usize,
    i1: usize,
    k0: usize,
    k1: usize,
    j0: usize,
    jw: usize,
    k: usize,
    n: usize,
) {
    // SAFETY: the wrapper asserted bounds for the first `jw` lanes past
    // every offset formed below; the masked loads/stores fault-suppress
    // their disabled lanes, so the ragged row edge is never touched.
    unsafe {
        let w0 = jw.min(8);
        let w1 = jw - w0;
        let m0 = _mm256_loadu_si256(MASKS[w0].as_ptr() as *const __m256i);
        let m1 = _mm256_loadu_si256(MASKS[w1].as_ptr() as *const __m256i);
        let bp = b.as_ptr();
        let cp = c_panel.as_mut_ptr();
        for i in i0..i1 {
            let arow = &a[i * k..i * k + k1];
            let mut acc0 = _mm256_setzero_ps();
            let mut acc1 = _mm256_setzero_ps();
            for p in k0..k1 {
                let av = _mm256_set1_ps(arow[p]);
                acc0 = _mm256_fmadd_ps(av, _mm256_maskload_ps(bp.add(p * n + j0), m0), acc0);
                if w1 > 0 {
                    acc1 =
                        _mm256_fmadd_ps(av, _mm256_maskload_ps(bp.add(p * n + j0 + 8), m1), acc1);
                }
            }
            let c0 = cp.add((i - i0) * n + j0);
            _mm256_maskstore_ps(c0, m0, _mm256_add_ps(_mm256_maskload_ps(c0, m0), acc0));
            if w1 > 0 {
                let c1 = c0.add(8);
                _mm256_maskstore_ps(c1, m1, _mm256_add_ps(_mm256_maskload_ps(c1, m1), acc1));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::util::rng::Pcg64;

    /// Exercise full tiles, a masked tail, and multi-stripe K blocking
    /// against a plain triple loop. Skips (vacuously passes) on hosts
    /// without AVX2+FMA, where the wrapper reports `false`.
    #[test]
    fn tiles_match_naive_reference() {
        let (m, k, n) = (5usize, 37usize, 23usize);
        let mut rng = Pcg64::seeded(7);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let mut c = vec![0f32; m * n];
        let mut hit = true;
        for kk in (0..k).step_by(16) {
            let kend = (kk + 16).min(k);
            for jj in (0..n).step_by(16) {
                let jend = (jj + 16).min(n);
                hit &= super::micro_kernel(&a, &b, &mut c, 0, m, kk, kend, jj, jend, k, n);
            }
        }
        if !hit {
            assert!(!super::super::have_avx2_fma());
            return;
        }
        for i in 0..m {
            for j in 0..n {
                let want: f32 = (0..k).map(|p| a[i * k + p] * b[p * n + j]).sum();
                let got = c[i * n + j];
                assert!(
                    (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                    "({i},{j}): {got} vs {want}"
                );
            }
        }
    }
}
