//! SIMD (AVX2+FMA) backend kernels — the vectorized twins of the scalar
//! compute kernels, selected via [`crate::kernels::backend`].
//!
//! Layout rules (enforced by `xtask lint`):
//!
//! * `std::arch` intrinsics and `#[target_feature]` fns live only under
//!   `kernels/simd/` — nothing outside this directory touches raw vector
//!   code.
//! * Every `#[target_feature]` fn is **private** and reached only through a
//!   safe `pub fn ... -> bool` wrapper that checks [`have_avx2_fma`] first.
//!   The wrappers return `false` when the CPU (or the shape) cannot run the
//!   vector kernel, and the scalar caller falls through to its own loop — so
//!   the scalar fallback is a guaranteed property of the call structure, not
//!   a promise.
//! * All `unsafe` carries a SAFETY comment; slice bounds are established in
//!   safe code before any raw pointer is formed.
//!
//! Numerics: FMA contracts mul+add and wider accumulators regroup sums, so
//! SIMD results are *allclose* to the scalar reference (per-seam tolerances
//! in `runtime/README.md` § Backend selection), not bit-identical — except
//! where a kernel performs the exact per-element operation sequence of its
//! scalar twin (the dense tail tiles keep FULL-tile accumulation order so
//! sharded column slices stay bit-identical to the unsharded run *within*
//! the SIMD backend).
//!
//! Threading: these kernels never create threads or scopes. They are leaf
//! compute called from inside the existing `util::threadpool` panel /
//! tile / block-row tasks, exactly where the scalar loops they replace ran.

pub mod bcsr;
pub mod dense;
pub mod nmg;
pub mod rows;

/// True when the host can run the AVX2+FMA kernels in this module.
#[cfg(target_arch = "x86_64")]
pub fn have_avx2_fma() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

/// Non-x86_64 hosts never run the vector kernels.
#[cfg(not(target_arch = "x86_64"))]
pub fn have_avx2_fma() -> bool {
    false
}

/// Detected CPU features relevant to kernel selection, joined with `+`
/// (e.g. `"avx2+fma+avx512f"`), or `"none"`. Recorded in the bench JSON so
/// perf numbers stay attributable to the hardware that produced them.
#[cfg(target_arch = "x86_64")]
pub fn cpu_features() -> String {
    let mut feats = Vec::new();
    if is_x86_feature_detected!("avx2") {
        feats.push("avx2");
    }
    if is_x86_feature_detected!("fma") {
        feats.push("fma");
    }
    if is_x86_feature_detected!("avx512f") {
        feats.push("avx512f");
    }
    if feats.is_empty() {
        "none".to_string()
    } else {
        feats.join("+")
    }
}

/// Non-x86_64 hosts report no vector features.
#[cfg(not(target_arch = "x86_64"))]
pub fn cpu_features() -> String {
    "none".to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_features_is_nonempty_and_consistent() {
        let feats = cpu_features();
        assert!(!feats.is_empty());
        if have_avx2_fma() {
            assert!(feats.contains("avx2") && feats.contains("fma"), "{feats}");
        }
    }
}
