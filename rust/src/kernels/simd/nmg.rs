//! AVX2+FMA band kernels for the n:m:g sparse-dense GEMM.
//!
//! Vector twins of the `nmg_gemm::slab_tile` n == 1 and n == 2 inner band
//! loops on full-width (jw == NR) tiles: the caller keeps the banded
//! pattern-major traversal, pad-free classification and the scalar merge of
//! the per-pattern accumulator into the slab tile; these kernels only
//! replace the per-chunk broadcast-FMA loops. Pad slots (only possible in
//! chunks at or past `padfree`) are skipped by the same `val == 0` test the
//! scalar loop uses — their stored index may point past the end of B, so
//! the skip happens *before* any B row is touched.
//!
//! All B-row accesses go through bounds-checked subslices formed in-line;
//! the intrinsics only ever read through pointers derived from those
//! slices, so an out-of-range stored index panics exactly like the scalar
//! kernel instead of reading wild memory.

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// Output-column tile width (must match `nmg_gemm::NR`).
#[cfg(target_arch = "x86_64")]
const NR: usize = 16;

/// n == 1 band: accumulate pattern `p` of chunks `[ch0, ch1)` into `acc0`
/// (one 16-wide accumulator row). Returns `false` when AVX2+FMA is
/// unavailable and the caller must run its scalar loop.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
pub fn band_n1(
    val: &[f32],
    idx: &[u32],
    b: &[f32],
    ncols: usize,
    jj: usize,
    cg: usize,
    p: usize,
    g: usize,
    ch0: usize,
    ch1: usize,
    padfree: usize,
    acc0: &mut [f32; 16],
) -> bool {
    if !super::have_avx2_fma() {
        return false;
    }
    // SAFETY: AVX2+FMA verified above; the kernel indexes val/idx/b through
    // bounds-checked slices only.
    unsafe { band_n1_avx(val, idx, b, ncols, jj, cg, p, g, ch0, ch1, padfree, acc0) };
    true
}

/// Scalar-fallback stub: non-x86_64 hosts never take the vector path.
#[cfg(not(target_arch = "x86_64"))]
#[allow(clippy::too_many_arguments)]
pub fn band_n1(
    _val: &[f32],
    _idx: &[u32],
    _b: &[f32],
    _ncols: usize,
    _jj: usize,
    _cg: usize,
    _p: usize,
    _g: usize,
    _ch0: usize,
    _ch1: usize,
    _padfree: usize,
    _acc0: &mut [f32; 16],
) -> bool {
    false
}

/// n == 2 band: accumulate pattern `p` of chunks `[ch0, ch1)` into the two
/// accumulator rows `acc0`/`acc1` (each B row is loaded once and
/// broadcast-FMAed into both). Returns `false` when AVX2+FMA is
/// unavailable.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
pub fn band_n2(
    val: &[f32],
    idx: &[u32],
    b: &[f32],
    ncols: usize,
    jj: usize,
    cg: usize,
    p: usize,
    g: usize,
    ch0: usize,
    ch1: usize,
    padfree: usize,
    acc0: &mut [f32; 16],
    acc1: &mut [f32; 16],
) -> bool {
    if !super::have_avx2_fma() {
        return false;
    }
    // SAFETY: AVX2+FMA verified above; the kernel indexes val/idx/b through
    // bounds-checked slices only.
    unsafe { band_n2_avx(val, idx, b, ncols, jj, cg, p, g, ch0, ch1, padfree, acc0, acc1) };
    true
}

/// Scalar-fallback stub: non-x86_64 hosts never take the vector path.
#[cfg(not(target_arch = "x86_64"))]
#[allow(clippy::too_many_arguments)]
pub fn band_n2(
    _val: &[f32],
    _idx: &[u32],
    _b: &[f32],
    _ncols: usize,
    _jj: usize,
    _cg: usize,
    _p: usize,
    _g: usize,
    _ch0: usize,
    _ch1: usize,
    _padfree: usize,
    _acc0: &mut [f32; 16],
    _acc1: &mut [f32; 16],
) -> bool {
    false
}

/// n == 1 inner band. Two slot-parity accumulator pairs keep two
/// independent FMA chains in flight (merged once at the end — a regrouping
/// the allclose parity seam absorbs); pad-capable chunks fall back to the
/// zero-checked single chain.
///
/// # Safety
///
/// Caller must verify AVX2+FMA before calling; all slice accesses inside
/// are bounds-checked.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[allow(clippy::too_many_arguments)]
#[inline]
unsafe fn band_n1_avx(
    val: &[f32],
    idx: &[u32],
    b: &[f32],
    ncols: usize,
    jj: usize,
    cg: usize,
    p: usize,
    g: usize,
    ch0: usize,
    ch1: usize,
    padfree: usize,
    acc0: &mut [f32; 16],
) {
    // SAFETY: every load/store goes through a pointer derived from a
    // bounds-checked subslice formed just above it; loadu/storeu carry no
    // alignment obligations.
    unsafe {
        let mut lo = _mm256_loadu_ps(acc0.as_ptr());
        let mut hi = _mm256_loadu_ps(acc0.as_ptr().add(8));
        let mut lo2 = _mm256_setzero_ps();
        let mut hi2 = _mm256_setzero_ps();
        for ch in ch0..ch1 {
            let base = ch * cg + p * g;
            if ch < padfree {
                // Pad-free chunk: checkless, slots split across the two
                // accumulator pairs.
                let mut gi = 0;
                while gi + 2 <= g {
                    let (sa, sb) = (base + gi, base + gi + 1);
                    let va = _mm256_set1_ps(val[sa]);
                    let vb = _mm256_set1_ps(val[sb]);
                    let ka = idx[sa] as usize * ncols + jj;
                    let kb = idx[sb] as usize * ncols + jj;
                    let ba = &b[ka..ka + NR];
                    let bb = &b[kb..kb + NR];
                    lo = _mm256_fmadd_ps(va, _mm256_loadu_ps(ba.as_ptr()), lo);
                    hi = _mm256_fmadd_ps(va, _mm256_loadu_ps(ba.as_ptr().add(8)), hi);
                    lo2 = _mm256_fmadd_ps(vb, _mm256_loadu_ps(bb.as_ptr()), lo2);
                    hi2 = _mm256_fmadd_ps(vb, _mm256_loadu_ps(bb.as_ptr().add(8)), hi2);
                    gi += 2;
                }
                while gi < g {
                    let slot = base + gi;
                    let v = _mm256_set1_ps(val[slot]);
                    let kk = idx[slot] as usize * ncols + jj;
                    let brow = &b[kk..kk + NR];
                    lo = _mm256_fmadd_ps(v, _mm256_loadu_ps(brow.as_ptr()), lo);
                    hi = _mm256_fmadd_ps(v, _mm256_loadu_ps(brow.as_ptr().add(8)), hi);
                    gi += 1;
                }
            } else {
                for gi in 0..g {
                    let slot = base + gi;
                    let v0 = val[slot];
                    if v0 == 0.0 {
                        continue; // pad slot: its index may point past B
                    }
                    let v = _mm256_set1_ps(v0);
                    let kk = idx[slot] as usize * ncols + jj;
                    let brow = &b[kk..kk + NR];
                    lo = _mm256_fmadd_ps(v, _mm256_loadu_ps(brow.as_ptr()), lo);
                    hi = _mm256_fmadd_ps(v, _mm256_loadu_ps(brow.as_ptr().add(8)), hi);
                }
            }
        }
        lo = _mm256_add_ps(lo, lo2);
        hi = _mm256_add_ps(hi, hi2);
        _mm256_storeu_ps(acc0.as_mut_ptr(), lo);
        _mm256_storeu_ps(acc0.as_mut_ptr().add(8), hi);
    }
}

/// n == 2 inner band: four resident accumulator registers (two rows x two
/// halves), one B-row load shared by both rows per slot.
///
/// # Safety
///
/// Caller must verify AVX2+FMA before calling; all slice accesses inside
/// are bounds-checked.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[allow(clippy::too_many_arguments)]
#[inline]
unsafe fn band_n2_avx(
    val: &[f32],
    idx: &[u32],
    b: &[f32],
    ncols: usize,
    jj: usize,
    cg: usize,
    p: usize,
    g: usize,
    ch0: usize,
    ch1: usize,
    padfree: usize,
    acc0: &mut [f32; 16],
    acc1: &mut [f32; 16],
) {
    // SAFETY: every load/store goes through a pointer derived from a
    // bounds-checked subslice formed just above it; loadu/storeu carry no
    // alignment obligations.
    unsafe {
        let mut lo0 = _mm256_loadu_ps(acc0.as_ptr());
        let mut hi0 = _mm256_loadu_ps(acc0.as_ptr().add(8));
        let mut lo1 = _mm256_loadu_ps(acc1.as_ptr());
        let mut hi1 = _mm256_loadu_ps(acc1.as_ptr().add(8));
        for ch in ch0..ch1 {
            let base = ch * cg + p * g;
            let checkless = ch < padfree;
            for gi in 0..g {
                let slot = base + gi;
                let v0 = val[slot * 2];
                let v1 = val[slot * 2 + 1];
                if !checkless && v0 == 0.0 && v1 == 0.0 {
                    continue; // pad slot: its index may point past B
                }
                let kk = idx[slot] as usize * ncols + jj;
                let brow = &b[kk..kk + NR];
                let blo = _mm256_loadu_ps(brow.as_ptr());
                let bhi = _mm256_loadu_ps(brow.as_ptr().add(8));
                let va = _mm256_set1_ps(v0);
                let vb = _mm256_set1_ps(v1);
                lo0 = _mm256_fmadd_ps(va, blo, lo0);
                hi0 = _mm256_fmadd_ps(va, bhi, hi0);
                lo1 = _mm256_fmadd_ps(vb, blo, lo1);
                hi1 = _mm256_fmadd_ps(vb, bhi, hi1);
            }
        }
        _mm256_storeu_ps(acc0.as_mut_ptr(), lo0);
        _mm256_storeu_ps(acc0.as_mut_ptr().add(8), hi0);
        _mm256_storeu_ps(acc1.as_mut_ptr(), lo1);
        _mm256_storeu_ps(acc1.as_mut_ptr().add(8), hi1);
    }
}
