//! AVX2(+FMA) row loops for the elementwise / normalization kernels.
//!
//! Vector twins of the `elementwise` row-block helpers. The softmax kernel
//! keeps `exp` and the running sum scalar (identical order to the scalar
//! twin — there is no vector exp in `std`) and vectorizes the max fold and
//! the divide, both of which are order-insensitive per element, so softmax
//! stays bit-identical across backends. LayerNorm regroups its mean /
//! variance sums into vector lanes and contracts the normalize step with
//! FMA, so it is an allclose seam. The bias add performs the exact same
//! per-element addition and stays bit-identical.

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// Row-wise softmax over rows `[i0, i1)` of `xd` (row width `c`) into the
/// relative rows of `od` — same contract as the scalar block helper in
/// `elementwise::softmax_rows`. Returns `false` when AVX2+FMA is
/// unavailable or the row is too narrow to vectorize.
#[cfg(target_arch = "x86_64")]
pub fn softmax_block(xd: &[f32], c: usize, od: &mut [f32], i0: usize, i1: usize) -> bool {
    if !super::have_avx2_fma() || c < 8 {
        return false;
    }
    assert!(xd.len() >= i1 * c && od.len() >= (i1 - i0) * c);
    // SAFETY: AVX2+FMA verified above; row bounds asserted above and every
    // vector access stays within one row slice.
    unsafe { softmax_avx(xd, c, od, i0, i1) };
    true
}

/// Scalar-fallback stub: non-x86_64 hosts never take the vector path.
#[cfg(not(target_arch = "x86_64"))]
pub fn softmax_block(_xd: &[f32], _c: usize, _od: &mut [f32], _i0: usize, _i1: usize) -> bool {
    false
}

/// Row-wise LayerNorm over rows `[i0, i1)` — same contract as the scalar
/// block helper in `elementwise::layernorm_rows` (eps = 1e-5). Returns
/// `false` when AVX2+FMA is unavailable or the row is too narrow.
#[cfg(target_arch = "x86_64")]
pub fn ln_block(
    xd: &[f32],
    gamma: &[f32],
    beta: &[f32],
    od: &mut [f32],
    i0: usize,
    i1: usize,
) -> bool {
    let c = gamma.len();
    if !super::have_avx2_fma() || c < 8 {
        return false;
    }
    assert!(beta.len() == c && xd.len() >= i1 * c && od.len() >= (i1 - i0) * c);
    // SAFETY: AVX2+FMA verified above; row bounds asserted above and every
    // vector access stays within one row / gamma / beta slice.
    unsafe { ln_avx(xd, gamma, beta, od, i0, i1) };
    true
}

/// Scalar-fallback stub: non-x86_64 hosts never take the vector path.
#[cfg(not(target_arch = "x86_64"))]
pub fn ln_block(
    _xd: &[f32],
    _gamma: &[f32],
    _beta: &[f32],
    _od: &mut [f32],
    _i0: usize,
    _i1: usize,
) -> bool {
    false
}

/// `data[r * c + j] += bias[j]` for every row — same contract as the loop
/// in `elementwise::bias_add` (`data.len()` must be a multiple of
/// `bias.len()`). Bit-identical to the scalar loop. Returns `false` when
/// AVX2+FMA is unavailable or the row is too narrow.
#[cfg(target_arch = "x86_64")]
pub fn bias_add(data: &mut [f32], bias: &[f32]) -> bool {
    if !super::have_avx2_fma() || bias.len() < 8 {
        return false;
    }
    assert_eq!(data.len() % bias.len(), 0);
    // SAFETY: AVX2+FMA verified above; all accesses stay within one
    // `chunks_exact` row of `data` or within `bias`.
    unsafe { bias_add_avx(data, bias) };
    true
}

/// Scalar-fallback stub: non-x86_64 hosts never take the vector path.
#[cfg(not(target_arch = "x86_64"))]
pub fn bias_add(_data: &mut [f32], _bias: &[f32]) -> bool {
    false
}

/// Horizontal sum of the 8 lanes.
///
/// # Safety
///
/// Caller must verify AVX2+FMA; pure register arithmetic otherwise.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[inline]
unsafe fn hsum(v: __m256) -> f32 {
    // SAFETY: pure register arithmetic, no memory access.
    unsafe {
        let s = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps(v, 1));
        let s = _mm_hadd_ps(s, s);
        let s = _mm_hadd_ps(s, s);
        _mm_cvtss_f32(s)
    }
}

/// Horizontal max of the 8 lanes.
///
/// # Safety
///
/// Caller must verify AVX2+FMA; pure register arithmetic otherwise.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[inline]
unsafe fn hmax(v: __m256) -> f32 {
    // SAFETY: pure register arithmetic, no memory access.
    unsafe {
        let m = _mm_max_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps(v, 1));
        let m = _mm_max_ps(m, _mm_movehl_ps(m, m));
        let m = _mm_max_ps(m, _mm_shuffle_ps(m, m, 0b01));
        _mm_cvtss_f32(m)
    }
}

/// # Safety
///
/// Caller must verify AVX2+FMA and assert the row bounds checked in
/// [`softmax_block`] before calling.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[inline]
unsafe fn softmax_avx(xd: &[f32], c: usize, od: &mut [f32], i0: usize, i1: usize) {
    // SAFETY: the wrapper asserted the row bounds; every pointer below is
    // derived from an in-bounds row slice with at least 8 lanes left.
    unsafe {
        for i in i0..i1 {
            let row = &xd[i * c..(i + 1) * c];
            let orow = &mut od[(i - i0) * c..(i - i0 + 1) * c];
            // Vector max fold (max is order-insensitive: same result bits).
            let mut mv = _mm256_set1_ps(f32::NEG_INFINITY);
            let mut j = 0;
            while j + 8 <= c {
                mv = _mm256_max_ps(mv, _mm256_loadu_ps(row.as_ptr().add(j)));
                j += 8;
            }
            let mut mx = hmax(mv);
            while j < c {
                mx = mx.max(row[j]);
                j += 1;
            }
            // Scalar exp + running sum: identical order to the scalar twin.
            let mut sum = 0.0;
            for (o, &v) in orow.iter_mut().zip(row) {
                *o = (v - mx).exp();
                sum += *o;
            }
            // Vector divide (per-element, same op as the scalar twin).
            let sv = _mm256_set1_ps(sum);
            let mut j = 0;
            while j + 8 <= c {
                let op = orow.as_mut_ptr().add(j);
                _mm256_storeu_ps(op, _mm256_div_ps(_mm256_loadu_ps(op), sv));
                j += 8;
            }
            while j < c {
                orow[j] /= sum;
                j += 1;
            }
        }
    }
}

/// # Safety
///
/// Caller must verify AVX2+FMA and assert the row bounds checked in
/// [`ln_block`] before calling.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[inline]
unsafe fn ln_avx(xd: &[f32], gamma: &[f32], beta: &[f32], od: &mut [f32], i0: usize, i1: usize) {
    // SAFETY: the wrapper asserted the row bounds; every pointer below is
    // derived from an in-bounds row / gamma / beta slice with at least 8
    // lanes left.
    unsafe {
        let c = gamma.len();
        for i in i0..i1 {
            let row = &xd[i * c..(i + 1) * c];
            let mut sv = _mm256_setzero_ps();
            let mut j = 0;
            while j + 8 <= c {
                sv = _mm256_add_ps(sv, _mm256_loadu_ps(row.as_ptr().add(j)));
                j += 8;
            }
            let mut sum = hsum(sv);
            while j < c {
                sum += row[j];
                j += 1;
            }
            let mean = sum / c as f32;
            let mv = _mm256_set1_ps(mean);
            let mut vv = _mm256_setzero_ps();
            let mut j = 0;
            while j + 8 <= c {
                let d = _mm256_sub_ps(_mm256_loadu_ps(row.as_ptr().add(j)), mv);
                vv = _mm256_fmadd_ps(d, d, vv);
                j += 8;
            }
            let mut var = hsum(vv);
            while j < c {
                let d = row[j] - mean;
                var += d * d;
                j += 1;
            }
            let inv = 1.0 / (var / c as f32 + 1e-5).sqrt();
            let iv = _mm256_set1_ps(inv);
            let orow = &mut od[(i - i0) * c..(i - i0 + 1) * c];
            let mut j = 0;
            while j + 8 <= c {
                let t = _mm256_mul_ps(_mm256_sub_ps(_mm256_loadu_ps(row.as_ptr().add(j)), mv), iv);
                let g = _mm256_loadu_ps(gamma.as_ptr().add(j));
                let bt = _mm256_loadu_ps(beta.as_ptr().add(j));
                _mm256_storeu_ps(orow.as_mut_ptr().add(j), _mm256_fmadd_ps(t, g, bt));
                j += 8;
            }
            while j < c {
                orow[j] = (row[j] - mean) * inv * gamma[j] + beta[j];
                j += 1;
            }
        }
    }
}

/// # Safety
///
/// Caller must verify AVX2+FMA and assert the whole-rows invariant checked
/// in [`bias_add`] before calling.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[inline]
unsafe fn bias_add_avx(data: &mut [f32], bias: &[f32]) {
    // SAFETY: the wrapper asserted data.len() is a whole number of
    // bias-width rows; every pointer below stays inside one row or bias.
    unsafe {
        let c = bias.len();
        for row in data.chunks_exact_mut(c) {
            let mut j = 0;
            while j + 8 <= c {
                let p = row.as_mut_ptr().add(j);
                let bv = _mm256_loadu_ps(bias.as_ptr().add(j));
                _mm256_storeu_ps(p, _mm256_add_ps(_mm256_loadu_ps(p), bv));
                j += 8;
            }
            while j < c {
                row[j] += bias[j];
                j += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::util::rng::Pcg64;

    #[test]
    fn softmax_and_bias_match_scalar_exactly() {
        let (r, c) = (3usize, 21usize);
        let mut rng = Pcg64::seeded(8);
        let xd: Vec<f32> = (0..r * c).map(|_| rng.normal()).collect();
        let mut got = vec![0f32; r * c];
        if !super::softmax_block(&xd, c, &mut got, 0, r) {
            assert!(!super::super::have_avx2_fma());
            return;
        }
        for i in 0..r {
            let row = &xd[i * c..(i + 1) * c];
            let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut sum = 0.0;
            let mut want = vec![0f32; c];
            for (o, &v) in want.iter_mut().zip(row) {
                *o = (v - mx).exp();
                sum += *o;
            }
            for (j, o) in want.iter_mut().enumerate() {
                *o /= sum;
                assert_eq!(got[i * c + j], *o, "softmax row {i} col {j}");
            }
        }
        let bias: Vec<f32> = (0..c).map(|_| rng.normal()).collect();
        let mut data: Vec<f32> = (0..r * c).map(|_| rng.normal()).collect();
        let before = data.clone();
        assert!(super::bias_add(&mut data, &bias));
        for (i, (&d, &b4)) in data.iter().zip(&before).enumerate() {
            assert_eq!(d, b4 + bias[i % c], "bias at {i}");
        }
    }

    #[test]
    fn layernorm_close_to_scalar() {
        let (r, c) = (2usize, 19usize);
        let mut rng = Pcg64::seeded(9);
        let xd: Vec<f32> = (0..r * c).map(|_| rng.normal()).collect();
        let gamma: Vec<f32> = (0..c).map(|_| 1.0 + 0.1 * rng.normal()).collect();
        let beta: Vec<f32> = (0..c).map(|_| 0.1 * rng.normal()).collect();
        let mut got = vec![0f32; r * c];
        if !super::ln_block(&xd, &gamma, &beta, &mut got, 0, r) {
            assert!(!super::super::have_avx2_fma());
            return;
        }
        for i in 0..r {
            let row = &xd[i * c..(i + 1) * c];
            let mean = row.iter().sum::<f32>() / c as f32;
            let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / c as f32;
            let inv = 1.0 / (var + 1e-5).sqrt();
            for j in 0..c {
                let want = (row[j] - mean) * inv * gamma[j] + beta[j];
                let g = got[i * c + j];
                assert!((g - want).abs() <= 1e-4 * (1.0 + want.abs()), "({i},{j}): {g} vs {want}");
            }
        }
    }
}
