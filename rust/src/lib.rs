//! # STen — productive and efficient sparsity (Rust + JAX + Pallas reproduction)
//!
//! This crate reimplements the STen sparsity programming model (Ivanov et al.,
//! 2023) as the Layer-3 coordinator of a three-layer Rust + JAX + Pallas stack:
//!
//! * [`formats`] — sparsity layouts (CSR, CSC, COO, ELL, BCSR, n:m, n:m:g, masked).
//! * [`sparsify`] — sparsifiers (keep-all, random fraction, threshold, per-block
//!   n:m, magnitude, block magnitude, same-format), classified streaming /
//!   blocking / materializing per Table 1 of the paper.
//! * [`ops`] + [`dispatch`] — operators with per-layout-signature implementations
//!   and the dispatch engine (registry lookup → lossless conversion → dense
//!   fallback) of §4.4.
//! * [`autograd`] — reverse-mode tape with per-tensor gradient output formats
//!   (inline sparsifier, temporary layout, external sparsifier, final layout).
//! * [`kernels`] — native CPU kernels: the paper's §5.1 n:m:g sparse-dense GEMM,
//!   a DeepSparse-style CSR comparator, a TVM-style BCSR comparator, a blocked
//!   dense GEMM baseline and the §5.2 dense→n:m:g conversion algorithms.
//! * [`model`] — module graph, transformer encoder, and the `SparsityBuilder`
//!   tracing API of §3.4.
//! * [`train`] — optimizers, masked sparse training, pruning schedules (§6.2).
//! * [`dist`] — data-parallel gradient synchronization with sparse handling (§4.6).
//! * [`runtime`] — manifest-driven executor for AOT-described JAX/Pallas
//!   artifacts (L2/L1), currently backed by a hermetic native interpreter.
//! * [`tune`] — cost-model / microbench format autotuner with a
//!   schema-versioned, deterministic decision cache.
//! * [`coordinator`] — batched sparse inference engine with dispatch/runtime
//!   timing breakdown (Fig 11), plus the concurrent deadline-batching
//!   serving front-end (bounded queue, N weight-sharing engine replicas).
//!
//! # Concurrency soundness
//!
//! The hand-rolled sync primitives (`util::threadpool`, `util::channel`,
//! the serving completion latch) go through the [`util::sync`] shim: plain
//! `std` types by default, model-checked drop-ins from [`util::loom`] under
//! `--features loom` (`cargo test --features loom --test loom` runs the
//! exhaustive interleaving suite). See `src/runtime/README.md`
//! § Concurrency invariants for the full lane matrix (loom / Miri / TSan /
//! `xtask lint`).

// Every `unsafe` operation inside an `unsafe fn` must carry its own
// `unsafe {}` block (and, by repo lint, its own `// SAFETY:` argument).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod util;
pub mod tensor;
pub mod formats;
pub mod sparsify;
pub mod ops;
pub mod dispatch;
pub mod autograd;
pub mod kernels;
pub mod model;
pub mod train;
pub mod dist;
pub mod runtime;
pub mod tune;
pub mod parity;
pub mod coordinator;
pub mod energy;

pub use tensor::DenseTensor;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
