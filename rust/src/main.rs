//! `sten` CLI — leader entrypoint for the coordinator.
//!
//! Subcommands:
//!
//! * `info`     — print artifact manifest + dispatcher summary.
//! * `infer`    — run sparse/dense encoder inference over the AOT artifacts
//!   (`--autotune [--tune-policy cost|bench]` picks per-layer FFN weight
//!   formats via the cost-model autotuner, cached across runs).
//! * `serve`    — run the dynamic batcher over synthetic requests
//!   (`--replicas N` switches to the concurrent deadline-batching server;
//!   `--shards W` serves each replica as a W-way tensor-parallel sharded
//!   model with per-shard timing in the report;
//!   `--models dense:2,nmg:2 --weights 1,3` serves a multi-model registry
//!   with weighted scheduling and per-model latency/SLO reports;
//!   `--admission --degrade-to dense=nmg --shed` turns on overload
//!   defense: reject/degrade at submit time, shed expired queue entries).
//! * `energy`   — print the Fig. 7 energy table for a random weight.
//! * `sparsify` — demonstrate the SparsityBuilder on an MLP.
//!
//! Global flag: `--backend scalar|simd|auto` selects the compute backend
//! for every subcommand (default auto: SIMD when the host has AVX2+FMA,
//! scalar otherwise; see `src/runtime/README.md` § Compute backends).

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};
use sten::coordinator::{
    BatchServer, ConcurrentServer, Engine, FfnMode, ModelRegistry, SchedPolicy, ServeConfig,
    ServeReport, SubmitError,
};
use sten::formats::Layout;
use sten::model::{MlpSpec, SparsityBuilder};
use sten::runtime::ArtifactRuntime;
use sten::sparsify::GroupedNm;
use sten::tensor::DenseTensor;
use sten::tune::{Autotuner, TuneCache, TunePolicy};
use sten::util::cli::Args;
use sten::util::rng::Pcg64;

fn main() -> Result<()> {
    let args = Args::parse();
    // Resolve the compute backend once, before any kernel runs: CLI
    // `--backend scalar|simd|auto` beats the `STEN_BACKEND` env (both lose
    // to `STEN_FORCE_SCALAR`, and "simd" degrades to scalar without AVX2).
    if let Some(req) = args.get("backend") {
        sten::kernels::backend::select(req);
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("info");
    match cmd {
        "info" => info(&args),
        "infer" => infer(&args),
        "serve" => serve(&args),
        "energy" => energy(&args),
        "sparsify" => sparsify(&args),
        other => {
            eprintln!("unknown command {other:?}; try info|infer|serve|energy|sparsify");
            std::process::exit(2);
        }
    }
}

fn info(_args: &Args) -> Result<()> {
    let rt = ArtifactRuntime::open_default()?;
    println!("artifacts ({}):", rt.manifest().len());
    for name in rt.manifest().names() {
        let spec = rt.spec(name)?;
        println!("  {name}: {} inputs, {} outputs", spec.inputs.len(), spec.outputs.len());
    }
    let d = sten::dispatch::global();
    println!("dispatcher: {} registered op implementations", d.len());
    println!(
        "backend: {} (cpu features: {})",
        sten::kernels::backend::active(),
        sten::kernels::simd::cpu_features()
    );
    Ok(())
}

fn infer(args: &Args) -> Result<()> {
    let tag = args.get_or("tag", "tiny");
    let mode = match args.get_or("ffn", "nmg").as_str() {
        "dense" => FfnMode::DenseArtifact,
        "native" => FfnMode::NativeDense,
        _ => FfnMode::NativeNmg { n: 2, m: 4, g: 4 },
    };
    let iters: usize = args.num("iters", 3);
    let rt = ArtifactRuntime::open_default()?;
    let mut engine = Engine::new(rt, &tag, mode, 42)?;
    if args.flag("autotune") {
        // Pick per-layer FFN weight formats; decisions replay from the
        // schema-versioned cache (`$STEN_AUTOTUNE_CACHE` or
        // `target/autotune_cache.json`) on later runs.
        let policy = match args.get_or("tune-policy", "cost").as_str() {
            "bench" => TunePolicy::Microbench { warmup: 1, iters: 3 },
            _ => TunePolicy::CostModel,
        };
        let cache_path = TuneCache::default_path();
        let mut tuner = Autotuner::with_cache(policy, TuneCache::load(&cache_path)?);
        let decisions = engine.autotune_ffn(&mut tuner)?;
        for (l, d) in decisions.iter().enumerate() {
            println!(
                "autotune layer {l}: {} via {} (cost {:.3e}, {})",
                d.layout, d.kernel, d.cost, d.policy
            );
        }
        println!(
            "autotune: {} hits, {} misses; cache {} entries -> {}",
            tuner.hits,
            tuner.misses,
            tuner.cache.len(),
            cache_path.display()
        );
        tuner.cache.save(&cache_path)?;
    }
    let mut rng = Pcg64::seeded(7);
    let tokens = engine.random_tokens(&mut rng);
    for i in 0..iters {
        let t = std::time::Instant::now();
        let logits = engine.forward(&tokens)?;
        println!(
            "iter {i}: {:?} logits in {:.3} ms",
            logits.shape(),
            t.elapsed().as_secs_f64() * 1e3
        );
    }
    println!("breakdown: {:?}", engine.timing().sorted());
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let tag = args.get_or("tag", "tiny");
    let requests: usize = args.num("requests", 32);
    let replicas: usize = args.num("replicas", 0); // 0 = synchronous drain loop
    let shards: usize = args.num("shards", 1);
    let max_wait = Duration::from_millis(args.num("max-wait-ms", 5));
    let slo = Duration::from_millis(args.num("slo-ms", 25));
    if args.get("models").is_some() {
        return serve_multi(args, &tag, requests, max_wait, slo);
    }
    let rt = ArtifactRuntime::open_default()?;
    let engine = Engine::new(rt, &tag, FfnMode::NativeNmg { n: 2, m: 4, g: 4 }, 42)?;
    let seq = engine.dims.seq;
    let vocab = engine.dims.vocab as u32;
    let mut rng = Pcg64::seeded(11);
    let next = |rng: &mut Pcg64| -> Vec<i32> {
        (0..seq).map(|_| rng.below(vocab) as i32).collect()
    };

    if replicas > 0 || shards > 1 {
        let replicas = replicas.max(1);
        let cfg = ServeConfig {
            replicas,
            queue_cap: args.num("queue-cap", 256),
            max_wait,
            slo,
            ..ServeConfig::default()
        };
        let server = if shards > 1 {
            // Tensor-parallel: each replica slot is a sharded instance
            // executing batches cooperatively on `shards` threads.
            let mut registry = ModelRegistry::new();
            registry.register_sharded("default", engine, replicas, 1, shards)?;
            ConcurrentServer::start_registry(registry, cfg)?
        } else {
            ConcurrentServer::start(engine, cfg)?
        };
        for _ in 0..requests {
            server.submit(&next(&mut rng))?;
        }
        let report = server.finish()?;
        match report.latency {
            Some(lat) => println!(
                "served {} requests on {replicas} replicas in {} batches; \
                 p50/p95/p99 {:.3}/{:.3}/{:.3} ms; slo-miss {:.1}%; {:.1} req/s wall; \
                 queue high-water {}",
                report.results.len(),
                report.batches,
                lat.p50 * 1e3,
                lat.p95 * 1e3,
                lat.p99 * 1e3,
                report.slo_miss.unwrap_or(0.0) * 100.0,
                report.wall_rps,
                report.queue_high_water,
            ),
            None => println!("served 0 requests"),
        }
        print_replica_timing(&report);
        print_shard_timing(&report);
        return Ok(());
    }

    let mut server = BatchServer::new(engine, max_wait);
    for _ in 0..requests {
        let toks = next(&mut rng);
        server.submit(&toks);
    }
    server.run_until_drained()?;
    println!(
        "served {} requests; median latency {:.3} ms; throughput {:.1} req/s",
        server.completed.len(),
        server.median_latency().unwrap_or(0.0) * 1e3,
        server.throughput().unwrap_or(0.0),
    );
    Ok(())
}

/// FFN execution mode for a `--models` entry name.
fn ffn_mode_for(kind: &str) -> Result<FfnMode> {
    Ok(match kind {
        "dense" => FfnMode::NativeDense,
        "dense-artifact" => FfnMode::DenseArtifact,
        "nmg" => FfnMode::NativeNmg { n: 2, m: 4, g: 4 },
        other => bail!("unknown model kind {other:?} (try dense|dense-artifact|nmg)"),
    })
}

/// `serve --models dense:2,nmg:2 --weights 1,3 [--policy wdrr|fifo]
/// [--admission] [--degrade-to dense=nmg] [--shed]`: a multi-model
/// registry behind one front-end, mixed synthetic traffic, per-model
/// p50/p95/p99 + SLO-miss reporting, and opt-in overload defense
/// (admission control with sparse-degrade, expired-entry shedding).
fn serve_multi(
    args: &Args,
    tag: &str,
    requests: usize,
    max_wait: Duration,
    slo: Duration,
) -> Result<()> {
    let spec = args.get("models").unwrap();
    let mut parts: Vec<(String, usize)> = Vec::new();
    for item in spec.split(',').filter(|s| !s.is_empty()) {
        match item.split_once(':') {
            Some((name, n)) => {
                let n: usize = n
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad replica count in {item:?}: {e}"))?;
                parts.push((name.to_string(), n));
            }
            None => parts.push((item.to_string(), 1)),
        }
    }
    if parts.is_empty() {
        bail!("--models needs at least one name:replicas entry");
    }
    let weights: Vec<u64> = match args.get("weights") {
        Some(w) => w
            .split(',')
            .map(|x| x.parse().map_err(|e| anyhow::anyhow!("bad weight {x:?}: {e}")))
            .collect::<Result<_>>()?,
        None => vec![1; parts.len()],
    };
    if weights.len() != parts.len() {
        bail!("--weights has {} entries for {} models", weights.len(), parts.len());
    }
    let policy = match args.get_or("policy", "wdrr").as_str() {
        "fifo" => SchedPolicy::Fifo,
        "wdrr" => SchedPolicy::Wdrr,
        other => bail!("unknown policy {other:?} (try fifo|wdrr)"),
    };

    let shards: usize = args.num("shards", 1);
    let rt = Arc::new(ArtifactRuntime::open_default()?);
    let mut registry = ModelRegistry::new();
    for (i, ((name, replicas), weight)) in parts.iter().zip(&weights).enumerate() {
        let engine = Engine::with_runtime(rt.clone(), tag, ffn_mode_for(name)?, 42 + i as u64)?;
        registry.register_sharded(name, engine, *replicas, *weight, shards)?;
    }
    if let Some(spec) = args.get("degrade-to") {
        for link in spec.split(',').filter(|s| !s.is_empty()) {
            let Some((from, to)) = link.split_once('=') else {
                bail!("--degrade-to wants from=to entries, got {link:?}");
            };
            registry.set_degrade(from, to)?;
        }
    }
    let names: Vec<String> = parts.iter().map(|(name, _)| name.clone()).collect();
    let workers = registry.total_replicas();
    let cfg = ServeConfig {
        queue_cap: args.num("queue-cap", 256),
        max_wait,
        policy,
        slo,
        admission: args.flag("admission"),
        shed: args.flag("shed"),
        ..ServeConfig::default()
    };
    let server = ConcurrentServer::start_registry(registry, cfg)?;
    let seq = server.dims().seq;
    let vocab = server.dims().vocab as u32;
    let mut rng = Pcg64::seeded(11);
    for _ in 0..requests {
        let model = &names[rng.below(names.len() as u32) as usize];
        let toks: Vec<i32> = (0..seq).map(|_| rng.below(vocab) as i32).collect();
        match server.submit_to(model, &toks) {
            Ok(_) => {}
            // Admission rejections are an expected overload outcome, not a
            // CLI failure; the final report carries the counts.
            Err(SubmitError::Rejected { .. }) => {}
            Err(e) => return Err(e.into()),
        }
    }
    let report = server.finish()?;
    println!(
        "served {} requests across {} models on {workers} workers ({policy:?}) in {} batches; \
         {:.1} req/s wall; slo {:.1} ms; overall slo-miss {:.1}%; goodput {:.1} req/s; \
         shed/rejected/degraded {}/{}/{}",
        report.results.len(),
        names.len(),
        report.batches,
        report.wall_rps,
        slo.as_secs_f64() * 1e3,
        report.slo_miss.unwrap_or(0.0) * 100.0,
        report.goodput_rps,
        report.shed,
        report.rejected,
        report.degraded,
    );
    for m in &report.per_model {
        match m.metrics.latency {
            Some(lat) => println!(
                "  model {}: {} requests in {} batches; p50/p95/p99 {:.3}/{:.3}/{:.3} ms; \
                 slo-miss {:.1}%; queue high-water {}; shed/rejected/degraded {}/{}/{}",
                m.name,
                m.metrics.requests,
                m.metrics.batches,
                lat.p50 * 1e3,
                lat.p95 * 1e3,
                lat.p99 * 1e3,
                m.metrics.slo_miss.unwrap_or(0.0) * 100.0,
                m.queue_high_water,
                m.shed,
                m.rejected,
                m.degraded,
            ),
            None => println!(
                "  model {}: no traffic (shed/rejected/degraded {}/{}/{})",
                m.name, m.shed, m.rejected, m.degraded
            ),
        }
    }
    print_replica_timing(&report);
    print_shard_timing(&report);
    Ok(())
}

fn print_replica_timing(report: &ServeReport) {
    for (r, t) in report.replica_timing.iter().enumerate() {
        println!(
            "  replica {r}: execute {:.3}s, transfer {:.3}s, compile {:.3}s",
            t.secs("execute"),
            t.secs("transfer"),
            t.secs("compile"),
        );
    }
}

fn print_shard_timing(report: &ServeReport) {
    for st in &report.shard_timing {
        println!("  model {} ({}-way tensor-parallel):", st.model, st.shards);
        for (r, t) in st.per_rank.iter().enumerate() {
            println!(
                "    shard {r}: compute {:.3}s, collective {:.3}s, cpu {:.3}s",
                t.secs("compute"),
                t.secs("collective"),
                t.secs("cpu"),
            );
        }
    }
}

fn energy(args: &Args) -> Result<()> {
    let rows: usize = args.num("rows", 768);
    let cols: usize = args.num("cols", 3072);
    let mut rng = Pcg64::seeded(1);
    let w = DenseTensor::randn(&[rows, cols], &mut rng);
    println!("format\tsparsity\tenergy");
    for (n, m) in [(2usize, 4usize), (1, 4), (1, 10)] {
        let s = 1.0 - n as f32 / m as f32;
        println!("unstructured\t{s:.2}\t{:.4}", sten::energy::energy_unstructured(&w, s));
        println!("{n}:{m}\t{s:.2}\t{:.4}", sten::energy::energy_nm(&w, n, m));
        for g in [1usize, 4, 16] {
            println!("{n}:{m}:{g}\t{s:.2}\t{:.4}", sten::energy::energy_nmg(&w, n, m, g));
        }
        println!("blocked4x4\t{s:.2}\t{:.4}", sten::energy::energy_blocked(&w, s, 4, 4));
    }
    Ok(())
}

fn sparsify(_args: &Args) -> Result<()> {
    let spec = MlpSpec { input_dim: 64, hidden: vec![128, 128], classes: 10 };
    let mut rng = Pcg64::seeded(3);
    let params = spec.init(&mut rng);
    let model = spec.build_graph(&params);
    println!("dense model: {} params, {} bytes", model.num_params(), model.param_bytes());

    let mut sb = SparsityBuilder::new();
    for w in spec.prunable_weights() {
        sb.set_weight(&w, Box::new(GroupedNm { n: 2, m: 4, g: 4 }), Layout::Nmg);
    }
    let sparse = sb.get_sparse_model(model)?;
    println!("sparse model: {} params, {} bytes", sparse.num_params(), sparse.param_bytes());

    let d = sten::dispatch::global();
    let x = sten::formats::AnyTensor::Dense(DenseTensor::randn(&[8, 64], &mut rng));
    let y = sparse.forward(d, &[x])?;
    println!(
        "forward ok: {:?}; dispatch (hit, convert, fallback) = {:?}",
        y.shape(),
        d.stats.counts()
    );
    Ok(())
}
