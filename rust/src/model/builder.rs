//! `SparsityBuilder` (§3.4): sparsify an existing model by traced names.
//!
//! ```text
//! let mut sb = SparsityBuilder::new();
//! sb.set_weight("fc1.w", Box::new(GroupedNm{n:2, m:4, g:4}), Layout::Nmg);
//! sb.set_interm("gelu1", Box::new(RandomFraction::new(0.9, 0)), Layout::Masked,
//!               Box::new(KeepAll), Layout::Csr);
//! sb.set_weight_grad("fc1.w", OutputFormat::external(..., Layout::Csr));
//! let sparse = sb.get_sparse_model(model)?;
//! ```
//!
//! Weights are sparsified immediately (they exist ahead of time); intermediate
//! tensors are sparsified at runtime by attaching an output format to the
//! producing node — exactly the paper's split.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::dispatch::OutputFormat;
use crate::formats::Layout;
use crate::sparsify::{sparsifier_registry, Sparsifier};
use crate::tune::{Autotuner, TunePolicy};

use super::graph::GraphModel;

struct WeightMark {
    sparsifier: Box<dyn Sparsifier>,
    out: Layout,
}

/// Builder collecting sparsification marks, applied by
/// [`SparsityBuilder::get_sparse_model`].
#[derive(Default)]
pub struct SparsityBuilder {
    weights: BTreeMap<String, WeightMark>,
    interms: BTreeMap<String, OutputFormat>,
    weight_grads: BTreeMap<String, OutputFormat>,
    /// Weights whose storage layout the autotuner picks: name -> expected
    /// dense rhs columns of the consuming matmul (the cost model's N).
    autos: BTreeMap<String, usize>,
    tuner: Option<Autotuner>,
}

impl SparsityBuilder {
    /// Empty builder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Mark a weight for initial sparsification into `out` layout.
    pub fn set_weight(&mut self, name: &str, initial_sparsifier: Box<dyn Sparsifier>, out: Layout) {
        self.weights.insert(name.to_string(), WeightMark { sparsifier: initial_sparsifier, out });
    }

    /// Mark an intermediate tensor (by producing node name) with an output
    /// format: inline sparsifier -> tmp layout -> external sparsifier -> out.
    pub fn set_interm(
        &mut self,
        node: &str,
        inline: Box<dyn Sparsifier>,
        tmp: Layout,
        external: Box<dyn Sparsifier>,
        out: Layout,
    ) {
        self.interms.insert(node.to_string(), OutputFormat { inline, tmp, external, out });
    }

    /// Attach a gradient output format to a weight (used during training).
    pub fn set_weight_grad(&mut self, name: &str, fmt: OutputFormat) {
        self.weight_grads.insert(name.to_string(), fmt);
    }

    /// Let the autotuner pick the storage layout for a (possibly already
    /// sparsified) weight: [`SparsityBuilder::get_sparse_model`] scores every
    /// registered lossless `(format, kernel)` matmul candidate and re-stores
    /// the weight in the winner. `ncols` is the expected dense rhs column
    /// count of the consuming matmul (the cost model's N). Runs after
    /// explicit [`SparsityBuilder::set_weight`] marks, so the two compose:
    /// prune first, then pick the layout the pruned weight executes best in.
    pub fn set_weight_auto(&mut self, name: &str, ncols: usize) {
        self.autos.insert(name.to_string(), ncols);
    }

    /// Supply a pre-loaded autotuner (policy + decision cache) for
    /// [`SparsityBuilder::set_weight_auto`] marks. Defaults to a fresh
    /// cost-model tuner.
    pub fn set_tuner(&mut self, tuner: Autotuner) {
        self.tuner = Some(tuner);
    }

    /// Apply all marks, producing the sparse model. Errors on unknown traced
    /// names (catching typos early, like STen).
    pub fn get_sparse_model(self, mut model: GraphModel) -> Result<GraphModel> {
        let reg = sparsifier_registry();
        for (name, mark) in self.weights {
            let Some(w) = model.weights.get(&name) else {
                bail!(
                    "set_weight: unknown weight {name:?} (have {:?})",
                    model.weight_names()
                );
            };
            let sparse = reg.apply(mark.sparsifier.as_ref(), w, mark.out)?;
            model.weights.insert(name, sparse);
        }
        for (name, fmt) in self.interms {
            let Some(node) = model.nodes.iter_mut().find(|n| n.name == name) else {
                bail!(
                    "set_interm: unknown node {name:?} (have {:?})",
                    model.nodes.iter().map(|n| n.name.clone()).collect::<Vec<_>>()
                );
            };
            node.out_fmt = Some(fmt);
        }
        if !self.autos.is_empty() {
            let d = crate::dispatch::global();
            let mut tuner =
                self.tuner.unwrap_or_else(|| Autotuner::new(TunePolicy::CostModel));
            for (name, ncols) in self.autos {
                let Some(w) = model.weights.get(&name) else {
                    bail!(
                        "set_weight_auto: unknown weight {name:?} (have {:?})",
                        model.weight_names()
                    );
                };
                // Densify (lossless for every layout), score, re-store in
                // the winning layout. No n:m:g config here: the builder path
                // only reformats, never re-prunes.
                let dense = w.to_dense();
                let dec = tuner.choose(d, &dense, ncols, None)?;
                model.weights.insert(name, crate::tune::materialize(&dense, dec.layout, None)?);
            }
        }
        for (name, fmt) in self.weight_grads {
            if !model.weights.contains_key(&name) {
                bail!("set_weight_grad: unknown weight {name:?}");
            }
            model.weight_grad_fmts.insert(name, fmt);
        }
        Ok(model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::Dispatcher;
    use crate::formats::AnyTensor;
    use crate::model::graph::NodeInput;
    use crate::ops::OpKind;
    use crate::sparsify::{GroupedNm, KeepAll, RandomFraction, ScalarFraction};
    use crate::tensor::DenseTensor;
    use crate::util::rng::Pcg64;

    fn model() -> GraphModel {
        let mut rng = Pcg64::seeded(500);
        let mut m = GraphModel::new();
        m.add_weight("fc1.w", AnyTensor::Dense(DenseTensor::kaiming(&[8, 24], &mut rng)));
        m.add_weight("fc2.w", AnyTensor::Dense(DenseTensor::kaiming(&[24, 4], &mut rng)));
        m.add_node("fc1", OpKind::MatMul, vec![NodeInput::Input(0), NodeInput::Weight("fc1.w".into())]);
        m.add_node("gelu1", OpKind::Gelu, vec![NodeInput::Node("fc1".into())]);
        m.add_node("fc2", OpKind::MatMul, vec![NodeInput::Node("gelu1".into()), NodeInput::Weight("fc2.w".into())]);
        m
    }

    #[test]
    fn sparsifies_marked_weight() {
        let mut sb = SparsityBuilder::new();
        sb.set_weight("fc1.w", Box::new(ScalarFraction { fraction: 0.75 }), Layout::Csr);
        let sparse = sb.get_sparse_model(model()).unwrap();
        let w = &sparse.weights["fc1.w"];
        assert_eq!(w.layout(), Layout::Csr);
        assert_eq!(w.nnz(), 8 * 24 / 4);
        // Unmarked weight untouched.
        assert_eq!(sparse.weights["fc2.w"].layout(), Layout::Dense);
    }

    #[test]
    fn forward_still_works_after_sparsification() {
        let mut sb = SparsityBuilder::new();
        sb.set_weight("fc1.w", Box::new(ScalarFraction { fraction: 0.5 }), Layout::Csr);
        sb.set_interm(
            "gelu1",
            Box::new(RandomFraction::new(0.5, 7)),
            Layout::Masked,
            Box::new(KeepAll),
            Layout::Dense,
        );
        let sparse = sb.get_sparse_model(model()).unwrap();
        let d = Dispatcher::with_builtins();
        let mut rng = Pcg64::seeded(501);
        let x = AnyTensor::Dense(DenseTensor::randn(&[2, 8], &mut rng));
        let y = sparse.forward(&d, &[x]).unwrap();
        assert_eq!(y.shape(), &[2, 4]);
    }

    #[test]
    fn nmg_weight_with_structured_sparsifier() {
        let mut sb = SparsityBuilder::new();
        sb.set_weight("fc1.w", Box::new(GroupedNm { n: 2, m: 4, g: 2 }), Layout::Nmg);
        let sparse = sb.get_sparse_model(model()).unwrap();
        assert_eq!(sparse.weights["fc1.w"].layout(), Layout::Nmg);
    }

    #[test]
    fn auto_weight_picks_a_sparse_layout_for_pruned_weight() {
        // Prune fc1.w hard, then let the tuner pick its storage layout: at
        // 95% unstructured sparsity no cost model should keep it dense.
        let mut sb = SparsityBuilder::new();
        sb.set_weight("fc1.w", Box::new(ScalarFraction { fraction: 0.95 }), Layout::Csr);
        sb.set_weight_auto("fc1.w", 4);
        let sparse = sb.get_sparse_model(model()).unwrap();
        let w = &sparse.weights["fc1.w"];
        assert_ne!(w.layout(), Layout::Dense, "95% sparse weight must not stay dense");
        // The reformat is lossless: the forward still runs and shapes hold.
        let d = Dispatcher::with_builtins();
        let mut rng = Pcg64::seeded(502);
        let x = AnyTensor::Dense(DenseTensor::randn(&[2, 8], &mut rng));
        let y = sparse.forward(&d, &[x]).unwrap();
        assert_eq!(y.shape(), &[2, 4]);

        // Unknown names are rejected like every other mark.
        let mut sb = SparsityBuilder::new();
        sb.set_weight_auto("typo.w", 4);
        assert!(sb.get_sparse_model(model()).is_err());
    }

    #[test]
    fn unknown_names_rejected() {
        let mut sb = SparsityBuilder::new();
        sb.set_weight("typo.w", Box::new(KeepAll), Layout::Dense);
        let err = sb.get_sparse_model(model()).err().unwrap().to_string();
        assert!(err.contains("typo.w"), "{err}");

        let mut sb = SparsityBuilder::new();
        sb.set_interm("typo", Box::new(KeepAll), Layout::Dense, Box::new(KeepAll), Layout::Dense);
        assert!(sb.get_sparse_model(model()).is_err());

        let mut sb = SparsityBuilder::new();
        sb.set_weight_grad("typo.w", crate::dispatch::OutputFormat::dense());
        assert!(sb.get_sparse_model(model()).is_err());
    }

    #[test]
    fn weight_grad_fmt_recorded() {
        let mut sb = SparsityBuilder::new();
        sb.set_weight_grad(
            "fc1.w",
            crate::dispatch::OutputFormat::external(
                Box::new(ScalarFraction { fraction: 0.9 }),
                Layout::Csr,
            ),
        );
        let sparse = sb.get_sparse_model(model()).unwrap();
        assert!(sparse.weight_grad_fmts.contains_key("fc1.w"));
    }
}
