//! Traced computation graph: named operators over named weights.
//!
//! STen sparsifies *existing* models by tracing them (torch.fx) and marking
//! traced names (§4.1). [`GraphModel`] is that trace: a topologically-ordered
//! node list where every node has a stable name, an op, and inputs referring
//! to model inputs, previous nodes, or named weights. Execution routes every
//! node through a [`Dispatcher`], so sparsified weights automatically hit
//! sparse kernels and unsupported combinations fall back per §4.4.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::dispatch::{Dispatcher, OutputFormat};
use crate::formats::AnyTensor;
use crate::ops::OpKind;

/// Reference to a node input.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NodeInput {
    /// The i-th model input.
    Input(usize),
    /// Output of a previous node, by traced name.
    Node(String),
    /// A named weight.
    Weight(String),
}

/// One traced operator application.
pub struct GraphNode {
    /// Traced name (unique).
    pub name: String,
    /// The operator.
    pub op: OpKind,
    /// Inputs in argument order.
    pub inputs: Vec<NodeInput>,
    /// Output format (attached by `SparsityBuilder::set_interm`).
    pub out_fmt: Option<OutputFormat>,
}

/// A traced model: ordered nodes + named weights.
#[derive(Default)]
pub struct GraphModel {
    /// Topologically ordered nodes.
    pub nodes: Vec<GraphNode>,
    /// Named weights in any layout.
    pub weights: BTreeMap<String, AnyTensor>,
    /// Gradient output formats attached by `set_weight_grad`.
    pub weight_grad_fmts: BTreeMap<String, OutputFormat>,
}

impl GraphModel {
    /// Empty model.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a weight tensor.
    pub fn add_weight(&mut self, name: &str, w: AnyTensor) {
        self.weights.insert(name.to_string(), w);
    }

    /// Append a traced node.
    pub fn add_node(&mut self, name: &str, op: OpKind, inputs: Vec<NodeInput>) {
        assert!(
            !self.nodes.iter().any(|n| n.name == name),
            "duplicate node name {name}"
        );
        self.nodes.push(GraphNode { name: name.to_string(), op, inputs, out_fmt: None });
    }

    /// Traced names of all nodes (the names `SparsityBuilder` accepts).
    pub fn node_names(&self) -> Vec<&str> {
        self.nodes.iter().map(|n| n.name.as_str()).collect()
    }

    /// Traced names of all weights.
    pub fn weight_names(&self) -> Vec<&str> {
        self.weights.keys().map(|s| s.as_str()).collect()
    }

    /// Execute the graph; returns the output of the final node.
    pub fn forward(&self, dispatcher: &Dispatcher, inputs: &[AnyTensor]) -> Result<AnyTensor> {
        let mut env: BTreeMap<&str, AnyTensor> = BTreeMap::new();
        let mut last: Option<AnyTensor> = None;
        for node in &self.nodes {
            let args: Vec<AnyTensor> = node
                .inputs
                .iter()
                .map(|r| -> Result<AnyTensor> {
                    Ok(match r {
                        NodeInput::Input(i) => inputs
                            .get(*i)
                            .cloned()
                            .ok_or_else(|| anyhow!("missing model input {i}"))?,
                        NodeInput::Node(n) => env
                            .get(n.as_str())
                            .cloned()
                            .ok_or_else(|| anyhow!("node {n:?} not yet computed"))?,
                        NodeInput::Weight(w) => self
                            .weights
                            .get(w)
                            .cloned()
                            .ok_or_else(|| anyhow!("unknown weight {w:?}"))?,
                    })
                })
                .collect::<Result<_>>()?;
            let out = match &node.out_fmt {
                Some(fmt) => dispatcher.call_sparse(node.op, &args, fmt)?,
                None => dispatcher.call(node.op, &args)?,
            };
            env.insert(node.name.as_str(), out.clone());
            last = Some(out);
        }
        last.ok_or_else(|| bail_empty())
    }

    /// Total parameter count (dense-equivalent elements).
    pub fn num_params(&self) -> usize {
        self.weights.values().map(|w| w.shape().iter().product::<usize>()).sum()
    }

    /// Total parameter storage in bytes under current layouts.
    pub fn param_bytes(&self) -> usize {
        self.weights.values().map(|w| w.bytes()).sum()
    }
}

fn bail_empty() -> anyhow::Error {
    anyhow!("empty graph")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Layout;
    use crate::tensor::DenseTensor;
    use crate::util::rng::Pcg64;

    fn linear_graph() -> GraphModel {
        let mut rng = Pcg64::seeded(400);
        let mut m = GraphModel::new();
        m.add_weight("w", AnyTensor::Dense(DenseTensor::kaiming(&[4, 3], &mut rng)));
        m.add_weight("b", AnyTensor::Dense(DenseTensor::zeros(&[3])));
        m.add_node("fc", OpKind::MatMul, vec![NodeInput::Input(0), NodeInput::Weight("w".into())]);
        m.add_node("bias", OpKind::BiasAdd, vec![NodeInput::Node("fc".into()), NodeInput::Weight("b".into())]);
        m.add_node("act", OpKind::Relu, vec![NodeInput::Node("bias".into())]);
        m
    }

    #[test]
    fn forward_executes_topologically() {
        let m = linear_graph();
        let d = Dispatcher::with_builtins();
        let mut rng = Pcg64::seeded(401);
        let x = AnyTensor::Dense(DenseTensor::randn(&[2, 4], &mut rng));
        let y = m.forward(&d, &[x]).unwrap();
        assert_eq!(y.shape(), &[2, 3]);
        // ReLU output is non-negative.
        assert!(y.to_dense().data().iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn missing_weight_errors() {
        let mut m = linear_graph();
        m.add_node("bad", OpKind::MatMul, vec![NodeInput::Node("act".into()), NodeInput::Weight("nope".into())]);
        let d = Dispatcher::with_builtins();
        let x = AnyTensor::Dense(DenseTensor::ones(&[2, 4]));
        let err = m.forward(&d, &[x]).unwrap_err().to_string();
        assert!(err.contains("nope"), "{err}");
    }

    #[test]
    fn sparse_weight_dispatches_sparse_kernel() {
        let mut m = linear_graph();
        // Replace w with an n:m:g weight: (4,3) -> transpose story aside,
        // use a (4, 24) weight to satisfy chunking.
        let mut rng = Pcg64::seeded(402);
        let w = DenseTensor::randn(&[4, 24], &mut rng);
        m.weights.insert(
            "w".into(),
            AnyTensor::Nmg(crate::formats::NmgTensor::from_dense(&w, 2, 4, 2)),
        );
        // MatMul(x [2,4] ... shapes: x [2,4] @ w [4,24] — but Nmg matmul wants
        // Nmg lhs. Build a graph with the weight first: w^T x^T pattern is
        // what the FFN uses; here simply call MatMul(weight, input).
        let mut m2 = GraphModel::new();
        m2.weights.insert("w".into(), m.weights["w"].clone());
        m2.add_node("mm", OpKind::MatMul, vec![NodeInput::Weight("w".into()), NodeInput::Input(0)]);
        let d = Dispatcher::with_builtins();
        let x = AnyTensor::Dense(DenseTensor::randn(&[24, 5], &mut rng));
        let y = m2.forward(&d, &[x]).unwrap();
        assert_eq!(y.shape(), &[4, 5]);
        assert_eq!(d.stats.counts().0, 1, "expected exact Nmg kernel hit");
    }

    #[test]
    fn param_accounting() {
        let m = linear_graph();
        assert_eq!(m.num_params(), 4 * 3 + 3);
        assert_eq!(m.param_bytes(), (4 * 3 + 3) * 4);
        assert_eq!(m.node_names(), vec!["fc", "bias", "act"]);
        assert_eq!(m.weight_names(), vec!["b", "w"]);
    }

    #[test]
    #[should_panic(expected = "duplicate node name")]
    fn duplicate_names_rejected() {
        let mut m = linear_graph();
        m.add_node("fc", OpKind::Relu, vec![NodeInput::Input(0)]);
    }

    #[test]
    fn out_fmt_applies_to_node_output() {
        let mut m = linear_graph();
        m.nodes[2].out_fmt = Some(OutputFormat::external(
            Box::new(crate::sparsify::ScalarFraction { fraction: 0.5 }),
            Layout::Csr,
        ));
        let d = Dispatcher::with_builtins();
        let mut rng = Pcg64::seeded(403);
        let x = AnyTensor::Dense(DenseTensor::randn(&[2, 4], &mut rng));
        let y = m.forward(&d, &[x]).unwrap();
        assert_eq!(y.layout(), Layout::Csr);
    }
}
