//! MLP model: graph construction + tape-autograd training forward.
//!
//! The §6.2 productivity study fine-tunes a pruned vision model; our
//! substitute (see DESIGN.md §Substitutions) is an MLP classifier on a
//! synthetic CIFAR-shaped dataset. The same weight set powers both the
//! dispatcher-routed inference graph ([`MlpSpec::build_graph`]) and the
//! autograd training pass ([`MlpSpec::forward_tape`]).

use std::collections::BTreeMap;

use crate::autograd::{Tape, Var};
use crate::formats::AnyTensor;
use crate::ops::OpKind;
use crate::tensor::DenseTensor;
use crate::util::rng::Pcg64;

use super::graph::{GraphModel, NodeInput};

/// MLP hyperparameters.
#[derive(Debug, Clone)]
pub struct MlpSpec {
    /// Input feature dimension.
    pub input_dim: usize,
    /// Hidden layer widths.
    pub hidden: Vec<usize>,
    /// Number of classes.
    pub classes: usize,
}

impl MlpSpec {
    /// Layer dimensions as (in, out) pairs.
    pub fn layer_dims(&self) -> Vec<(usize, usize)> {
        let mut dims = Vec::new();
        let mut prev = self.input_dim;
        for &h in &self.hidden {
            dims.push((prev, h));
            prev = h;
        }
        dims.push((prev, self.classes));
        dims
    }

    /// Weight names in layer order: `fcN.w`, `fcN.b`.
    pub fn weight_names(&self) -> Vec<String> {
        (0..self.layer_dims().len())
            .flat_map(|i| [format!("fc{i}.w"), format!("fc{i}.b")])
            .collect()
    }

    /// Names of the 2-D (prunable) weights, layer order — the unit the
    /// layer-wise schedule walks (§6.2).
    pub fn prunable_weights(&self) -> Vec<String> {
        (0..self.layer_dims().len()).map(|i| format!("fc{i}.w")).collect()
    }

    /// Initialize dense parameters.
    pub fn init(&self, rng: &mut Pcg64) -> BTreeMap<String, DenseTensor> {
        let mut params = BTreeMap::new();
        for (i, (din, dout)) in self.layer_dims().into_iter().enumerate() {
            params.insert(format!("fc{i}.w"), DenseTensor::kaiming(&[din, dout], rng));
            params.insert(format!("fc{i}.b"), DenseTensor::zeros(&[dout]));
        }
        params
    }

    /// Build the dispatcher-routed inference graph from parameters.
    pub fn build_graph(&self, params: &BTreeMap<String, DenseTensor>) -> GraphModel {
        let mut m = GraphModel::new();
        for (name, w) in params {
            m.add_weight(name, AnyTensor::Dense(w.clone()));
        }
        let layers = self.layer_dims().len();
        let mut prev: Option<String> = None;
        for i in 0..layers {
            let x_ref = match &prev {
                None => NodeInput::Input(0),
                Some(p) => NodeInput::Node(p.clone()),
            };
            m.add_node(&format!("fc{i}"), OpKind::MatMul, vec![x_ref, NodeInput::Weight(format!("fc{i}.w"))]);
            m.add_node(
                &format!("bias{i}"),
                OpKind::BiasAdd,
                vec![NodeInput::Node(format!("fc{i}")), NodeInput::Weight(format!("fc{i}.b"))],
            );
            if i + 1 < layers {
                m.add_node(&format!("gelu{i}"), OpKind::Gelu, vec![NodeInput::Node(format!("bias{i}"))]);
                prev = Some(format!("gelu{i}"));
            } else {
                prev = Some(format!("bias{i}"));
            }
        }
        m
    }

    /// Tape forward: returns (logit var, param vars by name).
    pub fn forward_tape(
        &self,
        tape: &Tape,
        params: &BTreeMap<String, DenseTensor>,
        x: DenseTensor,
    ) -> (Var, BTreeMap<String, Var>) {
        let mut vars = BTreeMap::new();
        let mut h = tape.input(x);
        let layers = self.layer_dims().len();
        for i in 0..layers {
            let w = tape.param(params[&format!("fc{i}.w")].clone());
            let b = tape.param(params[&format!("fc{i}.b")].clone());
            vars.insert(format!("fc{i}.w"), w);
            vars.insert(format!("fc{i}.b"), b);
            h = tape.bias_add(tape.matmul(h, w), b);
            if i + 1 < layers {
                h = tape.gelu(h);
            }
        }
        (h, vars)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dispatch::Dispatcher;

    fn spec() -> MlpSpec {
        MlpSpec { input_dim: 12, hidden: vec![16, 8], classes: 4 }
    }

    #[test]
    fn layer_dims_chain() {
        assert_eq!(spec().layer_dims(), vec![(12, 16), (16, 8), (8, 4)]);
        assert_eq!(spec().prunable_weights(), vec!["fc0.w", "fc1.w", "fc2.w"]);
    }

    #[test]
    fn graph_and_tape_forward_agree() {
        let s = spec();
        let mut rng = Pcg64::seeded(600);
        let params = s.init(&mut rng);
        let x = DenseTensor::randn(&[3, 12], &mut rng);

        let graph = s.build_graph(&params);
        let d = Dispatcher::with_builtins();
        let y_graph = graph.forward(&d, &[AnyTensor::Dense(x.clone())]).unwrap().to_dense();

        let tape = Tape::new();
        let (logits, _) = s.forward_tape(&tape, &params, x);
        let y_tape = tape.value(logits);

        assert!(y_graph.allclose(&y_tape, 1e-4, 1e-4), "diff {}", y_graph.max_abs_diff(&y_tape));
        assert_eq!(y_graph.shape(), &[3, 4]);
    }

    #[test]
    fn training_reduces_loss() {
        let s = MlpSpec { input_dim: 8, hidden: vec![16], classes: 3 };
        let mut rng = Pcg64::seeded(601);
        let mut params = s.init(&mut rng);
        let x = DenseTensor::randn(&[12, 8], &mut rng);
        let labels: Vec<usize> = (0..12).map(|i| i % 3).collect();
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..30 {
            let tape = Tape::new();
            let (logits, vars) = s.forward_tape(&tape, &params, x.clone());
            let loss = tape.softmax_cross_entropy(logits, &labels);
            last = tape.value(loss).data()[0];
            first.get_or_insert(last);
            tape.backward(loss).unwrap();
            let pvars: Vec<_> = vars.values().copied().collect();
            tape.sgd_step(&pvars, 0.5);
            for (name, v) in &vars {
                params.insert(name.clone(), tape.value(*v));
            }
        }
        assert!(last < first.unwrap() * 0.5, "{} -> {last}", first.unwrap());
    }
}
