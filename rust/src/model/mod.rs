//! Model construction and sparsification (§3.4).
//!
//! * [`graph`] — a traced computation graph over named nodes and weights
//!   (the `torch.fx` analog): the substrate [`builder::SparsityBuilder`]
//!   marks tensors on.
//! * [`builder`] — `SparsityBuilder`: `set_weight` / `set_interm` /
//!   `set_weight_grad` / `get_sparse_model`, STen's model-sparsification API.
//! * [`mlp`] — an MLP over the graph plus a tape-autograd forward for
//!   training (the §6.2 productivity-study network).

pub mod graph;
pub mod builder;
pub mod mlp;

pub use builder::SparsityBuilder;
pub use graph::{GraphModel, GraphNode, NodeInput};
pub use mlp::MlpSpec;
