//! Operators (§3.2): functions over tensors with any sparsity layouts.
//!
//! [`OpKind`] enumerates the operator vocabulary; [`dense_reference`] gives
//! every operator a dense implementation — the universal fallback of §4.4.
//! Layout-specialized implementations are registered with the dispatcher
//! (see [`crate::dispatch`]); the default registrations live in
//! [`crate::dispatch::builtin`].

use anyhow::{bail, Result};

use crate::formats::AnyTensor;
use crate::kernels::{dense_gemm, elementwise};
use crate::tensor::DenseTensor;

/// Operator vocabulary.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpKind {
    /// C = A · B (2-D).
    MatMul,
    /// C = A + B (elementwise).
    Add,
    /// C = A ⊙ B (elementwise).
    Mul,
    /// ReLU.
    Relu,
    /// GeLU (tanh approximation).
    Gelu,
    /// Row-wise softmax (2-D).
    Softmax,
    /// Row-wise LayerNorm: inputs (x, gamma, beta).
    LayerNorm,
    /// Bias add: inputs (x 2-D, bias 1-D).
    BiasAdd,
    /// 2-D transpose.
    Transpose,
}

impl OpKind {
    /// Number of tensor inputs.
    pub fn arity(&self) -> usize {
        match self {
            OpKind::MatMul | OpKind::Add | OpKind::Mul | OpKind::BiasAdd => 2,
            OpKind::LayerNorm => 3,
            OpKind::Relu | OpKind::Gelu | OpKind::Softmax | OpKind::Transpose => 1,
        }
    }

    /// True for ops whose semantics are elementwise over the first input.
    pub fn elementwise(&self) -> bool {
        matches!(self, OpKind::Relu | OpKind::Gelu | OpKind::Add | OpKind::Mul)
    }
}

impl std::fmt::Display for OpKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

/// Dense reference semantics for every operator. All layout-specialized
/// implementations must agree with this (tested in `dispatch`).
pub fn dense_reference(op: OpKind, inputs: &[DenseTensor]) -> Result<DenseTensor> {
    if inputs.len() != op.arity() {
        bail!("{op}: expected {} inputs, got {}", op.arity(), inputs.len());
    }
    Ok(match op {
        OpKind::MatMul => dense_gemm::matmul(&inputs[0], &inputs[1]),
        OpKind::Add => inputs[0].zip(&inputs[1], |a, b| a + b),
        OpKind::Mul => inputs[0].zip(&inputs[1], |a, b| a * b),
        OpKind::Relu => elementwise::relu(&inputs[0]),
        OpKind::Gelu => elementwise::gelu(&inputs[0]),
        OpKind::Softmax => elementwise::softmax_rows(&inputs[0]),
        OpKind::LayerNorm => {
            elementwise::layernorm_rows(&inputs[0], inputs[1].data(), inputs[2].data())
        }
        OpKind::BiasAdd => elementwise::bias_add(&inputs[0], inputs[1].data()),
        OpKind::Transpose => inputs[0].transpose2(),
    })
}

/// Dense reference over [`AnyTensor`] operands (densifies, computes, wraps).
pub fn dense_reference_any(op: OpKind, inputs: &[AnyTensor]) -> Result<AnyTensor> {
    let dense: Vec<DenseTensor> = inputs.iter().map(|t| t.to_dense()).collect();
    Ok(AnyTensor::Dense(dense_reference(op, &dense)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn arity_checked() {
        let x = DenseTensor::ones(&[2, 2]);
        assert!(dense_reference(OpKind::Add, &[x]).is_err());
    }

    #[test]
    fn add_mul_elementwise() {
        let a = DenseTensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let b = DenseTensor::from_vec(&[3], vec![10.0, 20.0, 30.0]);
        assert_eq!(dense_reference(OpKind::Add, &[a.clone(), b.clone()]).unwrap().data(), &[11.0, 22.0, 33.0]);
        assert_eq!(dense_reference(OpKind::Mul, &[a, b]).unwrap().data(), &[10.0, 40.0, 90.0]);
    }

    #[test]
    fn matmul_shapes() {
        let mut rng = Pcg64::seeded(90);
        let a = DenseTensor::randn(&[3, 4], &mut rng);
        let b = DenseTensor::randn(&[4, 5], &mut rng);
        let c = dense_reference(OpKind::MatMul, &[a, b]).unwrap();
        assert_eq!(c.shape(), &[3, 5]);
    }

    #[test]
    fn transpose_reference() {
        let a = DenseTensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let t = dense_reference(OpKind::Transpose, &[a]).unwrap();
        assert_eq!(t.shape(), &[3, 2]);
        assert_eq!(t.get2(2, 1), 6.0);
    }

    #[test]
    fn op_metadata() {
        assert_eq!(OpKind::LayerNorm.arity(), 3);
        assert!(OpKind::Relu.elementwise());
        assert!(!OpKind::MatMul.elementwise());
        assert_eq!(OpKind::MatMul.to_string(), "MatMul");
    }
}
