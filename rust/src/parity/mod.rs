//! Cross-backend golden-vector parity harness.
//!
//! The scalar backend is the bit-identical reference for the whole stack;
//! this module turns that into a checkable contract. For every runtime
//! artifact it can (a) synthesize deterministic inputs, (b) generate a
//! golden vector by running the artifact on the **forced scalar** backend,
//! and (c) replay the golden inputs under any backend and compare against
//! the recorded outputs within the per-seam tolerance from [`SEAMS`].
//!
//! Golden files use the `aot.py` interchange format (inputs then outputs in
//! manifest order, little-endian f32/i32), so a cross-language golden
//! shipped beside the artifacts (`make artifacts`) is preferred verbatim;
//! only when it is absent does [`ensure_golden`] generate a hermetic one
//! under [`golden_dir`] (`target/goldens`, override with `STEN_GOLDENS`).
//! Generation is deterministic (inputs are seeded from the artifact name,
//! the scalar backend is forced for the reference call), so concurrent test
//! binaries racing on the same golden write byte-identical files; the
//! tmp-write + rename keeps readers from ever seeing a partial file.
//!
//! Consumers: `tests/backend_parity.rs` (the scalar-vs-SIMD sweep),
//! `tests/pipeline_integration.rs` (the un-skipped golden path), and the
//! benches' pre-timing allclose asserts.

use crate::formats::nmg::NmgTensor;
use crate::kernels::backend::{self, Backend};
use crate::runtime::{ArtifactRuntime, DType, Json, Value};
use crate::tensor::DenseTensor;
use crate::util::rng::Pcg64;
use crate::Result;
use anyhow::{anyhow, bail, Context};
use std::path::{Path, PathBuf};

/// Tolerance contract for one family of runtime artifacts.
#[derive(Debug, Clone, Copy)]
pub struct Seam {
    /// Artifact-name prefix this seam covers.
    pub prefix: &'static str,
    /// Relative tolerance for cross-backend comparison.
    pub rtol: f32,
    /// Absolute tolerance for cross-backend comparison.
    pub atol: f32,
    /// Whether the SIMD backend must reproduce the scalar outputs
    /// bit-for-bit (gather/add-only seams with no reassociation).
    pub bit_identical: bool,
}

/// Per-seam parity tolerances, matched by prefix in order (more specific
/// prefixes first: `ffn_block_nmg_` must precede `ffn_block_`). Tolerances
/// mirror the historical golden-vector bounds in
/// `tests/pipeline_integration.rs`.
pub const SEAMS: &[Seam] = &[
    // Embedding is a pure gather + add: no dot products, no reassociation.
    Seam { prefix: "embed_", rtol: 1e-5, atol: 1e-5, bit_identical: true },
    Seam { prefix: "gemm_dense_", rtol: 1e-4, atol: 1e-4, bit_identical: false },
    Seam { prefix: "gemm_masked_", rtol: 1e-4, atol: 1e-4, bit_identical: false },
    Seam { prefix: "gemm_nmg_", rtol: 1e-4, atol: 1e-4, bit_identical: false },
    Seam { prefix: "ffn_block_nmg_", rtol: 1e-3, atol: 1e-3, bit_identical: false },
    Seam { prefix: "attn_block_", rtol: 1e-3, atol: 1e-3, bit_identical: false },
    Seam { prefix: "ffn_block_", rtol: 1e-3, atol: 1e-3, bit_identical: false },
    Seam { prefix: "lm_head_", rtol: 1e-3, atol: 1e-3, bit_identical: false },
    Seam { prefix: "encoder_fwd_", rtol: 1e-2, atol: 1e-2, bit_identical: false },
    Seam { prefix: "train_step_", rtol: 1e-2, atol: 1e-2, bit_identical: false },
];

/// Catch-all for artifacts without a dedicated seam entry.
const DEFAULT_SEAM: Seam =
    Seam { prefix: "", rtol: 1e-4, atol: 1e-4, bit_identical: false };

/// The tolerance contract governing `name` (first matching prefix wins).
pub fn seam_for(name: &str) -> Seam {
    SEAMS.iter().copied().find(|s| name.starts_with(s.prefix)).unwrap_or(DEFAULT_SEAM)
}

/// Directory for generated golden vectors: `STEN_GOLDENS` if set, else
/// `target/goldens` under the crate root (hermetic, wiped by `cargo clean`).
pub fn golden_dir() -> PathBuf {
    if let Some(d) = std::env::var_os("STEN_GOLDENS") {
        return PathBuf::from(d);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("target").join("goldens")
}

/// FNV-1a of the artifact name — the deterministic per-artifact RNG seed.
fn name_seed(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn meta_usize(meta: &Json, key: &str) -> Result<usize> {
    meta.get(key).ok_or_else(|| anyhow!("missing meta.{key}"))?.usize()
}

/// Deterministic inputs for `name`, valid against its manifest spec.
///
/// n:m:g artifacts get a *consistent* `(val, idx)` pair converted from a
/// random dense weight via [`NmgTensor::from_dense`] (independent random
/// val/idx would not describe any real tensor, and the runtime validates
/// idx bounds). Token inputs are drawn below the vocab from the spec meta;
/// gains (`*_g`) are ones, masks are Bernoulli(0.5) in {0, 1}, 2-D weights
/// are He-scaled, everything else is small Gaussian.
pub fn synth_inputs(rt: &ArtifactRuntime, name: &str) -> Result<Vec<Value>> {
    let spec = rt.spec(name).with_context(|| format!("synth_inputs({name})"))?.clone();
    let mut rng = Pcg64::seeded(name_seed(name));

    // A consistent n:m:g (val, idx) pair for the sparse-weight artifacts.
    let nmg_meta = if name.starts_with("gemm_nmg_") {
        Some(&spec.meta)
    } else if name.starts_with("ffn_block_nmg_") {
        Some(spec.meta.get("nmg").ok_or_else(|| anyhow!("{name}: missing meta.nmg"))?)
    } else {
        None
    };
    let sparse = match nmg_meta {
        Some(meta) => {
            let (m, n, g) = (
                meta_usize(meta, "m")?,
                meta_usize(meta, "n")?,
                meta_usize(meta, "g")?,
            );
            let (rows, k) = (meta_usize(meta, "M")?, meta_usize(meta, "K")?);
            let mut w = DenseTensor::randn(&[rows, k], &mut rng);
            w.scale((2.0 / rows as f32).sqrt());
            Some(NmgTensor::from_dense(&w, n, m, g))
        }
        None => None,
    };

    let vocab = spec.meta.get("vocab").and_then(|j| j.usize().ok()).unwrap_or(16) as u32;
    let mut inputs = Vec::with_capacity(spec.inputs.len());
    for io in &spec.inputs {
        let v = match (io.dtype, io.name.as_str()) {
            (DType::I32, "idx") if sparse.is_some() => {
                let s = sparse.as_ref().unwrap();
                Value::I32(io.shape.clone(), s.idx_flat().iter().map(|&i| i as i32).collect())
            }
            (DType::I32, _) => Value::I32(
                io.shape.clone(),
                (0..io.numel()).map(|_| rng.below(vocab) as i32).collect(),
            ),
            (DType::F32, "val") if sparse.is_some() => Value::from(DenseTensor::from_vec(
                &io.shape,
                sparse.as_ref().unwrap().val_flat().to_vec(),
            )),
            (DType::F32, "lr") => {
                Value::from(DenseTensor::from_vec(&io.shape, vec![0.05; io.numel()]))
            }
            (DType::F32, n) if n == "mask" || n.starts_with("mask.") => {
                Value::from(DenseTensor::from_vec(
                    &io.shape,
                    (0..io.numel())
                        .map(|_| if rng.next_f32() < 0.5 { 1.0 } else { 0.0 })
                        .collect(),
                ))
            }
            (DType::F32, n) if n.ends_with("_g") => Value::from(DenseTensor::ones(&io.shape)),
            (DType::F32, _) if io.shape.len() == 2 => {
                let mut w = DenseTensor::randn(&io.shape, &mut rng);
                w.scale((2.0 / io.shape[0] as f32).sqrt());
                Value::from(w)
            }
            (DType::F32, _) => {
                let mut t = DenseTensor::randn(&io.shape, &mut rng);
                if io.shape.len() == 1 {
                    t.scale(0.05); // bias-scale 1-D params
                }
                Value::from(t)
            }
        };
        inputs.push(v);
    }
    Ok(inputs)
}

fn push_value_bytes(v: &Value, out: &mut Vec<u8>) {
    match v {
        Value::F32(t) => {
            for x in t.data() {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        Value::I32(_, ints) => {
            for x in ints {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
}

/// Path to a golden vector for `name`, generating one if needed.
///
/// A cross-language golden in the artifact directory wins (it pins the
/// jax-computed outputs). Otherwise the golden is produced hermetically:
/// deterministic inputs from [`synth_inputs`], outputs from the **forced
/// scalar** backend (the reference numerics), written into [`golden_dir`]
/// via tmp + atomic rename.
///
/// Never call this while holding a [`backend::ForceGuard`] — the guard's
/// lock is not reentrant and generation takes it internally.
pub fn ensure_golden(rt: &ArtifactRuntime, name: &str) -> Result<PathBuf> {
    let shipped = rt.dir().join(format!("{name}.golden.bin"));
    if shipped.is_file() {
        return Ok(shipped);
    }
    let dir = golden_dir();
    let path = dir.join(format!("{name}.golden.bin"));
    if path.is_file() {
        return Ok(path);
    }
    std::fs::create_dir_all(&dir).with_context(|| format!("mkdir {}", dir.display()))?;

    let inputs = synth_inputs(rt, name)?;
    // The force guard doubles as the in-process writer lock: threads racing
    // on the same golden (the tmp name is only pid-unique) serialize here,
    // and the re-check turns every loser into a plain reader. Racing
    // *processes* interleave safely anyway — deterministic inputs + the
    // forced scalar call make both writers produce byte-identical files,
    // and the rename is atomic.
    let _scalar = backend::force(Backend::Scalar);
    if path.is_file() {
        return Ok(path);
    }
    let outputs =
        rt.call(name, &inputs).with_context(|| format!("golden generation for {name}"))?;
    let mut bytes = Vec::new();
    for v in inputs.iter().chain(outputs.iter()) {
        push_value_bytes(v, &mut bytes);
    }
    let tmp = dir.join(format!("{name}.golden.bin.{}.tmp", std::process::id()));
    std::fs::write(&tmp, &bytes).with_context(|| format!("write {}", tmp.display()))?;
    std::fs::rename(&tmp, &path).with_context(|| format!("rename to {}", path.display()))?;
    Ok(path)
}

/// Parse a golden file: inputs then outputs, manifest order, little-endian.
pub fn load_golden(
    rt: &ArtifactRuntime,
    name: &str,
    path: &Path,
) -> Result<(Vec<Value>, Vec<DenseTensor>)> {
    let spec = rt.spec(name)?.clone();
    let bytes =
        std::fs::read(path).with_context(|| format!("golden for {name} at {}", path.display()))?;
    let mut off = 0usize;
    let mut take = |n: usize| -> Result<&[u8]> {
        let end = off + 4 * n;
        if end > bytes.len() {
            bail!("golden for {name} truncated at byte {end} (file has {})", bytes.len());
        }
        let s = &bytes[off..end];
        off = end;
        Ok(s)
    };
    let mut inputs = Vec::new();
    for io in &spec.inputs {
        let raw = take(io.numel())?;
        match io.dtype {
            DType::F32 => {
                let f: Vec<f32> = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                inputs.push(Value::from(DenseTensor::from_vec(&io.shape, f)));
            }
            DType::I32 => {
                let ints: Vec<i32> = raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                inputs.push(Value::I32(io.shape.clone(), ints));
            }
        }
    }
    let mut outputs = Vec::new();
    for io in &spec.outputs {
        let raw = take(io.numel())?;
        let f: Vec<f32> =
            raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();
        outputs.push(DenseTensor::from_vec(&io.shape, f));
    }
    if off != bytes.len() {
        bail!("golden for {name}: {} trailing bytes", bytes.len() - off);
    }
    Ok((inputs, outputs))
}

/// Replay the golden inputs for `name` under the *ambient* backend and
/// compare against the golden outputs within the seam tolerance. Callers
/// choose the backend with [`backend::force`] (take the guard **after**
/// this has generated the golden, or call [`ensure_golden`] first).
pub fn verify_artifact(rt: &ArtifactRuntime, name: &str) -> Result<()> {
    let path = ensure_golden(rt, name)?;
    let (inputs, want) = load_golden(rt, name, &path)?;
    let got = rt.call(name, &inputs)?;
    if got.len() != want.len() {
        bail!("{name}: {} outputs, golden has {}", got.len(), want.len());
    }
    let seam = seam_for(name);
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        let g = g.as_f32().with_context(|| format!("{name} output {i}"))?;
        if !g.allclose(w, seam.rtol, seam.atol) {
            bail!(
                "{name} output {i} diverges from golden: max diff {} (rtol {}, atol {})",
                g.max_abs_diff(w),
                seam.rtol,
                seam.atol
            );
        }
    }
    Ok(())
}

/// Artifacts covered by the default parity sweep: every builtin-manifest
/// artifact with a deterministic single-call contract. `train_step_*` is
/// excluded — it is exercised through its own integration tests and its
/// looped optimizer updates amplify benign cross-backend rounding.
pub fn sweep_artifacts(rt: &ArtifactRuntime) -> Vec<String> {
    let mut names: Vec<String> = rt
        .manifest()
        .names()
        .into_iter()
        .filter(|n| !n.starts_with("train_step_"))
        .map(|n| n.to_string())
        .collect();
    names.sort();
    names
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seam_prefix_order_is_specific_first() {
        // The nmg ffn seam must win over the generic ffn prefix.
        assert_eq!(seam_for("ffn_block_nmg_tiny").prefix, "ffn_block_nmg_");
        assert_eq!(seam_for("ffn_block_tiny").prefix, "ffn_block_");
        assert!(seam_for("embed_tiny").bit_identical);
        assert!(!seam_for("encoder_fwd_base").bit_identical);
        // Unknown artifacts fall back to the strict default.
        assert_eq!(seam_for("mystery_op").rtol, 1e-4);
    }

    #[test]
    fn name_seed_is_stable_and_distinct() {
        assert_eq!(name_seed("gemm_dense_8x48x16"), name_seed("gemm_dense_8x48x16"));
        assert_ne!(name_seed("gemm_dense_8x48x16"), name_seed("gemm_dense_64x192x128"));
    }

    #[test]
    fn synth_inputs_match_spec_shapes() {
        let rt = ArtifactRuntime::open_default().unwrap();
        for name in sweep_artifacts(&rt) {
            let spec = rt.spec(&name).unwrap().clone();
            let inputs = synth_inputs(&rt, &name).unwrap();
            assert_eq!(inputs.len(), spec.inputs.len(), "{name}");
            for (io, v) in spec.inputs.iter().zip(&inputs) {
                let numel = match v {
                    Value::F32(t) => t.numel(),
                    Value::I32(_, d) => d.len(),
                };
                assert_eq!(numel, io.numel(), "{name} input {}", io.name);
            }
            // The inputs must actually be callable (validates dtypes,
            // nmg idx bounds, token ranges...).
            rt.call(&name, &inputs).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }
}
