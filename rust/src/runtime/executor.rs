//! The PJRT executor: compile-once, execute-many artifact runtime.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactSpec, DType, Manifest};
use crate::tensor::DenseTensor;
use crate::util::timer::TimeBreakdown;

/// A typed host value crossing the Rust <-> PJRT boundary.
#[derive(Debug, Clone)]
pub enum Value {
    /// Dense float tensor.
    F32(DenseTensor),
    /// Integer tensor (tokens, indices) with explicit shape.
    I32(Vec<usize>, Vec<i32>),
}

impl Value {
    /// Shape of the value.
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => t.shape(),
            Value::I32(shape, _) => shape,
        }
    }

    /// Dtype tag matching the manifest.
    pub fn dtype(&self) -> DType {
        match self {
            Value::F32(_) => DType::F32,
            Value::I32(..) => DType::I32,
        }
    }

    /// Unwrap as a float tensor.
    pub fn into_f32(self) -> Result<DenseTensor> {
        match self {
            Value::F32(t) => Ok(t),
            other => bail!("expected f32 value, got {:?}", other.dtype()),
        }
    }

    /// Borrow as a float tensor.
    pub fn as_f32(&self) -> Result<&DenseTensor> {
        match self {
            Value::F32(t) => Ok(t),
            other => bail!("expected f32 value, got {:?}", other.dtype()),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Value::F32(t) => {
                if dims.is_empty() {
                    xla::Literal::scalar(t.data()[0])
                } else {
                    xla::Literal::vec1(t.data()).reshape(&dims)?
                }
            }
            Value::I32(_, data) => {
                if dims.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    xla::Literal::vec1(data).reshape(&dims)?
                }
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal, dtype: DType, shape: &[usize]) -> Result<Value> {
        Ok(match dtype {
            DType::F32 => Value::F32(DenseTensor::from_vec(shape, lit.to_vec::<f32>()?)),
            DType::I32 => Value::I32(shape.to_vec(), lit.to_vec::<i32>()?),
        })
    }
}

impl From<DenseTensor> for Value {
    fn from(t: DenseTensor) -> Self {
        Value::F32(t)
    }
}

/// Compile-once, execute-many runtime over the artifacts directory.
///
/// Executables are compiled lazily on first call and cached. All timing is
/// recorded in a [`TimeBreakdown`] under `compile` / `execute` / `transfer`
/// buckets, which the coordinator folds into the Fig. 11 latency breakdown.
pub struct ArtifactRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    times: Mutex<TimeBreakdown>,
}

impl ArtifactRuntime {
    /// Open the default artifacts directory (`artifacts/` or `$STEN_ARTIFACTS`).
    pub fn open_default() -> Result<Self> {
        Self::open(super::default_artifacts_dir())
    }

    /// Open a specific artifacts directory.
    pub fn open(dir: PathBuf) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(ArtifactRuntime {
            client,
            dir,
            manifest,
            cache: Mutex::new(HashMap::new()),
            times: Mutex::new(TimeBreakdown::new()),
        })
    }

    /// The manifest describing all artifacts.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Artifact spec lookup.
    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.get(name)
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.get(name)?;
        let path = self.dir.join(&spec.file);
        let t = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?,
        );
        self.times.lock().unwrap().add("compile", t.elapsed());
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with typed, shape-checked inputs.
    pub fn call(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let spec = self.manifest.get(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (v, io) in inputs.iter().zip(&spec.inputs) {
            if v.shape() != io.shape.as_slice() || v.dtype() != io.dtype {
                bail!(
                    "artifact {name}: input {:?} expects {:?} {:?}, got {:?} {:?}",
                    io.name,
                    io.dtype,
                    io.shape,
                    v.dtype(),
                    v.shape()
                );
            }
        }
        let exe = self.load(name)?;

        let t = Instant::now();
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|v| v.to_literal()).collect::<Result<_>>()?;
        self.times.lock().unwrap().add("transfer", t.elapsed());

        let t = Instant::now();
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        self.times.lock().unwrap().add("execute", t.elapsed());

        let t = Instant::now();
        // aot.py lowers with return_tuple=True: the result is always a tuple.
        let parts = result.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact {name}: expected {} outputs, got {}",
                spec.outputs.len(),
                parts.len()
            );
        }
        let out = parts
            .iter()
            .zip(&spec.outputs)
            .map(|(lit, io)| Value::from_literal(lit, io.dtype, &io.shape))
            .collect::<Result<Vec<_>>>()?;
        self.times.lock().unwrap().add("transfer", t.elapsed());
        Ok(out)
    }

    /// Convenience: call and unwrap a single f32 output.
    pub fn call1(&self, name: &str, inputs: &[Value]) -> Result<DenseTensor> {
        let mut out = self.call(name, inputs)?;
        if out.len() != 1 {
            bail!("artifact {name} returned {} outputs, expected 1", out.len());
        }
        out.remove(0).into_f32()
    }

    /// Snapshot of accumulated timing.
    pub fn timing(&self) -> TimeBreakdown {
        self.times.lock().unwrap().clone()
    }

    /// Reset accumulated timing.
    pub fn reset_timing(&self) {
        *self.times.lock().unwrap() = TimeBreakdown::new();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_shape_dtype_roundtrip() {
        let v = Value::F32(DenseTensor::zeros(&[2, 3]));
        assert_eq!(v.shape(), &[2, 3]);
        assert_eq!(v.dtype(), DType::F32);
        let v = Value::I32(vec![4], vec![1, 2, 3, 4]);
        assert_eq!(v.shape(), &[4]);
        assert_eq!(v.dtype(), DType::I32);
        assert!(v.into_f32().is_err());
    }

    #[test]
    fn f32_literal_roundtrip() {
        let t = DenseTensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = Value::F32(t.clone()).to_literal().unwrap();
        let back = Value::from_literal(&lit, DType::F32, &[2, 2]).unwrap();
        assert_eq!(back.into_f32().unwrap(), t);
    }

    #[test]
    fn i32_literal_roundtrip() {
        let v = Value::I32(vec![3], vec![7, -1, 9]);
        let lit = v.to_literal().unwrap();
        let back = Value::from_literal(&lit, DType::I32, &[3]).unwrap();
        match back {
            Value::I32(shape, data) => {
                assert_eq!(shape, vec![3]);
                assert_eq!(data, vec![7, -1, 9]);
            }
            _ => panic!("wrong dtype"),
        }
    }

    #[test]
    fn scalar_literal_roundtrip() {
        let t = DenseTensor::from_vec(&[], vec![2.5]);
        let lit = Value::F32(t).to_literal().unwrap();
        let back = Value::from_literal(&lit, DType::F32, &[]).unwrap();
        assert_eq!(back.into_f32().unwrap().data(), &[2.5]);
    }
}
