//! The artifact executor: prepare-once, execute-many runtime.
//!
//! Executes manifest-described artifacts through the [`super::native`]
//! backend — pure-Rust implementations of each artifact's semantics, driven
//! entirely by the manifest so shapes are never hard-coded. The original
//! PJRT path (`xla::PjRtClient` over HLO text from `make artifacts`) needs
//! the `xla` crate from the full vendor set; restoring it as a second
//! backend behind a cargo feature is tracked in ROADMAP.md. The timing
//! contract is unchanged: `compile` (one-time artifact preparation),
//! `execute` (kernel time) and `transfer` (validation + host marshalling)
//! buckets feed the coordinator's Fig. 11 latency breakdown.

use std::collections::HashSet;
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::manifest::{ArtifactSpec, DType, Manifest};
use super::native;
use crate::tensor::DenseTensor;
use crate::util::timer::TimeBreakdown;

/// A typed host value crossing the Rust <-> runtime boundary.
#[derive(Debug, Clone)]
pub enum Value {
    /// Dense float tensor.
    F32(DenseTensor),
    /// Integer tensor (tokens, indices) with explicit shape.
    I32(Vec<usize>, Vec<i32>),
}

impl Value {
    /// Shape of the value.
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => t.shape(),
            Value::I32(shape, _) => shape,
        }
    }

    /// Dtype tag matching the manifest.
    pub fn dtype(&self) -> DType {
        match self {
            Value::F32(_) => DType::F32,
            Value::I32(..) => DType::I32,
        }
    }

    /// Unwrap as a float tensor.
    pub fn into_f32(self) -> Result<DenseTensor> {
        match self {
            Value::F32(t) => Ok(t),
            other => bail!("expected f32 value, got {:?}", other.dtype()),
        }
    }

    /// Borrow as a float tensor.
    pub fn as_f32(&self) -> Result<&DenseTensor> {
        match self {
            Value::F32(t) => Ok(t),
            other => bail!("expected f32 value, got {:?}", other.dtype()),
        }
    }
}

impl From<DenseTensor> for Value {
    fn from(t: DenseTensor) -> Self {
        Value::F32(t)
    }
}

/// Prepare-once, execute-many runtime over the artifacts directory.
///
/// When `<dir>/manifest.json` exists it is loaded (so real AOT artifact
/// sets keep driving shapes and metadata); otherwise the built-in manifest
/// mirroring `aot.py`'s output is synthesized and the runtime is fully
/// hermetic. All methods take `&self`: the runtime is shared across engine
/// replicas behind an `Arc` by the serving layer.
pub struct ArtifactRuntime {
    dir: PathBuf,
    manifest: Manifest,
    prepared: Mutex<HashSet<String>>,
    times: Mutex<TimeBreakdown>,
}

/// Clamp a measured duration away from zero so timing buckets are always
/// strictly positive once touched (coarse clocks can round tiny spans to 0).
fn nonzero(d: Duration) -> Duration {
    d.max(Duration::from_nanos(1))
}

impl ArtifactRuntime {
    /// Open the default artifacts directory (`artifacts/` or `$STEN_ARTIFACTS`).
    /// An explicitly-set `STEN_ARTIFACTS` must point at real artifacts: a
    /// missing manifest there is an error, never a silent built-in fallback.
    pub fn open_default() -> Result<Self> {
        let dir = super::default_artifacts_dir();
        if std::env::var_os("STEN_ARTIFACTS").is_some() {
            let manifest = Manifest::load(&dir)?;
            return Ok(Self::with_manifest(dir, manifest));
        }
        Self::open(dir)
    }

    /// Open a specific artifacts directory. A *nonexistent* directory means
    /// "no AOT artifacts": the built-in manifest is synthesized and the run
    /// is fully hermetic. A directory that exists but lacks `manifest.json`
    /// is a half-configured artifact set and fails loudly instead.
    pub fn open(dir: PathBuf) -> Result<Self> {
        let manifest = if dir.join("manifest.json").is_file() {
            Manifest::load(&dir)?
        } else if dir.is_dir() {
            bail!(
                "artifacts directory {dir:?} exists but has no manifest.json; \
                 run `make artifacts` (or remove the directory to use the \
                 built-in native manifest)"
            )
        } else {
            native::builtin_manifest()
        };
        Ok(Self::with_manifest(dir, manifest))
    }

    fn with_manifest(dir: PathBuf, manifest: Manifest) -> Self {
        ArtifactRuntime {
            dir,
            manifest,
            prepared: Mutex::new(HashSet::new()),
            times: Mutex::new(TimeBreakdown::new()),
        }
    }

    /// The artifacts directory this runtime was opened over.
    pub fn dir(&self) -> &PathBuf {
        &self.dir
    }

    /// The manifest describing all artifacts.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Artifact spec lookup.
    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.get(name)
    }

    /// Prepare an artifact (validated once per runtime, charged to the
    /// `compile` bucket — the PJRT-compile analog). The prepared-set lock is
    /// held across the check and the preparation so concurrent replicas
    /// hitting one artifact for the first time charge compile exactly once.
    pub fn load(&self, name: &str) -> Result<&ArtifactSpec> {
        let spec = self.manifest.get(name)?;
        let mut prepared = self.prepared.lock().unwrap();
        if !prepared.contains(name) {
            let t = Instant::now();
            native::prepare(spec)?;
            self.times.lock().unwrap().add("compile", nonzero(t.elapsed()));
            prepared.insert(name.to_string());
        }
        Ok(spec)
    }

    /// Execute an artifact with typed, shape-checked inputs.
    pub fn call(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let spec = self.load(name)?;
        let t = Instant::now();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (v, io) in inputs.iter().zip(&spec.inputs) {
            if v.shape() != io.shape.as_slice() || v.dtype() != io.dtype {
                bail!(
                    "artifact {name}: input {:?} expects {:?} {:?}, got {:?} {:?}",
                    io.name,
                    io.dtype,
                    io.shape,
                    v.dtype(),
                    v.shape()
                );
            }
        }
        self.times.lock().unwrap().add("transfer", nonzero(t.elapsed()));

        let t = Instant::now();
        let out = native::execute(spec, inputs)?;
        self.times.lock().unwrap().add("execute", nonzero(t.elapsed()));

        let t = Instant::now();
        if out.len() != spec.outputs.len() {
            bail!(
                "artifact {name}: expected {} outputs, got {}",
                spec.outputs.len(),
                out.len()
            );
        }
        for (v, io) in out.iter().zip(&spec.outputs) {
            if v.shape() != io.shape.as_slice() || v.dtype() != io.dtype {
                bail!(
                    "artifact {name}: output expects {:?} {:?}, produced {:?} {:?}",
                    io.dtype,
                    io.shape,
                    v.dtype(),
                    v.shape()
                );
            }
        }
        self.times.lock().unwrap().add("transfer", nonzero(t.elapsed()));
        Ok(out)
    }

    /// Convenience: call and unwrap a single f32 output.
    pub fn call1(&self, name: &str, inputs: &[Value]) -> Result<DenseTensor> {
        let mut out = self.call(name, inputs)?;
        if out.len() != 1 {
            bail!("artifact {name} returned {} outputs, expected 1", out.len());
        }
        out.remove(0).into_f32()
    }

    /// Snapshot of accumulated timing.
    pub fn timing(&self) -> TimeBreakdown {
        self.times.lock().unwrap().clone()
    }

    /// Reset accumulated timing.
    pub fn reset_timing(&self) {
        *self.times.lock().unwrap() = TimeBreakdown::new();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_gemm;
    use crate::util::rng::Pcg64;

    fn runtime() -> ArtifactRuntime {
        // A directory without manifest.json -> built-in manifest.
        ArtifactRuntime::open(PathBuf::from("target/nonexistent-artifacts")).unwrap()
    }

    #[test]
    fn value_shape_dtype_roundtrip() {
        let v = Value::F32(DenseTensor::zeros(&[2, 3]));
        assert_eq!(v.shape(), &[2, 3]);
        assert_eq!(v.dtype(), DType::F32);
        let v = Value::I32(vec![4], vec![1, 2, 3, 4]);
        assert_eq!(v.shape(), &[4]);
        assert_eq!(v.dtype(), DType::I32);
        assert!(v.into_f32().is_err());
    }

    #[test]
    fn builtin_gemm_matches_reference() {
        let rt = runtime();
        let mut rng = Pcg64::seeded(1);
        let a = DenseTensor::randn(&[8, 48], &mut rng);
        let b = DenseTensor::randn(&[48, 16], &mut rng);
        let got = rt.call1("gemm_dense_8x48x16", &[a.clone().into(), b.clone().into()]).unwrap();
        let want = dense_gemm::matmul_naive(&a, &b);
        assert!(got.allclose(&want, 1e-4, 1e-4), "max diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn call_rejects_wrong_arity_and_shape() {
        let rt = runtime();
        let a = DenseTensor::zeros(&[2, 2]);
        let err = rt.call("gemm_dense_8x48x16", &[a.clone().into()]).unwrap_err();
        assert!(err.to_string().contains("expected 2 inputs"), "{err}");
        let b = DenseTensor::zeros(&[48, 16]);
        let err = rt.call("gemm_dense_8x48x16", &[a.into(), b.into()]).unwrap_err();
        assert!(err.to_string().contains("expects"), "{err}");
    }

    #[test]
    fn unknown_artifact_is_an_error() {
        let rt = runtime();
        assert!(rt.call("no_such_artifact", &[]).is_err());
    }

    #[test]
    fn existing_dir_without_manifest_fails_loudly() {
        // A half-configured artifact set must not silently fall back to the
        // built-in manifest.
        let dir = PathBuf::from("target/sten-empty-artifacts-test");
        std::fs::create_dir_all(&dir).unwrap();
        let err = ArtifactRuntime::open(dir).unwrap_err().to_string();
        assert!(err.contains("manifest.json"), "{err}");
    }

    #[test]
    fn timing_buckets_populated_and_compile_charged_once() {
        let rt = runtime();
        let mut rng = Pcg64::seeded(2);
        let a = DenseTensor::randn(&[8, 48], &mut rng);
        let b = DenseTensor::randn(&[48, 16], &mut rng);
        rt.call1("gemm_dense_8x48x16", &[a.clone().into(), b.clone().into()]).unwrap();
        let compile0 = rt.timing().secs("compile");
        assert!(compile0 > 0.0);
        assert!(rt.timing().secs("execute") > 0.0);
        assert!(rt.timing().secs("transfer") > 0.0);
        rt.call1("gemm_dense_8x48x16", &[a.into(), b.into()]).unwrap();
        // Second call hits the prepared cache: no further compile time.
        assert_eq!(rt.timing().secs("compile"), compile0);
    }
}
