//! The artifact executor: prepare-once, execute-many runtime.
//!
//! Executes manifest-described artifacts through the [`super::native`]
//! backend — pure-Rust implementations of each artifact's semantics, driven
//! entirely by the manifest so shapes are never hard-coded. The original
//! PJRT path (`xla::PjRtClient` over HLO text from `make artifacts`) needs
//! the `xla` crate from the full vendor set; restoring it as a second
//! backend behind a cargo feature is tracked in ROADMAP.md. The timing
//! contract is unchanged: `compile` (one-time artifact preparation),
//! `execute` (kernel time) and `transfer` (validation + host marshalling)
//! buckets feed the coordinator's Fig. 11 latency breakdown.
//!
//! # Concurrency
//!
//! One runtime is shared by all engine replicas behind an `Arc`, so the
//! per-call state is deliberately read-mostly: the prepared-artifact set is
//! an `RwLock` taken for writing only on first preparation, and timing is
//! sharded per thread (each replica worker charges its own shard; snapshots
//! merge), so concurrent forwards never serialize on a single hot lock.
//! `Value::F32` holds an `Arc<DenseTensor>`: producers that already share a
//! tensor (engine replicas' weights) hand it to the runtime without copying
//! a byte. See `src/runtime/README.md` for the value-sharing conventions.

use std::cell::Cell;
use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::manifest::{ArtifactSpec, DType, Manifest};
use super::native;
use crate::tensor::DenseTensor;
use crate::util::timer::TimeBreakdown;

/// A typed host value crossing the Rust <-> runtime boundary.
///
/// Float tensors travel behind an `Arc`: cloning a `Value` (or building one
/// from an already-shared tensor with `Value::from(arc)`) is a pointer bump,
/// never a data copy. The owning converters ([`Value::into_f32`]) unwrap
/// without copying when the handle is the sole owner.
#[derive(Debug, Clone)]
pub enum Value {
    /// Dense float tensor (shared handle; clone is O(1)).
    F32(Arc<DenseTensor>),
    /// Integer tensor (tokens, indices) with explicit shape.
    I32(Vec<usize>, Vec<i32>),
}

impl Value {
    /// Shape of the value.
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => t.shape(),
            Value::I32(shape, _) => shape,
        }
    }

    /// Dtype tag matching the manifest.
    pub fn dtype(&self) -> DType {
        match self {
            Value::F32(_) => DType::F32,
            Value::I32(..) => DType::I32,
        }
    }

    /// Unwrap as a float tensor. Zero-copy when this handle is the sole
    /// owner; otherwise the data is cloned out of the shared allocation.
    pub fn into_f32(self) -> Result<DenseTensor> {
        match self {
            Value::F32(t) => Ok(Arc::try_unwrap(t).unwrap_or_else(|shared| (*shared).clone())),
            other => bail!("expected f32 value, got {:?}", other.dtype()),
        }
    }

    /// Unwrap the shared float-tensor handle without materializing a copy.
    pub fn into_f32_shared(self) -> Result<Arc<DenseTensor>> {
        match self {
            Value::F32(t) => Ok(t),
            other => bail!("expected f32 value, got {:?}", other.dtype()),
        }
    }

    /// Borrow as a float tensor.
    pub fn as_f32(&self) -> Result<&DenseTensor> {
        match self {
            Value::F32(t) => Ok(&**t),
            other => bail!("expected f32 value, got {:?}", other.dtype()),
        }
    }
}

impl From<DenseTensor> for Value {
    fn from(t: DenseTensor) -> Self {
        Value::F32(Arc::new(t))
    }
}

impl From<Arc<DenseTensor>> for Value {
    fn from(t: Arc<DenseTensor>) -> Self {
        Value::F32(t)
    }
}

/// Shards for the per-thread timing accumulator. A small power of two well
/// above any realistic replica count keeps the chance of two worker threads
/// hashing to one shard low while bounding snapshot cost.
const TIMING_SHARDS: usize = 16;

thread_local! {
    /// The engine-replica id the current thread charges runtime time to
    /// (`None` outside the serving workers). See [`set_replica_id`].
    static REPLICA_ID: Cell<Option<u64>> = const { Cell::new(None) };
}

/// Tag the calling thread with an engine-replica id: every subsequent
/// [`ArtifactRuntime::call`] on this thread is charged to that replica's
/// timing view in addition to the merged aggregate. The serving workers set
/// this once at startup; pass `None` to untag.
pub fn set_replica_id(id: Option<u64>) {
    REPLICA_ID.with(|c| c.set(id));
}

/// The calling thread's replica tag, if any.
pub fn current_replica_id() -> Option<u64> {
    REPLICA_ID.with(|c| c.get())
}

/// Thread-sharded timing: each thread charges buckets to the shard its
/// `ThreadId` hashes to, so concurrent replicas almost never contend on one
/// breakdown lock. Within a shard, buckets are keyed by the thread's
/// replica tag so snapshots can be filtered per replica; `snapshot` merges
/// everything.
struct ShardedTimes {
    shards: Vec<Mutex<HashMap<Option<u64>, TimeBreakdown>>>,
}

impl ShardedTimes {
    fn new() -> Self {
        ShardedTimes { shards: (0..TIMING_SHARDS).map(|_| Mutex::new(HashMap::new())).collect() }
    }

    /// The calling thread's shard.
    fn shard(&self) -> &Mutex<HashMap<Option<u64>, TimeBreakdown>> {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        std::thread::current().id().hash(&mut h);
        &self.shards[(h.finish() as usize) % TIMING_SHARDS]
    }

    fn add(&self, name: &'static str, d: Duration) {
        self.shard().lock().unwrap().entry(current_replica_id()).or_default().add(name, d);
    }

    fn snapshot(&self) -> TimeBreakdown {
        let mut out = TimeBreakdown::new();
        for s in &self.shards {
            for b in s.lock().unwrap().values() {
                out.merge(b);
            }
        }
        out
    }

    /// Merge only the buckets charged under replica `id`.
    fn snapshot_replica(&self, id: u64) -> TimeBreakdown {
        let mut out = TimeBreakdown::new();
        for s in &self.shards {
            if let Some(b) = s.lock().unwrap().get(&Some(id)) {
                out.merge(b);
            }
        }
        out
    }

    fn reset(&self) {
        for s in &self.shards {
            s.lock().unwrap().clear();
        }
    }
}

/// Prepare-once, execute-many runtime over the artifacts directory.
///
/// When `<dir>/manifest.json` exists it is loaded (so real AOT artifact
/// sets keep driving shapes and metadata); otherwise the built-in manifest
/// mirroring `aot.py`'s output is synthesized and the runtime is fully
/// hermetic. All methods take `&self`: the runtime is shared across engine
/// replicas behind an `Arc` by the serving layer.
pub struct ArtifactRuntime {
    dir: PathBuf,
    manifest: Manifest,
    /// Read-mostly: after warmup every call takes only the read lock.
    prepared: RwLock<HashSet<String>>,
    times: ShardedTimes,
}

/// Clamp a measured duration away from zero so timing buckets are always
/// strictly positive once touched (coarse clocks can round tiny spans to 0).
fn nonzero(d: Duration) -> Duration {
    d.max(Duration::from_nanos(1))
}

impl ArtifactRuntime {
    /// Open the default artifacts directory (`artifacts/` or `$STEN_ARTIFACTS`).
    /// An explicitly-set `STEN_ARTIFACTS` must point at real artifacts: a
    /// missing manifest there is an error, never a silent built-in fallback.
    pub fn open_default() -> Result<Self> {
        let dir = super::default_artifacts_dir();
        if std::env::var_os("STEN_ARTIFACTS").is_some() {
            let manifest = Manifest::load(&dir)?;
            return Ok(Self::with_manifest(dir, manifest));
        }
        Self::open(dir)
    }

    /// Open a specific artifacts directory. A *nonexistent* directory means
    /// "no AOT artifacts": the built-in manifest is synthesized and the run
    /// is fully hermetic. A directory that exists but lacks `manifest.json`
    /// is a half-configured artifact set and fails loudly instead.
    pub fn open(dir: PathBuf) -> Result<Self> {
        let manifest = if dir.join("manifest.json").is_file() {
            Manifest::load(&dir)?
        } else if dir.is_dir() {
            bail!(
                "artifacts directory {dir:?} exists but has no manifest.json; \
                 run `make artifacts` (or remove the directory to use the \
                 built-in native manifest)"
            )
        } else {
            native::builtin_manifest()
        };
        Ok(Self::with_manifest(dir, manifest))
    }

    fn with_manifest(dir: PathBuf, manifest: Manifest) -> Self {
        ArtifactRuntime {
            dir,
            manifest,
            prepared: RwLock::new(HashSet::new()),
            times: ShardedTimes::new(),
        }
    }

    /// The artifacts directory this runtime was opened over.
    pub fn dir(&self) -> &PathBuf {
        &self.dir
    }

    /// The manifest describing all artifacts.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Artifact spec lookup.
    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.get(name)
    }

    /// Prepare an artifact (validated once per runtime, charged to the
    /// `compile` bucket — the PJRT-compile analog). Steady state takes only
    /// the read lock; first use double-checks under the write lock so
    /// concurrent replicas hitting one artifact for the first time charge
    /// compile exactly once.
    pub fn load(&self, name: &str) -> Result<&ArtifactSpec> {
        let spec = self.manifest.get(name)?;
        if self.prepared.read().unwrap().contains(name) {
            return Ok(spec);
        }
        let mut prepared = self.prepared.write().unwrap();
        if !prepared.contains(name) {
            let t = Instant::now();
            native::prepare(spec)?;
            self.times.add("compile", nonzero(t.elapsed()));
            prepared.insert(name.to_string());
        }
        Ok(spec)
    }

    /// Execute an artifact with typed, shape-checked inputs.
    pub fn call(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let spec = self.load(name)?;
        let t = Instant::now();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (v, io) in inputs.iter().zip(&spec.inputs) {
            if v.shape() != io.shape.as_slice() || v.dtype() != io.dtype {
                bail!(
                    "artifact {name}: input {:?} expects {:?} {:?}, got {:?} {:?}",
                    io.name,
                    io.dtype,
                    io.shape,
                    v.dtype(),
                    v.shape()
                );
            }
        }
        let transfer_in = nonzero(t.elapsed());

        let t = Instant::now();
        let out = native::execute(spec, inputs)?;
        let execute = nonzero(t.elapsed());

        let t = Instant::now();
        if out.len() != spec.outputs.len() {
            bail!(
                "artifact {name}: expected {} outputs, produced {}",
                spec.outputs.len(),
                out.len()
            );
        }
        for (v, io) in out.iter().zip(&spec.outputs) {
            if v.shape() != io.shape.as_slice() || v.dtype() != io.dtype {
                bail!(
                    "artifact {name}: output expects {:?} {:?}, produced {:?} {:?}",
                    io.dtype,
                    io.shape,
                    v.dtype(),
                    v.shape()
                );
            }
        }
        let transfer_out = nonzero(t.elapsed());

        // One shard-lock acquisition per call for all three buckets.
        {
            let mut shard = self.times.shard().lock().unwrap();
            let times = shard.entry(current_replica_id()).or_default();
            times.add("transfer", transfer_in + transfer_out);
            times.add("execute", execute);
        }
        Ok(out)
    }

    /// Convenience: call and unwrap a single f32 output.
    pub fn call1(&self, name: &str, inputs: &[Value]) -> Result<DenseTensor> {
        let mut out = self.call(name, inputs)?;
        if out.len() != 1 {
            bail!("artifact {name} returned {} outputs, expected 1", out.len());
        }
        out.remove(0).into_f32()
    }

    /// Snapshot of accumulated timing (merged across all thread shards).
    pub fn timing(&self) -> TimeBreakdown {
        self.times.snapshot()
    }

    /// Timing charged by threads tagged with replica `id` (see
    /// [`set_replica_id`]) — the per-replica view the `serve --replicas N`
    /// summary reports.
    pub fn timing_for_replica(&self, id: u64) -> TimeBreakdown {
        self.times.snapshot_replica(id)
    }

    /// Reset accumulated timing.
    pub fn reset_timing(&self) {
        self.times.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense_gemm;
    use crate::util::rng::Pcg64;

    fn runtime() -> ArtifactRuntime {
        // A directory without manifest.json -> built-in manifest.
        ArtifactRuntime::open(PathBuf::from("target/nonexistent-artifacts")).unwrap()
    }

    #[test]
    fn value_shape_dtype_roundtrip() {
        let v = Value::from(DenseTensor::zeros(&[2, 3]));
        assert_eq!(v.shape(), &[2, 3]);
        assert_eq!(v.dtype(), DType::F32);
        let v = Value::I32(vec![4], vec![1, 2, 3, 4]);
        assert_eq!(v.shape(), &[4]);
        assert_eq!(v.dtype(), DType::I32);
        assert!(v.into_f32().is_err());
    }

    #[test]
    fn value_clone_shares_storage_and_sole_owner_unwraps_in_place() {
        let v = Value::from(DenseTensor::ones(&[4, 4]));
        let w = v.clone();
        // Clones alias one allocation (zero-copy sharing).
        let (pv, pw) = (v.as_f32().unwrap().data().as_ptr(), w.as_f32().unwrap().data().as_ptr());
        assert_eq!(pv, pw, "cloned Value must share tensor storage");
        drop(v);
        // Sole owner: into_f32 returns the same allocation, no copy.
        let t = w.into_f32().unwrap();
        assert_eq!(t.data().as_ptr(), pw, "sole-owner unwrap must not copy");
    }

    #[test]
    fn shared_value_into_f32_copies_out_but_shared_unwrap_does_not() {
        let v = Value::from(DenseTensor::ones(&[2, 2]));
        let w = v.clone();
        let t = w.into_f32().unwrap(); // v still holds the original
        assert_ne!(t.data().as_ptr(), v.as_f32().unwrap().data().as_ptr());
        assert!(t.allclose(v.as_f32().unwrap(), 0.0, 0.0));
        // The shared unwrap keeps aliasing the original allocation even
        // while other handles exist, and round-trips back into a Value.
        let arc = v.clone().into_f32_shared().unwrap();
        assert_eq!(arc.data().as_ptr(), v.as_f32().unwrap().data().as_ptr());
        assert_eq!(Value::from(arc).as_f32().unwrap().data().as_ptr(),
                   v.as_f32().unwrap().data().as_ptr());
        assert!(Value::I32(vec![1], vec![1]).into_f32_shared().is_err());
    }

    #[test]
    fn builtin_gemm_matches_reference() {
        let rt = runtime();
        let mut rng = Pcg64::seeded(1);
        let a = DenseTensor::randn(&[8, 48], &mut rng);
        let b = DenseTensor::randn(&[48, 16], &mut rng);
        let got = rt.call1("gemm_dense_8x48x16", &[a.clone().into(), b.clone().into()]).unwrap();
        let want = dense_gemm::matmul_naive(&a, &b);
        assert!(got.allclose(&want, 1e-4, 1e-4), "max diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn call_rejects_wrong_arity_and_shape() {
        let rt = runtime();
        let a = DenseTensor::zeros(&[2, 2]);
        let err = rt.call("gemm_dense_8x48x16", &[a.clone().into()]).unwrap_err();
        assert!(err.to_string().contains("expected 2 inputs"), "{err}");
        let b = DenseTensor::zeros(&[48, 16]);
        let err = rt.call("gemm_dense_8x48x16", &[a.into(), b.into()]).unwrap_err();
        assert!(err.to_string().contains("expects"), "{err}");
    }

    #[test]
    fn unknown_artifact_is_an_error() {
        let rt = runtime();
        assert!(rt.call("no_such_artifact", &[]).is_err());
    }

    #[test]
    fn existing_dir_without_manifest_fails_loudly() {
        // A half-configured artifact set must not silently fall back to the
        // built-in manifest.
        let dir = PathBuf::from("target/sten-empty-artifacts-test");
        std::fs::create_dir_all(&dir).unwrap();
        let err = ArtifactRuntime::open(dir).unwrap_err().to_string();
        assert!(err.contains("manifest.json"), "{err}");
    }

    #[test]
    fn timing_buckets_populated_and_compile_charged_once() {
        let rt = runtime();
        let mut rng = Pcg64::seeded(2);
        let a = DenseTensor::randn(&[8, 48], &mut rng);
        let b = DenseTensor::randn(&[48, 16], &mut rng);
        rt.call1("gemm_dense_8x48x16", &[a.clone().into(), b.clone().into()]).unwrap();
        let compile0 = rt.timing().secs("compile");
        assert!(compile0 > 0.0);
        assert!(rt.timing().secs("execute") > 0.0);
        assert!(rt.timing().secs("transfer") > 0.0);
        rt.call1("gemm_dense_8x48x16", &[a.into(), b.into()]).unwrap();
        // Second call hits the prepared cache: no further compile time.
        assert_eq!(rt.timing().secs("compile"), compile0);
    }

    #[test]
    fn replica_tagged_timing_is_filterable() {
        let rt = std::sync::Arc::new(runtime());
        let mut handles = Vec::new();
        for replica in 0..2u64 {
            let rt = rt.clone();
            handles.push(std::thread::spawn(move || {
                set_replica_id(Some(replica));
                let mut rng = Pcg64::seeded(replica + 10);
                let a = DenseTensor::randn(&[8, 48], &mut rng);
                let b = DenseTensor::randn(&[48, 16], &mut rng);
                for _ in 0..1 + replica {
                    rt.call1("gemm_dense_8x48x16", &[a.clone().into(), b.clone().into()])
                        .unwrap();
                }
                set_replica_id(None);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let (t0, t1) = (rt.timing_for_replica(0), rt.timing_for_replica(1));
        assert!(t0.secs("execute") > 0.0);
        assert!(t1.secs("execute") > 0.0);
        // An untagged call is visible in the aggregate but in no replica
        // view; the aggregate covers at least the per-replica views.
        let mut rng = Pcg64::seeded(30);
        let a = DenseTensor::randn(&[8, 48], &mut rng);
        let b = DenseTensor::randn(&[48, 16], &mut rng);
        rt.call1("gemm_dense_8x48x16", &[a.into(), b.into()]).unwrap();
        let all = rt.timing();
        assert!(all.secs("execute") >= t0.secs("execute") + t1.secs("execute"));
        assert!(rt.timing_for_replica(7).secs("execute") == 0.0);
        rt.reset_timing();
        assert_eq!(rt.timing_for_replica(0).secs("execute"), 0.0);
    }

    #[test]
    fn timing_merges_across_threads() {
        // Calls from several threads land in different shards; the snapshot
        // must still see all of them, and compile must be charged once.
        let rt = std::sync::Arc::new(runtime());
        let mut handles = Vec::new();
        for seed in 0..4u64 {
            let rt = rt.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Pcg64::seeded(seed);
                let a = DenseTensor::randn(&[8, 48], &mut rng);
                let b = DenseTensor::randn(&[48, 16], &mut rng);
                rt.call1("gemm_dense_8x48x16", &[a.into(), b.into()]).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let t = rt.timing();
        assert!(t.secs("execute") > 0.0);
        assert!(t.secs("transfer") > 0.0);
        assert!(t.secs("compile") > 0.0);
        rt.reset_timing();
        assert_eq!(rt.timing().secs("execute"), 0.0);
    }
}
