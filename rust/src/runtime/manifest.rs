//! `artifacts/manifest.json` model + a minimal JSON parser.
//!
//! serde is not in the offline vendor set, so this module includes a small
//! recursive-descent JSON parser sufficient for the manifest schema (objects,
//! arrays, strings, integers/floats, booleans, null).

use std::collections::{BTreeMap, HashMap};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(HashMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array view.
    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {other:?}"),
        }
    }

    /// String view.
    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    /// Numeric view as usize.
    pub fn usize(&self) -> Result<usize> {
        match self {
            Json::Num(n) => Ok(*n as usize),
            other => bail!("expected number, got {other:?}"),
        }
    }

    /// Numeric view as f64.
    pub fn f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    /// Deterministic serialization: object keys emitted in sorted order, so
    /// semantically identical documents are byte-identical. Used by the
    /// autotune cache, whose on-disk bytes are part of its determinism
    /// contract.
    pub fn to_string_sorted(&self) -> String {
        let mut out = String::new();
        self.write_sorted(&mut out);
        out
    }

    fn write_sorted(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                // Integral values print without a fractional part so the
                // output round-trips through the parser unchanged.
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write_sorted(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                let mut keys: Vec<&String> = map.keys().collect();
                keys.sort_unstable();
                out.push('{');
                for (i, k) in keys.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    map[*k].write_sorted(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}, found {:?}", b as char, self.pos, self.peek().map(|c| c as char))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| anyhow!("unexpected end of input"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.keyword("true", Json::Bool(true)),
            b'f' => self.keyword("false", Json::Bool(false)),
            b'n' => self.keyword("null", Json::Null),
            _ => self.number(),
        }
    }

    fn keyword(&mut self, kw: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(v)
        } else {
            bail!("invalid keyword at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = HashMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected ',' or '}}', found {other:?}"),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected ',' or ']', found {other:?}"),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| anyhow!("unterminated string"))? {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| anyhow!("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("unknown escape \\{}", esc as char),
                    }
                }
                _ => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse().with_context(|| format!("bad number {s:?}"))?))
    }
}

/// Element dtype of an artifact input/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

/// One input or output of an artifact.
#[derive(Debug, Clone)]
pub struct IoSpec {
    /// Input name (empty for outputs).
    pub name: String,
    /// Element type.
    pub dtype: DType,
    /// Shape (row-major).
    pub shape: Vec<usize>,
}

impl IoSpec {
    /// Number of elements.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact: an HLO computation plus its typed interface.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Artifact name (manifest key).
    pub name: String,
    /// HLO text file name, relative to the artifacts directory.
    pub file: String,
    /// Typed inputs, in call order.
    pub inputs: Vec<IoSpec>,
    /// Typed outputs, in tuple order.
    pub outputs: Vec<IoSpec>,
    /// Free-form metadata (config dims, n:m:g parameters, param names).
    pub meta: Json,
}

impl ArtifactSpec {
    /// Index of the named input.
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|i| i.name == name)
            .ok_or_else(|| anyhow!("artifact {} has no input {name:?}", self.name))
    }
}

/// The parsed manifest: every artifact the AOT step produced.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    artifacts: HashMap<String, ArtifactSpec>,
    /// Embedded autotune decisions: tune cache key -> decision object
    /// (layout / kernel / cost / policy), carried in the optional
    /// top-level `autotune` field of `manifest.json`. A deployed artifact
    /// thereby pins the exact layout choices it was tuned with;
    /// `tune::Autotuner::from_manifest` replays them without re-tuning.
    autotune: BTreeMap<String, Json>,
}

impl Manifest {
    /// Load `manifest.json` from the artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        Self::parse(&text)
    }

    /// Build a manifest from in-memory specs (the native backend's built-in
    /// artifact set when no `manifest.json` is on disk).
    pub fn from_specs(specs: Vec<ArtifactSpec>) -> Manifest {
        Manifest {
            artifacts: specs.into_iter().map(|s| (s.name.clone(), s)).collect(),
            autotune: BTreeMap::new(),
        }
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text)?;
        let mut artifacts = HashMap::new();
        for a in root.get("artifacts").ok_or_else(|| anyhow!("missing artifacts"))?.arr()? {
            let spec = ArtifactSpec {
                name: a.get("name").ok_or_else(|| anyhow!("missing name"))?.str()?.to_string(),
                file: a.get("file").ok_or_else(|| anyhow!("missing file"))?.str()?.to_string(),
                inputs: parse_ios(a.get("inputs"))?,
                outputs: parse_ios(a.get("outputs"))?,
                meta: a.get("meta").cloned().unwrap_or(Json::Null),
            };
            artifacts.insert(spec.name.clone(), spec);
        }
        let mut autotune = BTreeMap::new();
        if let Some(Json::Obj(map)) = root.get("autotune") {
            for (k, v) in map {
                autotune.insert(k.clone(), v.clone());
            }
        }
        Ok(Manifest { artifacts, autotune })
    }

    /// Look up an artifact by name.
    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow!(
                "artifact {name:?} not in manifest (have: {:?})",
                self.names()
            )
        })
    }

    /// All artifact names, sorted.
    pub fn names(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.artifacts.keys().map(|s| s.as_str()).collect();
        names.sort_unstable();
        names
    }

    /// Number of artifacts.
    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    /// True when no artifacts are present.
    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    /// Embedded autotune decisions (tune cache key -> decision object).
    pub fn autotune(&self) -> &BTreeMap<String, Json> {
        &self.autotune
    }

    /// Record an autotune decision under its tune cache key.
    pub fn set_autotune(&mut self, key: &str, decision: Json) {
        self.autotune.insert(key.to_string(), decision);
    }

    /// The `autotune` section as one JSON object. Serialize with
    /// [`Json::to_string_sorted`] to embed in a written manifest; parsing
    /// the result back yields the same decisions (round-trip tested).
    pub fn autotune_json(&self) -> Json {
        Json::Obj(self.autotune.iter().map(|(k, v)| (k.clone(), v.clone())).collect())
    }
}

fn parse_ios(v: Option<&Json>) -> Result<Vec<IoSpec>> {
    let mut out = Vec::new();
    for io in v.ok_or_else(|| anyhow!("missing io list"))?.arr()? {
        let shape = io
            .get("shape")
            .ok_or_else(|| anyhow!("missing shape"))?
            .arr()?
            .iter()
            .map(|d| d.usize())
            .collect::<Result<Vec<_>>>()?;
        out.push(IoSpec {
            name: io.get("name").map(|n| n.str().unwrap_or("").to_string()).unwrap_or_default(),
            dtype: DType::parse(io.get("dtype").ok_or_else(|| anyhow!("missing dtype"))?.str()?)?,
            shape,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": [
        {"name": "toy", "file": "toy.hlo.txt",
         "inputs": [{"name": "a", "dtype": "float32", "shape": [2, 3]},
                    {"name": "tok", "dtype": "int32", "shape": [4]}],
         "outputs": [{"dtype": "float32", "shape": []}],
         "meta": {"m": 4, "tag": "x", "names": ["a", "b"]}}
      ]
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 1);
        let a = m.get("toy").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![2, 3]);
        assert_eq!(a.inputs[1].dtype, DType::I32);
        assert_eq!(a.outputs[0].shape, Vec::<usize>::new());
        assert_eq!(a.meta.get("m").unwrap().usize().unwrap(), 4);
        assert_eq!(a.input_index("tok").unwrap(), 1);
        assert!(a.input_index("zzz").is_err());
    }

    #[test]
    fn json_parses_nested_values() {
        let v = Json::parse(r#"{"a": [1, 2.5, "s", true, null, {"b": -3e2}]}"#).unwrap();
        let arr = v.get("a").unwrap().arr().unwrap();
        assert_eq!(arr[0], Json::Num(1.0));
        assert_eq!(arr[1], Json::Num(2.5));
        assert_eq!(arr[2], Json::Str("s".into()));
        assert_eq!(arr[3], Json::Bool(true));
        assert_eq!(arr[4], Json::Null);
        assert_eq!(arr[5].get("b"), Some(&Json::Num(-300.0)));
    }

    #[test]
    fn json_string_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ A""#).unwrap();
        assert_eq!(v, Json::Str("a\n\t\"\\ A".into()));
    }

    #[test]
    fn json_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn sorted_serialization_is_deterministic_and_roundtrips() {
        let text = r#"{"z": 1, "a": [true, null, "x\n", -2.5], "m": {"k2": 3, "k1": 4.0}}"#;
        let v = Json::parse(text).unwrap();
        let s = v.to_string_sorted();
        assert_eq!(s, r#"{"a":[true,null,"x\n",-2.5],"m":{"k1":4,"k2":3},"z":1}"#);
        // Round-trip: parse(serialize(v)) == v, and re-serializing is stable.
        let v2 = Json::parse(&s).unwrap();
        assert_eq!(v2, v);
        assert_eq!(v2.to_string_sorted(), s);
    }

    #[test]
    fn autotune_section_parses_and_roundtrips() {
        let doc = r#"{"artifacts": [], "autotune": {
            "matmul:m16k32n8:sp500:nmg2:4:2": {"layout": "Nmg",
             "kernel": "nmg_gemm::spmm", "cost": 4096, "policy": "cost_model"}}}"#;
        let mut m = Manifest::parse(doc).unwrap();
        assert_eq!(m.autotune().len(), 1);
        let dec = &m.autotune()["matmul:m16k32n8:sp500:nmg2:4:2"];
        assert_eq!(dec.get("layout").unwrap().str().unwrap(), "Nmg");
        assert_eq!(dec.get("cost").unwrap().f64().unwrap(), 4096.0);
        // Add an entry, serialize the section, parse it back: identical.
        let mut extra = HashMap::new();
        extra.insert("layout".to_string(), Json::Str("Dense".to_string()));
        m.set_autotune("matmul:m8k8n4:sp0:nmgnone", Json::Obj(extra));
        let section = m.autotune_json().to_string_sorted();
        let doc2 = format!(r#"{{"artifacts": [], "autotune": {section}}}"#);
        let m2 = Manifest::parse(&doc2).unwrap();
        assert_eq!(m2.autotune(), m.autotune());
        assert_eq!(m2.autotune_json().to_string_sorted(), section, "byte-stable");
        // A manifest without the section has no decisions.
        assert!(Manifest::parse(r#"{"artifacts": []}"#).unwrap().autotune().is_empty());
    }

    #[test]
    fn missing_artifact_error_lists_names() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let err = m.get("nope").unwrap_err().to_string();
        assert!(err.contains("toy"), "{err}");
    }
}
