//! PJRT runtime: load and execute AOT-lowered JAX/Pallas artifacts.
//!
//! The Python side (`python/compile/aot.py`) lowers each computation to HLO
//! **text** and records its interface in `artifacts/manifest.json`. This
//! module is manifest-driven: it never hard-codes shapes, it validates every
//! call against the manifest, and it caches compiled executables so each
//! artifact is compiled exactly once per process.
//!
//! Python never runs on this path — the Rust binary is self-contained once
//! `make artifacts` has produced the HLO files.

mod manifest;
mod executor;

pub use executor::{ArtifactRuntime, Value};
pub use manifest::{ArtifactSpec, DType, IoSpec, Manifest};

/// Default artifacts directory, overridable via `STEN_ARTIFACTS`.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("STEN_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
