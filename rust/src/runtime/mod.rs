//! Artifact runtime: load and execute AOT-described computations.
//!
//! The Python side (`python/compile/aot.py`) lowers each computation to HLO
//! text and records its interface in `artifacts/manifest.json`. This module
//! is manifest-driven: it never hard-codes shapes and validates every call
//! against the manifest. Execution goes through the [`native`] backend — a
//! pure-Rust implementation of every artifact's semantics over the crate's
//! own kernels — so the full pipeline runs hermetically, with or without
//! `make artifacts` (when the manifest file is absent, a built-in manifest
//! mirroring `aot.py`'s output is synthesized). The PJRT execution path
//! (`xla` crate over the HLO text files) is planned as a second backend
//! behind a cargo feature once the vendor set ships `xla`; see ROADMAP.md.

mod manifest;
mod executor;
pub mod native;

pub use executor::{current_replica_id, set_replica_id, ArtifactRuntime, Value};
pub use manifest::{ArtifactSpec, DType, IoSpec, Json, Manifest};

/// Default artifacts directory, overridable via `STEN_ARTIFACTS`.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("STEN_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
