//! Native artifact backend: executes every manifest artifact in pure Rust.
//!
//! The PJRT executor needs the `xla` crate plus AOT-lowered HLO files from
//! `make artifacts` — neither is guaranteed offline. This module is the
//! fallback (and currently the default) execution engine: it implements the
//! *semantics* of each artifact (`python/compile/model.py`) on top of the
//! crate's own kernels, keyed by artifact name and driven entirely by the
//! manifest spec. When `artifacts/manifest.json` is absent a built-in
//! manifest mirroring `aot.py`'s non-quick output is synthesized, so the
//! coordinator, tests and benches run hermetically.
//!
//! Numerics are shared with the coordinator's native FFN path (same
//! `elementwise` / `dense_gemm` kernels), so block-composed and monolithic
//! forwards agree bit-for-bit. The train step implements the full
//! hand-derived backward pass (embedding gather, pre-LN attention, masked
//! FFN, LM head, mean token cross-entropy) with masked-SGD updates —
//! `(p - lr * grad) * mask`, the paper's Fig. 2 semantics.

use std::collections::{BTreeMap, HashMap};

use anyhow::{anyhow, bail, Result};

use super::executor::Value;
use super::manifest::{ArtifactSpec, DType, IoSpec, Json, Manifest};
use crate::formats::nmg::{binomial, NmgTensor};
use crate::kernels::{dense_gemm, elementwise, nmg_gemm};
use crate::tensor::DenseTensor;
use crate::util::threadpool;

// ---------------------------------------------------------------------------
// Built-in manifest (mirrors aot.py's non-quick artifact set)
// ---------------------------------------------------------------------------

/// Encoder hyperparameters fixed at "AOT" time (see `EncoderConfig` in
/// `python/compile/model.py`).
#[derive(Debug, Clone, Copy)]
pub struct EncoderCfg {
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_layers: usize,
}

impl EncoderCfg {
    /// The pytest/cargo-test scale configuration.
    pub fn tiny() -> Self {
        EncoderCfg { vocab: 256, seq: 16, batch: 2, d_model: 32, n_heads: 2, d_ff: 64, n_layers: 2 }
    }

    /// The example/bench scale configuration.
    pub fn base() -> Self {
        EncoderCfg {
            vocab: 2048,
            seq: 128,
            batch: 8,
            d_model: 256,
            n_heads: 4,
            d_ff: 1024,
            n_layers: 4,
        }
    }

    /// Canonical `(name, shape)` parameter list — the artifact input order.
    pub fn param_list(&self) -> Vec<(String, Vec<usize>)> {
        let (d, f, v, s) = (self.d_model, self.d_ff, self.vocab, self.seq);
        let mut out: Vec<(String, Vec<usize>)> =
            vec![("emb".into(), vec![v, d]), ("pos".into(), vec![s, d])];
        for i in 0..self.n_layers {
            let p = |n: &str| format!("layer{i}.{n}");
            out.extend([
                (p("ln1_g"), vec![d]),
                (p("ln1_b"), vec![d]),
                (p("wq"), vec![d, d]),
                (p("bq"), vec![d]),
                (p("wk"), vec![d, d]),
                (p("bk"), vec![d]),
                (p("wv"), vec![d, d]),
                (p("bv"), vec![d]),
                (p("wo"), vec![d, d]),
                (p("bo"), vec![d]),
                (p("ln2_g"), vec![d]),
                (p("ln2_b"), vec![d]),
                (p("w1"), vec![d, f]),
                (p("b1"), vec![f]),
                (p("w2"), vec![f, d]),
                (p("b2"), vec![d]),
            ]);
        }
        out.extend([
            ("lnf_g".into(), vec![d]),
            ("lnf_b".into(), vec![d]),
            ("out_w".into(), vec![d, v]),
            ("out_b".into(), vec![v]),
        ]);
        out
    }

    /// Parameters that carry sparsity masks in the train step (FFN weights).
    pub fn masked_param_names(&self) -> Vec<String> {
        (0..self.n_layers)
            .flat_map(|i| [format!("layer{i}.w1"), format!("layer{i}.w2")])
            .collect()
    }
}

fn jnum(n: usize) -> Json {
    Json::Num(n as f64)
}

fn jobj(pairs: &[(&str, Json)]) -> Json {
    let mut m = HashMap::new();
    for (k, v) in pairs {
        m.insert((*k).to_string(), v.clone());
    }
    Json::Obj(m)
}

fn fio(name: &str, shape: &[usize]) -> IoSpec {
    IoSpec { name: name.to_string(), dtype: DType::F32, shape: shape.to_vec() }
}

fn iio(name: &str, shape: &[usize]) -> IoSpec {
    IoSpec { name: name.to_string(), dtype: DType::I32, shape: shape.to_vec() }
}

fn spec(name: &str, inputs: Vec<IoSpec>, outputs: Vec<IoSpec>, meta: Json) -> ArtifactSpec {
    ArtifactSpec {
        name: name.to_string(),
        file: format!("{name}.hlo.txt"),
        inputs,
        outputs,
        meta,
    }
}

/// n:m:g metadata for an (M, K) operand, matching `aot.nmg_meta`.
fn nmg_meta(m: usize, n: usize, g: usize, mdim: usize, k: usize) -> Vec<(&'static str, Json)> {
    let c = binomial(m, n);
    let ch = k.div_ceil(c * g);
    vec![
        ("m", jnum(m)),
        ("n", jnum(n)),
        ("g", jnum(g)),
        ("C", jnum(c)),
        ("CH", jnum(ch)),
        ("S", jnum(mdim.div_ceil(m))),
        ("M", jnum(mdim)),
        ("K", jnum(k)),
    ]
}

fn encoder_meta(cfg: &EncoderCfg) -> Vec<(&'static str, Json)> {
    vec![
        ("vocab", jnum(cfg.vocab)),
        ("seq", jnum(cfg.seq)),
        ("batch", jnum(cfg.batch)),
        ("d_model", jnum(cfg.d_model)),
        ("n_heads", jnum(cfg.n_heads)),
        ("d_ff", jnum(cfg.d_ff)),
        ("n_layers", jnum(cfg.n_layers)),
    ]
}

fn push_gemm_specs(out: &mut Vec<ArtifactSpec>, mdim: usize, k: usize, n: usize) {
    out.push(spec(
        &format!("gemm_dense_{mdim}x{k}x{n}"),
        vec![fio("a", &[mdim, k]), fio("b", &[k, n])],
        vec![fio("", &[mdim, n])],
        jobj(&[]),
    ));
    out.push(spec(
        &format!("gemm_masked_{mdim}x{k}x{n}"),
        vec![fio("a", &[mdim, k]), fio("mask", &[mdim, k]), fio("b", &[k, n])],
        vec![fio("", &[mdim, n])],
        jobj(&[]),
    ));
}

fn push_nmg_gemm_spec(out: &mut Vec<ArtifactSpec>, mdim: usize, k: usize, n: usize) {
    let (mm, nn, g) = (4usize, 2usize, 4usize);
    let meta = nmg_meta(mm, nn, g, mdim, k);
    let c = binomial(mm, nn);
    let ch = k.div_ceil(c * g);
    let s = mdim / mm;
    let mut full = meta;
    full.push(("N", jnum(n)));
    out.push(spec(
        &format!("gemm_nmg_{mdim}x{k}x{n}"),
        vec![
            fio("val", &[s, ch, c, g, nn]),
            iio("idx", &[s, ch, c, g]),
            fio("b", &[k, n]),
        ],
        vec![fio("", &[mdim, n])],
        jobj(&full),
    ));
}

fn push_encoder_specs(out: &mut Vec<ArtifactSpec>, cfg: &EncoderCfg, tag: &str) {
    let (d, f, v, s, b) = (cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq, cfg.batch);
    let meta = jobj(&encoder_meta(cfg));
    let params = cfg.param_list();

    let mut fwd_inputs: Vec<IoSpec> = params.iter().map(|(n, sh)| fio(n, sh)).collect();
    fwd_inputs.push(iio("tokens", &[b, s]));
    out.push(spec(
        &format!("encoder_fwd_{tag}"),
        fwd_inputs,
        vec![fio("", &[b, s, v])],
        meta.clone(),
    ));

    out.push(spec(
        &format!("attn_block_{tag}"),
        vec![
            fio("x", &[b, s, d]),
            fio("ln_g", &[d]),
            fio("ln_b", &[d]),
            fio("wq", &[d, d]),
            fio("bq", &[d]),
            fio("wk", &[d, d]),
            fio("bk", &[d]),
            fio("wv", &[d, d]),
            fio("bv", &[d]),
            fio("wo", &[d, d]),
            fio("bo", &[d]),
        ],
        vec![fio("", &[b, s, d])],
        meta.clone(),
    ));

    out.push(spec(
        &format!("ffn_block_{tag}"),
        vec![
            fio("x", &[b, s, d]),
            fio("ln_g", &[d]),
            fio("ln_b", &[d]),
            fio("w1", &[d, f]),
            fio("b1", &[f]),
            fio("w2", &[f, d]),
            fio("b2", &[d]),
        ],
        vec![fio("", &[b, s, d])],
        meta.clone(),
    ));

    out.push(spec(
        &format!("embed_{tag}"),
        vec![fio("emb", &[v, d]), fio("pos", &[s, d]), iio("tokens", &[b, s])],
        vec![fio("", &[b, s, d])],
        meta.clone(),
    ));

    out.push(spec(
        &format!("lm_head_{tag}"),
        vec![
            fio("x", &[b, s, d]),
            fio("lnf_g", &[d]),
            fio("lnf_b", &[d]),
            fio("out_w", &[d, v]),
            fio("out_b", &[v]),
        ],
        vec![fio("", &[b, s, v])],
        meta.clone(),
    ));

    // n:m:g FFN block: W1^T (f, d) in 2:4:4.
    let (mm, nn, g) = (4usize, 2usize, 4usize);
    let c = binomial(mm, nn);
    let ch = d.div_ceil(c * g);
    let slabs = f / mm;
    let mut nmg_full = encoder_meta(cfg);
    nmg_full.push(("nmg", jobj(&nmg_meta(mm, nn, g, f, d))));
    out.push(spec(
        &format!("ffn_block_nmg_{tag}"),
        vec![
            fio("x", &[b, s, d]),
            fio("ln_g", &[d]),
            fio("ln_b", &[d]),
            fio("val", &[slabs, ch, c, g, nn]),
            iio("idx", &[slabs, ch, c, g]),
            fio("b1", &[f]),
            fio("w2", &[f, d]),
            fio("b2", &[d]),
        ],
        vec![fio("", &[b, s, d])],
        jobj(&nmg_full),
    ));

    // Train step: params + masks + tokens/targets + lr -> (loss, *params').
    let mut train_inputs: Vec<IoSpec> = params.iter().map(|(n, sh)| fio(n, sh)).collect();
    for name in cfg.masked_param_names() {
        let shape = params.iter().find(|(n, _)| *n == name).unwrap().1.clone();
        train_inputs.push(fio(&format!("mask.{name}"), &shape));
    }
    train_inputs.push(iio("tokens", &[b, s]));
    train_inputs.push(iio("targets", &[b, s]));
    train_inputs.push(fio("lr", &[]));
    let mut train_outputs: Vec<IoSpec> = vec![fio("", &[])];
    train_outputs.extend(params.iter().map(|(_, sh)| fio("", sh)));
    out.push(spec(&format!("train_step_{tag}"), train_inputs, train_outputs, meta));
}

/// The synthesized manifest used when no `artifacts/manifest.json` exists:
/// the same artifact set `aot.py` emits in non-quick mode.
pub fn builtin_manifest() -> Manifest {
    let mut specs = Vec::new();
    push_gemm_specs(&mut specs, 8, 48, 16);
    push_gemm_specs(&mut specs, 64, 192, 128);
    push_nmg_gemm_spec(&mut specs, 8, 48, 16);
    push_nmg_gemm_spec(&mut specs, 16, 96, 64);
    push_encoder_specs(&mut specs, &EncoderCfg::tiny(), "tiny");
    push_encoder_specs(&mut specs, &EncoderCfg::base(), "base");
    Manifest::from_specs(specs)
}

// ---------------------------------------------------------------------------
// Execution
// ---------------------------------------------------------------------------

fn f32_in<'a>(inputs: &'a [Value], i: usize) -> Result<&'a DenseTensor> {
    inputs[i].as_f32()
}

fn i32_in(inputs: &[Value], i: usize) -> Result<&[i32]> {
    match &inputs[i] {
        Value::I32(_, data) => Ok(data),
        other => bail!("expected i32 input, got {:?}", other.dtype()),
    }
}

fn meta_usize(meta: &Json, key: &str) -> Result<usize> {
    meta.get(key).ok_or_else(|| anyhow!("missing meta.{key}"))?.usize()
}

fn cfg_from_meta(meta: &Json) -> Result<EncoderCfg> {
    Ok(EncoderCfg {
        vocab: meta_usize(meta, "vocab")?,
        seq: meta_usize(meta, "seq")?,
        batch: meta_usize(meta, "batch")?,
        d_model: meta_usize(meta, "d_model")?,
        n_heads: meta_usize(meta, "n_heads")?,
        d_ff: meta_usize(meta, "d_ff")?,
        n_layers: meta_usize(meta, "n_layers")?,
    })
}

/// One-time per-artifact preparation (the "compile" analog): consistency
/// checks over the spec so malformed manifests fail at load, not mid-call.
pub fn prepare(spec: &ArtifactSpec) -> Result<()> {
    let name = spec.name.as_str();
    if name.starts_with("attn_block_") {
        let cfg = cfg_from_meta(&spec.meta)?;
        if cfg.d_model % cfg.n_heads != 0 {
            bail!("{name}: d_model {} not divisible by n_heads {}", cfg.d_model, cfg.n_heads);
        }
    } else if name.starts_with("gemm_nmg_") || name.starts_with("ffn_block_nmg_") {
        let nmg = if name.starts_with("ffn_block_nmg_") {
            spec.meta.get("nmg").ok_or_else(|| anyhow!("{name}: missing meta.nmg"))?
        } else {
            &spec.meta
        };
        let (m, n) = (meta_usize(nmg, "m")?, meta_usize(nmg, "n")?);
        // Ragged M (rows % m != 0) is fine: the format zero-pads the final
        // slab. Only the n <= m structural invariant is checked here.
        if n == 0 || n > m || meta_usize(nmg, "M")? == 0 {
            bail!("{name}: invalid n:m:g meta");
        }
    }
    Ok(())
}

/// Execute one artifact. Inputs are already shape/dtype-validated against
/// the spec by the caller.
pub fn execute(spec: &ArtifactSpec, inputs: &[Value]) -> Result<Vec<Value>> {
    let name = spec.name.as_str();
    if name.starts_with("gemm_dense_") {
        let out = dense_gemm::matmul(f32_in(inputs, 0)?, f32_in(inputs, 1)?);
        return Ok(vec![Value::from(out)]);
    }
    if name.starts_with("gemm_masked_") {
        let out =
            dense_gemm::matmul_masked(f32_in(inputs, 0)?, f32_in(inputs, 1)?, f32_in(inputs, 2)?);
        return Ok(vec![Value::from(out)]);
    }
    if name.starts_with("gemm_nmg_") {
        let sparse = nmg_from_inputs(&spec.meta, f32_in(inputs, 0)?, i32_in(inputs, 1)?)?;
        let out = nmg_gemm::spmm(&sparse, f32_in(inputs, 2)?);
        return Ok(vec![Value::from(out)]);
    }
    if name.starts_with("embed_") {
        let cfg = cfg_from_meta(&spec.meta)?;
        let x = embed_forward(f32_in(inputs, 0)?, f32_in(inputs, 1)?, i32_in(inputs, 2)?, &cfg);
        return Ok(vec![Value::from(x.reshape(&[cfg.batch, cfg.seq, cfg.d_model]))]);
    }
    if name.starts_with("attn_block_") {
        let cfg = cfg_from_meta(&spec.meta)?;
        let x = to_rows(f32_in(inputs, 0)?, cfg.d_model);
        let w = AttnWeights {
            ln_g: f32_in(inputs, 1)?,
            ln_b: f32_in(inputs, 2)?,
            wq: f32_in(inputs, 3)?,
            bq: f32_in(inputs, 4)?,
            wk: f32_in(inputs, 5)?,
            bk: f32_in(inputs, 6)?,
            wv: f32_in(inputs, 7)?,
            bv: f32_in(inputs, 8)?,
            wo: f32_in(inputs, 9)?,
            bo: f32_in(inputs, 10)?,
        };
        let (out, _) = attn_forward(&x, &w, cfg.batch, cfg.seq, cfg.n_heads);
        return Ok(vec![Value::from(out.reshape(&[cfg.batch, cfg.seq, cfg.d_model]))]);
    }
    if name.starts_with("ffn_block_nmg_") {
        let cfg = cfg_from_meta(&spec.meta)?;
        let nmg_meta = spec.meta.get("nmg").ok_or_else(|| anyhow!("missing meta.nmg"))?;
        let x = to_rows(f32_in(inputs, 0)?, cfg.d_model);
        let y = elementwise::layernorm_rows(&x, f32_in(inputs, 1)?.data(), f32_in(inputs, 2)?.data());
        let w1t = nmg_from_inputs(nmg_meta, f32_in(inputs, 3)?, i32_in(inputs, 4)?)?;
        // (F, D) nmg @ (D, rows) -> (F, rows) -> transpose.
        let h = nmg_gemm::spmm(&w1t, &y.transpose2()).transpose2();
        let h = elementwise::gelu(&elementwise::bias_add(&h, f32_in(inputs, 5)?.data()));
        let o = dense_gemm::matmul(&h, f32_in(inputs, 6)?);
        let o = elementwise::bias_add(&o, f32_in(inputs, 7)?.data());
        let out = x.zip(&o, |a, b| a + b);
        return Ok(vec![Value::from(out.reshape(&[cfg.batch, cfg.seq, cfg.d_model]))]);
    }
    if name.starts_with("ffn_block_") {
        let cfg = cfg_from_meta(&spec.meta)?;
        let x = to_rows(f32_in(inputs, 0)?, cfg.d_model);
        let w = FfnWeights {
            ln_g: f32_in(inputs, 1)?,
            ln_b: f32_in(inputs, 2)?,
            w1: f32_in(inputs, 3)?,
            b1: f32_in(inputs, 4)?,
            w2: f32_in(inputs, 5)?,
            b2: f32_in(inputs, 6)?,
        };
        let (out, _) = ffn_forward(&x, &w, None);
        return Ok(vec![Value::from(out.reshape(&[cfg.batch, cfg.seq, cfg.d_model]))]);
    }
    if name.starts_with("lm_head_") {
        let cfg = cfg_from_meta(&spec.meta)?;
        let x = to_rows(f32_in(inputs, 0)?, cfg.d_model);
        let y = elementwise::layernorm_rows(&x, f32_in(inputs, 1)?.data(), f32_in(inputs, 2)?.data());
        let logits = elementwise::bias_add(
            &dense_gemm::matmul(&y, f32_in(inputs, 3)?),
            f32_in(inputs, 4)?.data(),
        );
        return Ok(vec![Value::from(logits.reshape(&[cfg.batch, cfg.seq, cfg.vocab]))]);
    }
    if name.starts_with("encoder_fwd_") {
        let cfg = cfg_from_meta(&spec.meta)?;
        let params = named_f32_inputs(spec, inputs)?;
        let tokens = i32_in(inputs, spec.input_index("tokens")?)?;
        let logits = encoder_forward(&cfg, &params, tokens, None).logits;
        return Ok(vec![Value::from(logits.reshape(&[cfg.batch, cfg.seq, cfg.vocab]))]);
    }
    if name.starts_with("train_step_") {
        return train_step(spec, inputs);
    }
    bail!("native backend has no implementation for artifact {name:?}")
}

/// Rebuild an [`NmgTensor`] from the flat artifact `val`/`idx` inputs.
fn nmg_from_inputs(meta: &Json, val: &DenseTensor, idx: &[i32]) -> Result<NmgTensor> {
    let (m, n, g) = (meta_usize(meta, "m")?, meta_usize(meta, "n")?, meta_usize(meta, "g")?);
    let (mdim, k) = (meta_usize(meta, "M")?, meta_usize(meta, "K")?);
    let idx_u32: Vec<u32> = idx
        .iter()
        .map(|&i| {
            if i < 0 || i as usize >= k {
                bail!("n:m:g idx entry {i} out of range for K={k}");
            }
            Ok(i as u32)
        })
        .collect::<Result<_>>()?;
    Ok(NmgTensor::from_flat([mdim, k], n, m, g, val.data().to_vec(), idx_u32))
}

/// Collect the named f32 inputs of a spec into a name -> tensor map.
fn named_f32_inputs<'a>(
    spec: &ArtifactSpec,
    inputs: &'a [Value],
) -> Result<BTreeMap<String, &'a DenseTensor>> {
    let mut map = BTreeMap::new();
    for (io, v) in spec.inputs.iter().zip(inputs) {
        if let Value::F32(t) = v {
            map.insert(io.name.clone(), &**t);
        }
    }
    Ok(map)
}

/// View a (B, S, D)-shaped tensor as (B*S, D) rows.
fn to_rows(x: &DenseTensor, d: usize) -> DenseTensor {
    x.reshape(&[x.numel() / d, d])
}

// ---------------------------------------------------------------------------
// Encoder blocks (forward + caches)
// ---------------------------------------------------------------------------

struct AttnWeights<'a> {
    ln_g: &'a DenseTensor,
    ln_b: &'a DenseTensor,
    wq: &'a DenseTensor,
    bq: &'a DenseTensor,
    wk: &'a DenseTensor,
    bk: &'a DenseTensor,
    wv: &'a DenseTensor,
    bv: &'a DenseTensor,
    wo: &'a DenseTensor,
    bo: &'a DenseTensor,
}

struct AttnCache {
    y: DenseTensor,
    q: DenseTensor,
    k: DenseTensor,
    v: DenseTensor,
    /// Softmax probabilities per (batch, head), each (S, S).
    att: Vec<DenseTensor>,
    o: DenseTensor,
}

/// Copy a rectangular block `rows [r0, r0+nr) x cols [c0, c0+nc)`.
fn block(t: &DenseTensor, r0: usize, nr: usize, c0: usize, nc: usize) -> DenseTensor {
    let cols = t.cols();
    let mut out = vec![0f32; nr * nc];
    for r in 0..nr {
        let src = (r0 + r) * cols + c0;
        out[r * nc..(r + 1) * nc].copy_from_slice(&t.data()[src..src + nc]);
    }
    DenseTensor::from_vec(&[nr, nc], out)
}

/// Accumulate `src` into the (r0, c0)-offset block of a row-major buffer
/// with `dst_cols` columns.
///
/// # Safety
///
/// The caller must guarantee that no other thread touches the target block
/// `rows [r0, r0 + src.rows()) x cols [c0, c0 + src.cols())` concurrently
/// (the attention fan-out assigns each `(batch, head)` pair a disjoint
/// block).
unsafe fn add_block_raw(dst: *mut f32, dst_cols: usize, r0: usize, c0: usize, src: &DenseTensor) {
    let (nr, nc) = (src.rows(), src.cols());
    let sd = src.data();
    for r in 0..nr {
        let base = (r0 + r) * dst_cols + c0;
        for c in 0..nc {
            // SAFETY: in-bounds by the caller's contract (the target block
            // lies inside `dst`), and exclusive by the same contract (no
            // other thread touches this block).
            unsafe { *dst.add(base + c) += sd[r * nc + c] };
        }
    }
}

/// Column sums of a 2-D tensor (bias gradients), parallel over disjoint
/// column stripes. Each column accumulates its rows in ascending order, so
/// the result is bit-identical to the serial loop.
fn col_sum(t: &DenseTensor) -> DenseTensor {
    let (r, c) = (t.rows(), t.cols());
    let mut out = vec![0f32; c];
    let td = t.data();
    let out_ptr = threadpool::SyncPtr::new(out.as_mut_ptr());
    threadpool::parallel_for(c, 64, |c0, c1| {
        // SAFETY: columns [c0, c1) of out are written only by this chunk.
        let o = unsafe { std::slice::from_raw_parts_mut(out_ptr.get().add(c0), c1 - c0) };
        for i in 0..r {
            let row = &td[i * c + c0..i * c + c1];
            for (oj, &v) in o.iter_mut().zip(row) {
                *oj += v;
            }
        }
    });
    DenseTensor::from_vec(&[c], out)
}

/// Pre-LN multi-head self-attention with residual over (B*S, D) rows.
///
/// The score/softmax/value pipeline fans out over `(batch, head)` pairs as
/// pool tasks: every pair writes a disjoint rows-x-columns block of `o` and
/// its own `att` slot, so the fan-out is lock-free and the result is
/// deterministic under any scheduling. The per-pair GEMMs use the serial
/// blocked kernel — the pair fan-out is the parallel axis; a nested scope
/// per tiny GEMM would only add queueing overhead.
fn attn_forward(
    x: &DenseTensor,
    w: &AttnWeights,
    b: usize,
    s: usize,
    heads: usize,
) -> (DenseTensor, AttnCache) {
    let d = x.cols();
    let hd = d / heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let y = elementwise::layernorm_rows(x, w.ln_g.data(), w.ln_b.data());
    let q = elementwise::bias_add(&dense_gemm::matmul(&y, w.wq), w.bq.data());
    let k = elementwise::bias_add(&dense_gemm::matmul(&y, w.wk), w.bk.data());
    let v = elementwise::bias_add(&dense_gemm::matmul(&y, w.wv), w.bv.data());
    let mut o = DenseTensor::zeros(&[b * s, d]);
    let pairs = b * heads;
    let mut att: Vec<Option<DenseTensor>> = (0..pairs).map(|_| None).collect();
    {
        let o_ptr = threadpool::SyncPtr::new(o.data_mut().as_mut_ptr());
        let att_ptr = threadpool::SyncPtr::new(att.as_mut_ptr());
        threadpool::parallel_for(pairs, 1, |p0, p1| {
            for pair in p0..p1 {
                let (bi, h) = (pair / heads, pair % heads);
                let qb = block(&q, bi * s, s, h * hd, hd);
                let kb = block(&k, bi * s, s, h * hd, hd);
                let vb = block(&v, bi * s, s, h * hd, hd);
                let mut scores = dense_gemm::matmul_serial(&qb, &kb.transpose2());
                scores.scale(scale);
                let a = elementwise::softmax_rows(&scores);
                let ob = dense_gemm::matmul_serial(&a, &vb);
                // SAFETY: pair (bi, h) owns rows [bi*s, (bi+1)*s) x cols
                // [h*hd, (h+1)*hd) of `o` and slot `pair` of `att`.
                unsafe {
                    add_block_raw(o_ptr.get(), d, bi * s, h * hd, &ob);
                    *att_ptr.get().add(pair) = Some(a);
                }
            }
        });
    }
    let att: Vec<DenseTensor> =
        att.into_iter().map(|a| a.expect("missing attention head")).collect();
    let proj = elementwise::bias_add(&dense_gemm::matmul(&o, w.wo), w.bo.data());
    let out = x.zip(&proj, |a, c| a + c);
    (out, AttnCache { y, q, k, v, att, o })
}

struct FfnWeights<'a> {
    ln_g: &'a DenseTensor,
    ln_b: &'a DenseTensor,
    w1: &'a DenseTensor,
    b1: &'a DenseTensor,
    w2: &'a DenseTensor,
    b2: &'a DenseTensor,
}

struct FfnCache {
    y: DenseTensor,
    hpre: DenseTensor,
    h: DenseTensor,
    /// Effective (possibly masked) first/second weights.
    w1e: DenseTensor,
    w2e: DenseTensor,
}

/// Pre-LN GeLU FFN with residual; `masks` applies emulated sparsity to the
/// two linear weights (the training-path form).
fn ffn_forward(
    x: &DenseTensor,
    w: &FfnWeights,
    masks: Option<(&DenseTensor, &DenseTensor)>,
) -> (DenseTensor, FfnCache) {
    let y = elementwise::layernorm_rows(x, w.ln_g.data(), w.ln_b.data());
    let (w1e, w2e) = match masks {
        Some((m1, m2)) => (w.w1.zip(m1, |v, m| v * m), w.w2.zip(m2, |v, m| v * m)),
        None => (w.w1.clone(), w.w2.clone()),
    };
    let hpre = elementwise::bias_add(&dense_gemm::matmul(&y, &w1e), w.b1.data());
    let h = elementwise::gelu(&hpre);
    let o = elementwise::bias_add(&dense_gemm::matmul(&h, &w2e), w.b2.data());
    let out = x.zip(&o, |a, c| a + c);
    (out, FfnCache { y, hpre, h, w1e, w2e })
}

fn embed_forward(
    emb: &DenseTensor,
    pos: &DenseTensor,
    tokens: &[i32],
    cfg: &EncoderCfg,
) -> DenseTensor {
    let (d, s, v) = (cfg.d_model, cfg.seq, cfg.vocab);
    let rows = tokens.len();
    let mut out = vec![0f32; rows * d];
    let embd = emb.data();
    let posd = pos.data();
    let out_ptr = threadpool::SyncPtr::new(out.as_mut_ptr());
    threadpool::parallel_for(rows, 16, |r0, r1| {
        // SAFETY: rows [r0, r1) are written only by this chunk.
        let od =
            unsafe { std::slice::from_raw_parts_mut(out_ptr.get().add(r0 * d), (r1 - r0) * d) };
        for r in r0..r1 {
            let tok = (tokens[r].rem_euclid(v as i32)) as usize;
            let e = &embd[tok * d..(tok + 1) * d];
            let p = &posd[(r % s) * d..(r % s + 1) * d];
            let orow = &mut od[(r - r0) * d..(r - r0 + 1) * d];
            for j in 0..d {
                orow[j] = e[j] + p[j];
            }
        }
    });
    DenseTensor::from_vec(&[rows, d], out)
}

struct LayerCache {
    x_attn: DenseTensor,
    attn: AttnCache,
    x_ffn: DenseTensor,
    ffn: FfnCache,
}

struct ForwardResult {
    logits: DenseTensor,
    /// (B*S, D) input to the final LayerNorm.
    x_final: DenseTensor,
    ln_out: DenseTensor,
    layers: Vec<LayerCache>,
}

/// Full encoder forward over (B*S) rows; `masks` (name -> mask) applies to
/// FFN weights when present (the training-path network).
fn encoder_forward(
    cfg: &EncoderCfg,
    p: &BTreeMap<String, &DenseTensor>,
    tokens: &[i32],
    masks: Option<&BTreeMap<String, &DenseTensor>>,
) -> ForwardResult {
    let mut x = embed_forward(p["emb"], p["pos"], tokens, cfg);
    let mut layers = Vec::with_capacity(cfg.n_layers);
    for l in 0..cfg.n_layers {
        let n = |s: &str| format!("layer{l}.{s}");
        let aw = AttnWeights {
            ln_g: p[&n("ln1_g")],
            ln_b: p[&n("ln1_b")],
            wq: p[&n("wq")],
            bq: p[&n("bq")],
            wk: p[&n("wk")],
            bk: p[&n("bk")],
            wv: p[&n("wv")],
            bv: p[&n("bv")],
            wo: p[&n("wo")],
            bo: p[&n("bo")],
        };
        let (x1, attn) = attn_forward(&x, &aw, cfg.batch, cfg.seq, cfg.n_heads);
        let fw = FfnWeights {
            ln_g: p[&n("ln2_g")],
            ln_b: p[&n("ln2_b")],
            w1: p[&n("w1")],
            b1: p[&n("b1")],
            w2: p[&n("w2")],
            b2: p[&n("b2")],
        };
        let layer_masks = masks.map(|m| (m[&n("w1")], m[&n("w2")]));
        let (x2, ffn) = ffn_forward(&x1, &fw, layer_masks);
        layers.push(LayerCache { x_attn: x, attn, x_ffn: x1, ffn });
        x = x2;
    }
    let ln_out = elementwise::layernorm_rows(&x, p["lnf_g"].data(), p["lnf_b"].data());
    let logits =
        elementwise::bias_add(&dense_gemm::matmul(&ln_out, p["out_w"]), p["out_b"].data());
    ForwardResult { logits, x_final: x, ln_out, layers }
}

// ---------------------------------------------------------------------------
// Backward pass
// ---------------------------------------------------------------------------

/// LayerNorm backward: recomputes row statistics from `x` and returns
/// `(dx, dgamma, dbeta)`. Rows run in fixed blocks on the pool; per-block
/// dgamma/dbeta partials are merged in block order afterwards, so the
/// result is deterministic under any scheduling.
fn layernorm_backward(
    x: &DenseTensor,
    gamma: &[f32],
    dy: &DenseTensor,
) -> (DenseTensor, DenseTensor, DenseTensor) {
    const BLOCK_ROWS: usize = 32;
    let (r, c) = (x.rows(), x.cols());
    let nblocks = r.div_ceil(BLOCK_ROWS);
    let mut dx = vec![0f32; r * c];
    let mut partials: Vec<Option<(Vec<f32>, Vec<f32>)>> = (0..nblocks).map(|_| None).collect();
    {
        let xd = x.data();
        let dyd = dy.data();
        let dx_ptr = threadpool::SyncPtr::new(dx.as_mut_ptr());
        let part_ptr = threadpool::SyncPtr::new(partials.as_mut_ptr());
        threadpool::parallel_for(nblocks, 1, |b0, b1| {
            for blk in b0..b1 {
                let i0 = blk * BLOCK_ROWS;
                let i1 = (i0 + BLOCK_ROWS).min(r);
                let mut dgamma = vec![0f32; c];
                let mut dbeta = vec![0f32; c];
                // SAFETY: rows [i0, i1) of dx and partial slot blk are
                // written only by this block.
                let dxs = unsafe {
                    std::slice::from_raw_parts_mut(dx_ptr.get().add(i0 * c), (i1 - i0) * c)
                };
                for i in i0..i1 {
                    let row = &xd[i * c..(i + 1) * c];
                    let dyr = &dyd[i * c..(i + 1) * c];
                    let mean = row.iter().sum::<f32>() / c as f32;
                    let var =
                        row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / c as f32;
                    let inv = 1.0 / (var + 1e-5).sqrt();
                    let mut m1 = 0f32; // mean of dxhat
                    let mut m2 = 0f32; // mean of dxhat * xhat
                    for j in 0..c {
                        let xhat = (row[j] - mean) * inv;
                        let dxhat = dyr[j] * gamma[j];
                        dgamma[j] += dyr[j] * xhat;
                        dbeta[j] += dyr[j];
                        m1 += dxhat;
                        m2 += dxhat * xhat;
                    }
                    m1 /= c as f32;
                    m2 /= c as f32;
                    let dxrow = &mut dxs[(i - i0) * c..(i - i0 + 1) * c];
                    for j in 0..c {
                        let xhat = (row[j] - mean) * inv;
                        let dxhat = dyr[j] * gamma[j];
                        dxrow[j] = inv * (dxhat - m1 - xhat * m2);
                    }
                }
                // SAFETY: partial slot `blk` belongs to this block alone
                // (one slot per chunk index, chunks are disjoint).
                unsafe {
                    *part_ptr.get().add(blk) = Some((dgamma, dbeta));
                }
            }
        });
    }
    let mut dgamma = vec![0f32; c];
    let mut dbeta = vec![0f32; c];
    for p in partials {
        let (g, bt) = p.expect("missing layernorm backward block");
        for j in 0..c {
            dgamma[j] += g[j];
            dbeta[j] += bt[j];
        }
    }
    (
        DenseTensor::from_vec(&[r, c], dx),
        DenseTensor::from_vec(&[c], dgamma),
        DenseTensor::from_vec(&[c], dbeta),
    )
}

/// Gradient accumulation store keyed by parameter name.
#[derive(Default)]
struct GradStore {
    grads: BTreeMap<String, DenseTensor>,
}

impl GradStore {
    fn add(&mut self, name: &str, g: DenseTensor) {
        self.grads
            .entry(name.to_string())
            .and_modify(|acc| acc.axpy(1.0, &g))
            .or_insert(g);
    }
}

/// Attention backward; returns dx and accumulates parameter grads under
/// `layer{l}.` names.
#[allow(clippy::too_many_arguments)]
fn attn_backward(
    w: &AttnWeights,
    cache: &AttnCache,
    x: &DenseTensor,
    dout: &DenseTensor,
    grads: &mut GradStore,
    l: usize,
    b: usize,
    s: usize,
    heads: usize,
) -> DenseTensor {
    let d = x.cols();
    let hd = d / heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let n = |nm: &str| format!("layer{l}.{nm}");

    // out = x + o @ wo + bo
    let mut dx = dout.clone();
    grads.add(&n("wo"), dense_gemm::matmul(&cache.o.transpose2(), dout));
    grads.add(&n("bo"), col_sum(dout));
    let do_ = dense_gemm::matmul(dout, &w.wo.transpose2());

    let mut dq = DenseTensor::zeros(&[b * s, d]);
    let mut dk = DenseTensor::zeros(&[b * s, d]);
    let mut dv = DenseTensor::zeros(&[b * s, d]);
    // Mirror of the forward fan-out: one pool task per (batch, head) pair,
    // each writing disjoint blocks of dq/dk/dv with serial per-pair GEMMs.
    let pairs = b * heads;
    {
        let dq_ptr = threadpool::SyncPtr::new(dq.data_mut().as_mut_ptr());
        let dk_ptr = threadpool::SyncPtr::new(dk.data_mut().as_mut_ptr());
        let dv_ptr = threadpool::SyncPtr::new(dv.data_mut().as_mut_ptr());
        threadpool::parallel_for(pairs, 1, |p0, p1| {
            for pair in p0..p1 {
                let (bi, h) = (pair / heads, pair % heads);
                let a = &cache.att[pair];
                let qb = block(&cache.q, bi * s, s, h * hd, hd);
                let kb = block(&cache.k, bi * s, s, h * hd, hd);
                let vb = block(&cache.v, bi * s, s, h * hd, hd);
                let dob = block(&do_, bi * s, s, h * hd, hd);
                let da = dense_gemm::matmul_serial(&dob, &vb.transpose2());
                let dvb = dense_gemm::matmul_serial(&a.transpose2(), &dob);
                // Softmax backward per row: ds = a * (da - sum(da * a)).
                let mut ds = DenseTensor::zeros(&[s, s]);
                for i in 0..s {
                    let ar = &a.data()[i * s..(i + 1) * s];
                    let dar = &da.data()[i * s..(i + 1) * s];
                    let dot: f32 = ar.iter().zip(dar).map(|(&p, &g)| p * g).sum();
                    for j in 0..s {
                        ds.data_mut()[i * s + j] = ar[j] * (dar[j] - dot);
                    }
                }
                let mut dqb = dense_gemm::matmul_serial(&ds, &kb);
                dqb.scale(scale);
                let mut dkb = dense_gemm::matmul_serial(&ds.transpose2(), &qb);
                dkb.scale(scale);
                // SAFETY: pair (bi, h) owns the disjoint block rows
                // [bi*s, (bi+1)*s) x cols [h*hd, (h+1)*hd) of dq/dk/dv.
                unsafe {
                    add_block_raw(dq_ptr.get(), d, bi * s, h * hd, &dqb);
                    add_block_raw(dk_ptr.get(), d, bi * s, h * hd, &dkb);
                    add_block_raw(dv_ptr.get(), d, bi * s, h * hd, &dvb);
                }
            }
        });
    }

    // q = y @ wq + bq (and likewise k, v).
    let yt = cache.y.transpose2();
    grads.add(&n("wq"), dense_gemm::matmul(&yt, &dq));
    grads.add(&n("bq"), col_sum(&dq));
    grads.add(&n("wk"), dense_gemm::matmul(&yt, &dk));
    grads.add(&n("bk"), col_sum(&dk));
    grads.add(&n("wv"), dense_gemm::matmul(&yt, &dv));
    grads.add(&n("bv"), col_sum(&dv));
    let mut dy = dense_gemm::matmul(&dq, &w.wq.transpose2());
    dy.axpy(1.0, &dense_gemm::matmul(&dk, &w.wk.transpose2()));
    dy.axpy(1.0, &dense_gemm::matmul(&dv, &w.wv.transpose2()));

    let (dx_ln, dg, db) = layernorm_backward(x, w.ln_g.data(), &dy);
    grads.add(&n("ln1_g"), dg);
    grads.add(&n("ln1_b"), db);
    dx.axpy(1.0, &dx_ln);
    dx
}

/// FFN backward (masked weights); returns dx, accumulates grads.
fn ffn_backward(
    w: &FfnWeights,
    cache: &FfnCache,
    x: &DenseTensor,
    dout: &DenseTensor,
    masks: Option<(&DenseTensor, &DenseTensor)>,
    grads: &mut GradStore,
    l: usize,
) -> DenseTensor {
    let n = |nm: &str| format!("layer{l}.{nm}");
    // out = x + h @ w2e + b2
    let mut dx = dout.clone();
    let mut dw2 = dense_gemm::matmul(&cache.h.transpose2(), dout);
    if let Some((_, m2)) = masks {
        dw2 = dw2.zip(m2, |g, m| g * m);
    }
    grads.add(&n("w2"), dw2);
    grads.add(&n("b2"), col_sum(dout));
    let dh = dense_gemm::matmul(dout, &cache.w2e.transpose2());
    let dhpre = dh.zip(&elementwise::gelu_grad(&cache.hpre), |g, d| g * d);
    let mut dw1 = dense_gemm::matmul(&cache.y.transpose2(), &dhpre);
    if let Some((m1, _)) = masks {
        dw1 = dw1.zip(m1, |g, m| g * m);
    }
    grads.add(&n("w1"), dw1);
    grads.add(&n("b1"), col_sum(&dhpre));
    let dy = dense_gemm::matmul(&dhpre, &cache.w1e.transpose2());
    let (dx_ln, dg, db) = layernorm_backward(x, w.ln_g.data(), &dy);
    grads.add(&n("ln2_g"), dg);
    grads.add(&n("ln2_b"), db);
    dx.axpy(1.0, &dx_ln);
    dx
}

/// Mean token-level cross-entropy and its logits gradient. The per-row
/// log-sum-exp and gradient adjustments run in fixed row blocks on the
/// pool; block losses merge in block order (deterministic).
fn cross_entropy(logits: &DenseTensor, targets: &[i32], vocab: usize) -> (f32, DenseTensor) {
    const BLOCK_ROWS: usize = 64;
    let (rows, v) = (logits.rows(), logits.cols());
    assert_eq!(rows, targets.len());
    let mut dl = elementwise::softmax_rows(logits);
    let nblocks = rows.div_ceil(BLOCK_ROWS);
    let mut block_loss = vec![0f64; nblocks];
    {
        let ld = logits.data();
        let dl_ptr = threadpool::SyncPtr::new(dl.data_mut().as_mut_ptr());
        let loss_ptr = threadpool::SyncPtr::new(block_loss.as_mut_ptr());
        threadpool::parallel_for(nblocks, 1, |b0, b1| {
            for blk in b0..b1 {
                let i0 = blk * BLOCK_ROWS;
                let i1 = (i0 + BLOCK_ROWS).min(rows);
                let mut local = 0f64;
                for i in i0..i1 {
                    let y = (targets[i].rem_euclid(vocab as i32)) as usize;
                    let row = &ld[i * v..(i + 1) * v];
                    let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                    let lse = mx + row.iter().map(|&x| (x - mx).exp()).sum::<f32>().ln();
                    local += (lse - row[y]) as f64;
                    // SAFETY: row i of dl and slot blk are owned by this
                    // block.
                    unsafe {
                        *dl_ptr.get().add(i * v + y) -= 1.0;
                    }
                }
                unsafe {
                    *loss_ptr.get().add(blk) = local;
                }
            }
        });
    }
    let loss: f64 = block_loss.iter().sum();
    dl.scale(1.0 / rows as f32);
    ((loss / rows as f64) as f32, dl)
}

/// One masked-SGD train step: `(loss, *updated_params)`.
fn train_step(spec: &ArtifactSpec, inputs: &[Value]) -> Result<Vec<Value>> {
    let cfg = cfg_from_meta(&spec.meta)?;
    let mut params: BTreeMap<String, &DenseTensor> = BTreeMap::new();
    let mut masks: BTreeMap<String, &DenseTensor> = BTreeMap::new();
    let mut param_order: Vec<String> = Vec::new();
    for (io, v) in spec.inputs.iter().zip(inputs) {
        match (io.name.as_str(), v) {
            ("tokens", _) | ("targets", _) => {}
            ("lr", Value::F32(_)) => {}
            (name, Value::F32(t)) if name.starts_with("mask.") => {
                masks.insert(name.trim_start_matches("mask.").to_string(), &**t);
            }
            (name, Value::F32(t)) => {
                params.insert(name.to_string(), &**t);
                param_order.push(name.to_string());
            }
            _ => {}
        }
    }
    let tokens = i32_in(inputs, spec.input_index("tokens")?)?;
    let targets = i32_in(inputs, spec.input_index("targets")?)?;
    let lr = f32_in(inputs, spec.input_index("lr")?)?.data()[0];

    let fwd = encoder_forward(&cfg, &params, tokens, Some(&masks));
    let (loss, dlogits) = cross_entropy(&fwd.logits, targets, cfg.vocab);

    let mut grads = GradStore::default();
    // LM head: logits = ln_out @ out_w + out_b.
    grads.add("out_w", dense_gemm::matmul(&fwd.ln_out.transpose2(), &dlogits));
    grads.add("out_b", col_sum(&dlogits));
    let d_ln_out = dense_gemm::matmul(&dlogits, &params["out_w"].transpose2());
    let (mut dx, dg, db) = layernorm_backward(&fwd.x_final, params["lnf_g"].data(), &d_ln_out);
    grads.add("lnf_g", dg);
    grads.add("lnf_b", db);

    for l in (0..cfg.n_layers).rev() {
        let n = |s: &str| format!("layer{l}.{s}");
        let cache = &fwd.layers[l];
        let fw = FfnWeights {
            ln_g: params[&n("ln2_g")],
            ln_b: params[&n("ln2_b")],
            w1: params[&n("w1")],
            b1: params[&n("b1")],
            w2: params[&n("w2")],
            b2: params[&n("b2")],
        };
        let layer_masks = Some((masks[&n("w1")], masks[&n("w2")]));
        dx = ffn_backward(&fw, &cache.ffn, &cache.x_ffn, &dx, layer_masks, &mut grads, l);
        let aw = AttnWeights {
            ln_g: params[&n("ln1_g")],
            ln_b: params[&n("ln1_b")],
            wq: params[&n("wq")],
            bq: params[&n("bq")],
            wk: params[&n("wk")],
            bk: params[&n("bk")],
            wv: params[&n("wv")],
            bv: params[&n("bv")],
            wo: params[&n("wo")],
            bo: params[&n("bo")],
        };
        dx = attn_backward(
            &aw, &cache.attn, &cache.x_attn, &dx, &mut grads, l, cfg.batch, cfg.seq, cfg.n_heads,
        );
    }

    // Embedding backward: scatter-add token rows; positional sum over
    // batch. Repeated tokens collide on demb rows, so the parallel axis is
    // the *column* stripe: each thread owns columns [j0, j1) of demb/dpos
    // and accumulates all rows in ascending order — race-free and
    // bit-identical to the serial scatter.
    let d = cfg.d_model;
    let mut demb = DenseTensor::zeros(&[cfg.vocab, d]);
    let mut dpos = DenseTensor::zeros(&[cfg.seq, d]);
    {
        let dxd = dx.data();
        let demb_ptr = threadpool::SyncPtr::new(demb.data_mut().as_mut_ptr());
        let dpos_ptr = threadpool::SyncPtr::new(dpos.data_mut().as_mut_ptr());
        threadpool::parallel_for(d, 32, |j0, j1| {
            for (r, &t) in tokens.iter().enumerate() {
                let tok = (t.rem_euclid(cfg.vocab as i32)) as usize;
                let si = r % cfg.seq;
                for j in j0..j1 {
                    let g = dxd[r * d + j];
                    // SAFETY: columns [j0, j1) of demb/dpos are owned here.
                    unsafe {
                        *demb_ptr.get().add(tok * d + j) += g;
                        *dpos_ptr.get().add(si * d + j) += g;
                    }
                }
            }
        });
    }
    grads.add("emb", demb);
    grads.add("pos", dpos);

    // Updates: q = p - lr * grad, re-masked for masked params (Fig. 2).
    let mut out = vec![Value::from(DenseTensor::from_vec(&[], vec![loss]))];
    for name in &param_order {
        let mut q = (*params[name]).clone();
        if let Some(g) = grads.grads.get(name) {
            q.axpy(-lr, g);
        }
        if let Some(mask) = masks.get(name) {
            q = q.zip(mask, |v, m| v * m);
        }
        out.push(Value::from(q));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn micro_cfg() -> EncoderCfg {
        EncoderCfg { vocab: 11, seq: 3, batch: 2, d_model: 8, n_heads: 2, d_ff: 12, n_layers: 1 }
    }

    fn micro_train_spec() -> ArtifactSpec {
        let mut specs = Vec::new();
        push_encoder_specs(&mut specs, &micro_cfg(), "micro");
        specs.into_iter().find(|s| s.name == "train_step_micro").unwrap()
    }

    /// Deterministic inputs for the micro train step (masks all ones unless
    /// `sparse`, in which case every other mask element is zeroed).
    fn micro_inputs(spec: &ArtifactSpec, sparse: bool) -> Vec<Value> {
        let cfg = micro_cfg();
        let mut rng = Pcg64::seeded(99);
        let mut inputs = Vec::new();
        for io in &spec.inputs {
            let v = match io.name.as_str() {
                "tokens" | "targets" => Value::I32(
                    io.shape.clone(),
                    (0..io.numel()).map(|_| rng.below(cfg.vocab as u32) as i32).collect(),
                ),
                "lr" => Value::from(DenseTensor::from_vec(&[], vec![0.05])),
                name if name.starts_with("mask.") => {
                    let data = (0..io.numel())
                        .map(|i| if sparse && i % 2 == 0 { 0.0 } else { 1.0 })
                        .collect();
                    Value::from(DenseTensor::from_vec(&io.shape, data))
                }
                name if name.ends_with("_g") => Value::from(DenseTensor::ones(&io.shape)),
                _ if io.shape.len() == 2 => {
                    let mut w = DenseTensor::randn(&io.shape, &mut rng);
                    w.scale(0.15);
                    Value::from(w)
                }
                _ => Value::from(DenseTensor::zeros(&io.shape)),
            };
            inputs.push(v);
        }
        inputs
    }

    fn loss_of(spec: &ArtifactSpec, inputs: &[Value]) -> f32 {
        let mut zero_lr = inputs.to_vec();
        let li = spec.input_index("lr").unwrap();
        zero_lr[li] = Value::from(DenseTensor::from_vec(&[], vec![0.0]));
        let out = execute(spec, &zero_lr).unwrap();
        out[0].as_f32().unwrap().data()[0]
    }

    #[test]
    fn builtin_manifest_has_expected_artifacts() {
        let m = builtin_manifest();
        for name in [
            "gemm_dense_8x48x16",
            "gemm_masked_64x192x128",
            "gemm_nmg_8x48x16",
            "gemm_nmg_16x96x64",
            "encoder_fwd_tiny",
            "attn_block_base",
            "ffn_block_nmg_tiny",
            "train_step_tiny",
            "embed_base",
            "lm_head_tiny",
        ] {
            assert!(m.get(name).is_ok(), "missing {name}");
        }
    }

    #[test]
    fn embed_adds_positional() {
        let cfg = micro_cfg();
        let mut rng = Pcg64::seeded(3);
        let emb = DenseTensor::randn(&[cfg.vocab, cfg.d_model], &mut rng);
        let pos = DenseTensor::randn(&[cfg.seq, cfg.d_model], &mut rng);
        let tokens: Vec<i32> = vec![1, 2, 3, 4, 5, 6];
        let x = embed_forward(&emb, &pos, &tokens, &cfg);
        let want = emb.data()[cfg.d_model] + pos.data()[0];
        assert!((x.data()[0] - want).abs() < 1e-6);
        // Row 4 is batch 1, position 1, token 5.
        let want = emb.data()[5 * cfg.d_model + 2] + pos.data()[cfg.d_model + 2];
        assert!((x.get2(4, 2) - want).abs() < 1e-6);
    }

    #[test]
    fn attention_rows_are_convex_combinations() {
        let cfg = micro_cfg();
        let mut rng = Pcg64::seeded(5);
        let d = cfg.d_model;
        let x = DenseTensor::randn(&[cfg.batch * cfg.seq, d], &mut rng);
        let ln_g = DenseTensor::ones(&[d]);
        let ln_b = DenseTensor::zeros(&[d]);
        let mk = |rng: &mut Pcg64| DenseTensor::randn(&[d, d], rng);
        let (wq, wk, wv, wo) = (mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng));
        let z = DenseTensor::zeros(&[d]);
        let w = AttnWeights {
            ln_g: &ln_g, ln_b: &ln_b,
            wq: &wq, bq: &z, wk: &wk, bk: &z, wv: &wv, bv: &z, wo: &wo, bo: &z,
        };
        let (out, cache) = attn_forward(&x, &w, cfg.batch, cfg.seq, cfg.n_heads);
        assert_eq!(out.shape(), x.shape());
        for a in &cache.att {
            for i in 0..cfg.seq {
                let sum: f32 = a.data()[i * cfg.seq..(i + 1) * cfg.seq].iter().sum();
                assert!((sum - 1.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn gemm_nmg_roundtrips_through_flat_layout() {
        let m = builtin_manifest();
        let spec = m.get("gemm_nmg_8x48x16").unwrap().clone();
        let mut rng = Pcg64::seeded(7);
        let a = DenseTensor::randn(&[8, 48], &mut rng);
        let sparse = NmgTensor::from_dense(&a, 2, 4, 4);
        let b = DenseTensor::randn(&[48, 16], &mut rng);
        let val_spec = &spec.inputs[spec.input_index("val").unwrap()];
        let idx_spec = &spec.inputs[spec.input_index("idx").unwrap()];
        let inputs = vec![
            Value::from(DenseTensor::from_vec(&val_spec.shape, sparse.val_flat().to_vec())),
            Value::I32(idx_spec.shape.clone(), sparse.idx_flat().iter().map(|&i| i as i32).collect()),
            Value::from(b.clone()),
        ];
        let got = execute(&spec, &inputs).unwrap().remove(0).into_f32().unwrap();
        let want = nmg_gemm::spmm(&sparse, &b);
        assert!(got.allclose(&want, 1e-5, 1e-5), "max diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn train_step_gradients_match_finite_difference() {
        let spec = micro_train_spec();
        let inputs = micro_inputs(&spec, false);
        // lr = 1 makes the update read back the raw gradient: g = p - p'.
        let mut lr1 = inputs.clone();
        let li = spec.input_index("lr").unwrap();
        lr1[li] = Value::from(DenseTensor::from_vec(&[], vec![1.0]));
        let out = execute(&spec, &lr1).unwrap();

        let eps = 1e-2f32;
        // Sample a few coordinates across qualitatively different params.
        for (pname, coord) in [
            ("emb", 13usize),
            ("pos", 5),
            ("layer0.wq", 17),
            ("layer0.wo", 3),
            ("layer0.w1", 29),
            ("layer0.w2", 41),
            ("layer0.ln1_g", 2),
            ("out_w", 19),
            ("layer0.b1", 4),
        ] {
            let pi = spec.input_index(pname).unwrap();
            let p0 = inputs[pi].as_f32().unwrap().clone();
            let coord = coord % p0.numel();
            let grad = p0.data()[coord] - out[1 + pi].as_f32().unwrap().data()[coord];

            let mut up = inputs.clone();
            let mut t = p0.clone();
            t.data_mut()[coord] += eps;
            up[pi] = Value::from(t);
            let mut dn = inputs.clone();
            let mut t = p0.clone();
            t.data_mut()[coord] -= eps;
            dn[pi] = Value::from(t);
            let fd = (loss_of(&spec, &up) - loss_of(&spec, &dn)) / (2.0 * eps);
            assert!(
                (fd - grad).abs() < 2e-2 * (1.0 + fd.abs()),
                "{pname}[{coord}]: fd {fd} vs analytic {grad}"
            );
        }
    }

    #[test]
    fn train_step_decreases_loss_and_keeps_masks() {
        let spec = micro_train_spec();
        let mut inputs = micro_inputs(&spec, true);
        let n_params = spec.outputs.len() - 1;
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..5 {
            let out = execute(&spec, &inputs).unwrap();
            last = out[0].as_f32().unwrap().data()[0];
            first.get_or_insert(last);
            for (j, v) in out.into_iter().skip(1).enumerate() {
                inputs[j] = v;
            }
            assert_eq!(n_params + 1, spec.outputs.len());
        }
        assert!(last < first.unwrap(), "loss did not decrease: {last} !< {first:?}");
        // Masked params stay masked.
        for (i, io) in spec.inputs.iter().enumerate() {
            if let Some(pname) = io.name.strip_prefix("mask.") {
                let pi = spec.input_index(pname).unwrap();
                let p = inputs[pi].as_f32().unwrap();
                let m = inputs[i].as_f32().unwrap();
                let leaked = p
                    .data()
                    .iter()
                    .zip(m.data())
                    .filter(|&(v, mk)| *mk == 0.0 && *v != 0.0)
                    .count();
                assert_eq!(leaked, 0, "{pname} leaked {leaked} masked weights");
            }
        }
    }
}
