//! Sparsifiers (§3.3, Table 1): decide which output values to keep.
//!
//! Each sparsifier is classified by how much data it needs before it can
//! produce output:
//!
//! | Sparsifier          | Example           | Passes | Memory  | Kind          |
//! |---------------------|-------------------|--------|---------|---------------|
//! | [`KeepAll`]         | sparse add        | 1      | O(1)    | Streaming     |
//! | [`RandomFraction`]  | dropout           | 1      | O(1)    | Streaming     |
//! | [`ScalarThreshold`] | ReLU              | 1      | O(1)    | Streaming     |
//! | [`PerBlockNm`]      | n:m               | 2      | O(b)    | Blocking      |
//! | [`GroupedNm`]       | n:m:g (§5)        | 2      | O(b)    | Blocking      |
//! | [`ScalarFraction`]  | magnitude pruning | 2      | O(nnz)  | Materializing |
//! | [`BlockFraction`]   | block magnitude   | 2      | O(nnz)  | Materializing |
//! | [`SameFormat`]      | in-place updates  | 1      | O(nnz)  | Materializing |

mod registry;
pub mod movement;
pub use movement::MovementPruning;
pub use registry::{register_sparsifier_impl, sparsifier_registry, SparsifierImplFn};

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Result};

use crate::formats::{
    AnyTensor, CooTensor, CscTensor, CsrTensor, EllTensor, Layout, MaskedTensor, NmTensor,
    NmgTensor,
};
use crate::tensor::DenseTensor;
use crate::util::rng::Pcg64;

/// Classification by data requirements (Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SparsifierKind {
    /// One value at a time; can be fused (inlined) into the producing operator.
    Streaming,
    /// Needs a small block of values.
    Blocking,
    /// Needs the fully materialized tensor.
    Materializing,
}

/// Memory requirement class (Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemoryClass {
    /// O(1).
    Constant,
    /// O(block size).
    Block,
    /// O(nnz).
    Nnz,
}

/// A sparsifier: prunes a dense tensor (sets dropped values to zero) and
/// reports its Table-1 characteristics. Conversion of the pruned result into
/// a target layout happens in [`Sparsifier::apply`].
pub trait Sparsifier: std::fmt::Debug + Send + Sync {
    /// Stable name used as the dispatch-registry key.
    fn name(&self) -> &'static str;
    /// Streaming / blocking / materializing.
    fn kind(&self) -> SparsifierKind;
    /// Number of passes over the tensor (Table 1).
    fn passes(&self) -> usize;
    /// Memory requirement class (Table 1).
    fn memory(&self) -> MemoryClass;
    /// Prune: return a same-shape dense tensor with dropped values zeroed.
    fn prune(&self, t: &DenseTensor) -> DenseTensor;

    /// Sparsify `t` into `out` layout: prune, then compress.
    ///
    /// Structured output layouts (n:m, n:m:g) are only valid for sparsifiers
    /// that produce conforming structure; other combinations error, exactly
    /// like a missing registered implementation in STen (the caller may then
    /// fall back through the dispatcher).
    fn apply(&self, t: &AnyTensor, out: Layout) -> Result<AnyTensor> {
        let pruned = self.prune(&t.to_dense());
        dense_to_layout(&pruned, out, self.structure_params())
    }

    /// Structure parameters `(n, m, g)` if this sparsifier produces n:m(-like)
    /// structure; used to build structured output layouts.
    fn structure_params(&self) -> Option<(usize, usize, usize)> {
        None
    }
}

/// Compress an (already pruned) dense tensor into a layout.
pub fn dense_to_layout(
    pruned: &DenseTensor,
    out: Layout,
    structure: Option<(usize, usize, usize)>,
) -> Result<AnyTensor> {
    Ok(match out {
        Layout::Dense => AnyTensor::Dense(pruned.clone()),
        Layout::Csr => AnyTensor::Csr(CsrTensor::from_dense(pruned)),
        Layout::Csc => AnyTensor::Csc(CscTensor::from_dense(pruned)),
        Layout::Coo => AnyTensor::Coo(CooTensor::from_dense(pruned)),
        Layout::Ell => AnyTensor::Ell(EllTensor::from_dense(pruned)),
        Layout::Masked => AnyTensor::Masked(MaskedTensor::from_dense(pruned)),
        Layout::Nm => {
            let Some((n, m, _)) = structure else {
                bail!("output layout Nm requires an n:m-structured sparsifier");
            };
            AnyTensor::Nm(NmTensor::from_dense(pruned, n, m))
        }
        Layout::Nmg => {
            let Some((n, m, g)) = structure else {
                bail!("output layout Nmg requires an n:m:g-structured sparsifier");
            };
            AnyTensor::Nmg(NmgTensor::from_dense(pruned, n, m, g))
        }
        Layout::Bcsr | Layout::Custom => {
            bail!("no registered sparsifier implementation for output layout {out}")
        }
    })
}

/// Keep-all: the trivial sparsifier; default for dense outputs.
#[derive(Debug, Clone, Default)]
pub struct KeepAll;

impl Sparsifier for KeepAll {
    fn name(&self) -> &'static str {
        "keep_all"
    }
    fn kind(&self) -> SparsifierKind {
        SparsifierKind::Streaming
    }
    fn passes(&self) -> usize {
        1
    }
    fn memory(&self) -> MemoryClass {
        MemoryClass::Constant
    }
    fn prune(&self, t: &DenseTensor) -> DenseTensor {
        t.clone()
    }
}

/// Random-fraction sparsifier (dropout-style): drop each value with
/// probability `fraction`. Deterministic per instance via an internal
/// call counter.
#[derive(Debug)]
pub struct RandomFraction {
    /// Drop probability in [0, 1].
    pub fraction: f32,
    seed: u64,
    calls: AtomicU64,
}

impl RandomFraction {
    /// New with an explicit RNG seed.
    pub fn new(fraction: f32, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&fraction), "fraction must be in [0,1]");
        RandomFraction { fraction, seed, calls: AtomicU64::new(0) }
    }
}

impl Sparsifier for RandomFraction {
    fn name(&self) -> &'static str {
        "random_fraction"
    }
    fn kind(&self) -> SparsifierKind {
        SparsifierKind::Streaming
    }
    fn passes(&self) -> usize {
        1
    }
    fn memory(&self) -> MemoryClass {
        MemoryClass::Constant
    }
    fn prune(&self, t: &DenseTensor) -> DenseTensor {
        let call = self.calls.fetch_add(1, Ordering::Relaxed);
        let mut rng = Pcg64::new(self.seed, call.wrapping_add(1));
        let data = t
            .data()
            .iter()
            .map(|&v| if rng.next_f32() < self.fraction { 0.0 } else { v })
            .collect();
        DenseTensor::from_vec(t.shape(), data)
    }
}

/// Scalar-threshold sparsifier (ReLU-style): drop |v| < threshold.
#[derive(Debug, Clone)]
pub struct ScalarThreshold {
    /// Magnitude threshold.
    pub threshold: f32,
}

impl Sparsifier for ScalarThreshold {
    fn name(&self) -> &'static str {
        "scalar_threshold"
    }
    fn kind(&self) -> SparsifierKind {
        SparsifierKind::Streaming
    }
    fn passes(&self) -> usize {
        1
    }
    fn memory(&self) -> MemoryClass {
        MemoryClass::Constant
    }
    fn prune(&self, t: &DenseTensor) -> DenseTensor {
        let tau = self.threshold;
        t.map(|v| if v.abs() < tau { 0.0 } else { v })
    }
}

/// Per-block n:m sparsifier (blocking): keep the `n` largest magnitudes in
/// each block of `m` consecutive values along the row dimension.
#[derive(Debug, Clone)]
pub struct PerBlockNm {
    /// Kept values per block.
    pub n: usize,
    /// Block size.
    pub m: usize,
}

impl Sparsifier for PerBlockNm {
    fn name(&self) -> &'static str {
        "per_block_nm"
    }
    fn kind(&self) -> SparsifierKind {
        SparsifierKind::Blocking
    }
    fn passes(&self) -> usize {
        2
    }
    fn memory(&self) -> MemoryClass {
        MemoryClass::Block
    }
    fn prune(&self, t: &DenseTensor) -> DenseTensor {
        NmTensor::from_dense(t, self.n, self.m).to_dense()
    }
    fn structure_params(&self) -> Option<(usize, usize, usize)> {
        Some((self.n, self.m, 1))
    }
}

/// Grouped n:m sparsifier (§5): prune into n:m:g structure.
#[derive(Debug, Clone)]
pub struct GroupedNm {
    /// Kept values per block.
    pub n: usize,
    /// Block size.
    pub m: usize,
    /// Group size.
    pub g: usize,
}

impl Sparsifier for GroupedNm {
    fn name(&self) -> &'static str {
        "grouped_nm"
    }
    fn kind(&self) -> SparsifierKind {
        SparsifierKind::Blocking
    }
    fn passes(&self) -> usize {
        2
    }
    fn memory(&self) -> MemoryClass {
        MemoryClass::Block
    }
    fn prune(&self, t: &DenseTensor) -> DenseTensor {
        NmgTensor::from_dense(t, self.n, self.m, self.g).to_dense()
    }
    fn structure_params(&self) -> Option<(usize, usize, usize)> {
        Some((self.n, self.m, self.g))
    }
}

/// Scalar-fraction (magnitude) sparsifier: drop the smallest `fraction` of
/// values by magnitude, tensor-wide. The workhorse of §6.2.
#[derive(Debug, Clone)]
pub struct ScalarFraction {
    /// Fraction to drop in [0, 1].
    pub fraction: f32,
}

impl Sparsifier for ScalarFraction {
    fn name(&self) -> &'static str {
        "scalar_fraction"
    }
    fn kind(&self) -> SparsifierKind {
        SparsifierKind::Materializing
    }
    fn passes(&self) -> usize {
        2
    }
    fn memory(&self) -> MemoryClass {
        MemoryClass::Nnz
    }
    fn prune(&self, t: &DenseTensor) -> DenseTensor {
        let drop = ((t.numel() as f64) * self.fraction as f64).round() as usize;
        if drop == 0 {
            return t.clone();
        }
        if drop >= t.numel() {
            return DenseTensor::zeros(t.shape());
        }
        let mut mags: Vec<f32> = t.data().iter().map(|v| v.abs()).collect();
        mags.sort_by(f32::total_cmp);
        let tau = mags[drop - 1];
        // Drop everything strictly below tau, then drop values == tau until
        // the budget is exact (deterministic: first occurrences dropped).
        let mut below = t.data().iter().filter(|v| v.abs() < tau).count();
        let mut out = t.clone();
        for v in out.data_mut().iter_mut() {
            if v.abs() < tau {
                *v = 0.0;
            } else if v.abs() == tau && below < drop {
                *v = 0.0;
                below += 1;
            }
        }
        out
    }
}

/// Block-wise fraction sparsifier: drop the `fraction` of `bh x bw` blocks
/// with the smallest combined absolute magnitude.
#[derive(Debug, Clone)]
pub struct BlockFraction {
    /// Fraction of blocks to drop.
    pub fraction: f32,
    /// Block height.
    pub bh: usize,
    /// Block width.
    pub bw: usize,
}

impl Sparsifier for BlockFraction {
    fn name(&self) -> &'static str {
        "block_fraction"
    }
    fn kind(&self) -> SparsifierKind {
        SparsifierKind::Materializing
    }
    fn passes(&self) -> usize {
        2
    }
    fn memory(&self) -> MemoryClass {
        MemoryClass::Nnz
    }
    fn prune(&self, t: &DenseTensor) -> DenseTensor {
        assert_eq!(t.rank(), 2, "block pruning requires 2-D");
        let (rows, cols) = (t.rows(), t.cols());
        assert!(
            rows % self.bh == 0 && cols % self.bw == 0,
            "shape {rows}x{cols} not divisible by block {}x{}",
            self.bh,
            self.bw
        );
        let (br, bc) = (rows / self.bh, cols / self.bw);
        let mut mass: Vec<(f32, usize)> = (0..br * bc)
            .map(|b| {
                let (i0, j0) = ((b / bc) * self.bh, (b % bc) * self.bw);
                let mut acc = 0f32;
                for i in 0..self.bh {
                    for j in 0..self.bw {
                        acc += t.get2(i0 + i, j0 + j).abs();
                    }
                }
                (acc, b)
            })
            .collect();
        mass.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let drop = ((mass.len() as f64) * self.fraction as f64).round() as usize;
        let mut out = t.clone();
        for &(_, b) in mass.iter().take(drop) {
            let (i0, j0) = ((b / bc) * self.bh, (b % bc) * self.bw);
            for i in 0..self.bh {
                for j in 0..self.bw {
                    out.set2(i0 + i, j0 + j, 0.0);
                }
            }
        }
        out
    }
}

/// Same-format sparsifier (§4): re-sparsify fresh dense values so they match
/// the structure of an existing tensor — used after weight updates so the
/// updated weight keeps its layout (Fig. 2, right).
#[derive(Debug, Clone)]
pub struct SameFormat;

impl SameFormat {
    /// Re-sparsify `fresh` to match the structure of `like`.
    ///
    /// For mask-style formats (Masked) the nonzero *pattern* is reused (the
    /// optimized fixed-pattern path of §4.6); structured formats re-run their
    /// structure-preserving conversion; exact formats recompress.
    pub fn resparsify(&self, like: &AnyTensor, fresh: &DenseTensor) -> Result<AnyTensor> {
        Ok(match like {
            AnyTensor::Masked(mt) => AnyTensor::Masked(mt.with_values(fresh)),
            AnyTensor::Nm(t) => AnyTensor::Nm(NmTensor::from_dense(fresh, t.n, t.m)),
            AnyTensor::Nmg(t) => AnyTensor::Nmg(NmgTensor::from_dense(fresh, t.n, t.m, t.g)),
            AnyTensor::Dense(_) => AnyTensor::Dense(fresh.clone()),
            AnyTensor::Csr(_) => AnyTensor::Csr(CsrTensor::from_dense(fresh)),
            AnyTensor::Csc(_) => AnyTensor::Csc(CscTensor::from_dense(fresh)),
            AnyTensor::Coo(_) => AnyTensor::Coo(CooTensor::from_dense(fresh)),
            AnyTensor::Ell(_) => AnyTensor::Ell(EllTensor::from_dense(fresh)),
            AnyTensor::Bcsr(t) => {
                AnyTensor::Bcsr(crate::formats::BcsrTensor::from_dense(fresh, t.bh, t.bw))
            }
            AnyTensor::Custom(t) => AnyTensor::Custom(t.same_format_from_dense(fresh)),
        })
    }
}

impl Sparsifier for SameFormat {
    fn name(&self) -> &'static str {
        "same_format"
    }
    fn kind(&self) -> SparsifierKind {
        SparsifierKind::Materializing
    }
    fn passes(&self) -> usize {
        1
    }
    fn memory(&self) -> MemoryClass {
        MemoryClass::Nnz
    }
    fn prune(&self, t: &DenseTensor) -> DenseTensor {
        t.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> DenseTensor {
        DenseTensor::from_vec(&[4, 4], (1..=16).map(|i| i as f32 * if i % 2 == 0 { -1.0 } else { 1.0 }).collect())
    }

    #[test]
    fn table1_classification() {
        assert_eq!(KeepAll.kind(), SparsifierKind::Streaming);
        assert_eq!(KeepAll.passes(), 1);
        assert_eq!(RandomFraction::new(0.5, 1).kind(), SparsifierKind::Streaming);
        assert_eq!(ScalarThreshold { threshold: 0.1 }.kind(), SparsifierKind::Streaming);
        assert_eq!(PerBlockNm { n: 2, m: 4 }.kind(), SparsifierKind::Blocking);
        assert_eq!(PerBlockNm { n: 2, m: 4 }.passes(), 2);
        assert_eq!(PerBlockNm { n: 2, m: 4 }.memory(), MemoryClass::Block);
        assert_eq!(ScalarFraction { fraction: 0.5 }.kind(), SparsifierKind::Materializing);
        assert_eq!(ScalarFraction { fraction: 0.5 }.memory(), MemoryClass::Nnz);
        assert_eq!(BlockFraction { fraction: 0.5, bh: 2, bw: 2 }.kind(), SparsifierKind::Materializing);
        assert_eq!(GroupedNm { n: 2, m: 4, g: 4 }.kind(), SparsifierKind::Blocking);
    }

    #[test]
    fn keep_all_is_identity() {
        let t = sample();
        assert_eq!(KeepAll.prune(&t), t);
    }

    #[test]
    fn random_fraction_statistics() {
        let t = DenseTensor::ones(&[100, 100]);
        let s = RandomFraction::new(0.3, 7);
        let pruned = s.prune(&t);
        let frac = pruned.sparsity();
        assert!((frac - 0.3).abs() < 0.02, "observed drop fraction {frac}");
        // Different calls use different randomness.
        let pruned2 = s.prune(&t);
        assert_ne!(pruned.data(), pruned2.data());
    }

    #[test]
    fn threshold_drops_small_values() {
        let t = DenseTensor::from_vec(&[4], vec![0.05, -0.2, 0.0, 1.0]);
        let s = ScalarThreshold { threshold: 0.1 };
        assert_eq!(s.prune(&t).data(), &[0.0, -0.2, 0.0, 1.0]);
    }

    #[test]
    fn scalar_fraction_exact_budget() {
        let t = sample();
        for frac in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let pruned = ScalarFraction { fraction: frac }.prune(&t);
            let dropped = pruned.count_zeros();
            assert_eq!(dropped, (16.0 * frac) as usize, "frac {frac}");
        }
    }

    #[test]
    fn scalar_fraction_handles_ties() {
        let t = DenseTensor::from_vec(&[4], vec![1.0, 1.0, 1.0, 1.0]);
        let pruned = ScalarFraction { fraction: 0.5 }.prune(&t);
        assert_eq!(pruned.count_zeros(), 2);
    }

    #[test]
    fn scalar_fraction_drops_smallest() {
        let t = sample();
        let pruned = ScalarFraction { fraction: 0.5 }.prune(&t);
        // Values 1..=8 dropped, 9..=16 kept (by magnitude).
        for (i, v) in pruned.data().iter().enumerate() {
            if i < 8 {
                assert_eq!(*v, 0.0);
            } else {
                assert_ne!(*v, 0.0);
            }
        }
    }

    #[test]
    fn block_fraction_drops_whole_blocks() {
        let t = sample();
        let pruned = BlockFraction { fraction: 0.5, bh: 2, bw: 2 }.prune(&t);
        // Exactly 2 of the 4 2x2 blocks are zero.
        let mut zero_blocks = 0;
        for bi in 0..2 {
            for bj in 0..2 {
                let all_zero = (0..2).all(|i| (0..2).all(|j| pruned.get2(bi * 2 + i, bj * 2 + j) == 0.0));
                if all_zero {
                    zero_blocks += 1;
                }
            }
        }
        assert_eq!(zero_blocks, 2);
    }

    #[test]
    fn per_block_nm_structure() {
        let t = sample();
        let pruned = PerBlockNm { n: 1, m: 4 }.prune(&t);
        for c in 0..4 {
            let nnz = (0..4).filter(|&r| pruned.get2(r, c) != 0.0).count();
            assert_eq!(nnz, 1);
        }
    }

    #[test]
    fn apply_structured_layouts() {
        let t = AnyTensor::Dense(sample());
        let s = GroupedNm { n: 2, m: 4, g: 1 };
        let out = s.apply(&t, Layout::Nmg).unwrap();
        assert_eq!(out.layout(), Layout::Nmg);
        // Mismatched sparsifier/layout combination errors (like STen's
        // missing-implementation dispatch error).
        let err = KeepAll.apply(&t, Layout::Nmg).unwrap_err().to_string();
        assert!(err.contains("Nmg"), "{err}");
    }

    #[test]
    fn apply_exact_layouts_preserve_pruned_values() {
        let t = AnyTensor::Dense(sample());
        let s = ScalarFraction { fraction: 0.5 };
        let want = s.prune(&sample());
        for layout in [Layout::Csr, Layout::Csc, Layout::Coo, Layout::Ell, Layout::Masked] {
            let out = s.apply(&t, layout).unwrap();
            assert!(out.to_dense().allclose(&want, 0.0, 0.0), "{layout}");
        }
    }

    #[test]
    fn same_format_keeps_mask_pattern() {
        let d = DenseTensor::from_vec(&[4], vec![1.0, 0.0, 2.0, 0.0]);
        let like = AnyTensor::Masked(MaskedTensor::from_dense(&d));
        let fresh = DenseTensor::from_vec(&[4], vec![9.0, 9.0, 9.0, 9.0]);
        let out = SameFormat.resparsify(&like, &fresh).unwrap();
        assert_eq!(out.to_dense().data(), &[9.0, 0.0, 9.0, 0.0]);
    }

    #[test]
    fn same_format_restructures_nmg() {
        let mut rng = crate::util::rng::Pcg64::seeded(80);
        let d = DenseTensor::randn(&[4, 24], &mut rng);
        let like = AnyTensor::Nmg(NmgTensor::from_dense(&d, 2, 4, 2));
        let fresh = DenseTensor::randn(&[4, 24], &mut rng);
        let out = SameFormat.resparsify(&like, &fresh).unwrap();
        match out {
            AnyTensor::Nmg(t) => {
                assert_eq!((t.n, t.m, t.g), (2, 4, 2));
            }
            _ => panic!("expected Nmg"),
        }
    }
}
