//! Complex weight sparsifier (Table 1, last row): movement pruning.
//!
//! Movement pruning (Sanh et al., 2020) scores each weight by `-w * grad`
//! (how much training is "moving" it toward zero) and drops the weights
//! moving fastest toward zero. Unlike magnitude pruning it needs an
//! *additional input* (the gradient), which STen models as a sparsifier
//! whose application is delayed until its extra inputs are ready (§3.3).

use anyhow::{anyhow, Result};

use crate::formats::{AnyTensor, Layout};
use crate::tensor::DenseTensor;

use super::{dense_to_layout, MemoryClass, Sparsifier, SparsifierKind};

/// Movement-pruning sparsifier: requires the gradient as a side input
/// (provided via [`MovementPruning::with_grad`] before `prune` runs).
#[derive(Debug)]
pub struct MovementPruning {
    /// Fraction of weights to drop.
    pub fraction: f32,
    grad: std::sync::Mutex<Option<DenseTensor>>,
}

impl MovementPruning {
    /// New sparsifier; the gradient must be supplied before pruning.
    pub fn new(fraction: f32) -> Self {
        assert!((0.0..=1.0).contains(&fraction));
        MovementPruning { fraction, grad: std::sync::Mutex::new(None) }
    }

    /// Provide the delayed input (the gradient of the loss w.r.t. the
    /// tensor being sparsified).
    pub fn with_grad(self, grad: DenseTensor) -> Self {
        *self.grad.lock().unwrap() = Some(grad);
        self
    }

    /// Set the delayed gradient input in place.
    pub fn set_grad(&self, grad: DenseTensor) {
        *self.grad.lock().unwrap() = Some(grad);
    }

    /// Movement score: `-w * g`. Most-negative movement (weight being pushed
    /// toward zero) prunes first, so we *keep* the highest scores.
    pub fn scores(&self, w: &DenseTensor) -> Result<DenseTensor> {
        let guard = self.grad.lock().unwrap();
        let g = guard
            .as_ref()
            .ok_or_else(|| anyhow!("movement pruning requires a gradient (set_grad)"))?;
        if g.shape() != w.shape() {
            return Err(anyhow!("gradient shape mismatch"));
        }
        Ok(w.zip(g, |wi, gi| -wi * gi))
    }

    /// Apply with explicit output layout (errors if the gradient is missing).
    pub fn apply_checked(&self, t: &AnyTensor, out: Layout) -> Result<AnyTensor> {
        let dense = t.to_dense();
        let scores = self.scores(&dense)?;
        let drop = ((dense.numel() as f64) * self.fraction as f64).round() as usize;
        // Keep the `numel - drop` highest scores.
        let mut order: Vec<usize> = (0..dense.numel()).collect();
        order.sort_by(|&a, &b| scores.data()[a].total_cmp(&scores.data()[b]).then(a.cmp(&b)));
        let mut pruned = dense.clone();
        for &i in order.iter().take(drop) {
            pruned.data_mut()[i] = 0.0;
        }
        dense_to_layout(&pruned, out, None)
    }
}

impl Sparsifier for MovementPruning {
    fn name(&self) -> &'static str {
        "movement_pruning"
    }
    fn kind(&self) -> SparsifierKind {
        SparsifierKind::Materializing
    }
    fn passes(&self) -> usize {
        2
    }
    fn memory(&self) -> MemoryClass {
        MemoryClass::Nnz
    }
    fn prune(&self, t: &DenseTensor) -> DenseTensor {
        // The trait path panics without the gradient; prefer apply_checked.
        let out = self
            .apply_checked(&AnyTensor::Dense(t.clone()), Layout::Dense)
            .expect("movement pruning: gradient not set (use set_grad / apply_checked)");
        out.to_dense()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::Tape;
    use crate::util::rng::Pcg64;

    #[test]
    fn drops_weights_moving_toward_zero() {
        // w > 0 with g > 0 means the update w - lr*g shrinks w: movement
        // score -w*g < 0, so those weights prune first.
        let w = DenseTensor::from_vec(&[4], vec![1.0, 1.0, -1.0, -1.0]);
        let g = DenseTensor::from_vec(&[4], vec![2.0, -2.0, 2.0, -2.0]);
        let s = MovementPruning::new(0.5).with_grad(g);
        let pruned = s.prune(&w);
        // scores: [-2, 2, 2, -2] -> drop indices 0 and 3.
        assert_eq!(pruned.data(), &[0.0, 1.0, -1.0, 0.0]);
    }

    #[test]
    fn requires_gradient() {
        let s = MovementPruning::new(0.5);
        let t = AnyTensor::Dense(DenseTensor::ones(&[2, 2]));
        assert!(s.apply_checked(&t, Layout::Csr).is_err());
    }

    #[test]
    fn classification_is_materializing() {
        let s = MovementPruning::new(0.5);
        assert_eq!(s.kind(), SparsifierKind::Materializing);
        assert_eq!(s.memory(), MemoryClass::Nnz);
        assert_eq!(s.passes(), 2);
    }

    #[test]
    fn integrates_with_autograd_gradients() {
        // End-to-end: gradient from the tape feeds the sparsifier.
        let mut rng = Pcg64::seeded(900);
        let x0 = DenseTensor::randn(&[8, 6], &mut rng);
        let w0 = DenseTensor::randn(&[6, 4], &mut rng);
        let tape = Tape::new();
        let x = tape.input(x0);
        let w = tape.param(w0.clone());
        let y = tape.matmul(x, w);
        let l = tape.mse(y, &DenseTensor::zeros(&[8, 4]));
        tape.backward(l).unwrap();
        let grad = tape.grad(w).unwrap();

        let s = MovementPruning::new(0.5).with_grad(grad);
        let out = s.apply_checked(&AnyTensor::Dense(w0.clone()), Layout::Csr).unwrap();
        assert_eq!(out.layout(), Layout::Csr);
        assert_eq!(out.nnz(), w0.numel() / 2);
        // Kept values match the original weight.
        let d = out.to_dense();
        for (a, b) in d.data().iter().zip(w0.data()) {
            assert!(*a == 0.0 || a == b);
        }
    }
}
