//! Sparsifier implementation registry (§3.3's
//! `register_sparsifier_implementation`).
//!
//! Users register custom `(sparsifier name, input layout, output layout)`
//! implementations; [`super::Sparsifier::apply`]'s built-in path is the
//! default, and the registry overrides it — this is how a performance
//! engineer supplies e.g. a fused dense→CSC random-fraction kernel without
//! touching the framework core.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

use anyhow::Result;

use crate::formats::{AnyTensor, Layout};
use super::Sparsifier;

/// A registered sparsifier implementation.
pub type SparsifierImplFn =
    fn(sparsifier: &dyn Sparsifier, input: &AnyTensor) -> Result<AnyTensor>;

type Key = (&'static str, Layout, Layout);

/// Global registry instance.
pub struct SparsifierRegistry {
    impls: Mutex<HashMap<Key, SparsifierImplFn>>,
}

impl SparsifierRegistry {
    fn new() -> Self {
        SparsifierRegistry { impls: Mutex::new(HashMap::new()) }
    }

    /// Register an implementation (last registration wins, like STen).
    pub fn register(&self, name: &'static str, inp: Layout, out: Layout, f: SparsifierImplFn) {
        self.impls.lock().unwrap().insert((name, inp, out), f);
    }

    /// Look up an implementation.
    pub fn lookup(&self, name: &str, inp: Layout, out: Layout) -> Option<SparsifierImplFn> {
        // Keys are &'static str; compare by value.
        self.impls
            .lock()
            .unwrap()
            .iter()
            .find(|((n, i, o), _)| *n == name && *i == inp && *o == out)
            .map(|(_, f)| *f)
    }

    /// Apply `sparsifier` to `input` producing `out` layout: registered
    /// implementation first, then the sparsifier's built-in `apply`.
    pub fn apply(
        &self,
        sparsifier: &dyn Sparsifier,
        input: &AnyTensor,
        out: Layout,
    ) -> Result<AnyTensor> {
        if let Some(f) = self.lookup(sparsifier.name(), input.layout(), out) {
            return f(sparsifier, input);
        }
        sparsifier.apply(input, out)
    }

    /// Number of registered implementations.
    pub fn len(&self) -> usize {
        self.impls.lock().unwrap().len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The process-wide sparsifier registry.
pub fn sparsifier_registry() -> &'static SparsifierRegistry {
    static REG: OnceLock<SparsifierRegistry> = OnceLock::new();
    REG.get_or_init(SparsifierRegistry::new)
}

/// Convenience free function mirroring STen's decorator.
pub fn register_sparsifier_impl(
    name: &'static str,
    inp: Layout,
    out: Layout,
    f: SparsifierImplFn,
) {
    sparsifier_registry().register(name, inp, out, f);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsify::{KeepAll, ScalarThreshold};
    use crate::tensor::DenseTensor;

    fn custom_impl(_s: &dyn Sparsifier, input: &AnyTensor) -> Result<AnyTensor> {
        // Marker implementation: negate everything (observable in the test).
        Ok(AnyTensor::Dense(input.to_dense().map(|v| -v)))
    }

    #[test]
    fn registered_impl_overrides_builtin() {
        let reg = SparsifierRegistry::new();
        let t = AnyTensor::Dense(DenseTensor::ones(&[2, 2]));
        // Built-in first.
        let out = reg.apply(&KeepAll, &t, Layout::Dense).unwrap();
        assert_eq!(out.to_dense().data(), &[1.0; 4]);
        // Then override.
        reg.register("keep_all", Layout::Dense, Layout::Dense, custom_impl);
        let out = reg.apply(&KeepAll, &t, Layout::Dense).unwrap();
        assert_eq!(out.to_dense().data(), &[-1.0; 4]);
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn lookup_misses_other_combinations() {
        let reg = SparsifierRegistry::new();
        reg.register("keep_all", Layout::Dense, Layout::Csr, custom_impl);
        assert!(reg.lookup("keep_all", Layout::Dense, Layout::Csc).is_none());
        assert!(reg.lookup("scalar_threshold", Layout::Dense, Layout::Csr).is_none());
        assert!(reg.lookup("keep_all", Layout::Dense, Layout::Csr).is_some());
    }

    #[test]
    fn builtin_fallback_still_works_for_unregistered() {
        let reg = SparsifierRegistry::new();
        let t = AnyTensor::Dense(DenseTensor::from_vec(&[1, 2], vec![0.01, 5.0]));
        let out = reg.apply(&ScalarThreshold { threshold: 0.1 }, &t, Layout::Csr).unwrap();
        assert_eq!(out.layout(), Layout::Csr);
        assert_eq!(out.nnz(), 1);
    }

    #[test]
    fn global_registry_is_shared() {
        let before = sparsifier_registry().len();
        register_sparsifier_impl("keep_all", Layout::Coo, Layout::Coo, custom_impl);
        assert!(sparsifier_registry().len() > before || sparsifier_registry().len() == before);
        assert!(sparsifier_registry().lookup("keep_all", Layout::Coo, Layout::Coo).is_some());
    }
}
