//! The dense `f32` tensor.

use super::{numel, strides_for};
use crate::util::rng::Pcg64;
use crate::util::threadpool;

/// A row-major dense `f32` tensor.
///
/// This is deliberately simple: contiguous storage, owned data, no autograd
/// state (gradients are managed by [`crate::autograd`]). It plays the role of
/// `torch.Tensor` in the original STen: the layout every sparsity format
/// converts to and from, and the operand type of the dense fallback path.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseTensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl DenseTensor {
    /// Create from raw data; `data.len()` must equal the product of `shape`.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            data.len(),
            numel(shape),
            "data length {} does not match shape {:?}",
            data.len(),
            shape
        );
        DenseTensor { shape: shape.to_vec(), data }
    }

    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        DenseTensor { shape: shape.to_vec(), data: vec![0.0; numel(shape)] }
    }

    /// All-ones tensor.
    pub fn ones(shape: &[usize]) -> Self {
        Self::full(shape, 1.0)
    }

    /// Constant-filled tensor.
    pub fn full(shape: &[usize], v: f32) -> Self {
        DenseTensor { shape: shape.to_vec(), data: vec![v; numel(shape)] }
    }

    /// Standard-normal initialized tensor (deterministic via `rng`).
    pub fn randn(shape: &[usize], rng: &mut Pcg64) -> Self {
        let data = (0..numel(shape)).map(|_| rng.normal()).collect();
        DenseTensor { shape: shape.to_vec(), data }
    }

    /// Uniform `[lo, hi)` initialized tensor.
    pub fn rand_uniform(shape: &[usize], lo: f32, hi: f32, rng: &mut Pcg64) -> Self {
        let data = (0..numel(shape)).map(|_| rng.uniform(lo, hi)).collect();
        DenseTensor { shape: shape.to_vec(), data }
    }

    /// Kaiming-style init for a `[fan_in, fan_out]` weight.
    pub fn kaiming(shape: &[usize], rng: &mut Pcg64) -> Self {
        let fan_in = shape.first().copied().unwrap_or(1).max(1);
        let std = (2.0 / fan_in as f32).sqrt();
        let data = (0..numel(shape)).map(|_| rng.normal() * std).collect();
        DenseTensor { shape: shape.to_vec(), data }
    }

    /// Tensor shape.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Number of elements.
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Rank (number of dimensions).
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Raw data slice.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Raw mutable data slice.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the raw data vector.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Rows of a 2-D tensor.
    pub fn rows(&self) -> usize {
        assert_eq!(self.rank(), 2, "rows() requires a 2-D tensor, got {:?}", self.shape);
        self.shape[0]
    }

    /// Columns of a 2-D tensor.
    pub fn cols(&self) -> usize {
        assert_eq!(self.rank(), 2, "cols() requires a 2-D tensor, got {:?}", self.shape);
        self.shape[1]
    }

    /// Element access by multi-index.
    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    /// Mutable element access by multi-index.
    pub fn at_mut(&mut self, idx: &[usize]) -> &mut f32 {
        let off = self.offset(idx);
        &mut self.data[off]
    }

    /// 2-D element access.
    #[inline]
    pub fn get2(&self, r: usize, c: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[r * self.shape[1] + c]
    }

    /// 2-D element assignment.
    #[inline]
    pub fn set2(&mut self, r: usize, c: usize, v: f32) {
        debug_assert_eq!(self.rank(), 2);
        let cols = self.shape[1];
        self.data[r * cols + c] = v;
    }

    fn offset(&self, idx: &[usize]) -> usize {
        assert_eq!(idx.len(), self.rank(), "index rank mismatch");
        let strides = strides_for(&self.shape);
        idx.iter().zip(&strides).zip(&self.shape).fold(0, |acc, ((&i, &s), &d)| {
            assert!(i < d, "index {i} out of bounds for dim of size {d}");
            acc + i * s
        })
    }

    /// Reshape (same number of elements).
    pub fn reshape(&self, shape: &[usize]) -> DenseTensor {
        assert_eq!(numel(shape), self.numel(), "reshape {:?} -> {:?}", self.shape, shape);
        DenseTensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    /// 2-D transpose. Large tensors (the train-step backward's per-layer
    /// weight transposes) parallelize over output row blocks; the copy is
    /// element-identical either way.
    pub fn transpose2(&self) -> DenseTensor {
        assert_eq!(self.rank(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0; r * c];
        // Below the shared threshold the S x S attention transposes
        // executed from inside per-(batch, head) pool tasks stay serial
        // rather than opening nested scopes.
        if r * c < threadpool::SERIAL_THRESHOLD {
            for i in 0..r {
                for j in 0..c {
                    out[j * r + i] = self.data[i * c + j];
                }
            }
        } else {
            let src = &self.data;
            let out_ptr = threadpool::SyncPtr::new(out.as_mut_ptr());
            // Output row j is source column j: chunks own disjoint output
            // rows [j0, j1).
            threadpool::parallel_for(c, 16, |j0, j1| {
                // SAFETY: output rows [j0, j1) are written only here.
                let od = unsafe {
                    std::slice::from_raw_parts_mut(out_ptr.get().add(j0 * r), (j1 - j0) * r)
                };
                for j in j0..j1 {
                    let orow = &mut od[(j - j0) * r..(j - j0 + 1) * r];
                    for (i, o) in orow.iter_mut().enumerate() {
                        *o = src[i * c + j];
                    }
                }
            });
        }
        DenseTensor { shape: vec![c, r], data: out }
    }

    /// Elementwise map into a new tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> DenseTensor {
        DenseTensor { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    /// Elementwise zip with another tensor of the same shape.
    pub fn zip(&self, other: &DenseTensor, f: impl Fn(f32, f32) -> f32) -> DenseTensor {
        assert_eq!(self.shape, other.shape, "zip shape mismatch");
        let data = self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect();
        DenseTensor { shape: self.shape.clone(), data }
    }

    /// In-place elementwise update.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    /// In-place `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &DenseTensor) {
        assert_eq!(self.shape, other.shape, "axpy shape mismatch");
        for (x, y) in self.data.iter_mut().zip(&other.data) {
            *x += alpha * y;
        }
    }

    /// Scale all elements.
    pub fn scale(&mut self, alpha: f32) {
        for x in &mut self.data {
            *x *= alpha;
        }
    }

    /// Sum of all elements.
    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    /// L1 norm (sum of absolute values) — the paper's "energy" numerator/denominator.
    pub fn l1_norm(&self) -> f32 {
        self.data.iter().map(|x| x.abs()).sum()
    }

    /// L2 norm.
    pub fn l2_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Maximum absolute element.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// Count of exact zeros.
    pub fn count_zeros(&self) -> usize {
        self.data.iter().filter(|&&x| x == 0.0).count()
    }

    /// Sparsity ratio: zeros / numel.
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.count_zeros() as f64 / self.numel() as f64
    }

    /// Max-abs difference to another tensor (shape-checked).
    pub fn max_abs_diff(&self, other: &DenseTensor) -> f32 {
        assert_eq!(self.shape, other.shape, "compare shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// True if all elements are within `atol + rtol*|other|`.
    pub fn allclose(&self, other: &DenseTensor, rtol: f32, atol: f32) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = DenseTensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.get2(1, 2), 6.0);
        assert_eq!(t.at(&[0, 1]), 2.0);
    }

    #[test]
    #[should_panic(expected = "does not match shape")]
    fn bad_shape_panics() {
        DenseTensor::from_vec(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg64::seeded(1);
        let t = DenseTensor::randn(&[3, 5], &mut rng);
        let tt = t.transpose2().transpose2();
        assert_eq!(t, tt);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = DenseTensor::ones(&[4]);
        let b = DenseTensor::full(&[4], 2.0);
        a.axpy(0.5, &b);
        assert_eq!(a.data(), &[2.0; 4]);
        a.scale(2.0);
        assert_eq!(a.data(), &[4.0; 4]);
    }

    #[test]
    fn norms_and_sparsity() {
        let t = DenseTensor::from_vec(&[4], vec![0.0, -3.0, 0.0, 4.0]);
        assert_eq!(t.l1_norm(), 7.0);
        assert_eq!(t.l2_norm(), 5.0);
        assert_eq!(t.count_zeros(), 2);
        assert!((t.sparsity() - 0.5).abs() < 1e-12);
        assert_eq!(t.max_abs(), 4.0);
    }

    #[test]
    fn allclose_tolerances() {
        let a = DenseTensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = DenseTensor::from_vec(&[2], vec![1.0 + 1e-6, 2.0 - 1e-6]);
        assert!(a.allclose(&b, 1e-5, 1e-5));
        assert!(!a.allclose(&b, 0.0, 1e-8));
    }

    #[test]
    fn reshape_preserves_data() {
        let t = DenseTensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let r = t.reshape(&[4]);
        assert_eq!(r.data(), t.data());
        assert_eq!(r.shape(), &[4]);
    }

    #[test]
    fn kaiming_scale_depends_on_fan_in() {
        let mut rng = Pcg64::seeded(2);
        let w = DenseTensor::kaiming(&[512, 64], &mut rng);
        let var = w.data().iter().map(|x| x * x).sum::<f32>() / w.numel() as f32;
        let expect = 2.0 / 512.0;
        assert!((var - expect).abs() < expect * 0.2, "var {var} expect {expect}");
    }
}
