//! Dense tensor substrate.
//!
//! A minimal row-major `f32` tensor sufficient to host the STen programming
//! model: shape bookkeeping, initialization, element access, elementwise maps
//! and 2-D views. Heavy compute lives in [`crate::kernels`]; this type is the
//! "plain dense layout" end of every sparsity conversion.

mod dense;
pub use dense::DenseTensor;

/// Number of elements implied by a shape.
pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

/// Row-major strides for a shape.
pub fn strides_for(shape: &[usize]) -> Vec<usize> {
    let mut strides = vec![1; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        strides[i] = strides[i + 1] * shape[i + 1];
    }
    strides
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numel_and_strides() {
        assert_eq!(numel(&[2, 3, 4]), 24);
        assert_eq!(strides_for(&[2, 3, 4]), vec![12, 4, 1]);
        assert_eq!(strides_for(&[5]), vec![1]);
        assert_eq!(strides_for(&[]), Vec::<usize>::new());
    }
}
