//! Deterministic synthetic datasets.
//!
//! The paper trains on CIFAR10 and Wikipedia/BookCorpus; our substitutes
//! (DESIGN.md §Substitutions) exercise identical code paths:
//!
//! * [`ClusterDataset`] — CIFAR-shaped classification: one Gaussian cluster
//!   per class in feature space, so accuracy is meaningfully learnable and
//!   pruning-induced degradation is observable.
//! * [`TokenCorpus`] — a deterministic order-1 Markov token stream, so a
//!   language model has real structure to fit (loss decreases well below
//!   the uniform baseline).

use crate::tensor::DenseTensor;
use crate::util::rng::Pcg64;

/// Gaussian-cluster classification dataset.
pub struct ClusterDataset {
    /// Feature dimension.
    pub dim: usize,
    /// Number of classes.
    pub classes: usize,
    centers: Vec<Vec<f32>>,
    noise: f32,
}

impl ClusterDataset {
    /// Create with `classes` unit-norm cluster centers.
    pub fn new(dim: usize, classes: usize, noise: f32, seed: u64) -> Self {
        let mut rng = Pcg64::seeded(seed);
        let centers = (0..classes)
            .map(|_| {
                let mut c: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
                let norm = c.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
                c.iter_mut().for_each(|x| *x /= norm * 0.5); // radius 2
                c
            })
            .collect();
        ClusterDataset { dim, classes, centers, noise }
    }

    /// Sample a batch: (features [n, dim], labels).
    pub fn batch(&self, n: usize, rng: &mut Pcg64) -> (DenseTensor, Vec<usize>) {
        let mut xs = Vec::with_capacity(n * self.dim);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let y = rng.below(self.classes as u32) as usize;
            ys.push(y);
            for j in 0..self.dim {
                xs.push(self.centers[y][j] + self.noise * rng.normal());
            }
        }
        (DenseTensor::from_vec(&[n, self.dim], xs), ys)
    }

    /// Classification accuracy of logits against labels.
    pub fn accuracy(logits: &DenseTensor, labels: &[usize]) -> f64 {
        let (n, c) = (logits.rows(), logits.cols());
        assert_eq!(n, labels.len());
        let mut correct = 0;
        for i in 0..n {
            let row = &logits.data()[i * c..(i + 1) * c];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(j, _)| j)
                .unwrap();
            if pred == labels[i] {
                correct += 1;
            }
        }
        correct as f64 / n as f64
    }
}

/// Deterministic order-1 Markov token stream over a vocabulary.
pub struct TokenCorpus {
    /// Vocabulary size.
    pub vocab: usize,
    /// Per-token successor table (`branch` choices each).
    successors: Vec<Vec<u32>>,
    branch: usize,
}

impl TokenCorpus {
    /// Create a corpus where each token has `branch` plausible successors.
    pub fn new(vocab: usize, branch: usize, seed: u64) -> Self {
        let mut rng = Pcg64::seeded(seed);
        let successors = (0..vocab)
            .map(|_| (0..branch).map(|_| rng.below(vocab as u32)).collect())
            .collect();
        TokenCorpus { vocab, successors, branch }
    }

    /// Sample `(tokens, targets)` batches of shape [batch, seq]; targets are
    /// next tokens.
    pub fn batch(&self, batch: usize, seq: usize, rng: &mut Pcg64) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut t = rng.below(self.vocab as u32);
            for _ in 0..seq {
                tokens.push(t as i32);
                let next = self.successors[t as usize][rng.below(self.branch as u32) as usize];
                targets.push(next as i32);
                t = next;
            }
        }
        (tokens, targets)
    }

    /// Entropy lower bound on achievable loss: ln(branch) nats (uniform over
    /// successors), versus ln(vocab) for an untrained model.
    pub fn loss_floor(&self) -> f64 {
        (self.branch as f64).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clusters_are_learnable_by_nearest_center() {
        let ds = ClusterDataset::new(16, 4, 0.2, 1);
        let mut rng = Pcg64::seeded(2);
        let (x, y) = ds.batch(200, &mut rng);
        // Nearest-center classification should be nearly perfect at low noise.
        let mut correct = 0;
        for i in 0..200 {
            let row = &x.data()[i * 16..(i + 1) * 16];
            let best = (0..4)
                .min_by(|&a, &b| {
                    let da: f32 = row.iter().zip(&ds.centers[a]).map(|(u, v)| (u - v) * (u - v)).sum();
                    let db: f32 = row.iter().zip(&ds.centers[b]).map(|(u, v)| (u - v) * (u - v)).sum();
                    da.total_cmp(&db)
                })
                .unwrap();
            if best == y[i] {
                correct += 1;
            }
        }
        assert!(correct > 180, "nearest-center acc {correct}/200");
    }

    #[test]
    fn accuracy_helper() {
        let logits = DenseTensor::from_vec(&[2, 3], vec![1.0, 5.0, 0.0, 9.0, 1.0, 2.0]);
        assert_eq!(ClusterDataset::accuracy(&logits, &[1, 0]), 1.0);
        assert_eq!(ClusterDataset::accuracy(&logits, &[0, 0]), 0.5);
    }

    #[test]
    fn corpus_tokens_in_range_and_markov() {
        let c = TokenCorpus::new(64, 4, 3);
        let mut rng = Pcg64::seeded(4);
        let (tokens, targets) = c.batch(2, 32, &mut rng);
        assert_eq!(tokens.len(), 64);
        assert!(tokens.iter().all(|&t| (0..64).contains(&t)));
        // Every target is a legal successor of its token.
        for (t, n) in tokens.iter().zip(&targets) {
            assert!(c.successors[*t as usize].contains(&(*n as u32)));
        }
        assert!(c.loss_floor() < (64f64).ln());
    }

    #[test]
    fn batches_are_deterministic_per_seed() {
        let c = TokenCorpus::new(32, 2, 5);
        let (a, _) = c.batch(1, 16, &mut Pcg64::seeded(9));
        let (b, _) = c.batch(1, 16, &mut Pcg64::seeded(9));
        assert_eq!(a, b);
    }
}
