//! Masked sparse training (Fig. 9 semantics).
//!
//! Training uses dense weights + 0/1 masks (emulated sparsity, §2). Each
//! step: forward/backward on the masked weights, SGD update, then re-apply
//! masks (the `SameFormatSparsifier` of Fig. 2). Masks are *fixed* between
//! pruning events (cheap) and *recomputed* by a sparsifier at events
//! (expensive for structured formats) — the two bars of Fig. 9.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::autograd::Tape;
use crate::formats::{MaskedTensor, NmTensor, NmgTensor};
use crate::model::MlpSpec;
use crate::sparsify::{ScalarFraction, Sparsifier};
use crate::tensor::DenseTensor;
use crate::train::schedule::PruneEvent;

/// Mask format used when a pruning event recomputes masks — the Fig. 9
/// format axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MaskFormat {
    /// Unstructured magnitude (scalar fraction).
    Unstructured,
    /// Plain n:m (n chosen per sparsity: keep round(m*(1-s)) of m).
    Nm {
        /// Block size.
        m: usize,
    },
    /// Grouped n:m (§5).
    Nmg {
        /// Block size.
        m: usize,
        /// Group size.
        g: usize,
    },
}

/// Masked-MLP trainer: dense params + masks, tape autograd, SGD.
pub struct MaskedTrainer {
    /// Model spec.
    pub spec: MlpSpec,
    /// Dense parameters by name.
    pub params: BTreeMap<String, DenseTensor>,
    /// Masks for prunable (2-D) weights.
    pub masks: BTreeMap<String, MaskedTensor>,
    /// Learning rate.
    pub lr: f32,
    /// Mask format used at pruning events.
    pub format: MaskFormat,
}

impl MaskedTrainer {
    /// New trainer with all-ones masks (dense start).
    pub fn new(spec: MlpSpec, params: BTreeMap<String, DenseTensor>, lr: f32, format: MaskFormat) -> Self {
        let masks = spec
            .prunable_weights()
            .into_iter()
            .map(|name| {
                let shape = params[&name].shape().to_vec();
                (name, MaskedTensor::new(DenseTensor::ones(&shape), DenseTensor::ones(&shape)))
            })
            .collect();
        MaskedTrainer { spec, params, masks, lr, format }
    }

    /// Current masked view of a weight.
    fn masked_param(&self, name: &str) -> DenseTensor {
        match self.masks.get(name) {
            Some(m) => self.params[name].zip(m.mask(), |v, mk| v * mk),
            None => self.params[name].clone(),
        }
    }

    /// One training step: forward/backward/update with fixed masks.
    /// Returns the loss.
    pub fn step(&mut self, x: &DenseTensor, labels: &[usize]) -> Result<f32> {
        // Build masked parameter set for the forward pass.
        let mut masked: BTreeMap<String, DenseTensor> = BTreeMap::new();
        for name in self.spec.weight_names() {
            masked.insert(name.clone(), self.masked_param(&name));
        }
        let tape = Tape::new();
        let (logits, vars) = self.spec.forward_tape(&tape, &masked, x.clone());
        let loss = tape.softmax_cross_entropy(logits, labels);
        let loss_val = tape.value(loss).data()[0];
        tape.backward(loss)?;
        let pvars: Vec<_> = vars.values().copied().collect();
        tape.sgd_step(&pvars, self.lr);
        // Write back, re-applying masks (SameFormatSparsifier semantics).
        for (name, v) in &vars {
            let updated = tape.value(*v);
            let stored = match self.masks.get(name) {
                Some(m) => updated.zip(m.mask(), |x, mk| x * mk),
                None => updated,
            };
            self.params.insert(name.clone(), stored);
        }
        Ok(loss_val)
    }

    /// Apply a pruning event: recompute masks for the named layers (or all)
    /// at `event.sparsity` using the configured format.
    pub fn apply_event(&mut self, event: &PruneEvent) {
        let names = self.spec.prunable_weights();
        let targets: Vec<String> = if event.layers.is_empty() {
            names
        } else {
            event.layers.iter().map(|&i| names[i].clone()).collect()
        };
        for name in targets {
            let w = self.params[&name].clone();
            let mask = compute_mask(&w, event.sparsity, self.format);
            // Store pre-masked weights + mask.
            self.masks.insert(name.clone(), MaskedTensor::new(w.clone(), mask));
            self.params.insert(name.clone(), self.masked_param(&name));
        }
    }

    /// Evaluation: logits for a batch (masked weights).
    pub fn logits(&self, x: &DenseTensor) -> DenseTensor {
        let mut masked: BTreeMap<String, DenseTensor> = BTreeMap::new();
        for name in self.spec.weight_names() {
            masked.insert(name.clone(), self.masked_param(&name));
        }
        let tape = Tape::new();
        let (logits, _) = self.spec.forward_tape(&tape, &masked, x.clone());
        tape.value(logits)
    }

    /// Overall sparsity of the prunable weights.
    pub fn sparsity(&self) -> f64 {
        let (mut zeros, mut total) = (0usize, 0usize);
        for name in self.spec.prunable_weights() {
            let w = self.masked_param(&name);
            zeros += w.count_zeros();
            total += w.numel();
        }
        zeros as f64 / total.max(1) as f64
    }
}

/// Compute a 0/1 mask for `w` at `sparsity` under `format` — the Fig. 9
/// "new sparsification" cost.
pub fn compute_mask(w: &DenseTensor, sparsity: f32, format: MaskFormat) -> DenseTensor {
    let pruned = match format {
        MaskFormat::Unstructured => ScalarFraction { fraction: sparsity }.prune(w),
        MaskFormat::Nm { m } => {
            let n = keep_of(m, sparsity);
            NmTensor::from_dense(&pad_rows(w, m), n, m).to_dense().reshape_back(w)
        }
        MaskFormat::Nmg { m, g } => {
            let n = keep_of(m, sparsity);
            NmgTensor::from_dense(&pad_rows(w, m), n, m, g).to_dense().reshape_back(w)
        }
    };
    pruned.map(|v| if v != 0.0 { 1.0 } else { 0.0 })
}

fn keep_of(m: usize, sparsity: f32) -> usize {
    (((1.0 - sparsity) * m as f32).round() as usize).clamp(1, m)
}

/// Zero-pad rows up to a multiple of `m` (structured formats need it).
fn pad_rows(w: &DenseTensor, m: usize) -> DenseTensor {
    let rows = w.rows();
    let cols = w.cols();
    let padded = rows.div_ceil(m) * m;
    if padded == rows {
        return w.clone();
    }
    let mut out = DenseTensor::zeros(&[padded, cols]);
    out.data_mut()[..rows * cols].copy_from_slice(w.data());
    out
}

trait ReshapeBack {
    fn reshape_back(self, like: &DenseTensor) -> DenseTensor;
}

impl ReshapeBack for DenseTensor {
    /// Drop padding rows to recover `like`'s shape.
    fn reshape_back(self, like: &DenseTensor) -> DenseTensor {
        if self.shape() == like.shape() {
            return self;
        }
        let (rows, cols) = (like.rows(), like.cols());
        DenseTensor::from_vec(&[rows, cols], self.data()[..rows * cols].to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::data::ClusterDataset;
    use crate::train::schedule::PruneSchedule;
    use crate::util::rng::Pcg64;

    fn setup(format: MaskFormat) -> (MaskedTrainer, ClusterDataset, Pcg64) {
        let spec = MlpSpec { input_dim: 16, hidden: vec![32], classes: 4 };
        let mut rng = Pcg64::seeded(700);
        let params = spec.init(&mut rng);
        let trainer = MaskedTrainer::new(spec, params, 0.2, format);
        let ds = ClusterDataset::new(16, 4, 0.3, 1);
        (trainer, ds, rng)
    }

    #[test]
    fn dense_training_learns() {
        let (mut t, ds, mut rng) = setup(MaskFormat::Unstructured);
        let mut losses = Vec::new();
        for _ in 0..40 {
            let (x, y) = ds.batch(32, &mut rng);
            losses.push(t.step(&x, &y).unwrap());
        }
        assert!(losses.last().unwrap() < &(losses[0] * 0.5), "{losses:?}");
        let (x, y) = ds.batch(128, &mut rng);
        let acc = ClusterDataset::accuracy(&t.logits(&x), &y);
        assert!(acc > 0.8, "accuracy {acc}");
    }

    #[test]
    fn pruning_event_sets_sparsity_and_masks_hold() {
        let (mut t, ds, mut rng) = setup(MaskFormat::Unstructured);
        for _ in 0..10 {
            let (x, y) = ds.batch(32, &mut rng);
            t.step(&x, &y).unwrap();
        }
        t.apply_event(&PruneEvent { layers: Vec::new(), sparsity: 0.5 });
        let s = t.sparsity();
        assert!((s - 0.5).abs() < 0.02, "sparsity {s}");
        // Masks survive further training steps.
        for _ in 0..10 {
            let (x, y) = ds.batch(32, &mut rng);
            t.step(&x, &y).unwrap();
        }
        let s = t.sparsity();
        assert!(s >= 0.49, "sparsity after steps {s}");
    }

    #[test]
    fn sparse_fine_tuning_recovers_accuracy() {
        let (mut t, ds, mut rng) = setup(MaskFormat::Unstructured);
        for _ in 0..60 {
            let (x, y) = ds.batch(32, &mut rng);
            t.step(&x, &y).unwrap();
        }
        let (xe, ye) = ds.batch(256, &mut rng);
        let dense_acc = ClusterDataset::accuracy(&t.logits(&xe), &ye);
        t.apply_event(&PruneEvent { layers: Vec::new(), sparsity: 0.5 });
        for _ in 0..60 {
            let (x, y) = ds.batch(32, &mut rng);
            t.step(&x, &y).unwrap();
        }
        let sparse_acc = ClusterDataset::accuracy(&t.logits(&xe), &ye);
        assert!(
            sparse_acc >= dense_acc - 0.08,
            "sparse {sparse_acc} vs dense {dense_acc}"
        );
        assert!(t.sparsity() >= 0.49);
    }

    #[test]
    fn nm_and_nmg_masks_have_block_structure() {
        let mut rng = Pcg64::seeded(701);
        let w = DenseTensor::randn(&[16, 24], &mut rng);
        for format in [MaskFormat::Nm { m: 4 }, MaskFormat::Nmg { m: 4, g: 2 }] {
            let mask = compute_mask(&w, 0.5, format);
            assert_eq!(mask.shape(), w.shape());
            for s in 0..4 {
                for c in 0..24 {
                    let nnz = (0..4).filter(|&i| mask.get2(s * 4 + i, c) != 0.0).count();
                    assert!(nnz <= 2, "{format:?} block nnz {nnz}");
                }
            }
        }
    }

    #[test]
    fn mask_handles_non_divisible_rows() {
        let mut rng = Pcg64::seeded(702);
        let w = DenseTensor::randn(&[10, 8], &mut rng); // 10 % 4 != 0
        let mask = compute_mask(&w, 0.5, MaskFormat::Nm { m: 4 });
        assert_eq!(mask.shape(), &[10, 8]);
    }

    #[test]
    fn layer_wise_schedule_drives_trainer() {
        let (mut t, ds, mut rng) = setup(MaskFormat::Unstructured);
        let sched = PruneSchedule::LayerWise { every: 15, sparsity: 0.5, layers: 2 };
        for step in 0..45 {
            if let Some(e) = sched.event_at(step) {
                t.apply_event(&e);
            }
            let (x, y) = ds.batch(32, &mut rng);
            t.step(&x, &y).unwrap();
        }
        assert!(t.sparsity() > 0.4, "sparsity {}", t.sparsity());
    }
}
