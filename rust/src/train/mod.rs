//! Sparse training (§2, §6): masked fine-tuning, pruning schedules, data.
//!
//! * [`data`] — deterministic synthetic datasets (CIFAR-shaped clusters for
//!   the §6.2 study, token corpus for the transformer example).
//! * [`schedule`] — one-shot / iterative / layer-wise magnitude pruning
//!   schedules (§6.2, Table 2 / Fig. 12).
//! * [`masked`] — masked sparse training of an MLP via tape autograd, with
//!   fixed-mask vs recompute-mask step costs (Fig. 9).

pub mod data;
pub mod schedule;
pub mod masked;
pub mod optim;

pub use masked::MaskedTrainer;
pub use optim::{Adam, Sgd};
pub use schedule::{PruneEvent, PruneSchedule};
