//! Optimizers: SGD with momentum and Adam, with mask-aware updates.
//!
//! The STen training path updates weights out-of-place and re-sparsifies
//! (Fig. 2); these optimizers expose exactly that contract: `step` takes
//! `(param, grad, mask)` and returns the updated, re-masked parameter.

use std::collections::BTreeMap;

use crate::tensor::DenseTensor;

/// SGD with optional momentum.
pub struct Sgd {
    /// Learning rate.
    pub lr: f32,
    /// Momentum coefficient (0 disables).
    pub momentum: f32,
    velocity: BTreeMap<String, DenseTensor>,
}

impl Sgd {
    /// Plain SGD.
    pub fn new(lr: f32) -> Self {
        Sgd { lr, momentum: 0.0, velocity: BTreeMap::new() }
    }

    /// SGD with momentum.
    pub fn with_momentum(lr: f32, momentum: f32) -> Self {
        Sgd { lr, momentum, velocity: BTreeMap::new() }
    }

    /// One update; `mask` (if any) re-sparsifies the result.
    pub fn step(
        &mut self,
        name: &str,
        param: &DenseTensor,
        grad: &DenseTensor,
        mask: Option<&DenseTensor>,
    ) -> DenseTensor {
        let update = if self.momentum > 0.0 {
            let v = self
                .velocity
                .entry(name.to_string())
                .or_insert_with(|| DenseTensor::zeros(param.shape()));
            v.scale(self.momentum);
            v.axpy(1.0, grad);
            v.clone()
        } else {
            grad.clone()
        };
        let mut out = param.clone();
        out.axpy(-self.lr, &update);
        if let Some(m) = mask {
            out = out.zip(m, |x, mk| x * mk);
        }
        out
    }
}

/// Adam (Kingma & Ba) with bias correction.
pub struct Adam {
    /// Learning rate.
    pub lr: f32,
    /// First-moment decay.
    pub beta1: f32,
    /// Second-moment decay.
    pub beta2: f32,
    /// Numerical epsilon.
    pub eps: f32,
    m: BTreeMap<String, DenseTensor>,
    v: BTreeMap<String, DenseTensor>,
    t: BTreeMap<String, u32>,
}

impl Adam {
    /// Adam with standard betas.
    pub fn new(lr: f32) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: BTreeMap::new(),
            v: BTreeMap::new(),
            t: BTreeMap::new(),
        }
    }

    /// One update; `mask` (if any) re-sparsifies the result.
    pub fn step(
        &mut self,
        name: &str,
        param: &DenseTensor,
        grad: &DenseTensor,
        mask: Option<&DenseTensor>,
    ) -> DenseTensor {
        let m = self
            .m
            .entry(name.to_string())
            .or_insert_with(|| DenseTensor::zeros(param.shape()));
        let v = self
            .v
            .entry(name.to_string())
            .or_insert_with(|| DenseTensor::zeros(param.shape()));
        let t = self.t.entry(name.to_string()).or_insert(0);
        *t += 1;
        let (b1, b2) = (self.beta1, self.beta2);
        for ((mi, vi), &gi) in m.data_mut().iter_mut().zip(v.data_mut()).zip(grad.data()) {
            *mi = b1 * *mi + (1.0 - b1) * gi;
            *vi = b2 * *vi + (1.0 - b2) * gi * gi;
        }
        let bc1 = 1.0 - b1.powi(*t as i32);
        let bc2 = 1.0 - b2.powi(*t as i32);
        let mut out = param.clone();
        for ((o, &mi), &vi) in out.data_mut().iter_mut().zip(m.data()).zip(v.data()) {
            let mhat = mi / bc1;
            let vhat = vi / bc2;
            *o -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
        if let Some(mk) = mask {
            out = out.zip(mk, |x, mv| x * mv);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Minimize f(w) = ||w - target||^2 with each optimizer.
    fn converges(mut step: impl FnMut(&DenseTensor, &DenseTensor) -> DenseTensor) -> f32 {
        let mut rng = Pcg64::seeded(1);
        let target = DenseTensor::randn(&[16], &mut rng);
        let mut w = DenseTensor::zeros(&[16]);
        for _ in 0..200 {
            let grad = w.zip(&target, |wi, ti| 2.0 * (wi - ti));
            w = step(&w, &grad);
        }
        w.max_abs_diff(&target)
    }

    #[test]
    fn sgd_converges() {
        let mut opt = Sgd::new(0.1);
        let err = converges(|w, g| opt.step("w", w, g, None));
        assert!(err < 1e-3, "err {err}");
    }

    #[test]
    fn momentum_converges() {
        let mut opt = Sgd::with_momentum(0.05, 0.9);
        let err = converges(|w, g| opt.step("w", w, g, None));
        assert!(err < 1e-2, "err {err}");
    }

    #[test]
    fn adam_converges() {
        let mut opt = Adam::new(0.1);
        let err = converges(|w, g| opt.step("w", w, g, None));
        assert!(err < 1e-2, "err {err}");
    }

    #[test]
    fn masked_updates_stay_masked() {
        let mask = DenseTensor::from_vec(&[4], vec![1.0, 0.0, 1.0, 0.0]);
        let w = DenseTensor::from_vec(&[4], vec![1.0, 0.0, 1.0, 0.0]);
        let g = DenseTensor::ones(&[4]);
        let mut sgd = Sgd::new(0.5);
        let out = sgd.step("w", &w, &g, Some(&mask));
        assert_eq!(out.data()[1], 0.0);
        assert_eq!(out.data()[3], 0.0);
        assert!(out.data()[0] < 1.0);
        let mut adam = Adam::new(0.5);
        let out = adam.step("w", &w, &g, Some(&mask));
        assert_eq!(out.data()[1], 0.0);
        assert_eq!(out.data()[3], 0.0);
    }

    #[test]
    fn adam_state_is_per_parameter() {
        let mut adam = Adam::new(0.1);
        let w = DenseTensor::ones(&[2]);
        let g = DenseTensor::ones(&[2]);
        adam.step("a", &w, &g, None);
        adam.step("a", &w, &g, None);
        adam.step("b", &w, &g, None);
        assert_eq!(adam.t["a"], 2);
        assert_eq!(adam.t["b"], 1);
    }
}
