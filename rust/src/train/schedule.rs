//! Pruning schedules (§6.2): one-shot, iterative, and layer-wise magnitude
//! pruning. Each schedule is a few lines of "when to re-sparsify what to
//! which sparsity" — the paper's Table 2 measures exactly this brevity.

/// A sparsification action at some step.
#[derive(Debug, Clone, PartialEq)]
pub struct PruneEvent {
    /// Which prunable weights to (re)prune: indices into the model's
    /// prunable-weight list. Empty means "all".
    pub layers: Vec<usize>,
    /// Target sparsity for those weights.
    pub sparsity: f32,
}

/// The three §6.2 schedules.
#[derive(Debug, Clone)]
pub enum PruneSchedule {
    /// Prune everything to `sparsity` once at `at_step`, then fine-tune.
    OneShot {
        /// Step of the single pruning event.
        at_step: usize,
        /// Target sparsity.
        sparsity: f32,
    },
    /// Start at `start` sparsity, add `step` every `every` steps until
    /// `target` (pruning all layers each time).
    Iterative {
        /// Initial sparsity.
        start: f32,
        /// Sparsity increment per event.
        step: f32,
        /// Steps between events.
        every: usize,
        /// Final sparsity.
        target: f32,
    },
    /// Prune layer `k` at step `k * every` to `sparsity`, in order.
    LayerWise {
        /// Steps between layers.
        every: usize,
        /// Per-layer target sparsity.
        sparsity: f32,
        /// Number of prunable layers.
        layers: usize,
    },
}

impl PruneSchedule {
    /// The pruning event at `step`, if any.
    pub fn event_at(&self, step: usize) -> Option<PruneEvent> {
        match self {
            PruneSchedule::OneShot { at_step, sparsity } => (step == *at_step)
                .then(|| PruneEvent { layers: Vec::new(), sparsity: *sparsity }),
            PruneSchedule::Iterative { start, step: inc, every, target } => {
                if *every == 0 || step % every != 0 {
                    return None;
                }
                let k = step / every;
                let s = start + inc * k as f32;
                if s > *target + 1e-6 {
                    return None;
                }
                Some(PruneEvent { layers: Vec::new(), sparsity: s.min(*target) })
            }
            PruneSchedule::LayerWise { every, sparsity, layers } => {
                if *every == 0 || step % every != 0 {
                    return None;
                }
                let k = step / every;
                (k < *layers).then(|| PruneEvent { layers: vec![k], sparsity: *sparsity })
            }
        }
    }

    /// Final sparsity the schedule reaches.
    pub fn final_sparsity(&self) -> f32 {
        match self {
            PruneSchedule::OneShot { sparsity, .. } => *sparsity,
            PruneSchedule::Iterative { target, .. } => *target,
            PruneSchedule::LayerWise { sparsity, .. } => *sparsity,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_shot_fires_once() {
        let s = PruneSchedule::OneShot { at_step: 5, sparsity: 0.5 };
        assert_eq!(s.event_at(4), None);
        let e = s.event_at(5).unwrap();
        assert!(e.layers.is_empty());
        assert_eq!(e.sparsity, 0.5);
        assert_eq!(s.event_at(6), None);
        assert_eq!(s.final_sparsity(), 0.5);
    }

    #[test]
    fn iterative_ramps_to_target() {
        let s = PruneSchedule::Iterative { start: 0.1, step: 0.1, every: 10, target: 0.5 };
        let events: Vec<(usize, f32)> = (0..200)
            .filter_map(|t| s.event_at(t).map(|e| (t, e.sparsity)))
            .collect();
        assert_eq!(events.len(), 5);
        assert_eq!(events[0], (0, 0.1));
        assert!((events[4].1 - 0.5).abs() < 1e-6);
        assert_eq!(events[4].0, 40);
        // No events past the target.
        assert!(s.event_at(50).is_none());
    }

    #[test]
    fn layer_wise_walks_layers_in_order() {
        let s = PruneSchedule::LayerWise { every: 30, sparsity: 0.5, layers: 3 };
        assert_eq!(s.event_at(0).unwrap().layers, vec![0]);
        assert_eq!(s.event_at(30).unwrap().layers, vec![1]);
        assert_eq!(s.event_at(60).unwrap().layers, vec![2]);
        assert!(s.event_at(90).is_none());
        assert!(s.event_at(31).is_none());
    }
}
